//! Minimal vendored subset of the `anyhow` API.
//!
//! The offline build environment has no crates.io access, so this crate
//! reimplements exactly the surface the workspace uses: [`Error`],
//! [`Result`], the [`anyhow!`]/[`bail!`]/[`ensure!`] macros, and the
//! [`Context`] extension trait for `Result` and `Option`.
//!
//! Causes are flattened to strings at conversion time (no source-chain
//! downcasting), which is all the coordinator needs for its diagnostics.

use std::error::Error as StdError;
use std::fmt;

/// A dynamic error: an outermost description plus a flattened cause chain.
pub struct Error {
    /// `chain[0]` is the outermost context; deeper causes follow.
    chain: Vec<String>,
}

/// `Result<T, anyhow::Error>`, with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap the error with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context/cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` prints the whole chain, anyhow-style.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// NOTE: like real anyhow, `Error` must NOT implement `std::error::Error` —
// this blanket conversion would conflict with the reflexive `From`.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(context)
        })
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(f())
        })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any `Display` value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)+) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)+))
    };
}

/// Return early with an [`Error`] if the condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)+) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($t)+));
        }
    };
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = io_err().into();
        let e = e.context("loading config");
        assert_eq!(format!("{e}"), "loading config");
        assert_eq!(format!("{e:#}"), "loading config: missing file");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("step {}", 3)).unwrap_err();
        assert_eq!(format!("{e:#}"), "step 3: missing file");
        let o: Option<u8> = None;
        assert_eq!(format!("{}", o.context("empty").unwrap_err()), "empty");
    }

    #[test]
    fn macros_build_errors() {
        let x = 7;
        let e = anyhow!("bad value {x}");
        assert_eq!(format!("{e}"), "bad value 7");
        let e = anyhow!("bad {} of {}", "kind", 2);
        assert_eq!(format!("{e}"), "bad kind of 2");
        fn f(ok: bool) -> Result<u8> {
            ensure!(ok, "not ok: {}", 1);
            Ok(1)
        }
        assert!(f(false).is_err());
        assert_eq!(f(true).unwrap(), 1);
        fn g() -> Result<u8> {
            bail!("always");
        }
        assert!(g().is_err());
    }
}
