//! Minimal vendored subset of the `log` facade.
//!
//! Reimplements the surface this workspace uses — the five level macros,
//! [`Log`]/[`Record`]/[`Metadata`], [`set_logger`], and the max-level
//! switch — because the offline build environment has no crates.io
//! access. Semantics mirror the real facade: logging is a no-op until a
//! logger is installed and a max level is set.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Verbosity level of a record (most to least severe).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

/// Level filter installed via [`set_max_level`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        (*self as usize) == (*other as usize)
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

/// Metadata of a record (just the level in this subset).
pub struct Metadata {
    level: Level,
}

impl Metadata {
    pub fn level(&self) -> Level {
        self.level
    }
}

/// One log record: metadata, the emitting module (`target`), and
/// preformatted arguments.
pub struct Record<'a> {
    metadata: Metadata,
    target: &'static str,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn metadata(&self) -> &Metadata {
        &self.metadata
    }

    /// The module that emitted this record (`module_path!` at the macro
    /// call site — same as the real facade's default target).
    pub fn target(&self) -> &'static str {
        self.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// A logging backend.
pub trait Log: Send + Sync {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);
static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();

/// Error returned when a logger is already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "a logger is already installed")
    }
}

/// Install the global logger (first caller wins).
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// Set the global maximum level.
pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

/// The current global maximum level.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        0 => LevelFilter::Off,
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        _ => LevelFilter::Trace,
    }
}

/// Macro plumbing — not public API.
#[doc(hidden)]
pub fn __log(level: Level, target: &'static str, args: fmt::Arguments) {
    if (level as usize) > (max_level() as usize) {
        return;
    }
    if let Some(logger) = LOGGER.get().copied() {
        let record = Record { metadata: Metadata { level }, target, args };
        if logger.enabled(&record.metadata) {
            logger.log(&record);
        }
    }
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::__log($crate::Level::Error, module_path!(), format_args!($($arg)+)) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::__log($crate::Level::Warn, module_path!(), format_args!($($arg)+)) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::__log($crate::Level::Info, module_path!(), format_args!($($arg)+)) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::__log($crate::Level::Debug, module_path!(), format_args!($($arg)+)) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::__log($crate::Level::Trace, module_path!(), format_args!($($arg)+)) };
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    static HITS: AtomicUsize = AtomicUsize::new(0);

    struct Counter;

    impl Log for Counter {
        fn enabled(&self, _m: &Metadata) -> bool {
            true
        }
        fn log(&self, _r: &Record) {
            HITS.fetch_add(1, Ordering::Relaxed);
        }
        fn flush(&self) {}
    }

    static COUNTER: Counter = Counter;

    #[test]
    fn levels_compare_to_filters() {
        assert!(Level::Info <= LevelFilter::Info);
        assert!(Level::Debug > LevelFilter::Info);
        assert!(Level::Error <= LevelFilter::Trace);
    }

    #[test]
    fn macros_respect_max_level() {
        let _ = set_logger(&COUNTER);
        set_max_level(LevelFilter::Info);
        let before = HITS.load(Ordering::Relaxed);
        info!("counted {}", 1);
        debug!("not counted");
        assert_eq!(HITS.load(Ordering::Relaxed), before + 1);
    }

    #[test]
    fn records_carry_the_call_site_module() {
        struct Probe;
        static SEEN: AtomicUsize = AtomicUsize::new(0);
        impl Log for Probe {
            fn enabled(&self, _m: &Metadata) -> bool {
                true
            }
            fn log(&self, r: &Record) {
                if r.target().ends_with("::tests") {
                    SEEN.fetch_add(1, Ordering::Relaxed);
                }
            }
            fn flush(&self) {}
        }
        static PROBE: Probe = Probe;
        // Either this test's Probe or the sibling test's Counter is the
        // installed logger (first set_logger wins); only assert when we
        // won the race.
        if set_logger(&PROBE).is_ok() {
            set_max_level(LevelFilter::Info);
            info!("probe");
            assert_eq!(SEEN.load(Ordering::Relaxed), 1);
        }
    }
}
