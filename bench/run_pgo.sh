#!/usr/bin/env bash
# Profile-guided-optimization build of the pogo benches.
#
# Three phases:
#   1. instrumented build (-Cprofile-generate), run the hot benches in the
#      quick profile to collect .profraw samples;
#   2. merge the samples with llvm-profdata;
#   3. optimized rebuild (-Cprofile-use) and a re-run so the printed
#      tables + BENCH_*.json reflect the PGO binary.
#
# Usage:  bench/run_pgo.sh [extra-bench-names...]
#   PGO_DIR=/tmp/pogo-pgo  override the profile-data scratch directory.
#   POGO_BENCH_QUICK=1     is set for the collection phase only; the final
#                          run uses the full sweep unless you export it.
#
# Requires llvm-profdata matching the rustc LLVM (ships with the
# `llvm-tools` rustup component: `rustup component add llvm-tools`).

set -euo pipefail
cd "$(dirname "$0")/.."

PGO_DIR="${PGO_DIR:-$(pwd)/target/pgo-data}"
BENCHES=(step_kernels pool_dispatch "$@")

if ! command -v cargo >/dev/null; then
  echo "error: cargo not found on PATH" >&2
  exit 1
fi

# llvm-profdata lives either on PATH or inside the rustup toolchain's
# llvm-tools component; find whichever exists.
PROFDATA="$(command -v llvm-profdata || true)"
if [ -z "$PROFDATA" ]; then
  SYSROOT="$(rustc --print sysroot)"
  PROFDATA="$(find "$SYSROOT" -name llvm-profdata -type f 2>/dev/null | head -n1 || true)"
fi
if [ -z "$PROFDATA" ]; then
  echo "error: llvm-profdata not found; install it with:" >&2
  echo "  rustup component add llvm-tools" >&2
  exit 1
fi

rm -rf "$PGO_DIR"
mkdir -p "$PGO_DIR"

echo "== phase 1: instrumented build + profile collection =="
export RUSTFLAGS="-Cprofile-generate=$PGO_DIR"
for b in "${BENCHES[@]}"; do
  POGO_BENCH_QUICK=1 cargo bench --bench "$b"
done
unset RUSTFLAGS

echo "== phase 2: merge profiles =="
"$PROFDATA" merge -o "$PGO_DIR/merged.profdata" "$PGO_DIR"

echo "== phase 3: PGO rebuild + measured run =="
export RUSTFLAGS="-Cprofile-use=$PGO_DIR/merged.profdata"
for b in "${BENCHES[@]}"; do
  cargo bench --bench "$b"
done
unset RUSTFLAGS

echo "PGO run complete; profile data in $PGO_DIR"
