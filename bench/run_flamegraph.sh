#!/usr/bin/env bash
# CPU flamegraph of one pogo bench (default: pool_dispatch).
#
# Usage:  bench/run_flamegraph.sh [bench-name] [out.svg]
#   bench-name  any [[bench]] target from rust/Cargo.toml
#               (pool_dispatch, step_kernels, step_micro, ...)
#   out.svg     output path (default: flamegraph-<bench>.svg in the repo root)
#
# Prefers `cargo flamegraph` (cargo install flamegraph) and falls back to
# raw `perf record -g` + the flamegraph.pl/stackcollapse-perf.pl scripts
# if they are on PATH. Either path needs perf_event access — on locked-
# down kernels run:  sudo sysctl kernel.perf_event_paranoid=1

set -euo pipefail
cd "$(dirname "$0")/.."

BENCH="${1:-pool_dispatch}"
OUT="${2:-flamegraph-$BENCH.svg}"

if ! command -v cargo >/dev/null; then
  echo "error: cargo not found on PATH" >&2
  exit 1
fi

# Keep the workload bounded (the quick sweep) and symbols available.
export POGO_BENCH_QUICK=1
export CARGO_PROFILE_BENCH_DEBUG=true

if cargo flamegraph --version >/dev/null 2>&1; then
  echo "== cargo flamegraph --bench $BENCH =="
  cargo flamegraph --bench "$BENCH" -o "$OUT"
  echo "wrote $OUT"
  exit 0
fi

if ! command -v perf >/dev/null; then
  echo "error: neither 'cargo flamegraph' nor 'perf' is available." >&2
  echo "  install one of:" >&2
  echo "    cargo install flamegraph" >&2
  echo "    apt-get install linux-tools-common linux-tools-\$(uname -r)" >&2
  exit 1
fi

echo "== perf record on bench $BENCH =="
cargo bench --bench "$BENCH" --no-run
# The freshly built bench binary is the newest executable matching the name.
BIN="$(find target/release/deps -maxdepth 1 -type f -executable -name "${BENCH}-*" \
  -newer Cargo.toml -printf '%T@ %p\n' 2>/dev/null | sort -rn | head -n1 | cut -d' ' -f2-)"
if [ -z "$BIN" ]; then
  BIN="$(ls -t target/release/deps/${BENCH}-* 2>/dev/null | head -n1 || true)"
fi
if [ -z "$BIN" ]; then
  echo "error: could not locate the built bench binary for $BENCH" >&2
  exit 1
fi
perf record -g --call-graph dwarf -o perf.data -- "$BIN"

if command -v stackcollapse-perf.pl >/dev/null && command -v flamegraph.pl >/dev/null; then
  perf script -i perf.data | stackcollapse-perf.pl | flamegraph.pl > "$OUT"
  echo "wrote $OUT"
else
  echo "perf.data recorded; flamegraph.pl not on PATH, so inspect it with:" >&2
  echo "  perf report -i perf.data" >&2
  echo "or install https://github.com/brendangregg/FlameGraph for the SVG." >&2
fi
