"""Canonical experiment shapes, shared by aot.py and mirrored in the Rust
config presets (rust/src/config/mod.rs — keep in sync; the manifest is the
source of truth at runtime, Rust reads shapes from it).

Default shapes are scaled to the CPU PJRT client of this image; `--full`
emits the paper's exact Fig. 4 sizes as additional artifacts.
"""

# Fig. 4 — online PCA (paper: p=1500, n=2000).
PCA_P, PCA_N = 300, 400
PCA_FULL_P, PCA_FULL_N = 1500, 2000

# Fig. 4 — Procrustes (paper: p=n=2000).
PROC_N = 400
PROC_FULL_N = 2000

# NN experiment batch sizes.
CNN_BATCH = 64
CNN_EVAL_BATCH = 256
VIT_BATCH = 32
VIT_EVAL_BATCH = 128
BORN_BATCH = 64
LM_BATCH = 8

# Small shapes for integration tests (rust/tests).
TEST_B, TEST_P, TEST_N = 4, 8, 16
