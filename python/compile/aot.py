"""AOT pipeline: lower every L2 program to HLO text + write the manifest.

`make artifacts` runs this once; the Rust runtime (`rust/src/runtime/`)
reads `artifacts/manifest.json`, compiles each `.hlo.txt` with the PJRT CPU
client and executes them from the request path. Python never runs again.

Interchange is HLO **text** (not serialized protos): jax ≥ 0.5 emits
64-bit instruction ids that the xla crate's xla_extension 0.5.1 rejects;
the text parser reassigns ids (see /opt/xla-example/README.md).

Usage:
    python -m compile.aot --out-dir ../artifacts [--quick] [--full]
    python -m compile.aot --list
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp

from . import model, shapes
from .models import born, cnn, transformer, vit


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


class Entry:
    """One AOT artifact: a program at fixed input shapes."""

    def __init__(self, name, fn, specs, input_names, tags=()):
        self.name = name
        self.fn = fn
        self.specs = specs
        self.input_names = input_names
        self.tags = list(tags)

    def lower(self) -> str:
        return model.to_hlo_text(self.fn, *self.specs)

    def describe(self):
        outs = jax.eval_shape(self.fn, *self.specs)
        if not isinstance(outs, (tuple, list)):
            outs = (outs,)
        return {
            "file": f"{self.name}.hlo.txt",
            "inputs": [
                {"name": n, "shape": list(s.shape), "dtype": str(s.dtype)}
                for n, s in zip(self.input_names, self.specs)
            ],
            "outputs": [
                {"shape": list(o.shape), "dtype": str(o.dtype)} for o in outs
            ],
            "tags": self.tags,
        }


def _pogo_entries(reg, tag, b, p, n):
    """POGO/Landing/SLPG step programs for one (B, p, n) group shape."""
    x, g, eta = f32(b, p, n), f32(b, p, n), f32(1)
    key = f"b{b}_{p}x{n}"
    reg.append(Entry(f"pogo_step_{key}", model.pogo_step_program,
                     [x, g, eta], ["x", "g", "eta"], [tag, "step"]))
    reg.append(Entry(
        f"pogo_vadam_step_{key}", model.pogo_vadam_step_program,
        [x, g, f32(b, p, n), f32(b, 1, 1), f32(1), eta],
        ["x", "g", "m", "v", "t", "eta"], [tag, "step"]))
    reg.append(Entry(
        f"landing_step_{key}", model.landing_step_program,
        [x, g, eta, f32(1), f32(1)], ["x", "g", "eta", "attraction", "eps"],
        [tag, "step"]))
    reg.append(Entry(f"slpg_step_{key}", model.slpg_step_program,
                     [x, g, eta], ["x", "g", "eta"], [tag, "step"]))
    reg.append(Entry(f"pogo_coeffs_{key}", model.pogo_landing_coeffs_program,
                     [x, g, eta], ["x", "g", "eta"], [tag, "step"]))
    reg.append(Entry(f"pogo_normal_{key}", model.pogo_normal_program,
                     [f32(b, p, n), f32(b)], ["m", "lam"], [tag, "step"]))
    reg.append(Entry(f"distance_{key}", model.distance_program,
                     [x], ["x"], [tag, "telemetry"]))


def build_registry(quick: bool, full: bool):
    reg: list[Entry] = []

    # -- Integration-test shapes (always emitted; rust/tests depend on them).
    b, p, n = shapes.TEST_B, shapes.TEST_P, shapes.TEST_N
    _pogo_entries(reg, "test", b, p, n)
    reg.append(Entry(
        "pogo_step_complex_test",
        model.pogo_step_complex_program,
        [f32(2, 4, 8)] * 4 + [f32(1)],
        ["xr", "xi", "gr", "gi", "eta"], ["test", "step"]))
    reg.append(Entry(
        "pca_lossgrad_test",
        model.pca_lossgrad_program,
        [f32(p, n), f32(n, n)], ["x", "aat"], ["test", "lossgrad"]))
    if quick:
        return reg

    # -- Fig. 4: PCA / Procrustes (scaled shapes; --full adds paper sizes).
    pca_shapes = [(shapes.PCA_P, shapes.PCA_N)]
    proc_shapes = [(shapes.PROC_N, shapes.PROC_N)]
    if full:
        pca_shapes.append((shapes.PCA_FULL_P, shapes.PCA_FULL_N))
        proc_shapes.append((shapes.PROC_FULL_N, shapes.PROC_FULL_N))
    for (pp, nn) in pca_shapes:
        _pogo_entries(reg, "fig4-pca", 1, pp, nn)
        reg.append(Entry(
            f"pca_lossgrad_{pp}x{nn}", model.pca_lossgrad_program,
            [f32(pp, nn), f32(nn, nn)], ["x", "aat"], ["fig4-pca", "lossgrad"]))
        reg.append(Entry(
            f"pca_pogo_fused_{pp}x{nn}", model.pca_pogo_fused_program,
            [f32(pp, nn), f32(nn, nn), f32(1)], ["x", "aat", "eta"],
            ["fig4-pca", "fused"]))
    for (pp, nn) in proc_shapes:
        _pogo_entries(reg, "fig4-proc", 1, pp, nn)
        reg.append(Entry(
            f"procrustes_lossgrad_{pp}x{nn}", model.procrustes_lossgrad_program,
            [f32(pp, nn), f32(pp, pp), f32(pp, nn)], ["x", "a", "b"],
            ["fig4-proc", "lossgrad"]))
        reg.append(Entry(
            f"procrustes_pogo_fused_{pp}x{nn}",
            model.procrustes_pogo_fused_program,
            [f32(pp, nn), f32(pp, pp), f32(pp, nn), f32(1)],
            ["x", "a", "b", "eta"], ["fig4-proc", "fused"]))

    # -- Fig. 1/6/7: CNN, both parameterizations.
    bt, be = shapes.CNN_BATCH, shapes.CNN_EVAL_BATCH
    fshapes = cnn.FILTER_SHAPES
    img_t, lab_t = f32(bt, 32, 32, 3), i32(bt)
    img_e, lab_e = f32(be, 32, 32, 3), i32(be)
    fparams = [f32(*s) for s in fshapes] + [f32(*cnn.HEAD_SHAPE)]
    fnames = ["w1", "w2", "w3", "head"]
    reg.append(Entry("cnn_filters_lossgrad", cnn.cnn_filters_lossgrad_program,
                     fparams + [img_t, lab_t], fnames + ["images", "labels"],
                     ["fig1-cnn", "lossgrad"]))
    reg.append(Entry("cnn_filters_eval", cnn.cnn_filters_eval_program,
                     fparams + [img_e, lab_e], fnames + ["images", "labels"],
                     ["fig1-cnn", "eval"]))
    kparams = [f32(c, 3, 3) for c in cnn.KERNEL_COUNTS] + [f32(*cnn.HEAD_SHAPE)]
    knames = ["k1", "k2", "k3", "head"]
    reg.append(Entry("cnn_kernels_lossgrad", cnn.cnn_kernels_lossgrad_program,
                     kparams + [img_t, lab_t], knames + ["images", "labels"],
                     ["fig1-cnn", "lossgrad"]))
    reg.append(Entry("cnn_kernels_eval", cnn.cnn_kernels_eval_program,
                     kparams + [img_e, lab_e], knames + ["images", "labels"],
                     ["fig1-cnn", "eval"]))
    # Per-filter-group and per-kernel-group optimizer steps.
    for (o, ik) in fshapes:
        _pogo_entries(reg, "fig1-cnn", 1, o, ik)
    for c in cnn.KERNEL_COUNTS:
        _pogo_entries(reg, "fig1-cnn", c, 3, 3)

    # -- Fig. 5: O-ViT.
    vb, ve = shapes.VIT_BATCH, shapes.VIT_EVAL_BATCH
    vparams = [f32(vit.N_ORTH, *vit.ORTH_SHAPE), f32(*vit.PATCH_W_SHAPE),
               f32(*vit.POS_SHAPE), f32(*vit.HEAD_SHAPE)]
    vnames = ["orth", "patch_w", "pos", "head"]
    reg.append(Entry("vit_lossgrad", vit.vit_lossgrad_program,
                     vparams + [f32(vb, 32, 32, 3), i32(vb)],
                     vnames + ["images", "labels"], ["fig5-vit", "lossgrad"]))
    reg.append(Entry("vit_eval", vit.vit_eval_program,
                     vparams + [f32(ve, 32, 32, 3), i32(ve)],
                     vnames + ["images", "labels"], ["fig5-vit", "eval"]))
    _pogo_entries(reg, "fig5-vit", vit.N_ORTH, *vit.ORTH_SHAPE)

    # -- Fig. 8: Born-machine MPS (squared unitary circuit).
    bb = shapes.BORN_BATCH
    core_specs = []
    core_names = []
    for t, (pp, nn) in enumerate(born.core_shapes()):
        core_specs += [f32(pp, nn), f32(pp, nn)]
        core_names += [f"re_{t}", f"im_{t}"]
    reg.append(Entry("born_lossgrad", born.born_lossgrad_program,
                     core_specs + [i32(bb, born.T_SITES)],
                     core_names + ["bits"], ["fig8-born", "lossgrad"]))
    reg.append(Entry("born_eval", born.born_eval_program,
                     core_specs + [i32(512, born.T_SITES)],
                     core_names + ["bits"], ["fig8-born", "eval"]))

    # -- Scalability sweep (the Fig. 1 "3 min vs 17 h" mechanism): batched
    # 3×3 POGO steps at growing batch sizes.
    for bsz in (64, 512, 4096, 32768):
        reg.append(Entry(
            f"pogo_step_b{bsz}_3x3", model.pogo_step_program,
            [f32(bsz, 3, 3), f32(bsz, 3, 3), f32(1)], ["x", "g", "eta"],
            ["scale", "step"]))
        reg.append(Entry(
            f"pogo_vadam_step_b{bsz}_3x3", model.pogo_vadam_step_program,
            [f32(bsz, 3, 3), f32(bsz, 3, 3), f32(bsz, 3, 3), f32(bsz, 1, 1),
             f32(1), f32(1)],
            ["x", "g", "m", "v", "t", "eta"], ["scale", "step"]))

    # -- E2E transformer LM.
    lb = shapes.LM_BATCH
    lm_params = [
        f32(transformer.N_ORTH, *transformer.ORTH_SHAPE),
        f32(*transformer.TOK_EMB_SHAPE), f32(*transformer.POS_EMB_SHAPE),
        f32(transformer.LAYERS, *transformer.MLP_W1_SHAPE),
        f32(transformer.LAYERS, *transformer.MLP_W2_SHAPE),
        f32(*transformer.HEAD_SHAPE),
    ]
    lm_names = ["orth", "tok_emb", "pos_emb", "mlp_w1s", "mlp_w2s", "head"]
    reg.append(Entry("lm_lossgrad", transformer.lm_lossgrad_program,
                     lm_params + [i32(lb, transformer.SEQ + 1)],
                     lm_names + ["tokens"], ["e2e-lm", "lossgrad"]))
    reg.append(Entry("lm_eval", transformer.lm_eval_program,
                     lm_params + [i32(lb, transformer.SEQ + 1)],
                     lm_names + ["tokens"], ["e2e-lm", "eval"]))
    _pogo_entries(reg, "e2e-lm", transformer.N_ORTH, *transformer.ORTH_SHAPE)

    return reg


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="only the integration-test artifacts")
    ap.add_argument("--full", action="store_true",
                    help="also emit the paper's full Fig. 4 shapes")
    ap.add_argument("--only", default=None,
                    help="substring filter on entry names")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    reg = build_registry(args.quick, args.full)
    if args.only:
        reg = [e for e in reg if args.only in e.name]
    if args.list:
        for e in reg:
            print(e.name, [tuple(s.shape) for s in e.specs])
        return

    import os

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"version": 1, "entries": {}}
    t0 = time.time()
    for i, e in enumerate(reg):
        t1 = time.time()
        text = e.lower()
        path = os.path.join(args.out_dir, f"{e.name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["entries"][e.name] = e.describe()
        print(f"[{i + 1:>3}/{len(reg)}] {e.name:<42} "
              f"{len(text) / 1024:>8.1f} KiB  {time.time() - t1:>5.1f}s",
              file=sys.stderr)
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote {len(reg)} artifacts in {time.time() - t0:.1f}s "
          f"to {args.out_dir}", file=sys.stderr)


if __name__ == "__main__":
    main()
