"""L2: the JAX compute graphs AOT-lowered for the Rust coordinator.

Every public function here is a *step program* or a *loss+grad program*
with fixed shapes, lowered by aot.py into `artifacts/*.hlo.txt`. The
optimizer geometry calls into the L1 Pallas kernels so the kernel lowers
into the same HLO module (one fused executable per program).

Python never runs at serve/train time: these functions execute inside the
Rust process through PJRT.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import pogo_step as pk
from .kernels import ref


# ---------------------------------------------------------------------------
# Optimizer step programs (batched over same-shape groups).
# ---------------------------------------------------------------------------


# Batch-size threshold for the Pallas grid path. Under interpret=True a
# pallas grid lowers to an XLA while-loop whose per-iteration buffer
# traffic grows with B; above this threshold the vectorized jnp einsum
# form (identical math — tests assert equality) is what XLA:CPU fuses
# best. On a real TPU the Pallas kernel IS the batched hot path; this is
# a CPU-backend layout decision (EXPERIMENTS.md §Perf, L2).
PALLAS_MAX_BATCH = 8


def _pogo_core(x, g, eta, lam=0.5):
    b = x.shape[0]
    if b <= PALLAS_MAX_BATCH:
        return pk.pogo_step_dyn(x, g, eta, lam=lam)
    return ref.pogo_step_ref(x, g, eta[0], lam)


def pogo_step_program(x, g, eta):
    """POGO λ=1/2 batched step; η is a runtime (1,) array. Pallas L1 core
    for small groups, vectorized form for the many-matrix regime."""
    return (_pogo_core(x, g, eta),)


def pogo_vadam_step_program(x, g, m, v, t, eta):
    """Fused VAdam + POGO step (the Fig. 1 orthogonal-kernel hot path).

    Args:
      x, g, m: (B, p, n); v: (B, 1, 1); t: (1,) step count (float32);
      eta: (1,) learning rate.
    Returns (X⁺, m', v').
    """
    gt, m_new, v_new = ref.vadam_transform_ref(g, m, v, t[0])
    x_new = _pogo_core(x, gt, eta)
    return x_new, m_new, v_new


def landing_step_program(x, g, eta, attraction, eps_ball):
    """Landing update with the per-matrix step-size safeguard IN-GRAPH
    (ref.landing_step_safe_ref); η₀, λ_a and the safe-ball radius ε are
    runtime (1,) arrays — LandingPC disables the safeguard by passing a
    huge ε. Returns (X⁺, distances) — telemetry rides along for free."""
    return ref.landing_step_safe_ref(x, g, eta[0], attraction[0], eps_ball[0])


def slpg_step_program(x, g, eta):
    """SLPG batched step."""
    return (ref.slpg_step_ref(x, g, eta[0]),)


def pogo_landing_coeffs_program(x, g, eta):
    """Intermediate M plus the quartic landing-polynomial coefficients:
    the FindRoot policy solves the quartic on L3 (microseconds) and applies
    the normal step with `pogo_normal_program`."""
    m = x - eta[0] * ref.riemannian_gradient_ref(x, g)
    coeffs = ref.landing_coeffs_ref(m)
    return m, coeffs

def pogo_normal_program(m, lam):
    """Normal step X⁺ = M − λ(M Mᵀ − I)M with per-matrix λ of shape (B,)."""
    c = ref.gram_residual_ref(m)
    cm = jnp.einsum("...ij,...jk->...ik", c, m)
    return (m - lam[:, None, None] * cm,)


def pogo_step_complex_program(xr, xi, gr, gi, eta):
    """POGO on the complex Stiefel manifold, (re, im) split at the ABI."""
    out_r, out_i = ref.pogo_step_complex_ref(xr, xi, gr, gi, eta[0])
    return out_r, out_i


def distance_program(x):
    """Batched manifold distances (feasibility telemetry)."""
    return (ref.stiefel_distance_ref(x),)


# ---------------------------------------------------------------------------
# Fig. 4 loss+grad programs (closed-form gradients).
# ---------------------------------------------------------------------------


def pca_lossgrad_program(x, aat):
    """Online PCA: f(X) = −‖X A‖² = −Tr(X AAᵀ Xᵀ); ∇f = −2 X AAᵀ.

    `aat` is the n×n PSD matrix A Aᵀ (uploaded to device once by L3).
    """
    xa = jnp.dot(x, aat)
    loss = -jnp.sum(x * xa)
    grad = -2.0 * xa
    return loss, grad


def procrustes_lossgrad_program(x, a, b):
    """Procrustes: f(X) = ‖A X − B‖²; ∇f = 2 Aᵀ(A X − B)."""
    r = jnp.dot(a, x) - b
    loss = jnp.sum(r * r)
    grad = 2.0 * jnp.dot(a.T, r)
    return loss, grad


# ---------------------------------------------------------------------------
# Fused experiment step: loss+grad+POGO in ONE executable (perf pass).
# ---------------------------------------------------------------------------


def pca_pogo_fused_program(x, aat, eta):
    """One fused PCA training step: grad, POGO update, loss + distance out.

    Keeps X on device across the entire run — L3 only downloads two scalars
    per step. This is the headline L2 optimization (§Perf).
    """
    xa = jnp.dot(x, aat)
    loss = -jnp.sum(x * xa)
    grad = -2.0 * xa
    x_new = pk.pogo_step_dyn(x[None], grad[None], eta, lam=0.5)[0]
    d = ref.stiefel_distance_ref(x_new[None])[0]
    return x_new, loss, d


def procrustes_pogo_fused_program(x, a, b, eta):
    """One fused Procrustes training step (see pca_pogo_fused_program)."""
    r = jnp.dot(a, x) - b
    loss = jnp.sum(r * r)
    grad = 2.0 * jnp.dot(a.T, r)
    x_new = pk.pogo_step_dyn(x[None], grad[None], eta, lam=0.5)[0]
    d = ref.stiefel_distance_ref(x_new[None])[0]
    return x_new, loss, d


# ---------------------------------------------------------------------------
# Lowering helper (HLO text — see /opt/xla-example/README.md for why text).
# ---------------------------------------------------------------------------


def to_hlo_text(fn, *specs) -> str:
    """Lower a jax function at the given ShapeDtypeStructs to HLO text."""
    from jax._src.lib import xla_client as xc

    lowered = jax.jit(fn).lower(*specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()
