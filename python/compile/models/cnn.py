"""The Fig. 1/6/7 CNN with orthogonal filters or orthogonal kernels.

A compact 3-conv classifier in the spirit of the CIFAR-10 speedrun net
(Jordan 2024) the paper builds on, at CPU-PJRT-feasible scale. Two
parameterizations, as in §5.2:

- **filters**: each conv weight (O, I, k, k) is one wide orthogonal matrix
  (O, I·k²) — 3 matrices, sizes (32, 27), (64, 288), (128, 576).
- **kernels**: every (k, k) slice is itself orthogonal (Ozay & Okatani
  2016) — OI matrices of size 3×3 per layer, 96 + 2048 + 8192 = 10336
  matrices total, handled as batched (B, 3, 3) tensors.

The loss+grad programs return gradients in exactly the parameter layout the
Rust coordinator stores (flat list), so the PJRT boundary is copy-only.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# Channel progression and kernel size. The first conv has 24 outputs so
# every filter matrix is *wide* (O ≤ I·k²), as St(p, n) requires:
# (24, 27), (64, 216), (128, 576) — the same size range as the paper's
# "64×216 up to 256×2304".
CHANNELS = (3, 24, 64, 128)
K = 3
NUM_CLASSES = 10
IMAGE_HW = 32

# Orthogonal-filter matrix shapes (O, I·k²) per conv layer.
FILTER_SHAPES = tuple(
    (CHANNELS[i + 1], CHANNELS[i] * K * K) for i in range(len(CHANNELS) - 1)
)
# Orthogonal-kernel batch sizes (O·I) per conv layer.
KERNEL_COUNTS = tuple(
    CHANNELS[i + 1] * CHANNELS[i] for i in range(len(CHANNELS) - 1)
)
# Head: global-average-pooled features -> logits.
HEAD_SHAPE = (CHANNELS[-1], NUM_CLASSES)


def _conv(x, w_oikk):
    """NHWC conv, stride 1, SAME padding; w is (O, I, k, k)."""
    kernel = jnp.transpose(w_oikk, (2, 3, 1, 0))  # (k, k, I, O)
    return jax.lax.conv_general_dilated(
        x, kernel, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def _pool(x):
    return jax.lax.reduce_window(
        x, 0.0, jax.lax.add, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    ) * 0.25


def _forward_from_convs(conv_ws, head, images):
    """Shared trunk: conv → relu → pool ×3 → GAP → linear head."""
    h = images
    for w in conv_ws:
        h = jax.nn.relu(_conv(h, w))
        h = _pool(h)
    feats = jnp.mean(h, axis=(1, 2))  # (B, C_last)
    return jnp.dot(feats, head)  # (B, 10)


def _xent(logits, labels):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))


def filters_to_convs(filter_mats):
    """(O, I·k²) orthogonal matrices → (O, I, k, k) conv weights."""
    out = []
    for w, (ci, co) in zip(filter_mats, zip(CHANNELS[:-1], CHANNELS[1:])):
        out.append(w.reshape(co, ci, K, K))
    return out


def kernels_to_convs(kernel_batches):
    """Batched (O·I, k, k) orthogonal kernels → (O, I, k, k) conv weights.

    Kernels are scaled by 1/k so each 3×3 orthogonal kernel has unit
    spectral norm ≈ balanced activations (orthogonal 3×3 has ‖·‖_F = √3)."""
    out = []
    for kb, (ci, co) in zip(kernel_batches, zip(CHANNELS[:-1], CHANNELS[1:])):
        out.append(kb.reshape(co, ci, K, K) / K)
    return out


def cnn_filters_lossgrad_program(w1, w2, w3, head, images, labels):
    """Loss + grads for the orthogonal-FILTERS parameterization.

    w_i: (O_i, I_i·9) float32; head: (128, 10); images: (B, 32, 32, 3);
    labels: (B,) int32. Returns (loss, g_w1, g_w2, g_w3, g_head).
    """

    def loss_fn(params):
        w1, w2, w3, head = params
        convs = filters_to_convs([w1, w2, w3])
        logits = _forward_from_convs(convs, head, images)
        return _xent(logits, labels)

    loss, grads = jax.value_and_grad(loss_fn)((w1, w2, w3, head))
    return (loss, *grads)


def cnn_kernels_lossgrad_program(k1, k2, k3, head, images, labels):
    """Loss + grads for the orthogonal-KERNELS parameterization.

    k_i: (O_i·I_i, 3, 3) float32 batches of orthogonal kernels.
    """

    def loss_fn(params):
        k1, k2, k3, head = params
        convs = kernels_to_convs([k1, k2, k3])
        logits = _forward_from_convs(convs, head, images)
        return _xent(logits, labels)

    loss, grads = jax.value_and_grad(loss_fn)((k1, k2, k3, head))
    return (loss, *grads)


def cnn_filters_eval_program(w1, w2, w3, head, images, labels):
    """Test-time loss + accuracy (filters parameterization)."""
    convs = filters_to_convs([w1, w2, w3])
    logits = _forward_from_convs(convs, head, images)
    loss = _xent(logits, labels)
    acc = jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))
    return loss, acc


def cnn_kernels_eval_program(k1, k2, k3, head, images, labels):
    """Test-time loss + accuracy (kernels parameterization)."""
    convs = kernels_to_convs([k1, k2, k3])
    logits = _forward_from_convs(convs, head, images)
    loss = _xent(logits, labels)
    acc = jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))
    return loss, acc
