"""L2 model definitions (forward/backward programs) for the NN experiments."""

from . import born, cnn, transformer, vit  # noqa: F401
