"""The Fig. 8 workload: a squared *unitary* tensor-network density model.

Substitution for Loconte et al. (2025a)'s squared unitary PCs (code not
public, MNIST not on this image): a **Born-machine MPS with isometric
(complex-Stiefel) cores** over T binary variables. It is exactly a "squared
circuit": p(x) = |ψ(x)|², and the unitarity of the cores makes the squared
model *self-normalized* — Σₓ p(x) = 1 with no partition function — which is
the very reason the paper needs orthoptimizers in this setting (§5.3:
renormalizing the squared model is infeasible; orthogonality gives it for
free).

Core storage matches the Rust/PJRT ABI: core t is a wide row-orthonormal
complex matrix W_t ∈ C^{D_t × 2·D_{t−1}} carried as two f32 arrays
(re, im). Stacking S_t = W_t^H ∈ C^{2 D_{t−1} × D_t} is column-isometric
(S^H S = I), so with A_t[x] = S_t[x·D_{t−1} : (x+1)·D_{t−1}, :],

    ψ(x) = A_1[x_1] · A_2[x_2] ··· A_T[x_T]   (1×1),
    Σₓ |ψ(x)|² = 1 exactly (left-to-right telescoping).

bits-per-dim = −log₂ p(x) / T, the Fig. 8 metric.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

T_SITES = 16
D_MAX = 8


def bond_dims(t_sites: int = T_SITES, d_max: int = D_MAX):
    """D_0..D_T with D_0 = D_T = 1 and D_t = min(2^t, 2^(T−t), d_max)."""
    return [min(2 ** t, 2 ** (t_sites - t), d_max) for t in range(t_sites + 1)]


def core_shapes(t_sites: int = T_SITES, d_max: int = D_MAX):
    """Wide row-orthonormal core shapes (p, n) = (D_t, 2·D_{t−1})."""
    d = bond_dims(t_sites, d_max)
    return [(d[t + 1], 2 * d[t]) for t in range(t_sites)]


def _log_prob(cores_ri, bits):
    """log p(x) for a batch. cores_ri: list of (re, im) pairs; bits:
    (B, T) int32 in {0, 1}."""
    b = bits.shape[0]
    # v: (B, 1, D_0=1) complex — running left contraction.
    v = jnp.ones((b, 1, 1), dtype=jnp.complex64)
    for t, (wr, wi) in enumerate(cores_ri):
        w = wr + 1j * wi  # (D_t, 2·D_prev)
        d_t, two_dp = w.shape
        d_prev = two_dp // 2
        # S = W^H: (2·D_prev, D_t) → cores A[x]: (2, D_prev, D_t).
        s = jnp.conj(w).T.reshape(2, d_prev, d_t)
        a = s[bits[:, t]]  # (B, D_prev, D_t) gathered per sample
        v = jnp.einsum("bij,bjk->bik", v, a)
    amp = v[:, 0, 0]  # (B,) complex ψ(x)
    p = jnp.real(amp) ** 2 + jnp.imag(amp) ** 2
    return jnp.log(jnp.maximum(p, 1e-30))


def born_lossgrad_program(*args):
    """Loss (mean NLL in nats) + grads w.r.t. every core's (re, im).

    Args: re_1, im_1, ..., re_T, im_T, bits — 2T f32 arrays + (B, T) i32.
    Returns (loss, g_re_1, g_im_1, ..., g_re_T, g_im_T).
    """
    bits = args[-1]
    flat = args[:-1]
    assert len(flat) % 2 == 0
    cores = [(flat[2 * i], flat[2 * i + 1]) for i in range(len(flat) // 2)]

    def loss_fn(cs):
        return -jnp.mean(_log_prob(cs, bits))

    loss, grads = jax.value_and_grad(loss_fn)(cores)
    flat_grads = [g for pair in grads for g in pair]
    return (loss, *flat_grads)


def born_eval_program(*args):
    """Mean bits-per-dim on a batch (lower is better, Fig. 8 metric)."""
    bits = args[-1]
    flat = args[:-1]
    cores = [(flat[2 * i], flat[2 * i + 1]) for i in range(len(flat) // 2)]
    nll = -jnp.mean(_log_prob(cores, bits))
    bpd = nll / (T_SITES * jnp.log(2.0))
    return (bpd,)


def born_total_prob_program(*args):
    """Σₓ p(x) computed by exhaustive enumeration (T small): the
    self-normalization check. Inputs: the 2T core arrays (no bits).
    Returns a scalar that must be ≈ 1 when every core is on the complex
    Stiefel manifold."""
    flat = args
    cores = [(flat[2 * i], flat[2 * i + 1]) for i in range(len(flat) // 2)]
    t = len(cores)
    # Enumerate all 2^T bitstrings — only used in tests with small T.
    n = 2 ** t
    idx = jnp.arange(n, dtype=jnp.int32)
    bits = jnp.stack([(idx >> s) & 1 for s in range(t)], axis=1)
    logp = _log_prob(cores, bits)
    return (jnp.sum(jnp.exp(logp)),)
