"""The end-to-end validation model: a small causal transformer LM whose
attention projections are orthogonally constrained and trained with POGO.

This is `examples/e2e_transformer.rs`'s compute graph: one AOT executable
produces loss + gradients for every parameter; the Rust coordinator routes
the orthogonal gradients (Q, K, V, O per layer) to POGO and the rest
(embeddings, MLP) to Adam. Proves the full L1→L2→L3 composition on a real
training workload.

Scale note (DESIGN.md §Substitutions): the brief asks for ~100M params;
on a CPU-only PJRT client that is days of compute, so the default config
is ~3M params (d=256, 4 layers) trained a few hundred steps on a synthetic
character corpus — the loss curve and manifold telemetry are the
deliverable, not the parameter count.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

VOCAB = 64
DIM = 256
HEADS = 4
LAYERS = 4
SEQ = 128
MLP_MULT = 4

# Per layer: Q, K, V, O — all (DIM, DIM) square-orthogonal.
N_ORTH = 4 * LAYERS
ORTH_SHAPE = (DIM, DIM)
# Unconstrained parameters.
TOK_EMB_SHAPE = (VOCAB, DIM)
POS_EMB_SHAPE = (SEQ, DIM)
MLP_W1_SHAPE = (DIM, MLP_MULT * DIM)
MLP_W2_SHAPE = (MLP_MULT * DIM, DIM)
HEAD_SHAPE = (DIM, VOCAB)


def _rms_norm(x):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6)


def _causal_attention(h, wq, wk, wv, wo):
    b, t, d = h.shape
    hd = d // HEADS

    def split(x):
        return jnp.transpose(x.reshape(b, t, HEADS, hd), (0, 2, 1, 3))

    q = split(jnp.dot(h, wq))
    k = split(jnp.dot(h, wk))
    v = split(jnp.dot(h, wv))
    att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(jnp.float32(hd))
    mask = jnp.tril(jnp.ones((t, t), dtype=bool))
    att = jnp.where(mask[None, None], att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    out = jnp.transpose(out, (0, 2, 1, 3)).reshape(b, t, d)
    return jnp.dot(out, wo)


def forward(orth, tok_emb, pos_emb, mlp_w1s, mlp_w2s, head, tokens):
    """orth: (N_ORTH, DIM, DIM); mlp_w1s/w2s: (LAYERS, ...) stacked;
    tokens: (B, SEQ) int32. Returns logits (B, SEQ, VOCAB)."""
    h = tok_emb[tokens] + pos_emb[None, : tokens.shape[1]]
    for l in range(LAYERS):
        wq, wk, wv, wo = (orth[4 * l + i] for i in range(4))
        h = h + _causal_attention(_rms_norm(h), wq, wk, wv, wo)
        m = jax.nn.gelu(jnp.dot(_rms_norm(h), mlp_w1s[l]))
        h = h + jnp.dot(m, mlp_w2s[l])
    return jnp.dot(_rms_norm(h), head)


def _next_token_loss(logits, targets):
    logp = jax.nn.log_softmax(logits, axis=-1)
    picked = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return -jnp.mean(picked)


def lm_lossgrad_program(orth, tok_emb, pos_emb, mlp_w1s, mlp_w2s, head, tokens):
    """Loss + grads for one LM training step.

    tokens: (B, SEQ+1) int32 — inputs are [:, :-1], targets [:, 1:].
    Returns (loss, g_orth, g_tok, g_pos, g_w1s, g_w2s, g_head).
    """
    x = tokens[:, :-1]
    y = tokens[:, 1:]

    def loss_fn(params):
        return _next_token_loss(forward(*params, x), y)

    loss, grads = jax.value_and_grad(loss_fn)(
        (orth, tok_emb, pos_emb, mlp_w1s, mlp_w2s, head)
    )
    return (loss, *grads)


def lm_eval_program(orth, tok_emb, pos_emb, mlp_w1s, mlp_w2s, head, tokens):
    """Validation loss (nats/token)."""
    x = tokens[:, :-1]
    y = tokens[:, 1:]
    loss = _next_token_loss(forward(orth, tok_emb, pos_emb, mlp_w1s, mlp_w2s, head, x), y)
    return (loss,)
