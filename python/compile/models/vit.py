"""The Fig. 5 O-ViT: a vision transformer with orthogonal attention/MLP
matrices (Fei et al. 2022), at CPU-PJRT-feasible width.

Paper setting: 18 square orthogonal matrices of 1024×1024 inside a small
ViT. Here: 3 transformer blocks × 6 square orthogonal matrices each
(Q, K, V, O, W1, W2) = **18 orthogonal matrices** of (128, 128) — the same
multi-matrix-interaction structure at reduced width (substitution recorded
in DESIGN.md).

Unconstrained parameters: patch embedding, learned positional embedding,
classifier head — trained with Adam on L3, like the paper's baselines.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

DIM = 128
HEADS = 4
BLOCKS = 3
PATCH = 4
IMAGE_HW = 32
TOKENS = (IMAGE_HW // PATCH) ** 2  # 64
PATCH_DIM = PATCH * PATCH * 3  # 48
NUM_CLASSES = 10

# 18 orthogonal (DIM, DIM) matrices: [Q, K, V, O, W1, W2] × BLOCKS.
N_ORTH = 6 * BLOCKS
ORTH_SHAPE = (DIM, DIM)
# Unconstrained: patch embed, positional embed, head.
PATCH_W_SHAPE = (PATCH_DIM, DIM)
POS_SHAPE = (TOKENS, DIM)
HEAD_SHAPE = (DIM, NUM_CLASSES)


def _rms_norm(x):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6)


def _patchify(images):
    """(B, 32, 32, 3) → (B, 64, 48)."""
    b = images.shape[0]
    g = IMAGE_HW // PATCH
    x = images.reshape(b, g, PATCH, g, PATCH, 3)
    x = jnp.transpose(x, (0, 1, 3, 2, 4, 5))
    return x.reshape(b, TOKENS, PATCH_DIM)


def _attention(h, wq, wk, wv, wo):
    """Multi-head self-attention with orthogonal projections."""
    b, t, d = h.shape
    hd = d // HEADS

    def split(x):
        return jnp.transpose(x.reshape(b, t, HEADS, hd), (0, 2, 1, 3))

    q = split(jnp.dot(h, wq))
    k = split(jnp.dot(h, wk))
    v = split(jnp.dot(h, wv))
    att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(jnp.float32(hd))
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    out = jnp.transpose(out, (0, 2, 1, 3)).reshape(b, t, d)
    return jnp.dot(out, wo)


def _block(h, wq, wk, wv, wo, w1, w2):
    h = h + _attention(_rms_norm(h), wq, wk, wv, wo)
    m = jnp.dot(_rms_norm(h), w1)
    m = jax.nn.gelu(m)
    h = h + jnp.dot(m, w2)
    return h


def forward(orth, patch_w, pos, head, images):
    """orth: (18, DIM, DIM) stacked orthogonal matrices."""
    h = jnp.dot(_patchify(images), patch_w) + pos[None]
    for blk in range(BLOCKS):
        ws = [orth[6 * blk + i] for i in range(6)]
        h = _block(h, *ws)
    feats = jnp.mean(_rms_norm(h), axis=1)
    return jnp.dot(feats, head)


def _xent(logits, labels):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))


def vit_lossgrad_program(orth, patch_w, pos, head, images, labels):
    """Loss + grads. orth: (18, 128, 128); images: (B, 32, 32, 3);
    labels: (B,) int32. Returns (loss, g_orth, g_patch, g_pos, g_head)."""

    def loss_fn(params):
        return _xent(forward(*params, images), labels)

    loss, grads = jax.value_and_grad(loss_fn)((orth, patch_w, pos, head))
    return (loss, *grads)


def vit_eval_program(orth, patch_w, pos, head, images, labels):
    """Test loss + accuracy."""
    logits = forward(orth, patch_w, pos, head, images)
    loss = _xent(logits, labels)
    acc = jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))
    return loss, acc
