"""Pure-jnp oracles for every kernel and optimizer step (L1 correctness).

These are the *reference semantics*: the Pallas kernels (pogo_step.py,
gram.py) and the Rust engine are both tested against these functions.
Everything is written for batched inputs ``(B, p, n)``; single matrices are
``B = 1``.

Shapes follow the paper: wide row-orthogonal X in St(p, n), X Xᵀ = I_p.
"""

from __future__ import annotations

import jax.numpy as jnp


def skew(a):
    """Skew-symmetric part of a square (batched) matrix."""
    return 0.5 * (a - jnp.swapaxes(a, -1, -2))


def sym(a):
    """Symmetric part of a square (batched) matrix."""
    return 0.5 * (a + jnp.swapaxes(a, -1, -2))


def gram_residual_ref(x):
    """C = X Xᵀ − I_p, batched."""
    p = x.shape[-2]
    return jnp.einsum("...ik,...jk->...ij", x, x) - jnp.eye(p, dtype=x.dtype)


def stiefel_distance_ref(x):
    """‖X Xᵀ − I‖_F per batch element."""
    c = gram_residual_ref(x)
    return jnp.sqrt(jnp.sum(c * c, axis=(-2, -1)))


def riemannian_gradient_ref(x, g):
    """R = X Skew(Xᵀ G) = ½((X Xᵀ)G − (X Gᵀ)X)  (small-gram form)."""
    xxt = jnp.einsum("...ik,...jk->...ij", x, x)
    xgt = jnp.einsum("...ik,...jk->...ij", x, g)
    return 0.5 * (jnp.einsum("...ij,...jk->...ik", xxt, g)
                  - jnp.einsum("...ij,...jk->...ik", xgt, x))


def pogo_step_ref(x, g, eta, lam=0.5):
    """POGO Alg. 1 with fixed λ: M = X − ηR; X⁺ = M + λ(I − M Mᵀ)M."""
    m = x - eta * riemannian_gradient_ref(x, g)
    c = gram_residual_ref(m)
    return m - lam * jnp.einsum("...ij,...jk->...ik", c, m)


def landing_coeffs_ref(m):
    """Quartic landing-polynomial coefficients [a4, a3, a2, a1, a0] from M.

    With C = M Mᵀ − I, N = C + I: B-direction = −C M, D = −(NC + CN),
    E = C N C; P(λ) = ‖C + Dλ + Eλ²‖² (Lemma 3.1, with the two typos of the
    published statement fixed — verified against direct evaluation).
    """
    c = gram_residual_ref(m)
    p = m.shape[-2]
    n_mat = c + jnp.eye(p, dtype=m.dtype)
    nc = jnp.einsum("...ij,...jk->...ik", n_mat, c)
    d = -(nc + jnp.swapaxes(nc, -1, -2))
    e = jnp.einsum("...ij,...jk->...ik", c, nc)

    def ip(a, b):
        return jnp.sum(a * b, axis=(-2, -1))

    a4 = ip(e, e)
    a3 = 2.0 * ip(d, e)
    a2 = ip(d, d) + 2.0 * ip(c, e)
    a1 = 2.0 * ip(c, d)
    a0 = ip(c, c)
    return jnp.stack([a4, a3, a2, a1, a0], axis=-1)


def landing_field_ref(x, g, attraction):
    """Λ(X) = R + λ_a (X Xᵀ − I) X — the Landing direction (Eq. 6)."""
    r = riemannian_gradient_ref(x, g)
    c = gram_residual_ref(x)
    return r + attraction * jnp.einsum("...ij,...jk->...ik", c, x)


def landing_step_ref(x, g, eta, attraction):
    """Fixed-step Landing update (safeguard handled by the caller/L3)."""
    return x - eta * landing_field_ref(x, g, attraction)


def landing_step_safe_ref(x, g, eta0, attraction, eps_ball=0.5):
    """Landing with the per-matrix step-size SAFEGUARD in-graph.

    Mirrors `rust/src/optim/landing.rs`: with h = XXᵀ−I, d = ‖h‖,
    R = X·Skew(XᵀG) (so X Rᵀ + R Xᵀ ≡ 0) and ‖Λ‖² = ‖R‖² + λ²‖∇N‖²,
    requiring ‖h⁺‖ ≤ ε gives the quadratic-root safe step
        η* = [λ d(1−d) + sqrt(λ²d²(1−d)² + ‖Λ‖²(ε−d)₊)] / ‖Λ‖²,
    and η = min(η₀, η*, ½λ⁻¹). Returns (X⁺, distances).
    """
    r = riemannian_gradient_ref(x, g)
    c = gram_residual_ref(x)
    ngrad = jnp.einsum("...ij,...jk->...ik", c, x)
    d = jnp.sqrt(jnp.sum(c * c, axis=(-2, -1)))
    lam = attraction
    lam_sq = (jnp.sum(r * r, axis=(-2, -1))
              + lam * lam * jnp.sum(ngrad * ngrad, axis=(-2, -1)))
    slack = jnp.maximum(eps_ball - d, 0.0)
    b = lam * d * jnp.maximum(1.0 - d, 0.0)
    safe = (b + jnp.sqrt(b * b + lam_sq * slack)) / jnp.maximum(lam_sq, 1e-30)
    cap = jnp.where(lam > 0, 0.5 / jnp.maximum(lam, 1e-30), jnp.inf)
    eta = jnp.minimum(jnp.minimum(eta0, safe), cap)[..., None, None]
    x_new = x - eta * (r + lam * ngrad)
    d_new = stiefel_distance_ref(x_new)
    return x_new, d_new


def slpg_step_ref(x, g, eta):
    """SLPG smooth-case update (Liu et al. 2024; paper §B), row-orthogonal.

    Y = X − η (G − Sym(G Xᵀ) X); X⁺ = Y − ½(Y Yᵀ − I)Y.
    """
    gxt = jnp.einsum("...ik,...jk->...ij", g, x)
    d = g - jnp.einsum("...ij,...jk->...ik", sym(gxt), x)
    y = x - eta * d
    c = gram_residual_ref(y)
    return y - 0.5 * jnp.einsum("...ij,...jk->...ik", c, y)


def vadam_transform_ref(g, m, v, t, beta1=0.9, beta2=0.999, eps=1e-8):
    """VAdam (Ling et al. 2022): matrix-wise second moment ⇒ linear (Def. 1).

    Returns (G, m', v') with t the *new* (1-based) step count.
    """
    m_new = beta1 * m + (1.0 - beta1) * g
    gn2 = jnp.sum(g * g, axis=(-2, -1), keepdims=True)
    v_new = beta2 * v + (1.0 - beta2) * gn2
    mhat = m_new / (1.0 - beta1 ** t)
    vhat = v_new / (1.0 - beta2 ** t)
    out = mhat / (jnp.sqrt(vhat) + eps)
    return out, m_new, v_new


def pogo_vadam_step_ref(x, g, m, v, t, eta, lam=0.5):
    """Fused VAdam + POGO step: returns (X⁺, m', v')."""
    gt, m_new, v_new = vadam_transform_ref(g, m, v, t)
    x_new = pogo_step_ref(x, gt, eta, lam)
    return x_new, m_new, v_new


# -- Complex Stiefel (unitary) references, carried as (re, im) pairs. -------


def c_pack(re, im):
    return re + 1j * im


def pogo_step_complex_ref(xr, xi, gr, gi, eta, lam=0.5):
    """POGO on the complex Stiefel manifold; returns (re, im) of X⁺."""
    x = c_pack(xr, xi)
    g = c_pack(gr, gi)
    xxh = jnp.einsum("...ik,...jk->...ij", x, jnp.conj(x))
    xgh = jnp.einsum("...ik,...jk->...ij", x, jnp.conj(g))
    r = 0.5 * (jnp.einsum("...ij,...jk->...ik", xxh, g)
               - jnp.einsum("...ij,...jk->...ik", xgh, x))
    m = x - eta * r
    p = m.shape[-2]
    c = jnp.einsum("...ik,...jk->...ij", m, jnp.conj(m)) - jnp.eye(p, dtype=m.dtype)
    out = m - lam * jnp.einsum("...ij,...jk->...ik", c, m)
    return jnp.real(out), jnp.imag(out)
