"""L1 Pallas kernel: the batched POGO update (paper Alg. 1, λ = 1/2).

One grid step processes one matrix of the ``(B, p, n)`` batch; the whole
update — relative gradient, intermediate step, proximal normal step — runs
out of VMEM with five MXU matmuls and no HBM round-trips:

    XG   = X Gᵀ              (p×p)   MXU
    XX   = X Xᵀ              (p×p)   MXU
    R    = ½(XX·G − XG·X)    (p×n)   2 MXU matmuls
    M    = X − η R                   VPU
    C    = M Mᵀ − I          (p×p)   MXU
    X⁺   = M − λ C·M         (p×n)   MXU + VPU

TPU mapping notes (DESIGN.md §Hardware-Adaptation):

- The paper's shapes fall in two regimes. The *many-small* regime
  (thousands of 3×3 kernels, Fig. 1) pads each matrix to one (8, 128)
  tile — the grid over B is the only parallel dimension and the MXU sees
  a stream of tiny fused products; this is where batching beats per-matrix
  QR by orders of magnitude. The *single-large* regime (Fig. 4,
  2000×2000) exceeds VMEM (4 f32 buffers × 16 MB); a production TPU kernel
  tiles p into 256-row stripes with a k-loop accumulator for the p×p
  grams — the stripe variant of the same schedule is exercised by
  `gram.py` (tiled gram-residual kernel). Under `interpret=True` both
  regimes execute identically, so correctness is validated here and the
  tiling structure is validated in gram.py.

`interpret=True` is mandatory on this image: real TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pogo_kernel(x_ref, g_ref, o_ref, *, eta: float, lam: float):
    """Pallas kernel body for one (1, p, n) block."""
    x = x_ref[0]  # (p, n) in VMEM
    g = g_ref[0]
    p = x.shape[0]
    # Relative gradient in small-gram form (all products p×p or p×n).
    xx = jnp.dot(x, x.T, preferred_element_type=jnp.float32)   # MXU
    xg = jnp.dot(x, g.T, preferred_element_type=jnp.float32)   # MXU
    r = 0.5 * (jnp.dot(xx, g, preferred_element_type=jnp.float32)
               - jnp.dot(xg, x, preferred_element_type=jnp.float32))
    m = x - eta * r
    c = jnp.dot(m, m.T, preferred_element_type=jnp.float32) - jnp.eye(
        p, dtype=jnp.float32)
    o_ref[0] = m - lam * jnp.dot(c, m, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("eta", "lam"))
def pogo_step(x, g, eta: float, lam: float = 0.5):
    """Batched POGO step via the Pallas kernel.

    Args:
      x: (B, p, n) float32, points on (or near) St(p, n).
      g: (B, p, n) float32, Euclidean gradients (already base-optimized).
      eta: learning rate (static).
      lam: normal-step size (static; 0.5 per Thm 3.5).

    Returns:
      (B, p, n) float32 updated points.
    """
    b, p, n = x.shape
    return pl.pallas_call(
        functools.partial(_pogo_kernel, eta=eta, lam=lam),
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, p, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, p, n), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, p, n), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, p, n), jnp.float32),
        interpret=True,
    )(x, g)


def _pogo_dyn_kernel(eta_ref, x_ref, g_ref, o_ref, *, lam: float):
    """Variant with η as a runtime scalar (prefetched operand) so the L3
    scheduler can anneal the learning rate without recompiling."""
    eta = eta_ref[0]
    x = x_ref[0]
    g = g_ref[0]
    p = x.shape[0]
    xx = jnp.dot(x, x.T, preferred_element_type=jnp.float32)
    xg = jnp.dot(x, g.T, preferred_element_type=jnp.float32)
    r = 0.5 * (jnp.dot(xx, g, preferred_element_type=jnp.float32)
               - jnp.dot(xg, x, preferred_element_type=jnp.float32))
    m = x - eta * r
    c = jnp.dot(m, m.T, preferred_element_type=jnp.float32) - jnp.eye(
        p, dtype=jnp.float32)
    o_ref[0] = m - lam * jnp.dot(c, m, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("lam",))
def pogo_step_dyn(x, g, eta, lam: float = 0.5):
    """Batched POGO step with runtime learning rate.

    `eta` is a shape-(1,) float32 array; everything else as `pogo_step`.
    This is the variant AOT-compiled for the Rust hot path (the coordinator
    anneals η without carrying N executables).
    """
    b, p, n = x.shape
    eta = jnp.asarray(eta, jnp.float32).reshape((1,))
    return pl.pallas_call(
        functools.partial(_pogo_dyn_kernel, lam=lam),
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1, p, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, p, n), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, p, n), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, p, n), jnp.float32),
        interpret=True,
    )(eta, x, g)


def vmem_bytes(p: int, n: int) -> int:
    """Estimated VMEM working set of one grid step (f32): X, G, R/M, X⁺
    (p×n each) + XX, XG, C (p×p each). Used by DESIGN.md's table and the
    artifact manifest metadata."""
    return 4 * (4 * p * n + 3 * p * p)


def mxu_flops(p: int, n: int) -> int:
    """MXU flop count of one matrix update (5 products, 2pn·p each-ish):
    2·p²·n (XGᵀ) + 2·p²·n (XXᵀ) + 2·p²·n (XX·G) + 2·p²·n (XG·X)
    + 2·p²·n (MMᵀ) + 2·p²·n (C·M) = 12·p²·n."""
    return 12 * p * p * n
