"""L1 Pallas kernel: tiled gram-residual `C = X Xᵀ − I` (feasibility probe).

This kernel demonstrates the *single-large-matrix* tiling regime that the
batched POGO kernel (pogo_step.py) documents but does not need on its own
shapes: the output (p × p) is tiled into (TP × TP) blocks over a 2-D grid,
and each block contracts its two (TP, n) row stripes through a TK-sized
k-loop accumulator — the standard MXU schedule (the k-loop plays the role
a CUDA kernel gives to threadblock tiles staged through shared memory; on
TPU the stripes live in VMEM and each `jnp.dot` feeds the systolic array).

VMEM per grid step: 2·TP·n/number-of-live-slabs staged stripes + TP·TP
accumulator; with TP = 128, TK = 512 each slab pair is 0.5 MB and the
accumulator 64 KB — comfortably inside a TensorCore's 16 MB VMEM with
double-buffering headroom.

Used by the runtime's distance probes; validated against ref.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gram_kernel(xi_ref, xj_ref, o_ref, *, tk: int):
    """One (TP, TP) output tile: k-loop accumulation over TK slabs."""
    xi = xi_ref[...]  # (TP, n) row stripe for the i block
    xj = xj_ref[...]  # (TP, n) row stripe for the j block
    n = xi.shape[1]
    nk = n // tk

    def body(k, acc):
        a = jax.lax.dynamic_slice_in_dim(xi, k * tk, tk, axis=1)
        b = jax.lax.dynamic_slice_in_dim(xj, k * tk, tk, axis=1)
        return acc + jnp.dot(a, b.T, preferred_element_type=jnp.float32)

    acc0 = jnp.zeros((xi.shape[0], xj.shape[0]), jnp.float32)
    o_ref[...] = jax.lax.fori_loop(0, nk, body, acc0)


@functools.partial(jax.jit, static_argnames=("tp", "tk"))
def gram_residual(x, tp: int = 128, tk: int = 512):
    """`X Xᵀ − I` for a single (p, n) matrix via the tiled Pallas kernel.

    p must be divisible by `tp` and n by `tk` (callers pad; the AOT entries
    use shapes that already satisfy this).
    """
    p, n = x.shape
    assert p % tp == 0 and n % tk == 0, f"({p},{n}) not tiled by ({tp},{tk})"
    ni = p // tp
    xxt = pl.pallas_call(
        functools.partial(_gram_kernel, tk=tk),
        grid=(ni, ni),
        in_specs=[
            pl.BlockSpec((tp, n), lambda i, j: (i, 0)),
            pl.BlockSpec((tp, n), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tp, tp), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((p, p), jnp.float32),
        interpret=True,
    )(x, x)
    return xxt - jnp.eye(p, dtype=jnp.float32)


@jax.jit
def stiefel_distance(x):
    """‖X Xᵀ − I‖_F for one (p, n) matrix, via the tiled kernel when the
    shape is tile-aligned, else the jnp fallback."""
    p, n = x.shape
    if p % 128 == 0 and n % 512 == 0:
        c = gram_residual(x)
    else:
        c = jnp.dot(x, x.T) - jnp.eye(p, dtype=x.dtype)
    return jnp.sqrt(jnp.sum(c * c))
