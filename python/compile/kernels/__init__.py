"""L1 Pallas kernels + pure-jnp reference oracles."""

from . import gram, pogo_step, ref  # noqa: F401
