"""AOT pipeline integrity: registry construction, manifest schema, HLO-text
lowering, and the Pallas-vs-jnp batch-threshold equivalence that the CPU
perf pass relies on."""

import json

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot, model
from compile.kernels import pogo_step as pk, ref


def test_registry_builds_and_names_unique():
    reg = aot.build_registry(quick=False, full=False)
    names = [e.name for e in reg]
    assert len(names) == len(set(names)), "duplicate artifact names"
    assert len(reg) > 60
    # Required by rust/tests + experiments:
    for needed in [
        "pogo_step_b4_8x16", "pogo_step_complex_test", "pca_lossgrad_test",
        "pca_lossgrad_300x400", "procrustes_lossgrad_400x400",
        "cnn_filters_lossgrad", "cnn_kernels_lossgrad", "vit_lossgrad",
        "born_lossgrad", "lm_lossgrad", "pogo_step_b18_128x128",
        "pogo_vadam_step_b8192_3x3", "landing_step_b1_300x400",
    ]:
        assert needed in names, f"missing {needed}"


def test_quick_registry_is_subset():
    quick = {e.name for e in aot.build_registry(quick=True, full=False)}
    full = {e.name for e in aot.build_registry(quick=False, full=False)}
    assert quick <= full
    assert "pogo_step_b4_8x16" in quick


def test_entry_describe_schema():
    reg = aot.build_registry(quick=True, full=False)
    e = next(x for x in reg if x.name == "pogo_step_b4_8x16")
    d = e.describe()
    assert d["file"] == "pogo_step_b4_8x16.hlo.txt"
    assert [i["name"] for i in d["inputs"]] == ["x", "g", "eta"]
    assert d["inputs"][0]["shape"] == [4, 8, 16]
    assert d["inputs"][0]["dtype"] == "float32"
    assert len(d["outputs"]) == 1
    json.dumps(d)  # must be JSON-serializable


def test_lowering_produces_hlo_text():
    reg = aot.build_registry(quick=True, full=False)
    e = next(x for x in reg if x.name == "pca_lossgrad_test")
    text = e.lower()
    assert "HloModule" in text
    assert "dot(" in text or "dot." in text  # the matmul survived lowering


def test_full_flag_adds_paper_shapes():
    full = {e.name for e in aot.build_registry(quick=False, full=True)}
    assert "pca_lossgrad_1500x2000" in full
    assert "procrustes_lossgrad_2000x2000" in full


@pytest.mark.parametrize("b", [1, 8, 9, 64])
def test_pogo_core_threshold_equivalence(b):
    """The Pallas path (b ≤ PALLAS_MAX_BATCH) and the vectorized jnp path
    must be numerically interchangeable — this is what makes the CPU
    batch-threshold routing (EXPERIMENTS.md §Perf) safe."""
    rng = np.random.default_rng(b)
    g_np = rng.standard_normal((b, 6, 10)).astype(np.float32)
    q, _ = np.linalg.qr(rng.standard_normal((b, 10, 6)).astype(np.float32))
    x_np = np.swapaxes(q, -1, -2).copy()
    x, g = jnp.asarray(x_np), jnp.asarray(g_np)
    eta = jnp.asarray([0.1], jnp.float32)
    via_program = np.asarray(model.pogo_step_program(x, g, eta)[0])
    via_pallas = np.asarray(pk.pogo_step_dyn(x, g, eta))
    via_jnp = np.asarray(ref.pogo_step_ref(x, g, 0.1))
    np.testing.assert_allclose(via_pallas, via_jnp, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(via_program, via_jnp, rtol=2e-5, atol=2e-5)


def test_landing_program_attraction_is_runtime():
    """landing_step must honour the runtime attraction argument."""
    rng = np.random.default_rng(0)
    q, _ = np.linalg.qr(rng.standard_normal((8, 4)).astype(np.float32))
    x = jnp.asarray(q.T[None]) * 1.1  # slightly off-manifold
    g = jnp.zeros_like(x)
    eta = jnp.asarray([0.1], jnp.float32)
    eps = jnp.asarray([1e9], jnp.float32)  # disable the safeguard
    weak, _ = model.landing_step_program(
        x, g, eta, jnp.asarray([0.01], jnp.float32), eps)
    strong, _ = model.landing_step_program(
        x, g, eta, jnp.asarray([2.0], jnp.float32), eps)
    d_weak = float(ref.stiefel_distance_ref(weak)[0])
    d_strong = float(ref.stiefel_distance_ref(strong)[0])
    assert d_strong < d_weak, f"attraction ignored: {d_strong} !< {d_weak}"


def test_landing_safeguard_keeps_eps_ball():
    """In-graph safeguard: adversarial gradients cannot push X beyond ε."""
    rng = np.random.default_rng(2)
    q, _ = np.linalg.qr(rng.standard_normal((10, 5)).astype(np.float32))
    x = jnp.asarray(q.T[None])
    eta = jnp.asarray([5.0], jnp.float32)       # absurd suggested lr
    att = jnp.asarray([1.0], jnp.float32)
    eps = jnp.asarray([0.5], jnp.float32)
    for seed in range(5):
        g = jnp.asarray(
            np.random.default_rng(seed).standard_normal((1, 5, 10)).astype(np.float32)
            * 50.0)
        x, d = model.landing_step_program(x, g, eta, att, eps)
        assert float(d[0]) <= 0.5 + 1e-4, f"left the ball: {float(d[0])}"


def test_fused_procrustes_step_consistency():
    rng = np.random.default_rng(1)
    n = 12
    q, _ = np.linalg.qr(rng.standard_normal((n, n)).astype(np.float32))
    x = jnp.asarray(q.T)
    a = jnp.asarray(rng.standard_normal((n, n)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((n, n)).astype(np.float32))
    eta = jnp.asarray([1e-3], jnp.float32)
    x_f, loss_f, d_f = model.procrustes_pogo_fused_program(x, a, b, eta)
    loss_2, grad_2 = model.procrustes_lossgrad_program(x, a, b)
    (x_2,) = model.pogo_step_program(x[None], grad_2[None], eta)
    np.testing.assert_allclose(float(loss_f), float(loss_2), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(x_f), np.asarray(x_2)[0],
                               rtol=1e-4, atol=1e-5)
    assert float(d_f) < 1e-2


def test_mxu_vmem_metadata_documented_shapes():
    """The DESIGN.md hardware table's claims: the 2000×2000 single-matrix
    working set exceeds a 16 MiB VMEM, the 3×3 batched one does not."""
    assert pk.vmem_bytes(2000, 2000) > 16 * 1024 * 1024
    assert pk.vmem_bytes(3, 3) < 16 * 1024 * 1024
