"""L1 correctness: Pallas kernels vs the pure-jnp oracle, swept over
shapes/batch sizes/seeds (hand-rolled hypothesis-style sweep — the image
has no `hypothesis` package)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.kernels import gram, pogo_step as pk, ref


def random_stiefel(rng, b, p, n):
    """Batched random Stiefel points via QR of Gaussian."""
    g = rng.standard_normal((b, n, p)).astype(np.float32)
    q, r = np.linalg.qr(g)
    sign = np.sign(np.diagonal(r, axis1=-2, axis2=-1))
    q = q * sign[:, None, :]
    return np.swapaxes(q, -1, -2).copy()  # (b, p, n) row-orthonormal


SWEEP = [
    # (batch, p, n, eta, seed)
    (1, 1, 1, 0.1, 0),
    (1, 3, 3, 0.2, 1),
    (2, 4, 8, 0.1, 2),
    (4, 8, 16, 0.05, 3),
    (3, 8, 8, 0.3, 4),
    (8, 3, 3, 0.5, 5),
    (1, 16, 64, 0.1, 6),
    (2, 32, 32, 0.01, 7),
]


@pytest.mark.parametrize("b,p,n,eta,seed", SWEEP)
def test_pogo_kernel_matches_ref(b, p, n, eta, seed):
    rng = np.random.default_rng(seed)
    x = random_stiefel(rng, b, p, n)
    g = rng.standard_normal((b, p, n)).astype(np.float32)
    got = np.asarray(pk.pogo_step(jnp.asarray(x), jnp.asarray(g), eta))
    want = np.asarray(ref.pogo_step_ref(jnp.asarray(x), jnp.asarray(g), eta))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b,p,n,eta,seed", SWEEP)
def test_pogo_dyn_kernel_matches_ref(b, p, n, eta, seed):
    rng = np.random.default_rng(seed + 100)
    x = random_stiefel(rng, b, p, n)
    g = rng.standard_normal((b, p, n)).astype(np.float32)
    eta_arr = jnp.asarray([eta], jnp.float32)
    got = np.asarray(pk.pogo_step_dyn(jnp.asarray(x), jnp.asarray(g), eta_arr))
    want = np.asarray(ref.pogo_step_ref(jnp.asarray(x), jnp.asarray(g), eta))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b,p,n,eta,seed", SWEEP[:5])
def test_pogo_kernel_feasibility(b, p, n, eta, seed):
    """Kernel output must stay near the manifold when ξ < 1 (Thm 3.5)."""
    rng = np.random.default_rng(seed + 200)
    x = random_stiefel(rng, b, p, n)
    g = rng.standard_normal((b, p, n)).astype(np.float32)
    g = g / np.linalg.norm(g.reshape(b, -1), axis=1)[:, None, None]
    out = pk.pogo_step(jnp.asarray(x), jnp.asarray(g), eta)
    d = np.asarray(ref.stiefel_distance_ref(out))
    assert (d < 1e-3).all(), d


@pytest.mark.parametrize("p,n", [(128, 512), (128, 1024), (256, 512)])
def test_tiled_gram_matches_ref(p, n):
    rng = np.random.default_rng(42)
    x = rng.standard_normal((p, n)).astype(np.float32) * 0.1
    got = np.asarray(gram.gram_residual(jnp.asarray(x)))
    want = np.asarray(ref.gram_residual_ref(jnp.asarray(x)[None])[0])
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_tiled_distance_matches_ref():
    rng = np.random.default_rng(7)
    x = random_stiefel(rng, 1, 128, 512)[0]
    d_kernel = float(gram.stiefel_distance(jnp.asarray(x)))
    d_ref = float(ref.stiefel_distance_ref(jnp.asarray(x)[None])[0])
    assert abs(d_kernel - d_ref) < 1e-4


def test_landing_coeffs_match_direct_evaluation():
    """Lemma 3.1 (with fixed typos): symbolic P(λ) == direct ‖X₁X₁ᵀ−I‖²."""
    rng = np.random.default_rng(3)
    x = random_stiefel(rng, 2, 6, 10)
    g = rng.standard_normal((2, 6, 10)).astype(np.float32)
    m = jnp.asarray(x) - 0.3 * ref.riemannian_gradient_ref(
        jnp.asarray(x), jnp.asarray(g))
    coeffs = np.asarray(ref.landing_coeffs_ref(m))  # (2, 5)
    for lam in [0.0, 0.25, 0.5, 1.0]:
        c = ref.gram_residual_ref(m)
        x1 = m - lam * jnp.einsum("...ij,...jk->...ik", c, m)
        direct = np.asarray(ref.stiefel_distance_ref(x1)) ** 2
        symbolic = np.polyval(coeffs.T, lam)
        np.testing.assert_allclose(direct, symbolic, rtol=1e-3, atol=1e-5)


def test_vadam_is_linear_def1():
    """Def. 1: output direction invariant to input scaling."""
    rng = np.random.default_rng(11)
    g = jnp.asarray(rng.standard_normal((2, 4, 6)).astype(np.float32))
    m0 = jnp.zeros_like(g)
    v0 = jnp.zeros((2, 1, 1), jnp.float32)
    out1, _, _ = ref.vadam_transform_ref(g, m0, v0, 1.0)
    out2, _, _ = ref.vadam_transform_ref(3.7 * g, m0, v0, 1.0)
    cos = np.sum(np.asarray(out1) * np.asarray(out2)) / (
        np.linalg.norm(out1) * np.linalg.norm(out2))
    np.testing.assert_allclose(cos, 1.0, atol=1e-6)


def test_complex_pogo_preserves_unitarity():
    rng = np.random.default_rng(13)
    # Random complex Stiefel point via QR.
    a = rng.standard_normal((8, 4)) + 1j * rng.standard_normal((8, 4))
    q, _ = np.linalg.qr(a)
    x = np.conj(q.T)  # (4, 8) with X X^H = I
    xr = jnp.asarray(x.real[None].astype(np.float32))
    xi = jnp.asarray(x.imag[None].astype(np.float32))
    gr = jnp.asarray(rng.standard_normal((1, 4, 8)).astype(np.float32) * 0.3)
    gi = jnp.asarray(rng.standard_normal((1, 4, 8)).astype(np.float32) * 0.3)
    or_, oi = ref.pogo_step_complex_ref(xr, xi, gr, gi, 0.1)
    xo = np.asarray(or_)[0] + 1j * np.asarray(oi)[0]
    resid = xo @ np.conj(xo.T) - np.eye(4)
    assert np.abs(resid).max() < 1e-3


def test_slpg_and_landing_refs_descend():
    """Smoke: both baseline steps reduce a Procrustes loss."""
    rng = np.random.default_rng(17)
    p = 8
    a = jnp.asarray(rng.standard_normal((p, p)).astype(np.float32))
    bmat = jnp.asarray(rng.standard_normal((p, p)).astype(np.float32))
    x0 = jnp.asarray(random_stiefel(rng, 1, p, p))

    def loss(x):
        r = jnp.einsum("ij,bjk->bik", a, x) - bmat[None]
        return float(jnp.sum(r * r))

    def grad(x):
        r = jnp.einsum("ij,bjk->bik", a, x) - bmat[None]
        return 2.0 * jnp.einsum("ji,bjk->bik", a, r)

    for step in [lambda x, g: ref.landing_step_ref(x, g, 0.005, 1.0),
                 lambda x, g: ref.slpg_step_ref(x, g, 0.005)]:
        x = x0
        l0 = loss(x)
        for _ in range(100):
            x = step(x, grad(x))
        assert loss(x) < l0 * 0.9


def test_pogo_kernel_mxu_estimates():
    """The VMEM/MXU estimators must be monotone and positive (used by
    DESIGN.md's hardware table)."""
    assert pk.vmem_bytes(3, 3) > 0
    assert pk.mxu_flops(128, 512) == 12 * 128 * 128 * 512
    assert pk.vmem_bytes(128, 1024) > pk.vmem_bytes(64, 512)
