"""L2 correctness: model loss/grad programs — shapes, gradient checks
against numerical differentiation on tiny instances, and the Born model's
self-normalization property (the reason orthogonality is *required*)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model
from compile.models import born, cnn, transformer, vit


def _stiefel(rng, p, n):
    g = rng.standard_normal((n, p)).astype(np.float32)
    q, _ = np.linalg.qr(g)
    return np.ascontiguousarray(q.T)


def _unitary(rng, p, n):
    g = rng.standard_normal((n, p)) + 1j * rng.standard_normal((n, p))
    q, _ = np.linalg.qr(g)
    return np.conj(q.T)


# ---------------------------------------------------------------------------
# Fig. 4 loss+grad programs.
# ---------------------------------------------------------------------------


def test_pca_lossgrad_closed_form():
    rng = np.random.default_rng(0)
    p, n = 6, 10
    x = jnp.asarray(_stiefel(rng, p, n))
    a = rng.standard_normal((n, n)).astype(np.float32)
    aat = jnp.asarray(a @ a.T)
    loss, grad = model.pca_lossgrad_program(x, aat)
    want_loss = -np.linalg.norm(np.asarray(x) @ a) ** 2
    np.testing.assert_allclose(float(loss), want_loss, rtol=1e-4)
    # Autodiff cross-check.
    auto = jax.grad(lambda x: -jnp.sum(jnp.dot(x, aat) * x))(x)
    np.testing.assert_allclose(np.asarray(grad), np.asarray(auto),
                               rtol=1e-4, atol=1e-4)


def test_procrustes_lossgrad_closed_form():
    rng = np.random.default_rng(1)
    p, n = 5, 8
    x = jnp.asarray(_stiefel(rng, p, n))
    a = jnp.asarray(rng.standard_normal((p, p)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((p, n)).astype(np.float32))
    loss, grad = model.procrustes_lossgrad_program(x, a, b)
    auto_l, auto_g = jax.value_and_grad(
        lambda x: jnp.sum((jnp.dot(a, x) - b) ** 2))(x)
    np.testing.assert_allclose(float(loss), float(auto_l), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(grad), np.asarray(auto_g),
                               rtol=1e-4, atol=1e-4)


def test_fused_pca_step_matches_two_phase():
    rng = np.random.default_rng(2)
    p, n = 6, 10
    x = jnp.asarray(_stiefel(rng, p, n))
    a = rng.standard_normal((n, n)).astype(np.float32)
    aat = jnp.asarray(a @ a.T)
    eta = jnp.asarray([0.01], jnp.float32)
    x_fused, loss_f, d_f = model.pca_pogo_fused_program(x, aat, eta)
    loss_2, grad_2 = model.pca_lossgrad_program(x, aat)
    (x_two,) = model.pogo_step_program(x[None], grad_2[None], eta)
    np.testing.assert_allclose(np.asarray(x_fused), np.asarray(x_two)[0],
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(loss_f), float(loss_2), rtol=1e-5)
    assert float(d_f) < 1e-2


# ---------------------------------------------------------------------------
# CNN.
# ---------------------------------------------------------------------------


def _cnn_filter_params(rng):
    ws = [jnp.asarray(_stiefel(rng, o, ik)) for o, ik in cnn.FILTER_SHAPES]
    head = jnp.asarray(rng.standard_normal(cnn.HEAD_SHAPE).astype(np.float32) * 0.1)
    return ws + [head]


def test_cnn_filters_shapes_and_grads():
    rng = np.random.default_rng(3)
    params = _cnn_filter_params(rng)
    imgs = jnp.asarray(rng.standard_normal((4, 32, 32, 3)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, 10, 4).astype(np.int32))
    out = cnn.cnn_filters_lossgrad_program(*params, imgs, labels)
    loss, grads = out[0], out[1:]
    assert np.isfinite(float(loss))
    assert len(grads) == 4
    for g, p in zip(grads, params):
        assert g.shape == p.shape
        assert np.isfinite(np.asarray(g)).all()


def test_cnn_kernels_shapes_and_grads():
    rng = np.random.default_rng(4)
    ks = []
    for c in cnn.KERNEL_COUNTS:
        qs = np.stack([_stiefel(rng, 3, 3) for _ in range(c)])
        ks.append(jnp.asarray(qs))
    head = jnp.asarray(rng.standard_normal(cnn.HEAD_SHAPE).astype(np.float32) * 0.1)
    imgs = jnp.asarray(rng.standard_normal((2, 32, 32, 3)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, 10, 2).astype(np.int32))
    out = cnn.cnn_kernels_lossgrad_program(*ks, head, imgs, labels)
    loss, grads = out[0], out[1:]
    assert np.isfinite(float(loss))
    for g, p in zip(grads, ks + [head]):
        assert g.shape == p.shape


def test_cnn_eval_accuracy_range():
    rng = np.random.default_rng(5)
    params = _cnn_filter_params(rng)
    imgs = jnp.asarray(rng.standard_normal((8, 32, 32, 3)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, 10, 8).astype(np.int32))
    loss, acc = cnn.cnn_filters_eval_program(*params, imgs, labels)
    assert 0.0 <= float(acc) <= 1.0
    assert np.isfinite(float(loss))


# ---------------------------------------------------------------------------
# ViT.
# ---------------------------------------------------------------------------


def _vit_params(rng):
    orth = np.stack([_stiefel(rng, *vit.ORTH_SHAPE) for _ in range(vit.N_ORTH)])
    return [
        jnp.asarray(orth),
        jnp.asarray(rng.standard_normal(vit.PATCH_W_SHAPE).astype(np.float32) * 0.05),
        jnp.asarray(rng.standard_normal(vit.POS_SHAPE).astype(np.float32) * 0.02),
        jnp.asarray(rng.standard_normal(vit.HEAD_SHAPE).astype(np.float32) * 0.05),
    ]


def test_vit_lossgrad_shapes():
    rng = np.random.default_rng(6)
    params = _vit_params(rng)
    imgs = jnp.asarray(rng.standard_normal((2, 32, 32, 3)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, 10, 2).astype(np.int32))
    out = vit.vit_lossgrad_program(*params, imgs, labels)
    loss, grads = out[0], out[1:]
    assert np.isfinite(float(loss))
    for g, p in zip(grads, params):
        assert g.shape == p.shape
        assert np.isfinite(np.asarray(g)).all()


def test_vit_has_18_orthogonal_matrices():
    assert vit.N_ORTH == 18  # the paper's Fig. 5 count


# ---------------------------------------------------------------------------
# Born machine (squared unitary circuit).
# ---------------------------------------------------------------------------


def _born_cores(rng):
    cores = []
    for (p, n) in born.core_shapes():
        u = _unitary(rng, p, n)
        cores += [jnp.asarray(u.real.astype(np.float32)),
                  jnp.asarray(u.imag.astype(np.float32))]
    return cores


def test_born_self_normalization():
    """THE property: with unitary cores, Σₓ p(x) = 1 exactly — no partition
    function. This is why Fig. 8 needs an orthoptimizer."""
    rng = np.random.default_rng(7)
    cores = _born_cores(rng)
    (total,) = born.born_total_prob_program(*cores)
    np.testing.assert_allclose(float(total), 1.0, rtol=1e-3)


def test_born_normalization_breaks_off_manifold():
    """Perturb one core off the Stiefel manifold → Σₓ p(x) ≠ 1."""
    rng = np.random.default_rng(8)
    cores = _born_cores(rng)
    cores[8] = cores[8] + 0.2 * jnp.asarray(
        rng.standard_normal(cores[8].shape).astype(np.float32))
    (total,) = born.born_total_prob_program(*cores)
    assert abs(float(total) - 1.0) > 1e-3


def test_born_lossgrad_shapes():
    rng = np.random.default_rng(9)
    cores = _born_cores(rng)
    bits = jnp.asarray(rng.integers(0, 2, (16, born.T_SITES)).astype(np.int32))
    out = born.born_lossgrad_program(*cores, bits)
    loss, grads = out[0], out[1:]
    assert np.isfinite(float(loss))
    assert len(grads) == len(cores)
    for g, c in zip(grads, cores):
        assert g.shape == c.shape


def test_born_bpd_reasonable():
    rng = np.random.default_rng(10)
    cores = _born_cores(rng)
    bits = jnp.asarray(rng.integers(0, 2, (64, born.T_SITES)).astype(np.int32))
    (bpd,) = born.born_eval_program(*cores, bits)
    # Random unitary model on uniform bits: bpd ≈ 1 (cannot beat uniform).
    assert 0.5 < float(bpd) < 5.0


# ---------------------------------------------------------------------------
# Transformer LM.
# ---------------------------------------------------------------------------


def _lm_params(rng):
    tf = transformer
    orth = np.stack([_stiefel(rng, *tf.ORTH_SHAPE) for _ in range(tf.N_ORTH)])
    return [
        jnp.asarray(orth),
        jnp.asarray(rng.standard_normal(tf.TOK_EMB_SHAPE).astype(np.float32) * 0.02),
        jnp.asarray(rng.standard_normal(tf.POS_EMB_SHAPE).astype(np.float32) * 0.02),
        jnp.asarray(rng.standard_normal((tf.LAYERS, *tf.MLP_W1_SHAPE)).astype(np.float32) * 0.02),
        jnp.asarray(rng.standard_normal((tf.LAYERS, *tf.MLP_W2_SHAPE)).astype(np.float32) * 0.02),
        jnp.asarray(rng.standard_normal(tf.HEAD_SHAPE).astype(np.float32) * 0.02),
    ]


@pytest.mark.slow
def test_lm_lossgrad_shapes():
    rng = np.random.default_rng(11)
    params = _lm_params(rng)
    tokens = jnp.asarray(
        rng.integers(0, transformer.VOCAB, (2, transformer.SEQ + 1)).astype(np.int32))
    out = transformer.lm_lossgrad_program(*params, tokens)
    loss, grads = out[0], out[1:]
    # Initial loss ≈ ln(V) for random params.
    assert abs(float(loss) - np.log(transformer.VOCAB)) < 1.0
    for g, p in zip(grads, params):
        assert g.shape == p.shape
        assert np.isfinite(np.asarray(g)).all()


def test_step_programs_roundtrip_small():
    """pogo/landing/slpg step programs: shapes + feasibility smoke."""
    rng = np.random.default_rng(12)
    x = np.stack([_stiefel(rng, 8, 16) for _ in range(4)])
    g = rng.standard_normal((4, 8, 16)).astype(np.float32)
    g = g / np.linalg.norm(g.reshape(4, -1), axis=1)[:, None, None]
    eta = jnp.asarray([0.1], jnp.float32)
    (xp,) = model.pogo_step_program(jnp.asarray(x), jnp.asarray(g), eta)
    assert xp.shape == x.shape
    one = jnp.asarray([1.0], jnp.float32)
    x_l, d = model.landing_step_program(jnp.asarray(x), jnp.asarray(g), eta,
                                        one, 0.5 * one)
    assert x_l.shape == x.shape and d.shape == (4,)
    (x_s,) = model.slpg_step_program(jnp.asarray(x), jnp.asarray(g), eta)
    assert x_s.shape == x.shape


def test_pogo_coeffs_and_normal_programs():
    """FindRoot path: coefficients → (L3 solves quartic) → normal step."""
    rng = np.random.default_rng(13)
    x = np.stack([_stiefel(rng, 6, 10) for _ in range(2)])
    g = rng.standard_normal((2, 6, 10)).astype(np.float32)
    eta = jnp.asarray([0.2], jnp.float32)
    m, coeffs = model.pogo_landing_coeffs_program(
        jnp.asarray(x), jnp.asarray(g), eta)
    assert m.shape == x.shape and coeffs.shape == (2, 5)
    lam = jnp.asarray([0.5, 0.5], jnp.float32)
    (xp,) = model.pogo_normal_program(m, lam)
    want = np.asarray(model.pogo_step_program(
        jnp.asarray(x), jnp.asarray(g), eta)[0])
    np.testing.assert_allclose(np.asarray(xp), want, rtol=1e-4, atol=1e-5)
