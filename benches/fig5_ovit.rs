//! Regenerates Fig. 5: O-ViT test accuracy + manifold distance across the
//! full orthoptimizer lineup (18 orthogonal 128×128 matrices inside a
//! transformer classifier).

use pogo::config::{ExperimentId, RunConfig};
use pogo::optim::Method;

fn main() {
    pogo::util::logging::init();
    let quick = std::env::var("POGO_BENCH_QUICK").is_ok();
    let mut cfg = RunConfig::new(ExperimentId::Fig5Ovit);
    cfg.steps = if quick { 6 } else { 40 };
    if quick {
        cfg.methods = vec![Method::Pogo, Method::Rgd, Method::Adam];
    }
    if let Err(e) = pogo::experiments::run(&cfg) {
        eprintln!("fig5 failed: {e:#}");
        std::process::exit(1);
    }
}
