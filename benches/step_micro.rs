//! Micro-benchmarks: single-step latency of every orthoptimizer at the
//! paper's shape regimes, on all engines, plus the linalg substrate's
//! primitive costs. This quantifies the paper's Table-level claim that the
//! POGO update is "5 matrix products" away from unconstrained SGD while
//! QR-class retractions pay host-side, non-batchable costs.
//!
//! The batched-vs-loop sweep at the Fig. 1 regime additionally writes
//! `BENCH_scale.json` (redirect with `POGO_BENCH_JSON`); CI's
//! `bench-smoke` job runs this bench with `POGO_BENCH_QUICK=1` and fails
//! if `speedup_batched_vs_loop` drops below 1 at B = 4096.

use pogo::bench::{bench, bench_items, print_table, BenchOpts, ScaleRecord, Stats};
use pogo::coordinator::OptimizerSpec;
use pogo::experiments::scale::make_group;
use pogo::linalg::{matmul, matmul_a_bt, qr_retract_rows, MatF};
use pogo::manifold::stiefel;
use pogo::optim::{Engine, Method};
use pogo::rng::Rng;
use pogo::runtime::Registry;

fn main() {
    pogo::util::logging::init();
    let opts = BenchOpts::from_env();
    let mut rng = Rng::seed_from_u64(0);

    // ---- Substrate primitives at the Fig. 4 shape. ----------------------
    let (p, n) = (300, 400);
    let x = stiefel::random_point(p, n, &mut rng);
    let g = MatF::randn(p, n, &mut rng);
    let mut prim = Vec::new();
    let aat = pogo::linalg::matmul_at_b(&g, &g); // n×n
    prim.push(bench(&format!("matmul {p}x{n} · {n}x{n}"), opts, || {
        pogo::bench::black_box(matmul(&x, &aat));
    }));
    prim.push(bench(&format!("gram X·Xᵀ ({p}x{n})"), opts, || {
        pogo::bench::black_box(matmul_a_bt(&x, &x));
    }));
    prim.push(bench(&format!("QR retraction ({p}x{n})"), opts, || {
        pogo::bench::black_box(qr_retract_rows(&x));
    }));
    print_table("linalg substrate primitives", &prim);

    // ---- Rust-engine optimizer steps at (300, 400). ----------------------
    let mut rust_steps: Vec<Stats> = Vec::new();
    for &m in &[Method::Pogo, Method::Landing, Method::LandingPC, Method::Slpg,
                Method::Rgd, Method::Rsdm] {
        let spec = OptimizerSpec::new(m, 1e-4).with_submanifold(150);
        let mut opt = spec.build::<f32>(None, (1, p, n)).unwrap();
        let mut xs = vec![x.clone()];
        let gs = vec![g.scale(1e-3)];
        rust_steps.push(bench(&format!("{} step {p}x{n} [rust]", m.name()), opts, || {
            opt.step_group(&mut xs, &gs).unwrap();
        }));
        // keep iterates sane between iterations
        xs[0] = x.clone();
    }
    print_table("optimizer single-matrix step (rust engine)", &rust_steps);

    // ---- Batched host engine vs per-matrix loop (Fig. 1 regime). --------
    // The headline of the batched subsystem: µs/matrix of ONE packed
    // (B, 3, 3) step against the sequential loop, plus the speedup map
    // that lands in BENCH_scale.json.
    let quick = std::env::var("POGO_BENCH_QUICK").is_ok();
    // Below B ≈ 19.4k the batched engine wins on packing alone (serial
    // kernels, no allocator churn); only B = 32768 crosses
    // BATCH_PAR_FLOPS and also exercises the pool-sharded path, which is
    // why the ≥4× target is stated there and CI's robust gate is the
    // packing-only B = 4096 point.
    let batches: &[usize] = if quick {
        &[512, 4096, 8192]
    } else {
        &[64, 512, 4096, 8192, 32768]
    };
    let mut host_stats: Vec<Stats> = Vec::new();
    let mut scale_rows: Vec<ScaleRecord> = Vec::new();
    let mut speedups: Vec<(usize, f64)> = Vec::new();
    for &b in batches {
        let spec = OptimizerSpec::new(Method::Pogo, 0.1);
        let mut measure = |label: &str, engine: Engine| {
            let mut opt = spec.with_engine(engine).build::<f32>(None, (b, 3, 3)).unwrap();
            let (mut xs, gs) = make_group(b, &mut rng);
            opt.step_group(&mut xs, &gs).unwrap(); // warm-up (pool, allocator)
            let s = bench_items(&format!("{label} B={b} 3x3"), opts, b as f64, || {
                opt.step_group(&mut xs, &gs).unwrap();
            });
            scale_rows.push(ScaleRecord {
                label: label.to_string(),
                batch: b,
                us_per_matrix: s.mean * 1e6 / b as f64,
            });
            let mean = s.mean;
            host_stats.push(s);
            mean
        };
        let t_loop = measure("POGO[loop]", Engine::Rust);
        let t_batched = measure("POGO[batched]", Engine::BatchedHost);
        if t_batched > 0.0 {
            speedups.push((b, t_loop / t_batched));
        }
    }
    print_table("POGO batched host engine vs per-matrix loop (matrices/s)", &host_stats);
    for &(b, s) in &speedups {
        println!("  batched-vs-loop speedup at B={b}: {s:.2}x");
    }
    let default_json = pogo::repo_root().join("BENCH_scale.json");
    match pogo::bench::write_scale_json(&default_json, &scale_rows, &speedups) {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("failed to write BENCH_scale.json: {e}"),
    }

    // ---- XLA-engine steps (matmul-only methods). -------------------------
    match Registry::open_default() {
        Ok(reg) => {
            let mut xla_steps = Vec::new();
            for &m in &[Method::Pogo, Method::Landing, Method::Slpg] {
                let spec = OptimizerSpec::new(m, 1e-4).with_engine(Engine::Xla);
                let mut opt = spec.build::<f32>(Some(&reg), (1, p, n)).unwrap();
                let mut xs = vec![x.clone()];
                let gs = vec![g.scale(1e-3)];
                opt.step_group(&mut xs, &gs).unwrap(); // warm-up compile
                xla_steps.push(bench(
                    &format!("{} step {p}x{n} [xla]", m.name()),
                    opts,
                    || {
                        opt.step_group(&mut xs, &gs).unwrap();
                    },
                ));
                xs[0] = x.clone();
            }
            // Batched 3×3 regime: throughput per matrix.
            for &b in &[512usize, 4096] {
                let spec = OptimizerSpec::new(Method::Pogo, 0.1).with_engine(Engine::Xla);
                let mut opt = spec.build::<f32>(Some(&reg), (b, 3, 3)).unwrap();
                let mut xs: Vec<MatF> =
                    (0..b).map(|_| stiefel::random_point(3, 3, &mut rng)).collect();
                let gs: Vec<MatF> = (0..b)
                    .map(|_| {
                        let g = MatF::randn(3, 3, &mut rng);
                        let nn = g.norm();
                        g.scale(0.3 / nn)
                    })
                    .collect();
                opt.step_group(&mut xs, &gs).unwrap();
                xla_steps.push(bench_items(
                    &format!("POGO batched step B={b} 3x3 [xla]"),
                    opts,
                    b as f64,
                    || {
                        opt.step_group(&mut xs, &gs).unwrap();
                    },
                ));
            }
            print_table("optimizer steps (xla engine; throughput = matrices/s)",
                        &xla_steps);
        }
        Err(e) => eprintln!("skipping xla benches: {e}"),
    }
}
