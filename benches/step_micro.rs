//! Micro-benchmarks: single-step latency of every orthoptimizer at the
//! paper's shape regimes, on both engines, plus the linalg substrate's
//! primitive costs. This quantifies the paper's Table-level claim that the
//! POGO update is "5 matrix products" away from unconstrained SGD while
//! QR-class retractions pay host-side, non-batchable costs.

use pogo::bench::{bench, bench_items, print_table, BenchOpts, Stats};
use pogo::coordinator::OptimizerSpec;
use pogo::linalg::{matmul, matmul_a_bt, qr_retract_rows, MatF};
use pogo::manifold::stiefel;
use pogo::optim::{Engine, Method};
use pogo::rng::Rng;
use pogo::runtime::Registry;

fn main() {
    pogo::util::logging::init();
    let opts = BenchOpts::from_env();
    let mut rng = Rng::seed_from_u64(0);

    // ---- Substrate primitives at the Fig. 4 shape. ----------------------
    let (p, n) = (300, 400);
    let x = stiefel::random_point(p, n, &mut rng);
    let g = MatF::randn(p, n, &mut rng);
    let mut prim = Vec::new();
    let aat = pogo::linalg::matmul_at_b(&g, &g); // n×n
    prim.push(bench(&format!("matmul {p}x{n} · {n}x{n}"), opts, || {
        pogo::bench::black_box(matmul(&x, &aat));
    }));
    prim.push(bench(&format!("gram X·Xᵀ ({p}x{n})"), opts, || {
        pogo::bench::black_box(matmul_a_bt(&x, &x));
    }));
    prim.push(bench(&format!("QR retraction ({p}x{n})"), opts, || {
        pogo::bench::black_box(qr_retract_rows(&x));
    }));
    print_table("linalg substrate primitives", &prim);

    // ---- Rust-engine optimizer steps at (300, 400). ----------------------
    let mut rust_steps: Vec<Stats> = Vec::new();
    for &m in &[Method::Pogo, Method::Landing, Method::LandingPC, Method::Slpg,
                Method::Rgd, Method::Rsdm] {
        let spec = OptimizerSpec::new(m, 1e-4).with_submanifold(150);
        let mut opt = spec.build::<f32>(None, (1, p, n)).unwrap();
        let mut xs = vec![x.clone()];
        let gs = vec![g.scale(1e-3)];
        rust_steps.push(bench(&format!("{} step {p}x{n} [rust]", m.name()), opts, || {
            opt.step_group(&mut xs, &gs).unwrap();
        }));
        // keep iterates sane between iterations
        xs[0] = x.clone();
    }
    print_table("optimizer single-matrix step (rust engine)", &rust_steps);

    // ---- XLA-engine steps (matmul-only methods). -------------------------
    match Registry::open_default() {
        Ok(reg) => {
            let mut xla_steps = Vec::new();
            for &m in &[Method::Pogo, Method::Landing, Method::Slpg] {
                let spec = OptimizerSpec::new(m, 1e-4).with_engine(Engine::Xla);
                let mut opt = spec.build::<f32>(Some(&reg), (1, p, n)).unwrap();
                let mut xs = vec![x.clone()];
                let gs = vec![g.scale(1e-3)];
                opt.step_group(&mut xs, &gs).unwrap(); // warm-up compile
                xla_steps.push(bench(
                    &format!("{} step {p}x{n} [xla]", m.name()),
                    opts,
                    || {
                        opt.step_group(&mut xs, &gs).unwrap();
                    },
                ));
                xs[0] = x.clone();
            }
            // Batched 3×3 regime: throughput per matrix.
            for &b in &[512usize, 4096] {
                let spec = OptimizerSpec::new(Method::Pogo, 0.1).with_engine(Engine::Xla);
                let mut opt = spec.build::<f32>(Some(&reg), (b, 3, 3)).unwrap();
                let mut xs: Vec<MatF> =
                    (0..b).map(|_| stiefel::random_point(3, 3, &mut rng)).collect();
                let gs: Vec<MatF> = (0..b)
                    .map(|_| {
                        let g = MatF::randn(3, 3, &mut rng);
                        let nn = g.norm();
                        g.scale(0.3 / nn)
                    })
                    .collect();
                opt.step_group(&mut xs, &gs).unwrap();
                xla_steps.push(bench_items(
                    &format!("POGO batched step B={b} 3x3 [xla]"),
                    opts,
                    b as f64,
                    || {
                        opt.step_group(&mut xs, &gs).unwrap();
                    },
                ));
            }
            print_table("optimizer steps (xla engine; throughput = matrices/s)",
                        &xla_steps);
        }
        Err(e) => eprintln!("skipping xla benches: {e}"),
    }
}
