//! Regenerates Fig. 1 + Fig. 6 + Fig. 7: CNN training with orthogonal
//! filters and with orthogonal kernels (time-vs-accuracy + normalized
//! distance + accuracy evolution), plus the batch-scaling table behind the
//! "3 minutes vs 17 hours" headline (delegated to the scale experiment).

use pogo::config::{ExperimentId, RunConfig};
use pogo::optim::Method;

fn main() {
    pogo::util::logging::init();
    let quick = std::env::var("POGO_BENCH_QUICK").is_ok();

    // Fig. 1/6 (filters): full lineup, bounded steps.
    let mut filters = RunConfig::new(ExperimentId::Fig1CnnFilters);
    filters.steps = if quick { 6 } else { 40 };
    if let Err(e) = pogo::experiments::run(&filters) {
        eprintln!("fig1-filters failed: {e:#}");
        std::process::exit(1);
    }

    // Fig. 1/7 (kernels): the expensive lineup members (per-matrix QR over
    // 9800 kernels) are the point of the figure but dominate bench time —
    // keep POGO/Landing/Adam every run, add RGD/RSDM unless quick.
    let mut kernels = RunConfig::new(ExperimentId::Fig1CnnKernels);
    kernels.steps = if quick { 4 } else { 25 };
    kernels.methods = if quick {
        vec![Method::Pogo, Method::Adam]
    } else {
        vec![Method::Pogo, Method::Landing, Method::LandingPC, Method::Rgd,
             Method::Rsdm, Method::Adam]
    };
    if let Err(e) = pogo::experiments::run(&kernels) {
        eprintln!("fig1-kernels failed: {e:#}");
        std::process::exit(1);
    }
}
