//! Per-kernel step benchmarks: the fused single-pass `StepKernel` path vs
//! the 5-pass naive composition, on prepacked `(B, p, n)` groups at the
//! paper's Fig. 1 (tiny 3×3) and Fig. 8 (16×16 heads) shape regimes.
//!
//! Both paths are bit-identical by contract (`tests/fused_parity.rs` pins
//! this elementwise), so this bench measures the only thing that differs:
//! memory traffic and dispatch overhead. Packing cost is excluded — the
//! groups are packed once and `step_batch` is driven directly, which is
//! exactly what the batched engine does in steady state.
//!
//! Writes `BENCH_kernels.json` (redirect: `POGO_BENCH_JSON_KERNELS`);
//! CI's `bench-smoke` job runs this with `POGO_BENCH_QUICK=1` and fails
//! if `speedup_fused_vs_naive` drops below 1 at f32 (16,16), B = 4096.

use pogo::bench::{bench_items, print_table, BenchOpts, KernelRecord, Stats};
use pogo::linalg::{BatchMat, Field, KernelChoice, Mat, Scalar};
use pogo::manifold::stiefel;
use pogo::optim::base::BaseOptKind;
use pogo::optim::batched::BatchedHost;
use pogo::optim::pogo::LambdaPolicy;
use pogo::optim::Orthoptimizer;
use pogo::rng::Rng;

/// One packed problem instance: B row-orthogonal iterates + scaled grads.
fn make_packed<S: Scalar>(
    b: usize,
    p: usize,
    n: usize,
    rng: &mut Rng,
) -> (BatchMat<S>, BatchMat<S>) {
    let xs: Vec<Mat<S>> = (0..b).map(|_| stiefel::random_point_t::<S>(p, n, rng)).collect();
    let gs: Vec<Mat<S>> = (0..b)
        .map(|_| {
            let g = Mat::<S>::randn(p, n, rng);
            let nn = g.norm().to_f64().max(1e-6);
            g.scale(S::from_f64(0.3 / nn))
        })
        .collect();
    (BatchMat::from_mats(&xs), BatchMat::from_mats(&gs))
}

/// Measure one (rule, dtype, path) cell and return its stats + record.
#[allow(clippy::too_many_arguments)]
fn measure<S: Scalar>(
    opts: BenchOpts,
    rule: &str,
    dtype: &str,
    kernel: KernelChoice,
    b: usize,
    p: usize,
    n: usize,
    rng: &mut Rng,
) -> (Stats, KernelRecord) {
    let mut opt: BatchedHost<S> = match rule {
        "pogo" => BatchedHost::pogo(0.05, LambdaPolicy::Half, BaseOptKind::Sgd),
        "landing" => BatchedHost::landing(0.05, 1.0, BaseOptKind::Sgd),
        other => panic!("unknown rule {other}"),
    };
    opt = opt.with_kernel(kernel);
    let (mut xb, gb) = make_packed::<S>(b, p, n, rng);
    opt.step_batch(&mut xb, &gb).unwrap(); // warm-up (pool, allocator)
    let kname = match kernel {
        KernelChoice::Naive => "naive",
        _ => "fused",
    };
    let s = bench_items(
        &format!("{rule}-{dtype}[{kname}] B={b} {p}x{n}"),
        opts,
        b as f64,
        || {
            opt.step_batch(&mut xb, &gb).unwrap();
        },
    );
    let us_per_matrix = s.mean * 1e6 / b as f64;
    // Iterate traffic: read X, read G, write X — the irreducible bytes a
    // step must move regardless of path.
    let bytes = (3 * b * p * n * std::mem::size_of::<S>()) as f64;
    let gb_per_s = bytes / s.mean / (1u64 << 30) as f64;
    let rec = KernelRecord {
        label: format!("{rule}-{dtype}"),
        kernel: kname.to_string(),
        p,
        n,
        batch: b,
        us_per_matrix,
        gb_per_s,
    };
    (s, rec)
}

/// Race fused vs naive at one cell; push both records and the speedup.
#[allow(clippy::too_many_arguments)]
fn race<S: Scalar>(
    opts: BenchOpts,
    rule: &str,
    dtype: &str,
    b: usize,
    p: usize,
    n: usize,
    key_suffix: &str,
    rng: &mut Rng,
    stats: &mut Vec<Stats>,
    records: &mut Vec<KernelRecord>,
    speedups: &mut Vec<(String, f64)>,
) {
    let (s_naive, r_naive) = measure::<S>(opts, rule, dtype, KernelChoice::Naive, b, p, n, rng);
    let (s_fused, r_fused) = measure::<S>(opts, rule, dtype, KernelChoice::Fused, b, p, n, rng);
    if s_fused.mean > 0.0 && rule == "pogo" {
        speedups.push((format!("{p}x{n}@{b}{key_suffix}"), s_naive.mean / s_fused.mean));
    }
    stats.push(s_naive);
    stats.push(s_fused);
    records.push(r_naive);
    records.push(r_fused);
}

fn main() {
    pogo::util::logging::init();
    let opts = BenchOpts::from_env();
    let quick = std::env::var("POGO_BENCH_QUICK").is_ok();
    let mut rng = Rng::seed_from_u64(0);

    let selected = <f32 as Field>::step_kernel().name();
    println!("selected f32 step kernel: {selected}");
    println!("selected f64 step kernel: {}", <f64 as Field>::step_kernel().name());

    // B = 4096 must stay in the quick profile: CI's jq gate reads the
    // "16x16@4096" speedup from the quick run.
    let batches: &[usize] = if quick { &[1024, 4096] } else { &[1024, 4096, 32768] };
    let shapes: &[(usize, usize)] = &[(3, 3), (8, 16), (16, 16)];

    let mut stats: Vec<Stats> = Vec::new();
    let mut records: Vec<KernelRecord> = Vec::new();
    let mut speedups: Vec<(String, f64)> = Vec::new();

    // f32 POGO across the full shape × batch grid (the headline race).
    for &(p, n) in shapes {
        for &b in batches {
            race::<f32>(opts, "pogo", "f32", b, p, n, "", &mut rng,
                        &mut stats, &mut records, &mut speedups);
        }
    }
    // f64 POGO at the Fig. 8 head shape (precision-ablation dtype).
    for &b in batches {
        race::<f64>(opts, "pogo", "f64", b, 16, 16, ":f64", &mut rng,
                    &mut stats, &mut records, &mut speedups);
    }
    // Landing coverage at one representative cell (no speedup key; the
    // gate is POGO's).
    race::<f32>(opts, "landing", "f32", 4096, 16, 16, "", &mut rng,
                &mut stats, &mut records, &mut speedups);

    print_table("fused vs naive step kernels (throughput = matrices/s)", &stats);
    for (k, s) in &speedups {
        println!("  fused-vs-naive speedup at {k}: {s:.2}x");
    }

    let default_json = pogo::repo_root().join("BENCH_kernels.json");
    match pogo::bench::write_kernels_json(&default_json, selected, &records, &speedups) {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("failed to write BENCH_kernels.json: {e}"),
    }
}
