//! Load generator for the serve daemon: an in-process `pogo serve` on an
//! ephemeral loopback port, hammered by 1/4/16 concurrent clients each
//! submitting B = 1024 POGO jobs (the Fig. 1 batch regime on the
//! batched-host engine) and blocking until `done` — first through the v1
//! polling client, then through the v2 SSE streaming client (submit →
//! follow `/v2/jobs/:id/events` to the terminal event).
//!
//! Emits `BENCH_serve.json` — end-to-end jobs/s plus p50/p95 submit→done
//! latency per concurrency level for both client styles (redirect:
//! `POGO_BENCH_JSON_SERVE`; `POGO_BENCH_QUICK=1` shrinks budgets for
//! CI's `serve-smoke` job, which gates on the file being well-formed).

use pogo::bench::ServeLoadRow;
use pogo::coordinator::OptimizerSpec;
use pogo::optim::{Engine, Method};
use pogo::serve::{JobSpec, ProblemKind, ServeClient, ServeConfig, Server};
use pogo::util::Stopwatch;
use std::sync::Mutex;
use std::time::Duration;

fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let idx = ((sorted.len() as f64 - 1.0) * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

fn job_spec(client: usize, j: usize, steps: usize, tag: &str) -> JobSpec {
    let mut spec = JobSpec::new(ProblemKind::Quartic, 1024, 3, 3);
    spec.name = format!("load-{tag}-c{client}-j{j}");
    spec.steps = steps;
    spec.seed = (client as u64) * 1009 + j as u64;
    spec.optimizer = OptimizerSpec::new(Method::Pogo, 0.05).with_engine(Engine::BatchedHost);
    spec
}

/// Run one concurrency level; `drive` is the per-job client style
/// (poll-to-done or stream-to-terminal). Returns (wall_s, sorted ms).
fn run_level(
    addr: &str,
    clients: usize,
    jobs_per_client: usize,
    steps: usize,
    tag: &str,
    drive: impl Fn(&ServeClient, u64) + Sync,
) -> (f64, Vec<f64>) {
    let latencies: Mutex<Vec<f64>> = Mutex::new(Vec::new());
    let wall = Stopwatch::start();
    std::thread::scope(|scope| {
        for c in 0..clients {
            let addr = addr.to_string();
            let latencies = &latencies;
            let drive = &drive;
            scope.spawn(move || {
                let client = ServeClient::new(addr);
                for j in 0..jobs_per_client {
                    let spec = job_spec(c, j, steps, tag);
                    let t = Stopwatch::start();
                    let id = client.submit(&spec).expect("submit");
                    drive(&client, id);
                    latencies.lock().unwrap().push(t.seconds() * 1e3);
                }
            });
        }
    });
    let wall_s = wall.seconds();
    let mut lat = latencies.into_inner().unwrap();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (wall_s, lat)
}

fn main() {
    pogo::util::logging::init();
    let quick = std::env::var("POGO_BENCH_QUICK").is_ok();
    let steps = if quick { 5 } else { 50 };
    let jobs_per_client = if quick { 2 } else { 4 };

    let server = Server::start(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        workers: pogo::util::pool::num_threads().clamp(2, 4),
        capacity: 1024,
        state_dir: None,
    })
    .expect("starting in-process serve daemon");
    let addr = server.addr().to_string();
    println!("serve_load: daemon on {addr}, B=1024 POGO[batched] x {steps} steps");

    let mut rows: Vec<ServeLoadRow> = Vec::new();
    for &clients in &[1usize, 4, 16] {
        // v1 polling client: submit → poll status → fetch result.
        let (wall_s, lat) =
            run_level(&addr, clients, jobs_per_client, steps, "poll", |client, id| {
                client
                    .wait_result(id, Duration::from_secs(600))
                    .expect("job should reach done");
            });
        // v2 streaming client: submit → consume SSE to the terminal
        // event → fetch the full series + iterate.
        let (_, stream_lat) =
            run_level(&addr, clients, jobs_per_client, steps, "sse", |client, id| {
                client
                    .stream_result(id, Duration::from_secs(600))
                    .expect("streamed job should reach done");
            });
        let jobs = clients * jobs_per_client;
        let row = ServeLoadRow {
            clients,
            jobs,
            jobs_per_s: jobs as f64 / wall_s,
            p50_ms: percentile(&lat, 0.50),
            p95_ms: percentile(&lat, 0.95),
            stream_p50_ms: percentile(&stream_lat, 0.50),
            stream_p95_ms: percentile(&stream_lat, 0.95),
        };
        println!(
            "  {:>2} client(s): {:>4} jobs in {:6.2}s  ->  {:7.2} jobs/s, \
             poll p50 {:7.1} ms / p95 {:7.1} ms, sse p50 {:7.1} ms / p95 {:7.1} ms",
            row.clients,
            row.jobs,
            wall_s,
            row.jobs_per_s,
            row.p50_ms,
            row.p95_ms,
            row.stream_p50_ms,
            row.stream_p95_ms
        );
        rows.push(row);
    }

    let default_json = pogo::repo_root().join("BENCH_serve.json");
    match pogo::bench::write_serve_json(&default_json, &rows) {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("failed to write BENCH_serve.json: {e}"),
    }
    server.shutdown();
}
