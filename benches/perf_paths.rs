//! §Perf instrumentation: decomposes the Fig. 4 hot path into its parts
//! and measures the optimized alternatives, so EXPERIMENTS.md §Perf has
//! before/after numbers for each iteration.
//!
//! Paths measured at the Fig. 4 PCA shape (300 × 400):
//! 1. `lossgrad + step` (two dispatches, AAT re-uploaded each step) — the
//!    baseline two-phase trainer path;
//! 2. `fused` (ONE dispatch: grad + POGO step + loss + distance, X stays
//!    in the executable, AAT still uploaded) — the L2 fusion;
//! 3. the pure pack/unpack marshalling overhead at the CNN kernel shape.

use pogo::bench::{bench, bench_items, print_table, BenchOpts};
use pogo::linalg::{matmul_at_b, MatF};
use pogo::manifold::stiefel;
use pogo::rng::Rng;
use pogo::runtime::{Arg, Registry};

fn main() {
    pogo::util::logging::init();
    let opts = BenchOpts::from_env();
    let reg = match Registry::open_default() {
        Ok(r) => r,
        Err(e) => {
            eprintln!("skipping: {e}");
            return;
        }
    };
    let mut rng = Rng::seed_from_u64(0);
    let (p, n) = (300, 400);
    let x = stiefel::random_point(p, n, &mut rng);
    let a = MatF::randn(n, n, &mut rng);
    let aat = matmul_at_b(&a, &a);

    let lossgrad = reg.get(&format!("pca_lossgrad_{p}x{n}")).unwrap();
    let step = reg.get(&format!("pogo_step_b1_{p}x{n}")).unwrap();
    let fused = reg.get(&format!("pca_pogo_fused_{p}x{n}")).unwrap();

    let mut out = Vec::new();

    // Path 1: two-phase (what Trainer does by default).
    out.push(bench("fig4 step: lossgrad + pogo_step (2 dispatches)", opts, || {
        let o = lossgrad.run(&[Arg::Mat(&x), Arg::Mat(&aat)]).unwrap();
        let g = pogo::runtime::literal_to_mat(&o[1], p, n).unwrap();
        let xs = [x.clone()];
        let o2 = step
            .run(&[Arg::Batch(&xs), Arg::Batch(std::slice::from_ref(&g)),
                   Arg::Scalar(1e-4)])
            .unwrap();
        pogo::bench::black_box(&o2);
    }));

    // Path 2: fused single dispatch.
    out.push(bench("fig4 step: fused grad+step+loss (1 dispatch)", opts, || {
        let o = fused
            .run(&[Arg::Mat(&x), Arg::Mat(&aat), Arg::Scalar(1e-4)])
            .unwrap();
        pogo::bench::black_box(&o);
    }));

    // Component: lossgrad alone (isolates the AAT upload + grad compute).
    out.push(bench("  component: pca_lossgrad alone", opts, || {
        let o = lossgrad.run(&[Arg::Mat(&x), Arg::Mat(&aat)]).unwrap();
        pogo::bench::black_box(&o);
    }));

    // Component: step alone.
    let g = MatF::randn(p, n, &mut rng).scale(1e-3);
    out.push(bench("  component: pogo_step alone", opts, || {
        let xs = [x.clone()];
        let o = step
            .run(&[Arg::Batch(&xs), Arg::Batch(std::slice::from_ref(&g)),
                   Arg::Scalar(1e-4)])
            .unwrap();
        pogo::bench::black_box(&o);
    }));

    print_table("Fig. 4 hot-path decomposition (300×400)", &out);

    // Marshalling overhead at the kernel-batch shape.
    let b = 8192;
    let kernels: Vec<MatF> = (0..b).map(|_| stiefel::random_point(3, 3, &mut rng)).collect();
    let mut marsh = Vec::new();
    marsh.push(bench_items("pack_batch 8192×3×3", opts, b as f64, || {
        pogo::bench::black_box(pogo::runtime::pack_batch(&kernels).unwrap());
    }));
    let vadam = reg.get("pogo_vadam_step_b8192_3x3").unwrap();
    let gs: Vec<MatF> = (0..b)
        .map(|_| {
            let g = MatF::randn(3, 3, &mut rng);
            let nn = g.norm();
            g.scale(0.3 / nn)
        })
        .collect();
    let m = vec![0.0f32; b * 9];
    let v = vec![0.0f32; b];
    marsh.push(bench_items("vadam fused step 8192×3×3 (full dispatch)", opts,
                           b as f64, || {
        let o = vadam
            .run(&[
                Arg::Batch(&kernels),
                Arg::Batch(&gs),
                Arg::F32(&m, vec![b, 3, 3]),
                Arg::F32(&v, vec![b, 1, 1]),
                Arg::Scalar(1.0),
                Arg::Scalar(0.5),
            ])
            .unwrap();
        pogo::bench::black_box(&o);
    }));
    print_table("many-matrix marshalling + dispatch (throughput = matrices/s)",
                &marsh);
}
