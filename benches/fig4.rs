//! Regenerates Fig. 4: online PCA (left) and orthogonal Procrustes (right)
//! optimality-gap + manifold-distance series for the full method lineup.
//! Series CSVs land in results/; the printed summary is the figure's
//! qualitative content (who converges first, who stays feasible).
//!
//! Budget control: POGO_BENCH_QUICK=1 shrinks shapes/steps.

use pogo::config::{ExperimentId, RunConfig};

fn main() {
    pogo::util::logging::init();
    let quick = std::env::var("POGO_BENCH_QUICK").is_ok();

    let mut pca = RunConfig::new(ExperimentId::Fig4Pca);
    pca.steps = if quick { 60 } else { 300 };
    pca.quick = quick;
    if let Err(e) = pogo::experiments::run(&pca) {
        eprintln!("fig4-pca failed: {e:#}");
        std::process::exit(1);
    }

    let mut proc = RunConfig::new(ExperimentId::Fig4Procrustes);
    proc.steps = if quick { 60 } else { 300 };
    proc.quick = quick;
    if let Err(e) = pogo::experiments::run(&proc) {
        eprintln!("fig4-procrustes failed: {e:#}");
        std::process::exit(1);
    }
}
