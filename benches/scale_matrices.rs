//! Regenerates the Fig. 1 scalability mechanism: per-matrix step cost vs
//! number of orthogonal 3×3 matrices (64 → 32768), batched-XLA POGO vs
//! host-loop POGO vs QR-retraction baselines, with the extrapolated wall
//! time of the paper's 218 624-kernel × 100-epoch workload.

use pogo::config::{ExperimentId, RunConfig};

fn main() {
    pogo::util::logging::init();
    let quick = std::env::var("POGO_BENCH_QUICK").is_ok();
    let mut cfg = RunConfig::new(ExperimentId::ScaleMatrices);
    cfg.steps = if quick { 3 } else { 10 };
    cfg.quick = quick;
    if let Err(e) = pogo::experiments::run(&cfg) {
        eprintln!("scale failed: {e:#}");
        std::process::exit(1);
    }
}
