//! Regenerates the appendix ablations:
//! - Fig. C.1 — tensor-precision study (f32 / f64 / bf16-truncated) on
//!   online PCA, including the RSDM-drift-is-numerical finding (§C.5);
//! - Fig. C.2/C.3 — λ policy (solve quartic vs λ = 1/2) × learning rate,
//!   including the divergence boundary of the fixed-λ approximation.

use pogo::config::{ExperimentId, RunConfig};

fn main() {
    pogo::util::logging::init();
    let quick = std::env::var("POGO_BENCH_QUICK").is_ok();

    let mut c1 = RunConfig::new(ExperimentId::FigC1Precision);
    c1.steps = if quick { 60 } else { 200 };
    c1.quick = quick;
    if let Err(e) = pogo::experiments::run(&c1) {
        eprintln!("figc1 failed: {e:#}");
        std::process::exit(1);
    }

    let mut c2 = RunConfig::new(ExperimentId::FigC2Lambda);
    c2.steps = if quick { 60 } else { 200 };
    c2.quick = quick;
    if let Err(e) = pogo::experiments::run(&c2) {
        eprintln!("figc2 failed: {e:#}");
        std::process::exit(1);
    }
}
