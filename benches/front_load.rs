//! Load generator for the federated front door: two in-process
//! `pogo serve` backends behind one `pogo front`, hammered by 1/4/16
//! concurrent v2 clients submitting B = 1024 POGO jobs and streaming to
//! `done`. Every level is measured twice — through the front and
//! directly against a single backend — so `BENCH_front.json` quantifies
//! exactly what the extra hop (routing, placement table, SSE relay)
//! costs in jobs/s and p50/p95 latency.
//!
//! Redirect: `POGO_BENCH_JSON_FRONT`; `POGO_BENCH_QUICK=1` shrinks
//! budgets for CI's `front-smoke` job, which gates on the file being
//! well-formed.

use pogo::bench::FrontLoadRow;
use pogo::coordinator::OptimizerSpec;
use pogo::federate::{Front, FrontAdmission, FrontConfig};
use pogo::optim::{Engine, Method};
use pogo::serve::{JobSpec, ProblemKind, ServeClient, ServeConfig, Server};
use pogo::util::Stopwatch;
use std::sync::Mutex;
use std::time::Duration;

fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let idx = ((sorted.len() as f64 - 1.0) * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

fn job_spec(client: usize, j: usize, steps: usize, tag: &str) -> JobSpec {
    let mut spec = JobSpec::new(ProblemKind::Quartic, 1024, 3, 3);
    spec.name = format!("front-load-{tag}-c{client}-j{j}");
    spec.steps = steps;
    spec.seed = (client as u64) * 2003 + j as u64;
    spec.optimizer = OptimizerSpec::new(Method::Pogo, 0.05).with_engine(Engine::BatchedHost);
    spec
}

/// One concurrency level against `addr` (a front or a backend — both
/// speak the same v2 surface). Returns (wall_s, sorted latency ms).
fn run_level(
    addr: &str,
    clients: usize,
    jobs_per_client: usize,
    steps: usize,
    tag: &str,
) -> (f64, Vec<f64>) {
    let latencies: Mutex<Vec<f64>> = Mutex::new(Vec::new());
    let wall = Stopwatch::start();
    std::thread::scope(|scope| {
        for c in 0..clients {
            let addr = addr.to_string();
            let latencies = &latencies;
            scope.spawn(move || {
                let client = ServeClient::new(addr);
                for j in 0..jobs_per_client {
                    let spec = job_spec(c, j, steps, tag);
                    let t = Stopwatch::start();
                    let id = client.submit_v2(&spec).expect("submit");
                    client
                        .wait_result_v2(id, Duration::from_secs(600))
                        .expect("job should reach done");
                    latencies.lock().unwrap().push(t.seconds() * 1e3);
                }
            });
        }
    });
    let wall_s = wall.seconds();
    let mut lat = latencies.into_inner().unwrap();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (wall_s, lat)
}

fn main() {
    pogo::util::logging::init();
    let quick = std::env::var("POGO_BENCH_QUICK").is_ok();
    let steps = if quick { 5 } else { 50 };
    let jobs_per_client = if quick { 2 } else { 4 };
    let workers = pogo::util::pool::num_threads().clamp(2, 4);

    let b1 = Server::start(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        workers,
        capacity: 1024,
        state_dir: None,
    })
    .expect("backend 1");
    let b2 = Server::start(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        workers,
        capacity: 1024,
        state_dir: None,
    })
    .expect("backend 2");
    // The direct baseline runs against its own, non-federated backend so
    // neither path's queue depth pollutes the other's numbers.
    let direct = Server::start(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        workers,
        capacity: 1024,
        state_dir: None,
    })
    .expect("direct baseline backend");
    let front = Front::start(FrontConfig {
        addr: "127.0.0.1:0".to_string(),
        backends: vec![b1.addr().to_string(), b2.addr().to_string()],
        probe_interval: Duration::from_millis(500),
        fail_after: 2,
        admission: FrontAdmission::default(),
        state_dir: None,
    })
    .expect("front over two backends");
    let front_addr = front.addr().to_string();
    let direct_addr = direct.addr().to_string();
    println!(
        "front_load: front on {front_addr} over 2 backends ({workers} workers each), \
         B=1024 POGO[batched] x {steps} steps"
    );

    let mut rows: Vec<FrontLoadRow> = Vec::new();
    for &clients in &[1usize, 4, 16] {
        let (front_wall, front_lat) =
            run_level(&front_addr, clients, jobs_per_client, steps, "front");
        let (direct_wall, direct_lat) =
            run_level(&direct_addr, clients, jobs_per_client, steps, "direct");
        let jobs = clients * jobs_per_client;
        let row = FrontLoadRow {
            clients,
            jobs,
            front_jobs_per_s: jobs as f64 / front_wall,
            front_p50_ms: percentile(&front_lat, 0.50),
            front_p95_ms: percentile(&front_lat, 0.95),
            direct_jobs_per_s: jobs as f64 / direct_wall,
            direct_p50_ms: percentile(&direct_lat, 0.50),
            direct_p95_ms: percentile(&direct_lat, 0.95),
        };
        println!(
            "  {:>2} client(s): {:>4} jobs  front {:7.2} jobs/s (p50 {:6.1} / p95 {:6.1} ms)  \
             direct {:7.2} jobs/s (p50 {:6.1} / p95 {:6.1} ms)",
            row.clients,
            row.jobs,
            row.front_jobs_per_s,
            row.front_p50_ms,
            row.front_p95_ms,
            row.direct_jobs_per_s,
            row.direct_p50_ms,
            row.direct_p95_ms
        );
        rows.push(row);
    }

    let default_json = pogo::repo_root().join("BENCH_front.json");
    match pogo::bench::write_front_json(&default_json, &rows) {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("failed to write BENCH_front.json: {e}"),
    }
    front.shutdown();
    b1.shutdown();
    b2.shutdown();
    direct.shutdown();
}
