//! Artifact I/O throughput: seal / encode / decode / verify / store-insert
//! over realistic payload sizes, through the same `pogo-artifact-v1` code
//! paths `pogo compile` and the serve daemon's `/v2/artifacts` upload use.
//!
//! Emits `BENCH_artifact.json` — per-operation mean milliseconds and MiB/s
//! (redirect: `POGO_BENCH_JSON_ARTIFACT`; `POGO_BENCH_QUICK=1` shrinks the
//! payload set and budgets for CI's `serve-smoke` job, which gates on the
//! file being well-formed).

use pogo::artifact::{Artifact, ArtifactStore, Provenance};
use pogo::bench::{bench, black_box, print_table, ArtifactIoRow, BenchOpts};
use pogo::linalg::Mat;
use pogo::rng::Rng;
use pogo::serve::{InlineMat, InlineProblem, JobDomain};

/// A batch of n×n PCA matrices totalling `batch * n^2 * 4` payload bytes.
fn pca_problem(batch: usize, n: usize, seed: u64) -> InlineProblem {
    let mut rng = Rng::seed_from_u64(seed);
    let c = (0..batch)
        .map(|_| InlineMat::from_mat(&Mat::<f32>::randn(n, n, &mut rng)))
        .collect();
    InlineProblem::Pca { c }
}

fn main() {
    pogo::util::logging::init();
    let opts = BenchOpts::from_env();
    let quick = std::env::var("POGO_BENCH_QUICK").is_ok();
    // (batch, n): payload = batch * n^2 * 4 bytes of f32 words.
    let shapes: &[(usize, usize)] =
        if quick { &[(16, 128)] } else { &[(16, 128), (128, 128), (64, 256)] };

    let store_dir =
        std::env::temp_dir().join(format!("pogo_bench_artifact_{}", std::process::id()));
    let store = ArtifactStore::open(&store_dir, u64::MAX).expect("opening bench artifact store");

    let mut stats = Vec::new();
    let mut rows: Vec<ArtifactIoRow> = Vec::new();
    for &(batch, n) in shapes {
        let problem = pca_problem(batch, n, 42);
        let payload_mb = problem.payload_bytes() as f64 / (1 << 20) as f64;
        let sealed =
            Artifact::seal(&problem, JobDomain::Real, batch, 2, n, Provenance::new(42))
                .expect("sealing bench artifact");
        let encoded = sealed.encode();
        store.insert(&sealed).expect("priming store insert");

        let mut row = |op: &str, s: &pogo::bench::Stats| {
            rows.push(ArtifactIoRow {
                op: op.to_string(),
                payload_mb,
                ms: s.mean * 1e3,
                mb_per_s: payload_mb / s.mean,
            });
        };

        let tag = format!("B={batch} n={n} ({payload_mb:.2} MiB)");
        let s = bench(&format!("seal {tag}"), opts, || {
            black_box(
                Artifact::seal(&problem, JobDomain::Real, batch, 2, n, Provenance::new(42))
                    .unwrap(),
            );
        });
        row("seal", &s);
        stats.push(s);

        let s = bench(&format!("encode {tag}"), opts, || {
            black_box(sealed.encode());
        });
        row("encode", &s);
        stats.push(s);

        let s = bench(&format!("decode {tag}"), opts, || {
            black_box(Artifact::decode(&encoded).unwrap());
        });
        row("decode", &s);
        stats.push(s);

        let s = bench(&format!("verify {tag}"), opts, || {
            sealed.verify().unwrap();
        });
        row("verify", &s);
        stats.push(s);

        // Content-addressed re-insert: the store's dedupe-hit path (hash +
        // index lookup), i.e. what a second identical upload costs.
        let s = bench(&format!("store {tag}"), opts, || {
            black_box(store.insert(&sealed).unwrap());
        });
        row("store", &s);
        stats.push(s);
    }
    print_table("artifact I/O (pogo-artifact-v1 seal/encode/decode/verify/store)", &stats);

    let default_json = pogo::repo_root().join("BENCH_artifact.json");
    match pogo::bench::write_artifact_json(&default_json, &rows) {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("failed to write BENCH_artifact.json: {e}"),
    }
    let _ = std::fs::remove_dir_all(&store_dir);
}
