//! Flight-recorder overhead harness: fused POGO steps with `POGO_OBS`
//! off vs on, pinning the "<3% regression" contract from the issue.
//!
//! The enabled path adds, per *batched* step (never per matrix): two
//! `Instant::now` reads and one wait-free histogram record through a
//! cached `&'static Hist` handle — plus, when the pool engages, the
//! dispatch-wait/run clock pairs in `util::pool`. Both regimes are
//! measured:
//!
//! 1. **Serial** — small shapes below every parallel threshold, where a
//!    step is microseconds and fixed overhead is proportionally largest.
//! 2. **Pool-engaged** — the paper's B≫1 regime, where dispatch timing
//!    joins in but amortizes over much more work.
//!
//! Writes `BENCH_obs.json`; CI runs this quick and reads
//! `overhead_pct` per cell (gate lives in the workflow, not here, so a
//! noisy laptop run prints rather than fails).

use pogo::bench::{bench_items, print_table, BenchOpts, Stats};
use pogo::linalg::{BatchMat, Mat, Scalar};
use pogo::manifold::stiefel;
use pogo::optim::base::BaseOptKind;
use pogo::optim::batched::BatchedHost;
use pogo::optim::pogo::LambdaPolicy;
use pogo::optim::Orthoptimizer;
use pogo::rng::Rng;
use pogo::util::json::Json;
use pogo::util::pool::{self, PoolMode};

fn make_packed<S: Scalar>(
    b: usize,
    p: usize,
    n: usize,
    rng: &mut Rng,
) -> (BatchMat<S>, BatchMat<S>) {
    let xs: Vec<Mat<S>> = (0..b).map(|_| stiefel::random_point_t::<S>(p, n, rng)).collect();
    let gs: Vec<Mat<S>> = (0..b)
        .map(|_| {
            let g = Mat::<S>::randn(p, n, rng);
            let nn = g.norm().to_f64().max(1e-6);
            g.scale(S::from_f64(0.3 / nn))
        })
        .collect();
    (BatchMat::from_mats(&xs), BatchMat::from_mats(&gs))
}

/// Mean seconds per `step_batch` at one (shape, batch) cell under the
/// current obs switch. A fresh host per measurement keeps the cached
/// histogram handle's one-time registration inside the warmup.
fn measure<S: Scalar>(
    opts: BenchOpts,
    tag: &str,
    b: usize,
    p: usize,
    n: usize,
    rng: &mut Rng,
) -> Stats {
    let mut opt: BatchedHost<S> = BatchedHost::pogo(0.05, LambdaPolicy::Half, BaseOptKind::Sgd);
    let (mut xb, gb) = make_packed::<S>(b, p, n, rng);
    opt.step_batch(&mut xb, &gb).unwrap(); // warm-up (pool, scratch, handle)
    bench_items(&format!("pogo-f32 {p}x{n} B={b} obs={tag}"), opts, b as f64, || {
        opt.step_batch(&mut xb, &gb).unwrap();
    })
}

fn main() {
    pogo::util::logging::init();
    let opts = BenchOpts::from_env();
    let mut rng = Rng::seed_from_u64(0);

    pool::set_pool_mode(Some(PoolMode::Resident));
    pool::warm_pool();

    // (p, n, B): serial tiny, serial small, pool-engaged.
    let cells: &[(usize, usize, usize)] = &[(3, 3, 64), (16, 16, 256), (16, 16, 4096)];

    let mut stats: Vec<Stats> = Vec::new();
    let mut rows: Vec<Json> = Vec::new();
    for &(p, n, b) in cells {
        // Off first, on second, interleaved per cell so slow thermal /
        // frequency drift hits both sides of each ratio about equally.
        pogo::obs::set_enabled(Some(false));
        let off = measure::<f32>(opts, "off", b, p, n, &mut rng);
        pogo::obs::set_enabled(Some(true));
        let on = measure::<f32>(opts, "on", b, p, n, &mut rng);
        pogo::obs::set_enabled(None);

        let overhead_pct = (on.mean / off.mean - 1.0) * 100.0;
        println!("  {p}x{n} B={b}: obs overhead {overhead_pct:+.2}% (contract: < 3%)");
        rows.push(Json::obj(vec![
            ("p", Json::num(p as f64)),
            ("n", Json::num(n as f64)),
            ("batch", Json::num(b as f64)),
            ("us_per_step_off", Json::num(off.mean * 1e6)),
            ("us_per_step_on", Json::num(on.mean * 1e6)),
            ("overhead_pct", Json::num(overhead_pct)),
        ]));
        stats.push(off);
        stats.push(on);
    }
    pool::set_pool_mode(None);

    print_table("fused POGO steps, flight recorder off vs on", &stats);

    let out = Json::obj(vec![("cells", Json::Arr(rows))]);
    let path = pogo::repo_root().join("BENCH_obs.json");
    match std::fs::write(&path, out.to_string_pretty() + "\n") {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("failed to write BENCH_obs.json: {e}"),
    }
}
