//! Fig. 8 benchmark: the unitary batched-vs-loop engine race at the Born
//! core shape, emitting machine-readable `BENCH_born.json` through the
//! shared bench helper (redirect with `POGO_BENCH_JSON_BORN`). CI's
//! `bench-smoke` job runs this with `POGO_BENCH_QUICK=1`, uploads the
//! JSON, and fails if the batched unitary engine drops below 1× the
//! per-matrix loop at B = 1024.
//!
//! The full Fig. 8 training experiment (bits-per-dim + manifold distance,
//! §C.4 protocol) needs the AOT `born_lossgrad` artifacts; opt in with
//! `POGO_BORN_E2E=1` after `make artifacts`.

use pogo::config::{ExperimentId, RunConfig};
use pogo::experiments::born;

fn main() {
    pogo::util::logging::init();
    let quick = std::env::var("POGO_BENCH_QUICK").is_ok();

    // Quick profile covers B ∈ {64, 256}; the full run adds B = 1024 —
    // but CI gates on 1024, so force the full batch list there too.
    let (rows, speedups) = match born::race_unitary_engines(false, 0) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("unitary engine race failed: {e:#}");
            std::process::exit(1);
        }
    };
    for &(b, s) in &speedups {
        println!("unitary batched-vs-loop speedup at B={b}: {s:.2}x");
    }
    let default_json = pogo::repo_root().join("BENCH_born.json");
    match pogo::bench::write_born_json(&default_json, &rows, &speedups) {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => {
            eprintln!("failed to write BENCH_born.json: {e}");
            std::process::exit(1);
        }
    }

    // Optional: the registry-backed training experiment (Fig. 8 proper).
    if std::env::var("POGO_BORN_E2E").is_ok() {
        let mut cfg = RunConfig::new(ExperimentId::Fig8Born);
        cfg.steps = if quick { 30 } else { 200 };
        cfg.quick = quick;
        if let Err(e) = pogo::experiments::run(&cfg) {
            eprintln!("fig8 failed: {e:#}");
            std::process::exit(1);
        }
    }
}
