//! Regenerates Fig. 8: squared unitary circuit (Born MPS) bits-per-dim +
//! manifold distance on the complex Stiefel manifold, §C.4 protocol
//! (plateau-halving lr, early stopping).

use pogo::config::{ExperimentId, RunConfig};

fn main() {
    pogo::util::logging::init();
    let quick = std::env::var("POGO_BENCH_QUICK").is_ok();
    let mut cfg = RunConfig::new(ExperimentId::Fig8Born);
    cfg.steps = if quick { 30 } else { 200 };
    if let Err(e) = pogo::experiments::run(&cfg) {
        eprintln!("fig8 failed: {e:#}");
        std::process::exit(1);
    }
}
