//! Pool-dispatch latency harness: the resident worker pool vs the old
//! spawn-per-call path, measured two ways.
//!
//! 1. **Raw dispatch** — `parallel_shards` over empty shards: wake the
//!    workers, claim the shards, hit the completion barrier. This is pure
//!    orchestration cost (nanoseconds), the thing the resident pool
//!    exists to shrink: a parked-thread wake is a futex, a spawn is a
//!    clone(2) + stack + scheduler round-trip.
//! 2. **End-to-end** — whole fused POGO `step_batch` calls under each
//!    backend (microseconds/step), so the dispatch win is shown in terms
//!    of what a training loop actually feels at small-per-matrix-work
//!    regimes (the paper's B≫1 tiny-matrix sweet spot).
//!
//! Both backends run the identical sharding geometry and kernel loops —
//! `tests/pool_parity.rs` pins the trajectories bit-identical — so this
//! bench measures the only thing that differs: thread lifecycle overhead.
//!
//! Writes `BENCH_pool.json` (redirect: `POGO_BENCH_JSON_POOL`); CI's
//! `bench-smoke` job runs this with `POGO_BENCH_QUICK=1` and fails if
//! `speedup_resident_vs_spawn` drops below 1 at f32 (16,16), B = 4096.

use pogo::bench::{bench, bench_items, print_table, BenchOpts, DispatchRecord, PoolRecord, Stats};
use pogo::linalg::{BatchMat, Mat, Scalar};
use pogo::manifold::stiefel;
use pogo::optim::base::BaseOptKind;
use pogo::optim::batched::BatchedHost;
use pogo::optim::pogo::LambdaPolicy;
use pogo::optim::Orthoptimizer;
use pogo::rng::Rng;
use pogo::util::pool::{self, PoolMode};
use std::sync::atomic::{AtomicU64, Ordering};

/// One packed problem instance: B row-orthogonal iterates + scaled grads.
fn make_packed<S: Scalar>(
    b: usize,
    p: usize,
    n: usize,
    rng: &mut Rng,
) -> (BatchMat<S>, BatchMat<S>) {
    let xs: Vec<Mat<S>> = (0..b).map(|_| stiefel::random_point_t::<S>(p, n, rng)).collect();
    let gs: Vec<Mat<S>> = (0..b)
        .map(|_| {
            let g = Mat::<S>::randn(p, n, rng);
            let nn = g.norm().to_f64().max(1e-6);
            g.scale(S::from_f64(0.3 / nn))
        })
        .collect();
    (BatchMat::from_mats(&xs), BatchMat::from_mats(&gs))
}

/// Raw dispatch cost at one shard count under the active pool mode. The
/// shard body is a relaxed atomic add — cheap, but observable, so the
/// dispatch cannot be optimized away and every shard is provably run.
fn measure_dispatch(opts: BenchOpts, mode: PoolMode, shards: usize) -> (Stats, DispatchRecord) {
    let sink = AtomicU64::new(0);
    let s = bench(&format!("dispatch[{}] shards={shards}", mode.name()), opts, || {
        pool::parallel_shards(shards, |i| {
            sink.fetch_add(i as u64 + 1, Ordering::Relaxed);
        });
    });
    assert!(sink.load(Ordering::Relaxed) > 0);
    let rec = DispatchRecord {
        pool: mode.name().to_string(),
        shards,
        ns_per_dispatch: s.mean * 1e9,
    };
    (s, rec)
}

/// Whole fused POGO steps under the active pool mode.
fn measure_steps<S: Scalar>(
    opts: BenchOpts,
    mode: PoolMode,
    b: usize,
    p: usize,
    n: usize,
    rng: &mut Rng,
) -> (Stats, PoolRecord) {
    let mut opt: BatchedHost<S> =
        BatchedHost::pogo(0.05, LambdaPolicy::Half, BaseOptKind::Sgd);
    let (mut xb, gb) = make_packed::<S>(b, p, n, rng);
    opt.step_batch(&mut xb, &gb).unwrap(); // warm-up (pool, scratch, buffers)
    let s = bench_items(
        &format!("pogo-f32[{}] B={b} {p}x{n}", mode.name()),
        opts,
        b as f64,
        || {
            opt.step_batch(&mut xb, &gb).unwrap();
        },
    );
    let rec = PoolRecord {
        pool: mode.name().to_string(),
        label: "pogo-f32".to_string(),
        p,
        n,
        batch: b,
        us_per_step: s.mean * 1e6,
    };
    (s, rec)
}

fn main() {
    pogo::util::logging::init();
    let opts = BenchOpts::from_env();
    let quick = std::env::var("POGO_BENCH_QUICK").is_ok();
    let mut rng = Rng::seed_from_u64(0);

    println!("threads: {}", pool::num_threads());

    let mut disp_stats: Vec<Stats> = Vec::new();
    let mut step_stats: Vec<Stats> = Vec::new();
    let mut dispatch: Vec<DispatchRecord> = Vec::new();
    let mut records: Vec<PoolRecord> = Vec::new();
    let mut speedups: Vec<(String, f64)> = Vec::new();

    // B = 4096 must stay in the quick profile: CI's jq gate reads the
    // "16x16@4096" speedup from the quick run.
    let batches: &[usize] = if quick { &[1024, 4096] } else { &[1024, 4096, 32768] };
    let shapes: &[(usize, usize)] = &[(3, 3), (16, 16)];

    // Spawn first, resident second: the resident numbers then include any
    // first-dispatch pool growth only in their warmup, never in samples.
    for mode in [PoolMode::Spawn, PoolMode::Resident] {
        pool::set_pool_mode(Some(mode));
        if mode == PoolMode::Resident {
            pool::warm_pool();
        }
        for &shards in &[1usize, 4, 16] {
            let (s, rec) = measure_dispatch(opts, mode, shards);
            disp_stats.push(s);
            dispatch.push(rec);
        }
        for &(p, n) in shapes {
            for &b in batches {
                let (s, rec) = measure_steps::<f32>(opts, mode, b, p, n, &mut rng);
                step_stats.push(s);
                records.push(rec);
            }
        }
    }
    // Restore the env-driven default for anything running after us.
    pool::set_pool_mode(None);

    // speedup = spawn / resident per (shape, B) cell: >1 ⇒ resident wins.
    for r in records.iter().filter(|r| r.pool == "resident") {
        if let Some(s) = records.iter().find(|s| {
            s.pool == "spawn" && s.p == r.p && s.n == r.n && s.batch == r.batch
        }) {
            if r.us_per_step > 0.0 {
                speedups.push((
                    format!("{}x{}@{}", r.p, r.n, r.batch),
                    s.us_per_step / r.us_per_step,
                ));
            }
        }
    }

    print_table("pool dispatch latency (resident vs spawn)", &disp_stats);
    print_table("fused POGO steps under each backend (throughput = matrices/s)", &step_stats);
    for (k, s) in &speedups {
        println!("  resident-vs-spawn speedup at {k}: {s:.2}x");
    }
    let stats = pool::pool_stats();
    println!(
        "pool: mode={} workers={} dispatches={}",
        stats.mode, stats.resident_workers, stats.dispatches
    );

    let default_json = pogo::repo_root().join("BENCH_pool.json");
    match pogo::bench::write_pool_json(&default_json, &dispatch, &records, &speedups) {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("failed to write BENCH_pool.json: {e}"),
    }
}
