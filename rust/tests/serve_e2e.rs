//! End-to-end tests for `pogo serve`: a real daemon on an ephemeral
//! loopback port, driven over TCP by concurrent clients.
//!
//! The headline test pins the serve determinism contract: 8+ concurrent
//! submissions (mixed `rust`/`batched-host` engines, real and complex
//! domains) all reach `done`, and each job's final loss equals a direct
//! `run_job` execution of the same spec+seed **bit-for-bit** — the
//! daemon adds scheduling, not numerics.

use pogo::coordinator::OptimizerSpec;
use pogo::optim::{Engine, Method};
use pogo::serve::{
    run_job, JobDomain, JobOutcome, JobSpec, ProblemKind, RunCtl, ServeClient, ServeConfig,
    Server,
};
use std::time::Duration;

const WAIT: Duration = Duration::from_secs(120);

fn start_server(workers: usize, capacity: usize) -> (Server, ServeClient) {
    let server = Server::start(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        workers,
        capacity,
        state_dir: None,
    })
    .expect("server should bind an ephemeral port");
    let client = ServeClient::new(server.addr().to_string());
    (server, client)
}

fn spec(problem: ProblemKind, engine: Engine, domain: JobDomain, seed: u64) -> JobSpec {
    let mut s = JobSpec::new(problem, 4, 3, 6);
    s.name = format!("{}-{}-{}", problem.name(), engine.name(), domain.name());
    s.domain = domain;
    s.steps = 40;
    s.seed = seed;
    s.optimizer = OptimizerSpec::new(Method::Pogo, 0.05).with_engine(engine);
    s
}

/// The acceptance-criteria test: concurrent mixed-engine submissions,
/// bit-for-bit parity with direct OptimSession-backed runs.
#[test]
fn concurrent_jobs_match_direct_runs_bit_for_bit() {
    let (server, client) = start_server(3, 64);

    let mut specs = vec![
        spec(ProblemKind::Procrustes, Engine::Rust, JobDomain::Real, 1),
        spec(ProblemKind::Procrustes, Engine::BatchedHost, JobDomain::Real, 2),
        spec(ProblemKind::Pca, Engine::Rust, JobDomain::Real, 3),
        spec(ProblemKind::Pca, Engine::BatchedHost, JobDomain::Real, 4),
        spec(ProblemKind::Quartic, Engine::BatchedHost, JobDomain::Real, 5),
        spec(ProblemKind::Replay, Engine::Rust, JobDomain::Real, 6),
        // Complex Stiefel on both engines.
        spec(ProblemKind::Quartic, Engine::Rust, JobDomain::Complex, 7),
        spec(ProblemKind::Replay, Engine::BatchedHost, JobDomain::Complex, 8),
    ];
    // A Landing job for method variety (small lr keeps it well within
    // the 1e-3 feasibility gate).
    let mut landing = spec(ProblemKind::Pca, Engine::BatchedHost, JobDomain::Real, 9);
    landing.optimizer = OptimizerSpec::new(Method::Landing, 0.02).with_engine(Engine::BatchedHost);
    specs.push(landing);
    assert!(specs.len() >= 8, "acceptance criteria: >= 8 concurrent submissions");

    // Submit all jobs concurrently, one client thread each.
    let results: Vec<(JobSpec, f64, f64)> = std::thread::scope(|scope| {
        let handles: Vec<_> = specs
            .iter()
            .map(|s| {
                let client = client.clone();
                let s = s.clone();
                scope.spawn(move || {
                    let id = client.submit(&s).expect("submit");
                    let result = client
                        .wait_result(id, WAIT)
                        .unwrap_or_else(|e| panic!("{}: {e:#}", s.name));
                    let loss = result.get("final_loss").as_f64().expect("final_loss");
                    let ortho = result.get("ortho_error").as_f64().expect("ortho_error");
                    assert_eq!(
                        result.get("steps_done").as_usize(),
                        Some(s.steps),
                        "{}",
                        s.name
                    );
                    (s, loss, ortho)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("client thread")).collect()
    });

    // Every job done, feasible, and bit-identical to a direct run.
    for (s, loss, ortho) in &results {
        assert!(*ortho <= 1e-3, "{}: ortho error {ortho}", s.name);
        let JobOutcome::Done(direct) = run_job(s, &RunCtl::default()).expect("direct run")
        else {
            panic!("{}: direct run not done", s.name)
        };
        assert_eq!(
            loss.to_bits(),
            direct.final_loss.to_bits(),
            "{}: served {} vs direct {} — not bit-identical",
            s.name,
            loss,
            direct.final_loss
        );
        assert_eq!(ortho.to_bits(), direct.ortho_error.to_bits(), "{}", s.name);
    }

    // The daemon's counters saw all of it.
    let metrics = client.metrics().expect("metrics");
    let completed = metrics
        .lines()
        .find(|l| l.starts_with("pogo_serve_jobs_completed_total"))
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|v| v.parse::<f64>().ok())
        .expect("completed counter");
    assert!(completed >= specs.len() as f64, "completed {completed}");
    server.shutdown();
}

#[test]
fn cancel_and_queue_full_over_http() {
    // One worker and a backlog of one: the long job occupies the worker,
    // the next job queues, the third submission is refused with 429.
    let (server, client) = start_server(1, 1);
    let mut long = spec(ProblemKind::Replay, Engine::Rust, JobDomain::Real, 10);
    long.steps = 500_000;
    let long_id = client.submit(&long).expect("submit long");
    // Wait until the worker claims it.
    let deadline = std::time::Instant::now() + WAIT;
    loop {
        let st = client.status(long_id).expect("status");
        if st.get("state").as_str() == Some("running") {
            break;
        }
        assert!(std::time::Instant::now() < deadline, "long job never started");
        std::thread::sleep(Duration::from_millis(5));
    }
    let queued_id = client.submit(&spec(ProblemKind::Quartic, Engine::Rust, JobDomain::Real, 11))
        .expect("submit queued");
    let err = client
        .submit(&spec(ProblemKind::Quartic, Engine::Rust, JobDomain::Real, 12))
        .expect_err("third submission should be refused");
    assert!(format!("{err:#}").contains("429"), "{err:#}");

    // Cancel the queued job (immediate) and the running one (drains at a
    // step boundary); both end as cancelled.
    let j = client.cancel(queued_id).expect("cancel queued");
    assert_eq!(j.get("state").as_str(), Some("cancelled"));
    client.cancel(long_id).expect("cancel running");
    let st = client.wait_terminal(long_id, WAIT).expect("terminal");
    assert_eq!(st.get("state").as_str(), Some("cancelled"));
    // A cancelled job still reports its partial trajectory.
    let r = client.result(long_id).expect("partial result");
    assert!(r.get("steps_done").as_usize().unwrap() < long.steps);
    server.shutdown();
}

#[test]
fn failed_job_reports_cause_and_daemon_survives() {
    let (server, client) = start_server(1, 8);
    // XLA engine without a registry fails at session build.
    let mut bad = spec(ProblemKind::Quartic, Engine::Rust, JobDomain::Real, 13);
    bad.optimizer = bad.optimizer.with_engine(Engine::Xla);
    let id = client.submit(&bad).expect("submit");
    let st = client.wait_terminal(id, WAIT).expect("terminal");
    assert_eq!(st.get("state").as_str(), Some("failed"));
    assert!(st.get("error").as_str().unwrap_or("").contains("registry"));
    // GET result of a failed job is a 409 naming the failure.
    let err = client.result(id).expect_err("no result for failed job");
    assert!(format!("{err:#}").contains("409"), "{err:#}");
    // Daemon is still healthy and takes more work.
    let ok = client
        .submit(&spec(ProblemKind::Quartic, Engine::BatchedHost, JobDomain::Real, 14))
        .expect("submit after failure");
    let r = client.wait_result(ok, WAIT).expect("job after failure");
    assert!(r.get("ortho_error").as_f64().unwrap() <= 1e-3);
    server.shutdown();
}

#[test]
fn restart_recovers_and_resumes_checkpointed_jobs() {
    let dir = std::env::temp_dir().join(format!("pogo_serve_e2e_state_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();

    let mut job = spec(ProblemKind::Procrustes, Engine::Rust, JobDomain::Real, 21);
    job.steps = 2000;
    job.checkpoint_every = 100;

    // Simulate a daemon that died mid-job: run the first ~550 steps
    // directly (same execution path the worker uses), leaving a
    // checkpoint behind, and persist the job's state file as `running` —
    // exactly what a crashed `pogo serve --state-dir` leaves on disk.
    let crashed_id: u64 = 77;
    let ckpt = dir.join(format!("job-{crashed_id}.ckpt"));
    {
        use std::sync::atomic::{AtomicBool, Ordering};
        let cancel = AtomicBool::new(false);
        let on_step = |step: usize, _loss: f64| {
            if step >= 550 {
                cancel.store(true, Ordering::Relaxed);
            }
        };
        let ctl = RunCtl {
            cancel: Some(&cancel),
            on_step: Some(&on_step),
            checkpoint_path: Some(ckpt.clone()),
        };
        let JobOutcome::Cancelled(_) = run_job(&job, &ctl).expect("interrupted run") else {
            panic!("expected the simulated crash to stop mid-run")
        };
        assert!(ckpt.exists(), "checkpoint should have landed before the crash");
    }
    let state_file = pogo::util::json::Json::obj(vec![
        ("id", pogo::util::json::Json::num(crashed_id as f64)),
        ("state", pogo::util::json::Json::str("running")),
        ("spec", job.to_json()),
    ]);
    std::fs::write(dir.join(format!("job-{crashed_id}.json")), state_file.to_string_pretty())
        .unwrap();

    // A restarted daemon re-lists the unfinished job, resumes it from
    // the checkpoint, and completes it.
    let server = Server::start(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        workers: 2,
        capacity: 8,
        state_dir: Some(dir.clone()),
    })
    .expect("restarted daemon");
    let client = ServeClient::new(server.addr().to_string());
    let result = client.wait_result(crashed_id, WAIT).expect("recovered job");
    assert_eq!(result.get("steps_done").as_usize(), Some(job.steps));
    assert!(result.get("ortho_error").as_f64().unwrap() <= 1e-3);
    assert!(
        result.get("checkpoint").as_str().unwrap_or("").contains("job-77.ckpt"),
        "result should point at the checkpoint"
    );
    // The resumed trajectory equals the uninterrupted one bit-for-bit
    // (POGO/sgd is stateless, and the checkpoint restores params + step).
    let direct_ckpt = dir.join("direct.ckpt");
    let direct_ctl = RunCtl { checkpoint_path: Some(direct_ckpt), ..Default::default() };
    let JobOutcome::Done(direct) = run_job(&job, &direct_ctl).expect("direct") else {
        panic!()
    };
    assert_eq!(
        result.get("final_loss").as_f64().unwrap().to_bits(),
        direct.final_loss.to_bits(),
        "resumed job diverged from the uninterrupted trajectory"
    );

    // New submissions get fresh ids above the recovered one.
    let fresh = client
        .submit(&spec(ProblemKind::Quartic, Engine::BatchedHost, JobDomain::Real, 22))
        .expect("fresh submit");
    assert!(fresh > crashed_id);
    client.wait_result(fresh, WAIT).expect("fresh job");
    server.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}
