//! End-to-end tests for `pogo serve`: a real daemon on an ephemeral
//! loopback port, driven over TCP by concurrent clients.
//!
//! The headline test pins the serve determinism contract: 8+ concurrent
//! submissions (mixed `rust`/`batched-host` engines, real and complex
//! domains) all reach `done`, and each job's final loss equals a direct
//! `run_job` execution of the same spec+seed **bit-for-bit** — the
//! daemon adds scheduling, not numerics.

use pogo::coordinator::OptimizerSpec;
use pogo::optim::{Engine, Method};
use pogo::serve::{
    run_job, JobDomain, JobOutcome, JobSpec, ProblemKind, RunCtl, ServeClient, ServeConfig,
    Server,
};
use std::time::Duration;

const WAIT: Duration = Duration::from_secs(120);

fn start_server(workers: usize, capacity: usize) -> (Server, ServeClient) {
    let server = Server::start(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        workers,
        capacity,
        state_dir: None,
    })
    .expect("server should bind an ephemeral port");
    let client = ServeClient::new(server.addr().to_string());
    (server, client)
}

fn spec(problem: ProblemKind, engine: Engine, domain: JobDomain, seed: u64) -> JobSpec {
    let mut s = JobSpec::new(problem, 4, 3, 6);
    s.name = format!("{}-{}-{}", problem.name(), engine.name(), domain.name());
    s.domain = domain;
    s.steps = 40;
    s.seed = seed;
    s.optimizer = OptimizerSpec::new(Method::Pogo, 0.05).with_engine(engine);
    s
}

/// The acceptance-criteria test: concurrent mixed-engine submissions,
/// bit-for-bit parity with direct OptimSession-backed runs.
#[test]
fn concurrent_jobs_match_direct_runs_bit_for_bit() {
    let (server, client) = start_server(3, 64);

    let mut specs = vec![
        spec(ProblemKind::Procrustes, Engine::Rust, JobDomain::Real, 1),
        spec(ProblemKind::Procrustes, Engine::BatchedHost, JobDomain::Real, 2),
        spec(ProblemKind::Pca, Engine::Rust, JobDomain::Real, 3),
        spec(ProblemKind::Pca, Engine::BatchedHost, JobDomain::Real, 4),
        spec(ProblemKind::Quartic, Engine::BatchedHost, JobDomain::Real, 5),
        spec(ProblemKind::Replay, Engine::Rust, JobDomain::Real, 6),
        // Complex Stiefel on both engines.
        spec(ProblemKind::Quartic, Engine::Rust, JobDomain::Complex, 7),
        spec(ProblemKind::Replay, Engine::BatchedHost, JobDomain::Complex, 8),
    ];
    // A Landing job for method variety (small lr keeps it well within
    // the 1e-3 feasibility gate).
    let mut landing = spec(ProblemKind::Pca, Engine::BatchedHost, JobDomain::Real, 9);
    landing.optimizer = OptimizerSpec::new(Method::Landing, 0.02).with_engine(Engine::BatchedHost);
    specs.push(landing);
    assert!(specs.len() >= 8, "acceptance criteria: >= 8 concurrent submissions");

    // Submit all jobs concurrently, one client thread each.
    let results: Vec<(JobSpec, f64, f64)> = std::thread::scope(|scope| {
        let handles: Vec<_> = specs
            .iter()
            .map(|s| {
                let client = client.clone();
                let s = s.clone();
                scope.spawn(move || {
                    let id = client.submit(&s).expect("submit");
                    let result = client
                        .wait_result(id, WAIT)
                        .unwrap_or_else(|e| panic!("{}: {e:#}", s.name));
                    let loss = result.get("final_loss").as_f64().expect("final_loss");
                    let ortho = result.get("ortho_error").as_f64().expect("ortho_error");
                    assert_eq!(
                        result.get("steps_done").as_usize(),
                        Some(s.steps),
                        "{}",
                        s.name
                    );
                    (s, loss, ortho)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("client thread")).collect()
    });

    // Every job done, feasible, and bit-identical to a direct run.
    for (s, loss, ortho) in &results {
        assert!(*ortho <= 1e-3, "{}: ortho error {ortho}", s.name);
        let JobOutcome::Done(direct) = run_job(s, &RunCtl::default()).expect("direct run")
        else {
            panic!("{}: direct run not done", s.name)
        };
        assert_eq!(
            loss.to_bits(),
            direct.final_loss.to_bits(),
            "{}: served {} vs direct {} — not bit-identical",
            s.name,
            loss,
            direct.final_loss
        );
        assert_eq!(ortho.to_bits(), direct.ortho_error.to_bits(), "{}", s.name);
    }

    // The daemon's counters saw all of it.
    let metrics = client.metrics().expect("metrics");
    let completed = metrics
        .lines()
        .find(|l| l.starts_with("pogo_serve_jobs_completed_total"))
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|v| v.parse::<f64>().ok())
        .expect("completed counter");
    assert!(completed >= specs.len() as f64, "completed {completed}");
    server.shutdown();
}

#[test]
fn cancel_and_queue_full_over_http() {
    // One worker and a backlog of one: the long job occupies the worker,
    // the next job queues, the third submission is refused with 429.
    let (server, client) = start_server(1, 1);
    let mut long = spec(ProblemKind::Replay, Engine::Rust, JobDomain::Real, 10);
    long.steps = 500_000;
    let long_id = client.submit(&long).expect("submit long");
    // Wait until the worker claims it.
    let deadline = std::time::Instant::now() + WAIT;
    loop {
        let st = client.status(long_id).expect("status");
        if st.get("state").as_str() == Some("running") {
            break;
        }
        assert!(std::time::Instant::now() < deadline, "long job never started");
        std::thread::sleep(Duration::from_millis(5));
    }
    let queued_id = client.submit(&spec(ProblemKind::Quartic, Engine::Rust, JobDomain::Real, 11))
        .expect("submit queued");
    let err = client
        .submit(&spec(ProblemKind::Quartic, Engine::Rust, JobDomain::Real, 12))
        .expect_err("third submission should be refused");
    assert!(format!("{err:#}").contains("429"), "{err:#}");

    // Cancel the queued job (immediate) and the running one (drains at a
    // step boundary); both end as cancelled.
    let j = client.cancel(queued_id).expect("cancel queued");
    assert_eq!(j.get("state").as_str(), Some("cancelled"));
    client.cancel(long_id).expect("cancel running");
    let st = client.wait_terminal(long_id, WAIT).expect("terminal");
    assert_eq!(st.get("state").as_str(), Some("cancelled"));
    // A cancelled job still reports its partial trajectory.
    let r = client.result(long_id).expect("partial result");
    assert!(r.get("steps_done").as_usize().unwrap() < long.steps);
    server.shutdown();
}

#[test]
fn failed_job_reports_cause_and_daemon_survives() {
    let (server, client) = start_server(1, 8);
    // XLA engine without a registry fails at session build.
    let mut bad = spec(ProblemKind::Quartic, Engine::Rust, JobDomain::Real, 13);
    bad.optimizer = bad.optimizer.with_engine(Engine::Xla);
    let id = client.submit(&bad).expect("submit");
    let st = client.wait_terminal(id, WAIT).expect("terminal");
    assert_eq!(st.get("state").as_str(), Some("failed"));
    assert!(st.get("error").as_str().unwrap_or("").contains("registry"));
    // GET result of a failed job is a 409 naming the failure.
    let err = client.result(id).expect_err("no result for failed job");
    assert!(format!("{err:#}").contains("409"), "{err:#}");
    // Daemon is still healthy and takes more work.
    let ok = client
        .submit(&spec(ProblemKind::Quartic, Engine::BatchedHost, JobDomain::Real, 14))
        .expect("submit after failure");
    let r = client.wait_result(ok, WAIT).expect("job after failure");
    assert!(r.get("ortho_error").as_f64().unwrap() <= 1e-3);
    server.shutdown();
}

#[test]
fn restart_recovers_and_resumes_checkpointed_jobs() {
    let dir = std::env::temp_dir().join(format!("pogo_serve_e2e_state_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();

    let mut job = spec(ProblemKind::Procrustes, Engine::Rust, JobDomain::Real, 21);
    job.steps = 2000;
    job.checkpoint_every = 100;

    // Simulate a daemon that died mid-job: run the first ~550 steps
    // directly (same execution path the worker uses), leaving a
    // checkpoint behind, and persist the job's state file as `running` —
    // exactly what a crashed `pogo serve --state-dir` leaves on disk.
    let crashed_id: u64 = 77;
    let ckpt = dir.join(format!("job-{crashed_id}.ckpt"));
    {
        use std::sync::atomic::{AtomicBool, Ordering};
        let cancel = AtomicBool::new(false);
        let on_step = |step: usize, _loss: f64| {
            if step >= 550 {
                cancel.store(true, Ordering::Relaxed);
            }
        };
        let ctl = RunCtl {
            cancel: Some(&cancel),
            on_step: Some(&on_step),
            checkpoint_path: Some(ckpt.clone()),
            ..Default::default()
        };
        let JobOutcome::Cancelled(_) = run_job(&job, &ctl).expect("interrupted run") else {
            panic!("expected the simulated crash to stop mid-run")
        };
        assert!(ckpt.exists(), "checkpoint should have landed before the crash");
    }
    let state_file = pogo::util::json::Json::obj(vec![
        ("id", pogo::util::json::Json::num(crashed_id as f64)),
        ("state", pogo::util::json::Json::str("running")),
        ("spec", job.to_json()),
    ]);
    std::fs::write(dir.join(format!("job-{crashed_id}.json")), state_file.to_string_pretty())
        .unwrap();

    // A restarted daemon re-lists the unfinished job, resumes it from
    // the checkpoint, and completes it.
    let server = Server::start(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        workers: 2,
        capacity: 8,
        state_dir: Some(dir.clone()),
    })
    .expect("restarted daemon");
    let client = ServeClient::new(server.addr().to_string());
    let result = client.wait_result(crashed_id, WAIT).expect("recovered job");
    assert_eq!(result.get("steps_done").as_usize(), Some(job.steps));
    assert!(result.get("ortho_error").as_f64().unwrap() <= 1e-3);
    assert!(
        result.get("checkpoint").as_str().unwrap_or("").contains("job-77.ckpt"),
        "result should point at the checkpoint"
    );
    // The resumed trajectory equals the uninterrupted one bit-for-bit
    // (POGO/sgd is stateless, and the checkpoint restores params + step).
    let direct_ckpt = dir.join("direct.ckpt");
    let direct_ctl = RunCtl { checkpoint_path: Some(direct_ckpt), ..Default::default() };
    let JobOutcome::Done(direct) = run_job(&job, &direct_ctl).expect("direct") else {
        panic!()
    };
    assert_eq!(
        result.get("final_loss").as_f64().unwrap().to_bits(),
        direct.final_loss.to_bits(),
        "resumed job diverged from the uninterrupted trajectory"
    );

    // New submissions get fresh ids above the recovered one.
    let fresh = client
        .submit(&spec(ProblemKind::Quartic, Engine::BatchedHost, JobDomain::Real, 22))
        .expect("fresh submit");
    assert!(fresh > crashed_id);
    client.wait_result(fresh, WAIT).expect("fresh job");
    server.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------------
// v2: inline problem sources, SSE streaming, admission control.
// ---------------------------------------------------------------------------

/// An inline-source v2 job: client-supplied procrustes data, submitted
/// over `/v2/jobs`, followed live over SSE (monotone step events to the
/// terminal state), with the full-series + final-iterate result matching
/// a direct `run_job` of the same spec **bit-for-bit**.
#[test]
fn inline_v2_job_streams_monotone_events_and_matches_direct() {
    use pogo::linalg::Mat;
    use pogo::rng::Rng;
    use pogo::serve::{InlineProblem, ProblemSource};
    use pogo::serve::problem::InlineMat;

    let (server, client) = start_server(2, 16);
    let (bsz, p, n) = (3usize, 3usize, 6usize);
    let mut data_rng = Rng::seed_from_u64(2024);
    let a: Vec<InlineMat> =
        (0..bsz).map(|_| InlineMat::from_mat(&Mat::<f32>::randn(p, p, &mut data_rng))).collect();
    let b: Vec<InlineMat> =
        (0..bsz).map(|_| InlineMat::from_mat(&Mat::<f32>::randn(p, n, &mut data_rng))).collect();
    let mut job = JobSpec::new(ProblemKind::Procrustes, bsz, p, n);
    job.name = "inline-sse".into();
    job.source = ProblemSource::Inline(InlineProblem::Procrustes { a, b });
    job.steps = 40;
    job.seed = 31;
    job.optimizer = OptimizerSpec::new(Method::Pogo, 0.05).with_engine(Engine::BatchedHost);

    let id = client.submit_v2(&job).expect("v2 submit");
    // Follow the live event stream to the terminal state.
    let mut steps: Vec<usize> = Vec::new();
    let terminal = client
        .stream_events(id, WAIT, |s| {
            assert!(s.loss.is_finite() && s.ortho_error.is_finite());
            steps.push(s.step);
            true
        })
        .expect("SSE stream");
    assert_eq!(terminal, "done");
    assert!(steps.len() >= 3, "got {} progress events", steps.len());
    assert!(steps.windows(2).all(|w| w[0] < w[1]), "steps must be monotone: {steps:?}");
    assert_eq!(*steps.last().unwrap(), job.steps, "stream reaches the final step");

    // The v2 result: untruncated series + final iterate.
    let result = client.result_v2(id).expect("v2 result");
    assert_eq!(result.get("state").as_str(), Some("done"));
    let series = result.get("series").as_arr().expect("series");
    assert_eq!(series.len(), job.steps, "full series, no truncation");
    let iterate = result.get("iterate");
    assert_eq!(iterate.get("domain").as_str(), Some("real"));
    let words =
        pogo::serve::problem::b64_to_words(iterate.get("b64").as_str().expect("b64")).unwrap();
    assert_eq!(words.len(), bsz * p * n);
    // The first packed matrix is feasible: ‖X Xᵀ − I‖_F ≤ 1e-3.
    let x = &words[..p * n];
    let mut gram_err = 0.0f64;
    for i in 0..p {
        for j in 0..p {
            let dot: f64 = (0..n).map(|k| (x[i * n + k] as f64) * (x[j * n + k] as f64)).sum();
            let target = if i == j { 1.0 } else { 0.0 };
            gram_err += (dot - target) * (dot - target);
        }
    }
    assert!(gram_err.sqrt() <= 1e-3, "iterate off-manifold: {}", gram_err.sqrt());

    // Bit-for-bit parity with a direct run of the same inline spec.
    let JobOutcome::Done(direct) = run_job(&job, &RunCtl::default()).expect("direct run")
    else {
        panic!("direct run not done")
    };
    let served_loss = result.get("final_loss").as_f64().expect("final_loss");
    assert_eq!(
        served_loss.to_bits(),
        direct.final_loss.to_bits(),
        "served inline job diverged from the direct run"
    );
    // The streamed series' last loss is the loss fed into the final
    // update — finite and consistent with the result's ortho gate.
    assert!(result.get("ortho_error").as_f64().unwrap() <= 1e-3);
    server.shutdown();
}

/// The v1 shim: a spec submitted through the frozen v1 surface and the
/// same JSON submitted through v2 execute identically, and v1 responses
/// carry no v2 fields.
#[test]
fn v1_shim_and_v2_agree_on_builtin_specs() {
    let (server, client) = start_server(2, 16);
    let job = spec(ProblemKind::Pca, Engine::Rust, JobDomain::Real, 71);
    let v1_id = client.submit(&job).expect("v1 submit");
    let v2_id = client.submit_v2(&job).expect("v2 submit");
    let r1 = client.wait_result(v1_id, WAIT).expect("v1 result");
    let r2 = client.stream_result(v2_id, WAIT).expect("v2 streamed result");
    assert_eq!(
        r1.get("final_loss").as_f64().unwrap().to_bits(),
        r2.get("final_loss").as_f64().unwrap().to_bits(),
        "same spec, same trajectory on both surfaces"
    );
    // v1 stays frozen: no series/iterate/tenant fields.
    assert_eq!(r1.get("series"), &pogo::util::json::Json::Null);
    assert_eq!(r1.get("iterate"), &pogo::util::json::Json::Null);
    assert_eq!(r1.get("tenant"), &pogo::util::json::Json::Null);
    // v2 carries them.
    assert_eq!(r2.get("series").as_arr().unwrap().len(), job.steps);
    assert_eq!(r2.get("tenant").as_str(), Some("anonymous"));
    server.shutdown();
}

/// Complex-domain checkpointing through the daemon: a crashed unitary
/// job resumes from its interleaved-pair (`c64`) checkpoint on restart
/// and lands bit-identically to an uninterrupted run.
#[test]
fn restart_resumes_complex_jobs_from_c64_checkpoints() {
    let dir =
        std::env::temp_dir().join(format!("pogo_serve_e2e_cstate_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();

    let mut job = spec(ProblemKind::Quartic, Engine::Rust, JobDomain::Complex, 23);
    job.steps = 800;
    job.checkpoint_every = 100;

    // Simulate a daemon that died mid-job (same crash shape as the real
    // test above, complex domain this time).
    let crashed_id: u64 = 88;
    let ckpt = dir.join(format!("job-{crashed_id}.ckpt"));
    {
        use std::sync::atomic::{AtomicBool, Ordering};
        let cancel = AtomicBool::new(false);
        let on_step = |step: usize, _loss: f64| {
            if step >= 450 {
                cancel.store(true, Ordering::Relaxed);
            }
        };
        let ctl = RunCtl {
            cancel: Some(&cancel),
            on_step: Some(&on_step),
            checkpoint_path: Some(ckpt.clone()),
            ..Default::default()
        };
        let JobOutcome::Cancelled(_) = run_job(&job, &ctl).expect("interrupted run") else {
            panic!("expected the simulated crash to stop mid-run")
        };
        assert!(ckpt.exists(), "checkpoint should have landed before the crash");
        // It really is a c64 checkpoint: the f32 loader refuses it.
        assert!(pogo::coordinator::checkpoint::load(&ckpt).is_err());
    }
    let state_file = pogo::util::json::Json::obj(vec![
        ("id", pogo::util::json::Json::num(crashed_id as f64)),
        ("state", pogo::util::json::Json::str("running")),
        ("spec", job.to_json()),
    ]);
    std::fs::write(dir.join(format!("job-{crashed_id}.json")), state_file.to_string_pretty())
        .unwrap();

    let server = Server::start(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        workers: 2,
        capacity: 8,
        state_dir: Some(dir.clone()),
    })
    .expect("restarted daemon");
    let client = ServeClient::new(server.addr().to_string());
    let result = client.wait_result(crashed_id, WAIT).expect("recovered complex job");
    assert_eq!(result.get("steps_done").as_usize(), Some(job.steps));
    assert!(result.get("ortho_error").as_f64().unwrap() <= 1e-3);
    assert!(
        result.get("checkpoint").as_str().unwrap_or("").contains("job-88.ckpt"),
        "result should point at the checkpoint"
    );
    // Bit-identical to the uninterrupted trajectory (POGO/sgd is
    // stateless and the c64 checkpoint restores params + step).
    let direct_ctl = RunCtl {
        checkpoint_path: Some(dir.join("direct-complex.ckpt")),
        ..Default::default()
    };
    let JobOutcome::Done(direct) = run_job(&job, &direct_ctl).expect("direct") else { panic!() };
    assert_eq!(
        result.get("final_loss").as_f64().unwrap().to_bits(),
        direct.final_loss.to_bits(),
        "resumed complex job diverged from the uninterrupted trajectory"
    );
    server.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// Sealed artifacts end-to-end: upload a `pogo compile`-style artifact
/// over `/v2/artifacts`, run it as an `artifact`-sourced job
/// **bit-identical** to the same payload submitted inline, and watch
/// repeat submissions against the hash get served from the store cache
/// (the hit counter increments, nothing is revalidated). Unknown hashes
/// are a 404, not a failed job.
#[test]
fn artifact_jobs_match_inline_bit_for_bit_and_hit_the_cache() {
    use pogo::artifact::{Artifact, ArtifactStore, Provenance};
    use pogo::linalg::Mat;
    use pogo::rng::Rng;
    use pogo::serve::problem::InlineMat;
    use pogo::serve::{Admission, ArtifactRef, InlineProblem, ProblemSource};
    use std::sync::Arc;

    fn counter(metrics: &str, name: &str) -> u64 {
        metrics
            .lines()
            .find(|l| l.starts_with(name))
            .and_then(|l| l.split_whitespace().nth(1))
            .and_then(|v| v.parse::<f64>().ok())
            .unwrap_or_else(|| panic!("{name} missing from:\n{metrics}")) as u64
    }

    let dir =
        std::env::temp_dir().join(format!("pogo_serve_e2e_artifacts_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let store = Arc::new(ArtifactStore::open(&dir, 64 << 20).expect("artifact store"));
    let server = Server::start_with_artifacts(
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            workers: 2,
            capacity: 16,
            state_dir: None,
        },
        Admission::default(),
        Some(store),
    )
    .expect("server with artifact store");
    let client = ServeClient::new(server.addr().to_string());

    // One procrustes payload, and the job spec both runs will share.
    let (bsz, p, n) = (3usize, 3usize, 6usize);
    let mut rng = Rng::seed_from_u64(2025);
    let a: Vec<InlineMat> =
        (0..bsz).map(|_| InlineMat::from_mat(&Mat::<f32>::randn(p, p, &mut rng))).collect();
    let b: Vec<InlineMat> =
        (0..bsz).map(|_| InlineMat::from_mat(&Mat::<f32>::randn(p, n, &mut rng))).collect();
    let inline = InlineProblem::Procrustes { a, b };
    let mut job = JobSpec::new(ProblemKind::Procrustes, bsz, p, n);
    job.steps = 40;
    job.seed = 33;
    job.optimizer = OptimizerSpec::new(Method::Pogo, 0.05).with_engine(Engine::BatchedHost);

    // Seal exactly as `pogo compile` does (same provenance construction,
    // so an inline submission of this spec collides onto the same hash).
    let mut prov = Provenance::new(job.seed);
    prov.optimizer = Some(job.optimizer.to_json());
    let art = Artifact::seal(&inline, job.domain, bsz, p, n, prov).expect("seal");
    let hash = art.hash();

    // Upload: a 201-created receipt carrying the content address; the
    // same bytes again take the idempotent already-stored (409) path.
    let receipt = client.upload_artifact(&art.encode()).expect("upload");
    assert_eq!(receipt.get("hash").as_str(), Some(hash.as_str()));
    assert_eq!(receipt.get("existed").as_bool(), Some(false));
    let again = client.upload_artifact(&art.encode()).expect("idempotent re-upload");
    assert_eq!(again.get("existed").as_bool(), Some(true));

    let hits_before =
        counter(&client.metrics().unwrap(), "pogo_serve_artifact_cache_hits_total");

    // The artifact-sourced job and the inline job land bit-identically:
    // both decode through the same payload path.
    let mut art_job = job.clone();
    art_job.name = "artifact-src".into();
    art_job.source = ProblemSource::Artifact(ArtifactRef::new(&hash).unwrap());
    let art_id = client.submit_v2(&art_job).expect("artifact submit");
    let r_art = client.wait_result(art_id, WAIT).expect("artifact result");

    let mut inline_job = job.clone();
    inline_job.name = "inline-src".into();
    inline_job.source = ProblemSource::Inline(inline.clone());
    let inline_id = client.submit_v2(&inline_job).expect("inline submit");
    let r_inline = client.wait_result(inline_id, WAIT).expect("inline result");

    assert_eq!(
        r_art.get("final_loss").as_f64().unwrap().to_bits(),
        r_inline.get("final_loss").as_f64().unwrap().to_bits(),
        "artifact-sourced run must be bit-identical to the inline run"
    );
    assert_eq!(
        r_art.get("ortho_error").as_f64().unwrap().to_bits(),
        r_inline.get("ortho_error").as_f64().unwrap().to_bits(),
    );
    assert!(r_art.get("ortho_error").as_f64().unwrap() <= 1e-3);

    // Cache accounting: the artifact admission hit the store once, and
    // the inline submission deduped onto the uploaded hash (its content
    // address collides with the `pogo compile`-style seal above), so the
    // hit counter moved by two and the payload was never revalidated.
    let hits_after =
        counter(&client.metrics().unwrap(), "pogo_serve_artifact_cache_hits_total");
    assert_eq!(hits_after, hits_before + 2, "artifact admission + inline dedupe");

    // A second submission against the same hash is another pure cache hit.
    let rerun_id = client.submit_v2(&art_job).expect("second artifact submit");
    client.wait_result(rerun_id, WAIT).expect("second artifact result");
    let hits_rerun =
        counter(&client.metrics().unwrap(), "pogo_serve_artifact_cache_hits_total");
    assert_eq!(hits_rerun, hits_after + 1);

    // An unknown hash is refused at admission with a 404 naming the
    // upload route — no job is created, and the miss is counted.
    let mut missing = art_job.clone();
    missing.source = ProblemSource::Artifact(
        ArtifactRef::new(&pogo::util::sha256::hex(b"never uploaded")).unwrap(),
    );
    let err = client.submit_v2(&missing).expect_err("missing artifact");
    assert!(format!("{err:#}").contains("404"), "{err:#}");
    assert!(format!("{err:#}").contains("not in the store"), "{err:#}");
    assert!(
        counter(&client.metrics().unwrap(), "pogo_serve_artifact_cache_misses_total") >= 1
    );

    // The store summary reflects exactly one stored payload.
    let summary = client.artifact_summary().expect("summary");
    assert_eq!(summary.get("count").as_usize(), Some(1));

    server.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// Admission control over HTTP: tenant quotas and the cost budget answer
/// 429 + `Retry-After` before the FIFO, inline payload caps answer 413,
/// and `/metrics` counts each cause.
#[test]
fn admission_control_rejects_over_http_and_counts_causes() {
    use pogo::serve::Admission;

    let server = Server::start_with(
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            workers: 1,
            capacity: 16,
            state_dir: None,
        },
        Admission { tenant_quota: 2, cost_cap: 50_000_000, max_inline_bytes: 256 },
    )
    .expect("server with admission");
    let addr = server.addr().to_string();
    let client = ServeClient::new(addr.clone()).with_api_key("alice");

    // Two long jobs fill alice's quota; the third is a 429 with
    // Retry-After. (cost: 4·3·6·100000 = 7.2M units each — within cap.)
    let mut long = spec(ProblemKind::Replay, Engine::Rust, JobDomain::Real, 41);
    long.steps = 100_000;
    let id_a = client.submit_v2(&long).expect("first");
    let id_b = client.submit_v2(&long).expect("second");
    let err = client.submit_v2(&long).expect_err("quota");
    assert!(format!("{err:#}").contains("429"), "{err:#}");
    assert!(format!("{err:#}").contains("quota"), "{err:#}");
    let (code, headers, _) = pogo::serve::http::request_full(
        &addr,
        "POST",
        "/v2/jobs",
        Some(&long.to_json().to_string()),
        &[("X-Api-Key", "alice")],
    )
    .unwrap();
    assert_eq!(code, 429);
    assert!(headers.iter().any(|(k, _)| k.eq_ignore_ascii_case("retry-after")), "{headers:?}");

    // A different tenant hits the COST gate instead: its huge job would
    // blow the remaining budget (2 × 7.2M held; 4·3·6·1M = 72M > cap).
    let bob = ServeClient::new(addr.clone()).with_api_key("bob");
    let mut huge = long.clone();
    huge.steps = 1_000_000;
    let err = bob.submit_v2(&huge).expect_err("cost");
    assert!(format!("{err:#}").contains("cost budget"), "{err:#}");

    // Inline payloads over --max-inline-bytes are a 413.
    {
        use pogo::linalg::Mat;
        use pogo::rng::Rng;
        use pogo::serve::problem::InlineMat;
        use pogo::serve::{InlineProblem, ProblemSource};
        let mut rng = Rng::seed_from_u64(9);
        let mut inline = spec(ProblemKind::Pca, Engine::Rust, JobDomain::Real, 42);
        // 4 matrices of 6×6 f32 = 576 bytes > 256.
        inline.source = ProblemSource::Inline(InlineProblem::Pca {
            c: (0..4)
                .map(|_| InlineMat::from_mat(&Mat::<f32>::randn(6, 6, &mut rng)))
                .collect(),
        });
        let err = bob.submit_v2(&inline).expect_err("payload cap");
        assert!(format!("{err:#}").contains("413"), "{err:#}");
    }

    // Metrics count each cause (quota was hit twice: once through the
    // client, once through the raw request above).
    let metrics = client.metrics().expect("metrics");
    assert!(
        metrics.contains("pogo_serve_admission_rejected_total{cause=\"quota\"} 2"),
        "{metrics}"
    );
    assert!(
        metrics.contains("pogo_serve_admission_rejected_total{cause=\"cost\"} 1"),
        "{metrics}"
    );
    assert!(
        metrics.contains("pogo_serve_admission_rejected_total{cause=\"inline_bytes\"} 1"),
        "{metrics}"
    );

    // Cancelling releases the quota: alice can submit again.
    client.cancel(id_a).expect("cancel a");
    client.cancel(id_b).expect("cancel b");
    let mut short = long.clone();
    short.steps = 10;
    let id_c = client.submit_v2(&short).expect("after release");
    client.wait_terminal(id_c, WAIT).expect("short job terminal");
    server.shutdown();
}
