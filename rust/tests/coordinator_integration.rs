//! Coordinator-level integration: trainer + schedulers + metrics + engines
//! on realistic multi-group workloads, plus XLA/Rust base-optimizer parity.

use pogo::coordinator::{
    EarlyStop, LrSchedule, OptimizerSpec, ParamStore, Scheduler, Trainer, TrainerConfig,
};
use pogo::linalg::{matmul, matmul_at_b, MatF};
use pogo::manifold::stiefel;
use pogo::optim::base::BaseOptKind;
use pogo::optim::{Engine, Method};
use pogo::rng::Rng;
use pogo::runtime::Registry;

fn registry() -> Option<Registry> {
    let dir = pogo::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built — run `make artifacts`");
        return None;
    }
    Some(Registry::open(dir).unwrap())
}

/// Mixed store: two constrained shape groups + one free parameter.
fn mixed_store(rng: &mut Rng) -> ParamStore {
    let mut store = ParamStore::new();
    store.add_stiefel_group("small", 4, 8, 16, rng);
    store.add_stiefel_group("big", 2, 8, 16, rng); // same shape, distinct key
    store.add_free("head", MatF::randn(4, 4, rng).scale(0.1));
    store
}

#[test]
fn multi_group_trainer_with_mixed_constraints() {
    let mut rng = Rng::seed_from_u64(0);
    let store = mixed_store(&mut rng);
    assert_eq!(store.stiefel_groups().len(), 2); // keyed groups stay apart
    let targets: Vec<MatF> =
        (0..store.len()).map(|_| MatF::randn(8, 16, &mut rng)).collect();
    let head_target = MatF::ones(4, 4);

    let spec = OptimizerSpec::new(Method::Pogo, 0.05).with_base(BaseOptKind::vadam());
    let mut tr = Trainer::new(
        store,
        spec,
        None,
        TrainerConfig { max_steps: 120, log_every: 20, free_lr: 0.05,
                        ..Default::default() },
    )
    .unwrap();

    let mut src = move |store: &ParamStore| {
        let mut loss = 0.0;
        let mut grads = Vec::new();
        for (i, p) in store.params().iter().enumerate() {
            let t = if p.mat.shape() == (4, 4) { &head_target } else { &targets[i] };
            let r = p.mat.sub(t);
            loss += r.norm_sq() as f64;
            grads.push(r.scale(2.0));
        }
        Ok((loss, grads))
    };
    let l0 = src(&tr.store).unwrap().0;
    let l1 = tr.run(&mut src).unwrap();
    assert!(l1 < l0, "{l0} → {l1}");
    assert!(tr.store.max_stiefel_distance() < 1e-3);
    // The free head must have moved toward its target (Adam path).
    let head = tr.store.mat(6);
    assert!(head.sub(&MatF::ones(4, 4)).norm() < MatF::ones(4, 4).norm());
}

#[test]
fn plateau_scheduler_drives_group_lr() {
    let mut rng = Rng::seed_from_u64(1);
    let mut store = ParamStore::new();
    store.add_stiefel("x", stiefel::random_point(4, 8, &mut rng));
    let spec = OptimizerSpec::new(Method::Pogo, 0.2);
    let mut tr = Trainer::new(
        store,
        spec,
        None,
        TrainerConfig {
            max_steps: 40,
            scheduler: Some(Scheduler::new(
                LrSchedule::Plateau { patience: 3, factor: 0.5, min_delta: 1e-12 },
                0.2,
            )),
            ..Default::default()
        },
    )
    .unwrap();
    // Constant loss → plateaus every 3 steps → lr halves repeatedly.
    let mut src = |_: &ParamStore| Ok((1.0, vec![MatF::zeros(4, 8)]));
    tr.run(&mut src).unwrap();
    assert!(tr.lr() < 0.2 / 8.0, "lr {}", tr.lr());
}

#[test]
fn early_stop_halts_run() {
    let mut rng = Rng::seed_from_u64(2);
    let mut store = ParamStore::new();
    store.add_stiefel("x", stiefel::random_point(4, 8, &mut rng));
    let spec = OptimizerSpec::new(Method::Pogo, 0.1);
    let mut tr = Trainer::new(
        store,
        spec,
        None,
        TrainerConfig {
            max_steps: 10_000,
            early_stop: Some(EarlyStop::new(5, 1e-9)),
            ..Default::default()
        },
    )
    .unwrap();
    let mut src = |_: &ParamStore| Ok((1.0, vec![MatF::zeros(4, 8)]));
    tr.run(&mut src).unwrap();
    assert!(tr.step_idx() <= 10, "ran {} steps", tr.step_idx());
}

#[test]
fn xla_base_optimizer_parity_with_rust() {
    // POGO + momentum must agree across engines (the base transform runs
    // host-side for the XLA stepper).
    let Some(reg) = registry() else { return };
    let mut rng = Rng::seed_from_u64(3);
    let (b, p, n) = (4, 8, 16);
    let x0: Vec<MatF> = (0..b).map(|_| stiefel::random_point(p, n, &mut rng)).collect();
    let gseq: Vec<Vec<MatF>> = (0..6)
        .map(|_| {
            (0..b)
                .map(|_| {
                    let g = MatF::randn(p, n, &mut rng);
                    let nn = g.norm();
                    g.scale(0.7 / nn)
                })
                .collect()
        })
        .collect();

    let run = |engine: Engine| -> Vec<MatF> {
        let spec = OptimizerSpec::new(Method::Pogo, 0.1)
            .with_base(BaseOptKind::momentum(0.5))
            .with_engine(engine);
        let reg_opt = if engine == Engine::Xla { Some(&reg) } else { None };
        let mut opt = spec.build::<f32>(reg_opt, (b, p, n)).unwrap();
        let mut xs = x0.clone();
        for gs in &gseq {
            opt.step_group(&mut xs, gs).unwrap();
        }
        xs
    };
    let rust = run(Engine::Rust);
    let xla = run(Engine::Xla);
    for (i, (r, x)) in rust.iter().zip(&xla).enumerate() {
        let d = r.sub(x).max_abs();
        assert!(d < 1e-3, "matrix {i}: engines diverged by {d}");
    }
}

#[test]
fn landing_pc_xla_scale_invariance() {
    // LandingPC's normalize-grad semantics must survive the XLA engine.
    let Some(reg) = registry() else { return };
    let mut rng = Rng::seed_from_u64(4);
    let (b, p, n) = (4, 8, 16);
    let x0: Vec<MatF> = (0..b).map(|_| stiefel::random_point(p, n, &mut rng)).collect();
    let gs: Vec<MatF> = (0..b).map(|_| MatF::randn(p, n, &mut rng)).collect();
    let gs_scaled: Vec<MatF> = gs.iter().map(|g| g.scale(41.0)).collect();

    let spec = OptimizerSpec::new(Method::LandingPC, 0.05).with_engine(Engine::Xla);
    let mut o1 = spec.build::<f32>(Some(&reg), (b, p, n)).unwrap();
    let mut o2 = spec.build::<f32>(Some(&reg), (b, p, n)).unwrap();
    let mut x1 = x0.clone();
    let mut x2 = x0;
    o1.step_group(&mut x1, &gs).unwrap();
    o2.step_group(&mut x2, &gs_scaled).unwrap();
    for (a, b) in x1.iter().zip(&x2) {
        assert!(a.sub(b).max_abs() < 1e-5, "not scale invariant");
    }
}

#[test]
fn metric_log_csv_emission_from_trainer() {
    let mut rng = Rng::seed_from_u64(5);
    let mut store = ParamStore::new();
    store.add_stiefel("x", stiefel::random_point(4, 8, &mut rng));
    let a = MatF::randn(4, 4, &mut rng);
    let bm = MatF::randn(4, 8, &mut rng);
    let spec = OptimizerSpec::new(Method::Pogo, 0.02);
    let mut tr = Trainer::new(
        store,
        spec,
        None,
        TrainerConfig { max_steps: 30, log_every: 5, ..Default::default() },
    )
    .unwrap();
    let mut src = move |store: &ParamStore| {
        let r = matmul(&a, store.mat(0)).sub(&bm);
        Ok((r.norm_sq() as f64, vec![matmul_at_b(&a, &r).scale(2.0)]))
    };
    tr.run(&mut src).unwrap();
    let path = std::env::temp_dir().join("pogo_it_metrics.csv");
    tr.log.write_csv(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    assert!(text.lines().count() > 3);
    assert!(text.starts_with("step,wall_s,"));
    assert!(text.contains("distance"));
}
