//! Integration: AOT artifacts (JAX/Pallas → HLO text) executed through the
//! PJRT runtime must agree step-for-step with the pure-Rust engine.
//!
//! Requires `make artifacts` (the quick set suffices); tests self-skip with
//! a loud message if the manifest is missing.

use pogo::coordinator::{OptimizerSpec, ParamStore, Trainer, TrainerConfig};
use pogo::linalg::{matmul, matmul_at_b, Mat, MatF};
use pogo::manifold::stiefel;
use pogo::optim::base::{BaseOpt, BaseOptKind};
use pogo::optim::pogo::{LambdaPolicy, Pogo};
use pogo::optim::{Engine, Method, Orthoptimizer};
use pogo::rng::Rng;
use pogo::runtime::stepper::{StepKind, XlaStepper};
use pogo::runtime::{Arg, Registry};

fn registry() -> Option<Registry> {
    let dir = pogo::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built — run `make artifacts`");
        return None;
    }
    Some(Registry::open(dir).unwrap())
}

fn group(rng: &mut Rng, b: usize, p: usize, n: usize) -> (Vec<MatF>, Vec<MatF>) {
    let xs: Vec<MatF> = (0..b).map(|_| stiefel::random_point(p, n, rng)).collect();
    let gs: Vec<MatF> = (0..b)
        .map(|_| {
            let g = MatF::randn(p, n, rng);
            let norm = g.norm();
            g.scale(1.0 / norm) // ‖G‖ = 1 keeps ξ < 1
        })
        .collect();
    (xs, gs)
}

fn max_diff(a: &[MatF], b: &[MatF]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x.sub(y).max_abs() as f64).fold(0.0, f64::max)
}

#[test]
fn pogo_xla_matches_rust_engine() {
    let Some(reg) = registry() else { return };
    let mut rng = Rng::seed_from_u64(0);
    let (xs0, gs) = group(&mut rng, 4, 8, 16);

    let mut xla = XlaStepper::new(&reg, StepKind::Pogo, 0.1, 4, 8, 16).unwrap();
    let mut xs_xla = xs0.clone();
    XlaStepper::step_group(&mut xla, &mut xs_xla, &gs).unwrap();

    let mut xs_rust = xs0;
    for (x, g) in xs_rust.iter_mut().zip(&gs) {
        let (xp, _) = Pogo::<f32>::update(x, g, 0.1, LambdaPolicy::Half);
        *x = xp;
    }
    let d = max_diff(&xs_xla, &xs_rust);
    assert!(d < 2e-5, "xla vs rust diff {d}");
    for x in &xs_xla {
        assert!(stiefel::distance(x) < 1e-3);
    }
}

#[test]
fn pogo_vadam_xla_matches_rust_engine() {
    let Some(reg) = registry() else { return };
    let mut rng = Rng::seed_from_u64(1);
    let (xs0, _) = group(&mut rng, 4, 8, 16);

    let mut xla = XlaStepper::new(&reg, StepKind::PogoVadam, 0.1, 4, 8, 16).unwrap();
    let mut base = BaseOpt::<f32>::new(BaseOptKind::vadam(), 4);
    let mut xs_xla = xs0.clone();
    let mut xs_rust = xs0;

    // Multiple steps so the (m, v, t) state paths are exercised.
    for step in 0..5 {
        let gs: Vec<MatF> =
            (0..4).map(|_| MatF::randn(8, 16, &mut rng).scale(1.0 + step as f32)).collect();
        XlaStepper::step_group(&mut xla, &mut xs_xla, &gs).unwrap();
        for (i, (x, g)) in xs_rust.iter_mut().zip(&gs).enumerate() {
            let gt = base.transform(i, g);
            let (xp, _) = Pogo::<f32>::update(x, &gt, 0.1, LambdaPolicy::Half);
            *x = xp;
        }
        let d = max_diff(&xs_xla, &xs_rust);
        assert!(d < 5e-4, "step {step}: diff {d}");
    }
}

#[test]
fn landing_and_slpg_xla_match_rust() {
    let Some(reg) = registry() else { return };
    let mut rng = Rng::seed_from_u64(2);
    let (xs0, gs) = group(&mut rng, 4, 8, 16);

    // Landing (fixed-step program; rust side without safeguard for parity).
    let mut xla = XlaStepper::new(&reg, StepKind::Landing, 0.05, 4, 8, 16).unwrap();
    let mut xs_xla = xs0.clone();
    XlaStepper::step_group(&mut xla, &mut xs_xla, &gs).unwrap();
    let cfg = pogo::optim::landing::LandingConfig {
        lr: 0.05,
        safeguard: false,
        ..Default::default()
    };
    let mut xs_rust = xs0.clone();
    for (x, g) in xs_rust.iter_mut().zip(&gs) {
        let (xp, _) = pogo::optim::landing::Landing::<f32>::update(x, g, &cfg);
        *x = xp;
    }
    assert!(max_diff(&xs_xla, &xs_rust) < 2e-5);

    // SLPG.
    let mut xla = XlaStepper::new(&reg, StepKind::Slpg, 0.05, 4, 8, 16).unwrap();
    let mut xs_xla = xs0.clone();
    XlaStepper::step_group(&mut xla, &mut xs_xla, &gs).unwrap();
    let mut xs_rust = xs0;
    for (x, g) in xs_rust.iter_mut().zip(&gs) {
        *x = pogo::optim::slpg::Slpg::<f32>::update(x, g, 0.05);
    }
    assert!(max_diff(&xs_xla, &xs_rust) < 2e-5);
}

#[test]
fn find_root_xla_three_phase_matches_rust() {
    let Some(reg) = registry() else { return };
    let mut rng = Rng::seed_from_u64(3);
    let (xs0, gs) = group(&mut rng, 4, 8, 16);

    let mut xla = XlaStepper::new(&reg, StepKind::PogoFindRoot, 0.3, 4, 8, 16).unwrap();
    let mut xs_xla = xs0.clone();
    XlaStepper::step_group(&mut xla, &mut xs_xla, &gs).unwrap();
    assert_eq!(xla.last_lambdas.len(), 4);

    let mut xs_rust = xs0;
    for (x, g) in xs_rust.iter_mut().zip(&gs) {
        let (xp, _) = Pogo::<f32>::update(x, g, 0.3, LambdaPolicy::FindRoot);
        *x = xp;
    }
    let d = max_diff(&xs_xla, &xs_rust);
    assert!(d < 1e-3, "find-root xla vs rust diff {d}");
}

#[test]
fn pca_lossgrad_artifact_matches_closed_form() {
    let Some(reg) = registry() else { return };
    let mut rng = Rng::seed_from_u64(4);
    let (p, n) = (8, 16);
    let x = stiefel::random_point(p, n, &mut rng);
    let a = MatF::randn(n, n, &mut rng);
    let aat = matmul(&a, &a.transpose());

    let exe = reg.get("pca_lossgrad_test").unwrap();
    let outs = exe.run(&[Arg::Mat(&x), Arg::Mat(&aat)]).unwrap();
    let loss = pogo::runtime::literal_to_scalar(&outs[0]).unwrap();
    let grad = pogo::runtime::literal_to_mat(&outs[1], p, n).unwrap();

    let want_loss = -matmul(&x, &aat).dot(&x);
    let want_grad = matmul(&x, &aat).scale(-2.0);
    assert!((loss - want_loss).abs() < 1e-2 * want_loss.abs(), "{loss} vs {want_loss}");
    assert!(grad.sub(&want_grad).max_abs() < 1e-2);
}

#[test]
fn complex_pogo_artifact_matches_rust() {
    let Some(reg) = registry() else { return };
    let mut rng = Rng::seed_from_u64(5);
    let b = 2;
    let (p, n) = (4, 8);
    let xs: Vec<pogo::linalg::CMatF> =
        (0..b).map(|_| stiefel::random_point_complex::<f32>(p, n, &mut rng)).collect();
    let gs: Vec<pogo::linalg::CMatF> =
        (0..b).map(|_| pogo::linalg::CMat::randn(p, n, &mut rng)).collect();

    // Pack (B, p, n) re/im planes.
    let pack = |f: &dyn Fn(&pogo::linalg::CMatF) -> Vec<f32>| -> Vec<f32> {
        xs.iter().flat_map(|m| f(m)).collect()
    };
    let xr = pack(&|m| m.re_vec());
    let xi = pack(&|m| m.im_vec());
    let gr: Vec<f32> = gs.iter().flat_map(|m| m.re_vec()).collect();
    let gi: Vec<f32> = gs.iter().flat_map(|m| m.im_vec()).collect();

    let exe = reg.get("pogo_step_complex_test").unwrap();
    let dims = vec![b, p, n];
    let outs = exe
        .run(&[
            Arg::F32(&xr, dims.clone()),
            Arg::F32(&xi, dims.clone()),
            Arg::F32(&gr, dims.clone()),
            Arg::F32(&gi, dims.clone()),
            Arg::Scalar(0.1),
        ])
        .unwrap();
    let out_r = pogo::runtime::literal_to_vec(&outs[0]).unwrap();
    let out_i = pogo::runtime::literal_to_vec(&outs[1]).unwrap();

    for i in 0..b {
        let (xp, _) = pogo::optim::unitary::PogoC::<f32>::update(
            &xs[i],
            &gs[i],
            0.1,
            LambdaPolicy::Half,
        );
        let pn = p * n;
        let got_r = &out_r[i * pn..(i + 1) * pn];
        let got_i = &out_i[i * pn..(i + 1) * pn];
        for (a, b) in got_r.iter().zip(&xp.re_vec()) {
            assert!((a - b).abs() < 5e-4, "re mismatch {a} vs {b}");
        }
        for (a, b) in got_i.iter().zip(&xp.im_vec()) {
            assert!((a - b).abs() < 5e-4, "im mismatch {a} vs {b}");
        }
    }
}

#[test]
fn trainer_with_xla_engine_descends() {
    let Some(reg) = registry() else { return };
    let mut rng = Rng::seed_from_u64(6);
    let (b, p, n) = (4, 8, 16);
    let mut store = ParamStore::new();
    store.add_stiefel_group("x", b, p, n, &mut rng);
    let targets: Vec<MatF> = (0..b).map(|_| stiefel::random_point(p, n, &mut rng)).collect();
    let spec = OptimizerSpec::new(Method::Pogo, 0.05).with_engine(Engine::Xla);
    let mut tr = Trainer::new(
        store,
        spec,
        Some(&reg),
        TrainerConfig { max_steps: 100, log_every: 20, ..Default::default() },
    )
    .unwrap();
    // Loss: Σ ‖X_i − T_i‖² (closest-orthogonal-matrix chase).
    let mut src = move |store: &ParamStore| {
        let mut loss = 0.0f64;
        let mut grads = Vec::new();
        for (i, prm) in store.params().iter().enumerate() {
            let r = prm.mat.sub(&targets[i]);
            loss += r.norm_sq() as f64;
            grads.push(r.scale(2.0));
        }
        Ok((loss, grads))
    };
    let l0 = src(&tr.store).unwrap().0;
    let l1 = tr.run(&mut src).unwrap();
    assert!(l1 < l0 * 0.7, "{l0} → {l1}");
    assert!(tr.store.max_stiefel_distance() < 1e-3);
}

#[test]
fn rust_vs_xla_full_trajectory_agreement() {
    // 20 steps of POGO on identical Procrustes problems: the two engines
    // must produce the same loss curve within f32 tolerance.
    let Some(reg) = registry() else { return };
    let mut rng = Rng::seed_from_u64(7);
    let (b, p, n) = (4, 8, 16);
    let x0: Vec<MatF> = (0..b).map(|_| stiefel::random_point(p, n, &mut rng)).collect();
    let a: Vec<MatF> = (0..b).map(|_| MatF::randn(p, p, &mut rng)).collect();
    let t: Vec<MatF> = (0..b).map(|_| MatF::randn(p, n, &mut rng)).collect();

    let run = |engine: Engine| -> Vec<f64> {
        let mut store = ParamStore::new();
        for (i, x) in x0.iter().enumerate() {
            store.add_stiefel(format!("x{i}"), x.clone());
        }
        let spec = OptimizerSpec::new(Method::Pogo, 0.02).with_engine(engine);
        let reg_opt = if engine == Engine::Xla { Some(&reg) } else { None };
        let mut tr = Trainer::new(
            store,
            spec,
            reg_opt,
            TrainerConfig { max_steps: 20, log_every: 1, ..Default::default() },
        )
        .unwrap();
        let a = a.clone();
        let t = t.clone();
        let mut losses = Vec::new();
        let mut src = move |store: &ParamStore| {
            let mut loss = 0.0f64;
            let mut grads = Vec::new();
            for (i, prm) in store.params().iter().enumerate() {
                let r = matmul(&a[i], &prm.mat).sub(&t[i]);
                loss += r.norm_sq() as f64;
                grads.push(matmul_at_b(&a[i], &r).scale(2.0));
            }
            Ok((loss, grads))
        };
        for _ in 0..20 {
            losses.push(tr.step(&mut src).unwrap());
        }
        losses
    };

    let rust = run(Engine::Rust);
    let xla = run(Engine::Xla);
    for (i, (r, x)) in rust.iter().zip(&xla).enumerate() {
        assert!(
            (r - x).abs() < 1e-2 * (1.0 + r.abs()),
            "step {i}: rust {r} vs xla {x}"
        );
    }
}

#[test]
fn distance_artifact_matches_rust() {
    let Some(reg) = registry() else { return };
    let mut rng = Rng::seed_from_u64(8);
    let (xs, _) = group(&mut rng, 4, 8, 16);
    let exe = reg.get("distance_b4_8x16").unwrap();
    let outs = exe.run(&[Arg::Batch(&xs)]).unwrap();
    let d = pogo::runtime::literal_to_vec(&outs[0]).unwrap();
    for (i, x) in xs.iter().enumerate() {
        let want = stiefel::distance(x) as f32;
        assert!((d[i] - want).abs() < 1e-4, "{} vs {want}", d[i]);
    }
}

#[test]
fn every_manifest_entry_compiles() {
    // Heavier check (compiles all 80+ programs) — gated behind an env var
    // so `cargo test` stays fast; the bench harness exercises the big ones.
    if std::env::var("POGO_COMPILE_ALL").is_err() {
        return;
    }
    let Some(reg) = registry() else { return };
    for name in reg.names() {
        reg.get(name).unwrap_or_else(|e| panic!("{name}: {e}"));
    }
}
