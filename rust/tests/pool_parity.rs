//! Resident pool ≡ spawn-per-call ≡ single-threaded serial, bit-for-bit.
//!
//! The pool backend promises to be invisible in results: both backends
//! compute the same shard geometry (`per = rows.div_ceil(nt)` contiguous
//! chunks) and run the same per-chunk closures, and the serial path runs
//! the very same closure over `0..rows` — each matrix/row is processed by
//! exactly one thread with the same sequential arithmetic regardless of
//! which thread that is. So the comparisons below are EXACT (`== 0.0`),
//! not tolerance checks, across update rules, shapes crossing the
//! parallelization thresholds in both directions, element types, and
//! stateful base optimizers.
//!
//! Tests serialize on a lock because the pool mode / thread-count
//! overrides are process-global.

use pogo::linalg::{BatchMat, Complex, Field, Mat, Scalar};
use pogo::manifold::stiefel;
use pogo::optim::base::BaseOptKind;
use pogo::optim::batched::BatchedHost;
use pogo::optim::pogo::LambdaPolicy;
use pogo::optim::Orthoptimizer;
use pogo::rng::Rng;
use pogo::util::pool::{self, PoolMode};
use std::sync::Mutex;

/// Serializes tests: the backend/thread overrides are process-global.
static OVERRIDE_LOCK: Mutex<()> = Mutex::new(());

/// Clears the overrides even if an assertion unwinds mid-test.
struct Restore;
impl Drop for Restore {
    fn drop(&mut self) {
        pool::set_pool_mode(None);
        pool::set_num_threads(None);
    }
}

/// The three execution backends under comparison.
#[derive(Clone, Copy, Debug)]
enum Backend {
    Resident,
    Spawn,
    Serial,
}

impl Backend {
    fn engage(self) {
        match self {
            Backend::Resident => {
                pool::set_num_threads(None);
                pool::set_pool_mode(Some(PoolMode::Resident));
            }
            Backend::Spawn => {
                pool::set_num_threads(None);
                pool::set_pool_mode(Some(PoolMode::Spawn));
            }
            Backend::Serial => {
                // num_threads() == 1 short-circuits every parallel entry
                // point to the inline serial path, whatever the mode.
                pool::set_num_threads(Some(1));
                pool::set_pool_mode(Some(PoolMode::Resident));
            }
        }
    }
}

/// Largest elementwise |a − b|² across two packed groups.
fn max_abs_sq_diff<E: Field>(a: &BatchMat<E>, b: &BatchMat<E>) -> f64 {
    a.as_slice()
        .iter()
        .zip(b.as_slice())
        .map(|(&x, &y)| (x - y).abs_sq().to_f64())
        .fold(0.0, f64::max)
}

/// Unit-scaled random gradient (keeps the Thm 3.5 step regime).
fn random_grad<E: Field>(p: usize, n: usize, rng: &mut Rng) -> Mat<E> {
    let g = Mat::<E>::randn(p, n, rng);
    let nn = g.norm().to_f64().max(1e-30);
    g.scale(E::from_f64(0.3 / nn))
}

/// Step the same initial group with the same gradient sequence under one
/// backend and return the final iterate tensor (plus the last λ report).
fn trajectory<E: Field>(
    backend: Backend,
    make_opt: &dyn Fn() -> BatchedHost<E>,
    x0: &BatchMat<E>,
    grads: &[BatchMat<E>],
) -> (BatchMat<E>, Option<f64>) {
    backend.engage();
    let mut x = x0.clone();
    let mut opt = make_opt();
    for g in grads {
        opt.step_batch(&mut x, g).unwrap();
    }
    (x, opt.last_lambda())
}

/// Compare resident vs spawn vs serial trajectories on one rule, at one
/// shape crossing the fused-parallel threshold and one staying below it.
fn assert_backend_parity<E: Field>(
    make_opt: &dyn Fn() -> BatchedHost<E>,
    random_point: &dyn Fn(usize, usize, &mut Rng) -> Mat<E>,
    seed: u64,
) {
    // (4, 8) at B = 1024: 12·B·p²·n ≈ 1.57M flops — above FUSED_PAR_FLOPS
    // (2²⁰), so the fused sweep genuinely shards across the pool.
    // (3, 3) at B = 64 stays far below every threshold (serial regime).
    for (p, n, b, steps) in [(4usize, 8usize, 1024usize, 3usize), (3, 3, 64, 4)] {
        let mut rng = Rng::seed_from_u64(seed ^ (p * 100 + n * 10 + b) as u64);
        let xs: Vec<Mat<E>> = (0..b).map(|_| random_point(p, n, &mut rng)).collect();
        let x0 = BatchMat::from_mats(&xs);
        let grads: Vec<BatchMat<E>> = (0..steps)
            .map(|_| {
                let gs: Vec<Mat<E>> = (0..b).map(|_| random_grad(p, n, &mut rng)).collect();
                BatchMat::from_mats(&gs)
            })
            .collect();

        let (x_res, lam_res) = trajectory(Backend::Resident, make_opt, &x0, &grads);
        let (x_spawn, lam_spawn) = trajectory(Backend::Spawn, make_opt, &x0, &grads);
        let (x_serial, lam_serial) = trajectory(Backend::Serial, make_opt, &x0, &grads);

        let d_spawn = max_abs_sq_diff(&x_res, &x_spawn);
        assert!(
            d_spawn == 0.0,
            "resident diverged from spawn by |Δ|²={d_spawn} at ({p}, {n}) B={b}"
        );
        let d_serial = max_abs_sq_diff(&x_res, &x_serial);
        assert!(
            d_serial == 0.0,
            "resident diverged from serial by |Δ|²={d_serial} at ({p}, {n}) B={b}"
        );
        assert_eq!(lam_res, lam_spawn, "λ report differs resident vs spawn");
        assert_eq!(lam_res, lam_serial, "λ report differs resident vs serial");
        for m in x_res.to_mats() {
            assert!(m.all_finite());
        }
    }
}

fn real_point<S: Scalar>(p: usize, n: usize, rng: &mut Rng) -> Mat<S> {
    stiefel::random_point_t::<S>(p, n, rng)
}

fn complex_point<S: Scalar>(p: usize, n: usize, rng: &mut Rng) -> Mat<Complex<S>> {
    stiefel::random_point_complex::<S>(p, n, rng)
}

#[test]
fn pogo_find_root_f64_parity_across_backends() {
    let _g = OVERRIDE_LOCK.lock().unwrap();
    let _r = Restore;
    // FindRoot exercises the deepest fused stack: per-matrix gram
    // residuals → slice-form quartic coefficients → fixed-storage solver,
    // all inside pool workers with thread-local scratch.
    assert_backend_parity::<f64>(
        &|| BatchedHost::pogo(0.1, LambdaPolicy::FindRoot, BaseOptKind::Sgd),
        &real_point::<f64>,
        1,
    );
}

#[test]
fn pogo_half_momentum_f32_parity_across_backends() {
    let _g = OVERRIDE_LOCK.lock().unwrap();
    let _r = Restore;
    // Momentum base: the batched base-optimizer state update (scale +
    // axpy, elementwise-sharded on large buffers) rides the pool too.
    assert_backend_parity::<f32>(
        &|| BatchedHost::pogo(0.1, LambdaPolicy::Half, BaseOptKind::momentum(0.9)),
        &real_point::<f32>,
        2,
    );
}

#[test]
fn landing_f64_parity_across_backends() {
    let _g = OVERRIDE_LOCK.lock().unwrap();
    let _r = Restore;
    assert_backend_parity::<f64>(
        &|| BatchedHost::landing(0.1, 1.0, BaseOptKind::Sgd),
        &real_point::<f64>,
        3,
    );
}

#[test]
fn pogo_half_complex_parity_across_backends() {
    let _g = OVERRIDE_LOCK.lock().unwrap();
    let _r = Restore;
    // The unitary manifold through the same engine: complex multiplies
    // are componentwise-commutative, so bit-exactness holds there too.
    assert_backend_parity::<Complex<f32>>(
        &|| BatchedHost::pogo(0.1, LambdaPolicy::Half, BaseOptKind::Sgd),
        &complex_point::<f32>,
        4,
    );
}

#[test]
fn naive_path_parity_across_backends() {
    let _g = OVERRIDE_LOCK.lock().unwrap();
    let _r = Restore;
    // The 5-pass naive composition (per-product pool dispatches) must be
    // backend-invisible as well — it shares parallel_rows with matmul.
    use pogo::linalg::KernelChoice;
    assert_backend_parity::<f64>(
        &|| {
            BatchedHost::pogo(0.1, LambdaPolicy::Half, BaseOptKind::Sgd)
                .with_kernel(KernelChoice::Naive)
        },
        &real_point::<f64>,
        5,
    );
}
