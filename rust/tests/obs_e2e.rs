//! Flight-recorder end-to-end: Prometheus conformance of the full
//! `/metrics` scrape and span-tree invariants of `/v2/jobs/:id/trace`.
//!
//! The conformance check is deliberately schema-free: it parses every
//! line of the exposition text and asserts the format rules Prometheus
//! itself enforces — exactly one `# HELP` and one `# TYPE` per family,
//! every sample attributable to a declared family, histogram buckets
//! cumulative and monotone in declaration order ending at `+Inf`, and
//! `+Inf == _count` per series. New metrics added later are covered
//! automatically; a malformed one fails here before a scraper sees it.
//!
//! This is an integration test (its own process), so it may force the
//! flight recorder on without racing the unit suite's override tests.

use pogo::coordinator::OptimizerSpec;
use pogo::optim::{Engine, Method};
use pogo::serve::{JobDomain, JobSpec, ProblemKind, ServeClient, ServeConfig, Server};
use pogo::util::json::Json;
use std::collections::BTreeMap;
use std::time::Duration;

const WAIT: Duration = Duration::from_secs(120);

fn start_server(state_dir: Option<std::path::PathBuf>) -> (Server, ServeClient) {
    let server = Server::start(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        workers: 2,
        capacity: 16,
        state_dir,
    })
    .expect("server should bind an ephemeral port");
    let client = ServeClient::new(server.addr().to_string());
    (server, client)
}

fn spec(seed: u64) -> JobSpec {
    let mut s = JobSpec::new(ProblemKind::Procrustes, 4, 3, 6);
    s.name = format!("obs-e2e-{seed}");
    s.domain = JobDomain::Real;
    s.steps = 60;
    s.seed = seed;
    s.optimizer = OptimizerSpec::new(Method::Pogo, 0.05).with_engine(Engine::BatchedHost);
    s
}

// ---------------------------------------------------------------------------
// Prometheus text-format conformance.
// ---------------------------------------------------------------------------

/// `metric{a="x",le="0.005"} 12` → (name, labels-without-le, le, value).
fn parse_sample(line: &str) -> (String, String, Option<String>, f64) {
    let (metric, value) = line.rsplit_once(' ').unwrap_or_else(|| panic!("bad sample: {line}"));
    let value: f64 = value.parse().unwrap_or_else(|_| panic!("bad value: {line}"));
    let (name, labels) = match metric.split_once('{') {
        Some((n, rest)) => (n, rest.strip_suffix('}').unwrap_or_else(|| panic!("{line}"))),
        None => (metric, ""),
    };
    // Label values here are routes, states and `le` bounds — none contain
    // commas or escaped quotes, so a flat split is exact.
    let mut le = None;
    let mut rest: Vec<&str> = Vec::new();
    for part in labels.split(',').filter(|p| !p.is_empty()) {
        match part.strip_prefix("le=\"") {
            Some(v) => le = Some(v.trim_end_matches('"').to_string()),
            None => rest.push(part),
        }
    }
    (name.to_string(), rest.join(","), le, value)
}

/// Assert the exposition rules over the whole scrape; return the set of
/// `histogram`-typed family names and each series' `_count` value.
fn assert_prometheus_conformant(text: &str) -> (Vec<String>, BTreeMap<(String, String), f64>) {
    let mut help: BTreeMap<&str, usize> = BTreeMap::new();
    let mut types: BTreeMap<&str, (&str, usize)> = BTreeMap::new();
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("# HELP ") {
            let name = rest.split_whitespace().next().expect("HELP names a metric");
            *help.entry(name).or_insert(0) += 1;
        } else if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            let name = it.next().expect("TYPE names a metric");
            let kind = it.next().expect("TYPE declares a kind");
            let e = types.entry(name).or_insert((kind, 0));
            assert_eq!(e.0, kind, "{name}: conflicting TYPE declarations");
            e.1 += 1;
        }
    }
    for (name, n) in &help {
        assert_eq!(*n, 1, "{name}: {n} HELP lines");
        assert!(types.contains_key(name), "{name}: HELP without TYPE");
    }
    for (name, (_, n)) in &types {
        assert_eq!(*n, 1, "{name}: {n} TYPE lines");
        assert!(help.contains_key(name), "{name}: TYPE without HELP");
    }

    // Every sample must belong to a declared family; histogram suffixes
    // resolve to their base name.
    let family_of = |metric: &str| -> Option<String> {
        for suffix in ["_bucket", "_sum", "_count"] {
            if let Some(base) = metric.strip_suffix(suffix) {
                if types.get(base).is_some_and(|(k, _)| *k == "histogram") {
                    return Some(base.to_string());
                }
            }
        }
        types.contains_key(metric).then(|| metric.to_string())
    };

    // (family, labels) → in-order cumulative bucket values / count value.
    let mut buckets: BTreeMap<(String, String), Vec<(Option<String>, f64)>> = BTreeMap::new();
    let mut counts: BTreeMap<(String, String), f64> = BTreeMap::new();
    for line in text.lines() {
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (name, labels, le, value) = parse_sample(line);
        let family = family_of(&name)
            .unwrap_or_else(|| panic!("sample {name} has no HELP/TYPE declaration"));
        if name == format!("{family}_bucket") {
            buckets.entry((family, labels)).or_default().push((le, value));
        } else if name == format!("{family}_count") {
            counts.insert((family, labels), value);
        }
    }
    for ((family, labels), series) in &buckets {
        let mut last = 0.0;
        for (le, v) in series {
            assert!(le.is_some(), "{family}{{{labels}}}: bucket without le");
            assert!(*v >= last, "{family}{{{labels}}}: non-monotone bucket {v} after {last}");
            last = *v;
        }
        let (last_le, inf) = series.last().expect("non-empty series");
        assert_eq!(last_le.as_deref(), Some("+Inf"), "{family}{{{labels}}}");
        let count = counts
            .get(&(family.clone(), labels.clone()))
            .unwrap_or_else(|| panic!("{family}{{{labels}}}: buckets without _count"));
        assert_eq!(*inf, *count, "{family}{{{labels}}}: +Inf bucket != _count");
    }

    let hist_families =
        types.iter().filter(|(_, (k, _))| *k == "histogram").map(|(n, _)| n.to_string()).collect();
    (hist_families, counts)
}

#[test]
fn metrics_scrape_is_prometheus_conformant_and_histograms_fill() {
    pogo::obs::set_enabled(Some(true));
    let dir = std::env::temp_dir().join(format!("pogo_obs_e2e_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let (server, client) = start_server(Some(dir.clone()));

    // One checkpointed job exercises queue wait, run time, step timing,
    // session apply and checkpoint save in a single pass.
    let mut job = spec(51);
    job.checkpoint_every = 20;
    let id = client.submit(&job).expect("submit");
    client.wait_result(id, WAIT).expect("result");

    // A request's duration is recorded after its response is written, so
    // warm the /metrics route with one scrape and parse the second.
    client.metrics().expect("warm-up scrape");
    let text = client.metrics().expect("metrics");
    let (hist_families, counts) = assert_prometheus_conformant(&text);

    // The flight recorder exports its full ladder set (ISSUE floor: >= 4).
    for family in [
        "pogo_serve_http_request_duration_seconds",
        "pogo_serve_job_queue_wait_seconds",
        "pogo_serve_job_run_seconds",
        "pogo_checkpoint_io_seconds",
        "pogo_step_duration_seconds",
        "pogo_session_apply_seconds",
        "pogo_pool_dispatch_wait_seconds",
        "pogo_pool_run_seconds",
    ] {
        assert!(hist_families.iter().any(|f| f == family), "{family} missing:\n{text}");
    }
    assert!(hist_families.len() >= 4, "{hist_families:?}");

    // And the job actually filled them: at least one observation each.
    let total = |family: &str| -> f64 {
        counts.iter().filter(|((f, _), _)| f == family).map(|(_, v)| *v).sum()
    };
    assert!(total("pogo_serve_job_queue_wait_seconds") >= 1.0, "{text}");
    assert!(total("pogo_serve_job_run_seconds") >= 1.0, "{text}");
    assert!(total("pogo_step_duration_seconds") >= 1.0, "{text}");
    assert!(total("pogo_session_apply_seconds") >= 1.0, "{text}");
    assert!(total("pogo_checkpoint_io_seconds") >= 1.0, "checkpointed job saved:\n{text}");
    // The scrape request itself was timed under its normalized route.
    let scrape = counts
        .iter()
        .filter(|((f, l), _)| {
            f == "pogo_serve_http_request_duration_seconds" && l.contains("route=\"/metrics\"")
        })
        .map(|(_, v)| *v)
        .sum::<f64>();
    assert!(scrape >= 1.0, "{text}");

    server.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------------
// Per-job trace endpoint.
// ---------------------------------------------------------------------------

/// Recursively assert the self/total invariant and count spans.
fn check_node(node: &Json) -> usize {
    let dur = node.get("dur_us").as_f64().expect("dur_us") as i64;
    let self_us = node.get("self_us").as_f64().expect("self_us") as i64;
    let children = node.get("children").as_arr().unwrap_or(&[]);
    let child_sum: i64 =
        children.iter().map(|c| c.get("dur_us").as_f64().unwrap() as i64).sum();
    let name = node.get("name").as_str().unwrap_or("?");
    assert!(child_sum <= dur, "{name}: children ({child_sum} us) exceed span ({dur} us)");
    assert_eq!(self_us, dur - child_sum, "{name}: self time is total minus children");
    1 + children.iter().map(check_node).sum::<usize>()
}

#[test]
fn terminal_job_trace_nests_and_accounts_for_wall_time() {
    pogo::obs::set_enabled(Some(true));
    let (server, client) = start_server(None);
    let id = client.submit(&spec(52)).expect("submit");
    client.wait_result(id, WAIT).expect("result");

    let (code, _, body) = pogo::serve::http::request_full(
        &server.addr().to_string(),
        "GET",
        &format!("/v2/jobs/{id}/trace"),
        None,
        &[],
    )
    .expect("trace request");
    assert_eq!(code, 200, "{body}");
    let trace = Json::parse(&body).expect("trace JSON");
    assert_eq!(trace.get("id").as_f64(), Some(id as f64));
    assert_eq!(trace.get("state").as_str(), Some("done"));

    let roots = trace.get("spans").as_arr().expect("spans");
    assert_eq!(roots.len(), 1, "one job root: {body}");
    let job = &roots[0];
    assert_eq!(job.get("name").as_str(), Some("job"));
    let total_spans: usize = check_node(job);
    assert!(total_spans >= 3, "expected a real span tree, got {total_spans}: {body}");
    assert_eq!(total_spans, trace.get("span_count").as_usize().expect("span_count"));

    // The lifecycle segments under the root cover its wall time: admission
    // + queue wait + run account for the job span within 5%.
    let children = job.get("children").as_arr().expect("children");
    let seg = |name: &str| -> f64 {
        children
            .iter()
            .find(|c| c.get("name").as_str() == Some(name))
            .unwrap_or_else(|| panic!("missing {name} segment: {body}"))
            .get("dur_us")
            .as_f64()
            .unwrap()
    };
    let covered = seg("admit") + seg("queued") + seg("run");
    let wall = job.get("dur_us").as_f64().expect("job dur");
    assert!(
        (covered - wall).abs() <= 0.05 * wall.max(1.0),
        "admit+queued+run = {covered} us vs job = {wall} us"
    );

    // The run segment carries the engine-side detail (steps windows).
    let run = children.iter().find(|c| c.get("name").as_str() == Some("run")).unwrap();
    let run_children = run.get("children").as_arr().unwrap_or(&[]);
    assert!(
        run_children.iter().any(|c| c.get("name").as_str() == Some("steps")),
        "run should nest a steps span: {body}"
    );

    // Unknown ids answer 404, not an empty trace.
    let (code, _, _) = pogo::serve::http::request_full(
        &server.addr().to_string(),
        "GET",
        "/v2/jobs/999999/trace",
        None,
        &[],
    )
    .expect("trace request");
    assert_eq!(code, 404);
    server.shutdown();
}
