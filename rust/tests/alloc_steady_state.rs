//! Steady-state steps are allocation-free.
//!
//! A counting `#[global_allocator]` wraps the system allocator; after a
//! warm-up phase (pool threads spawned, scratch arenas first-touched,
//! host-side buffers grown to capacity) repeated `step_batch` calls must
//! execute without a single heap allocation on any thread.
//!
//! Workers claim shards dynamically, so a thread that sat out the warm-up
//! steps can first-touch its keyed scratch slot later — the measurement
//! therefore retries: the invariant is that SOME window of consecutive
//! steps allocates nothing, i.e. allocations stop once every participant
//! is warm, rather than that warm-up has a fixed length.
//!
//! This is an integration test (its own binary) so the global allocator
//! hook cannot interfere with the rest of the suite, and it holds exactly
//! one #[test] so no sibling test allocates concurrently.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

struct CountingAlloc;

/// Total allocation events (alloc + alloc_zeroed + realloc) on all threads.
static ALLOC_EVENTS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

use pogo::linalg::{BatchMat, Mat, Scalar};
use pogo::manifold::stiefel;
use pogo::optim::base::BaseOptKind;
use pogo::optim::batched::BatchedHost;
use pogo::optim::pogo::LambdaPolicy;
use pogo::optim::Orthoptimizer;
use pogo::rng::Rng;

fn make_packed<S: Scalar>(
    b: usize,
    p: usize,
    n: usize,
    rng: &mut Rng,
) -> (BatchMat<S>, BatchMat<S>) {
    let xs: Vec<Mat<S>> = (0..b).map(|_| stiefel::random_point_t::<S>(p, n, rng)).collect();
    let gs: Vec<Mat<S>> = (0..b)
        .map(|_| {
            let g = Mat::<S>::randn(p, n, rng);
            let nn = g.norm().to_f64().max(1e-6);
            g.scale(S::from_f64(0.2 / nn))
        })
        .collect();
    (BatchMat::from_mats(&xs), BatchMat::from_mats(&gs))
}

/// Drive `step` until a window of consecutive calls allocates nothing.
///
/// WINDOW steps with zero allocation events proves the steady state; up
/// to ATTEMPTS windows tolerate late first-touches (a pool worker that
/// claimed its first shard of this shape mid-measurement).
fn assert_settles(label: &str, mut step: impl FnMut()) {
    const WARMUP: usize = 8;
    const WINDOW: usize = 10;
    const ATTEMPTS: usize = 50;
    for _ in 0..WARMUP {
        step();
    }
    let mut last_delta = 0u64;
    for _ in 0..ATTEMPTS {
        let before = ALLOC_EVENTS.load(Ordering::Relaxed);
        for _ in 0..WINDOW {
            step();
        }
        last_delta = ALLOC_EVENTS.load(Ordering::Relaxed) - before;
        if last_delta == 0 {
            return;
        }
    }
    panic!(
        "{label}: still allocating after {ATTEMPTS} windows of {WINDOW} steps \
         ({last_delta} allocation events in the last window)"
    );
}

#[test]
fn steady_state_steps_do_not_allocate() {
    // Force the resident pool so the measurement covers worker wake +
    // claim + scratch reuse (spawn-per-call allocates by construction:
    // thread stacks). Serial cases below still go through the same entry
    // points and must be clean too.
    pogo::util::pool::set_pool_mode(Some(pogo::util::pool::PoolMode::Resident));
    pogo::util::pool::warm_pool();
    // Pin the POGO_OBS=off contract: with the flight recorder disabled the
    // step/dispatch hot paths must not even read the clock, let alone
    // allocate. (The obs-on window at the end covers the cached-handle
    // path separately.)
    pogo::obs::set_enabled(Some(false));
    let mut rng = Rng::seed_from_u64(7);

    {
        // Fused POGO, pool-engaged (12·B·p²·n ≈ 50M flops ≫ 2²⁰ threshold).
        let mut opt: BatchedHost<f32> =
            BatchedHost::pogo(0.05, LambdaPolicy::Half, BaseOptKind::Sgd);
        let (mut x, g) = make_packed::<f32>(1024, 16, 16, &mut rng);
        assert_settles("fused pogo-half f32 (16,16) B=1024", || {
            opt.step_batch(&mut x, &g).unwrap();
        });
    }

    {
        // FindRoot: per-matrix quartic solve through the slice-form
        // coefficient path + fixed-storage root finder. Below every
        // parallel threshold, so this pins the serial path as clean.
        let mut opt: BatchedHost<f64> =
            BatchedHost::pogo(0.05, LambdaPolicy::FindRoot, BaseOptKind::Sgd);
        let (mut x, g) = make_packed::<f64>(64, 3, 3, &mut rng);
        assert_settles("fused pogo-root f64 (3,3) B=64 serial", || {
            opt.step_batch(&mut x, &g).unwrap();
        });
    }

    {
        // Landing with a stateful base: momentum buffers must reach fixed
        // capacity during warm-up and then be updated strictly in place.
        let mut opt: BatchedHost<f32> =
            BatchedHost::landing(0.05, 1.0, BaseOptKind::momentum(0.9));
        let (mut x, g) = make_packed::<f32>(1024, 4, 8, &mut rng);
        assert_settles("fused landing f32 momentum (4,8) B=1024", || {
            opt.step_batch(&mut x, &g).unwrap();
        });
    }

    {
        // VAdam: second-moment scalars + transformed-gradient output
        // buffer are the largest per-step host allocations we hoisted.
        let mut opt: BatchedHost<f64> =
            BatchedHost::pogo(0.05, LambdaPolicy::Half, BaseOptKind::vadam());
        let (mut x, g) = make_packed::<f64>(512, 4, 8, &mut rng);
        assert_settles("fused pogo-half f64 vadam (4,8) B=512", || {
            opt.step_batch(&mut x, &g).unwrap();
        });
    }

    {
        // Obs ON: after the first step leaks its interned histogram handle
        // (covered by warm-up), recording is clock reads + atomic adds —
        // the enabled path must also settle to zero allocations.
        pogo::obs::set_enabled(Some(true));
        let mut opt: BatchedHost<f32> =
            BatchedHost::pogo(0.05, LambdaPolicy::Half, BaseOptKind::Sgd);
        let (mut x, g) = make_packed::<f32>(1024, 16, 16, &mut rng);
        assert_settles("fused pogo-half f32 (16,16) B=1024 obs-on", || {
            opt.step_batch(&mut x, &g).unwrap();
        });
    }

    pogo::obs::set_enabled(None);
    pogo::util::pool::set_pool_mode(None);
}
