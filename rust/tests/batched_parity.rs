//! Batched host engine ≡ per-matrix host loop, elementwise.
//!
//! `Engine::BatchedHost` promises the *same arithmetic in the same order*
//! as the sequential per-matrix engine — its kernels run the identical
//! serial row-range matmul code once per batch element. This suite pins
//! that contract for every batched rule (POGO under each base optimizer
//! and λ policy, Landing, LandingPC, SLPG, unconstrained Adam) across the
//! paper's shape regimes and several batch sizes, checks feasibility over
//! a long batched run, and exercises the `OptimizerSpec` round-trip +
//! coordinator path for the new engine.

use pogo::coordinator::{OptimSession, OptimizerSpec, ParamStore};
use pogo::linalg::{CMatF, Complex, Field, MatF};
use pogo::manifold::stiefel;
use pogo::optim::base::BaseOptKind;
use pogo::optim::pogo::LambdaPolicy;
use pogo::optim::{Engine, Method};
use pogo::rng::Rng;
use pogo::util::json::Json;

/// Shape regimes: the Fig. 1 3×3 kernels, a wide Stiefel block, and a
/// square attention-sized matrix. (The manifold convention is wide
/// row-orthogonal `p ≤ n`.)
const SHAPES: &[(usize, usize)] = &[(3, 3), (16, 64), (128, 128)];
const BATCHES: &[usize] = &[1, 7, 64];
const STEPS: usize = 3;

/// Max |batched − loop| across every matrix after stepping both engines
/// from identical state with identical gradients.
fn max_divergence(spec: &OptimizerSpec, p: usize, n: usize, b: usize, seed: u64) -> f32 {
    let mut rng = Rng::seed_from_u64(seed);
    let mut xs_loop: Vec<MatF> =
        (0..b).map(|_| stiefel::random_point(p, n, &mut rng)).collect();
    let mut xs_batched = xs_loop.clone();
    let grads: Vec<Vec<MatF>> = (0..STEPS)
        .map(|_| {
            (0..b)
                .map(|_| {
                    let g = MatF::randn(p, n, &mut rng);
                    let nn = g.norm();
                    g.scale(0.5 / nn)
                })
                .collect()
        })
        .collect();

    let mut opt_loop = spec
        .with_engine(Engine::Rust)
        .build::<f32>(None, (b, p, n))
        .expect("loop engine builds");
    let mut opt_batched = spec
        .with_engine(Engine::BatchedHost)
        .build::<f32>(None, (b, p, n))
        .expect("batched engine builds");
    assert!(!opt_loop.prefers_batch());
    assert!(opt_batched.prefers_batch());

    for gs in &grads {
        opt_loop.step_group(&mut xs_loop, gs).unwrap();
        opt_batched.step_group(&mut xs_batched, gs).unwrap();
    }
    let mut worst = 0.0f32;
    for (xl, xb) in xs_loop.iter().zip(&xs_batched) {
        assert!(xb.all_finite());
        worst = worst.max(xl.sub(xb).max_abs());
    }
    worst
}

/// Run the full (shape × batch) grid for one spec. The (128, 128) shape
/// is capped at B = 7 to keep debug-mode test time bounded; the large-B
/// coverage comes from the smaller shapes.
fn assert_parity(spec: OptimizerSpec) {
    for &(p, n) in SHAPES {
        for &b in BATCHES {
            if p >= 128 && b > 7 {
                continue;
            }
            let d = max_divergence(&spec, p, n, b, (p * 1000 + n * 10 + b) as u64);
            assert!(
                d <= 1e-5,
                "{} diverged by {d} at ({p}, {n}) B={b}",
                spec.label()
            );
        }
    }
}

#[test]
fn pogo_sgd_parity() {
    assert_parity(OptimizerSpec::new(Method::Pogo, 0.1));
}

#[test]
fn pogo_momentum_parity() {
    assert_parity(OptimizerSpec::new(Method::Pogo, 0.1).with_base(BaseOptKind::momentum(0.9)));
}

#[test]
fn pogo_vadam_parity() {
    // Exercises the batched per-matrix scalar second-moment state.
    assert_parity(OptimizerSpec::new(Method::Pogo, 0.2).with_base(BaseOptKind::vadam()));
}

#[test]
fn pogo_find_root_parity() {
    // Per-matrix quartic roots from the batched gram residuals.
    assert_parity(OptimizerSpec::new(Method::Pogo, 0.1).with_lambda(LambdaPolicy::FindRoot));
}

#[test]
fn landing_parity() {
    assert_parity(
        OptimizerSpec::new(Method::Landing, 0.1).with_base(BaseOptKind::momentum(0.1)),
    );
}

#[test]
fn landing_pc_parity() {
    // Per-matrix gradient normalization path.
    assert_parity(OptimizerSpec::new(Method::LandingPC, 0.5).with_attraction(1.0));
}

#[test]
fn slpg_parity() {
    assert_parity(OptimizerSpec::new(Method::Slpg, 0.05));
}

#[test]
fn adam_parity() {
    // Batched elementwise Adam state (first + second moments).
    assert_parity(OptimizerSpec::new(Method::Adam, 0.01));
}

// ---------------------------------------------------------------------------
// Complex (unitary) parity: the SAME batched engine at E = Complex<f32>.
// ---------------------------------------------------------------------------

/// Complex shape regimes: a Born-core-sized block (see
/// `experiments::born::core_shapes`), a tiny square unitary, and a wide
/// isometry.
const C_SHAPES: &[(usize, usize)] = &[(2, 2), (8, 16), (4, 8)];

/// Max elementwise |batched − loop| (Frobenius, per matrix) after
/// stepping both unitary engines from identical state with identical
/// complex gradients.
fn max_divergence_c(spec: &OptimizerSpec, p: usize, n: usize, b: usize, seed: u64) -> f64 {
    let mut rng = Rng::seed_from_u64(seed);
    let mut xs_loop: Vec<CMatF> =
        (0..b).map(|_| stiefel::random_point_complex::<f32>(p, n, &mut rng)).collect();
    let mut xs_batched = xs_loop.clone();
    let grads: Vec<Vec<CMatF>> = (0..STEPS)
        .map(|_| {
            (0..b)
                .map(|_| {
                    let g = CMatF::randn(p, n, &mut rng);
                    let nn = g.norm();
                    g.scale(Complex::from_f64(0.5 / nn as f64))
                })
                .collect()
        })
        .collect();

    let mut opt_loop = spec
        .with_engine(Engine::Rust)
        .build_unitary::<f32>(b)
        .expect("unitary loop engine builds");
    let mut opt_batched = spec
        .with_engine(Engine::BatchedHost)
        .build_unitary::<f32>(b)
        .expect("unitary batched engine builds");
    assert!(!opt_loop.prefers_batch());
    assert!(opt_batched.prefers_batch());

    for gs in &grads {
        opt_loop.step_group(&mut xs_loop, gs).unwrap();
        opt_batched.step_group(&mut xs_batched, gs).unwrap();
    }
    let mut worst = 0.0f64;
    for (xl, xb) in xs_loop.iter().zip(&xs_batched) {
        assert!(xb.all_finite());
        worst = worst.max(xl.sub(xb).norm() as f64);
    }
    worst
}

/// Run the complex (shape × batch) grid for one spec.
fn assert_parity_c(spec: OptimizerSpec) {
    for &(p, n) in C_SHAPES {
        for &b in BATCHES {
            let d = max_divergence_c(&spec, p, n, b, (p * 1000 + n * 10 + b) as u64);
            assert!(
                d <= 1e-5,
                "unitary {} diverged by {d} at ({p}, {n}) B={b}",
                spec.label()
            );
        }
    }
}

#[test]
fn unitary_pogo_sgd_parity() {
    assert_parity_c(OptimizerSpec::new(Method::Pogo, 0.1));
}

#[test]
fn unitary_pogo_vadam_parity() {
    // Batched per-matrix scalar second-moment state on the complex field.
    assert_parity_c(OptimizerSpec::new(Method::Pogo, 0.2).with_base(BaseOptKind::vadam()));
}

#[test]
fn unitary_pogo_find_root_parity() {
    // Per-matrix quartic roots from the batched Hermitian gram residuals
    // (the coefficients stay real on the complex field — §2 fn. 1).
    assert_parity_c(OptimizerSpec::new(Method::Pogo, 0.1).with_lambda(LambdaPolicy::FindRoot));
}

#[test]
fn unitary_landing_parity() {
    assert_parity_c(
        OptimizerSpec::new(Method::Landing, 0.1).with_base(BaseOptKind::momentum(0.1)),
    );
}

#[test]
fn unitary_landing_pc_parity() {
    assert_parity_c(OptimizerSpec::new(Method::LandingPC, 0.5).with_attraction(1.0));
}

#[test]
fn unitary_slpg_parity() {
    assert_parity_c(OptimizerSpec::new(Method::Slpg, 0.05));
}

#[test]
fn unitary_batched_orthogonality_over_100_steps() {
    // ‖X Xᴴ − I‖ ≤ 1e-3 for every core after 100 batched unitary steps
    // (Thm 3.5 regime: ‖G‖ = 0.5, η = 0.2 ⇒ ξ = 0.1).
    let (p, n, b) = (8, 16, 7);
    let spec = OptimizerSpec::new(Method::Pogo, 0.2)
        .with_base(BaseOptKind::vadam())
        .with_engine(Engine::BatchedHost);
    let mut rng = Rng::seed_from_u64(43);
    let mut xs: Vec<CMatF> =
        (0..b).map(|_| stiefel::random_point_complex::<f32>(p, n, &mut rng)).collect();
    let mut opt = spec.build_unitary::<f32>(b).unwrap();
    for _ in 0..100 {
        let gs: Vec<CMatF> = (0..b)
            .map(|_| {
                let g = CMatF::randn(p, n, &mut rng);
                let nn = g.norm();
                g.scale(Complex::from_f64(0.5 / nn as f64))
            })
            .collect();
        opt.step_group(&mut xs, &gs).unwrap();
    }
    for x in &xs {
        let d = stiefel::distance_complex(x);
        assert!(d <= 1e-3, "left the complex manifold: {d}");
    }
}

#[test]
fn spec_round_trips_complex_batched_host_engine() {
    // A "batched-host" spec builds the batched engine on BOTH domains
    // from the same JSON.
    let spec = OptimizerSpec::new(Method::Slpg, 0.05).with_engine(Engine::BatchedHost);
    let text = spec.to_json().to_string();
    assert!(text.contains("batched-host"), "{text}");
    let back = OptimizerSpec::from_json(&Json::parse(&text).unwrap()).unwrap();
    assert_eq!(back, spec);
    assert!(back.build::<f32>(None, (4, 3, 3)).unwrap().prefers_batch());
    assert!(back.build_unitary::<f32>(4).unwrap().prefers_batch());
}

#[test]
fn batched_orthogonality_over_100_steps() {
    // Feasibility at the seed tolerance after a long batched run (Thm 3.5
    // regime: ‖G‖ = 0.5, η = 0.2 ⇒ ξ = 0.1).
    let (p, n, b) = (16, 64, 7);
    let spec = OptimizerSpec::new(Method::Pogo, 0.2)
        .with_base(BaseOptKind::vadam())
        .with_engine(Engine::BatchedHost);
    let mut rng = Rng::seed_from_u64(42);
    let mut xs: Vec<MatF> = (0..b).map(|_| stiefel::random_point(p, n, &mut rng)).collect();
    let mut opt = spec.build::<f32>(None, (b, p, n)).unwrap();
    for _ in 0..100 {
        let gs: Vec<MatF> = (0..b)
            .map(|_| {
                let g = MatF::randn(p, n, &mut rng);
                let nn = g.norm();
                g.scale(0.5 / nn)
            })
            .collect();
        opt.step_group(&mut xs, &gs).unwrap();
    }
    for x in &xs {
        let d = stiefel::distance(x);
        assert!(d <= 1e-3, "left the manifold: {d}");
    }
}

#[test]
fn spec_round_trips_batched_host_engine() {
    // `pogo run --spec` input format: engine "batched-host" survives the
    // JSON round-trip byte-identically and builds the batched engine.
    let spec = OptimizerSpec::new(Method::Pogo, 0.1)
        .with_base(BaseOptKind::vadam())
        .with_engine(Engine::BatchedHost);
    let text = spec.to_json().to_string();
    assert!(text.contains("batched-host"), "{text}");
    let back = OptimizerSpec::from_json(&Json::parse(&text).unwrap()).unwrap();
    assert_eq!(back, spec);
    assert_eq!(back.to_json().to_string(), text, "byte-identical reserialization");
    assert_eq!(back.label(), "POGO[batched]");
    let opt = back.build::<f32>(None, (4, 3, 3)).unwrap();
    assert!(opt.prefers_batch());
    // The manifest-file path too (what --spec actually reads).
    let dir = std::env::temp_dir().join(format!("pogo_batched_spec_{}", std::process::id()));
    let path = dir.join("run.spec.json");
    spec.write_json_file(&path).unwrap();
    assert_eq!(OptimizerSpec::from_json_file(&path).unwrap(), spec);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn retraction_methods_refuse_batched_engine() {
    for m in [Method::Rgd, Method::Rsdm] {
        let spec = OptimizerSpec::new(m, 0.1).with_engine(Engine::BatchedHost);
        let err = spec.build::<f32>(None, (4, 3, 3)).unwrap_err();
        assert!(format!("{err:#}").contains("no batched host engine"), "{err:#}");
    }
}

#[test]
fn session_batched_path_matches_loop_path() {
    // End-to-end through the coordinator: OptimSession extracts a
    // (B, p, n) BatchMat for the batched engine and a Vec<Mat> for the
    // loop engine; trajectories must agree elementwise across a store
    // with several shape groups.
    let mut rng = Rng::seed_from_u64(7);
    let mut store_loop = ParamStore::new();
    store_loop.add_stiefel_group("kernels", 9, 3, 3, &mut rng);
    store_loop.add_stiefel_group("wide", 3, 8, 24, &mut rng);
    store_loop.add_free("head", MatF::zeros(4, 4));
    let mut store_batched = store_loop.clone();

    let spec = OptimizerSpec::new(Method::Pogo, 0.05).with_base(BaseOptKind::vadam());
    let mut s_loop = OptimSession::new(&spec, &store_loop, None).unwrap();
    let mut s_batched = OptimSession::new(
        &spec.with_engine(Engine::BatchedHost),
        &store_batched,
        None,
    )
    .unwrap();

    for step in 0..5u64 {
        let mut rng = Rng::seed_from_u64(1000 + step);
        let grads: Vec<MatF> = store_loop
            .params()
            .iter()
            .map(|p| MatF::randn(p.mat.rows(), p.mat.cols(), &mut rng).scale(0.1))
            .collect();
        s_loop.apply(&mut store_loop, &grads).unwrap();
        s_batched.apply(&mut store_batched, &grads).unwrap();
    }
    for i in 0..store_loop.len() {
        let d = store_loop.mat(i).sub(store_batched.mat(i)).max_abs();
        assert!(d <= 1e-5, "param {i} diverged by {d}");
    }
    // Free parameter untouched by the constrained session on both paths.
    assert_eq!(store_loop.mat(12).norm_sq(), 0.0);
    assert_eq!(store_batched.mat(12).norm_sq(), 0.0);
}
