//! End-to-end tests for `pogo front`: a real federated front door over
//! real `pogo serve` backends, all on ephemeral loopback ports.
//!
//! The headline contracts:
//! - a job submitted **through the front** lands bit-identically to a
//!   direct `run_job` of the same spec (the federation adds routing, not
//!   numerics), with the SSE stream relayed intact;
//! - placement is deterministic: a second front replica that never saw
//!   the submission answers for the job via the hash ring;
//! - per-tenant quotas hold **globally** across shards;
//! - a killed backend's queued jobs re-list onto a survivor and
//!   complete, with the re-list visible in `/metrics`;
//! - spilled results survive a backend restart and re-read through a
//!   restarted front, byte-for-byte.

use pogo::coordinator::OptimizerSpec;
use pogo::federate::{Front, FrontAdmission, FrontConfig};
use pogo::optim::{Engine, Method};
use pogo::serve::{
    run_job, JobDomain, JobOutcome, JobSpec, ProblemKind, RunCtl, ServeClient, ServeConfig,
    Server,
};
use std::time::Duration;

const WAIT: Duration = Duration::from_secs(120);

fn start_backend(workers: usize, state_dir: Option<std::path::PathBuf>) -> Server {
    Server::start(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        workers,
        capacity: 64,
        state_dir,
    })
    .expect("backend should bind an ephemeral port")
}

/// A front over `backends` with manual probing: the interval is parked
/// at an hour so tests drive node-state transitions deterministically
/// via `probe_now()`.
fn start_front(backends: Vec<String>, admission: FrontAdmission) -> Front {
    Front::start(FrontConfig {
        addr: "127.0.0.1:0".to_string(),
        backends,
        probe_interval: Duration::from_secs(3600),
        fail_after: 2,
        admission,
        state_dir: None,
    })
    .expect("front should bind an ephemeral port")
}

fn spec(problem: ProblemKind, seed: u64) -> JobSpec {
    let mut s = JobSpec::new(problem, 4, 3, 6);
    s.name = format!("front-{}-{seed}", problem.name());
    s.steps = 40;
    s.seed = seed;
    s.optimizer = OptimizerSpec::new(Method::Pogo, 0.05).with_engine(Engine::Rust);
    s
}

fn counter(metrics: &str, name: &str) -> f64 {
    metrics
        .lines()
        .find(|l| l.starts_with(name))
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|v| v.parse::<f64>().ok())
        .unwrap_or_else(|| panic!("{name} missing from:\n{metrics}"))
}

/// Jobs through the front behave exactly like jobs against a backend:
/// SSE streams relay with monotone steps to the terminal state, and the
/// result is bit-identical to a direct in-process `run_job`.
#[test]
fn jobs_through_the_front_match_direct_runs_bit_for_bit() {
    let b1 = start_backend(2, None);
    let b2 = start_backend(2, None);
    let front =
        start_front(vec![b1.addr().to_string(), b2.addr().to_string()], FrontAdmission::default());
    let client = ServeClient::new(front.addr().to_string());

    for seed in [101u64, 102, 103, 104] {
        let job = spec(ProblemKind::Procrustes, seed);
        let id = client.submit_v2(&job).expect("submit through front");
        // Follow the relayed SSE stream to its terminal state.
        let mut steps: Vec<usize> = Vec::new();
        let terminal = client
            .stream_events(id, WAIT, |s| {
                assert!(s.loss.is_finite());
                steps.push(s.step);
                true
            })
            .expect("relayed SSE stream");
        assert_eq!(terminal, "done");
        assert!(steps.windows(2).all(|w| w[0] < w[1]), "steps monotone: {steps:?}");
        assert_eq!(*steps.last().unwrap(), job.steps);

        let result = client.result_v2(id).expect("result through front");
        let JobOutcome::Done(direct) = run_job(&job, &RunCtl::default()).expect("direct run")
        else {
            panic!("direct run not done")
        };
        assert_eq!(
            result.get("final_loss").as_f64().unwrap().to_bits(),
            direct.final_loss.to_bits(),
            "seed {seed}: the front changed the numbers"
        );
        assert_eq!(result.get("series").as_arr().unwrap().len(), job.steps);
    }

    // The front's own surfaces are live: healthz names the role, metrics
    // carries the per-backend gauges, /front/nodes lists both nodes up.
    let (code, _, body) =
        pogo::serve::http::request_full(&front.addr().to_string(), "GET", "/healthz", None, &[])
            .unwrap();
    assert_eq!(code, 200);
    let health = pogo::util::json::Json::parse(&body).unwrap();
    assert_eq!(health.get("role").as_str(), Some("front"));
    assert_eq!(health.get("backends_up").as_usize(), Some(2));
    let metrics = client.metrics().expect("front metrics");
    assert!(metrics.contains(&format!("pogo_front_backend_up{{backend=\"{}\"}} 1", b1.addr())));
    assert!(metrics.contains(&format!("pogo_front_backend_up{{backend=\"{}\"}} 1", b2.addr())));
    assert_eq!(counter(&metrics, "pogo_front_jobs_submitted_total"), 4.0);

    front.shutdown();
    b1.shutdown();
    b2.shutdown();
}

/// Placement is a pure function of (node set, job id): a second front
/// replica that never saw the submission resolves the same owner through
/// the hash ring and serves reads for it.
#[test]
fn any_front_replica_answers_for_any_job() {
    let b1 = start_backend(2, None);
    let b2 = start_backend(2, None);
    let backends = vec![b1.addr().to_string(), b2.addr().to_string()];
    let front_a = start_front(backends.clone(), FrontAdmission::default());
    // Replica B sees the same node set in a different order — rendezvous
    // hashing is node-order-free.
    let front_b = start_front(
        backends.iter().rev().cloned().collect(),
        FrontAdmission::default(),
    );

    let job = spec(ProblemKind::Pca, 201);
    let client_a = ServeClient::new(front_a.addr().to_string());
    let client_b = ServeClient::new(front_b.addr().to_string());

    // Submit through A; the receipt names the backend A placed on.
    let (code, headers, body) = pogo::serve::http::request_full(
        &front_a.addr().to_string(),
        "POST",
        "/v2/jobs",
        Some(&job.to_json().to_string()),
        &[],
    )
    .unwrap();
    assert_eq!(code, 202, "{body}");
    let placed_on = headers
        .iter()
        .find(|(k, _)| k.eq_ignore_ascii_case("x-pogo-backend"))
        .map(|(_, v)| v.clone())
        .expect("submit receipt names the placement");
    let id = pogo::util::json::Json::parse(&body).unwrap().get("id").as_usize().unwrap() as u64;

    // The ring agrees with the receipt, from either replica's node list.
    assert_eq!(pogo::federate::ring::owner(&backends, id), Some(placed_on.as_str()));

    // Replica B — which never saw the submission — serves the job.
    let status = client_b.wait_terminal_v2(id, WAIT).expect("status via replica B");
    assert_eq!(status.get("state").as_str(), Some("done"));
    let via_b = client_b.result_v2(id).expect("result via replica B");
    let via_a = client_a.result_v2(id).expect("result via replica A");
    assert_eq!(
        via_a.get("final_loss").as_f64().unwrap().to_bits(),
        via_b.get("final_loss").as_f64().unwrap().to_bits()
    );

    front_a.shutdown();
    front_b.shutdown();
    b1.shutdown();
    b2.shutdown();
}

/// The global half of split admission: a tenant quota of 2 holds across
/// both shards — the third submission 429s at the front with a
/// `Retry-After`, even though each backend individually has room.
#[test]
fn tenant_quota_is_enforced_globally_across_shards() {
    let b1 = start_backend(1, None);
    let b2 = start_backend(1, None);
    let front = start_front(
        vec![b1.addr().to_string(), b2.addr().to_string()],
        FrontAdmission { tenant_quota: 2, cost_cap: 0 },
    );
    let addr = front.addr().to_string();
    let alice = ServeClient::new(addr.clone()).with_api_key("alice");

    let mut long = spec(ProblemKind::Replay, 301);
    long.steps = 500_000;
    let id_a = alice.submit_v2(&long).expect("first");
    let id_b = alice.submit_v2(&long).expect("second");

    // Third submission: refused at the front door, before any backend.
    let (code, headers, body) = pogo::serve::http::request_full(
        &addr,
        "POST",
        "/v2/jobs",
        Some(&long.to_json().to_string()),
        &[("X-Api-Key", "alice")],
    )
    .unwrap();
    assert_eq!(code, 429, "{body}");
    assert!(body.contains("federation"), "{body}");
    let retry_after = headers
        .iter()
        .find(|(k, _)| k.eq_ignore_ascii_case("retry-after"))
        .and_then(|(_, v)| v.parse::<u64>().ok())
        .expect("429 carries Retry-After");
    assert!(retry_after >= 1);

    // A different tenant is unaffected.
    let bob = ServeClient::new(addr.clone()).with_api_key("bob");
    let ok = bob.submit_v2(&spec(ProblemKind::Quartic, 302)).expect("bob submits");
    bob.wait_terminal_v2(ok, WAIT).expect("bob's job terminal");

    // The refusals are counted, and cancelling frees the slots globally.
    let metrics = alice.metrics().expect("metrics");
    assert!(
        metrics.contains("pogo_front_admission_rejected_total{cause=\"quota\"} 1"),
        "{metrics}"
    );
    alice.cancel_v2(id_a).expect("cancel a");
    alice.cancel_v2(id_b).expect("cancel b");
    let id_c = alice.submit_v2(&spec(ProblemKind::Quartic, 303)).expect("after release");
    alice.wait_terminal_v2(id_c, WAIT).expect("terminal");

    front.shutdown();
    b1.shutdown();
    b2.shutdown();
}

/// The failover proof: two backends, the owner of a queued job killed
/// (listener closed mid-queue, the exact crash shape of `kill -9`), the
/// job re-listed onto the survivor with its original id pinned, and the
/// result through the front bit-identical to a direct run. The front's
/// `/metrics` counts the re-list and drops the dead node's gauge to 0.
#[test]
fn killed_backend_jobs_relist_onto_the_survivor_and_complete() {
    let b1 = start_backend(1, None);
    let b2 = start_backend(1, None);
    let addr1 = b1.addr().to_string();
    let addr2 = b2.addr().to_string();
    let front =
        start_front(vec![addr1.clone(), addr2.clone()], FrontAdmission::default());
    let front_addr = front.addr().to_string();
    let client = ServeClient::new(front_addr.clone());

    // Pin both single-worker backends with direct (non-federated)
    // blocker jobs so anything placed through the front queues. The
    // blockers also hold each backend's local id 1, forcing the front's
    // id-collision (409) retry path on submit.
    let mut blocker = spec(ProblemKind::Replay, 900);
    blocker.steps = 5_000_000;
    let direct1 = ServeClient::new(addr1.clone());
    let direct2 = ServeClient::new(addr2.clone());
    let blocker1 = direct1.submit_v2(&blocker).expect("blocker on b1");
    let blocker2 = direct2.submit_v2(&blocker).expect("blocker on b2");

    let victim = spec(ProblemKind::Procrustes, 901);
    let id = client.submit_v2(&victim).expect("victim through front");
    let status = client.status_v2(id).expect("victim status");
    assert_eq!(status.get("state").as_str(), Some("queued"), "victim should be waiting");

    // Kill the victim's owner: dropping the Server closes its listener
    // at once (drain only begins; the queued victim is never claimed).
    let owner = pogo::federate::ring::owner(&[addr1.clone(), addr2.clone()], id)
        .expect("two nodes up")
        .to_string();
    let (survivor_client, survivor_blocker, survivor_addr) = if owner == addr1 {
        drop(b1);
        (direct2, blocker2, addr2.clone())
    } else {
        drop(b2);
        (direct1, blocker1, addr1.clone())
    };
    // Free the survivor's worker so the re-listed victim can run.
    survivor_client.cancel_v2(survivor_blocker).expect("cancel survivor blocker");

    // Two failed probes mark the owner Down and re-list its jobs.
    front.probe_now();
    front.probe_now();

    let metrics = client.metrics().expect("front metrics");
    assert!(
        metrics.contains(&format!("pogo_front_backend_up{{backend=\"{owner}\"}} 0")),
        "{metrics}"
    );
    assert!(
        metrics.contains(&format!("pogo_front_backend_up{{backend=\"{survivor_addr}\"}} 1")),
        "{metrics}"
    );
    assert!(counter(&metrics, "pogo_front_relists_total") >= 1.0, "{metrics}");

    // The job completes on the survivor, reachable under its original id
    // through the front, flagged as resubmitted, and bit-identical to a
    // direct run of the same spec.
    let result = client.wait_result_v2(id, WAIT).expect("failover result");
    let JobOutcome::Done(direct) = run_job(&victim, &RunCtl::default()).expect("direct run")
    else {
        panic!("direct run not done")
    };
    assert_eq!(
        result.get("final_loss").as_f64().unwrap().to_bits(),
        direct.final_loss.to_bits(),
        "failover changed the numbers"
    );
    let (code, headers, _) = pogo::serve::http::request_full(
        &front_addr,
        "GET",
        &format!("/v2/jobs/{id}"),
        None,
        &[],
    )
    .unwrap();
    assert_eq!(code, 200);
    assert!(
        headers
            .iter()
            .any(|(k, v)| k.eq_ignore_ascii_case("x-pogo-resubmitted") && v == "1"),
        "{headers:?}"
    );

    front.shutdown();
    // The survivor still holds a worker slot; shut it down gracefully.
    survivor_client.cancel_v2(id).ok();
}

/// Durability: results spilled to a backend's `--state-dir` survive a
/// full backend restart (on a new port) *and* a front restart — the
/// restarted front re-reads the same series byte-for-byte through its
/// persisted placement table plus ring fallback.
#[test]
fn spilled_results_survive_backend_and_front_restarts() {
    let base = std::env::temp_dir().join(format!("pogo_federate_e2e_{}", std::process::id()));
    let backend_dir = base.join("backend");
    let front_dir = base.join("front");
    std::fs::remove_dir_all(&base).ok();
    std::fs::create_dir_all(&backend_dir).unwrap();
    std::fs::create_dir_all(&front_dir).unwrap();

    let job = spec(ProblemKind::Procrustes, 401);
    let (id, series_before) = {
        let backend = start_backend(2, Some(backend_dir.clone()));
        let front = Front::start(FrontConfig {
            addr: "127.0.0.1:0".to_string(),
            backends: vec![backend.addr().to_string()],
            probe_interval: Duration::from_secs(3600),
            fail_after: 2,
            admission: FrontAdmission::default(),
            state_dir: Some(front_dir.clone()),
        })
        .expect("front");
        let client = ServeClient::new(front.addr().to_string());
        let id = client.submit_v2(&job).expect("submit");
        let result = client.wait_result_v2(id, WAIT).expect("result");
        let series = result.get("series").clone();
        front.shutdown();
        backend.shutdown();
        (id, series)
    };
    assert_eq!(series_before.as_arr().unwrap().len(), job.steps);

    // Everything restarts: the backend on a NEW port (recovering its
    // spilled series), the front from its persisted placement table.
    let backend = start_backend(2, Some(backend_dir.clone()));
    let front = Front::start(FrontConfig {
        addr: "127.0.0.1:0".to_string(),
        backends: vec![backend.addr().to_string()],
        probe_interval: Duration::from_secs(3600),
        fail_after: 2,
        admission: FrontAdmission::default(),
        state_dir: Some(front_dir.clone()),
    })
    .expect("restarted front");
    let client = ServeClient::new(front.addr().to_string());

    let result = client.result_v2(id).expect("re-read spilled result through front");
    assert_eq!(result.get("state").as_str(), Some("done"));
    assert_eq!(
        result.get("series").to_string(),
        series_before.to_string(),
        "spilled series must re-read byte-for-byte"
    );
    // A restarted front also keeps allocating ids above what it placed.
    let fresh = client.submit_v2(&spec(ProblemKind::Quartic, 402)).expect("fresh submit");
    assert!(fresh > id);
    client.wait_terminal_v2(fresh, WAIT).expect("fresh job");

    front.shutdown();
    backend.shutdown();
    std::fs::remove_dir_all(&base).ok();
}
