//! The redesigned optimizer API, end to end:
//! - `OptimizerSpec` JSON round-trip (every field, every `Method`),
//!   including the on-disk `*.spec.json` manifest format;
//! - scalar-generic `build::<f64>()` parity with the legacy
//!   direct-construction path the precision ablation used;
//! - fallible stepping: a missing-artifact XLA spec surfaces an error
//!   through `build`/`Trainer::new`, and engine errors inside
//!   `step_group` propagate through `OptimSession`/`Trainer::step`
//!   instead of panicking.

use pogo::config::{spec_for, ExperimentId};
use pogo::coordinator::{
    OptimSession, OptimizerSpec, ParamStore, Trainer, TrainerConfig,
};
use pogo::linalg::{Mat, MatD};
use pogo::manifold::stiefel;
use pogo::optim::base::BaseOptKind;
use pogo::optim::landing::{Landing, LandingConfig};
use pogo::optim::pogo::{LambdaPolicy, Pogo, PogoConfig};
use pogo::optim::rgd::{Rgd, RgdConfig};
use pogo::optim::rsdm::{Rsdm, RsdmConfig};
use pogo::optim::{Engine, Method, Orthoptimizer};
use pogo::rng::Rng;
use pogo::util::json::Json;

// ---------------------------------------------------------------------------
// JSON round-trip
// ---------------------------------------------------------------------------

/// A spec exercising every non-default field for `method`. The seed is
/// deliberately > 2^53 to prove u64 seeds survive JSON exactly.
fn full_spec(method: Method) -> OptimizerSpec {
    OptimizerSpec::new(method, 0.125)
        .with_base(BaseOptKind::momentum(0.35))
        .with_lambda(LambdaPolicy::FindRoot)
        .with_attraction(2.5)
        .with_submanifold(17)
        .with_seed(u64::MAX - 12345)
        .with_engine(Engine::Xla)
}

#[test]
fn spec_json_roundtrip_every_method_every_field() {
    for &m in Method::all() {
        for spec in [OptimizerSpec::new(m, 0.05), full_spec(m)] {
            let text = spec.to_json().to_string();
            let back = OptimizerSpec::from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back, spec, "{text}");
            // Byte-identical reserialization (BTreeMap keys ⇒ stable order).
            assert_eq!(back.to_json().to_string(), text);
            // Pretty form parses to the same spec too.
            let pretty = spec.to_json_string();
            let back2 =
                OptimizerSpec::from_json(&Json::parse(&pretty).unwrap()).unwrap();
            assert_eq!(back2, spec);
        }
    }
}

#[test]
fn spec_manifest_file_roundtrip() {
    let dir = std::env::temp_dir().join(format!("pogo_spec_api_{}", std::process::id()));
    let path = dir.join("run.spec.json");
    let spec = full_spec(Method::Rsdm);
    spec.write_json_file(&path).unwrap();
    let back = OptimizerSpec::from_json_file(&path).unwrap();
    assert_eq!(back, spec);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn spec_json_rejects_garbage() {
    for text in [
        r#"{}"#,
        r#"{"method": "warp-drive", "lr": 0.1}"#,
        r#"{"method": "pogo"}"#,
        r#"{"method": "pogo", "lr": 0.1, "engine": "tpu"}"#,
        r#"{"method": "pogo", "lr": 0.1, "lambda": "third"}"#,
        // Present-but-malformed fields must error, not silently default.
        r#"{"method": "pogo", "lr": "fast"}"#,
        r#"{"method": "pogo", "lr": 0.1, "attraction": "0.1"}"#,
        r#"{"method": "pogo", "lr": 0.1, "lambda": 3}"#,
        r#"{"method": "pogo", "lr": 0.1, "submanifold_dim": 17.5}"#,
        r#"{"method": "pogo", "lr": 0.1, "seed": -1}"#,
        r#"{"method": "pogo", "lr": 0.1, "seed": 2.5}"#,
        r#"{"method": "pogo", "lr": 0.1, "seed": "not-a-number"}"#,
        r#"{"method": "pogo", "lr": 0.1, "engine": 2}"#,
        r#"{"method": "pogo", "lr": 0.1, "base": {"kind": "momentum", "beta": "x"}}"#,
    ] {
        let j = Json::parse(text).unwrap();
        assert!(OptimizerSpec::from_json(&j).is_err(), "{text}");
    }
}

#[test]
fn spec_seed_accepts_numeric_and_string_forms() {
    // Small numeric seeds (hand-written manifests) parse fine…
    let j = Json::parse(r#"{"method": "pogo", "lr": 0.1, "seed": 42}"#).unwrap();
    assert_eq!(OptimizerSpec::from_json(&j).unwrap().seed, 42);
    // …and the string form carries full u64 range exactly.
    let spec = OptimizerSpec::new(Method::Pogo, 0.1).with_seed(u64::MAX);
    let back =
        OptimizerSpec::from_json(&Json::parse(&spec.to_json().to_string()).unwrap())
            .unwrap();
    assert_eq!(back.seed, u64::MAX);
}

// ---------------------------------------------------------------------------
// Generic build::<f64> parity with the legacy precision.rs constructors
// ---------------------------------------------------------------------------

/// The legacy `precision.rs::build_opt` construction, reproduced verbatim
/// so the registry path can be checked against it.
fn legacy_build_f64(spec: &OptimizerSpec) -> Box<dyn Orthoptimizer<f64>> {
    match spec.method {
        Method::Pogo => Box::new(Pogo::<f64>::new(
            PogoConfig { lr: spec.lr, base: spec.base, ..Default::default() },
            1,
        )),
        Method::Landing => Box::new(Landing::<f64>::new(
            LandingConfig { lr: spec.lr, base: spec.base, ..Default::default() },
            1,
        )),
        Method::Rgd => {
            Box::new(Rgd::<f64>::new(RgdConfig { lr: spec.lr, base: BaseOptKind::Sgd }, 1))
        }
        Method::Rsdm => Box::new(Rsdm::<f64>::new(
            RsdmConfig {
                lr: spec.lr,
                submanifold_dim: spec.submanifold_dim,
                base: BaseOptKind::Sgd,
                seed: spec.seed,
                ..Default::default()
            },
            1,
        )),
        _ => unreachable!("precision ablation lineup"),
    }
}

#[test]
fn generic_f64_build_matches_legacy_precision_path() {
    // The FigC1 lineup at its paper presets — exactly what precision.rs
    // used to hand-construct.
    for method in [Method::Pogo, Method::Landing, Method::Rgd, Method::Rsdm] {
        let spec = spec_for(ExperimentId::FigC1Precision, method);
        let mut new_opt = spec.build::<f64>(None, (1, 8, 14)).unwrap();
        let mut old_opt = legacy_build_f64(&spec);

        let mut rng = Rng::seed_from_u64(7);
        let mut x_new = stiefel::random_point_t::<f64>(8, 14, &mut rng);
        let mut x_old = x_new.clone();
        let grads: Vec<MatD> = (0..5).map(|_| MatD::randn(8, 14, &mut rng)).collect();
        for g in &grads {
            new_opt.step(0, &mut x_new, g).unwrap();
            old_opt.step(0, &mut x_old, g).unwrap();
        }
        let diff = x_new.sub(&x_old).max_abs();
        assert!(
            diff <= 1e-12,
            "{}: registry vs legacy trajectories diverged by {diff}",
            method.name()
        );
    }
}

// ---------------------------------------------------------------------------
// Fallible stepping / error propagation
// ---------------------------------------------------------------------------

/// Registry with a valid (but empty) manifest: every artifact is missing.
/// `tag` keeps concurrently-running tests in separate directories.
fn empty_registry(tag: &str) -> (std::path::PathBuf, pogo::runtime::Registry) {
    let dir = std::env::temp_dir()
        .join(format!("pogo_empty_artifacts_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), r#"{"entries": {}}"#).unwrap();
    let reg = pogo::runtime::Registry::open(&dir).unwrap();
    (dir, reg)
}

#[test]
fn missing_artifact_xla_spec_errors_instead_of_panicking() {
    let (dir, reg) = empty_registry("missing");
    let spec = OptimizerSpec::new(Method::Pogo, 0.1).with_engine(Engine::Xla);

    // Direct build: the missing step artifact is a clean error that names
    // the artifact problem.
    let err = spec.build::<f32>(Some(&reg), (4, 8, 16)).unwrap_err();
    let text = format!("{err:#}");
    assert!(text.contains("rebuild artifacts") || text.contains("no artifact"), "{text}");

    // Through the Trainer: same error, still no panic.
    let mut rng = Rng::seed_from_u64(0);
    let mut store = ParamStore::new();
    store.add_stiefel_group("x", 4, 8, 16, &mut rng);
    let result = Trainer::new(store, spec, Some(&reg), TrainerConfig::default());
    assert!(result.is_err());

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn xla_engine_rejects_non_f32_scalars() {
    let (dir, reg) = empty_registry("scalar");
    let spec = OptimizerSpec::new(Method::Rgd, 0.1).with_engine(Engine::Xla);
    // RGD has no XLA engine at all — the step-kind gate fires first.
    let err = spec.build::<f64>(Some(&reg), (1, 4, 8)).unwrap_err();
    assert!(format!("{err}").contains("no XLA engine"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

/// A stepper whose engine fails on the second group dispatch.
struct FlakyStepper {
    calls: usize,
}

impl Orthoptimizer<f32> for FlakyStepper {
    fn step(&mut self, _idx: usize, _x: &mut Mat<f32>, _g: &Mat<f32>) -> anyhow::Result<()> {
        self.calls += 1;
        if self.calls > 4 {
            anyhow::bail!("simulated dispatch failure at call {}", self.calls);
        }
        Ok(())
    }
    fn name(&self) -> &str {
        "flaky"
    }
    fn lr(&self) -> f64 {
        0.1
    }
    fn set_lr(&mut self, _lr: f64) {}
}

#[test]
fn step_group_errors_propagate_to_trainer() {
    let mut rng = Rng::seed_from_u64(1);
    let mut store = ParamStore::new();
    store.add_stiefel_group("x", 4, 4, 8, &mut rng);
    let groups = store.stiefel_groups();
    let session =
        OptimSession::from_parts("flaky", groups, vec![Box::new(FlakyStepper { calls: 0 })])
            .unwrap();
    let mut tr = Trainer::with_session(
        store,
        session,
        TrainerConfig { max_steps: 10, ..Default::default() },
    );
    let mut src = |store: &ParamStore| {
        let grads: Vec<_> =
            store.params().iter().map(|p| p.mat.scale(0.0)).collect();
        Ok((1.0, grads))
    };
    // First step: 4 sub-steps succeed. Second step: the 5th call fails and
    // the error must reach the caller as a Result, not a panic.
    assert!(tr.step(&mut src).is_ok());
    let err = tr.step(&mut src).unwrap_err();
    let text = format!("{err:#}");
    assert!(text.contains("simulated dispatch failure"), "{text}");
    assert!(text.contains("stepping group"), "{text}");
}
