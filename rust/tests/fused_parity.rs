//! Fused single-pass step kernels ≡ the 5-pass naive composition,
//! elementwise.
//!
//! The fused `StepKernel` path promises the *same arithmetic in the same
//! order* as the naive batched composition — every product, axpy, and
//! scalar is constructed identically, so the comparison below is EXACT
//! (`== 0.0`), not a tolerance check. The grid covers every fused rule
//! (POGO under both λ policies, Landing, LandingPC) across shapes, batch
//! sizes, and element types (f32, f64, complex), plus a long-run
//! feasibility gate on the fused path and a direct portable-kernel case.
//!
//! The same binary re-runs under `POGO_STEP_KERNEL=portable` in CI (the
//! forced-scalar-fallback leg), pinning that kernel selection never
//! changes results.

use pogo::linalg::{
    BatchMat, Complex, Field, KernelChoice, Mat, PogoLambda, Scalar, StepScratch, PORTABLE,
};
use pogo::manifold::stiefel;
use pogo::optim::base::BaseOptKind;
use pogo::optim::batched::BatchedHost;
use pogo::optim::pogo::LambdaPolicy;
use pogo::optim::Orthoptimizer;
use pogo::rng::Rng;

const SHAPES: &[(usize, usize)] = &[(3, 3), (4, 8), (16, 16)];
const BATCHES: &[usize] = &[1, 7, 64];
const STEPS: usize = 5;

/// Largest elementwise |a − b|² across two packed groups.
fn max_abs_sq_diff<E: Field>(a: &BatchMat<E>, b: &BatchMat<E>) -> f64 {
    a.as_slice()
        .iter()
        .zip(b.as_slice())
        .map(|(&x, &y)| (x - y).abs_sq().to_f64())
        .fold(0.0, f64::max)
}

/// Unit-scaled random gradient (keeps the Thm 3.5 step regime).
fn random_grad<E: Field>(p: usize, n: usize, rng: &mut Rng) -> Mat<E> {
    let g = Mat::<E>::randn(p, n, rng);
    let nn = g.norm().to_f64().max(1e-30);
    g.scale(E::from_f64(0.3 / nn))
}

/// Step the SAME initial group `STEPS` times on the fused and the naive
/// path and require exact elementwise agreement after every step.
fn assert_exact_parity<E: Field>(
    make_opt: &dyn Fn() -> BatchedHost<E>,
    random_point: &dyn Fn(usize, usize, &mut Rng) -> Mat<E>,
    p: usize,
    n: usize,
    b: usize,
    seed: u64,
) {
    let mut rng = Rng::seed_from_u64(seed);
    let xs: Vec<Mat<E>> = (0..b).map(|_| random_point(p, n, &mut rng)).collect();
    let mut xb_fused = BatchMat::from_mats(&xs);
    let mut xb_naive = xb_fused.clone();
    let mut opt_fused = make_opt().with_kernel(KernelChoice::Fused);
    let mut opt_naive = make_opt().with_kernel(KernelChoice::Naive);

    for step in 0..STEPS {
        let gs: Vec<Mat<E>> = (0..b).map(|_| random_grad(p, n, &mut rng)).collect();
        let gb = BatchMat::from_mats(&gs);
        opt_fused.step_batch(&mut xb_fused, &gb).unwrap();
        opt_naive.step_batch(&mut xb_naive, &gb).unwrap();
        let d = max_abs_sq_diff(&xb_fused, &xb_naive);
        assert!(
            d == 0.0,
            "fused diverged from naive by |Δ|²={d} at ({p}, {n}) B={b} step {step}"
        );
    }
    for m in xb_fused.to_mats() {
        assert!(m.all_finite());
    }
}

/// Run the full (shape × batch) grid for one rule on one element type.
fn assert_rule_parity<E: Field>(
    make_opt: &dyn Fn() -> BatchedHost<E>,
    random_point: &dyn Fn(usize, usize, &mut Rng) -> Mat<E>,
) {
    for &(p, n) in SHAPES {
        for &b in BATCHES {
            assert_exact_parity(make_opt, random_point, p, n, b, (p * 1000 + n * 10 + b) as u64);
        }
    }
}

fn real_point<S: Scalar>(p: usize, n: usize, rng: &mut Rng) -> Mat<S> {
    stiefel::random_point_t::<S>(p, n, rng)
}

fn complex_point<S: Scalar>(p: usize, n: usize, rng: &mut Rng) -> Mat<Complex<S>> {
    stiefel::random_point_complex::<S>(p, n, rng)
}

/// One rule across all three element types (f32, f64, Complex<f64> — the
/// complex path exercises the portable kernel under the same dispatch).
macro_rules! rule_parity_tests {
    ($f32_name:ident, $f64_name:ident, $c64_name:ident, $ctor:expr) => {
        #[test]
        fn $f32_name() {
            assert_rule_parity::<f32>(&|| $ctor, &real_point::<f32>);
        }
        #[test]
        fn $f64_name() {
            assert_rule_parity::<f64>(&|| $ctor, &real_point::<f64>);
        }
        #[test]
        fn $c64_name() {
            assert_rule_parity::<Complex<f64>>(&|| $ctor, &complex_point::<f64>);
        }
    };
}

rule_parity_tests!(
    pogo_half_fused_parity_f32,
    pogo_half_fused_parity_f64,
    pogo_half_fused_parity_c64,
    // Momentum base: fused/naive must agree with base-optimizer state in
    // the loop, not just on raw gradients.
    BatchedHost::pogo(0.1, LambdaPolicy::Half, BaseOptKind::momentum(0.9))
);

rule_parity_tests!(
    pogo_find_root_fused_parity_f32,
    pogo_find_root_fused_parity_f64,
    pogo_find_root_fused_parity_c64,
    // Per-matrix quartic λ roots from the fused gram residual.
    BatchedHost::pogo(0.1, LambdaPolicy::FindRoot, BaseOptKind::Sgd)
);

rule_parity_tests!(
    landing_fused_parity_f32,
    landing_fused_parity_f64,
    landing_fused_parity_c64,
    // Safeguarded η + attraction term, fused into one sweep.
    BatchedHost::landing(0.1, 1.0, BaseOptKind::Sgd)
);

rule_parity_tests!(
    landing_pc_fused_parity_f32,
    landing_pc_fused_parity_f64,
    landing_pc_fused_parity_c64,
    // Per-matrix gradient normalization inside the fused sweep.
    BatchedHost::landing_pc(0.5, 1.0)
);

#[test]
fn fused_last_lambda_matches_naive() {
    // The reported λ (diagnostics surface) must come from the same place
    // on both paths — the LAST batch element under FindRoot.
    let (p, n, b) = (4, 8, 7);
    let mut rng = Rng::seed_from_u64(11);
    let xs: Vec<Mat<f64>> = (0..b).map(|_| real_point::<f64>(p, n, &mut rng)).collect();
    let gs: Vec<Mat<f64>> = (0..b).map(|_| random_grad(p, n, &mut rng)).collect();
    let gb = BatchMat::from_mats(&gs);
    let mut run = |kernel: KernelChoice| {
        let mut xb = BatchMat::from_mats(&xs);
        let mut opt = BatchedHost::<f64>::pogo(0.1, LambdaPolicy::FindRoot, BaseOptKind::Sgd)
            .with_kernel(kernel);
        opt.step_batch(&mut xb, &gb).unwrap();
        opt.last_lambda()
    };
    let lam_fused = run(KernelChoice::Fused);
    let lam_naive = run(KernelChoice::Naive);
    assert_eq!(lam_fused, lam_naive);
    assert!(lam_fused.is_some());
}

#[test]
fn fused_orthogonality_over_100_steps() {
    // ‖X Xᴴ − I‖ ≤ 1e-3 for every matrix after 100 fused steps (Thm 3.5
    // regime) — the fused path stays on the manifold, not just close to
    // the naive path.
    let (p, n, b) = (8, 16, 64);
    let mut rng = Rng::seed_from_u64(42);
    let xs: Vec<Mat<f32>> = (0..b).map(|_| real_point::<f32>(p, n, &mut rng)).collect();
    let mut xb = BatchMat::from_mats(&xs);
    let mut opt = BatchedHost::<f32>::pogo(0.2, LambdaPolicy::Half, BaseOptKind::vadam())
        .with_kernel(KernelChoice::Fused);
    for _ in 0..100 {
        let gs: Vec<Mat<f32>> = (0..b).map(|_| random_grad(p, n, &mut rng)).collect();
        let gb = BatchMat::from_mats(&gs);
        opt.step_batch(&mut xb, &gb).unwrap();
    }
    for x in xb.to_mats() {
        let d = stiefel::distance_f(&x);
        assert!(d <= 1e-3, "fused path left the manifold: {d}");
    }
}

#[test]
fn portable_kernel_direct_step_matches_naive() {
    // Scalar-fallback coverage without the env override: drive the
    // PORTABLE kernel's fused step directly and compare to the naive
    // batched composition. (The SIMD kernels are pinned bit-identical to
    // PORTABLE by the linalg unit tests; CI's forced-scalar leg re-runs
    // this whole binary under POGO_STEP_KERNEL=portable on top.)
    let (p, n, b) = (4, 8, 5);
    let eta = 0.1;
    let mut rng = Rng::seed_from_u64(3);
    let xs: Vec<Mat<f32>> = (0..b).map(|_| real_point::<f32>(p, n, &mut rng)).collect();
    let gs: Vec<Mat<f32>> = (0..b).map(|_| random_grad(p, n, &mut rng)).collect();

    let mut xb_naive = BatchMat::from_mats(&xs);
    let gb = BatchMat::from_mats(&gs);
    let mut opt = BatchedHost::<f32>::pogo(eta, LambdaPolicy::Half, BaseOptKind::Sgd)
        .with_kernel(KernelChoice::Naive);
    opt.step_batch(&mut xb_naive, &gb).unwrap();

    let mut xb_direct = BatchMat::from_mats(&xs);
    let stride = p * n;
    let mut scratch = StepScratch::new(p, n);
    let x_slice = xb_direct.as_mut_slice();
    let g_slice = gb.as_slice();
    for i in 0..b {
        let lam = PORTABLE.pogo_step(
            &mut x_slice[i * stride..(i + 1) * stride],
            &g_slice[i * stride..(i + 1) * stride],
            p,
            n,
            eta,
            &PogoLambda::Const(0.5),
            &mut scratch,
        );
        assert_eq!(lam, 0.5);
    }
    let d = max_abs_sq_diff(&xb_direct, &xb_naive);
    assert!(d == 0.0, "portable fused step diverged from naive by |Δ|²={d}");
}
