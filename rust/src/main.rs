//! `pogo` — the leader binary: experiment runner, artifact inspector.
//!
//! ```text
//! pogo run <experiment> [--methods a,b] [--steps N] [--reps K] [--seed S]
//!                       [--out DIR] [--full] [--quick]
//!                       [--spec FILE.json] [--dump-spec]
//! pogo serve [--addr HOST:PORT] [--workers N] [--queue-cap N]
//!            [--state-dir DIR] [--tenant-quota N] [--cost-cap UNITS]
//!            [--max-inline-bytes B] [--artifact-dir DIR]
//!            [--artifact-cap-mb MB]  # multi-tenant optimization job daemon
//! pogo front --backend H:P[,H:P...] [--addr HOST:PORT] [--probe-interval-ms MS]
//!            [--fail-after N] [--tenant-quota N] [--cost-cap UNITS]
//!            [--state-dir DIR]      # federated front door over N backends
//! pogo compile --job FILE.json [--out FILE.pogoart | --artifact-dir DIR]
//!                               # seal inline problem data into an artifact
//! pogo artifact inspect <file.pogoart> [--json]
//! pogo artifact verify <file.pogoart>
//! pogo trace <job.json> [--out trace.json]
//!                               # run one job under the flight recorder
//! pogo list                     # experiments + their paper figures
//! pogo info [--artifacts DIR]   # artifact registry contents
//! pogo report [--dir DIR]       # summarize results CSVs + BENCH_*.json
//! pogo version
//! ```
//!
//! `--dump-spec` prints the lineup's optimizer specs as JSON (one object
//! per method) without running; `--spec` replays a `*.spec.json` manifest
//! emitted next to any run's CSV.

use pogo::config::{ExperimentId, RunConfig};
use pogo::optim::Method;
use pogo::util::cli::Cli;

fn main() {
    pogo::util::logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let code = match cmd {
        "run" => cmd_run(),
        "serve" => cmd_serve(),
        "front" => cmd_front(),
        "compile" => cmd_compile(),
        "artifact" => cmd_artifact(),
        "trace" => cmd_trace(),
        "list" => cmd_list(),
        "info" => cmd_info(),
        "report" => cmd_report(),
        "version" | "--version" => {
            println!("pogo {}", pogo::VERSION);
            0
        }
        _ => {
            print_help();
            if cmd == "help" || cmd == "--help" || cmd == "-h" {
                0
            } else {
                eprintln!("\nunknown command '{cmd}'");
                2
            }
        }
    };
    std::process::exit(code);
}

fn print_help() {
    println!(
        "pogo — Proximal One-step Geometric Orthoptimizer (paper reproduction)\n\n\
         Commands:\n\
         \x20 run <experiment>   run a paper experiment (see `pogo list`)\n\
         \x20 serve              run the optimization job daemon (v1: submit/poll;\n\
         \x20                    v2: inline problem uploads, SSE event streams,\n\
         \x20                    per-tenant quotas + cost-aware admission,\n\
         \x20                    --artifact-dir: content-addressed problem store)\n\
         \x20 front              federated front door over N serve backends\n\
         \x20                    (consistent-hash placement, health probing +\n\
         \x20                    failover re-listing, global quotas, SSE relay)\n\
         \x20 compile            seal a job's inline problem data into a\n\
         \x20                    .pogoart artifact (--job FILE --out FILE)\n\
         \x20 artifact           inspect | verify a sealed .pogoart artifact\n\
         \x20 trace              run one job spec under the flight recorder and\n\
         \x20                    write Chrome trace-event JSON (--out trace.json)\n\
         \x20 list               list experiments\n\
         \x20 info               inspect the AOT artifact registry\n\
         \x20 report             summarize results/*.csv and BENCH_*.json\n\
         \x20                    (scale, born, serve, artifact) from past runs;\n\
         \x20                    --artifact-dir also summarizes an artifact store\n\
         \x20 version            print the version\n\n\
         Run `pogo run <experiment> --help` or `pogo serve --help` for flags."
    );
}

fn cmd_list() -> i32 {
    println!("{:<16} {}", "experiment", "reproduces");
    let figures = [
        ("fig4-pca", "Fig. 4 (left): online PCA, gap + distance vs time"),
        ("fig4-procrustes", "Fig. 4 (right): orthogonal Procrustes"),
        ("fig5-ovit", "Fig. 5: O-ViT, 18 orthogonal attention/MLP matrices"),
        ("fig1-filters", "Fig. 1/6: CNN with orthogonal filters"),
        ("fig1-kernels", "Fig. 1/6/7: CNN with 9800 orthogonal 3x3 kernels"),
        ("fig8-born", "Fig. 8: squared unitary circuit (complex Stiefel)"),
        ("figc1", "Fig. C.1: precision ablation (f32/f64/bf16)"),
        ("figc2", "Fig. C.2/C.3: lambda policy x learning-rate ablation"),
        ("scale", "Fig. 1 mechanism: us/matrix vs batch size"),
    ];
    for (name, what) in figures {
        println!("{name:<16} {what}");
    }
    println!(
        "\nBeyond experiments: `pogo compile --job FILE [--out FILE | --artifact-dir DIR]`\n\
         seals inline problem data into a content-addressed .pogoart artifact, and\n\
         `pogo artifact inspect|verify <file>` examines one (see `pogo --help`)."
    );
    0
}

fn cmd_info() -> i32 {
    let cli = Cli::new("pogo info", "inspect the artifact registry")
        .flag_opt("artifacts", "artifacts directory (default <repo>/artifacts)");
    let a = cli.parse_env_or_exit(1);
    let dir = a
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(pogo::artifacts_dir);
    match pogo::runtime::Registry::open(dir) {
        Ok(reg) => {
            println!("{:<44} {:>8} {:>8}  tags", "program", "inputs", "outputs");
            for name in reg.names() {
                let m = reg.meta(name).unwrap();
                println!(
                    "{:<44} {:>8} {:>8}  {}",
                    name,
                    m.inputs.len(),
                    m.outputs.len(),
                    m.tags.join(",")
                );
            }
            0
        }
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

fn cmd_serve() -> i32 {
    let cli = Cli::new("pogo serve", "multi-tenant optimization job service")
        .flag("addr", "127.0.0.1:7070", "listen address (HOST:PORT; port 0 = ephemeral)")
        .flag_opt("workers", "worker threads (default min(cores, 4))")
        .flag("queue-cap", "256", "max queued (not yet running) jobs")
        .flag_opt("state-dir", "persist job state + checkpoints here (enables restart recovery)")
        .flag("tenant-quota", "0", "max active jobs per X-Api-Key tenant (0 = unlimited)")
        .flag("cost-cap", "0", "max outstanding B*p*n*steps cost units (0 = unlimited)")
        .flag_opt("max-inline-bytes", "max inline problem payload bytes (default 8 MiB)")
        .flag_opt(
            "artifact-dir",
            "content-addressed artifact store directory (enables POST /v2/artifacts, \
             the 'artifact' problem source and inline dedupe)",
        )
        .flag("artifact-cap-mb", "512", "artifact store byte budget in MiB (LRU eviction)");
    let a = cli.parse_env_or_exit(1);
    let mut cfg = pogo::serve::ServeConfig {
        addr: a.get_or("addr", "127.0.0.1:7070"),
        ..Default::default()
    };
    if let Some(w) = a.get_usize("workers") {
        cfg.workers = w.max(1);
    }
    if let Some(c) = a.get_usize("queue-cap") {
        cfg.capacity = c.max(1);
    }
    cfg.state_dir = a.get("state-dir").map(std::path::PathBuf::from);
    let mut admission = pogo::serve::Admission::default();
    if let Some(q) = a.get_usize("tenant-quota") {
        admission.tenant_quota = q;
    }
    if let Some(c) = a.get_u64("cost-cap") {
        admission.cost_cap = c;
    }
    if let Some(b) = a.get_usize("max-inline-bytes") {
        admission.max_inline_bytes = b;
    }
    let artifacts = match a.get("artifact-dir") {
        Some(dir) => {
            let cap_mb = a.get_u64("artifact-cap-mb").unwrap_or(512).max(1);
            match pogo::artifact::ArtifactStore::open(
                std::path::Path::new(dir),
                cap_mb.saturating_mul(1 << 20),
            ) {
                Ok(store) => Some(std::sync::Arc::new(store)),
                Err(e) => {
                    eprintln!("error opening --artifact-dir {dir}: {e:#}");
                    return 1;
                }
            }
        }
        None => None,
    };
    match pogo::serve::Server::start_with_artifacts(cfg, admission, artifacts) {
        Ok(server) => {
            println!("pogo serve listening on http://{}", server.addr());
            println!(
                "endpoints: POST /v1|v2/jobs · GET /v1|v2/jobs[/:id[/result]] · \
                 GET /v2/jobs/:id/events (SSE) · GET /v2/problems · \
                 POST|GET /v2/artifacts[/:hash] · \
                 DELETE /v1|v2/jobs/:id · GET /healthz · GET /metrics"
            );
            // No signal handling without libc: a kill stops the daemon
            // immediately. With --state-dir the next start recovers and
            // resumes unfinished jobs from their checkpoints.
            server.wait();
            0
        }
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

fn cmd_front() -> i32 {
    let cli = Cli::new("pogo front", "federated front door over N pogo serve backends")
        .flag("addr", "127.0.0.1:7071", "listen address (HOST:PORT; port 0 = ephemeral)")
        .flag_opt("backend", "comma-separated backend addresses (HOST:PORT,...) — required")
        .flag("probe-interval-ms", "1000", "health-probe period per backend")
        .flag("fail-after", "2", "consecutive probe failures before a backend is down")
        .flag("tenant-quota", "0", "global max active jobs per tenant across all backends (0 = unlimited)")
        .flag("cost-cap", "0", "global max outstanding B*p*n*steps cost units (0 = unlimited)")
        .flag_opt("state-dir", "persist the placement table here (front restart keeps routing)");
    let a = cli.parse_env_or_exit(1);
    let backends: Vec<String> = a
        .get("backend")
        .map(|b| {
            b.split(',')
                .map(str::trim)
                .filter(|s| !s.is_empty())
                .map(String::from)
                .collect()
        })
        .unwrap_or_default();
    if backends.is_empty() {
        eprintln!("error: pogo front needs --backend HOST:PORT[,HOST:PORT...]");
        return 1;
    }
    let cfg = pogo::federate::FrontConfig {
        addr: a.get_or("addr", "127.0.0.1:7071"),
        backends,
        probe_interval: std::time::Duration::from_millis(
            a.get_u64("probe-interval-ms").unwrap_or(1000).max(10),
        ),
        fail_after: a.get_u64("fail-after").unwrap_or(2).max(1) as u32,
        admission: pogo::federate::FrontAdmission {
            tenant_quota: a.get_usize("tenant-quota").unwrap_or(0),
            cost_cap: a.get_u64("cost-cap").unwrap_or(0),
        },
        state_dir: a.get("state-dir").map(std::path::PathBuf::from),
    };
    match pogo::federate::Front::start(cfg) {
        Ok(front) => {
            println!("pogo front listening on http://{}", front.addr());
            println!(
                "federating the v2 surface: POST /v2/jobs (rendezvous-hash placement) · \
                 GET /v2/jobs[/:id[/result|/trace|/events]] · DELETE /v2/jobs/:id · \
                 POST|GET /v2/artifacts[/:hash] (fan-out) · \
                 GET /front/nodes · GET /healthz · GET /metrics"
            );
            front.wait();
            0
        }
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

fn cmd_compile() -> i32 {
    let cli = Cli::new(
        "pogo compile",
        "seal a job's inline problem data into a content-addressed .pogoart artifact",
    )
    .flag_opt("job", "job spec JSON file (the same body POST /v2/jobs takes, inline source)")
    .flag_opt("out", "output file (default ./<hash>.pogoart)")
    .flag_opt("artifact-dir", "insert into this artifact store directory instead of --out")
    .flag_opt("note", "free-form provenance note (changes the content address)");
    let a = cli.parse_env_or_exit(1);
    let Some(job) = a.get("job") else {
        eprintln!("error: --job FILE.json is required\n\n{}", cli.usage());
        return 2;
    };
    match compile_artifact(
        std::path::Path::new(job),
        a.get("out").map(std::path::Path::new),
        a.get("artifact-dir").map(std::path::Path::new),
        a.get("note"),
    ) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

/// Seal `job_path`'s inline payload. The provenance is constructed
/// exactly like the daemon's inline-dedupe path (job seed + optimizer
/// spec, no note), so compiling a job and submitting it inline land on
/// the same content address.
fn compile_artifact(
    job_path: &std::path::Path,
    out: Option<&std::path::Path>,
    store_dir: Option<&std::path::Path>,
    note: Option<&str>,
) -> anyhow::Result<()> {
    use anyhow::Context;
    use pogo::artifact::{Artifact, ArtifactStore, Provenance, FILE_EXT};
    let text = std::fs::read_to_string(job_path)
        .with_context(|| format!("reading {}", job_path.display()))?;
    let parsed = pogo::util::json::Json::parse(&text)
        .map_err(|e| anyhow::anyhow!("parsing {}: {e}", job_path.display()))?;
    let spec = pogo::serve::JobSpec::from_json(&parsed)?;
    let pogo::serve::ProblemSource::Inline(inline) = &spec.source else {
        anyhow::bail!(
            "compile needs a job with an inline problem source, got '{}'",
            spec.source.label()
        );
    };
    let mut prov = Provenance::new(spec.seed);
    prov.optimizer = Some(spec.optimizer.to_json());
    prov.note = note.map(|s| s.to_string());
    let art = Artifact::seal(inline, spec.domain, spec.batch, spec.p, spec.n, prov)?;
    let hash = art.hash();
    if let Some(dir) = store_dir {
        let store = ArtifactStore::open(dir, u64::MAX)?;
        let outcome = store.insert(&art)?;
        println!(
            "{hash}  {} bytes  {}",
            art.encoded_len(),
            if outcome.existed { "already stored" } else { "stored" }
        );
        println!("{}", store.dir().join(format!("{hash}.{FILE_EXT}")).display());
    } else {
        let default = std::path::PathBuf::from(format!("{hash}.{FILE_EXT}"));
        let path = out.unwrap_or(&default);
        art.write_file(path)?;
        println!("{hash}  {} bytes  {}", art.encoded_len(), path.display());
    }
    Ok(())
}

fn cmd_trace() -> i32 {
    let cli = Cli::new(
        "pogo trace",
        "run one job spec under the flight recorder and write a Chrome trace",
    )
    .flag("out", "trace.json", "output file (load in chrome://tracing or ui.perfetto.dev)");
    let a = cli.parse_env_or_exit(1);
    let Some(job) = a.positional().first().cloned() else {
        eprintln!("usage: pogo trace <job.json> [--out trace.json]\n\n{}", cli.usage());
        return 2;
    };
    let out = a.get_or("out", "trace.json");
    match run_trace(std::path::Path::new(&job), std::path::Path::new(&out)) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

/// Run `job_path`'s spec in-process with a [`pogo::obs::JobTrace`] wired
/// through [`pogo::serve::RunCtl`] — the same flight recorder the daemon
/// attaches to every queued job — then write the Chrome trace-event JSON
/// and print the span tree. Observability is forced on for the run: a
/// trace command that silently recorded nothing would be useless.
fn run_trace(job_path: &std::path::Path, out: &std::path::Path) -> anyhow::Result<()> {
    use anyhow::Context;
    let text = std::fs::read_to_string(job_path)
        .with_context(|| format!("reading {}", job_path.display()))?;
    let parsed = pogo::util::json::Json::parse(&text)
        .map_err(|e| anyhow::anyhow!("parsing {}: {e}", job_path.display()))?;
    let spec = pogo::serve::JobSpec::from_json(&parsed)?;
    pogo::obs::set_enabled(Some(true));
    let trace = pogo::obs::JobTrace::new();
    let ctl = pogo::serve::RunCtl { trace: Some(&trace), ..Default::default() };
    let t_run = trace.now_us();
    let (outcome, _iterate) = pogo::serve::run_job_with(&spec, &ctl, None)?;
    let now = trace.now_us();
    trace.record_span("run", t_run, now - t_run, 1);
    trace.record_span("job", 0, now, 0);
    std::fs::write(out, trace.chrome_json().to_string_pretty() + "\n")
        .with_context(|| format!("writing {}", out.display()))?;
    let r = match &outcome {
        pogo::serve::JobOutcome::Done(r) | pogo::serve::JobOutcome::Cancelled(r) => r,
    };
    println!(
        "{} steps in {:.3} s (final loss {:.6e}); trace written to {}",
        r.steps_done,
        now as f64 / 1e6,
        r.final_loss,
        out.display()
    );
    for line in pogo::coordinator::report::trace_summary_lines(&trace.tree_json()) {
        println!("{line}");
    }
    Ok(())
}

fn cmd_artifact() -> i32 {
    let sub = std::env::args().nth(2).unwrap_or_default();
    match sub.as_str() {
        "inspect" => {
            let cli = Cli::new("pogo artifact inspect", "print a sealed artifact's manifest")
                .switch("json", "emit the full describe JSON");
            let a = cli.parse_env_or_exit(2);
            let Some(file) = a.positional().first() else {
                eprintln!("usage: pogo artifact inspect <file.pogoart> [--json]");
                return 2;
            };
            match pogo::artifact::Artifact::read_file(std::path::Path::new(file)) {
                Ok(art) => {
                    if a.get_bool("json") {
                        println!("{}", art.describe().to_string_pretty());
                    } else {
                        let m = &art.manifest;
                        println!("hash:       {}", art.hash());
                        println!(
                            "objective:  {}  ({} domain, dtype {})",
                            m.objective,
                            m.domain.name(),
                            m.dtype
                        );
                        println!("shapes:     batch={}  St({}, {})", m.batch, m.p, m.n);
                        for s in &m.sections {
                            println!(
                                "section:    '{}'  {} x {}x{}  {} bytes  sha256 {}",
                                s.name, s.count, s.rows, s.cols, s.bytes, s.sha256
                            );
                        }
                        println!(
                            "provenance: seed={}  created_by='{}'",
                            m.provenance.seed, m.provenance.created_by
                        );
                    }
                    0
                }
                Err(e) => {
                    eprintln!("error: {e:#}");
                    1
                }
            }
        }
        "verify" => {
            let cli = Cli::new(
                "pogo artifact verify",
                "re-hash every payload section against its manifest checksum",
            );
            let a = cli.parse_env_or_exit(2);
            let Some(file) = a.positional().first() else {
                eprintln!("usage: pogo artifact verify <file.pogoart>");
                return 2;
            };
            let checked = pogo::artifact::Artifact::read_file(std::path::Path::new(file))
                .and_then(|art| {
                    art.verify()?;
                    Ok(art)
                });
            match checked {
                Ok(art) => {
                    println!(
                        "OK {}  {} sections, {} payload bytes",
                        art.hash(),
                        art.manifest.sections.len(),
                        art.payload.len()
                    );
                    0
                }
                Err(e) => {
                    eprintln!("verify FAILED: {e:#}");
                    1
                }
            }
        }
        other => {
            eprintln!("usage: pogo artifact <inspect|verify> <file.pogoart> [--json]");
            if !other.is_empty() && other != "--help" && other != "-h" {
                eprintln!("unknown artifact subcommand '{other}'");
            }
            2
        }
    }
}

fn cmd_report() -> i32 {
    let cli = Cli::new("pogo report", "summarize experiment series CSVs")
        .flag_opt("dir", "results directory (default <repo>/results)")
        .flag_opt("filter", "substring filter on series names")
        .flag_opt("artifact-dir", "also summarize this content-addressed artifact store")
        .switch("json", "emit machine-readable JSON");
    let a = cli.parse_env_or_exit(1);
    let dir = a
        .get("dir")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| pogo::repo_root().join("results"));
    let result = if a.get_bool("json") {
        pogo::coordinator::report::report_json(&dir).map(|j| println!("{j}"))
    } else {
        pogo::coordinator::report::report(&dir, a.get("filter"))
    };
    if let Some(ad) = a.get("artifact-dir") {
        println!("\n== artifact store ==");
        for line in
            pogo::coordinator::report::artifact_store_lines(std::path::Path::new(ad))
        {
            println!("{line}");
        }
    }
    match result {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

fn cmd_run() -> i32 {
    let exp_name = std::env::args().nth(2).unwrap_or_default();
    let Some(exp) = ExperimentId::parse(&exp_name) else {
        eprintln!("unknown experiment '{exp_name}' — see `pogo list`");
        return 2;
    };
    let cli = Cli::new(
        &format!("pogo run {exp_name}"),
        "run a paper experiment and emit figure CSVs",
    )
    .flag_opt("methods", "comma-separated methods (default: paper lineup)")
    .flag_opt("steps", "step budget override")
    .flag("reps", "1", "independent repetitions")
    .flag("seed", "0", "base RNG seed")
    .flag_opt("out", "output directory for CSVs (default <repo>/results)")
    .flag_opt("spec", "optimizer spec JSON to replay (overrides its method's preset)")
    .switch("dump-spec", "print the lineup's optimizer specs as JSON and exit")
    .switch("full", "use the paper's full Fig. 4 shapes (needs full artifacts)")
    .switch("quick", "tiny smoke-run shapes/budgets");
    let a = cli.parse_env_or_exit(2);

    let mut cfg = RunConfig::new(exp);
    if let Some(ms) = a.get("methods") {
        let mut methods = Vec::new();
        for tok in ms.split(',') {
            match Method::parse(tok.trim()) {
                Some(m) => methods.push(m),
                None => {
                    eprintln!("unknown method '{tok}'");
                    return 2;
                }
            }
        }
        cfg.methods = methods;
    }
    if let Some(path) = a.get("spec") {
        let spec =
            match pogo::coordinator::OptimizerSpec::from_json_file(std::path::Path::new(path))
            {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("error reading --spec {path}: {e:#}");
                    return 2;
                }
            };
        // An explicit spec narrows the default lineup to its own method;
        // an explicit --methods list still wins.
        if a.get("methods").is_none() {
            cfg.methods = vec![spec.method];
        }
        cfg.spec = Some(spec);
    }
    if let Some(s) = a.get_usize("steps") {
        cfg.steps = s;
    }
    cfg.repetitions = a.get_usize("reps").unwrap_or(1);
    cfg.seed = a.get_u64("seed").unwrap_or(0);
    if let Some(o) = a.get("out") {
        cfg.out_dir = std::path::PathBuf::from(o);
    }
    cfg.full = a.get_bool("full");
    cfg.quick = a.get_bool("quick");

    if a.get_bool("dump-spec") {
        // Mirror the drivers' engine assignment so the dump matches what
        // a run would actually build (replayed specs pin their engine).
        let entries: Vec<(&str, pogo::util::json::Json)> = cfg
            .methods
            .iter()
            .map(|&m| {
                let spec = pogo::experiments::common::with_engine_for(
                    &cfg,
                    pogo::config::resolve_spec(&cfg, m),
                );
                (m.name(), spec.to_json())
            })
            .collect();
        println!("{}", pogo::util::json::Json::obj(entries).to_string_pretty());
        return 0;
    }

    log::info!("config: {}", cfg.to_json().to_string());
    match pogo::experiments::run(&cfg) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}
