//! The serve job model: what a client submits and what a worker runs.
//!
//! A [`JobSpec`] names a problem source (see [`super::problem`]: seeded
//! `builtin` objectives, or `inline` client-supplied matrices), an
//! [`OptimizerSpec`] (so `"engine": "rust" | "batched-host"` round-trips
//! exactly as in spec JSON today), a `(batch, p, n)` shape group, the
//! manifold domain (real/complex Stiefel), a step budget and a seed.
//! [`run_job`] is the ONE execution path: it drives an [`OptimSession`]
//! over a seeded `ParamStore`, so a job run through the daemon is
//! **bit-for-bit** the same trajectory as calling `run_job` (or an
//! `OptimSession` loop with the same construction order) directly — the
//! property the e2e test pins. [`run_job_with`] is the same path with a
//! per-step [`StepProgress`] observer (what the daemon's SSE stream and
//! full loss series feed from) and returns the [`FinalIterate`] for the
//! v2 result surface.
//!
//! Jobs with `checkpoint_every > 0` periodically persist through
//! [`crate::coordinator::checkpoint`] on **both** domains (real stores
//! save as `f32`, complex ones as interleaved `c64` pairs) and resume
//! from the checkpoint on restart (parameters + step counter;
//! base-optimizer state restarts, so resumed momentum runs continue
//! feasibly but are not bitwise-identical to an uninterrupted run —
//! POGO/sgd is stateless and resumes exactly).

use crate::coordinator::{checkpoint, OptimSession, OptimizerSpec, ParamStore};
use crate::linalg::{matmul, matmul_ah_b, Complex, Field, Mat};
use crate::rng::Rng;
use crate::util::json::Json;
use anyhow::{anyhow, ensure, Context, Result};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};

pub use super::problem::{
    ArtifactRef, InlineMat, InlineProblem, ProblemKind, ProblemSource, WireElem,
};

/// Which manifold a job optimizes over.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobDomain {
    /// Real Stiefel `X Xᵀ = I` (f32, the experiment default).
    Real,
    /// Complex Stiefel `X Xᴴ = I` (`Complex<f32>`, the Fig. 8 regime).
    Complex,
}

impl JobDomain {
    pub fn name(self) -> &'static str {
        match self {
            JobDomain::Real => "real",
            JobDomain::Complex => "complex",
        }
    }

    pub fn parse(s: &str) -> Option<JobDomain> {
        Some(match s.to_ascii_lowercase().as_str() {
            "real" => JobDomain::Real,
            "complex" | "unitary" => JobDomain::Complex,
            _ => return None,
        })
    }
}

/// One submitted optimization job.
#[derive(Clone, Debug, PartialEq)]
pub struct JobSpec {
    /// Client-chosen label (shows up in listings; empty is fine).
    pub name: String,
    /// Where the objective comes from (builtin seeded, or inline client
    /// data — see [`super::problem`]).
    pub source: ProblemSource,
    pub domain: JobDomain,
    /// Shape group: `batch` matrices on St(p, n).
    pub batch: usize,
    pub p: usize,
    pub n: usize,
    /// Step budget.
    pub steps: usize,
    /// Seed for parameters AND builtin problem data (full determinism).
    pub seed: u64,
    /// Persist every k steps (0 = never).
    pub checkpoint_every: usize,
    /// Method, hyperparameters and engine — the same serializable spec
    /// the CLI replays.
    pub optimizer: OptimizerSpec,
}

impl JobSpec {
    /// A small POGO job on a builtin problem — the starting point tests
    /// and examples tweak.
    pub fn new(problem: ProblemKind, batch: usize, p: usize, n: usize) -> JobSpec {
        JobSpec {
            name: String::new(),
            source: ProblemSource::Builtin(problem),
            domain: JobDomain::Real,
            batch,
            p,
            n,
            steps: 100,
            seed: 0,
            checkpoint_every: 0,
            optimizer: OptimizerSpec::new(crate::optim::Method::Pogo, 0.05),
        }
    }

    /// Admission-time validation: shape sanity, a size ceiling so one
    /// bad request cannot OOM the daemon, and source-specific payload
    /// checks (inline matrices must match `(batch, p, n)` and the
    /// domain's element width). Engine/method capability mismatches
    /// surface later, at session build, as a `failed` job — never a
    /// panic.
    pub fn validate(&self) -> Result<()> {
        self.validate_scalars()?;
        self.source.validate(self.domain, self.batch, self.p, self.n)
    }

    /// The cheap, source-independent half of [`JobSpec::validate`]: shape
    /// sanity and the scalar-count ceiling, without the O(payload) pass
    /// over inline matrices. The queue runs this unconditionally and
    /// skips the payload pass when the payload's content hash is already
    /// in the artifact store (it was validated when it entered).
    pub fn validate_scalars(&self) -> Result<()> {
        ensure!(self.batch >= 1, "job: batch must be >= 1");
        ensure!(self.p >= 1 && self.p <= self.n, "job: need 1 <= p <= n, got ({}, {})", self.p, self.n);
        ensure!(self.steps >= 1, "job: steps must be >= 1");
        let scalars = self.batch.saturating_mul(self.p).saturating_mul(self.n);
        ensure!(
            scalars <= 1 << 26,
            "job too large: {} x {} x {} = {scalars} scalars (cap 2^26)",
            self.batch,
            self.p,
            self.n
        );
        Ok(())
    }

    /// Admission cost units, `B·p·n·steps` — the work model the daemon's
    /// cost-aware gate budgets (saturating, so absurd specs cost `u64::MAX`
    /// rather than wrapping past the cap).
    pub fn cost(&self) -> u64 {
        (self.batch as u64)
            .saturating_mul(self.p as u64)
            .saturating_mul(self.n as u64)
            .saturating_mul(self.steps as u64)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            // Builtin sources serialize as the frozen v1 string form.
            ("problem", self.source.to_json()),
            ("domain", Json::str(self.domain.name())),
            ("batch", Json::num(self.batch as f64)),
            ("p", Json::num(self.p as f64)),
            ("n", Json::num(self.n as f64)),
            ("steps", Json::num(self.steps as f64)),
            // Seeds are u64; JSON numbers are f64 (2^53) — keep exact.
            ("seed", Json::str(self.seed.to_string())),
            ("checkpoint_every", Json::num(self.checkpoint_every as f64)),
            ("optimizer", self.optimizer.to_json()),
        ])
    }

    /// Parse a job. `problem` (v1 name string or v2 source object),
    /// `batch`, `p`, `n`, `steps` and a valid `optimizer` (method + lr)
    /// are required; the rest defaults like the CLI's minimal spec files.
    /// Present-but-malformed fields are errors.
    pub fn from_json(j: &Json) -> Result<JobSpec> {
        let source = ProblemSource::from_json(j.get("problem"))?;
        let need = |key: &str| -> Result<usize> {
            j.get(key)
                .as_usize()
                .ok_or_else(|| anyhow!("job: missing or non-integer '{key}'"))
        };
        let batch = need("batch")?;
        let p = need("p")?;
        let n = need("n")?;
        let steps = need("steps")?;
        let optimizer = OptimizerSpec::from_json(j.get("optimizer"))
            .context("job: in 'optimizer'")?;
        let mut spec = JobSpec {
            name: String::new(),
            source,
            domain: JobDomain::Real,
            batch,
            p,
            n,
            steps,
            seed: 0,
            checkpoint_every: 0,
            optimizer,
        };
        match j.get("name") {
            Json::Null => {}
            v => {
                spec.name = v
                    .as_str()
                    .ok_or_else(|| anyhow!("job: 'name' must be a string"))?
                    .to_string();
            }
        }
        match j.get("domain") {
            Json::Null => {}
            v => {
                let s = v.as_str().ok_or_else(|| anyhow!("job: 'domain' must be a string"))?;
                spec.domain =
                    JobDomain::parse(s).ok_or_else(|| anyhow!("job: unknown domain '{s}'"))?;
            }
        }
        match j.get("seed") {
            Json::Null => {}
            Json::Str(s) => {
                spec.seed =
                    s.parse::<u64>().map_err(|_| anyhow!("job: 'seed' is not a u64: '{s}'"))?;
            }
            Json::Num(v) => {
                if *v < 0.0 || v.fract() != 0.0 || *v > 9.0e15 {
                    return Err(anyhow!(
                        "job: 'seed' must be a non-negative integer <= 2^53 \
                         (use a string for larger seeds)"
                    ));
                }
                spec.seed = *v as u64;
            }
            _ => return Err(anyhow!("job: 'seed' must be an integer or string")),
        }
        match j.get("checkpoint_every") {
            Json::Null => {}
            v => {
                spec.checkpoint_every = v.as_usize().ok_or_else(|| {
                    anyhow!("job: 'checkpoint_every' must be a non-negative integer")
                })?;
            }
        }
        spec.validate()?;
        Ok(spec)
    }
}

/// What a finished (or cancelled) job measured.
#[derive(Clone, Debug)]
pub struct JobResult {
    /// Objective at the final iterate (see [`ProblemKind`] for the
    /// replay pseudo-loss).
    pub final_loss: f64,
    /// `max_i ‖Xᵢ Xᵢᴴ − I‖_F` at the final iterate.
    pub ortho_error: f64,
    pub steps_done: usize,
    pub wall_s: f64,
    /// Where the last checkpoint landed, if the job checkpointed.
    pub checkpoint: Option<PathBuf>,
}

impl JobResult {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("final_loss", Json::num(self.final_loss)),
            ("ortho_error", Json::num(self.ortho_error)),
            ("steps_done", Json::num(self.steps_done as f64)),
            ("wall_s", Json::num(self.wall_s)),
            (
                "checkpoint",
                match &self.checkpoint {
                    Some(p) => Json::str(p.display().to_string()),
                    None => Json::Null,
                },
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<JobResult> {
        Ok(JobResult {
            final_loss: j
                .get("final_loss")
                .as_f64()
                .ok_or_else(|| anyhow!("result: missing 'final_loss'"))?,
            ortho_error: j
                .get("ortho_error")
                .as_f64()
                .ok_or_else(|| anyhow!("result: missing 'ortho_error'"))?,
            steps_done: j.get("steps_done").as_usize().unwrap_or(0),
            wall_s: j.get("wall_s").as_f64().unwrap_or(0.0),
            checkpoint: j.get("checkpoint").as_str().map(PathBuf::from),
        })
    }
}

/// Lifecycle of a job inside the queue.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobState {
    Queued,
    Running,
    Done,
    Failed,
    Cancelled,
}

impl JobState {
    pub fn name(self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Failed => "failed",
            JobState::Cancelled => "cancelled",
        }
    }

    pub fn parse(s: &str) -> Option<JobState> {
        Some(match s {
            "queued" => JobState::Queued,
            "running" => JobState::Running,
            "done" => JobState::Done,
            "failed" => JobState::Failed,
            "cancelled" => JobState::Cancelled,
            _ => return None,
        })
    }

    pub fn all() -> &'static [JobState] {
        &[JobState::Queued, JobState::Running, JobState::Done, JobState::Failed, JobState::Cancelled]
    }

    pub fn is_terminal(self) -> bool {
        matches!(self, JobState::Done | JobState::Failed | JobState::Cancelled)
    }
}

/// How [`run_job`] ended (errors are a separate `Err`).
#[derive(Clone, Debug)]
pub enum JobOutcome {
    Done(JobResult),
    /// The cancel flag was observed between steps; the result holds the
    /// partial trajectory's final numbers.
    Cancelled(JobResult),
}

/// One applied step, as seen by a streaming observer (the v2 SSE feed).
#[derive(Clone, Copy, Debug)]
pub struct StepProgress {
    /// Steps applied so far (1-based; strictly increasing per job run).
    pub step: usize,
    /// Objective before this step's update was applied.
    pub loss: f64,
    /// `max_i ‖Xᵢ Xᵢᴴ − I‖_F`. Telemetry, not a per-step invariant
    /// check: recomputed on the first, every [`ORTHO_EVERY`]-th and the
    /// final step (a full Gram pass per reading); events in between
    /// carry the latest reading.
    pub ortho_error: f64,
    /// Wall-clock seconds since this run (or resume) started.
    pub wall_s: f64,
}

/// How often the observer path recomputes the orthogonality reading —
/// a Gram pass costs the same order as an optimizer step, so doing it
/// every step would tax every served job for telemetry nobody may read.
pub const ORTHO_EVERY: usize = 16;

/// The final (or cancellation-point) iterate, packed for the v2 result
/// surface: row-major f32 words per matrix, matrices concatenated in
/// registration order, complex entries interleaved `re,im`.
#[derive(Clone, Debug, PartialEq)]
pub struct FinalIterate {
    pub domain: JobDomain,
    pub batch: usize,
    pub p: usize,
    pub n: usize,
    pub data: Vec<f32>,
}

impl FinalIterate {
    fn pack<E: WireElem>(domain: JobDomain, spec: &JobSpec, store: &ParamStore<E>) -> FinalIterate {
        let mut data = Vec::with_capacity(store.num_scalars() * E::WIDTH);
        for prm in store.params() {
            for &v in prm.mat.as_slice() {
                v.push_words(&mut data);
            }
        }
        FinalIterate { domain, batch: spec.batch, p: spec.p, n: spec.n, data }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("domain", Json::str(self.domain.name())),
            ("batch", Json::num(self.batch as f64)),
            ("p", Json::num(self.p as f64)),
            ("n", Json::num(self.n as f64)),
            ("b64", Json::str(super::problem::words_to_b64(&self.data))),
        ])
    }
}

/// Runtime hooks the queue wires into a job execution. The defaults run
/// to completion with no observers (what the parity tests use).
#[derive(Default)]
pub struct RunCtl<'a> {
    /// Checked between steps; set → the job stops as `Cancelled`.
    pub cancel: Option<&'a AtomicBool>,
    /// Called after every applied step with (steps_done, loss).
    pub on_step: Option<&'a dyn Fn(usize, f64)>,
    /// Where to checkpoint/resume (`checkpoint_every > 0`; either domain).
    pub checkpoint_path: Option<PathBuf>,
    /// Flight recorder for this job, if anyone wants the timeline: the
    /// run records `build`/`resume`/`steps` segments plus sampled step
    /// windows and checkpoint spans into it. Ignored when observability
    /// is off ([`crate::obs::enabled`]).
    pub trace: Option<&'a crate::obs::JobTrace>,
}

/// Execute a job to completion (or cancellation). Deterministic given the
/// spec: the daemon and a direct caller produce bit-identical
/// trajectories. This is the single execution path behind `pogo serve`.
pub fn run_job(spec: &JobSpec, ctl: &RunCtl) -> Result<JobOutcome> {
    run_job_with(spec, ctl, None).map(|(outcome, _)| outcome)
}

/// [`run_job`] plus the v2 surfaces: an optional per-step
/// [`StepProgress`] observer (fed after `RunCtl::on_step`; computing its
/// orthogonality reading never mutates state, so the trajectory is
/// untouched) and the packed [`FinalIterate`].
pub fn run_job_with(
    spec: &JobSpec,
    ctl: &RunCtl,
    observer: Option<&dyn Fn(&StepProgress)>,
) -> Result<(JobOutcome, FinalIterate)> {
    spec.validate()?;
    match spec.domain {
        JobDomain::Real => run_field::<f32, _, _>(
            spec,
            ctl,
            observer,
            |store, rng| {
                store.add_stiefel_group("x", spec.batch, spec.p, spec.n, rng);
            },
            |opt, store| OptimSession::new(opt, store, None),
        ),
        JobDomain::Complex => run_field::<Complex<f32>, _, _>(
            spec,
            ctl,
            observer,
            |store, rng| {
                store.add_unitary_group("x", spec.batch, spec.p, spec.n, rng);
            },
            OptimSession::new_unitary,
        ),
    }
}

/// The domain-generic execution path: seed parameters, build the problem
/// from its source, resume from a checkpoint when one applies, then
/// drive the step loop. `init` registers the parameter group and
/// `build_session` constructs the engine — the only two domain-specific
/// moves.
fn run_field<E, I, B>(
    spec: &JobSpec,
    ctl: &RunCtl,
    observer: Option<&dyn Fn(&StepProgress)>,
    init: I,
    build_session: B,
) -> Result<(JobOutcome, FinalIterate)>
where
    E: Field + WireElem + checkpoint::CkptDtype,
    I: FnOnce(&mut ParamStore<E>, &mut Rng),
    B: FnOnce(&OptimizerSpec, &ParamStore<E>) -> Result<OptimSession<E>>,
{
    let trace = ctl.trace.filter(|_| crate::obs::enabled());
    let t_build = trace.map(|tr| tr.now_us());
    let mut rng = Rng::seed_from_u64(spec.seed);
    let mut store: ParamStore<E> = ParamStore::new();
    init(&mut store, &mut rng);
    let problem = ProblemData::<E>::build(spec, &mut rng)?;

    // Resume: an existing checkpoint replaces the seeded parameters and
    // fast-forwards the step counter (builtin problem data is regenerated
    // from the seed and inline data rides in the spec, so the objective
    // is identical).
    let mut start_step = 0usize;
    let ckpt = if spec.checkpoint_every > 0 { ctl.checkpoint_path.clone() } else { None };
    if let Some(path) = &ckpt {
        if path.exists() {
            let t_resume = trace.map(|tr| tr.now_us());
            // A bad checkpoint degrades to a fresh start instead of
            // failing the job: the spec is still valid, only the saved
            // progress is lost (saves are write-then-rename, so this is
            // a stale-file edge case, not the common crash path).
            match checkpoint::load_t::<E>(path) {
                Ok((loaded, step))
                    if loaded.len() == store.len()
                        && loaded
                            .params()
                            .iter()
                            .zip(store.params())
                            .all(|(a, b)| a.mat.shape() == b.mat.shape()) =>
                {
                    store = loaded;
                    start_step = step.min(spec.steps);
                }
                Ok(_) => log::warn!(
                    "checkpoint {} does not match the job's shapes; restarting from step 0",
                    path.display()
                ),
                Err(e) => log::warn!(
                    "unreadable checkpoint {} ({e:#}); restarting from step 0",
                    path.display()
                ),
            }
            if let (Some(tr), Some(t0)) = (trace, t_resume) {
                tr.record_span("resume", t0, tr.now_us() - t0, 3);
            }
        }
    }

    let mut session = build_session(&spec.optimizer, &store)?;
    if let (Some(tr), Some(t0)) = (trace, t_build) {
        tr.record_span("build", t0, tr.now_us() - t0, 2);
    }
    // `ckpt` is Some exactly when checkpointing applies (path given AND
    // checkpoint_every > 0, resolved above) — the single gate.
    let ckpt_for_save = ckpt.clone();
    let mut save = move |st: &ParamStore<E>, step: usize| -> Result<()> {
        if let Some(p) = &ckpt_for_save {
            checkpoint::save_t::<E>(st, step, p)
                .with_context(|| format!("checkpointing to {}", p.display()))?;
        }
        Ok(())
    };
    let saver: Option<&mut dyn FnMut(&ParamStore<E>, usize) -> Result<()>> =
        if ckpt.is_some() { Some(&mut save) } else { None };
    let outcome =
        drive(spec, ctl, observer, &mut session, &mut store, &problem, start_step, saver)?;
    let outcome = attach_checkpoint(outcome, ckpt);
    let iterate = FinalIterate::pack(spec.domain, spec, &store);
    Ok((outcome, iterate))
}

fn attach_checkpoint(outcome: JobOutcome, ckpt: Option<PathBuf>) -> JobOutcome {
    let stamp = |mut r: JobResult| {
        r.checkpoint = ckpt.filter(|p| p.exists());
        r
    };
    match outcome {
        JobOutcome::Done(r) => JobOutcome::Done(stamp(r)),
        JobOutcome::Cancelled(r) => JobOutcome::Cancelled(stamp(r)),
    }
}

/// The step loop shared by both domains.
#[allow(clippy::too_many_arguments)]
fn drive<E: Field>(
    spec: &JobSpec,
    ctl: &RunCtl,
    observer: Option<&dyn Fn(&StepProgress)>,
    session: &mut OptimSession<E>,
    store: &mut ParamStore<E>,
    problem: &ProblemData<E>,
    start_step: usize,
    mut save: Option<&mut dyn FnMut(&ParamStore<E>, usize) -> Result<()>>,
) -> Result<JobOutcome> {
    let clock = crate::util::Stopwatch::start();
    let mut steps_done = start_step;
    let mut last_ortho = f64::NAN;
    // Flight recorder: one `steps` span for the whole loop, sampled
    // window spans every `win` steps (never per step), and a span per
    // checkpoint save. All trace reads are behind one Option check.
    let trace = ctl.trace.filter(|_| crate::obs::enabled());
    let t_steps = trace.map(|tr| tr.now_us());
    let win = (spec.steps / 32).max(16);
    let mut win_from_us = t_steps;
    let mut win_from_step = start_step;
    let close_steps_span = |tr: &crate::obs::JobTrace, t0: u64| {
        tr.record_span("steps", t0, tr.now_us() - t0, 2);
    };
    for step in start_step..spec.steps {
        if let Some(flag) = ctl.cancel {
            if flag.load(Ordering::Relaxed) {
                let loss = problem.loss(spec, step, store);
                if let (Some(tr), Some(t0)) = (trace, t_steps) {
                    close_steps_span(tr, t0);
                }
                return Ok(JobOutcome::Cancelled(JobResult {
                    final_loss: loss,
                    ortho_error: store.max_stiefel_distance(),
                    steps_done,
                    wall_s: clock.seconds(),
                    checkpoint: None,
                }));
            }
        }
        let (loss, grads) = problem.lossgrad(spec, step, store);
        session.apply(store, &grads)?;
        steps_done = step + 1;
        if let Some(cb) = ctl.on_step {
            cb(steps_done, loss);
        }
        if let Some(obs) = observer {
            if last_ortho.is_nan()
                || steps_done % ORTHO_EVERY == 0
                || steps_done == spec.steps
            {
                last_ortho = store.max_stiefel_distance();
            }
            obs(&StepProgress {
                step: steps_done,
                loss,
                ortho_error: last_ortho,
                wall_s: clock.seconds(),
            });
        }
        if let (Some(tr), Some(t0)) = (trace, win_from_us) {
            if steps_done - win_from_step >= win || steps_done == spec.steps {
                let now = tr.now_us();
                tr.record_span_full(
                    "steps",
                    t0,
                    now - t0,
                    3,
                    Some((win_from_step as u64, steps_done as u64)),
                );
                win_from_us = Some(now);
                win_from_step = steps_done;
            }
        }
        if let Some(s) = save.as_mut() {
            if spec.checkpoint_every > 0 && steps_done % spec.checkpoint_every == 0 {
                // Close the in-flight window before the save so window and
                // checkpoint spans never overlap (child self-times must
                // not double-count under the `steps` parent).
                let t_ck = trace.map(|tr| {
                    let now = tr.now_us();
                    if let Some(t0) = win_from_us {
                        if win_from_step < steps_done {
                            tr.record_span_full(
                                "steps",
                                t0,
                                now - t0,
                                3,
                                Some((win_from_step as u64, steps_done as u64)),
                            );
                        }
                    }
                    now
                });
                s(store, steps_done)?;
                if let (Some(tr), Some(t0)) = (trace, t_ck) {
                    let now = tr.now_us();
                    tr.record_span("checkpoint", t0, now - t0, 3);
                    win_from_us = Some(now);
                    win_from_step = steps_done;
                }
            }
        }
    }
    let final_loss = problem.loss(spec, spec.steps, store);
    if let (Some(tr), Some(t0)) = (trace, t_steps) {
        close_steps_span(tr, t0);
    }
    Ok(JobOutcome::Done(JobResult {
        final_loss,
        ortho_error: store.max_stiefel_distance(),
        steps_done,
        wall_s: clock.seconds(),
        checkpoint: None,
    }))
}

/// Problem data, built once per run. Builtin sources generate from the
/// job seed (after the parameter init draws, in a fixed order — part of
/// the determinism contract); inline sources decode the spec's payload
/// (already shape/width-validated at admission); artifact sources decode
/// the payload the queue resolved from the store — through the **same**
/// `InlineMat` path as inline data, which is what makes an artifact run
/// bit-identical to the equivalent inline run.
enum ProblemData<E: Field> {
    Procrustes { a: Vec<Mat<E>>, b: Vec<Mat<E>> },
    Pca { c: Vec<Mat<E>> },
    Quartic,
    Replay,
}

impl<E: Field + WireElem> ProblemData<E> {
    fn build(spec: &JobSpec, rng: &mut Rng) -> Result<ProblemData<E>> {
        let (bsz, p, n) = (spec.batch, spec.p, spec.n);
        Ok(match &spec.source {
            ProblemSource::Builtin(kind) => match kind {
                ProblemKind::Procrustes => {
                    let mut a = Vec::with_capacity(bsz);
                    let mut b = Vec::with_capacity(bsz);
                    for _ in 0..bsz {
                        a.push(Mat::<E>::randn(p, p, rng));
                        b.push(Mat::<E>::randn(p, n, rng));
                    }
                    ProblemData::Procrustes { a, b }
                }
                ProblemKind::Pca => {
                    let c = (0..bsz)
                        .map(|_| {
                            let m = Mat::<E>::randn(p, n, rng);
                            matmul_ah_b(&m, &m).scale(E::from_f64(1.0 / n as f64))
                        })
                        .collect();
                    ProblemData::Pca { c }
                }
                ProblemKind::Quartic => ProblemData::Quartic,
                ProblemKind::Replay => ProblemData::Replay,
            },
            ProblemSource::Inline(inline) => Self::from_inline(inline)?,
            ProblemSource::Artifact(art) => match art.resolved() {
                Some(inline) => Self::from_inline(inline)?,
                None => {
                    return Err(anyhow!(
                        "artifact {} is not resolved — artifact jobs must be admitted through \
                         a daemon running with --artifact-dir",
                        art.short()
                    ))
                }
            },
        })
    }

    /// Decode an inline-form payload into typed matrices. The single
    /// decode path shared by the `inline` source and store-resolved
    /// artifact payloads, so the two sources cannot diverge bit-wise.
    fn from_inline(inline: &InlineProblem) -> Result<ProblemData<E>> {
        let decode = |mats: &[InlineMat]| -> Result<Vec<Mat<E>>> {
            mats.iter().map(InlineMat::to_mat::<E>).collect()
        };
        Ok(match inline {
            InlineProblem::Procrustes { a, b } => {
                ProblemData::Procrustes { a: decode(a)?, b: decode(b)? }
            }
            InlineProblem::Pca { c } => ProblemData::Pca { c: decode(c)? },
        })
    }
}

impl<E: Field> ProblemData<E> {
    /// Loss and per-parameter Euclidean gradients at the current iterate
    /// (indexed by store parameter index, as `OptimSession::apply`
    /// expects). `step` only matters for the replay stream.
    fn lossgrad(&self, spec: &JobSpec, step: usize, store: &ParamStore<E>) -> (f64, Vec<Mat<E>>) {
        self.eval(spec, step, store, true)
    }

    /// Loss alone — the cancellation/final-report path, skipping the
    /// gradient products and allocations `lossgrad` would discard.
    fn loss(&self, spec: &JobSpec, step: usize, store: &ParamStore<E>) -> f64 {
        self.eval(spec, step, store, false).0
    }

    fn eval(
        &self,
        spec: &JobSpec,
        step: usize,
        store: &ParamStore<E>,
        want_grads: bool,
    ) -> (f64, Vec<Mat<E>>) {
        let mut loss = 0.0f64;
        let mut grads = Vec::with_capacity(if want_grads { store.len() } else { 0 });
        match self {
            ProblemData::Procrustes { a, b } => {
                for i in 0..store.len() {
                    let r = matmul(&a[i], store.mat(i)).sub(&b[i]);
                    loss += r.norm_sq().to_f64();
                    if want_grads {
                        grads.push(matmul_ah_b(&a[i], &r).scale(E::from_f64(2.0)));
                    }
                }
            }
            ProblemData::Pca { c } => {
                for i in 0..store.len() {
                    let x = store.mat(i);
                    let xc = matmul(x, &c[i]);
                    loss -= xc.dot_re(x).to_f64();
                    if want_grads {
                        grads.push(xc.scale(E::from_f64(-2.0)));
                    }
                }
            }
            ProblemData::Quartic => {
                for i in 0..store.len() {
                    let x = store.mat(i);
                    let mut l = 0.0f64;
                    for &v in x.as_slice() {
                        let a = v.abs_sq().to_f64();
                        l += a * a;
                    }
                    loss += l;
                    if want_grads {
                        grads.push(x.map(|v| v * E::from_re(v.abs_sq()) * E::from_f64(4.0)));
                    }
                }
            }
            ProblemData::Replay => {
                // Per-step seeding (not a sequential stream) so a resumed
                // job replays the exact gradients of the steps it skips.
                let mut srng = Rng::seed_from_u64(
                    spec.seed
                        ^ 0x9E37_79B9_7F4A_7C15
                        ^ (step as u64).wrapping_mul(0x0100_0000_01b3),
                );
                for i in 0..store.len() {
                    let (p, n) = store.mat(i).shape();
                    let g = Mat::<E>::randn(p, n, &mut srng);
                    let nn = g.norm().to_f64().max(1e-12);
                    let g = g.scale(E::from_f64(0.1 / nn));
                    loss += store.mat(i).dot_re(&g).to_f64();
                    if want_grads {
                        grads.push(g);
                    }
                }
            }
        }
        (loss, grads)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{Engine, Method};

    fn small(problem: ProblemKind) -> JobSpec {
        let mut s = JobSpec::new(problem, 3, 3, 5);
        s.steps = 30;
        s.seed = 11;
        s.optimizer = OptimizerSpec::new(Method::Pogo, 0.05);
        s
    }

    /// An inline procrustes spec whose payload was drawn from `data_seed`
    /// (independent of the job seed, like a real client upload).
    fn inline_spec(data_seed: u64) -> JobSpec {
        let mut rng = Rng::seed_from_u64(data_seed);
        let (bsz, p, n) = (2usize, 3usize, 5usize);
        let a = (0..bsz).map(|_| InlineMat::from_mat(&Mat::<f32>::randn(p, p, &mut rng))).collect();
        let b = (0..bsz).map(|_| InlineMat::from_mat(&Mat::<f32>::randn(p, n, &mut rng))).collect();
        let mut s = JobSpec::new(ProblemKind::Procrustes, bsz, p, n);
        s.source = ProblemSource::Inline(InlineProblem::Procrustes { a, b });
        s.steps = 25;
        s.seed = 11;
        s
    }

    #[test]
    fn json_roundtrip() {
        let mut spec = small(ProblemKind::Procrustes);
        spec.name = "rt".into();
        spec.domain = JobDomain::Complex;
        spec.checkpoint_every = 7;
        spec.seed = u64::MAX;
        spec.optimizer = spec.optimizer.with_engine(Engine::BatchedHost);
        let text = spec.to_json().to_string();
        let back = JobSpec::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, spec);
    }

    #[test]
    fn v1_wire_form_is_frozen() {
        // Builtin sources serialize as the bare v1 problem string, so a
        // v1 spec round-trips bit-for-bit through the shim.
        let spec = small(ProblemKind::Quartic);
        let text = spec.to_json().to_string();
        assert!(text.contains(r#""problem":"quartic""#), "{text}");
        let reparsed = JobSpec::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(reparsed.to_json().to_string(), text);
    }

    #[test]
    fn inline_spec_roundtrips_and_runs() {
        let spec = inline_spec(404);
        let text = spec.to_json().to_string();
        let back = JobSpec::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, spec);

        // Deterministic and feasible, like any builtin job.
        let JobOutcome::Done(r1) = run_job(&spec, &RunCtl::default()).unwrap() else { panic!() };
        let JobOutcome::Done(r2) = run_job(&back, &RunCtl::default()).unwrap() else { panic!() };
        assert_eq!(r1.final_loss.to_bits(), r2.final_loss.to_bits());
        assert!(r1.ortho_error <= 1e-3, "{}", r1.ortho_error);
        // Different payloads give different trajectories (the data is
        // really coming from the payload, not the seed).
        let other = inline_spec(405);
        let JobOutcome::Done(r3) = run_job(&other, &RunCtl::default()).unwrap() else { panic!() };
        assert_ne!(r1.final_loss.to_bits(), r3.final_loss.to_bits());
    }

    #[test]
    fn resolved_artifact_runs_bit_identical_to_inline() {
        // The same payload submitted inline and through a (resolved)
        // artifact ref produces the exact same trajectory — the
        // acceptance-criterion property, pinned at the run_job layer.
        let inline = inline_spec(2025);
        let ProblemSource::Inline(payload) = inline.source.clone() else { panic!() };
        let art = crate::artifact::Artifact::seal(
            &payload,
            inline.domain,
            inline.batch,
            inline.p,
            inline.n,
            crate::artifact::Provenance::new(inline.seed),
        )
        .unwrap();
        // Round-trip through the sealed byte form, like a real upload.
        let decoded = crate::artifact::Artifact::decode(&art.encode()).unwrap();
        decoded.verify().unwrap();
        let mut aref = ArtifactRef::new(&decoded.hash()).unwrap();
        aref.resolve(decoded.to_problem().unwrap());
        let mut via_artifact = inline.clone();
        via_artifact.source = ProblemSource::Artifact(aref);

        let JobOutcome::Done(ri) = run_job(&inline, &RunCtl::default()).unwrap() else {
            panic!()
        };
        let (JobOutcome::Done(ra), iter_a) =
            run_job_with(&via_artifact, &RunCtl::default(), None).unwrap()
        else {
            panic!()
        };
        let (_, iter_i) = run_job_with(&inline, &RunCtl::default(), None).unwrap();
        assert_eq!(ri.final_loss.to_bits(), ra.final_loss.to_bits());
        assert_eq!(ri.ortho_error.to_bits(), ra.ortho_error.to_bits());
        assert_eq!(iter_i, iter_a, "final iterates are bit-identical");

        // An unresolved ref is a clear error, not a panic.
        let mut unresolved = inline.clone();
        unresolved.source =
            ProblemSource::Artifact(ArtifactRef::new(&decoded.hash()).unwrap());
        let err = run_job(&unresolved, &RunCtl::default()).unwrap_err();
        assert!(format!("{err:#}").contains("not resolved"), "{err:#}");
    }

    #[test]
    fn inline_payload_mismatch_rejected_at_validation() {
        let mut spec = inline_spec(7);
        spec.batch = 3; // payload has 2 matrices
        assert!(spec.validate().is_err());
        let mut spec = inline_spec(7);
        spec.domain = JobDomain::Complex; // real-width payload
        assert!(spec.validate().is_err());
    }

    #[test]
    fn minimal_json_parses_with_defaults() {
        let j = Json::parse(
            r#"{"problem": "quartic", "batch": 2, "p": 2, "n": 4, "steps": 5,
                "optimizer": {"method": "pogo", "lr": 0.1}}"#,
        )
        .unwrap();
        let spec = JobSpec::from_json(&j).unwrap();
        assert_eq!(spec.domain, JobDomain::Real);
        assert_eq!(spec.seed, 0);
        assert_eq!(spec.checkpoint_every, 0);
        assert_eq!(spec.optimizer.method, Method::Pogo);
    }

    #[test]
    fn invalid_specs_rejected() {
        // p > n.
        let j = Json::parse(
            r#"{"problem": "pca", "batch": 1, "p": 5, "n": 3, "steps": 5,
                "optimizer": {"method": "pogo", "lr": 0.1}}"#,
        )
        .unwrap();
        assert!(JobSpec::from_json(&j).is_err());
        // Missing optimizer.
        let j = Json::parse(r#"{"problem": "pca", "batch": 1, "p": 2, "n": 3, "steps": 5}"#)
            .unwrap();
        assert!(JobSpec::from_json(&j).is_err());
        // Unknown problem.
        let j = Json::parse(
            r#"{"problem": "nope", "batch": 1, "p": 2, "n": 3, "steps": 5,
                "optimizer": {"method": "pogo", "lr": 0.1}}"#,
        )
        .unwrap();
        assert!(JobSpec::from_json(&j).is_err());
        // Size ceiling.
        let mut big = small(ProblemKind::Quartic);
        big.batch = 1 << 22;
        big.p = 8;
        big.n = 8;
        assert!(big.validate().is_err());
    }

    #[test]
    fn cost_model_is_b_p_n_steps() {
        let spec = small(ProblemKind::Quartic); // 3 × 3 × 5 × 30
        assert_eq!(spec.cost(), 3 * 3 * 5 * 30);
        let mut huge = small(ProblemKind::Quartic);
        huge.batch = usize::MAX;
        huge.steps = usize::MAX;
        assert_eq!(huge.cost(), u64::MAX, "saturates, never wraps");
    }

    #[test]
    fn every_problem_runs_and_stays_feasible() {
        for &pk in ProblemKind::all() {
            let spec = small(pk);
            let out = run_job(&spec, &RunCtl::default()).unwrap();
            let JobOutcome::Done(r) = out else { panic!("{}: not done", pk.name()) };
            assert_eq!(r.steps_done, spec.steps, "{}", pk.name());
            assert!(r.ortho_error <= 1e-3, "{}: {}", pk.name(), r.ortho_error);
            assert!(r.final_loss.is_finite(), "{}", pk.name());
        }
    }

    #[test]
    fn deterministic_across_runs_and_engines_are_consistent() {
        let spec = small(ProblemKind::Pca);
        let a = run_job(&spec, &RunCtl::default()).unwrap();
        let b = run_job(&spec, &RunCtl::default()).unwrap();
        let (JobOutcome::Done(ra), JobOutcome::Done(rb)) = (a, b) else { panic!() };
        assert_eq!(ra.final_loss.to_bits(), rb.final_loss.to_bits());
        assert_eq!(ra.ortho_error.to_bits(), rb.ortho_error.to_bits());

        // The batched engine follows the loop engine closely (exact
        // parity is pinned engine-wide by tests/batched_parity.rs).
        let mut batched = spec.clone();
        batched.optimizer = batched.optimizer.with_engine(Engine::BatchedHost);
        let JobOutcome::Done(rc) = run_job(&batched, &RunCtl::default()).unwrap() else {
            panic!()
        };
        assert!((rc.final_loss - ra.final_loss).abs() <= 1e-3 * ra.final_loss.abs().max(1.0));
    }

    #[test]
    fn observer_sees_monotone_steps_and_matches_run_job() {
        // The observer path adds telemetry, not numerics: the final loss
        // is bit-identical to a plain run, steps arrive strictly
        // increasing, and the iterate matches what the store held.
        let spec = small(ProblemKind::Procrustes);
        let seen = std::cell::RefCell::new(Vec::<StepProgress>::new());
        let obs = |p: &StepProgress| seen.borrow_mut().push(*p);
        let (outcome, iterate) =
            run_job_with(&spec, &RunCtl::default(), Some(&obs)).unwrap();
        let JobOutcome::Done(r) = outcome else { panic!() };
        let seen = seen.into_inner();
        assert_eq!(seen.len(), spec.steps);
        assert!(seen.windows(2).all(|w| w[0].step < w[1].step), "monotone steps");
        assert_eq!(seen.last().unwrap().step, spec.steps);
        assert!((seen.last().unwrap().ortho_error - r.ortho_error).abs() < 1e-12);
        assert!(seen.iter().all(|p| p.loss.is_finite() && p.ortho_error <= 1e-3));

        let JobOutcome::Done(plain) = run_job(&spec, &RunCtl::default()).unwrap() else {
            panic!()
        };
        assert_eq!(plain.final_loss.to_bits(), r.final_loss.to_bits());

        // Iterate dimensions and payload width match the job.
        assert_eq!(iterate.domain, JobDomain::Real);
        assert_eq!(
            iterate.data.len(),
            spec.batch * spec.p * spec.n,
            "one f32 word per real scalar"
        );
        assert!(iterate.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn complex_domain_runs() {
        let mut spec = small(ProblemKind::Quartic);
        spec.domain = JobDomain::Complex;
        spec.batch = 2;
        let JobOutcome::Done(r) = run_job(&spec, &RunCtl::default()).unwrap() else { panic!() };
        assert!(r.ortho_error <= 1e-3, "{}", r.ortho_error);
        // Batched complex engine too.
        spec.optimizer = spec.optimizer.with_engine(Engine::BatchedHost);
        let JobOutcome::Done(r) = run_job(&spec, &RunCtl::default()).unwrap() else { panic!() };
        assert!(r.ortho_error <= 1e-3, "{}", r.ortho_error);
    }

    #[test]
    fn bad_engine_fails_without_panicking() {
        let mut spec = small(ProblemKind::Quartic);
        spec.optimizer = spec.optimizer.with_engine(Engine::Xla);
        assert!(run_job(&spec, &RunCtl::default()).is_err());
        // RSDM has no complex engine.
        let mut spec = small(ProblemKind::Quartic);
        spec.domain = JobDomain::Complex;
        spec.optimizer = OptimizerSpec::new(Method::Rsdm, 0.05);
        assert!(run_job(&spec, &RunCtl::default()).is_err());
    }

    #[test]
    fn cancel_flag_stops_mid_run() {
        let spec = {
            let mut s = small(ProblemKind::Replay);
            s.steps = 10_000;
            s
        };
        let cancel = AtomicBool::new(false);
        let seen = std::cell::Cell::new(0usize);
        let on_step = |step: usize, _loss: f64| {
            seen.set(step);
            if step >= 5 {
                cancel.store(true, Ordering::Relaxed);
            }
        };
        let ctl =
            RunCtl { cancel: Some(&cancel), on_step: Some(&on_step), ..Default::default() };
        let JobOutcome::Cancelled(r) = run_job(&spec, &ctl).unwrap() else {
            panic!("expected cancellation")
        };
        assert!(r.steps_done >= 5 && r.steps_done < spec.steps);
        assert_eq!(seen.get(), r.steps_done);
    }

    #[test]
    fn corrupt_checkpoint_degrades_to_fresh_start() {
        let dir = std::env::temp_dir()
            .join(format!("pogo_serve_job_corrupt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("job.ckpt");
        std::fs::write(&path, b"definitely not a checkpoint").unwrap();
        let mut spec = small(ProblemKind::Quartic);
        spec.checkpoint_every = 5;
        let ctl = RunCtl { checkpoint_path: Some(path.clone()), ..Default::default() };
        let JobOutcome::Done(r) = run_job(&spec, &ctl).unwrap() else {
            panic!("corrupt checkpoint must not fail the job")
        };
        assert_eq!(r.steps_done, spec.steps);
        // And the bad file has been replaced by a real checkpoint.
        assert!(checkpoint::load(&path).is_ok());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn checkpoint_resume_completes_from_midpoint() {
        let dir = std::env::temp_dir()
            .join(format!("pogo_serve_job_resume_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("job.ckpt");
        std::fs::remove_file(&path).ok();

        let mut spec = small(ProblemKind::Procrustes);
        spec.steps = 40;
        spec.checkpoint_every = 10;

        // First attempt: cancel after the step-20 checkpoint landed.
        let cancel = AtomicBool::new(false);
        let on_step = |step: usize, _loss: f64| {
            if step >= 25 {
                cancel.store(true, Ordering::Relaxed);
            }
        };
        let ctl = RunCtl {
            cancel: Some(&cancel),
            on_step: Some(&on_step),
            checkpoint_path: Some(path.clone()),
            ..Default::default()
        };
        let JobOutcome::Cancelled(_) = run_job(&spec, &ctl).unwrap() else {
            panic!("expected cancellation")
        };
        let (_, step) = checkpoint::load(&path).unwrap();
        assert!(step >= 20, "checkpoint at step {step}");

        // Second attempt resumes from the checkpoint and completes.
        let ctl =
            RunCtl { checkpoint_path: Some(path.clone()), ..Default::default() };
        let JobOutcome::Done(r) = run_job(&spec, &ctl).unwrap() else { panic!() };
        assert_eq!(r.steps_done, spec.steps);
        assert!(r.ortho_error <= 1e-3);
        assert_eq!(r.checkpoint.as_deref(), Some(path.as_path()));

        // POGO/sgd is stateless, so the resumed trajectory equals the
        // uninterrupted one bit-for-bit.
        std::fs::remove_file(&path).ok();
        let direct =
            run_job(&spec, &RunCtl { checkpoint_path: Some(path.clone()), ..Default::default() })
                .unwrap();
        let JobOutcome::Done(rd) = direct else { panic!() };
        assert_eq!(rd.final_loss.to_bits(), r.final_loss.to_bits());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn complex_checkpoint_resume_roundtrip() {
        // The satellite: a unitary job checkpoints as interleaved c64
        // pairs and a resumed run completes bit-identically to an
        // uninterrupted one (POGO/sgd is stateless).
        let dir = std::env::temp_dir()
            .join(format!("pogo_serve_job_cresume_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("job.ckpt");
        std::fs::remove_file(&path).ok();

        let mut spec = small(ProblemKind::Quartic);
        spec.domain = JobDomain::Complex;
        spec.batch = 2;
        spec.steps = 40;
        spec.checkpoint_every = 10;

        let cancel = AtomicBool::new(false);
        let on_step = |step: usize, _loss: f64| {
            if step >= 25 {
                cancel.store(true, Ordering::Relaxed);
            }
        };
        let ctl = RunCtl {
            cancel: Some(&cancel),
            on_step: Some(&on_step),
            checkpoint_path: Some(path.clone()),
            ..Default::default()
        };
        let JobOutcome::Cancelled(_) = run_job(&spec, &ctl).unwrap() else {
            panic!("expected cancellation")
        };
        // On disk it is a c64 checkpoint: the f32 loader refuses it.
        let (_, step) = checkpoint::load_t::<Complex<f32>>(&path).unwrap();
        assert!(step >= 20, "checkpoint at step {step}");
        assert!(checkpoint::load(&path).is_err(), "c64 is not silently read as f32");

        let ctl = RunCtl { checkpoint_path: Some(path.clone()), ..Default::default() };
        let JobOutcome::Done(r) = run_job(&spec, &ctl).unwrap() else { panic!() };
        assert_eq!(r.steps_done, spec.steps);
        assert!(r.ortho_error <= 1e-3);
        assert_eq!(r.checkpoint.as_deref(), Some(path.as_path()));

        std::fs::remove_file(&path).ok();
        let JobOutcome::Done(rd) =
            run_job(&spec, &RunCtl { checkpoint_path: Some(path.clone()), ..Default::default() })
                .unwrap()
        else {
            panic!()
        };
        assert_eq!(rd.final_loss.to_bits(), r.final_loss.to_bits());
        std::fs::remove_dir_all(&dir).ok();
    }
}
