//! Problem sources: where a job's objective comes from.
//!
//! v1 hard-coded a closed [`ProblemKind`] enum of four seeded problems.
//! v2 opens that up into a [`ProblemSource`] — a small registry of
//! problem *builders* keyed by a `source` name:
//!
//! - `builtin` — the seeded procrustes/pca/quartic/replay objectives,
//!   fully determined by `(seed, batch, p, n)`. The v1 wire form
//!   (`"problem": "procrustes"`) is a compatibility shim onto this
//!   source and serializes back bit-for-bit.
//! - `inline` — client-supplied matrices (base64-packed little-endian
//!   f32, or plain JSON number arrays) shipped inside the job spec and
//!   validated against `(batch, p, n)` and the domain *before*
//!   admission. This is how real workloads (the sketched-landing /
//!   stochastic regimes of PAPERS.md) feed their own objective data to
//!   the daemon instead of replaying seeded stand-ins.
//! - `artifact` — a sealed [`crate::artifact`] payload already sitting
//!   in the daemon's content-addressed store, referenced by its sha256
//!   manifest hash ("upload once, run many"). The spec carries only the
//!   hash; the queue resolves it against the store at admission, so
//!   repeat submissions skip payload revalidation entirely.
//!
//! New sources register by adding a [`SourceBuilder`] to
//! [`source_registry`] — the parse/validate/build plumbing is shared.

use crate::linalg::{Complex, Field, Mat};
use crate::util::{b64, json::Json};
use anyhow::{anyhow, ensure, Context, Result};

use super::job::JobDomain;

/// The seeded objectives of the `builtin` source (the closed v1 set).
/// All four are matmul/elementwise only, defined on both domains, and
/// fully determined by `(seed, batch, p, n)` — no data upload.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProblemKind {
    /// `Σᵢ ‖Aᵢ Xᵢ − Bᵢ‖²`, `Aᵢ ∈ F^{p×p}`, `Bᵢ ∈ F^{p×n}` Gaussian
    /// (Fig. 4-right generalized to wide X and B > 1).
    Procrustes,
    /// PCA-style `Σᵢ −Re Tr(Xᵢ Cᵢ Xᵢᴴ)` with `Cᵢ = Mᵢᴴ Mᵢ / n` PSD.
    Pca,
    /// Quartic localization `Σᵢ Σⱼₖ |Xᵢ[j,k]|⁴` (gradient `4 |x|² x`).
    Quartic,
    /// Raw gradient-replay: per-step seeded Gaussian pseudo-gradients of
    /// norm 0.1; the reported "loss" is `Σᵢ Re⟨Xᵢ, Gᵢ⟩` (a deterministic
    /// trajectory fingerprint, not an objective).
    Replay,
}

impl ProblemKind {
    pub fn name(self) -> &'static str {
        match self {
            ProblemKind::Procrustes => "procrustes",
            ProblemKind::Pca => "pca",
            ProblemKind::Quartic => "quartic",
            ProblemKind::Replay => "replay",
        }
    }

    pub fn parse(s: &str) -> Option<ProblemKind> {
        Some(match s.to_ascii_lowercase().as_str() {
            "procrustes" => ProblemKind::Procrustes,
            "pca" => ProblemKind::Pca,
            "quartic" => ProblemKind::Quartic,
            "replay" | "grad-replay" | "gradient-replay" => ProblemKind::Replay,
            _ => return None,
        })
    }

    pub fn all() -> &'static [ProblemKind] {
        &[ProblemKind::Procrustes, ProblemKind::Pca, ProblemKind::Quartic, ProblemKind::Replay]
    }
}

/// A matrix element the v2 wire formats can carry: packed as f32 words
/// (real: one word per element; complex: an interleaved `re,im` pair).
/// Shared by inline problem payloads (decode) and final-iterate dumps
/// (encode).
pub trait WireElem: Field {
    /// f32 words per element.
    const WIDTH: usize;
    fn from_words(words: &[f32]) -> Self;
    fn push_words(self, out: &mut Vec<f32>);
}

impl WireElem for f32 {
    const WIDTH: usize = 1;
    #[inline]
    fn from_words(words: &[f32]) -> Self {
        words[0]
    }
    #[inline]
    fn push_words(self, out: &mut Vec<f32>) {
        out.push(self);
    }
}

impl WireElem for Complex<f32> {
    const WIDTH: usize = 2;
    #[inline]
    fn from_words(words: &[f32]) -> Self {
        Complex::new(words[0], words[1])
    }
    #[inline]
    fn push_words(self, out: &mut Vec<f32>) {
        out.push(self.re);
        out.push(self.im);
    }
}

/// Pack f32 words as base64 little-endian bytes (the compact wire form).
pub fn words_to_b64(words: &[f32]) -> String {
    let bytes: Vec<u8> = words.iter().flat_map(|v| v.to_le_bytes()).collect();
    b64::encode(&bytes)
}

/// Decode base64 little-endian bytes back into f32 words.
pub fn b64_to_words(text: &str) -> Result<Vec<f32>> {
    let bytes = b64::decode(text).map_err(|e| anyhow!("bad base64 payload: {e}"))?;
    ensure!(bytes.len() % 4 == 0, "base64 payload is {} bytes, not a multiple of 4", bytes.len());
    Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
}

/// One client-supplied matrix: raw f32 words in row-major order (complex
/// entries interleave `re,im`, so `data.len() == rows·cols·width`).
#[derive(Clone, Debug, PartialEq)]
pub struct InlineMat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl InlineMat {
    /// Build from a typed matrix (what tests and in-process clients use).
    pub fn from_mat<E: WireElem>(m: &Mat<E>) -> InlineMat {
        let mut data = Vec::with_capacity(m.len() * E::WIDTH);
        for &v in m.as_slice() {
            v.push_words(&mut data);
        }
        InlineMat { rows: m.rows(), cols: m.cols(), data }
    }

    /// Decode into a typed matrix. The element width (real vs complex)
    /// must match the stored word count — checked, never reinterpreted.
    pub fn to_mat<E: WireElem>(&self) -> Result<Mat<E>> {
        let want = self.rows * self.cols * E::WIDTH;
        ensure!(
            self.data.len() == want,
            "inline matrix has {} words, but a {}x{} {} matrix needs {want}",
            self.data.len(),
            self.rows,
            self.cols,
            if E::WIDTH == 2 { "complex" } else { "real" },
        );
        let elems = self.data.chunks_exact(E::WIDTH).map(E::from_words).collect();
        Ok(Mat::from_vec(self.rows, self.cols, elems))
    }

    /// Payload size in bytes (what `--max-inline-bytes` caps).
    pub fn byte_len(&self) -> usize {
        self.data.len() * 4
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("rows", Json::num(self.rows as f64)),
            ("cols", Json::num(self.cols as f64)),
            ("b64", Json::str(words_to_b64(&self.data))),
        ])
    }

    /// Parse `{"rows", "cols", "b64"}` or `{"rows", "cols", "data": [..]}`.
    pub fn from_json(j: &Json) -> Result<InlineMat> {
        let rows = j.get("rows").as_usize().ok_or_else(|| anyhow!("inline matrix: missing or non-integer 'rows'"))?;
        let cols = j.get("cols").as_usize().ok_or_else(|| anyhow!("inline matrix: missing or non-integer 'cols'"))?;
        ensure!(rows >= 1 && cols >= 1, "inline matrix: rows/cols must be >= 1");
        let data = match (j.get("b64"), j.get("data")) {
            (Json::Null, Json::Null) => {
                return Err(anyhow!("inline matrix: need 'b64' or 'data'"));
            }
            (b, Json::Null) => {
                let text = b.as_str().ok_or_else(|| anyhow!("inline matrix: 'b64' must be a string"))?;
                b64_to_words(text)?
            }
            (Json::Null, d) => {
                let arr = d.as_arr().ok_or_else(|| anyhow!("inline matrix: 'data' must be an array"))?;
                arr.iter()
                    .map(|v| {
                        v.as_f64()
                            .map(|x| x as f32)
                            .ok_or_else(|| anyhow!("inline matrix: 'data' must hold numbers"))
                    })
                    .collect::<Result<Vec<f32>>>()?
            }
            _ => return Err(anyhow!("inline matrix: give 'b64' or 'data', not both")),
        };
        Ok(InlineMat { rows, cols, data })
    }
}

/// A client-supplied objective: which family the payload feeds, plus the
/// per-matrix data. Shapes are validated against the job's `(batch, p, n)`
/// and domain at admission — a bad payload is a 400, never a failed job.
#[derive(Clone, Debug, PartialEq)]
pub enum InlineProblem {
    /// `Σᵢ ‖Aᵢ Xᵢ − Bᵢ‖²` with client `Aᵢ` (p×p) and `Bᵢ` (p×n).
    Procrustes { a: Vec<InlineMat>, b: Vec<InlineMat> },
    /// `Σᵢ −Re Tr(Xᵢ Cᵢ Xᵢᴴ)` with client `Cᵢ` (n×n).
    Pca { c: Vec<InlineMat> },
}

impl InlineProblem {
    pub fn objective(&self) -> &'static str {
        match self {
            InlineProblem::Procrustes { .. } => "procrustes",
            InlineProblem::Pca { .. } => "pca",
        }
    }

    /// Total payload bytes across every matrix.
    pub fn payload_bytes(&self) -> usize {
        match self {
            InlineProblem::Procrustes { a, b } => {
                a.iter().chain(b).map(InlineMat::byte_len).sum()
            }
            InlineProblem::Pca { c } => c.iter().map(InlineMat::byte_len).sum(),
        }
    }

    /// Admission-time validation: the structural half
    /// ([`InlineProblem::validate_structure`]) plus an O(payload) scan
    /// that every word is finite.
    pub fn validate(&self, domain: JobDomain, batch: usize, p: usize, n: usize) -> Result<()> {
        self.validate_structure(domain, batch, p, n)?;
        let scan = |name: &str, mats: &[InlineMat]| -> Result<()> {
            for (i, m) in mats.iter().enumerate() {
                ensure!(
                    m.data.iter().all(|v| v.is_finite()),
                    "inline '{name}[{i}]': payload contains non-finite values"
                );
            }
            Ok(())
        };
        match self {
            InlineProblem::Procrustes { a, b } => {
                scan("a", a)?;
                scan("b", b)
            }
            InlineProblem::Pca { c } => scan("c", c),
        }
    }

    /// The cheap structural half of [`InlineProblem::validate`]: matrix
    /// counts match the batch, shapes match the objective family, and
    /// word counts match the domain's element width. O(batch), no
    /// payload scan — what the queue's artifact-dedupe path runs before
    /// hashing, deferring the value scan to first-seen payloads only.
    pub fn validate_structure(
        &self,
        domain: JobDomain,
        batch: usize,
        p: usize,
        n: usize,
    ) -> Result<()> {
        let width = match domain {
            JobDomain::Real => 1usize,
            JobDomain::Complex => 2usize,
        };
        let check = |name: &str, mats: &[InlineMat], rows: usize, cols: usize| -> Result<()> {
            ensure!(
                mats.len() == batch,
                "inline '{name}': {} matrices for batch {batch}",
                mats.len()
            );
            for (i, m) in mats.iter().enumerate() {
                ensure!(
                    m.rows == rows && m.cols == cols,
                    "inline '{name}[{i}]': shape ({}, {}) but the job needs ({rows}, {cols})",
                    m.rows,
                    m.cols
                );
                ensure!(
                    m.data.len() == rows * cols * width,
                    "inline '{name}[{i}]': {} words for a {rows}x{cols} {} matrix (need {})",
                    m.data.len(),
                    domain.name(),
                    rows * cols * width
                );
            }
            Ok(())
        };
        match self {
            InlineProblem::Procrustes { a, b } => {
                check("a", a, p, p)?;
                check("b", b, p, n)
            }
            InlineProblem::Pca { c } => check("c", c, n, n),
        }
    }

    pub fn to_json(&self) -> Json {
        let mats = |v: &[InlineMat]| Json::arr(v.iter().map(InlineMat::to_json));
        let mut fields = vec![
            ("source", Json::str("inline")),
            ("objective", Json::str(self.objective())),
        ];
        match self {
            InlineProblem::Procrustes { a, b } => {
                fields.push(("a", mats(a)));
                fields.push(("b", mats(b)));
            }
            InlineProblem::Pca { c } => fields.push(("c", mats(c))),
        }
        Json::obj(fields)
    }
}

/// Reference to a sealed artifact in the daemon's content-addressed
/// store. On the wire this is only the 64-hex sha256 manifest hash; the
/// queue resolves the payload from the store at admission (a hash the
/// store does not hold is a 404-class rejection, never a failed job).
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactRef {
    /// Lowercase-hex sha256 of the artifact manifest (the content address).
    pub hash: String,
    /// The payload decoded from the store at admission. Never serialized
    /// — a persisted artifact job re-resolves from the store on recovery.
    resolved: Option<Box<InlineProblem>>,
}

impl ArtifactRef {
    pub fn new(hash: &str) -> Result<ArtifactRef> {
        ensure!(
            crate::util::sha256::is_hex_digest(hash),
            "artifact hash must be 64 lowercase hex chars, got '{hash}'"
        );
        Ok(ArtifactRef { hash: hash.to_string(), resolved: None })
    }

    /// Attach the store-decoded payload (queue admission / worker claim).
    pub fn resolve(&mut self, problem: InlineProblem) {
        self.resolved = Some(Box::new(problem));
    }

    pub fn resolved(&self) -> Option<&InlineProblem> {
        self.resolved.as_deref()
    }

    /// Short display form of the content address.
    pub fn short(&self) -> &str {
        &self.hash[..12]
    }
}

/// Where a job's objective comes from (see module docs).
#[derive(Clone, Debug, PartialEq)]
pub enum ProblemSource {
    Builtin(ProblemKind),
    Inline(InlineProblem),
    Artifact(ArtifactRef),
}

impl ProblemSource {
    /// Display label: the v1 name for builtin problems, `inline:<family>`
    /// for client data (what listings and state files show).
    pub fn label(&self) -> String {
        match self {
            ProblemSource::Builtin(k) => k.name().to_string(),
            ProblemSource::Inline(p) => format!("inline:{}", p.objective()),
            ProblemSource::Artifact(r) => format!("artifact:{}", r.short()),
        }
    }

    /// Inline payload bytes (0 for builtin and artifact sources — an
    /// artifact's payload was size-capped once at upload, not per job).
    pub fn payload_bytes(&self) -> usize {
        match self {
            ProblemSource::Builtin(_) => 0,
            ProblemSource::Inline(p) => p.payload_bytes(),
            ProblemSource::Artifact(_) => 0,
        }
    }

    /// Source-specific admission validation. Artifact payloads were
    /// fully validated when they entered the store, so the per-job check
    /// is only the hash format (enforced at construction) — the whole
    /// point of admitting by content hash.
    pub fn validate(&self, domain: JobDomain, batch: usize, p: usize, n: usize) -> Result<()> {
        match self {
            ProblemSource::Builtin(_) => Ok(()),
            ProblemSource::Inline(inline) => inline.validate(domain, batch, p, n),
            ProblemSource::Artifact(_) => Ok(()),
        }
    }

    /// Serialize. Builtin sources keep the frozen v1 wire form (a bare
    /// string), so v1 specs round-trip bit-for-bit; inline and artifact
    /// sources use the v2 object form (an artifact ref serializes as its
    /// hash alone — resolved payloads never ride the wire).
    pub fn to_json(&self) -> Json {
        match self {
            ProblemSource::Builtin(k) => Json::str(k.name()),
            ProblemSource::Inline(p) => p.to_json(),
            ProblemSource::Artifact(r) => Json::obj(vec![
                ("source", Json::str("artifact")),
                ("hash", Json::str(r.hash.clone())),
            ]),
        }
    }

    /// Parse either wire form (v1 string shim, or v2 `{"source": …}`
    /// object dispatched through the registry).
    pub fn from_json(j: &Json) -> Result<ProblemSource> {
        match j {
            Json::Null => Err(anyhow!("job: missing 'problem'")),
            Json::Str(s) => ProblemKind::parse(s)
                .map(ProblemSource::Builtin)
                .ok_or_else(|| anyhow!("job: unknown problem '{s}'")),
            Json::Obj(_) => {
                let name = j
                    .get("source")
                    .as_str()
                    .ok_or_else(|| anyhow!("job: problem object needs a 'source' name"))?;
                let builder = source_registry()
                    .iter()
                    .find(|b| b.name == name)
                    .ok_or_else(|| {
                        anyhow!(
                            "job: unknown problem source '{name}' (registered: {})",
                            source_registry()
                                .iter()
                                .map(|b| b.name)
                                .collect::<Vec<_>>()
                                .join(", ")
                        )
                    })?;
                (builder.parse)(j).with_context(|| format!("job: in '{name}' problem"))
            }
            _ => Err(anyhow!("job: 'problem' must be a name or a source object")),
        }
    }
}

/// One registered problem source: how to parse its wire form. Building
/// the runtime objective stays with `run_job` (it is domain-generic);
/// what varies per source is the spec-side contract captured here.
pub struct SourceBuilder {
    pub name: &'static str,
    /// One-line human description (served by `GET /v2/problems`).
    pub summary: &'static str,
    pub parse: fn(&Json) -> Result<ProblemSource>,
}

fn parse_builtin(j: &Json) -> Result<ProblemSource> {
    let kind = j
        .get("kind")
        .as_str()
        .ok_or_else(|| anyhow!("builtin source needs a 'kind' name"))?;
    ProblemKind::parse(kind)
        .map(ProblemSource::Builtin)
        .ok_or_else(|| anyhow!("unknown builtin problem '{kind}'"))
}

fn parse_inline(j: &Json) -> Result<ProblemSource> {
    let mats = |key: &str| -> Result<Vec<InlineMat>> {
        let arr = j
            .get(key)
            .as_arr()
            .ok_or_else(|| anyhow!("inline source needs a '{key}' matrix array"))?;
        arr.iter()
            .enumerate()
            .map(|(i, m)| InlineMat::from_json(m).with_context(|| format!("'{key}[{i}]'")))
            .collect()
    };
    let objective = j
        .get("objective")
        .as_str()
        .ok_or_else(|| anyhow!("inline source needs an 'objective' name"))?;
    let inline = match objective {
        "procrustes" => InlineProblem::Procrustes { a: mats("a")?, b: mats("b")? },
        "pca" => InlineProblem::Pca { c: mats("c")? },
        other => {
            return Err(anyhow!(
                "unknown inline objective '{other}' (supported: procrustes, pca)"
            ))
        }
    };
    Ok(ProblemSource::Inline(inline))
}

fn parse_artifact(j: &Json) -> Result<ProblemSource> {
    let hash = j
        .get("hash")
        .as_str()
        .ok_or_else(|| anyhow!("artifact source needs a 'hash' content address"))?;
    Ok(ProblemSource::Artifact(ArtifactRef::new(hash)?))
}

/// The problem-source registry. Open by construction: a new source is
/// one more entry here plus a `ProblemData` build arm in `job.rs`.
pub fn source_registry() -> &'static [SourceBuilder] {
    &[
        SourceBuilder {
            name: "builtin",
            summary: "seeded procrustes/pca/quartic/replay, determined by (seed, batch, p, n)",
            parse: parse_builtin,
        },
        SourceBuilder {
            name: "inline",
            summary: "client-supplied matrices (base64 LE f32 or JSON arrays; procrustes/pca)",
            parse: parse_inline,
        },
        SourceBuilder {
            name: "artifact",
            summary: "a sealed artifact from the daemon store, referenced by sha256 content hash",
            parse: parse_artifact,
        },
    ]
}

/// Registry description for `GET /v2/problems`.
pub fn registry_json() -> Json {
    Json::arr(source_registry().iter().map(|b| {
        Json::obj(vec![
            ("source", Json::str(b.name)),
            ("summary", Json::str(b.summary)),
        ])
    }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn v1_string_shim_and_v2_object_both_parse() {
        let s = ProblemSource::from_json(&Json::str("procrustes")).unwrap();
        assert_eq!(s, ProblemSource::Builtin(ProblemKind::Procrustes));
        // Builtin serializes back to the bare v1 string.
        assert_eq!(s.to_json(), Json::str("procrustes"));
        let v2 = Json::parse(r#"{"source": "builtin", "kind": "pca"}"#).unwrap();
        assert_eq!(
            ProblemSource::from_json(&v2).unwrap(),
            ProblemSource::Builtin(ProblemKind::Pca)
        );
        assert!(ProblemSource::from_json(&Json::str("nope")).is_err());
        let bad = Json::parse(r#"{"source": "martian"}"#).unwrap();
        let err = ProblemSource::from_json(&bad).unwrap_err();
        assert!(format!("{err:#}").contains("registered"), "{err:#}");
    }

    #[test]
    fn inline_roundtrip_exact() {
        let mut rng = Rng::seed_from_u64(3);
        let a: Vec<InlineMat> =
            (0..2).map(|_| InlineMat::from_mat(&Mat::<f32>::randn(3, 3, &mut rng))).collect();
        let b: Vec<InlineMat> =
            (0..2).map(|_| InlineMat::from_mat(&Mat::<f32>::randn(3, 5, &mut rng))).collect();
        let src = ProblemSource::Inline(InlineProblem::Procrustes { a, b });
        let text = src.to_json().to_string();
        let back = ProblemSource::from_json(&Json::parse(&text).unwrap()).unwrap();
        // Base64 f32 packing is exact: bit-for-bit payload round-trip.
        assert_eq!(back, src);
        assert_eq!(src.label(), "inline:procrustes");
        assert!(src.payload_bytes() > 0);
    }

    #[test]
    fn inline_json_array_form_parses() {
        let j = Json::parse(
            r#"{"source": "inline", "objective": "pca",
                "c": [{"rows": 2, "cols": 2, "data": [1.0, 0.5, 0.5, 2.0]}]}"#,
        )
        .unwrap();
        let src = ProblemSource::from_json(&j).unwrap();
        let ProblemSource::Inline(InlineProblem::Pca { c }) = &src else { panic!() };
        assert_eq!(c[0].data, vec![1.0, 0.5, 0.5, 2.0]);
        src.validate(JobDomain::Real, 1, 1, 2).unwrap();
        // Wrong batch / shape / width rejected.
        assert!(src.validate(JobDomain::Real, 2, 1, 2).is_err());
        assert!(src.validate(JobDomain::Real, 1, 1, 3).is_err());
        assert!(src.validate(JobDomain::Complex, 1, 1, 2).is_err());
    }

    #[test]
    fn inline_rejects_malformed_payloads() {
        for bad in [
            // Both b64 and data.
            r#"{"source":"inline","objective":"pca",
                "c":[{"rows":1,"cols":1,"data":[1.0],"b64":"AACAPw=="}]}"#,
            // Neither.
            r#"{"source":"inline","objective":"pca","c":[{"rows":1,"cols":1}]}"#,
            // Bad base64.
            r#"{"source":"inline","objective":"pca","c":[{"rows":1,"cols":1,"b64":"!!"}]}"#,
            // Unknown objective.
            r#"{"source":"inline","objective":"quartic","x":[]}"#,
            // Zero-sized matrix.
            r#"{"source":"inline","objective":"pca","c":[{"rows":0,"cols":1,"data":[]}]}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(ProblemSource::from_json(&j).is_err(), "{bad}");
        }
        // Non-finite payloads are caught at validation.
        let src = ProblemSource::Inline(InlineProblem::Pca {
            c: vec![InlineMat { rows: 1, cols: 1, data: vec![f32::NAN] }],
        });
        assert!(src.validate(JobDomain::Real, 1, 1, 1).is_err());
    }

    #[test]
    fn complex_wire_elements_interleave() {
        let m = Mat::from_vec(
            1,
            2,
            vec![Complex::new(1.0f32, -2.0), Complex::new(0.5, 0.25)],
        );
        let im = InlineMat::from_mat(&m);
        assert_eq!(im.data, vec![1.0, -2.0, 0.5, 0.25]);
        let back: Mat<Complex<f32>> = im.to_mat().unwrap();
        assert_eq!(back, m);
        // Width mismatch is an error, not a reinterpretation.
        assert!(im.to_mat::<f32>().is_err());
    }

    #[test]
    fn registry_lists_every_source() {
        let names: Vec<&str> = source_registry().iter().map(|b| b.name).collect();
        assert_eq!(names, vec!["builtin", "inline", "artifact"]);
        assert_eq!(registry_json().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn artifact_source_roundtrips_hash_only() {
        let hash = crate::util::sha256::hex(b"some payload");
        let j = Json::parse(&format!(r#"{{"source": "artifact", "hash": "{hash}"}}"#)).unwrap();
        let src = ProblemSource::from_json(&j).unwrap();
        let ProblemSource::Artifact(r) = &src else { panic!("{src:?}") };
        assert_eq!(r.hash, hash);
        assert!(r.resolved().is_none());
        assert_eq!(src.label(), format!("artifact:{}", &hash[..12]));
        assert_eq!(src.payload_bytes(), 0);
        // Hash-only validation: no payload to check per job.
        src.validate(JobDomain::Real, 4, 2, 3).unwrap();
        // Serializes back to the hash alone, resolved or not.
        let mut resolved = src.clone();
        let ProblemSource::Artifact(r) = &mut resolved else { unreachable!() };
        r.resolve(InlineProblem::Pca {
            c: vec![InlineMat { rows: 1, cols: 1, data: vec![1.0] }],
        });
        assert_eq!(resolved.to_json(), src.to_json());
        let back = ProblemSource::from_json(&src.to_json()).unwrap();
        assert_eq!(back, src);
    }

    #[test]
    fn artifact_source_rejects_malformed_hashes() {
        for bad in [
            r#"{"source": "artifact"}"#,
            r#"{"source": "artifact", "hash": "abc"}"#,
            // Uppercase hex is not a canonical content address.
            r#"{"source": "artifact", "hash": "AB7816BF8F01CFEA414140DE5DAE2223B00361A396177A9CB410FF61F20015AD"}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(ProblemSource::from_json(&j).is_err(), "{bad}");
        }
    }
}
