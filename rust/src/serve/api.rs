//! `pogo serve` — the daemon: a TCP accept loop, per-connection handler
//! threads, and the `/v1` (frozen) + `/v2` job routes over the
//! [`JobQueue`].
//!
//! Endpoints (JSON bodies unless noted; connections close after one
//! request unless the client sends `Connection: keep-alive` — proxies
//! like `pogo front` do, to reuse one warm socket per backend; the v2
//! event stream uses chunked transfer-encoding and always consumes its
//! connection):
//!
//! | method | path                  | what                                    |
//! |--------|-----------------------|-----------------------------------------|
//! | POST   | `/v1/jobs`            | submit a [`JobSpec`]; 202 + `{id}`      |
//! | GET    | `/v1/jobs`            | list all jobs (compact)                 |
//! | GET    | `/v1/jobs/:id`        | status + metrics tail                   |
//! | GET    | `/v1/jobs/:id/result` | final loss + orthogonality error        |
//! | DELETE | `/v1/jobs/:id`        | cancel                                  |
//! | POST   | `/v2/jobs`            | submit (inline sources, quota headers)  |
//! | GET    | `/v2/jobs`            | list all jobs                           |
//! | GET    | `/v2/jobs/:id`        | status + tenant/cost/series length      |
//! | GET    | `/v2/jobs/:id/events` | live SSE progress stream                |
//! | GET    | `/v2/jobs/:id/result` | full loss series + final iterate        |
//! | GET    | `/v2/jobs/:id/trace`  | flight-recorder span tree               |
//! | DELETE | `/v2/jobs/:id`        | cancel                                  |
//! | GET    | `/v2/problems`        | the problem-source registry             |
//! | POST   | `/v2/artifacts`       | upload a sealed artifact (binary body)  |
//! | GET    | `/v2/artifacts`       | artifact-store summary                  |
//! | GET    | `/v2/artifacts/:hash` | one stored artifact's manifest          |
//! | GET    | `/healthz`            | liveness                                |
//! | GET    | `/metrics`            | Prometheus text                         |
//!
//! The v1 **API surface** is frozen: same routes, same response shapes,
//! same route-level status codes. New capability lands on `/v2` only.
//! Transport-layer limits are daemon-wide and version-independent (the
//! body cap grew to fit inline uploads; oversized bodies are now `413`
//! and header floods `431` on every route — protocol hygiene, not API
//! semantics). Tenancy rides the `X-Api-Key` header (missing =
//! `anonymous`); admission control (quotas, cost budget, inline byte
//! cap) answers `429` + `Retry-After` / `413` before a job touches the
//! queue.

use super::http::{self, Request, Response};
use super::job::{JobSpec, JobState};
use super::metrics::{QueueGauges, ServeMetrics};
use super::problem;
use super::queue::{
    Admission, BusPoll, JobQueue, ProgressBus, ProgressEvent, QueueConfig, SubmitError,
};
use crate::artifact::{Artifact, ArtifactStore};
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Max simultaneous connection-handler threads. Beyond it, connections
/// get an immediate 503 from the accept thread instead of a handler —
/// the per-request caps in [`http`] bound each handler, this bounds how
/// many there are.
const MAX_CONNS: usize = 64;

/// Max simultaneous SSE subscriber streams. Event streams are the only
/// long-lived connections, so they get their own (smaller) budget —
/// saturating them with cheap subscriptions can never starve the
/// short-request half of [`MAX_CONNS`] (submits, polls, `/healthz`).
const MAX_SSE: i64 = 32;

/// How long the SSE handler waits on the progress bus before emitting a
/// keepalive comment (stays under the socket write timeout).
const SSE_KEEPALIVE: Duration = Duration::from_secs(5);

/// Decrements the live-connection count when a handler ends — by any
/// path, including unwind (or the handler thread failing to spawn at
/// all, which drops the closure holding it).
struct ConnGuard(Arc<AtomicUsize>);

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Decrements the SSE subscriber gauge when a stream handler ends.
struct SseGuard<'a>(&'a ServeMetrics);

impl Drop for SseGuard<'_> {
    fn drop(&mut self) {
        self.0.sse_clients.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Daemon configuration (`pogo serve` flags map 1:1). Admission knobs
/// ride separately through [`Server::start_with`] so this struct — and
/// every v1 caller constructing it — stays frozen.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// `HOST:PORT`; port 0 binds an ephemeral port (tests/benches).
    pub addr: String,
    pub workers: usize,
    /// Max queued (not yet running) jobs.
    pub capacity: usize,
    /// Job state + checkpoint directory (enables restart recovery).
    pub state_dir: Option<PathBuf>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:7070".to_string(),
            workers: crate::util::pool::num_threads().min(4).max(1),
            capacity: 256,
            state_dir: None,
        }
    }
}

/// A running daemon. Keep it alive for as long as you serve; `shutdown`
/// drains in-flight jobs and joins every thread.
pub struct Server {
    queue: Arc<JobQueue>,
    local: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind, recover persisted jobs, spawn workers + accept loop, with
    /// admission control left wide open (the v1-compatible default).
    pub fn start(cfg: ServeConfig) -> Result<Server> {
        Server::start_with(cfg, Admission::default())
    }

    /// [`Server::start`] with explicit admission control (what the
    /// `--tenant-quota` / `--cost-cap` / `--max-inline-bytes` flags
    /// feed).
    pub fn start_with(cfg: ServeConfig, admission: Admission) -> Result<Server> {
        Server::start_with_artifacts(cfg, admission, None)
    }

    /// [`Server::start_with`] plus an artifact store (what the
    /// `--artifact-dir` / `--artifact-cap-mb` flags feed). With a store,
    /// the `/v2/artifacts` routes come alive, jobs may name an
    /// `artifact` problem source, and inline submissions are deduped
    /// through the store's content addresses.
    pub fn start_with_artifacts(
        cfg: ServeConfig,
        admission: Admission,
        artifacts: Option<Arc<ArtifactStore>>,
    ) -> Result<Server> {
        let metrics = Arc::new(ServeMetrics::new());
        let queue = JobQueue::start(
            QueueConfig {
                workers: cfg.workers.max(1),
                capacity: cfg.capacity.max(1),
                state_dir: cfg.state_dir.clone(),
                admission,
                artifacts,
            },
            metrics.clone(),
        )?;
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding {}", cfg.addr))?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));

        let q = queue.clone();
        let m = metrics.clone();
        let stop_flag = stop.clone();
        let accept = std::thread::Builder::new()
            .name("pogo-serve-accept".to_string())
            .spawn(move || {
                let active = Arc::new(AtomicUsize::new(0));
                for conn in listener.incoming() {
                    if stop_flag.load(Ordering::SeqCst) {
                        break;
                    }
                    match conn {
                        Ok(mut stream) => {
                            if active.load(Ordering::Relaxed) >= MAX_CONNS {
                                let resp = Response::error(503, "too many connections");
                                http::write_response(&mut stream, &resp).ok();
                                continue;
                            }
                            active.fetch_add(1, Ordering::Relaxed);
                            let q = q.clone();
                            let m = m.clone();
                            let guard = ConnGuard(active.clone());
                            let spawned = std::thread::Builder::new()
                                .name("pogo-serve-conn".to_string())
                                .spawn(move || {
                                    let _guard = guard;
                                    handle_conn(stream, &q, &m);
                                });
                            if let Err(e) = spawned {
                                // The closure (and its guard) never ran.
                                log::warn!("failed to spawn connection handler: {e}");
                            }
                        }
                        Err(e) => log::warn!("accept error: {e}"),
                    }
                }
            })
            .context("spawning accept loop")?;

        log::info!("pogo serve listening on http://{local}");
        Ok(Server { queue, local, stop, accept: Some(accept) })
    }

    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.local
    }

    /// Block on the accept loop (the daemon's main thread parks here).
    pub fn wait(mut self) {
        if let Some(h) = self.accept.take() {
            h.join().ok();
        }
    }

    /// Graceful shutdown: stop accepting, drain in-flight jobs, join
    /// workers. Queued jobs stay queued (persisted with a state dir).
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Poke the blocking accept() so the loop observes the flag.
        TcpStream::connect(self.local).ok();
        if let Some(h) = self.accept.take() {
            h.join().ok();
        }
        self.queue.shutdown();
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        // Best-effort stop if the caller forgot `shutdown()`: halt the
        // accept loop and flip the queue into draining so workers exit
        // once their current job ends. No joins here — drop must not
        // block on an in-flight job.
        if !self.stop.swap(true, Ordering::SeqCst) {
            TcpStream::connect(self.local).ok();
            self.queue.begin_drain();
        }
    }
}

/// What a routed request turns into: a buffered response, or a live
/// event stream that needs the socket.
enum Routed {
    Plain(Response),
    /// Stream `GET /v2/jobs/:id/events` for this job's bus (subscribed
    /// once, at routing time).
    Events(u64, Arc<ProgressBus>),
}

/// Requests served per kept-alive connection before the daemon hangs up
/// anyway — a bound so one client cannot pin a connection slot forever.
const MAX_REQS_PER_CONN: usize = 256;

fn handle_conn(mut stream: TcpStream, queue: &JobQueue, metrics: &ServeMetrics) {
    for _ in 0..MAX_REQS_PER_CONN {
        if !handle_one(&mut stream, queue, metrics) {
            return;
        }
    }
}

/// Serve one request off the connection; `true` keeps it open for the
/// next one (the client opted in with `Connection: keep-alive` and the
/// response went out whole). Each request gets its own metrics count and
/// latency sample — a reused socket must look identical to fresh ones in
/// the histograms.
fn handle_one(stream: &mut TcpStream, queue: &JobQueue, metrics: &ServeMetrics) -> bool {
    let t0 = crate::obs::enabled().then(std::time::Instant::now);
    let (label, mut keep, routed) = match http::read_request(stream) {
        Ok(req) => {
            (route_label(&req.path), http::wants_keep_alive(&req), route(&req, queue, metrics))
        }
        Err(e) => match e.response() {
            // A protocol violation we could answer: count it under the
            // "unparsed" route (there is no trustworthy path to label)
            // and close — framing may be out of sync past this point.
            Some(resp) => ("unparsed", false, Routed::Plain(resp)),
            None => {
                // Transport-level failure before a request existed:
                // nothing to label, nothing to time. On a kept-alive
                // connection this is also the normal way clients leave.
                log::debug!("client went away mid-request: {e}");
                return false;
            }
        },
    };
    let status = match routed {
        Routed::Plain(resp) => {
            if let Err(e) = http::write_response_conn(stream, &resp, keep) {
                log::debug!("client went away mid-response: {e}");
                keep = false;
            }
            resp.status
        }
        // SSE durations cover the whole stream lifetime, keepalives
        // included — they land in the top histogram buckets by design.
        // The chunked stream consumes the connection.
        Routed::Events(id, bus) => {
            keep = false;
            stream_events(stream, id, &bus, metrics)
        }
    };
    let class = http::status_class(status);
    metrics.count_request(label, class);
    if let Some(t0) = t0 {
        crate::obs::hist::HTTP_REQUEST_SECONDS.hist(&[label, class]).record_since(t0);
    }
    keep
}

/// Normalize a request path to one of a fixed set of route labels so the
/// route-labelled metrics (and their histogram series) stay bounded no
/// matter what clients send: id/hash segments collapse to `:id`/`:hash`,
/// unknown paths to `other`.
fn route_label(path: &str) -> &'static str {
    let segs: Vec<&str> = path.split('/').filter(|s| !s.is_empty()).collect();
    match segs.as_slice() {
        ["healthz"] => "/healthz",
        ["metrics"] => "/metrics",
        ["v1", "jobs"] => "/v1/jobs",
        ["v1", "jobs", _] => "/v1/jobs/:id",
        ["v1", "jobs", _, "result"] => "/v1/jobs/:id/result",
        ["v2", "jobs"] => "/v2/jobs",
        ["v2", "jobs", _] => "/v2/jobs/:id",
        ["v2", "jobs", _, "result"] => "/v2/jobs/:id/result",
        ["v2", "jobs", _, "events"] => "/v2/jobs/:id/events",
        ["v2", "jobs", _, "trace"] => "/v2/jobs/:id/trace",
        ["v2", "problems"] => "/v2/problems",
        ["v2", "artifacts"] => "/v2/artifacts",
        ["v2", "artifacts", _] => "/v2/artifacts/:hash",
        _ => "other",
    }
}

/// The tenant identity of a request: the `X-Api-Key` header, trimmed and
/// capped (it becomes a metrics/accounting key), or `anonymous`.
fn tenant_of(req: &Request) -> String {
    let raw = req.header("x-api-key").unwrap_or("").trim();
    if raw.is_empty() {
        "anonymous".to_string()
    } else {
        raw.chars().take(64).collect()
    }
}

fn route(req: &Request, queue: &JobQueue, metrics: &ServeMetrics) -> Routed {
    let segs: Vec<&str> = req.path.split('/').filter(|s| !s.is_empty()).collect();
    let plain = |resp: Response| Routed::Plain(resp);
    match (req.method.as_str(), segs.as_slice()) {
        ("GET", ["healthz"]) => {
            // `status` flips to "draining" during shutdown so a front
            // door stops placing new jobs here while reads still work.
            let draining = queue.is_draining();
            plain(Response::json(
                200,
                &Json::obj(vec![
                    ("status", Json::str(if draining { "draining" } else { "ok" })),
                    ("version", Json::str(crate::VERSION)),
                ]),
            ))
        }
        ("GET", ["metrics"]) => {
            let (depth, running) = queue.depth_running();
            let pool = crate::util::pool::pool_stats();
            let gauges = QueueGauges {
                depth,
                running,
                capacity: queue.capacity(),
                workers: queue.workers(),
                by_state: queue.state_counts(),
                outstanding_cost: queue.outstanding_cost(),
                pool_mode: pool.mode,
                pool_workers: pool.resident_workers,
                pool_dispatches: pool.dispatches,
                pool_busy_ns: pool.busy_ns,
                pool_idle_ns: pool.idle_ns,
            };
            plain(Response::text(200, metrics.render(&gauges)))
        }
        ("POST", ["v1", "jobs"]) => plain(submit(req, queue, false)),
        ("POST", ["v2", "jobs"]) => plain(submit(req, queue, true)),
        ("GET", ["v1" | "v2", "jobs"]) => plain(Response::json(200, &queue.list_json())),
        ("GET", ["v1", "jobs", id]) => plain(match parse_id(id) {
            Some(id) => match queue.status_json(id) {
                Some(j) => Response::json(200, &j),
                None => Response::error(404, format!("no job {id}")),
            },
            None => Response::error(400, format!("bad job id '{id}'")),
        }),
        ("GET", ["v2", "jobs", id]) => plain(match parse_id(id) {
            Some(id) => match queue.status_v2_json(id) {
                Some(j) => Response::json(200, &j),
                None => Response::error(404, format!("no job {id}")),
            },
            None => Response::error(400, format!("bad job id '{id}'")),
        }),
        ("GET", ["v1", "jobs", id, "result"]) => plain(match parse_id(id) {
            Some(id) => result_v1(id, queue),
            None => Response::error(400, format!("bad job id '{id}'")),
        }),
        ("GET", ["v2", "jobs", id, "result"]) => plain(match parse_id(id) {
            Some(id) => result_v2(id, queue),
            None => Response::error(400, format!("bad job id '{id}'")),
        }),
        ("GET", ["v2", "jobs", id, "trace"]) => plain(match parse_id(id) {
            Some(id) => match queue.trace_json(id) {
                Some(j) => Response::json(200, &j),
                None => Response::error(404, format!("no job {id}")),
            },
            None => Response::error(400, format!("bad job id '{id}'")),
        }),
        ("GET", ["v2", "jobs", id, "events"]) => match parse_id(id) {
            Some(id) => match queue.subscribe(id) {
                Some(bus) => Routed::Events(id, bus),
                None => plain(Response::error(404, format!("no job {id}"))),
            },
            None => plain(Response::error(400, format!("bad job id '{id}'"))),
        },
        ("GET", ["v2", "problems"]) => plain(Response::json(200, &problem::registry_json())),
        ("POST", ["v2", "artifacts"]) => plain(upload_artifact(req, queue, metrics)),
        ("GET", ["v2", "artifacts"]) => plain(artifact_summary(queue)),
        ("GET", ["v2", "artifacts", hash]) => plain(artifact_describe(hash, queue)),
        ("PUT" | "DELETE", ["v2", "artifacts", ..]) => {
            plain(Response::error(405, "artifacts are content-addressed and immutable"))
        }
        ("DELETE", ["v1" | "v2", "jobs", id]) => plain(match parse_id(id) {
            Some(id) => match queue.cancel(id) {
                Some(state) => Response::json(
                    200,
                    &Json::obj(vec![
                        ("id", Json::num(id as f64)),
                        ("state", Json::str(state.name())),
                    ]),
                ),
                None => Response::error(404, format!("no job {id}")),
            },
            None => Response::error(400, format!("bad job id '{id}'")),
        }),
        ("POST" | "PUT" | "DELETE", ["healthz" | "metrics"]) => {
            plain(Response::error(405, "read-only endpoint"))
        }
        ("POST" | "PUT" | "DELETE", ["v2", "problems"]) => {
            plain(Response::error(405, "read-only endpoint"))
        }
        _ => plain(Response::error(404, format!("no route for {} {}", req.method, req.path))),
    }
}

fn parse_id(s: &str) -> Option<u64> {
    s.parse::<u64>().ok()
}

fn submit(req: &Request, queue: &JobQueue, v2: bool) -> Response {
    let body = match req.body_utf8() {
        Ok(b) => b,
        Err(e) => return Response::error(400, format!("{e:#}")),
    };
    let parsed = match Json::parse(body) {
        Ok(j) => j,
        Err(e) => return Response::error(400, format!("bad JSON: {e}")),
    };
    let spec = match JobSpec::from_json(&parsed) {
        Ok(s) => s,
        Err(e) => return Response::error(400, format!("{e:#}")),
    };
    let tenant = tenant_of(req);
    // A federated front door pins placement by assigning the id up
    // front (`X-Pogo-Job-Id`) so every replica and re-list lands on the
    // same id. Plain clients never send it.
    let requested = match req.header("x-pogo-job-id") {
        Some(v) => match v.trim().parse::<u64>() {
            Ok(rid) => Some(rid),
            Err(_) => return Response::error(400, format!("bad X-Pogo-Job-Id '{v:.40}'")),
        },
        None => None,
    };
    match queue.submit_with_id(spec, &tenant, requested) {
        Ok(id) => {
            let mut resp = Response::json(
                202,
                &Json::obj(vec![
                    ("id", Json::num(id as f64)),
                    ("state", Json::str(JobState::Queued.name())),
                ]),
            );
            if v2 {
                // Quota telemetry headers (documented in README "Serving
                // v2"): how much admission headroom the tenant has left.
                let adm = queue.admission();
                if adm.tenant_quota > 0 {
                    let active = queue.tenant_active(&tenant);
                    resp = resp.with_header(
                        "X-Quota-Remaining",
                        adm.tenant_quota.saturating_sub(active).to_string(),
                    );
                }
                if adm.cost_cap > 0 {
                    resp = resp.with_header(
                        "X-Cost-Remaining",
                        adm.cost_cap.saturating_sub(queue.outstanding_cost()).to_string(),
                    );
                }
            }
            resp
        }
        Err(err) => {
            let msg = err.to_string();
            match err {
                SubmitError::Full(_) => Response::error(429, msg),
                SubmitError::Draining => Response::error(503, msg),
                SubmitError::Invalid(_) => Response::error(400, msg),
                SubmitError::Quota { retry_after_s, .. }
                | SubmitError::Cost { retry_after_s, .. } => Response::error(429, msg)
                    .with_header("Retry-After", retry_after_s.to_string()),
                SubmitError::InlineTooLarge { .. } => Response::error(413, msg),
                SubmitError::ArtifactMissing { .. } => Response::error(404, msg),
                // Replayed re-lists treat this as "already placed".
                SubmitError::IdTaken(_) => Response::error(409, msg),
            }
        }
    }
}

/// How a daemon without `--artifact-dir` answers every artifact route.
const NO_STORE: &str = "this daemon has no artifact store (start it with --artifact-dir)";

/// `POST /v2/artifacts` — upload one sealed artifact (binary body in the
/// [`Artifact::encode`] framing). The payload is checksummed and fully
/// validated here, once; job admissions against the stored hash trust it
/// from then on. `201` on first store, `409` (with the same body shape)
/// when the hash was already present.
fn upload_artifact(req: &Request, queue: &JobQueue, metrics: &ServeMetrics) -> Response {
    let Some(store) = queue.artifacts() else {
        return Response::error(404, NO_STORE);
    };
    let art = match Artifact::decode(&req.body) {
        Ok(a) => a,
        Err(e) => return Response::error(400, format!("{e:#}")),
    };
    if let Err(e) = art.verify() {
        return Response::error(400, format!("{e:#}"));
    }
    let problem = match art.to_problem() {
        Ok(p) => p,
        Err(e) => return Response::error(400, format!("{e:#}")),
    };
    let m = &art.manifest;
    if let Err(e) = problem.validate(m.domain, m.batch, m.p, m.n) {
        return Response::error(400, format!("{e:#}"));
    }
    if art.encoded_len() as u64 > store.summary().cap_bytes {
        return Response::error(
            413,
            format!(
                "artifact of {} bytes exceeds the store budget of {} bytes",
                art.encoded_len(),
                store.summary().cap_bytes
            ),
        );
    }
    match store.insert(&art) {
        Ok(outcome) => {
            metrics.artifact_evictions.fetch_add(outcome.evicted as u64, Ordering::Relaxed);
            let status = if outcome.existed { 409 } else { 201 };
            Response::json(
                status,
                &Json::obj(vec![
                    ("hash", Json::str(outcome.hash)),
                    ("bytes", Json::num(art.encoded_len() as f64)),
                    ("existed", Json::Bool(outcome.existed)),
                ]),
            )
        }
        Err(e) => Response::error(500, format!("{e:#}")),
    }
}

/// `GET /v2/artifacts` — store summary (count, bytes, per-hash sizes).
fn artifact_summary(queue: &JobQueue) -> Response {
    let Some(store) = queue.artifacts() else {
        return Response::error(404, NO_STORE);
    };
    let s = store.summary();
    Response::json(
        200,
        &Json::obj(vec![
            ("count", Json::num(s.count as f64)),
            ("total_bytes", Json::num(s.total_bytes as f64)),
            ("cap_bytes", Json::num(s.cap_bytes as f64)),
            (
                "artifacts",
                Json::arr(s.entries.iter().map(|(hash, bytes)| {
                    Json::obj(vec![
                        ("hash", Json::str(hash.clone())),
                        ("bytes", Json::num(*bytes as f64)),
                    ])
                })),
            ),
        ]),
    )
}

/// `GET /v2/artifacts/:hash` — one stored artifact's manifest + sizes.
fn artifact_describe(hash: &str, queue: &JobQueue) -> Response {
    let Some(store) = queue.artifacts() else {
        return Response::error(404, NO_STORE);
    };
    if !crate::util::sha256::is_hex_digest(hash) {
        return Response::error(400, format!("bad artifact hash '{hash:.80}'"));
    }
    match store.get(hash) {
        Ok(Some(art)) => Response::json(200, &art.describe()),
        Ok(None) => Response::error(404, format!("artifact {hash} is not in the store")),
        Err(e) => Response::error(500, format!("{e:#}")),
    }
}

fn result_v1(id: u64, queue: &JobQueue) -> Response {
    let Some((state, result, error)) = queue.snapshot(id) else {
        return Response::error(404, format!("no job {id}"));
    };
    match (state, result) {
        // Done — and cancelled jobs report their partial trajectory.
        (JobState::Done | JobState::Cancelled, Some(r)) => {
            let mut map = match r.to_json() {
                Json::Obj(m) => m,
                _ => Default::default(),
            };
            map.insert("id".to_string(), Json::num(id as f64));
            map.insert("state".to_string(), Json::str(state.name()));
            Response::json(200, &Json::Obj(map))
        }
        // Cancelled before a worker ever claimed it: terminal, but there
        // is no trajectory to report. Still a 200 so result-pollers
        // terminate.
        (JobState::Cancelled, None) => Response::json(
            200,
            &Json::obj(vec![
                ("id", Json::num(id as f64)),
                ("state", Json::str(JobState::Cancelled.name())),
                ("steps_done", Json::num(0.0)),
            ]),
        ),
        (JobState::Failed, _) => Response::error(
            409,
            format!("job {id} failed: {}", error.unwrap_or_else(|| "unknown error".into())),
        ),
        (s, _) => Response::error(409, format!("job {id} is {} — result not ready", s.name())),
    }
}

/// The v2 result: everything v1 serves, plus the untruncated loss series
/// and the final iterate (base64-packed f32 words; complex interleaved).
/// The series — up to millions of points — is spliced into the body as
/// raw text: a `Json` node per point would transiently allocate orders
/// of magnitude more than the series itself.
fn result_v2(id: u64, queue: &JobQueue) -> Response {
    let Some(view) = queue.result_view(id) else {
        return Response::error(404, format!("no job {id}"));
    };
    match (view.state, view.result) {
        (JobState::Done | JobState::Cancelled, Some(r)) => {
            let mut map = match r.to_json() {
                Json::Obj(m) => m,
                _ => Default::default(),
            };
            map.insert("id".to_string(), Json::num(id as f64));
            map.insert("state".to_string(), Json::str(view.state.name()));
            map.insert("tenant".to_string(), Json::str(view.tenant));
            map.insert(
                "iterate".to_string(),
                match &view.iterate {
                    Some(it) => it.to_json(),
                    None => Json::Null,
                },
            );
            // Compact body with the series appended as flat text (the
            // scalar fields still render through Json, so escaping and
            // number formatting stay consistent).
            let head = Json::Obj(map).to_string();
            let mut body = String::with_capacity(head.len() + 16 + view.series.len() * 24);
            body.push_str(&head[..head.len() - 1]); // open the object back up
            body.push_str(",\"series\":[");
            for (i, &(step, loss)) in view.series.iter().enumerate() {
                if i > 0 {
                    body.push(',');
                }
                body.push('[');
                body.push_str(&Json::num(step as f64).to_string());
                body.push(',');
                body.push_str(&Json::num(loss).to_string());
                body.push(']');
            }
            body.push_str("]}\n");
            Response {
                status: 200,
                content_type: "application/json",
                headers: Vec::new(),
                body: body.into_bytes(),
            }
        }
        (JobState::Cancelled, None) => Response::json(
            200,
            &Json::obj(vec![
                ("id", Json::num(id as f64)),
                ("state", Json::str(JobState::Cancelled.name())),
                ("steps_done", Json::num(0.0)),
                ("series", Json::arr(Vec::new())),
                ("iterate", Json::Null),
            ]),
        ),
        (JobState::Failed, _) => Response::error(
            409,
            format!(
                "job {id} failed: {}",
                view.error.unwrap_or_else(|| "unknown error".into())
            ),
        ),
        (s, _) => Response::error(409, format!("job {id} is {} — result not ready", s.name())),
    }
}

/// Stream one job's progress as Server-Sent Events over chunked
/// transfer-encoding. Late subscribers replay the bus's buffered tail
/// (monotone, gap-free within the buffer window); the stream closes with
/// a terminal `state` event. Keepalive comments hold the connection
/// through quiet stretches. Returns the HTTP status it answered with,
/// for the caller's request accounting.
fn stream_events(
    stream: &mut TcpStream,
    id: u64,
    bus: &ProgressBus,
    metrics: &ServeMetrics,
) -> u16 {
    // Long-lived streams get their own budget (see [`MAX_SSE`]).
    // Increment-then-check: a check-then-increment race would let a
    // burst of subscribers sail past the cap together.
    if metrics.sse_clients.fetch_add(1, Ordering::Relaxed) >= MAX_SSE {
        metrics.sse_clients.fetch_sub(1, Ordering::Relaxed);
        let resp = Response::error(503, "too many event subscribers")
            .with_header("Retry-After", "1");
        http::write_response(stream, &resp).ok();
        return 503;
    }
    let _guard = SseGuard(metrics);
    let id_text = id.to_string();
    if http::write_stream_head(stream, 200, "text/event-stream", &[("X-Job-Id", &id_text)])
        .is_err()
    {
        return 200;
    }
    let mut cursor = 0u64;
    loop {
        let chunk = match bus.next_event(cursor, SSE_KEEPALIVE) {
            BusPoll::Event(next, ProgressEvent::Step(p)) => {
                cursor = next;
                metrics.events_streamed.fetch_add(1, Ordering::Relaxed);
                let data = Json::obj(vec![
                    ("step", Json::num(p.step as f64)),
                    ("loss", Json::num(p.loss)),
                    ("ortho_error", Json::num(p.ortho_error)),
                    ("wall_s", Json::num(p.wall_s)),
                ])
                .to_string();
                format!("event: progress\ndata: {data}\n\n")
            }
            BusPoll::Event(next, ProgressEvent::Terminal(state)) => {
                cursor = next;
                let data = Json::obj(vec![
                    ("id", Json::num(id as f64)),
                    ("state", Json::str(state.name())),
                ])
                .to_string();
                format!("event: state\ndata: {data}\n\n")
            }
            BusPoll::Pending => ": keepalive\n\n".to_string(),
            BusPoll::Closed => break,
        };
        if http::write_chunk(stream, chunk.as_bytes()).is_err() {
            return 200; // subscriber went away
        }
    }
    http::finish_chunked(stream).ok();
    200
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::client::ServeClient;
    use crate::serve::job::ProblemKind;

    fn quick_spec() -> JobSpec {
        let mut s = JobSpec::new(ProblemKind::Quartic, 2, 2, 4);
        s.steps = 10;
        s
    }

    fn ephemeral() -> (Server, ServeClient) {
        let server = Server::start(ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            workers: 2,
            capacity: 32,
            state_dir: None,
        })
        .unwrap();
        let client = ServeClient::new(server.addr().to_string());
        (server, client)
    }

    #[test]
    fn healthz_metrics_and_routes() {
        let (server, client) = ephemeral();
        let h = client.healthz().unwrap();
        assert_eq!(h.get("status").as_str(), Some("ok"));
        let m = client.metrics().unwrap();
        assert!(m.contains("pogo_serve_queue_capacity 32"), "{m}");
        assert!(m.contains("pogo_serve_jobs{state=\"queued\"} 0"), "{m}");
        assert!(
            m.contains("pogo_serve_admission_rejected_total{cause=\"quota\"} 0"),
            "{m}"
        );
        // Unknown routes and ids.
        let (code, _) = http::request(client.addr(), "GET", "/nope", None).unwrap();
        assert_eq!(code, 404);
        let (code, _) = http::request(client.addr(), "GET", "/v1/jobs/999", None).unwrap();
        assert_eq!(code, 404);
        let (code, _) = http::request(client.addr(), "GET", "/v2/jobs/999", None).unwrap();
        assert_eq!(code, 404);
        let (code, _) =
            http::request(client.addr(), "GET", "/v2/jobs/999/events", None).unwrap();
        assert_eq!(code, 404);
        let (code, _) =
            http::request(client.addr(), "GET", "/v2/jobs/999/trace", None).unwrap();
        assert_eq!(code, 404);
        let (code, _) = http::request(client.addr(), "GET", "/v1/jobs/xyz", None).unwrap();
        assert_eq!(code, 400);
        let (code, _) = http::request(client.addr(), "POST", "/metrics", None).unwrap();
        assert_eq!(code, 405);
        let (code, _) = http::request(client.addr(), "POST", "/v2/problems", None).unwrap();
        assert_eq!(code, 405);
        // The problem-source registry is served.
        let (code, body) = http::request(client.addr(), "GET", "/v2/problems", None).unwrap();
        assert_eq!(code, 200);
        let registry = Json::parse(&body).unwrap();
        let names: Vec<String> = registry
            .as_arr()
            .unwrap()
            .iter()
            .map(|b| b.get("source").as_str().unwrap().to_string())
            .collect();
        assert_eq!(
            names,
            vec!["builtin".to_string(), "inline".to_string(), "artifact".to_string()]
        );
        // Artifact routes on a daemon without a store: a clear 404.
        let (code, body) = http::request(client.addr(), "GET", "/v2/artifacts", None).unwrap();
        assert_eq!(code, 404);
        assert!(body.contains("--artifact-dir"), "{body}");
        server.shutdown();
    }

    #[test]
    fn route_labels_are_a_fixed_set() {
        for (path, label) in [
            ("/healthz", "/healthz"),
            ("/metrics", "/metrics"),
            ("/v1/jobs/7", "/v1/jobs/:id"),
            ("/v1/jobs/7/result", "/v1/jobs/:id/result"),
            ("/v2/jobs/123/trace", "/v2/jobs/:id/trace"),
            ("/v2/jobs/123/events", "/v2/jobs/:id/events"),
            ("/v2/artifacts/abcdef", "/v2/artifacts/:hash"),
            ("/totally/unknown", "other"),
            ("/v1/jobs/../../etc/passwd", "other"),
        ] {
            assert_eq!(route_label(path), label, "{path}");
        }
    }

    #[test]
    fn artifact_upload_and_admission_lifecycle() {
        use crate::artifact::{Artifact, ArtifactStore, Provenance};
        use crate::serve::problem::{ArtifactRef, InlineMat, InlineProblem, ProblemSource};

        let dir =
            std::env::temp_dir().join(format!("pogo_api_artifacts_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let store = Arc::new(ArtifactStore::open(&dir, 1 << 20).unwrap());
        let server = Server::start_with_artifacts(
            ServeConfig {
                addr: "127.0.0.1:0".to_string(),
                workers: 1,
                capacity: 8,
                state_dir: None,
            },
            Admission::default(),
            Some(store),
        )
        .unwrap();
        let addr = server.addr().to_string();
        let client = ServeClient::new(addr.clone());

        // Seal exactly what `pogo compile` would for this job.
        let mut rng = crate::rng::Rng::seed_from_u64(41);
        let mats: Vec<InlineMat> = (0..2)
            .map(|_| InlineMat::from_mat(&crate::linalg::Mat::<f32>::randn(4, 4, &mut rng)))
            .collect();
        let inline = InlineProblem::Pca { c: mats };
        let mut spec = JobSpec::new(ProblemKind::Pca, 2, 2, 4);
        spec.steps = 10;
        let mut prov = Provenance::new(spec.seed);
        prov.optimizer = Some(spec.optimizer.to_json());
        let art = Artifact::seal(&inline, spec.domain, 2, 2, 4, prov).unwrap();
        let hash = art.hash();

        // First upload: 201 Created. Identical re-upload: 409, same hash.
        let (code, _, body) =
            http::request_bytes(&addr, "POST", "/v2/artifacts", &art.encode(), &[]).unwrap();
        assert_eq!(code, 201, "{body}");
        assert_eq!(Json::parse(&body).unwrap().get("hash").as_str(), Some(hash.as_str()));
        let (code, _, body) =
            http::request_bytes(&addr, "POST", "/v2/artifacts", &art.encode(), &[]).unwrap();
        assert_eq!(code, 409, "{body}");
        assert_eq!(Json::parse(&body).unwrap().get("existed").as_bool(), Some(true));

        // Summary, describe, and the malformed/immutable edges.
        let (code, body) = http::request(&addr, "GET", "/v2/artifacts", None).unwrap();
        assert_eq!(code, 200);
        let summary = Json::parse(&body).unwrap();
        assert_eq!(summary.get("count").as_usize(), Some(1));
        let (code, body) =
            http::request(&addr, "GET", &format!("/v2/artifacts/{hash}"), None).unwrap();
        assert_eq!(code, 200, "{body}");
        let desc = Json::parse(&body).unwrap();
        assert_eq!(desc.get("manifest").get("objective").as_str(), Some("pca"));
        let (code, _) = http::request(&addr, "GET", "/v2/artifacts/zzz", None).unwrap();
        assert_eq!(code, 400);
        let (code, _) =
            http::request(&addr, "DELETE", &format!("/v2/artifacts/{hash}"), None).unwrap();
        assert_eq!(code, 405);

        // A job sourced from the stored artifact completes, and matches
        // the same job submitted inline bit-for-bit.
        spec.source = ProblemSource::Artifact(ArtifactRef::new(&hash).unwrap());
        let id = client.submit(&spec).unwrap();
        client.wait_terminal(id, Duration::from_secs(30)).unwrap();
        let ra = client.result(id).unwrap();
        assert_eq!(ra.get("state").as_str(), Some("done"), "{}", ra.to_string());
        let mut inline_spec = spec.clone();
        inline_spec.source = ProblemSource::Inline(inline);
        let id2 = client.submit(&inline_spec).unwrap();
        client.wait_terminal(id2, Duration::from_secs(30)).unwrap();
        let ri = client.result(id2).unwrap();
        assert_eq!(
            ra.get("final_loss").as_f64().unwrap().to_bits(),
            ri.get("final_loss").as_f64().unwrap().to_bits(),
            "artifact-sourced and inline runs must be bit-identical"
        );

        // Unknown hashes 404 at submission time.
        let mut missing = spec.clone();
        missing.source = ProblemSource::Artifact(
            ArtifactRef::new(&crate::util::sha256::hex(b"never uploaded")).unwrap(),
        );
        let (code, _, body) = http::request_full(
            &addr,
            "POST",
            "/v2/jobs",
            Some(&missing.to_json().to_string()),
            &[],
        )
        .unwrap();
        assert_eq!(code, 404, "{body}");
        assert!(body.contains("not in the store"), "{body}");

        // Undecodable uploads are a clean 400.
        let (code, _, body) =
            http::request_bytes(&addr, "POST", "/v2/artifacts", b"garbage", &[]).unwrap();
        assert_eq!(code, 400, "{body}");

        server.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn submit_poll_result_lifecycle() {
        let (server, client) = ephemeral();
        let id = client.submit(&quick_spec()).unwrap();
        let status = client.wait_terminal(id, std::time::Duration::from_secs(30)).unwrap();
        assert_eq!(status.get("state").as_str(), Some("done"));
        let result = client.result(id).unwrap();
        assert_eq!(result.get("state").as_str(), Some("done"));
        assert!(result.get("ortho_error").as_f64().unwrap() <= 1e-3);
        assert_eq!(result.get("steps_done").as_usize(), Some(10));
        // v1 results stay frozen: no v2 fields leak in.
        assert_eq!(result.get("series"), &Json::Null);
        assert_eq!(result.get("iterate"), &Json::Null);
        assert_eq!(result.get("tenant"), &Json::Null);
        // The v2 result carries the full series and the iterate.
        let v2 = client.result_v2(id).unwrap();
        assert_eq!(v2.get("series").as_arr().unwrap().len(), 10);
        let iterate = v2.get("iterate");
        assert_eq!(iterate.get("domain").as_str(), Some("real"));
        let words =
            crate::serve::problem::b64_to_words(iterate.get("b64").as_str().unwrap()).unwrap();
        assert_eq!(words.len(), 2 * 2 * 4);
        // Listing shows the job on both surfaces.
        let (code, body) = http::request(client.addr(), "GET", "/v1/jobs", None).unwrap();
        assert_eq!(code, 200);
        assert_eq!(Json::parse(&body).unwrap().as_arr().unwrap().len(), 1);
        let (code, body) = http::request(client.addr(), "GET", "/v2/jobs", None).unwrap();
        assert_eq!(code, 200);
        assert_eq!(Json::parse(&body).unwrap().as_arr().unwrap().len(), 1);
        server.shutdown();
    }

    #[test]
    fn sse_stream_replays_and_terminates() {
        let (server, client) = ephemeral();
        let id = client.submit(&quick_spec()).unwrap();
        client.wait_terminal(id, std::time::Duration::from_secs(30)).unwrap();
        // Subscribe after completion: the bounded bus replays the steps
        // and closes with the terminal state event.
        let mut steps = Vec::new();
        let mut state = String::new();
        http::stream_sse(
            client.addr(),
            &format!("/v2/jobs/{id}/events"),
            &[],
            Duration::from_secs(30),
            &mut |event, data| {
                let j = Json::parse(data).unwrap();
                match event {
                    "progress" => steps.push(j.get("step").as_usize().unwrap()),
                    "state" => state = j.get("state").as_str().unwrap().to_string(),
                    other => panic!("unexpected event '{other}'"),
                }
                true
            },
        )
        .unwrap();
        assert_eq!(steps, (1..=10).collect::<Vec<_>>());
        assert_eq!(state, "done");
        // The SSE gauge drops back to zero (the handler's guard may
        // decrement a beat after the client sees the stream end) and the
        // streamed events were counted.
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        loop {
            let m = client.metrics().unwrap();
            if m.contains("pogo_serve_sse_clients 0") {
                assert!(m.contains("pogo_serve_sse_events_total 10"), "{m}");
                break;
            }
            assert!(std::time::Instant::now() < deadline, "SSE gauge never returned to 0:\n{m}");
            std::thread::sleep(Duration::from_millis(10));
        }
        server.shutdown();
    }

    #[test]
    fn quota_rejections_carry_retry_after() {
        let server = Server::start_with(
            ServeConfig {
                addr: "127.0.0.1:0".to_string(),
                workers: 1,
                capacity: 32,
                state_dir: None,
            },
            Admission { tenant_quota: 1, ..Admission::default() },
        )
        .unwrap();
        let addr = server.addr().to_string();
        let mut long = quick_spec();
        long.steps = 500_000;
        let body = long.to_json().to_string();
        let (code, headers, _) = http::request_full(
            &addr,
            "POST",
            "/v2/jobs",
            Some(&body),
            &[("X-Api-Key", "tenant-a")],
        )
        .unwrap();
        assert_eq!(code, 202);
        // Quota telemetry on the 202.
        assert!(
            headers.iter().any(|(k, v)| k == "X-Quota-Remaining" && v == "0"),
            "{headers:?}"
        );
        // Second active job for the same tenant: 429 + Retry-After.
        let (code, headers, resp_body) = http::request_full(
            &addr,
            "POST",
            "/v2/jobs",
            Some(&body),
            &[("X-Api-Key", "tenant-a")],
        )
        .unwrap();
        assert_eq!(code, 429, "{resp_body}");
        assert!(headers.iter().any(|(k, _)| k == "Retry-After"), "{headers:?}");
        assert!(resp_body.contains("quota"), "{resp_body}");
        // A different tenant is unaffected.
        let (code, _, _) = http::request_full(
            &addr,
            "POST",
            "/v2/jobs",
            Some(&body),
            &[("X-Api-Key", "tenant-b")],
        )
        .unwrap();
        assert_eq!(code, 202);
        // Cancel the long jobs so shutdown's drain returns promptly.
        for id in 1..=2u64 {
            http::request(&addr, "DELETE", &format!("/v2/jobs/{id}"), None).ok();
        }
        server.shutdown();
    }

    #[test]
    fn bad_submissions_rejected() {
        let (server, client) = ephemeral();
        let (code, body) =
            http::request(client.addr(), "POST", "/v1/jobs", Some("{not json")).unwrap();
        assert_eq!(code, 400, "{body}");
        let (code, body) = http::request(
            client.addr(),
            "POST",
            "/v1/jobs",
            Some(r#"{"problem": "pca", "batch": 1, "p": 9, "n": 3, "steps": 5,
                     "optimizer": {"method": "pogo", "lr": 0.1}}"#),
        )
        .unwrap();
        assert_eq!(code, 400, "{body}");
        // An inline payload that does not match the declared shapes.
        let (code, body) = http::request(
            client.addr(),
            "POST",
            "/v2/jobs",
            Some(r#"{"problem": {"source": "inline", "objective": "pca",
                      "c": [{"rows": 2, "cols": 2, "data": [1, 0, 0, 1]}]},
                     "batch": 2, "p": 1, "n": 2, "steps": 5,
                     "optimizer": {"method": "pogo", "lr": 0.1}}"#),
        )
        .unwrap();
        assert_eq!(code, 400, "{body}");
        assert!(body.contains("inline"), "{body}");
        // Result of a job that does not exist.
        let (code, _) =
            http::request(client.addr(), "GET", "/v1/jobs/7/result", None).unwrap();
        assert_eq!(code, 404);
        server.shutdown();
    }

    #[test]
    fn malformed_requests_never_leak_connection_slots() {
        // More bad requests than MAX_CONNS: if any 4xx path leaked its
        // slot, the daemon would start answering 503 before the end.
        let (server, client) = ephemeral();
        let addr = server.addr();
        for i in 0..(MAX_CONNS + 8) {
            use std::io::{Read, Write};
            let mut s = TcpStream::connect(addr).unwrap();
            s.set_read_timeout(Some(Duration::from_secs(5))).ok();
            // Alternate protocol violations.
            let bad: &[u8] = if i % 2 == 0 {
                b"POST /v1/jobs HTTP/1.1\r\nContent-Length: zero\r\n\r\n"
            } else {
                b"POST /v1/jobs HTTP/1.1\r\nContent-Length: 99\r\n\r\nshort"
            };
            s.write_all(bad).unwrap();
            s.shutdown(std::net::Shutdown::Write).ok();
            let mut out = String::new();
            s.read_to_string(&mut out).ok();
            assert!(
                out.starts_with("HTTP/1.1 4"),
                "request {i} should get a 4xx, got: {out:.60}"
            );
        }
        // And the daemon still serves real traffic.
        let h = client.healthz().unwrap();
        assert_eq!(h.get("status").as_str(), Some("ok"));
        server.shutdown();
    }
}
