//! `pogo serve` — the daemon: a TCP accept loop, per-connection handler
//! threads, and the `/v1` job routes over the [`JobQueue`].
//!
//! Endpoints (all `Connection: close`, JSON bodies unless noted):
//!
//! | method | path                 | what                                     |
//! |--------|----------------------|------------------------------------------|
//! | POST   | `/v1/jobs`           | submit a [`JobSpec`]; 202 + `{id}`       |
//! | GET    | `/v1/jobs`           | list all jobs (compact)                  |
//! | GET    | `/v1/jobs/:id`       | status + metrics tail                    |
//! | GET    | `/v1/jobs/:id/result`| final loss + orthogonality error         |
//! | DELETE | `/v1/jobs/:id`       | cancel                                   |
//! | GET    | `/healthz`           | liveness                                 |
//! | GET    | `/metrics`           | Prometheus text                          |

use super::http::{self, Request, Response};
use super::job::{JobSpec, JobState};
use super::metrics::ServeMetrics;
use super::queue::{JobQueue, QueueConfig, SubmitError};
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

/// Max simultaneous connection-handler threads. Beyond it, connections
/// get an immediate 503 from the accept thread instead of a handler —
/// the per-request caps in [`http`] bound each handler, this bounds how
/// many there are.
const MAX_CONNS: usize = 64;

/// Decrements the live-connection count when a handler ends — by any
/// path, including unwind (or the handler thread failing to spawn at
/// all, which drops the closure holding it).
struct ConnGuard(Arc<AtomicUsize>);

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Daemon configuration (`pogo serve` flags map 1:1).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// `HOST:PORT`; port 0 binds an ephemeral port (tests/benches).
    pub addr: String,
    pub workers: usize,
    /// Max queued (not yet running) jobs.
    pub capacity: usize,
    /// Job state + checkpoint directory (enables restart recovery).
    pub state_dir: Option<PathBuf>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:7070".to_string(),
            workers: crate::util::pool::num_threads().min(4).max(1),
            capacity: 256,
            state_dir: None,
        }
    }
}

/// A running daemon. Keep it alive for as long as you serve; `shutdown`
/// drains in-flight jobs and joins every thread.
pub struct Server {
    queue: Arc<JobQueue>,
    local: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind, recover persisted jobs, spawn workers + accept loop.
    pub fn start(cfg: ServeConfig) -> Result<Server> {
        let metrics = Arc::new(ServeMetrics::new());
        let queue = JobQueue::start(
            QueueConfig {
                workers: cfg.workers.max(1),
                capacity: cfg.capacity.max(1),
                state_dir: cfg.state_dir.clone(),
            },
            metrics.clone(),
        )?;
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding {}", cfg.addr))?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));

        let q = queue.clone();
        let m = metrics.clone();
        let stop_flag = stop.clone();
        let accept = std::thread::Builder::new()
            .name("pogo-serve-accept".to_string())
            .spawn(move || {
                let active = Arc::new(AtomicUsize::new(0));
                for conn in listener.incoming() {
                    if stop_flag.load(Ordering::SeqCst) {
                        break;
                    }
                    match conn {
                        Ok(mut stream) => {
                            if active.load(Ordering::Relaxed) >= MAX_CONNS {
                                let resp = Response::error(503, "too many connections");
                                http::write_response(&mut stream, &resp).ok();
                                continue;
                            }
                            active.fetch_add(1, Ordering::Relaxed);
                            let q = q.clone();
                            let m = m.clone();
                            let guard = ConnGuard(active.clone());
                            let spawned = std::thread::Builder::new()
                                .name("pogo-serve-conn".to_string())
                                .spawn(move || {
                                    let _guard = guard;
                                    handle_conn(stream, &q, &m);
                                });
                            if let Err(e) = spawned {
                                // The closure (and its guard) never ran.
                                log::warn!("failed to spawn connection handler: {e}");
                            }
                        }
                        Err(e) => log::warn!("accept error: {e}"),
                    }
                }
            })
            .context("spawning accept loop")?;

        log::info!("pogo serve listening on http://{local}");
        Ok(Server { queue, local, stop, accept: Some(accept) })
    }

    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.local
    }

    /// Block on the accept loop (the daemon's main thread parks here).
    pub fn wait(mut self) {
        if let Some(h) = self.accept.take() {
            h.join().ok();
        }
    }

    /// Graceful shutdown: stop accepting, drain in-flight jobs, join
    /// workers. Queued jobs stay queued (persisted with a state dir).
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Poke the blocking accept() so the loop observes the flag.
        TcpStream::connect(self.local).ok();
        if let Some(h) = self.accept.take() {
            h.join().ok();
        }
        self.queue.shutdown();
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        // Best-effort stop if the caller forgot `shutdown()`: halt the
        // accept loop and flip the queue into draining so workers exit
        // once their current job ends. No joins here — drop must not
        // block on an in-flight job.
        if !self.stop.swap(true, Ordering::SeqCst) {
            TcpStream::connect(self.local).ok();
            self.queue.begin_drain();
        }
    }
}

fn handle_conn(mut stream: TcpStream, queue: &JobQueue, metrics: &ServeMetrics) {
    metrics.requests.fetch_add(1, Ordering::Relaxed);
    let resp = match http::read_request(&stream) {
        Ok(req) => route(&req, queue, metrics),
        Err(e) => Response::error(400, format!("{e:#}")),
    };
    if let Err(e) = http::write_response(&mut stream, &resp) {
        log::debug!("client went away mid-response: {e}");
    }
}

fn route(req: &Request, queue: &JobQueue, metrics: &ServeMetrics) -> Response {
    let segs: Vec<&str> = req.path.split('/').filter(|s| !s.is_empty()).collect();
    match (req.method.as_str(), segs.as_slice()) {
        ("GET", ["healthz"]) => Response::json(
            200,
            &Json::obj(vec![
                ("status", Json::str("ok")),
                ("version", Json::str(crate::VERSION)),
            ]),
        ),
        ("GET", ["metrics"]) => {
            let (depth, running) = queue.depth_running();
            Response::text(200, metrics.render(depth, running, queue.capacity(), queue.workers()))
        }
        ("POST", ["v1", "jobs"]) => submit(req, queue),
        ("GET", ["v1", "jobs"]) => Response::json(200, &queue.list_json()),
        ("GET", ["v1", "jobs", id]) => match parse_id(id) {
            Some(id) => match queue.status_json(id) {
                Some(j) => Response::json(200, &j),
                None => Response::error(404, format!("no job {id}")),
            },
            None => Response::error(400, format!("bad job id '{id}'")),
        },
        ("GET", ["v1", "jobs", id, "result"]) => match parse_id(id) {
            Some(id) => result_of(id, queue),
            None => Response::error(400, format!("bad job id '{id}'")),
        },
        ("DELETE", ["v1", "jobs", id]) => match parse_id(id) {
            Some(id) => match queue.cancel(id) {
                Some(state) => Response::json(
                    200,
                    &Json::obj(vec![
                        ("id", Json::num(id as f64)),
                        ("state", Json::str(state.name())),
                    ]),
                ),
                None => Response::error(404, format!("no job {id}")),
            },
            None => Response::error(400, format!("bad job id '{id}'")),
        },
        ("POST" | "PUT" | "DELETE", ["healthz" | "metrics"]) => {
            Response::error(405, "read-only endpoint")
        }
        _ => Response::error(404, format!("no route for {} {}", req.method, req.path)),
    }
}

fn parse_id(s: &str) -> Option<u64> {
    s.parse::<u64>().ok()
}

fn submit(req: &Request, queue: &JobQueue) -> Response {
    let body = match req.body_utf8() {
        Ok(b) => b,
        Err(e) => return Response::error(400, format!("{e:#}")),
    };
    let parsed = match Json::parse(body) {
        Ok(j) => j,
        Err(e) => return Response::error(400, format!("bad JSON: {e}")),
    };
    let spec = match JobSpec::from_json(&parsed) {
        Ok(s) => s,
        Err(e) => return Response::error(400, format!("{e:#}")),
    };
    match queue.submit(spec) {
        Ok(id) => Response::json(
            202,
            &Json::obj(vec![
                ("id", Json::num(id as f64)),
                ("state", Json::str(JobState::Queued.name())),
            ]),
        ),
        Err(e @ SubmitError::Full(_)) => Response::error(429, e.to_string()),
        Err(e @ SubmitError::Draining) => Response::error(503, e.to_string()),
        Err(SubmitError::Invalid(e)) => Response::error(400, format!("{e:#}")),
    }
}

fn result_of(id: u64, queue: &JobQueue) -> Response {
    let Some((state, result, error)) = queue.snapshot(id) else {
        return Response::error(404, format!("no job {id}"));
    };
    match (state, result) {
        // Done — and cancelled jobs report their partial trajectory.
        (JobState::Done | JobState::Cancelled, Some(r)) => {
            let mut map = match r.to_json() {
                Json::Obj(m) => m,
                _ => Default::default(),
            };
            map.insert("id".to_string(), Json::num(id as f64));
            map.insert("state".to_string(), Json::str(state.name()));
            Response::json(200, &Json::Obj(map))
        }
        // Cancelled before a worker ever claimed it: terminal, but there
        // is no trajectory to report. Still a 200 so result-pollers
        // terminate.
        (JobState::Cancelled, None) => Response::json(
            200,
            &Json::obj(vec![
                ("id", Json::num(id as f64)),
                ("state", Json::str(JobState::Cancelled.name())),
                ("steps_done", Json::num(0.0)),
            ]),
        ),
        (JobState::Failed, _) => Response::error(
            409,
            format!("job {id} failed: {}", error.unwrap_or_else(|| "unknown error".into())),
        ),
        (s, _) => Response::error(409, format!("job {id} is {} — result not ready", s.name())),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::client::ServeClient;
    use crate::serve::job::ProblemKind;

    fn quick_spec() -> JobSpec {
        let mut s = JobSpec::new(ProblemKind::Quartic, 2, 2, 4);
        s.steps = 10;
        s
    }

    fn ephemeral() -> (Server, ServeClient) {
        let server = Server::start(ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            workers: 2,
            capacity: 32,
            state_dir: None,
        })
        .unwrap();
        let client = ServeClient::new(server.addr().to_string());
        (server, client)
    }

    #[test]
    fn healthz_metrics_and_routes() {
        let (server, client) = ephemeral();
        let h = client.healthz().unwrap();
        assert_eq!(h.get("status").as_str(), Some("ok"));
        let m = client.metrics().unwrap();
        assert!(m.contains("pogo_serve_queue_capacity 32"), "{m}");
        // Unknown routes and ids.
        let (code, _) = http::request(client.addr(), "GET", "/nope", None).unwrap();
        assert_eq!(code, 404);
        let (code, _) = http::request(client.addr(), "GET", "/v1/jobs/999", None).unwrap();
        assert_eq!(code, 404);
        let (code, _) = http::request(client.addr(), "GET", "/v1/jobs/xyz", None).unwrap();
        assert_eq!(code, 400);
        let (code, _) = http::request(client.addr(), "POST", "/metrics", None).unwrap();
        assert_eq!(code, 405);
        server.shutdown();
    }

    #[test]
    fn submit_poll_result_lifecycle() {
        let (server, client) = ephemeral();
        let id = client.submit(&quick_spec()).unwrap();
        let status = client.wait_terminal(id, std::time::Duration::from_secs(30)).unwrap();
        assert_eq!(status.get("state").as_str(), Some("done"));
        let result = client.result(id).unwrap();
        assert_eq!(result.get("state").as_str(), Some("done"));
        assert!(result.get("ortho_error").as_f64().unwrap() <= 1e-3);
        assert_eq!(result.get("steps_done").as_usize(), Some(10));
        // Listing shows the job.
        let (code, body) = http::request(client.addr(), "GET", "/v1/jobs", None).unwrap();
        assert_eq!(code, 200);
        assert_eq!(Json::parse(&body).unwrap().as_arr().unwrap().len(), 1);
        server.shutdown();
    }

    #[test]
    fn bad_submissions_rejected() {
        let (server, client) = ephemeral();
        let (code, body) =
            http::request(client.addr(), "POST", "/v1/jobs", Some("{not json")).unwrap();
        assert_eq!(code, 400, "{body}");
        let (code, body) = http::request(
            client.addr(),
            "POST",
            "/v1/jobs",
            Some(r#"{"problem": "pca", "batch": 1, "p": 9, "n": 3, "steps": 5,
                     "optimizer": {"method": "pogo", "lr": 0.1}}"#),
        )
        .unwrap();
        assert_eq!(code, 400, "{body}");
        // Result of a job that does not exist.
        let (code, _) =
            http::request(client.addr(), "GET", "/v1/jobs/7/result", None).unwrap();
        assert_eq!(code, 404);
        server.shutdown();
    }
}
