//! `pogo serve` — a multi-tenant optimization job service over the
//! engine stack.
//!
//! The paper's headline is optimizing thousands of orthogonal
//! constraints in minutes; this subsystem serves that capability as a
//! resident daemon instead of a one-shot CLI: clients POST serialized
//! job specs (problem + [`OptimizerSpec`](crate::coordinator::OptimizerSpec)
//! + shapes + seed), a bounded queue schedules them across a fixed
//! worker set (each worker drives the job's own
//! [`OptimSession`](crate::coordinator::OptimSession)), and results,
//! loss tails and Prometheus metrics stream back over minimal HTTP/1.1
//! on `std::net` — no new dependencies.
//!
//! - [`job`] — the job model and `run_job`, the single deterministic
//!   execution path (daemon and direct callers agree bit-for-bit);
//! - [`queue`] — bounded FIFO + per-job state machine
//!   (queued → running → done/failed/cancelled), graceful drain,
//!   restart recovery via persisted state + checkpoints;
//! - [`http`] / [`api`] — the protocol layer and the `/v1` routes;
//! - [`client`] — the in-process client the load bench and tests use;
//! - [`metrics`] — daemon counters for `GET /metrics`.
//!
//! Start one with `pogo serve [--addr HOST:PORT] [--workers N]`, or in
//! process via [`Server::start`] (port 0 = ephemeral, as the tests do).

pub mod api;
pub mod client;
pub mod http;
pub mod job;
pub mod metrics;
pub mod queue;

pub use api::{ServeConfig, Server};
pub use client::ServeClient;
pub use job::{run_job, JobDomain, JobOutcome, JobResult, JobSpec, JobState, ProblemKind, RunCtl};
pub use metrics::ServeMetrics;
pub use queue::{JobId, JobQueue, QueueConfig, SubmitError};
