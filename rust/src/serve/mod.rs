//! `pogo serve` — a multi-tenant optimization job service over the
//! engine stack.
//!
//! The paper's headline is optimizing thousands of orthogonal
//! constraints in minutes; this subsystem serves that capability as a
//! resident daemon instead of a one-shot CLI: clients POST serialized
//! job specs (problem source + [`OptimizerSpec`](crate::coordinator::OptimizerSpec)
//! + shapes + seed), admission control (per-tenant quotas, a
//! `B·p·n·steps` cost budget, inline payload caps) gates the door, a
//! bounded queue schedules admitted jobs across a fixed worker set
//! (each worker drives the job's own
//! [`OptimSession`](crate::coordinator::OptimSession)), and progress
//! streams back live over minimal HTTP/1.1 on `std::net` — no new
//! dependencies.
//!
//! - [`problem`] — the open problem-source registry: `builtin` seeded
//!   objectives, `inline` client-supplied matrices (v2), and `artifact`
//!   hash references into the daemon's content-addressed store (see
//!   [`crate::artifact`]);
//! - [`job`] — the job model and `run_job`/`run_job_with`, the single
//!   deterministic execution path (daemon and direct callers agree
//!   bit-for-bit) with per-step progress observation;
//! - [`queue`] — admission control + bounded FIFO + per-job state
//!   machine (queued → running → done/failed/cancelled), the per-job
//!   [`ProgressBus`] broadcast, graceful drain, restart recovery via
//!   persisted state + dtype-tagged checkpoints (both domains);
//! - [`http`] / [`api`] — the protocol layer (buffered + chunked/SSE
//!   streaming) and the `/v1` (frozen) + `/v2` routes;
//! - [`client`] — the in-process client the load bench and tests use,
//!   including the streaming SSE consumer;
//! - [`metrics`] — daemon counters/gauges for `GET /metrics`.
//!
//! Start one with `pogo serve [--addr HOST:PORT] [--workers N]
//! [--tenant-quota N] [--cost-cap UNITS] [--max-inline-bytes B]
//! [--artifact-dir DIR [--artifact-cap-mb MB]]`, or in process via
//! [`Server::start`] / [`Server::start_with`] /
//! [`Server::start_with_artifacts`] (port 0 = ephemeral, as the tests
//! do).

pub mod api;
pub mod client;
pub mod http;
pub mod job;
pub mod metrics;
pub mod problem;
pub mod queue;

pub use api::{ServeConfig, Server};
pub use client::{ServeClient, StreamedStep};
pub use job::{
    run_job, run_job_with, FinalIterate, JobDomain, JobOutcome, JobResult, JobSpec, JobState,
    ProblemKind, RunCtl, StepProgress,
};
pub use metrics::ServeMetrics;
pub use problem::{ArtifactRef, InlineMat, InlineProblem, ProblemSource};
pub use queue::{Admission, JobId, JobQueue, ProgressBus, QueueConfig, SubmitError};
