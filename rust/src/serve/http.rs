//! Minimal HTTP/1.1 over `std::net` — just enough protocol for the serve
//! API and its in-process client (the offline registry has no hyper).
//!
//! Server side: [`read_request`] parses one request (method, path,
//! headers, `Content-Length` or `Transfer-Encoding: chunked` body;
//! size-capped) off a stream; protocol violations come back as a typed
//! [`ReadError::Protocol`] carrying the 4xx status to answer with, so a
//! hostile peer can never panic a handler or leak its connection slot.
//! [`write_response`] writes one `Connection: close` response;
//! [`write_stream_head`] / [`write_chunk`] / [`finish_chunked`] stream a
//! chunked response (the v2 SSE event feed). Client side: [`request`]
//! performs one buffered round-trip and [`stream_sse`] consumes a live
//! `text/event-stream`.
//!
//! By default every connection carries exactly one request/response
//! pair. Connection reuse is **opt-in by explicit
//! `Connection: keep-alive`** (HTTP/1.0 style): the one-shot clients
//! here read responses to EOF, so default-on HTTP/1.1 persistence would
//! hang them. [`Conn`] is the persistent counterpart — it sends the
//! header and frames responses by `Content-Length` — and the server
//! loop honors it via [`wants_keep_alive`]. That pair is what the
//! federated front door's proxy data plane rides: one warm TCP
//! connection per backend instead of a connect per proxied request,
//! plus [`relay_sse_blocks`] to pass SSE streams through byte-for-byte.

use anyhow::{anyhow, Context, Result};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

/// Largest accepted request body. Sized for v2 inline problem payloads
/// (base64-packed matrices), not just bare job specs; the daemon's
/// `--max-inline-bytes` admission cap bounds the decoded payload more
/// precisely.
pub const MAX_BODY: usize = 16 << 20;
/// Largest accepted header section.
const MAX_HEADERS: usize = 64;
/// Largest accepted single line (request line or one header) — caps the
/// memory a malicious peer can grow before the body length is even known.
const MAX_LINE: usize = 8 << 10;
/// Per-connection socket timeout.
pub const IO_TIMEOUT: Duration = Duration::from_secs(10);

/// One parsed request.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    /// Path only (any `?query` is split off and discarded).
    pub path: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }

    pub fn body_utf8(&self) -> Result<&str> {
        std::str::from_utf8(&self.body).context("request body is not UTF-8")
    }
}

/// Why a request could not be read.
#[derive(Debug)]
pub enum ReadError {
    /// Protocol violation — answer with this status, then close.
    Protocol { status: u16, msg: String },
    /// Transport failure (peer vanished, timeout) — nothing to answer.
    Transport(anyhow::Error),
}

impl ReadError {
    fn protocol(status: u16, msg: impl Into<String>) -> ReadError {
        ReadError::Protocol { status, msg: msg.into() }
    }

    /// The response a protocol violation maps to (transport errors have
    /// no one left to answer).
    pub fn response(&self) -> Option<Response> {
        match self {
            ReadError::Protocol { status, msg } => Some(Response::error(*status, msg.clone())),
            ReadError::Transport(_) => None,
        }
    }
}

impl std::fmt::Display for ReadError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ReadError::Protocol { status, msg } => write!(f, "HTTP {status}: {msg}"),
            ReadError::Transport(e) => write!(f, "transport: {e:#}"),
        }
    }
}

// `?` promotes a ReadError into anyhow::Error at call sites that do not
// care about the Protocol/Transport split.
impl std::error::Error for ReadError {}

/// One response about to be written.
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    /// Extra headers beyond the Content-Type/Length/Connection set
    /// (`Retry-After`, quota telemetry, …).
    pub headers: Vec<(&'static str, String)>,
    pub body: Vec<u8>,
}

impl Response {
    pub fn json(status: u16, body: &crate::util::json::Json) -> Response {
        Response {
            status,
            content_type: "application/json",
            headers: Vec::new(),
            body: (body.to_string_pretty() + "\n").into_bytes(),
        }
    }

    pub fn text(status: u16, body: impl Into<String>) -> Response {
        Response {
            status,
            content_type: "text/plain; charset=utf-8",
            headers: Vec::new(),
            body: body.into().into_bytes(),
        }
    }

    /// `{"error": msg}` with the given status.
    pub fn error(status: u16, msg: impl Into<String>) -> Response {
        Response::json(
            status,
            &crate::util::json::Json::obj(vec![(
                "error",
                crate::util::json::Json::str(msg.into()),
            )]),
        )
    }

    /// Attach an extra header.
    pub fn with_header(mut self, name: &'static str, value: impl Into<String>) -> Response {
        self.headers.push((name, value.into()));
        self
    }
}

/// Status class label for metrics (`"2xx"`, `"4xx"`, …). Anything
/// outside 100–599 is `"other"` (can only arise from a bug, but metrics
/// must never panic).
pub fn status_class(status: u16) -> &'static str {
    match status {
        100..=199 => "1xx",
        200..=299 => "2xx",
        300..=399 => "3xx",
        400..=499 => "4xx",
        500..=599 => "5xx",
        _ => "other",
    }
}

pub fn status_reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        201 => "Created",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// `read_line` with a hard byte cap, so a peer streaming an endless
/// line cannot grow an unbounded buffer (plain `BufRead::read_line`
/// has no limit).
fn read_line_capped<R: BufRead>(reader: &mut R, what: &str) -> Result<String, ReadError> {
    let mut buf: Vec<u8> = Vec::new();
    loop {
        let available = reader
            .fill_buf()
            .map_err(|e| ReadError::Transport(anyhow!("reading {what}: {e}")))?;
        if available.is_empty() {
            break; // EOF
        }
        match available.iter().position(|&b| b == b'\n') {
            Some(i) => {
                buf.extend_from_slice(&available[..i]);
                reader.consume(i + 1);
                break;
            }
            None => {
                buf.extend_from_slice(available);
                let n = available.len();
                reader.consume(n);
            }
        }
        if buf.len() > MAX_LINE {
            return Err(ReadError::protocol(
                400,
                format!("{what} exceeds the {MAX_LINE}-byte line cap"),
            ));
        }
    }
    if buf.len() > MAX_LINE {
        return Err(ReadError::protocol(
            400,
            format!("{what} exceeds the {MAX_LINE}-byte line cap"),
        ));
    }
    Ok(String::from_utf8_lossy(&buf).into_owned())
}

/// Parse one chunked-transfer size line: hex count, optional `;ext`
/// chunk extensions ignored. Shared by the server-side body reader and
/// the client-side SSE consumer so framing rules cannot drift.
fn parse_chunk_size(line: &str) -> Option<usize> {
    let text = line.trim_end();
    let text = text.split(';').next().unwrap_or(text).trim();
    usize::from_str_radix(text, 16).ok()
}

/// Read a `Transfer-Encoding: chunked` body: hex-sized chunks until the
/// terminal `0` chunk, total capped at [`MAX_BODY`]. Truncated or
/// malformed framing is a 400, never a panic.
fn read_chunked_body<R: BufRead>(reader: &mut R) -> Result<Vec<u8>, ReadError> {
    let mut body = Vec::new();
    loop {
        let line = read_line_capped(reader, "chunk size")?;
        let size = parse_chunk_size(&line).ok_or_else(|| {
            ReadError::protocol(400, format!("malformed chunk size '{}'", line.trim_end()))
        })?;
        if size == 0 {
            // Optional trailers, then the blank terminator line.
            for _ in 0..MAX_HEADERS {
                let t = read_line_capped(reader, "chunk trailer")?;
                if t.trim_end().is_empty() {
                    return Ok(body);
                }
            }
            return Err(ReadError::protocol(400, "too many chunk trailers"));
        }
        if body.len() + size > MAX_BODY {
            return Err(ReadError::protocol(
                413,
                format!("chunked body exceeds the {MAX_BODY}-byte cap"),
            ));
        }
        let start = body.len();
        body.resize(start + size, 0);
        reader.read_exact(&mut body[start..]).map_err(|e| {
            ReadError::protocol(400, format!("truncated chunk ({size} bytes expected): {e}"))
        })?;
        let mut crlf = [0u8; 2];
        match reader.read_exact(&mut crlf) {
            Ok(()) if &crlf == b"\r\n" => {}
            Ok(_) => return Err(ReadError::protocol(400, "chunk not CRLF-terminated")),
            Err(e) => {
                return Err(ReadError::protocol(400, format!("truncated chunk framing: {e}")))
            }
        }
    }
}

/// Parse one request off the stream. [`ReadError::Protocol`] carries the
/// 4xx the caller should answer with; [`ReadError::Transport`] means the
/// peer is gone.
pub fn read_request(stream: &TcpStream) -> Result<Request, ReadError> {
    stream.set_read_timeout(Some(IO_TIMEOUT)).ok();
    stream.set_write_timeout(Some(IO_TIMEOUT)).ok();
    let mut reader = BufReader::new(
        stream
            .try_clone()
            .map_err(|e| ReadError::Transport(anyhow!("cloning connection: {e}")))?,
    );

    let line = read_line_capped(&mut reader, "request line")?;
    let mut parts = line.split_whitespace();
    let method = parts
        .next()
        .ok_or_else(|| ReadError::protocol(400, "empty request line"))?
        .to_string();
    let target =
        parts.next().ok_or_else(|| ReadError::protocol(400, "request line has no path"))?;
    let path = target.split('?').next().unwrap_or(target).to_string();

    let mut headers = Vec::new();
    // Count every header LINE against the cap, parsed or not — skipping
    // colon-less junk without counting it would let a peer trickle such
    // lines forever and pin this handler's connection slot.
    let mut header_lines = 0usize;
    loop {
        let h = read_line_capped(&mut reader, "header")?;
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        header_lines += 1;
        if header_lines > MAX_HEADERS {
            return Err(ReadError::protocol(431, "too many headers"));
        }
        if let Some((k, v)) = h.split_once(':') {
            headers.push((k.trim().to_string(), v.trim().to_string()));
        }
    }

    let header = |name: &str| {
        headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    };
    let chunked = header("transfer-encoding")
        .map(|v| v.to_ascii_lowercase().contains("chunked"))
        .unwrap_or(false);
    let body = if chunked {
        // Refuse the request-smuggling ambiguity outright.
        if header("content-length").is_some() {
            return Err(ReadError::protocol(
                400,
                "both Content-Length and Transfer-Encoding: chunked",
            ));
        }
        read_chunked_body(&mut reader)?
    } else {
        let len = match header("content-length") {
            None => 0,
            Some(v) => v.trim().parse::<usize>().map_err(|_| {
                ReadError::protocol(400, format!("malformed Content-Length '{v}'"))
            })?,
        };
        if len > MAX_BODY {
            return Err(ReadError::protocol(
                413,
                format!("request body of {len} bytes exceeds the {MAX_BODY} cap"),
            ));
        }
        let mut body = vec![0u8; len];
        reader.read_exact(&mut body).map_err(|e| {
            ReadError::protocol(400, format!("truncated body ({len} bytes expected): {e}"))
        })?;
        body
    };
    Ok(Request { method, path, headers, body })
}

/// Write one `Connection: close` response.
pub fn write_response(stream: &mut TcpStream, resp: &Response) -> std::io::Result<()> {
    write_response_conn(stream, resp, false)
}

/// [`write_response`] with the connection token chosen by the caller's
/// keep-alive decision (see [`wants_keep_alive`]). The `Content-Length`
/// is always present, so a persistent peer can frame the body without
/// waiting for EOF.
pub fn write_response_conn(
    stream: &mut TcpStream,
    resp: &Response,
    keep_alive: bool,
) -> std::io::Result<()> {
    let mut head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: {}\r\n",
        resp.status,
        status_reason(resp.status),
        resp.content_type,
        resp.body.len(),
        if keep_alive { "keep-alive" } else { "close" },
    );
    for (name, value) in &resp.headers {
        head.push_str(&format!("{name}: {value}\r\n"));
    }
    head.push_str("\r\n");
    stream.write_all(head.as_bytes())?;
    stream.write_all(&resp.body)?;
    stream.flush()
}

/// Whether the peer explicitly opted into connection reuse. Persistence
/// here is HTTP/1.0-style opt-in — only a literal
/// `Connection: keep-alive` request header keeps the connection open,
/// anything else (including its absence) closes after one response — so
/// existing read-to-EOF clients and `curl` keep working unchanged.
pub fn wants_keep_alive(req: &Request) -> bool {
    req.header("connection").map(|v| v.eq_ignore_ascii_case("keep-alive")).unwrap_or(false)
}

/// Begin a chunked streaming response (what the SSE endpoint emits);
/// follow with [`write_chunk`] calls and a final [`finish_chunked`].
pub fn write_stream_head(
    stream: &mut TcpStream,
    status: u16,
    content_type: &str,
    extra: &[(&str, &str)],
) -> std::io::Result<()> {
    let mut head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nTransfer-Encoding: chunked\r\n\
         Cache-Control: no-store\r\nConnection: close\r\n",
        status,
        status_reason(status),
        content_type,
    );
    for (name, value) in extra {
        head.push_str(&format!("{name}: {value}\r\n"));
    }
    head.push_str("\r\n");
    stream.write_all(head.as_bytes())?;
    stream.flush()
}

/// Write one chunk (no-op for empty data — a zero-length chunk would
/// terminate the stream).
pub fn write_chunk(stream: &mut TcpStream, data: &[u8]) -> std::io::Result<()> {
    if data.is_empty() {
        return Ok(());
    }
    stream.write_all(format!("{:x}\r\n", data.len()).as_bytes())?;
    stream.write_all(data)?;
    stream.write_all(b"\r\n")?;
    stream.flush()
}

/// Terminate a chunked stream.
pub fn finish_chunked(stream: &mut TcpStream) -> std::io::Result<()> {
    stream.write_all(b"0\r\n\r\n")?;
    stream.flush()
}

/// Base request head shared by every client entry point ([`request_full`]
/// and [`stream_sse`]), so the line format cannot drift between them.
fn client_head(method: &str, path: &str, addr: &str) -> String {
    format!("{method} {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n")
}

/// Status code out of an HTTP/1.1 status line.
fn parse_status_line(line: &str) -> Option<u16> {
    line.split_whitespace().nth(1).and_then(|s| s.parse::<u16>().ok())
}

/// Client side: one request/response round-trip. Returns (status, body).
pub fn request(addr: &str, method: &str, path: &str, body: Option<&str>) -> Result<(u16, String)> {
    let (status, _, body) =
        request_full(addr, method, path, body, &[]).map_err(|e| anyhow!("{e}"))?;
    Ok((status, body))
}

/// [`request`] with extra request headers (e.g. `X-Api-Key`); returns
/// (status, response headers, body). Errors are typed:
/// [`ReadError::Transport`] for connect/IO failures and vanished peers
/// (the retryable class — see `client::retry_transport`), never for a
/// well-formed HTTP error response (those return `Ok` with the status).
pub fn request_full(
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&str>,
    headers: &[(&str, &str)],
) -> Result<(u16, Vec<(String, String)>, String), ReadError> {
    request_typed(addr, method, path, "application/json", body.unwrap_or("").as_bytes(), headers)
}

/// [`request_full`] with a binary body sent as `application/octet-stream`
/// — what `POST /v2/artifacts` uploads use. Returns (status, response
/// headers, body text).
pub fn request_bytes(
    addr: &str,
    method: &str,
    path: &str,
    body: &[u8],
    headers: &[(&str, &str)],
) -> Result<(u16, Vec<(String, String)>, String), ReadError> {
    request_typed(addr, method, path, "application/octet-stream", body, headers)
}

/// The shared one-shot client: connect, send, drain to EOF.
fn request_typed(
    addr: &str,
    method: &str,
    path: &str,
    content_type: &str,
    body: &[u8],
    headers: &[(&str, &str)],
) -> Result<(u16, Vec<(String, String)>, String), ReadError> {
    let transport = |e: std::io::Error| ReadError::Transport(anyhow!("{method} {addr}: {e}"));
    let mut stream = TcpStream::connect(addr)
        .map_err(|e| ReadError::Transport(anyhow!("connecting to {addr}: {e}")))?;
    stream.set_read_timeout(Some(IO_TIMEOUT)).ok();
    stream.set_write_timeout(Some(IO_TIMEOUT)).ok();
    let mut head = client_head(method, path, addr);
    head.push_str(&format!("Content-Type: {content_type}\r\nContent-Length: {}\r\n", body.len()));
    for (name, value) in headers {
        head.push_str(&format!("{name}: {value}\r\n"));
    }
    head.push_str("\r\n");
    stream.write_all(head.as_bytes()).map_err(transport)?;
    stream.write_all(body).map_err(transport)?;
    stream.flush().map_err(transport)?;
    read_client_response(stream, addr)
}

/// Drain and parse one buffered `Connection: close` response — the
/// shared tail of [`request_full`] and [`request_bytes`]. A peer that
/// closes without a parseable status line is a transport failure (it
/// accepted the connection and died — the flaky-listener case retries
/// care about), not a protocol one: the client has no one to answer.
fn read_client_response(
    mut stream: TcpStream,
    addr: &str,
) -> Result<(u16, Vec<(String, String)>, String), ReadError> {
    let mut raw = Vec::new();
    stream
        .read_to_end(&mut raw)
        .map_err(|e| ReadError::Transport(anyhow!("reading response from {addr}: {e}")))?;
    let text = String::from_utf8_lossy(&raw);
    let status = text.lines().next().and_then(parse_status_line).ok_or_else(|| {
        ReadError::Transport(anyhow!("no usable response from {addr}: '{:.120}'", text))
    })?;
    let (head_text, payload) = match text.find("\r\n\r\n") {
        Some(i) => (text[..i].to_string(), text[i + 4..].to_string()),
        None => (text.to_string(), String::new()),
    };
    let resp_headers: Vec<(String, String)> = head_text
        .lines()
        .skip(1)
        .filter_map(|l| l.split_once(':'))
        .map(|(k, v)| (k.trim().to_string(), v.trim().to_string()))
        .collect();
    Ok((status, resp_headers, payload))
}

/// A persistent client connection: every request carries
/// `Connection: keep-alive` and responses are framed by their
/// `Content-Length`, so sequential round-trips reuse one TCP socket.
/// This is the front door's data-plane primitive — one warm connection
/// per backend instead of a connect per proxied request. Any transport
/// error poisons the connection; callers reconnect (the socket is cheap,
/// the type just makes reuse the common case).
pub struct Conn {
    addr: String,
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Conn {
    pub fn connect(addr: &str) -> Result<Conn, ReadError> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| ReadError::Transport(anyhow!("connecting to {addr}: {e}")))?;
        stream.set_read_timeout(Some(IO_TIMEOUT)).ok();
        stream.set_write_timeout(Some(IO_TIMEOUT)).ok();
        let writer = stream
            .try_clone()
            .map_err(|e| ReadError::Transport(anyhow!("cloning connection: {e}")))?;
        Ok(Conn { addr: addr.to_string(), reader: BufReader::new(stream), writer })
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// One buffered round-trip on the persistent connection; returns
    /// (status, response headers, body bytes). A transport error means
    /// the connection is dead — drop the `Conn` and reconnect.
    pub fn roundtrip(
        &mut self,
        method: &str,
        path: &str,
        content_type: &str,
        body: &[u8],
        headers: &[(&str, &str)],
    ) -> Result<(u16, Vec<(String, String)>, Vec<u8>), ReadError> {
        let addr = self.addr.clone();
        let transport = |e: std::io::Error| ReadError::Transport(anyhow!("{method} {addr}: {e}"));
        let mut head = format!(
            "{method} {path} HTTP/1.1\r\nHost: {}\r\nConnection: keep-alive\r\n\
             Content-Type: {content_type}\r\nContent-Length: {}\r\n",
            self.addr,
            body.len()
        );
        for (name, value) in headers {
            head.push_str(&format!("{name}: {value}\r\n"));
        }
        head.push_str("\r\n");
        self.writer.write_all(head.as_bytes()).map_err(transport)?;
        self.writer.write_all(body).map_err(transport)?;
        self.writer.flush().map_err(transport)?;

        let status_line = read_line_capped(&mut self.reader, "status line")?;
        let status = parse_status_line(&status_line).ok_or_else(|| {
            ReadError::Transport(anyhow!(
                "no usable response from {} ('{:.120}')",
                self.addr,
                status_line.trim_end()
            ))
        })?;
        let mut resp_headers = Vec::new();
        let mut content_length = 0usize;
        for _ in 0..=MAX_HEADERS {
            let h = read_line_capped(&mut self.reader, "response header")?;
            let h = h.trim_end();
            if h.is_empty() {
                break;
            }
            if let Some((k, v)) = h.split_once(':') {
                let (k, v) = (k.trim().to_string(), v.trim().to_string());
                if k.eq_ignore_ascii_case("content-length") {
                    content_length = v.parse().map_err(|_| {
                        ReadError::Transport(anyhow!(
                            "malformed Content-Length '{v}' from {}",
                            self.addr
                        ))
                    })?;
                }
                resp_headers.push((k, v));
            }
        }
        if content_length > MAX_BODY {
            return Err(ReadError::Transport(anyhow!(
                "response body of {content_length} bytes exceeds the {MAX_BODY} cap"
            )));
        }
        let mut body_out = vec![0u8; content_length];
        self.reader.read_exact(&mut body_out).map_err(|e| {
            ReadError::Transport(anyhow!("truncated response from {}: {e}", self.addr))
        })?;
        Ok((status, resp_headers, body_out))
    }
}

/// Client side: open a streaming GET and hand each SSE event to
/// `on_event(event_name, data)`. Returns when the server closes the
/// stream, `on_event` returns `false`, or `deadline` passes (an error).
/// Comment lines (`: keepalive`) are skipped.
pub fn stream_sse(
    addr: &str,
    path: &str,
    headers: &[(&str, &str)],
    deadline: Duration,
    on_event: &mut dyn FnMut(&str, &str) -> bool,
) -> Result<()> {
    let stream =
        TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
    stream.set_read_timeout(Some(IO_TIMEOUT)).ok();
    stream.set_write_timeout(Some(IO_TIMEOUT)).ok();
    let mut out = stream.try_clone().context("cloning connection")?;
    let mut head = client_head("GET", path, addr);
    head.push_str("Accept: text/event-stream\r\n");
    for (name, value) in headers {
        head.push_str(&format!("{name}: {value}\r\n"));
    }
    head.push_str("\r\n");
    out.write_all(head.as_bytes())?;
    out.flush()?;

    let until = Instant::now() + deadline;
    let mut reader = BufReader::new(stream);
    let status_line = read_line_capped(&mut reader, "status line")
        .map_err(|e| anyhow!("reading SSE status: {e}"))?;
    let status = parse_status_line(&status_line)
        .ok_or_else(|| anyhow!("malformed SSE status line: {status_line:.120}"))?;
    let mut chunked = false;
    loop {
        let h = read_line_capped(&mut reader, "header")
            .map_err(|e| anyhow!("reading SSE headers: {e}"))?;
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.trim().eq_ignore_ascii_case("transfer-encoding")
                && v.to_ascii_lowercase().contains("chunked")
            {
                chunked = true;
            }
        }
    }
    if status != 200 {
        // Error bodies are small; drain what is there and surface it.
        let mut body = String::new();
        (&mut reader).take(4096).read_to_string(&mut body).ok();
        return Err(anyhow!("GET {path}: HTTP {status}: {}", body.trim()));
    }
    if !chunked {
        return Err(anyhow!("GET {path}: expected a chunked event stream"));
    }

    // De-chunk into a text buffer, dispatching complete SSE events
    // (blank-line separated blocks) as they land.
    let mut text = String::new();
    loop {
        if Instant::now() > until {
            return Err(anyhow!("SSE stream on {path}: no terminal event after {deadline:?}"));
        }
        let size_line = read_line_capped(&mut reader, "chunk size")
            .map_err(|e| anyhow!("reading SSE chunk: {e}"))?;
        if size_line.trim().is_empty() {
            return Ok(()); // clean EOF after the final chunk
        }
        let size = parse_chunk_size(&size_line)
            .ok_or_else(|| anyhow!("malformed SSE chunk size '{}'", size_line.trim_end()))?;
        if size == 0 {
            return Ok(());
        }
        let mut chunk = vec![0u8; size + 2]; // data + CRLF
        reader.read_exact(&mut chunk).context("truncated SSE chunk")?;
        text.push_str(&String::from_utf8_lossy(&chunk[..size]));
        while let Some(split) = text.find("\n\n") {
            let block: String = text[..split].to_string();
            text.drain(..split + 2);
            let mut event = "message";
            let mut data = String::new();
            for line in block.lines() {
                if let Some(rest) = line.strip_prefix("event:") {
                    event = rest.trim();
                } else if let Some(rest) = line.strip_prefix("data:") {
                    if !data.is_empty() {
                        data.push('\n');
                    }
                    data.push_str(rest.trim());
                }
                // Comment lines (": keepalive") fall through untouched.
            }
            if data.is_empty() && event == "message" {
                continue; // pure keepalive block
            }
            if !on_event(event, &data) {
                return Ok(());
            }
        }
    }
}

/// Relay-grade SSE client: like [`stream_sse`] but hands over each raw
/// blank-line-terminated block — *including* its trailing `\n\n` and any
/// comment/keepalive lines — so a proxy hop can forward the stream
/// byte-for-bit without re-encoding. Two behavioural differences from
/// `stream_sse` matter to the front door: keepalive comment blocks are
/// delivered (the next hop's client needs them to keep its own read
/// timeout alive), and a mid-stream EOF without the terminating 0-chunk
/// is a [`ReadError::Transport`] error rather than a clean return — that
/// is the failover cue that the backend died under the stream. A non-200
/// answer surfaces as [`ReadError::Protocol`] carrying the proxied
/// status and body so the relay can answer its own client verbatim.
pub fn relay_sse_blocks(
    addr: &str,
    path: &str,
    headers: &[(&str, &str)],
    deadline: Duration,
    on_block: &mut dyn FnMut(&[u8]) -> bool,
) -> Result<(), ReadError> {
    let transport = |msg: String| ReadError::Transport(anyhow!(msg));
    let stream = TcpStream::connect(addr)
        .map_err(|e| ReadError::Transport(anyhow!("connecting to {addr}: {e}")))?;
    stream.set_read_timeout(Some(IO_TIMEOUT)).ok();
    stream.set_write_timeout(Some(IO_TIMEOUT)).ok();
    let mut out = stream
        .try_clone()
        .map_err(|e| ReadError::Transport(anyhow!("cloning connection: {e}")))?;
    let mut head = client_head("GET", path, addr);
    head.push_str("Accept: text/event-stream\r\n");
    for (name, value) in headers {
        head.push_str(&format!("{name}: {value}\r\n"));
    }
    head.push_str("\r\n");
    out.write_all(head.as_bytes())
        .and_then(|()| out.flush())
        .map_err(|e| ReadError::Transport(anyhow!("GET {addr}{path}: {e}")))?;

    let until = Instant::now() + deadline;
    let mut reader = BufReader::new(stream);
    let status_line = read_line_capped(&mut reader, "status line")?;
    let status = parse_status_line(&status_line)
        .ok_or_else(|| transport(format!("no usable SSE response from {addr}")))?;
    let mut chunked = false;
    for _ in 0..=MAX_HEADERS {
        let h = read_line_capped(&mut reader, "header")?;
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.trim().eq_ignore_ascii_case("transfer-encoding")
                && v.to_ascii_lowercase().contains("chunked")
            {
                chunked = true;
            }
        }
    }
    if status != 200 {
        let mut body = String::new();
        (&mut reader).take(4096).read_to_string(&mut body).ok();
        return Err(ReadError::protocol(status, body.trim()));
    }
    if !chunked {
        return Err(transport(format!("GET {path}: expected a chunked event stream")));
    }

    let mut buf: Vec<u8> = Vec::new();
    loop {
        if Instant::now() > until {
            return Err(transport(format!(
                "SSE relay on {path}: no terminal event after {deadline:?}"
            )));
        }
        let size_line = read_line_capped(&mut reader, "chunk size")?;
        if size_line.trim().is_empty() {
            // EOF mid-stream: the backend vanished without the 0-chunk
            // goodbye. This is what re-list failover keys on.
            return Err(transport(format!("SSE stream from {addr} dropped mid-flight")));
        }
        let size = parse_chunk_size(&size_line)
            .ok_or_else(|| transport(format!("malformed SSE chunk size from {addr}")))?;
        if size == 0 {
            return Ok(());
        }
        let mut chunk = vec![0u8; size + 2]; // data + CRLF
        reader
            .read_exact(&mut chunk)
            .map_err(|e| transport(format!("truncated SSE chunk from {addr}: {e}")))?;
        buf.extend_from_slice(&chunk[..size]);
        while let Some(split) = buf.windows(2).position(|w| w == b"\n\n") {
            let rest = buf.split_off(split + 2);
            let block = std::mem::replace(&mut buf, rest);
            if !on_block(&block) {
                return Ok(());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    /// One-shot echo server: parses a request, answers with its method,
    /// path and body length as JSON; protocol violations answer with
    /// their mapped 4xx like the real daemon does.
    fn spawn_echo() -> std::net::SocketAddr {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::spawn(move || {
            for conn in listener.incoming() {
                let Ok(mut stream) = conn else { break };
                match read_request(&stream) {
                    Ok(req) => {
                        let j = crate::util::json::Json::obj(vec![
                            ("method", crate::util::json::Json::str(req.method.clone())),
                            ("path", crate::util::json::Json::str(req.path.clone())),
                            (
                                "body_len",
                                crate::util::json::Json::num(req.body.len() as f64),
                            ),
                        ]);
                        write_response(&mut stream, &Response::json(200, &j)).ok();
                    }
                    Err(e) => {
                        if let Some(resp) = e.response() {
                            write_response(&mut stream, &resp).ok();
                        }
                    }
                }
            }
        });
        addr
    }

    /// Send raw bytes, return the full response text.
    fn raw_roundtrip(addr: &std::net::SocketAddr, payload: &[u8]) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).ok();
        s.write_all(payload).unwrap();
        // Half-close so a server waiting for more body sees EOF.
        s.shutdown(std::net::Shutdown::Write).ok();
        let mut out = Vec::new();
        s.read_to_end(&mut out).ok();
        String::from_utf8_lossy(&out).into_owned()
    }

    fn status_of(resp: &str) -> u16 {
        resp.lines()
            .next()
            .and_then(|l| l.split_whitespace().nth(1))
            .and_then(|s| s.parse().ok())
            .unwrap_or(0)
    }

    #[test]
    fn roundtrip_with_body() {
        let addr = spawn_echo().to_string();
        let (code, body) =
            request(&addr, "POST", "/v1/jobs?verbose=1", Some("{\"x\": 1}")).unwrap();
        assert_eq!(code, 200);
        let j = crate::util::json::Json::parse(&body).unwrap();
        assert_eq!(j.get("method").as_str(), Some("POST"));
        // Query string stripped.
        assert_eq!(j.get("path").as_str(), Some("/v1/jobs"));
        assert_eq!(j.get("body_len").as_usize(), Some(8));
    }

    #[test]
    fn get_without_body() {
        let addr = spawn_echo().to_string();
        let (code, body) = request(&addr, "GET", "/healthz", None).unwrap();
        assert_eq!(code, 200);
        let j = crate::util::json::Json::parse(&body).unwrap();
        assert_eq!(j.get("path").as_str(), Some("/healthz"));
        assert_eq!(j.get("body_len").as_usize(), Some(0));
    }

    #[test]
    fn chunked_request_body_reassembled() {
        let addr = spawn_echo();
        let resp = raw_roundtrip(
            &addr,
            b"POST /v1/jobs HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n\
              4\r\nwiki\r\n5\r\npedia\r\n0\r\n\r\n",
        );
        assert_eq!(status_of(&resp), 200, "{resp}");
        assert!(resp.contains("\"body_len\": 9"), "{resp}");
    }

    #[test]
    fn malformed_content_length_is_400() {
        let addr = spawn_echo();
        for bad in ["abc", "-1", "1e3", "18446744073709551617"] {
            let resp = raw_roundtrip(
                &addr,
                format!("POST /x HTTP/1.1\r\nContent-Length: {bad}\r\n\r\n").as_bytes(),
            );
            assert_eq!(status_of(&resp), 400, "Content-Length: {bad} -> {resp}");
        }
    }

    #[test]
    fn oversized_content_length_is_413() {
        let addr = spawn_echo();
        let resp = raw_roundtrip(
            &addr,
            format!("POST /x HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY + 1).as_bytes(),
        );
        assert_eq!(status_of(&resp), 413, "{resp}");
    }

    #[test]
    fn truncated_bodies_are_400() {
        let addr = spawn_echo();
        // Declared Content-Length longer than what arrives.
        let resp = raw_roundtrip(&addr, b"POST /x HTTP/1.1\r\nContent-Length: 50\r\n\r\nshort");
        assert_eq!(status_of(&resp), 400, "{resp}");
        // Chunked body cut off mid-chunk.
        let resp = raw_roundtrip(
            &addr,
            b"POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\nff\r\nonly-a-few-bytes",
        );
        assert_eq!(status_of(&resp), 400, "{resp}");
        // Chunked body missing its 0-terminator.
        let resp = raw_roundtrip(
            &addr,
            b"POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n4\r\nwiki\r\n",
        );
        assert_eq!(status_of(&resp), 400, "{resp}");
    }

    #[test]
    fn oversized_chunked_body_is_413() {
        let addr = spawn_echo();
        let resp = raw_roundtrip(
            &addr,
            format!(
                "POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n{:x}\r\n",
                MAX_BODY + 1
            )
            .as_bytes(),
        );
        assert_eq!(status_of(&resp), 413, "{resp}");
    }

    #[test]
    fn chunked_body_exactly_at_the_cap_is_accepted() {
        let addr = spawn_echo();
        // Two chunks summing to exactly MAX_BODY: accepted and fully
        // reassembled (this is the artifact-upload boundary case).
        let half = MAX_BODY / 2;
        let mut req = Vec::with_capacity(MAX_BODY + 256);
        req.extend_from_slice(
            b"POST /v2/artifacts HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n",
        );
        for _ in 0..2 {
            req.extend_from_slice(format!("{half:x}\r\n").as_bytes());
            req.resize(req.len() + half, b'a');
            req.extend_from_slice(b"\r\n");
        }
        req.extend_from_slice(b"0\r\n\r\n");
        let resp = raw_roundtrip(&addr, &req);
        assert_eq!(status_of(&resp), 200, "{resp:.200}");
        assert!(resp.contains(&format!("\"body_len\": {MAX_BODY}")), "{resp:.200}");

        // One byte over, split across chunks so no single chunk exceeds
        // the cap on its own: still 413.
        let mut req = Vec::with_capacity(MAX_BODY + 256);
        req.extend_from_slice(
            b"POST /v2/artifacts HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n",
        );
        req.extend_from_slice(format!("{MAX_BODY:x}\r\n").as_bytes());
        req.resize(req.len() + MAX_BODY, b'a');
        // End at the offending size line: the server rejects right here,
        // and a request with no unread tail closes cleanly.
        req.extend_from_slice(b"\r\n1\r\n");
        let resp = raw_roundtrip(&addr, &req);
        assert_eq!(status_of(&resp), 413, "{resp:.200}");
    }

    #[test]
    fn binary_client_roundtrip() {
        let addr = spawn_echo().to_string();
        let payload = vec![0u8; 1024]; // NULs would mangle a string body
        let (code, _, body) =
            request_bytes(&addr, "POST", "/v2/artifacts", &payload, &[]).unwrap();
        assert_eq!(code, 200);
        let j = crate::util::json::Json::parse(&body).unwrap();
        assert_eq!(j.get("body_len").as_usize(), Some(1024));
    }

    #[test]
    fn smuggling_ambiguity_rejected() {
        let addr = spawn_echo();
        let resp = raw_roundtrip(
            &addr,
            b"POST /x HTTP/1.1\r\nContent-Length: 4\r\nTransfer-Encoding: chunked\r\n\r\n\
              0\r\n\r\n",
        );
        assert_eq!(status_of(&resp), 400, "{resp}");
    }

    #[test]
    fn header_overflow_is_431_and_long_lines_400() {
        let addr = spawn_echo();
        let mut req = String::from("GET /x HTTP/1.1\r\n");
        for i in 0..100 {
            req.push_str(&format!("X-Flood-{i}: y\r\n"));
        }
        req.push_str("\r\n");
        let resp = raw_roundtrip(&addr, req.as_bytes());
        assert_eq!(status_of(&resp), 431, "{resp}");

        // Colon-less junk lines count against the cap too — otherwise a
        // peer could trickle them forever and pin the connection slot.
        let mut req = String::from("GET /x HTTP/1.1\r\n");
        for i in 0..100 {
            req.push_str(&format!("not-a-header-{i}\r\n"));
        }
        req.push_str("\r\n");
        let resp = raw_roundtrip(&addr, req.as_bytes());
        assert_eq!(status_of(&resp), 431, "{resp}");

        let long = format!("GET /x HTTP/1.1\r\nX-Big: {}\r\n\r\n", "a".repeat(MAX_LINE + 10));
        let resp = raw_roundtrip(&addr, long.as_bytes());
        assert_eq!(status_of(&resp), 400, "{resp}");
    }

    #[test]
    fn chunked_stream_roundtrip() {
        // A server that streams three SSE events over chunked encoding;
        // the client-side consumer reassembles them in order.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::spawn(move || {
            let (mut stream, _) = listener.accept().unwrap();
            read_request(&stream).unwrap();
            write_stream_head(&mut stream, 200, "text/event-stream", &[("X-Job", "7")])
                .unwrap();
            write_chunk(&mut stream, b": keepalive\n\n").unwrap();
            for i in 1..=3 {
                let ev = format!("event: progress\ndata: {{\"step\":{i}}}\n\n");
                write_chunk(&mut stream, ev.as_bytes()).unwrap();
            }
            write_chunk(&mut stream, b"event: state\ndata: {\"state\":\"done\"}\n\n").unwrap();
            finish_chunked(&mut stream).unwrap();
        });
        let mut seen: Vec<(String, String)> = Vec::new();
        stream_sse(
            &addr.to_string(),
            "/v2/jobs/7/events",
            &[],
            Duration::from_secs(10),
            &mut |event, data| {
                seen.push((event.to_string(), data.to_string()));
                true
            },
        )
        .unwrap();
        assert_eq!(seen.len(), 4, "{seen:?}");
        assert!(seen[..3].iter().all(|(e, _)| e == "progress"));
        assert_eq!(seen[3].0, "state");
        assert!(seen[3].1.contains("done"));
    }

    #[test]
    fn keep_alive_reuses_one_connection() {
        // A server that honours `Connection: keep-alive` the way the
        // daemon does: loop read_request → respond → hang up only when
        // the client didn't opt in. The accept counter proves reuse.
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::sync::Arc;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let conns = Arc::new(AtomicUsize::new(0));
        let conns_srv = conns.clone();
        std::thread::spawn(move || {
            for conn in listener.incoming() {
                let Ok(mut stream) = conn else { break };
                conns_srv.fetch_add(1, Ordering::SeqCst);
                loop {
                    let req = match read_request(&stream) {
                        Ok(req) => req,
                        Err(_) => break,
                    };
                    let keep = wants_keep_alive(&req);
                    let resp = Response::json(
                        200,
                        &crate::util::json::Json::obj(vec![(
                            "path",
                            crate::util::json::Json::str(req.path.clone()),
                        )]),
                    );
                    if write_response_conn(&mut stream, &resp, keep).is_err() || !keep {
                        break;
                    }
                }
            }
        });

        let mut conn = Conn::connect(&addr).unwrap();
        for i in 0..5 {
            let path = format!("/v2/jobs/{i}");
            let (status, _, body) = conn.roundtrip("GET", &path, "application/json", b"", &[])
                .unwrap();
            assert_eq!(status, 200);
            let j = crate::util::json::Json::parse(&String::from_utf8(body).unwrap()).unwrap();
            assert_eq!(j.get("path").as_str(), Some(path.as_str()));
        }
        assert_eq!(
            conns.load(Ordering::SeqCst),
            1,
            "five sequential round-trips should share one TCP connection"
        );

        // One-shot clients still close per request.
        let (status, _, _) = request_full(&addr, "GET", "/healthz", None, &[]).unwrap();
        assert_eq!(status, 200);
        assert_eq!(conns.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn sse_relay_preserves_event_boundaries_through_a_second_hop() {
        // Origin → hop → client. The origin writes four known blocks in
        // fixed 7-byte chunk slices so chunk boundaries never line up
        // with event boundaries; the hop re-emits whatever
        // relay_sse_blocks hands it. The client must see the original
        // blocks byte-for-bit — keepalive comments included.
        let blocks: Vec<&[u8]> = vec![
            b": keepalive\n\n",
            b"event: progress\ndata: {\"step\":1,\"loss\":0.5}\n\n",
            b"event: progress\ndata: {\"step\":2,\"loss\":0.25}\n\n",
            b"event: state\ndata: {\"id\":7,\"state\":\"done\"}\n\n",
        ];
        let stream_bytes: Vec<u8> = blocks.concat();

        let origin = TcpListener::bind("127.0.0.1:0").unwrap();
        let origin_addr = origin.local_addr().unwrap().to_string();
        std::thread::spawn(move || {
            let (mut stream, _) = origin.accept().unwrap();
            read_request(&stream).unwrap();
            write_stream_head(&mut stream, 200, "text/event-stream", &[]).unwrap();
            for piece in stream_bytes.chunks(7) {
                write_chunk(&mut stream, piece).unwrap();
            }
            finish_chunked(&mut stream).unwrap();
        });

        // The relay hop: consume from the origin, re-chunk each block
        // onto its own downstream client untouched.
        let hop = TcpListener::bind("127.0.0.1:0").unwrap();
        let hop_addr = hop.local_addr().unwrap().to_string();
        std::thread::spawn(move || {
            let (mut stream, _) = hop.accept().unwrap();
            read_request(&stream).unwrap();
            write_stream_head(&mut stream, 200, "text/event-stream", &[]).unwrap();
            relay_sse_blocks(
                &origin_addr,
                "/v2/jobs/7/events",
                &[],
                Duration::from_secs(10),
                &mut |block| write_chunk(&mut stream, block).is_ok(),
            )
            .unwrap();
            finish_chunked(&mut stream).unwrap();
        });

        let mut relayed: Vec<Vec<u8>> = Vec::new();
        relay_sse_blocks(
            &hop_addr,
            "/v2/jobs/7/events",
            &[],
            Duration::from_secs(10),
            &mut |block| {
                relayed.push(block.to_vec());
                true
            },
        )
        .unwrap();
        let want: Vec<Vec<u8>> = blocks.iter().map(|b| b.to_vec()).collect();
        assert_eq!(relayed, want, "relay must preserve block boundaries byte-for-bit");
    }

    #[test]
    fn status_reasons_cover_api_codes() {
        for code in [200, 201, 202, 400, 404, 405, 409, 413, 429, 431, 500, 503] {
            assert_ne!(status_reason(code), "Unknown", "{code}");
        }
    }

    #[test]
    fn status_classes() {
        assert_eq!(status_class(200), "2xx");
        assert_eq!(status_class(202), "2xx");
        assert_eq!(status_class(404), "4xx");
        assert_eq!(status_class(503), "5xx");
        assert_eq!(status_class(0), "other");
    }
}
