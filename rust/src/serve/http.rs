//! Minimal HTTP/1.1 over `std::net` — just enough protocol for the serve
//! API and its in-process client (the offline registry has no hyper).
//!
//! Server side: [`read_request`] parses one request (method, path,
//! headers, `Content-Length` body; 1 MiB body cap) off a stream and
//! [`write_response`] writes one `Connection: close` response. Client
//! side: [`request`] performs one round-trip. Every connection carries
//! exactly one request/response pair — simple, and plenty for a job API
//! whose unit of work is minutes of optimization.

use anyhow::{anyhow, Context, Result};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::time::Duration;

/// Largest accepted request body (a job spec is ~1 KiB).
pub const MAX_BODY: usize = 1 << 20;
/// Largest accepted header section.
const MAX_HEADERS: usize = 64;
/// Largest accepted single line (request line or one header) — caps the
/// memory a malicious peer can grow before the body length is even known.
const MAX_LINE: usize = 8 << 10;
/// Per-connection socket timeout.
pub const IO_TIMEOUT: Duration = Duration::from_secs(10);

/// One parsed request.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    /// Path only (any `?query` is split off and discarded).
    pub path: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }

    pub fn body_utf8(&self) -> Result<&str> {
        std::str::from_utf8(&self.body).context("request body is not UTF-8")
    }
}

/// One response about to be written.
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
}

impl Response {
    pub fn json(status: u16, body: &crate::util::json::Json) -> Response {
        Response {
            status,
            content_type: "application/json",
            body: (body.to_string_pretty() + "\n").into_bytes(),
        }
    }

    pub fn text(status: u16, body: impl Into<String>) -> Response {
        Response { status, content_type: "text/plain; charset=utf-8", body: body.into().into_bytes() }
    }

    /// `{"error": msg}` with the given status.
    pub fn error(status: u16, msg: impl Into<String>) -> Response {
        Response::json(
            status,
            &crate::util::json::Json::obj(vec![(
                "error",
                crate::util::json::Json::str(msg.into()),
            )]),
        )
    }
}

pub fn status_reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// `read_line` with a hard byte cap, so a peer streaming an endless
/// line cannot grow an unbounded buffer (plain `BufRead::read_line`
/// has no limit).
fn read_line_capped<R: BufRead>(reader: &mut R, what: &str) -> Result<String> {
    let mut buf: Vec<u8> = Vec::new();
    loop {
        let available = reader.fill_buf().with_context(|| format!("reading {what}"))?;
        if available.is_empty() {
            break; // EOF
        }
        match available.iter().position(|&b| b == b'\n') {
            Some(i) => {
                buf.extend_from_slice(&available[..i]);
                reader.consume(i + 1);
                break;
            }
            None => {
                buf.extend_from_slice(available);
                let n = available.len();
                reader.consume(n);
            }
        }
        if buf.len() > MAX_LINE {
            return Err(anyhow!("{what} exceeds the {MAX_LINE}-byte line cap"));
        }
    }
    if buf.len() > MAX_LINE {
        return Err(anyhow!("{what} exceeds the {MAX_LINE}-byte line cap"));
    }
    Ok(String::from_utf8_lossy(&buf).into_owned())
}

/// Parse one request off the stream. Errors map to a 400 at the call
/// site (or a dropped connection if the peer vanished).
pub fn read_request(stream: &TcpStream) -> Result<Request> {
    stream.set_read_timeout(Some(IO_TIMEOUT)).ok();
    stream.set_write_timeout(Some(IO_TIMEOUT)).ok();
    let mut reader = BufReader::new(stream.try_clone().context("cloning connection")?);

    let line = read_line_capped(&mut reader, "request line")?;
    let mut parts = line.split_whitespace();
    let method = parts.next().ok_or_else(|| anyhow!("empty request line"))?.to_string();
    let target = parts.next().ok_or_else(|| anyhow!("request line has no path"))?;
    let path = target.split('?').next().unwrap_or(target).to_string();

    let mut headers = Vec::new();
    loop {
        let h = read_line_capped(&mut reader, "header")?;
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if headers.len() >= MAX_HEADERS {
            return Err(anyhow!("too many headers"));
        }
        if let Some((k, v)) = h.split_once(':') {
            headers.push((k.trim().to_string(), v.trim().to_string()));
        }
    }

    let len = headers
        .iter()
        .find(|(k, _)| k.eq_ignore_ascii_case("content-length"))
        .and_then(|(_, v)| v.parse::<usize>().ok())
        .unwrap_or(0);
    if len > MAX_BODY {
        return Err(anyhow!("request body of {len} bytes exceeds the {MAX_BODY} cap"));
    }
    let mut body = vec![0u8; len];
    reader.read_exact(&mut body).context("reading request body")?;
    Ok(Request { method, path, headers, body })
}

/// Write one `Connection: close` response.
pub fn write_response(stream: &mut TcpStream, resp: &Response) -> std::io::Result<()> {
    let head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        resp.status,
        status_reason(resp.status),
        resp.content_type,
        resp.body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(&resp.body)?;
    stream.flush()
}

/// Client side: one request/response round-trip. Returns (status, body).
pub fn request(addr: &str, method: &str, path: &str, body: Option<&str>) -> Result<(u16, String)> {
    let mut stream =
        TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
    stream.set_read_timeout(Some(IO_TIMEOUT)).ok();
    stream.set_write_timeout(Some(IO_TIMEOUT)).ok();
    let body = body.unwrap_or("");
    let head = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\
         Content-Type: application/json\r\nContent-Length: {}\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()?;

    let mut raw = Vec::new();
    stream.read_to_end(&mut raw).context("reading response")?;
    let text = String::from_utf8_lossy(&raw);
    let status = text
        .lines()
        .next()
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|s| s.parse::<u16>().ok())
        .ok_or_else(|| anyhow!("malformed response from {addr}: {:.120}", text))?;
    let payload = match text.find("\r\n\r\n") {
        Some(i) => text[i + 4..].to_string(),
        None => String::new(),
    };
    Ok((status, payload))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    /// One-shot echo server: parses a request, answers with its method,
    /// path and body length as JSON.
    fn spawn_echo() -> std::net::SocketAddr {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::spawn(move || {
            for conn in listener.incoming() {
                let Ok(mut stream) = conn else { break };
                match read_request(&stream) {
                    Ok(req) => {
                        let j = crate::util::json::Json::obj(vec![
                            ("method", crate::util::json::Json::str(req.method.clone())),
                            ("path", crate::util::json::Json::str(req.path.clone())),
                            (
                                "body_len",
                                crate::util::json::Json::num(req.body.len() as f64),
                            ),
                        ]);
                        write_response(&mut stream, &Response::json(200, &j)).ok();
                    }
                    Err(e) => {
                        write_response(&mut stream, &Response::error(400, format!("{e:#}")))
                            .ok();
                    }
                }
            }
        });
        addr
    }

    #[test]
    fn roundtrip_with_body() {
        let addr = spawn_echo().to_string();
        let (code, body) =
            request(&addr, "POST", "/v1/jobs?verbose=1", Some("{\"x\": 1}")).unwrap();
        assert_eq!(code, 200);
        let j = crate::util::json::Json::parse(&body).unwrap();
        assert_eq!(j.get("method").as_str(), Some("POST"));
        // Query string stripped.
        assert_eq!(j.get("path").as_str(), Some("/v1/jobs"));
        assert_eq!(j.get("body_len").as_usize(), Some(8));
    }

    #[test]
    fn get_without_body() {
        let addr = spawn_echo().to_string();
        let (code, body) = request(&addr, "GET", "/healthz", None).unwrap();
        assert_eq!(code, 200);
        let j = crate::util::json::Json::parse(&body).unwrap();
        assert_eq!(j.get("path").as_str(), Some("/healthz"));
        assert_eq!(j.get("body_len").as_usize(), Some(0));
    }

    #[test]
    fn status_reasons_cover_api_codes() {
        for code in [200, 202, 400, 404, 405, 409, 413, 429, 500, 503] {
            assert_ne!(status_reason(code), "Unknown", "{code}");
        }
    }
}
