//! The job queue: a bounded FIFO with a per-job state machine
//! (queued → running → done/failed/cancelled) executed by a fixed worker
//! set, fronted by admission control.
//!
//! Each worker claims one job at a time and drives it through
//! [`run_job_with`](super::job::run_job_with) (which owns the job's
//! `OptimSession`), recording the **full** loss series (the v2 result
//! surface), a short tail (the frozen v1 status surface), and publishing
//! every step through the job's bounded [`ProgressBus`] — the broadcast
//! channel behind `GET /v2/jobs/:id/events`. Worker panics are caught
//! and surface as `failed` jobs — the daemon never dies on a bad spec.
//!
//! Admission ([`Admission`]) runs **ahead** of the FIFO: per-tenant
//! active-job quotas, a `B·p·n·steps` cost budget across all admitted
//! work, and an inline-payload byte cap each refuse a submission before
//! it occupies queue capacity (mapped to `429` + `Retry-After` / `413`
//! by the API layer and counted separately in `/metrics`).
//!
//! Shutdown is graceful: workers stop claiming new jobs and drain the
//! ones they are running; still-queued jobs stay queued (and, with a
//! state dir, persisted for the next daemon). With a `state_dir`, every
//! job's spec + state lands in `job-<id>.json`, jobs with
//! `checkpoint_every > 0` (either domain — complex stores checkpoint as
//! interleaved `c64` pairs) checkpoint to `job-<id>.ckpt`, and every
//! terminal job spills its full loss series + final iterate to
//! `job-<id>.series.ckpt` (POGO-CKPT framing, f64). A restarted queue
//! re-lists unfinished jobs, resumes them from their checkpoints, and
//! serves recovered terminal jobs' v2 results — series and iterate
//! bit-identical — from the spill, which is what lets the federated
//! front door treat a backend restart as a non-event.

use super::job::{
    self, FinalIterate, JobDomain, JobOutcome, JobResult, JobSpec, JobState, RunCtl, StepProgress,
};
use super::metrics::ServeMetrics;
use super::problem::ProblemSource;
use crate::artifact::{Artifact, ArtifactStore, Provenance};
use crate::coordinator::{checkpoint, ParamStore};
use crate::linalg::Mat;
use crate::obs::hist::Hist;
use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::collections::{BTreeMap, VecDeque};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

pub type JobId = u64;

/// Kept loss-tail length per job (the "metrics tail" of the v1 status
/// API; v2 keeps the full series).
const TAIL_LEN: usize = 8;

/// Progress events buffered per job. A subscriber that connects late (or
/// falls behind) replays from the oldest buffered event — enough that a
/// short job's whole stream is still served after it finished, which is
/// what makes `curl -N …/events` deterministic in CI.
const EVENT_BUF: usize = 256;

/// In-memory loss-series points retained per job (32 MB at 16 B/point).
/// Jobs within the cap serve their series untruncated; a longer run
/// drops its OLDEST points so one pathological `steps` value cannot OOM
/// the daemon through its own telemetry.
const SERIES_CAP: usize = 2_000_000;

/// Terminal jobs retained in memory for status queries. Older terminal
/// entries are evicted (oldest id first) so a resident daemon's job map
/// and `GET /v1/jobs` stay bounded; with a state dir the evicted jobs'
/// files remain on disk for offline inspection.
const MAX_TERMINAL_RETAINED: usize = 1024;

/// Admission-control knobs, all "0 = unlimited" (the v1-compatible
/// default). Checked ahead of the FIFO so rejected work never occupies
/// queue capacity.
#[derive(Clone, Copy, Debug)]
pub struct Admission {
    /// Max active (queued + running) jobs per tenant.
    pub tenant_quota: usize,
    /// Max total outstanding `B·p·n·steps` cost across admitted jobs.
    pub cost_cap: u64,
    /// Max inline problem payload bytes per job.
    pub max_inline_bytes: usize,
}

impl Default for Admission {
    fn default() -> Self {
        Admission { tenant_quota: 0, cost_cap: 0, max_inline_bytes: 8 << 20 }
    }
}

/// Queue sizing, persistence and admission.
#[derive(Clone, Debug)]
pub struct QueueConfig {
    /// Fixed worker thread count.
    pub workers: usize,
    /// Max queued (not yet running) jobs; submissions beyond it are
    /// refused with [`SubmitError::Full`].
    pub capacity: usize,
    /// Persist job state (+ checkpoints) here; `None` = in-memory only.
    pub state_dir: Option<PathBuf>,
    /// Admission control ahead of the FIFO.
    pub admission: Admission,
    /// Content-addressed artifact store (`--artifact-dir`). `None`
    /// disables the `artifact` problem source and inline dedupe.
    pub artifacts: Option<Arc<ArtifactStore>>,
}

impl Default for QueueConfig {
    fn default() -> Self {
        QueueConfig {
            workers: crate::util::pool::num_threads().min(4).max(1),
            capacity: 256,
            state_dir: None,
            admission: Admission::default(),
            artifacts: None,
        }
    }
}

/// Why a submission was refused.
#[derive(Debug)]
pub enum SubmitError {
    /// Backlog at capacity; retry later.
    Full(usize),
    /// The queue is shutting down.
    Draining,
    /// The spec failed admission validation.
    Invalid(anyhow::Error),
    /// The tenant is at its active-job quota; retry after `retry_after_s`.
    Quota { tenant: String, active: usize, quota: usize, retry_after_s: u64 },
    /// The cost budget has no room for this job; retry after
    /// `retry_after_s`.
    Cost { cost: u64, outstanding: u64, cap: u64, retry_after_s: u64 },
    /// The inline problem payload exceeds the daemon's byte cap.
    InlineTooLarge { bytes: usize, cap: usize },
    /// The referenced artifact hash is not in the daemon's store.
    ArtifactMissing { hash: String },
    /// A submission requested an explicit id that is already tracked
    /// (federated re-list/replay collisions map to `409`).
    IdTaken(JobId),
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Full(cap) => write!(f, "queue full (capacity {cap})"),
            SubmitError::Draining => write!(f, "queue is draining (shutdown in progress)"),
            SubmitError::Invalid(e) => write!(f, "invalid job: {e:#}"),
            SubmitError::Quota { tenant, active, quota, .. } => write!(
                f,
                "tenant '{tenant}' is at its quota ({active} active of {quota} allowed)"
            ),
            SubmitError::Cost { cost, outstanding, cap, .. } => write!(
                f,
                "cost budget exhausted: job costs {cost} units, {outstanding} of {cap} \
                 already admitted"
            ),
            SubmitError::InlineTooLarge { bytes, cap } => {
                write!(f, "inline payload of {bytes} bytes exceeds the {cap}-byte cap")
            }
            SubmitError::ArtifactMissing { hash } => {
                write!(
                    f,
                    "artifact {hash} is not in the store (upload it with POST /v2/artifacts \
                     or `pogo compile`)"
                )
            }
            SubmitError::IdTaken(id) => {
                write!(f, "job id {id} already exists on this daemon")
            }
        }
    }
}

/// `Retry-After` hint (seconds) for admission rejections. Once the
/// process has observed queue waits and run times (the PR-9 `obs::hist`
/// families), a new arrival is estimated to ride out the median queue
/// wait plus the backlog ahead of it draining at one median run per
/// worker slot. Before any observation — cold start, or a daemon running
/// without `POGO_OBS=1` — it falls back to the old backlog-scaled
/// constant. Clamped to `[1, 600]` seconds.
pub fn retry_after_hint(pending: usize, workers: usize) -> u64 {
    retry_after_from(
        pending,
        workers,
        crate::obs::hist::JOB_QUEUE_WAIT_SECONDS.hist0(),
        crate::obs::hist::JOB_RUN_SECONDS.hist0(),
    )
}

/// [`retry_after_hint`] against explicit histograms (unit-testable
/// without touching the process-wide families).
fn retry_after_from(pending: usize, workers: usize, wait: &Hist, run: &Hist) -> u64 {
    if wait.count() == 0 && run.count() == 0 {
        return 1 + (pending as u64).min(59);
    }
    let p50_wait_us = wait.quantile_us(0.5).unwrap_or(0);
    let p50_run_us = run.quantile_us(0.5).unwrap_or(0);
    let backlog_us = (pending as u64).saturating_mul(p50_run_us) / workers.max(1) as u64;
    let est_s = p50_wait_us.saturating_add(backlog_us).div_ceil(1_000_000);
    est_s.clamp(1, 600)
}

/// One event on a job's progress bus.
#[derive(Clone, Debug)]
pub enum ProgressEvent {
    /// One applied optimizer step.
    Step(StepProgress),
    /// The job reached a terminal state; the bus closes after this.
    Terminal(JobState),
}

/// What [`ProgressBus::next_event`] observed.
#[derive(Debug)]
pub enum BusPoll {
    /// An event at the returned cursor; pass the cursor back to resume.
    Event(u64, ProgressEvent),
    /// Nothing new before the wait elapsed (send a keepalive and retry).
    Pending,
    /// Terminal event already consumed and the bus is closed.
    Closed,
}

/// A bounded broadcast channel of one job's progress: the last
/// [`EVENT_BUF`] events stay buffered (late subscribers replay them),
/// every subscriber polls with its own cursor, and slow subscribers skip
/// ahead rather than block the publisher.
pub struct ProgressBus {
    state: Mutex<BusState>,
    cv: Condvar,
}

struct BusState {
    next_seq: u64,
    buf: VecDeque<(u64, ProgressEvent)>,
    closed: bool,
}

impl ProgressBus {
    fn new() -> Arc<ProgressBus> {
        Arc::new(ProgressBus {
            state: Mutex::new(BusState { next_seq: 0, buf: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
        })
    }

    /// A bus that was already terminal when observed (recovered jobs).
    fn closed_with(state: JobState) -> Arc<ProgressBus> {
        let bus = ProgressBus::new();
        bus.close(state);
        bus
    }

    fn publish(&self, ev: ProgressEvent) {
        {
            let mut st = self.state.lock().unwrap();
            if st.closed {
                return;
            }
            let seq = st.next_seq;
            st.next_seq += 1;
            if st.buf.len() == EVENT_BUF {
                st.buf.pop_front();
            }
            st.buf.push_back((seq, ev));
        }
        self.cv.notify_all();
    }

    /// Publish the terminal event and close (idempotent).
    fn close(&self, terminal: JobState) {
        {
            let mut st = self.state.lock().unwrap();
            if st.closed {
                return;
            }
            let seq = st.next_seq;
            st.next_seq += 1;
            if st.buf.len() == EVENT_BUF {
                st.buf.pop_front();
            }
            st.buf.push_back((seq, ProgressEvent::Terminal(terminal)));
            st.closed = true;
        }
        self.cv.notify_all();
    }

    /// Earliest buffered event with sequence ≥ `cursor`, waiting up to
    /// `wait` while the bus is open. A subscriber starts at cursor 0 and
    /// feeds each returned cursor back in.
    pub fn next_event(&self, cursor: u64, wait: Duration) -> BusPoll {
        let deadline = Instant::now() + wait;
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some((seq, ev)) = st.buf.iter().find(|(s, _)| *s >= cursor) {
                return BusPoll::Event(seq + 1, ev.clone());
            }
            if st.closed {
                return BusPoll::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return BusPoll::Pending;
            }
            let (guard, _) = self.cv.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
    }
}

/// One tracked job.
struct Entry {
    spec: JobSpec,
    /// API-key tenant that admitted the job (`anonymous` by default).
    tenant: String,
    /// Admission cost units held while the job is active.
    cost: u64,
    state: JobState,
    error: Option<String>,
    result: Option<JobResult>,
    steps_done: usize,
    /// Last [`TAIL_LEN`] (step, wall_s, loss) records (v1 status tail).
    tail: VecDeque<(usize, f64, f64)>,
    /// Live (step, loss) series, bounded at [`SERIES_CAP`] points (the
    /// oldest drop first past the cap).
    series: VecDeque<(usize, f64)>,
    /// The series, frozen into an `Arc` at the terminal transition so
    /// result reads are O(1) under the queue lock. With a state dir it
    /// is also spilled to `job-<id>.series.ckpt` and recovered on
    /// restart, so the v2 result surface survives the daemon.
    series_final: Option<Arc<Vec<(usize, f64)>>>,
    /// Final iterate (v2 result surface; spilled and recovered alongside
    /// the series).
    iterate: Option<Arc<FinalIterate>>,
    bus: Arc<ProgressBus>,
    cancel: Arc<AtomicBool>,
    /// Flight recorder: this job's bounded span buffer (epoch = the
    /// submission instant). In-memory only — a recovered job starts a
    /// fresh, empty trace.
    trace: Arc<crate::obs::JobTrace>,
    /// Trace-relative µs at which the job entered the FIFO (the end of
    /// its `admit` span); the `queued` span runs from here to claim.
    queued_from_us: u64,
}

#[derive(Default)]
struct State {
    next_id: JobId,
    pending: VecDeque<JobId>,
    jobs: BTreeMap<JobId, Entry>,
    draining: bool,
    running: usize,
    /// Active (queued + running) jobs per tenant.
    active_by_tenant: BTreeMap<String, usize>,
    /// Total admitted-but-unfinished cost units.
    outstanding_cost: u64,
}

impl State {
    fn admit_accounting(&mut self, tenant: &str, cost: u64) {
        *self.active_by_tenant.entry(tenant.to_string()).or_insert(0) += 1;
        self.outstanding_cost = self.outstanding_cost.saturating_add(cost);
    }

    /// Release a job's admission hold (exactly once, when it turns
    /// terminal).
    fn release_accounting(&mut self, tenant: &str, cost: u64) {
        if let Some(n) = self.active_by_tenant.get_mut(tenant) {
            *n = n.saturating_sub(1);
            if *n == 0 {
                self.active_by_tenant.remove(tenant);
            }
        }
        self.outstanding_cost = self.outstanding_cost.saturating_sub(cost);
    }

    /// Evict the oldest terminal entries beyond [`MAX_TERMINAL_RETAINED`]
    /// (in-memory only; persisted state files are left alone).
    fn prune_terminal(&mut self) {
        let terminal: Vec<JobId> = self
            .jobs
            .iter()
            .filter(|(_, e)| e.state.is_terminal())
            .map(|(&id, _)| id)
            .collect();
        if terminal.len() > MAX_TERMINAL_RETAINED {
            for id in &terminal[..terminal.len() - MAX_TERMINAL_RETAINED] {
                self.jobs.remove(id);
            }
        }
    }
}

struct Inner {
    cfg: QueueConfig,
    metrics: Arc<ServeMetrics>,
    state: Mutex<State>,
    cv: Condvar,
}

/// Everything the v2 result endpoint serves about one job. The series
/// is the terminal snapshot (shared, not copied); it is empty while the
/// job is still live — the result endpoint answers 409 then anyway.
pub struct ResultView {
    pub state: JobState,
    pub tenant: String,
    pub result: Option<JobResult>,
    pub error: Option<String>,
    pub series: Arc<Vec<(usize, f64)>>,
    pub iterate: Option<Arc<FinalIterate>>,
}

/// The queue handle. Cheap to share (`Arc` it once in the server).
pub struct JobQueue {
    inner: Arc<Inner>,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl JobQueue {
    /// Create the queue, recover any persisted jobs, spawn the workers.
    pub fn start(cfg: QueueConfig, metrics: Arc<ServeMetrics>) -> Result<Arc<JobQueue>> {
        if let Some(dir) = &cfg.state_dir {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating state dir {}", dir.display()))?;
        }
        // Zero workers is allowed (a queue that only accepts/persists —
        // used by tests); the server layer guards its own default.
        let workers = cfg.workers;
        if workers > 0 {
            // Spin up the shared compute pool once, before any job runs:
            // every serve worker dispatches into the SAME resident pool
            // (serialized by its run lock), so the daemon's thread budget
            // is `workers + num_threads()−1` rather than the old
            // spawn-per-call worst case of `workers × num_threads()`.
            // `/metrics` surfaces the pool's mode/size/dispatch count.
            crate::util::pool::warm_pool();
        }
        let inner = Arc::new(Inner {
            cfg,
            metrics,
            state: Mutex::new(State { next_id: 1, ..State::default() }),
            cv: Condvar::new(),
        });
        inner.recover();
        let queue = Arc::new(JobQueue { inner, workers: Mutex::new(Vec::new()) });
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let inner = queue.inner.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("pogo-serve-worker-{w}"))
                    .spawn(move || worker_loop(inner))
                    .context("spawning worker thread")?,
            );
        }
        *queue.workers.lock().unwrap() = handles;
        Ok(queue)
    }

    /// Submit a job under the default (`anonymous`) tenant.
    pub fn submit(&self, spec: JobSpec) -> std::result::Result<JobId, SubmitError> {
        self.submit_as(spec, "anonymous")
    }

    /// Submit a job for `tenant`; returns its id or why admission
    /// refused it. Admission runs in order: scalar validity → inline
    /// byte cap → source admission (payload validation, artifact
    /// resolution, inline dedupe through the store) → tenant quota →
    /// cost budget → backlog capacity — all before the job touches the
    /// FIFO.
    pub fn submit_as(
        &self,
        spec: JobSpec,
        tenant: &str,
    ) -> std::result::Result<JobId, SubmitError> {
        self.submit_with_id(spec, tenant, None)
    }

    /// [`submit_as`](Self::submit_as) with an optional caller-chosen id.
    /// The federated front door assigns ids itself (so the public id is
    /// identical on whichever backend the job lands on, including after a
    /// re-list) and passes them down via `X-Pogo-Job-Id`; a requested id
    /// that is already tracked is refused with [`SubmitError::IdTaken`].
    pub fn submit_with_id(
        &self,
        mut spec: JobSpec,
        tenant: &str,
        requested: Option<JobId>,
    ) -> std::result::Result<JobId, SubmitError> {
        // The flight recorder's epoch is the submission instant, so the
        // `admit` span below covers everything admission does (payload
        // validation, artifact resolution, inline dedupe). Rejected
        // submissions drop the trace with the error.
        let trace = Arc::new(crate::obs::JobTrace::new());
        let reject = |counter: &std::sync::atomic::AtomicU64, err: SubmitError| {
            self.inner.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            counter.fetch_add(1, Ordering::Relaxed);
            Err(err)
        };
        if let Err(e) = spec.validate_scalars() {
            return reject(&self.inner.metrics.rejected_invalid, SubmitError::Invalid(e));
        }
        let adm = self.inner.cfg.admission;
        let payload = spec.source.payload_bytes();
        if adm.max_inline_bytes > 0 && payload > adm.max_inline_bytes {
            return reject(
                &self.inner.metrics.rejected_inline,
                SubmitError::InlineTooLarge { bytes: payload, cap: adm.max_inline_bytes },
            );
        }
        if let Err(err) = self.admit_source(&mut spec) {
            let counter = match &err {
                SubmitError::ArtifactMissing { .. } => &self.inner.metrics.rejected_artifact,
                _ => &self.inner.metrics.rejected_invalid,
            };
            return reject(counter, err);
        }
        let cost = spec.cost();
        let id = {
            let mut st = self.inner.state.lock().unwrap();
            if st.draining {
                self.inner.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                return Err(SubmitError::Draining);
            }
            // Retry hint: estimated from the observed queue-wait/run-time
            // histograms when they have data; the old backlog-scaled
            // constant covers the cold start (see retry_after_hint).
            let retry_after_s = retry_after_hint(st.pending.len(), self.inner.cfg.workers);
            if adm.tenant_quota > 0 {
                let active = st.active_by_tenant.get(tenant).copied().unwrap_or(0);
                if active >= adm.tenant_quota {
                    drop(st);
                    return reject(
                        &self.inner.metrics.rejected_quota,
                        SubmitError::Quota {
                            tenant: tenant.to_string(),
                            active,
                            quota: adm.tenant_quota,
                            retry_after_s,
                        },
                    );
                }
            }
            if adm.cost_cap > 0 && st.outstanding_cost.saturating_add(cost) > adm.cost_cap {
                let outstanding = st.outstanding_cost;
                drop(st);
                return reject(
                    &self.inner.metrics.rejected_cost,
                    SubmitError::Cost { cost, outstanding, cap: adm.cost_cap, retry_after_s },
                );
            }
            if st.pending.len() >= self.inner.cfg.capacity {
                self.inner.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                return Err(SubmitError::Full(self.inner.cfg.capacity));
            }
            let id = match requested {
                Some(rid) => {
                    if st.jobs.contains_key(&rid) {
                        drop(st);
                        return reject(
                            &self.inner.metrics.rejected_invalid,
                            SubmitError::IdTaken(rid),
                        );
                    }
                    st.next_id = st.next_id.max(rid + 1);
                    rid
                }
                None => {
                    let id = st.next_id;
                    st.next_id += 1;
                    id
                }
            };
            st.admit_accounting(tenant, cost);
            let queued_from_us = if crate::obs::enabled() {
                let t = trace.now_us();
                trace.record_span("admit", 0, t, 1);
                t
            } else {
                0
            };
            st.jobs.insert(
                id,
                Entry {
                    spec,
                    tenant: tenant.to_string(),
                    cost,
                    state: JobState::Queued,
                    error: None,
                    result: None,
                    steps_done: 0,
                    tail: VecDeque::new(),
                    series: VecDeque::new(),
                    series_final: None,
                    iterate: None,
                    bus: ProgressBus::new(),
                    cancel: Arc::new(AtomicBool::new(false)),
                    trace,
                    queued_from_us,
                },
            );
            st.pending.push_back(id);
            id
        };
        self.inner.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        self.inner.persist(id);
        // notify_all, not notify_one: the condvar is shared with
        // wait_terminal waiters, and a single wakeup could land on one of
        // them while an idle worker keeps sleeping.
        self.inner.cv.notify_all();
        Ok(id)
    }

    /// Source admission: validate payloads, resolve artifact refs from
    /// the store, and dedupe inline payloads through it.
    ///
    /// - `builtin` — nothing to check beyond the scalars.
    /// - `artifact` — look the hash up in the store; a hit decodes the
    ///   (upload-time validated) payload into the spec, a miss is
    ///   [`SubmitError::ArtifactMissing`]. Runs before the queue lock —
    ///   store I/O never blocks other submissions.
    /// - `inline` with a store — seal the payload exactly as
    ///   `pogo compile` would and look the content address up: a hit
    ///   means this payload already passed full validation once, so only
    ///   the structural checks rerun (the O(payload) value scan is
    ///   skipped); a miss validates fully and inserts, so the *next*
    ///   identical submission (or an `artifact` job by this hash) is
    ///   served from cache.
    /// - `inline` without a store — the classic full-validation path.
    fn admit_source(&self, spec: &mut JobSpec) -> std::result::Result<(), SubmitError> {
        let store = self.inner.cfg.artifacts.as_deref();
        let metrics = &self.inner.metrics;
        let (domain, batch, p, n) = (spec.domain, spec.batch, spec.p, spec.n);
        match &mut spec.source {
            ProblemSource::Builtin(_) => Ok(()),
            ProblemSource::Artifact(r) => {
                let Some(store) = store else {
                    return Err(SubmitError::Invalid(anyhow!(
                        "this daemon has no artifact store (start it with --artifact-dir)"
                    )));
                };
                match store.get(&r.hash) {
                    Ok(Some(art)) => {
                        let problem = art.to_problem().map_err(SubmitError::Invalid)?;
                        metrics.artifact_hits.fetch_add(1, Ordering::Relaxed);
                        r.resolve(problem);
                        Ok(())
                    }
                    Ok(None) => {
                        metrics.artifact_misses.fetch_add(1, Ordering::Relaxed);
                        Err(SubmitError::ArtifactMissing { hash: r.hash.clone() })
                    }
                    Err(e) => Err(SubmitError::Invalid(e)),
                }
            }
            ProblemSource::Inline(inline) => {
                let Some(store) = store else {
                    return inline.validate(domain, batch, p, n).map_err(SubmitError::Invalid);
                };
                let mut prov = Provenance::new(spec.seed);
                prov.optimizer = Some(spec.optimizer.to_json());
                let art = Artifact::seal_for_hash(inline, domain, batch, p, n, prov)
                    .map_err(SubmitError::Invalid)?;
                let hash = art.hash();
                if store.touch(&hash) {
                    metrics.artifact_hits.fetch_add(1, Ordering::Relaxed);
                    return Ok(());
                }
                metrics.artifact_misses.fetch_add(1, Ordering::Relaxed);
                inline.validate(domain, batch, p, n).map_err(SubmitError::Invalid)?;
                match store.insert(&art) {
                    Ok(outcome) => {
                        metrics
                            .artifact_evictions
                            .fetch_add(outcome.evicted as u64, Ordering::Relaxed);
                    }
                    // A store refusal (payload larger than the whole
                    // budget, disk trouble) must not fail an already
                    // fully validated job — it just stays uncached.
                    Err(e) => log::warn!("inline dedupe: not caching {hash}: {e:#}"),
                }
                Ok(())
            }
        }
    }

    /// The daemon's artifact store, when one is configured (what the API
    /// layer serves `POST /v2/artifacts` from).
    pub fn artifacts(&self) -> Option<Arc<ArtifactStore>> {
        self.inner.cfg.artifacts.clone()
    }

    /// Cancel a job. Queued jobs flip to `cancelled` immediately; running
    /// jobs get their flag set and finish at the next step boundary.
    /// Returns the state after the call, or `None` for unknown ids.
    pub fn cancel(&self, id: JobId) -> Option<JobState> {
        let (state, persist) = {
            let mut st = self.inner.state.lock().unwrap();
            let current = st.jobs.get(&id)?.state;
            match current {
                JobState::Queued => {
                    st.pending.retain(|&q| q != id);
                    let (tenant, cost, bus) = match st.jobs.get_mut(&id) {
                        Some(e) => {
                            e.state = JobState::Cancelled;
                            e.result = None;
                            (e.tenant.clone(), e.cost, e.bus.clone())
                        }
                        None => unreachable!("checked above"),
                    };
                    st.release_accounting(&tenant, cost);
                    bus.close(JobState::Cancelled);
                    self.inner.metrics.cancelled.fetch_add(1, Ordering::Relaxed);
                    (JobState::Cancelled, true)
                }
                JobState::Running => {
                    if let Some(e) = st.jobs.get(&id) {
                        e.cancel.store(true, Ordering::Relaxed);
                    }
                    // Persist too: the state file records cancel_requested
                    // so a crash before the next step boundary cannot
                    // resurrect an acknowledged cancellation on restart.
                    (JobState::Running, true)
                }
                s => (s, false),
            }
        };
        if persist {
            self.inner.persist(id);
            self.inner.prune();
            self.inner.cv.notify_all();
        }
        Some(state)
    }

    /// Status snapshot for the v1 API (`None` for unknown ids).
    pub fn status_json(&self, id: JobId) -> Option<Json> {
        let st = self.inner.state.lock().unwrap();
        let e = st.jobs.get(&id)?;
        Some(entry_json(id, e, true))
    }

    /// v2 status: the v1 fields plus tenant, admission cost and the
    /// series length (the full series itself is on the result endpoint).
    pub fn status_v2_json(&self, id: JobId) -> Option<Json> {
        let st = self.inner.state.lock().unwrap();
        let e = st.jobs.get(&id)?;
        let mut map = match entry_json(id, e, true) {
            Json::Obj(m) => m,
            _ => unreachable!("entry_json returns an object"),
        };
        map.insert("tenant".to_string(), Json::str(e.tenant.clone()));
        map.insert("cost".to_string(), Json::num(e.cost as f64));
        let series_len =
            e.series_final.as_ref().map(|s| s.len()).unwrap_or_else(|| e.series.len());
        map.insert("series_len".to_string(), Json::num(series_len as f64));
        Some(Json::Obj(map))
    }

    /// Flight-recorder timeline for one job (`None` for unknown ids):
    /// the span tree from [`crate::obs::JobTrace::tree_json`] plus the
    /// job's id, state, and wall-clock age in µs. Served by
    /// `GET /v2/jobs/:id/trace`; live jobs answer with whatever spans
    /// have closed so far.
    pub fn trace_json(&self, id: JobId) -> Option<Json> {
        let (trace, state) = {
            let st = self.inner.state.lock().unwrap();
            let e = st.jobs.get(&id)?;
            (e.trace.clone(), e.state)
        };
        let mut map = match trace.tree_json() {
            Json::Obj(m) => m,
            _ => unreachable!("tree_json returns an object"),
        };
        map.insert("id".to_string(), Json::num(id as f64));
        map.insert("state".to_string(), Json::str(state.name()));
        map.insert("wall_us".to_string(), Json::num(trace.now_us() as f64));
        Some(Json::Obj(map))
    }

    /// (state, result, error) snapshot, for the result endpoint/tests.
    pub fn snapshot(&self, id: JobId) -> Option<(JobState, Option<JobResult>, Option<String>)> {
        let st = self.inner.state.lock().unwrap();
        let e = st.jobs.get(&id)?;
        Some((e.state, e.result.clone(), e.error.clone()))
    }

    /// Everything the v2 result endpoint serves. O(1) under the queue
    /// lock: the series is the frozen terminal `Arc`, never a copy.
    pub fn result_view(&self, id: JobId) -> Option<ResultView> {
        let st = self.inner.state.lock().unwrap();
        let e = st.jobs.get(&id)?;
        Some(ResultView {
            state: e.state,
            tenant: e.tenant.clone(),
            result: e.result.clone(),
            error: e.error.clone(),
            series: e.series_final.clone().unwrap_or_default(),
            iterate: e.iterate.clone(),
        })
    }

    /// Subscribe to a job's progress bus (`None` for unknown ids). The
    /// bus replays its buffered tail to late subscribers and closes with
    /// a terminal event.
    pub fn subscribe(&self, id: JobId) -> Option<Arc<ProgressBus>> {
        let st = self.inner.state.lock().unwrap();
        Some(st.jobs.get(&id)?.bus.clone())
    }

    /// All jobs, compact.
    pub fn list_json(&self) -> Json {
        let st = self.inner.state.lock().unwrap();
        Json::arr(st.jobs.iter().map(|(&id, e)| entry_json(id, e, false)))
    }

    /// (queued, running) — the headline gauges of `GET /metrics`.
    pub fn depth_running(&self) -> (usize, usize) {
        let st = self.inner.state.lock().unwrap();
        (st.pending.len(), st.running)
    }

    /// Retained job count per state (the per-state `/metrics` gauges).
    pub fn state_counts(&self) -> Vec<(&'static str, usize)> {
        let st = self.inner.state.lock().unwrap();
        JobState::all()
            .iter()
            .map(|&s| (s.name(), st.jobs.values().filter(|e| e.state == s).count()))
            .collect()
    }

    /// Outstanding admitted cost units (the `/metrics` gauge).
    pub fn outstanding_cost(&self) -> u64 {
        self.inner.state.lock().unwrap().outstanding_cost
    }

    /// Active (queued + running) jobs held by one tenant.
    pub fn tenant_active(&self, tenant: &str) -> usize {
        self.inner
            .state
            .lock()
            .unwrap()
            .active_by_tenant
            .get(tenant)
            .copied()
            .unwrap_or(0)
    }

    pub fn capacity(&self) -> usize {
        self.inner.cfg.capacity
    }

    pub fn workers(&self) -> usize {
        self.inner.cfg.workers
    }

    pub fn admission(&self) -> Admission {
        self.inner.cfg.admission
    }

    /// Block until the job reaches a terminal state (or the deadline).
    /// Returns the last observed state; `None` for unknown ids.
    pub fn wait_terminal(&self, id: JobId, timeout: Duration) -> Option<JobState> {
        let deadline = Instant::now() + timeout;
        let mut st = self.inner.state.lock().unwrap();
        loop {
            let state = st.jobs.get(&id)?.state;
            if state.is_terminal() {
                return Some(state);
            }
            let now = Instant::now();
            if now >= deadline {
                return Some(state);
            }
            let (guard, _) = self.inner.cv.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
    }

    /// Whether the queue has begun draining — surfaced through
    /// `/healthz` so a federated front door stops placing new jobs here
    /// while still proxying reads.
    pub fn is_draining(&self) -> bool {
        self.inner.state.lock().unwrap().draining
    }

    /// Flip the queue into draining (workers stop claiming and exit once
    /// idle) without blocking on them — what `Server`'s `Drop` uses.
    pub fn begin_drain(&self) {
        {
            let mut st = self.inner.state.lock().unwrap();
            st.draining = true;
        }
        self.inner.cv.notify_all();
    }

    /// Graceful shutdown: stop claiming, drain in-flight jobs, join the
    /// workers. Queued jobs stay queued (persisted if a state dir is
    /// configured, for the next daemon to recover).
    pub fn shutdown(&self) {
        self.begin_drain();
        let handles = std::mem::take(&mut *self.workers.lock().unwrap());
        for h in handles {
            h.join().ok();
        }
    }
}

fn entry_json(id: JobId, e: &Entry, with_tail: bool) -> Json {
    let mut fields = vec![
        ("id", Json::num(id as f64)),
        ("name", Json::str(e.spec.name.clone())),
        ("state", Json::str(e.state.name())),
        ("problem", Json::str(e.spec.source.label())),
        ("domain", Json::str(e.spec.domain.name())),
        ("engine", Json::str(e.spec.optimizer.engine.name())),
        ("batch", Json::num(e.spec.batch as f64)),
        ("p", Json::num(e.spec.p as f64)),
        ("n", Json::num(e.spec.n as f64)),
        ("steps", Json::num(e.spec.steps as f64)),
        ("steps_done", Json::num(e.steps_done as f64)),
    ];
    if let Some(err) = &e.error {
        fields.push(("error", Json::str(err.clone())));
    }
    if let Some(r) = &e.result {
        fields.push(("result", r.to_json()));
    }
    if with_tail {
        fields.push((
            "tail",
            Json::arr(e.tail.iter().map(|&(step, wall, loss)| {
                Json::obj(vec![
                    ("step", Json::num(step as f64)),
                    ("wall_s", Json::num(wall)),
                    ("loss", Json::num(loss)),
                ])
            })),
        ));
    }
    Json::obj(fields)
}

/// Name prefix of the iterate parameter inside a series spill. The
/// iterate's shape metadata rides the parameter *name*
/// (`iterate/<domain>/<batch>/<p>/<n>`), so the POGO-CKPT header needs
/// no extension for the spill to be self-describing.
const SPILL_ITERATE_PREFIX: &str = "iterate/";

/// Encode a terminal job's series + iterate as a `ParamStore<f64>` for
/// the POGO-CKPT container: `series` is a 2×k free matrix (row 0 the
/// step numbers — exact in f64 below 2⁵³ — row 1 the losses) and the
/// iterate a 1×W free matrix of its f32 words, widened to f64 (exact,
/// so the round-trip back to f32 is bit-identical).
fn spill_store(series: &[(usize, f64)], iterate: Option<&FinalIterate>) -> ParamStore<f64> {
    let mut store: ParamStore<f64> = ParamStore::new();
    if !series.is_empty() {
        let mut data = Vec::with_capacity(2 * series.len());
        data.extend(series.iter().map(|&(step, _)| step as f64));
        data.extend(series.iter().map(|&(_, loss)| loss));
        store.add_free("series", Mat::from_vec(2, series.len(), data));
    }
    if let Some(it) = iterate {
        let name = format!(
            "{SPILL_ITERATE_PREFIX}{}/{}/{}/{}",
            it.domain.name(),
            it.batch,
            it.p,
            it.n
        );
        let wide: Vec<f64> = it.data.iter().map(|&w| w as f64).collect();
        store.add_free(name, Mat::from_vec(1, wide.len(), wide));
    }
    store
}

/// Decode a series spill written by [`spill_store`].
fn read_spill(path: &Path) -> Result<(Vec<(usize, f64)>, Option<FinalIterate>)> {
    let (store, _step) = checkpoint::load_t::<f64>(path)?;
    let mut series = Vec::new();
    let mut iterate = None;
    for prm in store.params() {
        if prm.name == "series" {
            let k = prm.mat.cols();
            let d = prm.mat.as_slice();
            series = (0..k).map(|i| (d[i] as usize, d[k + i])).collect();
        } else if let Some(meta) = prm.name.strip_prefix(SPILL_ITERATE_PREFIX) {
            let parts: Vec<&str> = meta.split('/').collect();
            if parts.len() != 4 {
                return Err(anyhow!("bad iterate metadata '{meta}' in {}", path.display()));
            }
            let domain = JobDomain::parse(parts[0])
                .ok_or_else(|| anyhow!("bad iterate domain '{}'", parts[0]))?;
            let batch: usize = parts[1].parse()?;
            let p: usize = parts[2].parse()?;
            let n: usize = parts[3].parse()?;
            let data: Vec<f32> = prm.mat.as_slice().iter().map(|&v| v as f32).collect();
            iterate = Some(FinalIterate { domain, batch, p, n, data });
        }
    }
    Ok((series, iterate))
}

impl Inner {
    /// Bound the in-memory terminal-job history (after persisting, so
    /// an evicted job's state file is already final on disk).
    fn prune(&self) {
        self.state.lock().unwrap().prune_terminal();
    }

    /// Per-step progress from a worker: bump the counters, the v1 tail,
    /// the v2 series, and broadcast on the job's bus.
    fn progress(&self, id: JobId, p: &StepProgress) {
        self.metrics.steps.fetch_add(1, Ordering::Relaxed);
        let bus = {
            let mut st = self.state.lock().unwrap();
            let Some(e) = st.jobs.get_mut(&id) else { return };
            e.steps_done = p.step;
            if e.tail.len() == TAIL_LEN {
                e.tail.pop_front();
            }
            e.tail.push_back((p.step, p.wall_s, p.loss));
            if e.series.len() == SERIES_CAP {
                e.series.pop_front();
            }
            e.series.push_back((p.step, p.loss));
            e.bus.clone()
        };
        bus.publish(ProgressEvent::Step(*p));
    }

    /// Re-attach an artifact job's payload from the store at claim time.
    /// The resolved payload never rides the state file, so a job
    /// recovered from a previous daemon reaches its worker unresolved —
    /// this is the second (and last) place resolution can happen. A
    /// no-op for other sources and for already-resolved refs.
    fn resolve_artifact(&self, spec: &mut JobSpec) -> Result<()> {
        let ProblemSource::Artifact(r) = &mut spec.source else { return Ok(()) };
        if r.resolved().is_some() {
            return Ok(());
        }
        let store = self.cfg.artifacts.as_deref().ok_or_else(|| {
            anyhow!("artifact job recovered on a daemon without --artifact-dir")
        })?;
        let art = store
            .get(&r.hash)?
            .ok_or_else(|| anyhow!("artifact {} is no longer in the store", r.hash))?;
        self.metrics.artifact_hits.fetch_add(1, Ordering::Relaxed);
        r.resolve(art.to_problem()?);
        Ok(())
    }

    /// Checkpoint path for a job, when persistence applies to it (both
    /// domains — the checkpoint format is dtype-tagged).
    fn checkpoint_path(&self, id: JobId, spec: &JobSpec) -> Option<PathBuf> {
        if spec.checkpoint_every == 0 {
            return None;
        }
        self.cfg.state_dir.as_ref().map(|d| d.join(format!("job-{id}.ckpt")))
    }

    /// Sidecar path for a terminal job's spilled series + iterate.
    fn spill_path(&self, id: JobId) -> Option<PathBuf> {
        self.cfg.state_dir.as_ref().map(|d| d.join(format!("job-{id}.series.ckpt")))
    }

    /// Spill a terminal job's frozen series + final iterate to
    /// `job-<id>.series.ckpt` in the POGO-CKPT dtype-tagged framing, so
    /// the v2 result surface survives a restart (and the federated front
    /// door can re-read results after a backend comes back). Best effort,
    /// like [`persist`](Self::persist): a full disk degrades durability,
    /// never the daemon.
    fn spill(&self, id: JobId) {
        let Some(path) = self.spill_path(id) else { return };
        let (series, iterate, steps_done) = {
            let st = self.state.lock().unwrap();
            let Some(e) = st.jobs.get(&id) else { return };
            if !e.state.is_terminal() {
                return;
            }
            (e.series_final.clone(), e.iterate.clone(), e.steps_done)
        };
        let series = series.unwrap_or_default();
        if series.is_empty() && iterate.is_none() {
            return; // nothing beyond the state file to keep
        }
        let store = spill_store(&series, iterate.as_deref());
        if let Err(e) = checkpoint::save_t::<f64>(&store, steps_done, &path) {
            log::warn!("failed to spill job {id} series to {}: {e:#}", path.display());
        }
    }

    /// Reload a terminal job's spilled series + iterate on recovery.
    /// Missing or unreadable spills degrade to the pre-durability
    /// behaviour (scalars only), never fail recovery.
    fn load_spill(&self, id: JobId) -> (Option<Arc<Vec<(usize, f64)>>>, Option<Arc<FinalIterate>>) {
        let Some(path) = self.spill_path(id) else { return (None, None) };
        if !path.exists() {
            return (None, None);
        }
        match read_spill(&path) {
            Ok((series, iterate)) => (Some(Arc::new(series)), iterate.map(Arc::new)),
            Err(e) => {
                log::warn!("ignoring unreadable series spill {}: {e:#}", path.display());
                (None, None)
            }
        }
    }

    /// Persist one job's spec + state to the state dir (best effort: a
    /// full disk must not take the daemon down).
    fn persist(&self, id: JobId) {
        let Some(dir) = &self.cfg.state_dir else { return };
        let json = {
            let st = self.state.lock().unwrap();
            let Some(e) = st.jobs.get(&id) else { return };
            let mut fields = vec![
                ("id", Json::num(id as f64)),
                ("state", Json::str(e.state.name())),
                ("tenant", Json::str(e.tenant.clone())),
                ("spec", e.spec.to_json()),
            ];
            if e.cancel.load(Ordering::Relaxed) {
                fields.push(("cancel_requested", Json::Bool(true)));
            }
            if let Some(err) = &e.error {
                fields.push(("error", Json::str(err.clone())));
            }
            if let Some(r) = &e.result {
                fields.push(("result", r.to_json()));
            }
            Json::obj(fields)
        };
        // Write-then-rename with a per-call unique temp name: a crash
        // mid-write never tears the state file, and two racing persists
        // (cancel ack vs worker finish) each land a complete document —
        // whichever rename lands last wins, and recover() maps either to
        // the same terminal outcome.
        static PERSIST_SEQ: std::sync::atomic::AtomicU64 =
            std::sync::atomic::AtomicU64::new(0);
        let seq = PERSIST_SEQ.fetch_add(1, Ordering::Relaxed);
        let path = dir.join(format!("job-{id}.json"));
        let tmp = dir.join(format!("job-{id}.json.{seq}.tmp"));
        let write = std::fs::write(&tmp, json.to_string_pretty() + "\n")
            .and_then(|()| std::fs::rename(&tmp, &path));
        if let Err(e) = write {
            std::fs::remove_file(&tmp).ok();
            log::warn!("failed to persist job {id} to {}: {e}", path.display());
        }
    }

    /// Re-list persisted jobs on startup. Unfinished jobs (queued or
    /// running at the previous daemon's death) are re-queued — their
    /// checkpoints, if any, make the re-run resume instead of restart —
    /// and re-held against their tenant's quota and the cost budget.
    /// Terminal jobs stay queryable, with their full series + final
    /// iterate reloaded from the `job-<id>.series.ckpt` spill when one
    /// exists. Malformed files are skipped with a warning, never fatal.
    fn recover(&self) {
        let Some(dir) = &self.cfg.state_dir else { return };
        let Ok(entries) = std::fs::read_dir(dir) else { return };
        let mut found: Vec<(JobId, Json)> = Vec::new();
        for entry in entries.flatten() {
            let path = entry.path();
            let name = path.file_name().and_then(|s| s.to_str()).unwrap_or("");
            let Some(id) = name
                .strip_prefix("job-")
                .and_then(|s| s.strip_suffix(".json"))
                .and_then(|s| s.parse::<JobId>().ok())
            else {
                continue;
            };
            match Json::parse_file(&path) {
                Ok(j) => found.push((id, j)),
                Err(e) => log::warn!("skipping unreadable state file {}: {e:#}", path.display()),
            }
        }
        found.sort_by_key(|&(id, _)| id);
        let mut st = self.state.lock().unwrap();
        for (id, j) in found {
            let spec = match JobSpec::from_json(j.get("spec")) {
                Ok(s) => s,
                Err(e) => {
                    log::warn!("skipping persisted job {id} with bad spec: {e:#}");
                    continue;
                }
            };
            let state = j
                .get("state")
                .as_str()
                .and_then(JobState::parse)
                .unwrap_or(JobState::Queued);
            // An acknowledged-but-unfinished cancellation lands as
            // cancelled, never re-queued.
            let state = if !state.is_terminal()
                && j.get("cancel_requested").as_bool().unwrap_or(false)
            {
                JobState::Cancelled
            } else {
                state
            };
            let tenant =
                j.get("tenant").as_str().unwrap_or("anonymous").to_string();
            let result = JobResult::from_json(j.get("result")).ok();
            let error = j.get("error").as_str().map(str::to_string);
            let requeue = !state.is_terminal();
            let steps_done =
                if requeue { 0 } else { result.as_ref().map(|r| r.steps_done).unwrap_or(0) };
            let cost = spec.cost();
            if requeue {
                st.admit_accounting(&tenant, cost);
            }
            let (series_final, iterate) =
                if requeue { (None, None) } else { self.load_spill(id) };
            st.jobs.insert(
                id,
                Entry {
                    spec,
                    tenant,
                    cost,
                    state: if requeue { JobState::Queued } else { state },
                    error,
                    result,
                    steps_done,
                    tail: VecDeque::new(),
                    series: VecDeque::new(),
                    series_final,
                    iterate,
                    bus: if requeue {
                        ProgressBus::new()
                    } else {
                        ProgressBus::closed_with(state)
                    },
                    cancel: Arc::new(AtomicBool::new(false)),
                    // Spans don't survive a restart: a re-queued job gets
                    // a fresh recorder (its re-run is traced normally), a
                    // terminal one an empty trace.
                    trace: Arc::new(crate::obs::JobTrace::new()),
                    queued_from_us: 0,
                },
            );
            if requeue {
                st.pending.push_back(id);
                log::info!("recovered unfinished job {id}; re-queued");
            }
            st.next_id = st.next_id.max(id + 1);
        }
        st.prune_terminal();
    }
}

fn worker_loop(inner: Arc<Inner>) {
    loop {
        // Claim one job (or exit once draining).
        let claimed = {
            let mut st = inner.state.lock().unwrap();
            loop {
                if st.draining {
                    break None;
                }
                if let Some(id) = st.pending.pop_front() {
                    let claim = match st.jobs.get_mut(&id) {
                        Some(e) => {
                            e.state = JobState::Running;
                            (id, e.spec.clone(), e.cancel.clone(), e.trace.clone(), e.queued_from_us)
                        }
                        None => continue, // stale id; keep looking
                    };
                    st.running += 1;
                    break Some(claim);
                }
                st = inner.cv.wait(st).unwrap();
            }
        };
        let Some((id, mut spec, cancel, trace, queued_from_us)) = claimed else { return };
        inner.persist(id);

        // Close the `queued` span (admission end → claim) and open `run`.
        let run_from_us = crate::obs::enabled().then(|| {
            let t = trace.now_us();
            let waited = t.saturating_sub(queued_from_us);
            trace.record_span("queued", queued_from_us, waited, 1);
            crate::obs::hist::JOB_QUEUE_WAIT_SECONDS.hist0().record_us(waited);
            t
        });

        // Run the job. The observer records the loss series and feeds the
        // job's progress bus — the SSE stream — on every applied step.
        // Recovered artifact jobs re-resolve their payload here first; a
        // store that no longer holds the hash fails the job cleanly.
        let inner_cb = inner.clone();
        let observer = |p: &StepProgress| inner_cb.progress(id, p);
        let ctl = RunCtl {
            cancel: Some(&cancel),
            on_step: None,
            checkpoint_path: inner.checkpoint_path(id, &spec),
            trace: Some(&trace),
        };
        let outcome = match inner.resolve_artifact(&mut spec) {
            Ok(()) => std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                job::run_job_with(&spec, &ctl, Some(&observer))
            })),
            Err(e) => Ok(Err(e)),
        };

        // Close `run` and the root `job` span; the trace is complete from
        // here on (the tree under `run` came from run_job_with).
        if let Some(t_run) = run_from_us {
            let now = trace.now_us();
            trace.record_span("run", t_run, now.saturating_sub(t_run), 1);
            trace.record_span("job", 0, now, 0);
            crate::obs::hist::JOB_RUN_SECONDS.hist0().record_us(now.saturating_sub(t_run));
        }

        let bus = {
            let mut st = inner.state.lock().unwrap();
            st.running -= 1;
            let mut closed: Option<(Arc<ProgressBus>, JobState)> = None;
            if let Some(e) = st.jobs.get_mut(&id) {
                match outcome {
                    Ok(Ok((JobOutcome::Done(r), iterate))) => {
                        e.state = JobState::Done;
                        e.steps_done = r.steps_done;
                        e.result = Some(r);
                        e.iterate = Some(Arc::new(iterate));
                        inner.metrics.completed.fetch_add(1, Ordering::Relaxed);
                    }
                    Ok(Ok((JobOutcome::Cancelled(r), iterate))) => {
                        e.state = JobState::Cancelled;
                        e.steps_done = r.steps_done;
                        e.result = Some(r);
                        e.iterate = Some(Arc::new(iterate));
                        inner.metrics.cancelled.fetch_add(1, Ordering::Relaxed);
                    }
                    Ok(Err(err)) => {
                        e.state = JobState::Failed;
                        e.error = Some(format!("{err:#}"));
                        inner.metrics.failed.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(panic) => {
                        let msg = panic
                            .downcast_ref::<String>()
                            .map(String::as_str)
                            .or_else(|| panic.downcast_ref::<&str>().copied())
                            .unwrap_or("worker panicked");
                        log::error!("job {id} failed: worker panicked: {msg}");
                        e.state = JobState::Failed;
                        e.error = Some(format!("panic: {msg}"));
                        inner.metrics.failed.fetch_add(1, Ordering::Relaxed);
                        inner.metrics.worker_panics.fetch_add(1, Ordering::Relaxed);
                    }
                }
                // Freeze the series so result reads never copy it under
                // this lock again (terminal entries are immutable).
                e.series_final = Some(Arc::new(e.series.drain(..).collect()));
                closed = Some((e.bus.clone(), e.state));
                let (tenant, cost) = (e.tenant.clone(), e.cost);
                st.release_accounting(&tenant, cost);
            }
            closed
        };
        if let Some((bus, state)) = bus {
            bus.close(state);
        }
        inner.persist(id);
        inner.spill(id);
        inner.prune();
        inner.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::OptimizerSpec;
    use crate::optim::{Engine, Method};
    use crate::serve::job::ProblemKind;

    fn quick_spec(steps: usize) -> JobSpec {
        let mut s = JobSpec::new(ProblemKind::Quartic, 2, 2, 4);
        s.steps = steps;
        s.seed = 3;
        s.optimizer = OptimizerSpec::new(Method::Pogo, 0.05);
        s
    }

    fn start(workers: usize, capacity: usize) -> Arc<JobQueue> {
        JobQueue::start(
            QueueConfig { workers, capacity, ..QueueConfig::default() },
            Arc::new(ServeMetrics::new()),
        )
        .unwrap()
    }

    #[test]
    fn runs_jobs_to_done() {
        let q = start(2, 16);
        let a = q.submit(quick_spec(20)).unwrap();
        let b = q.submit(quick_spec(20)).unwrap();
        assert_ne!(a, b);
        assert_eq!(q.wait_terminal(a, Duration::from_secs(30)), Some(JobState::Done));
        assert_eq!(q.wait_terminal(b, Duration::from_secs(30)), Some(JobState::Done));
        let (state, result, error) = q.snapshot(a).unwrap();
        assert_eq!(state, JobState::Done);
        assert!(error.is_none());
        let r = result.unwrap();
        assert_eq!(r.steps_done, 20);
        assert!(r.ortho_error <= 1e-3);
        // The metrics tail survives in the status JSON.
        let j = q.status_json(a).unwrap();
        assert_eq!(j.get("state").as_str(), Some("done"));
        assert!(!j.get("tail").as_arr().unwrap().is_empty());
        // The v2 surfaces: full series, final iterate, tenant.
        let view = q.result_view(a).unwrap();
        assert_eq!(view.series.len(), 20, "untruncated series");
        assert!(view.series.windows(2).all(|w| w[0].0 < w[1].0));
        let iterate = view.iterate.expect("final iterate retained");
        assert_eq!(iterate.data.len(), 2 * 2 * 4);
        assert_eq!(view.tenant, "anonymous");
        let v2 = q.status_v2_json(a).unwrap();
        assert_eq!(v2.get("series_len").as_usize(), Some(20));
        assert_eq!(v2.get("tenant").as_str(), Some("anonymous"));
        q.shutdown();
    }

    #[test]
    fn terminal_job_answers_a_trace() {
        let _g = crate::obs::TEST_OVERRIDE_LOCK.lock().unwrap();
        crate::obs::set_enabled(Some(true));
        let q = start(1, 4);
        let id = q.submit(quick_spec(40)).unwrap();
        assert_eq!(q.wait_terminal(id, Duration::from_secs(30)), Some(JobState::Done));
        crate::obs::set_enabled(None);
        let t = q.trace_json(id).unwrap();
        assert_eq!(t.get("id").as_usize(), Some(id as usize));
        assert_eq!(t.get("state").as_str(), Some("done"));
        // One root — the depth-0 `job` span — with the lifecycle under it.
        let spans = t.get("spans").as_arr().unwrap();
        assert_eq!(spans.len(), 1, "{}", t.to_string());
        let job = &spans[0];
        assert_eq!(job.get("name").as_str(), Some("job"));
        let kids = job.get("children").as_arr().unwrap();
        let names: Vec<&str> = kids.iter().filter_map(|c| c.get("name").as_str()).collect();
        assert_eq!(names, ["admit", "queued", "run"]);
        // The run span carries the in-job tree from run_job_with.
        let run_kids: Vec<&str> = kids[2]
            .get("children")
            .as_arr()
            .unwrap()
            .iter()
            .filter_map(|c| c.get("name").as_str())
            .collect();
        assert!(run_kids.contains(&"steps"), "{run_kids:?}");
        assert!(q.trace_json(9999).is_none(), "unknown ids answer None");
        q.shutdown();
    }

    #[test]
    fn progress_bus_replays_to_late_subscribers() {
        let q = start(1, 4);
        let id = q.submit(quick_spec(15)).unwrap();
        assert_eq!(q.wait_terminal(id, Duration::from_secs(30)), Some(JobState::Done));
        // Subscribe AFTER the job finished: the bounded bus replays its
        // buffered steps, then the terminal event, then closes.
        let bus = q.subscribe(id).unwrap();
        let mut cursor = 0u64;
        let mut steps = Vec::new();
        let mut terminal = None;
        loop {
            match bus.next_event(cursor, Duration::from_secs(5)) {
                BusPoll::Event(next, ProgressEvent::Step(p)) => {
                    steps.push(p.step);
                    cursor = next;
                }
                BusPoll::Event(next, ProgressEvent::Terminal(s)) => {
                    terminal = Some(s);
                    cursor = next;
                }
                BusPoll::Closed => break,
                BusPoll::Pending => panic!("closed bus must not leave a subscriber pending"),
            }
        }
        assert_eq!(steps, (1..=15).collect::<Vec<_>>(), "monotone, gap-free replay");
        assert_eq!(terminal, Some(JobState::Done));
        q.shutdown();
    }

    #[test]
    fn tenant_quota_and_cost_cap_reject_ahead_of_fifo() {
        // Zero workers: admitted jobs stay active, so admission state is
        // deterministic.
        let metrics = Arc::new(ServeMetrics::new());
        let q = JobQueue::start(
            QueueConfig {
                workers: 0,
                capacity: 16,
                state_dir: None,
                admission: Admission {
                    tenant_quota: 2,
                    cost_cap: 10 * quick_spec(10).cost(),
                    max_inline_bytes: 64,
                },
                artifacts: None,
            },
            metrics.clone(),
        )
        .unwrap();

        // Tenant quota: third active job for 'alice' is refused, bob and
        // anonymous are unaffected.
        q.submit_as(quick_spec(10), "alice").unwrap();
        q.submit_as(quick_spec(10), "alice").unwrap();
        match q.submit_as(quick_spec(10), "alice") {
            Err(SubmitError::Quota { active: 2, quota: 2, retry_after_s, .. }) => {
                assert!(retry_after_s >= 1);
            }
            other => panic!("expected Quota, got {other:?}"),
        }
        q.submit_as(quick_spec(10), "bob").unwrap();
        q.submit(quick_spec(10)).unwrap();

        // Cost cap: a job pushing the outstanding budget past the cap is
        // refused; a small one still fits.
        match q.submit_as(quick_spec(10 * 10), "bob") {
            Err(SubmitError::Cost { cap, outstanding, .. }) => {
                assert!(outstanding > 0 && cap > 0);
            }
            other => panic!("expected Cost, got {other:?}"),
        }
        q.submit_as(quick_spec(10), "bob").unwrap();

        // Inline byte cap (64 bytes here; the payload is 2 matrices of
        // 4×4 f32 = 128 bytes).
        let mut inline = quick_spec(10);
        let mut rng = crate::rng::Rng::seed_from_u64(1);
        let c = (0..2)
            .map(|_| {
                super::super::problem::InlineMat::from_mat(
                    &crate::linalg::Mat::<f32>::randn(4, 4, &mut rng),
                )
            })
            .collect();
        inline.source = super::super::problem::ProblemSource::Inline(
            super::super::problem::InlineProblem::Pca { c },
        );
        match q.submit(inline) {
            Err(SubmitError::InlineTooLarge { bytes, cap: 64 }) => assert!(bytes > 64),
            other => panic!("expected InlineTooLarge, got {other:?}"),
        }

        // Rejections were counted by cause.
        assert_eq!(metrics.rejected_quota.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.rejected_cost.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.rejected_inline.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.rejected.load(Ordering::Relaxed), 3);

        // Cancelling releases the quota hold: alice can submit again.
        let ids: Vec<JobId> = {
            let st = q.inner.state.lock().unwrap();
            st.jobs
                .iter()
                .filter(|(_, e)| e.tenant == "alice")
                .map(|(&id, _)| id)
                .collect()
        };
        q.cancel(ids[0]).unwrap();
        q.submit_as(quick_spec(10), "alice").unwrap();
        q.shutdown();
    }

    #[test]
    fn artifact_store_resolves_and_dedupes() {
        use super::super::problem::{ArtifactRef, InlineMat, InlineProblem};
        let dir = std::env::temp_dir()
            .join(format!("pogo_serve_queue_artifacts_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let store = Arc::new(ArtifactStore::open(&dir, 1 << 20).unwrap());
        let metrics = Arc::new(ServeMetrics::new());
        let q = JobQueue::start(
            QueueConfig {
                workers: 1,
                capacity: 8,
                artifacts: Some(store.clone()),
                ..QueueConfig::default()
            },
            metrics.clone(),
        )
        .unwrap();
        let inline_pca = |seed: u64| {
            let mut s = quick_spec(10);
            let mut rng = crate::rng::Rng::seed_from_u64(seed);
            let c = (0..2)
                .map(|_| InlineMat::from_mat(&crate::linalg::Mat::<f32>::randn(4, 4, &mut rng)))
                .collect();
            s.source = super::super::problem::ProblemSource::Inline(InlineProblem::Pca { c });
            s
        };

        // First inline submission: a miss that seals + stores the payload.
        let a = q.submit(inline_pca(9)).unwrap();
        assert_eq!(q.wait_terminal(a, Duration::from_secs(30)), Some(JobState::Done));
        assert_eq!(metrics.artifact_misses.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.artifact_hits.load(Ordering::Relaxed), 0);
        let summary = store.summary();
        assert_eq!(summary.count, 1, "inline payload was cached");
        let hash = summary.entries[0].0.clone();

        // Identical resubmission: served from cache (hit, no new entry).
        let b = q.submit(inline_pca(9)).unwrap();
        assert_eq!(q.wait_terminal(b, Duration::from_secs(30)), Some(JobState::Done));
        assert_eq!(metrics.artifact_hits.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.artifact_misses.load(Ordering::Relaxed), 1);
        assert_eq!(store.summary().count, 1);

        // An artifact-sourced job by that hash resolves and runs to done,
        // with the same result as the inline run (bit-identity is pinned
        // end-to-end in job.rs and serve_e2e.rs).
        let mut by_hash = quick_spec(10);
        by_hash.source =
            super::super::problem::ProblemSource::Artifact(ArtifactRef::new(&hash).unwrap());
        let c = q.submit(by_hash).unwrap();
        assert_eq!(q.wait_terminal(c, Duration::from_secs(30)), Some(JobState::Done));
        assert_eq!(metrics.artifact_hits.load(Ordering::Relaxed), 2);
        let ra = q.snapshot(a).unwrap().1.unwrap();
        let rc = q.snapshot(c).unwrap().1.unwrap();
        assert_eq!(ra.final_loss.to_bits(), rc.final_loss.to_bits());

        // Unknown hash: refused ahead of the FIFO, counted as a miss.
        let mut missing = quick_spec(10);
        missing.source = super::super::problem::ProblemSource::Artifact(
            ArtifactRef::new(&crate::util::sha256::hex(b"never uploaded")).unwrap(),
        );
        match q.submit(missing) {
            Err(SubmitError::ArtifactMissing { hash: h }) => {
                assert_eq!(h, crate::util::sha256::hex(b"never uploaded"));
            }
            other => panic!("expected ArtifactMissing, got {other:?}"),
        }
        assert_eq!(metrics.artifact_misses.load(Ordering::Relaxed), 2);
        assert_eq!(metrics.rejected_artifact.load(Ordering::Relaxed), 1);
        q.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn artifact_job_without_a_store_is_invalid() {
        use super::super::problem::ArtifactRef;
        let q = start(0, 4);
        let mut spec = quick_spec(10);
        spec.source = super::super::problem::ProblemSource::Artifact(
            ArtifactRef::new(&crate::util::sha256::hex(b"x")).unwrap(),
        );
        match q.submit(spec) {
            Err(SubmitError::Invalid(e)) => {
                assert!(format!("{e:#}").contains("--artifact-dir"), "{e:#}");
            }
            other => panic!("expected Invalid, got {other:?}"),
        }
        q.shutdown();
    }

    #[test]
    fn bad_spec_fails_cleanly() {
        let q = start(1, 4);
        let mut spec = quick_spec(5);
        spec.optimizer = spec.optimizer.with_engine(Engine::Xla); // no registry in serve
        let id = q.submit(spec).unwrap();
        assert_eq!(q.wait_terminal(id, Duration::from_secs(30)), Some(JobState::Failed));
        let (_, _, error) = q.snapshot(id).unwrap();
        assert!(error.unwrap().contains("registry"), "error should name the cause");
        // A failed job closes its bus with the failed terminal event.
        let bus = q.subscribe(id).unwrap();
        match bus.next_event(0, Duration::from_secs(5)) {
            BusPoll::Event(_, ProgressEvent::Terminal(JobState::Failed)) => {}
            other => panic!("expected Terminal(Failed), got {other:?}"),
        }
        // The queue is still alive after the failure.
        let ok = q.submit(quick_spec(5)).unwrap();
        assert_eq!(q.wait_terminal(ok, Duration::from_secs(30)), Some(JobState::Done));
        q.shutdown();
    }

    #[test]
    fn cancel_queued_job_and_capacity_limit() {
        // One worker, one long job occupying it; the backlog then fills.
        let q = start(1, 1);
        let long = q.submit(quick_spec(200_000)).unwrap();
        // Wait until the long job is claimed so the backlog is empty.
        let t0 = Instant::now();
        while q.depth_running() != (0, 1) {
            assert!(t0.elapsed() < Duration::from_secs(10), "worker never claimed the job");
            std::thread::sleep(Duration::from_millis(5));
        }
        let queued = q.submit(quick_spec(5)).unwrap();
        match q.submit(quick_spec(5)) {
            Err(SubmitError::Full(1)) => {}
            other => panic!("expected Full, got {other:?}"),
        }
        // Cancel the queued job: immediate, no worker involved.
        assert_eq!(q.cancel(queued), Some(JobState::Cancelled));
        // Cancel the running job: flag flips, worker drains at a step edge.
        q.cancel(long);
        assert_eq!(q.wait_terminal(long, Duration::from_secs(30)), Some(JobState::Cancelled));
        assert!(q.cancel(9999).is_none());
        q.shutdown();
    }

    #[test]
    fn shutdown_drains_in_flight_and_refuses_new() {
        let q = start(1, 8);
        let id = q.submit(quick_spec(50)).unwrap();
        q.shutdown();
        // The in-flight (or queued-then-drained-by-timing) job is not
        // left running; after shutdown new submissions are refused.
        match q.submit(quick_spec(5)) {
            Err(SubmitError::Draining) => {}
            other => panic!("expected Draining, got {other:?}"),
        }
        let (state, _, _) = q.snapshot(id).unwrap();
        assert!(
            state == JobState::Done || state == JobState::Queued,
            "drained job ended as {state:?}"
        );
    }

    #[test]
    fn acknowledged_cancellation_survives_a_crash() {
        // A state file left by a daemon that died after acknowledging a
        // DELETE of a running job: recovered as cancelled, never re-run.
        let dir = std::env::temp_dir()
            .join(format!("pogo_serve_queue_cancelreq_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let state = Json::obj(vec![
            ("id", Json::num(5.0)),
            ("state", Json::str("running")),
            ("cancel_requested", Json::Bool(true)),
            ("spec", quick_spec(10).to_json()),
        ]);
        std::fs::write(dir.join("job-5.json"), state.to_string_pretty()).unwrap();
        let q = JobQueue::start(
            QueueConfig {
                workers: 1,
                capacity: 4,
                state_dir: Some(dir.clone()),
                ..QueueConfig::default()
            },
            Arc::new(ServeMetrics::new()),
        )
        .unwrap();
        let (state, _, _) = q.snapshot(5).unwrap();
        assert_eq!(state, JobState::Cancelled);
        // A recovered terminal job's bus is already closed with its state.
        let bus = q.subscribe(5).unwrap();
        match bus.next_event(0, Duration::from_secs(5)) {
            BusPoll::Event(_, ProgressEvent::Terminal(JobState::Cancelled)) => {}
            other => panic!("expected Terminal(Cancelled), got {other:?}"),
        }
        q.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn persists_and_recovers_unfinished_jobs() {
        let dir = std::env::temp_dir()
            .join(format!("pogo_serve_queue_recover_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();

        // First daemon: enqueue two jobs into a zero-worker queue (they
        // stay queued), then shut down. The tenant rides the state file.
        let q = JobQueue::start(
            QueueConfig {
                workers: 0,
                capacity: 8,
                state_dir: Some(dir.clone()),
                ..QueueConfig::default()
            },
            Arc::new(ServeMetrics::new()),
        )
        .unwrap();
        let a = q.submit_as(quick_spec(10), "carol").unwrap();
        let b = q.submit(quick_spec(10)).unwrap();
        q.shutdown();
        drop(q);

        // Second daemon recovers both (re-holding carol's quota), runs
        // them to done, and keeps ids.
        let q2 = JobQueue::start(
            QueueConfig {
                workers: 2,
                capacity: 8,
                state_dir: Some(dir.clone()),
                admission: Admission { tenant_quota: 1, ..Admission::default() },
            },
            Arc::new(ServeMetrics::new()),
        )
        .unwrap();
        assert_eq!(q2.result_view(a).unwrap().tenant, "carol");
        assert_eq!(q2.wait_terminal(a, Duration::from_secs(30)), Some(JobState::Done));
        assert_eq!(q2.wait_terminal(b, Duration::from_secs(30)), Some(JobState::Done));
        // Fresh ids don't collide with recovered ones.
        let c = q2.submit(quick_spec(5)).unwrap();
        assert!(c > b);
        let before = q2.result_view(a).unwrap();
        assert_eq!(before.series.len(), 10);
        let before_iter = before.iterate.clone().expect("iterate present before restart");
        // Terminal states were persisted for the third daemon.
        q2.shutdown();
        let q3 = JobQueue::start(
            QueueConfig {
                workers: 0,
                capacity: 8,
                state_dir: Some(dir.clone()),
                ..QueueConfig::default()
            },
            Arc::new(ServeMetrics::new()),
        )
        .unwrap();
        let (state, result, _) = q3.snapshot(a).unwrap();
        assert_eq!(state, JobState::Done);
        assert!(result.unwrap().ortho_error <= 1e-3);
        // The v2 surfaces were spilled at the terminal transition in
        // POGO-CKPT framing: the full series and the final iterate
        // survive the restart bit-for-bit alongside the result scalars.
        let view = q3.result_view(a).unwrap();
        assert_eq!(view.series.len(), before.series.len());
        for (x, y) in before.series.iter().zip(view.series.iter()) {
            assert_eq!(x.0, y.0);
            assert_eq!(x.1.to_bits(), y.1.to_bits());
        }
        let it = view.iterate.expect("iterate recovered from spill");
        assert_eq!(it.domain, before_iter.domain);
        assert_eq!((it.batch, it.p, it.n), (before_iter.batch, before_iter.p, before_iter.n));
        assert_eq!(
            it.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            before_iter.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        q3.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn retry_after_scales_with_observed_load() {
        // Cold start (no observations): the old backlog-scaled constant.
        let wait = Hist::new();
        let run = Hist::new();
        assert_eq!(retry_after_from(0, 2, &wait, &run), 1);
        assert_eq!(retry_after_from(10, 2, &wait, &run), 11);
        assert_eq!(retry_after_from(1000, 2, &wait, &run), 60);
        // Observed short waits/runs on an idle queue: a small hint.
        for _ in 0..10 {
            wait.record_us(2_000);
            run.record_us(50_000);
        }
        let idle = retry_after_from(0, 2, &wait, &run);
        // The same latency profile with a deep backlog: a larger hint.
        let loaded = retry_after_from(64, 2, &wait, &run);
        assert!(loaded > idle, "loaded {loaded} vs idle {idle}");
        // Slower jobs push it up further; the hint is capped at 600 s.
        let slow_wait = Hist::new();
        let slow_run = Hist::new();
        for _ in 0..10 {
            slow_wait.record_us(5_000_000);
            slow_run.record_us(10_000_000);
        }
        assert!(retry_after_from(64, 2, &slow_wait, &slow_run) > loaded);
        assert_eq!(retry_after_from(1_000_000, 1, &slow_wait, &slow_run), 600);
    }

    #[test]
    fn requested_ids_are_honored_and_collisions_refused() {
        let q = start(0, 8);
        assert_eq!(q.submit_with_id(quick_spec(10), "front", Some(7)).unwrap(), 7);
        match q.submit_with_id(quick_spec(10), "front", Some(7)) {
            Err(SubmitError::IdTaken(7)) => {}
            other => panic!("expected IdTaken, got {other:?}"),
        }
        // The id counter advanced past the requested id.
        assert_eq!(q.submit(quick_spec(10)).unwrap(), 8);
        q.shutdown();
    }
}
