//! The job queue: a bounded FIFO with a per-job state machine
//! (queued → running → done/failed/cancelled) executed by a fixed worker
//! set.
//!
//! Each worker claims one job at a time and drives it through
//! [`run_job`](super::job::run_job) (which owns the job's
//! `OptimSession`), recording the loss series in a
//! [`MetricLog`](crate::coordinator::MetricLog) whose tail feeds
//! `GET /v1/jobs/:id`. Worker panics are caught and surface as `failed`
//! jobs — the daemon never dies on a bad spec.
//!
//! Shutdown is graceful: workers stop claiming new jobs and drain the
//! ones they are running; still-queued jobs stay queued (and, with a
//! state dir, persisted for the next daemon). With a `state_dir`, every
//! job's spec + state lands in `job-<id>.json` and real-domain jobs with
//! `checkpoint_every > 0` checkpoint to `job-<id>.ckpt`; a restarted
//! queue re-lists unfinished jobs and resumes them from their
//! checkpoints.

use super::job::{self, JobOutcome, JobResult, JobSpec, JobState, RunCtl};
use super::metrics::ServeMetrics;
use crate::coordinator::MetricLog;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::{BTreeMap, VecDeque};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

pub type JobId = u64;

/// Kept loss-tail length per job (the "metrics tail" of the status API).
const TAIL_LEN: usize = 8;

/// Terminal jobs retained in memory for status queries. Older terminal
/// entries are evicted (oldest id first) so a resident daemon's job map
/// and `GET /v1/jobs` stay bounded; with a state dir the evicted jobs'
/// files remain on disk for offline inspection.
const MAX_TERMINAL_RETAINED: usize = 1024;

/// Queue sizing and persistence.
#[derive(Clone, Debug)]
pub struct QueueConfig {
    /// Fixed worker thread count.
    pub workers: usize,
    /// Max queued (not yet running) jobs; submissions beyond it are
    /// refused with [`SubmitError::Full`].
    pub capacity: usize,
    /// Persist job state (+ checkpoints) here; `None` = in-memory only.
    pub state_dir: Option<PathBuf>,
}

impl Default for QueueConfig {
    fn default() -> Self {
        QueueConfig {
            workers: crate::util::pool::num_threads().min(4).max(1),
            capacity: 256,
            state_dir: None,
        }
    }
}

/// Why a submission was refused.
#[derive(Debug)]
pub enum SubmitError {
    /// Backlog at capacity; retry later.
    Full(usize),
    /// The queue is shutting down.
    Draining,
    /// The spec failed admission validation.
    Invalid(anyhow::Error),
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Full(cap) => write!(f, "queue full (capacity {cap})"),
            SubmitError::Draining => write!(f, "queue is draining (shutdown in progress)"),
            SubmitError::Invalid(e) => write!(f, "invalid job: {e:#}"),
        }
    }
}

/// One tracked job.
struct Entry {
    spec: JobSpec,
    state: JobState,
    error: Option<String>,
    result: Option<JobResult>,
    steps_done: usize,
    /// Last [`TAIL_LEN`] (step, wall_s, loss) records.
    tail: VecDeque<(usize, f64, f64)>,
    cancel: Arc<AtomicBool>,
}

#[derive(Default)]
struct State {
    next_id: JobId,
    pending: VecDeque<JobId>,
    jobs: BTreeMap<JobId, Entry>,
    draining: bool,
    running: usize,
}

impl State {
    /// Evict the oldest terminal entries beyond [`MAX_TERMINAL_RETAINED`]
    /// (in-memory only; persisted state files are left alone).
    fn prune_terminal(&mut self) {
        let terminal: Vec<JobId> = self
            .jobs
            .iter()
            .filter(|(_, e)| e.state.is_terminal())
            .map(|(&id, _)| id)
            .collect();
        if terminal.len() > MAX_TERMINAL_RETAINED {
            for id in &terminal[..terminal.len() - MAX_TERMINAL_RETAINED] {
                self.jobs.remove(id);
            }
        }
    }
}

struct Inner {
    cfg: QueueConfig,
    metrics: Arc<ServeMetrics>,
    state: Mutex<State>,
    cv: Condvar,
}

/// The queue handle. Cheap to share (`Arc` it once in the server).
pub struct JobQueue {
    inner: Arc<Inner>,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl JobQueue {
    /// Create the queue, recover any persisted jobs, spawn the workers.
    pub fn start(cfg: QueueConfig, metrics: Arc<ServeMetrics>) -> Result<Arc<JobQueue>> {
        if let Some(dir) = &cfg.state_dir {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating state dir {}", dir.display()))?;
        }
        // Zero workers is allowed (a queue that only accepts/persists —
        // used by tests); the server layer guards its own default.
        let workers = cfg.workers;
        let inner = Arc::new(Inner {
            cfg,
            metrics,
            state: Mutex::new(State { next_id: 1, ..State::default() }),
            cv: Condvar::new(),
        });
        inner.recover();
        let queue = Arc::new(JobQueue { inner, workers: Mutex::new(Vec::new()) });
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let inner = queue.inner.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("pogo-serve-worker-{w}"))
                    .spawn(move || worker_loop(inner))
                    .context("spawning worker thread")?,
            );
        }
        *queue.workers.lock().unwrap() = handles;
        Ok(queue)
    }

    /// Submit a job; returns its id or why it was refused.
    pub fn submit(&self, spec: JobSpec) -> std::result::Result<JobId, SubmitError> {
        if let Err(e) = spec.validate() {
            self.inner.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(SubmitError::Invalid(e));
        }
        let id = {
            let mut st = self.inner.state.lock().unwrap();
            if st.draining {
                self.inner.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                return Err(SubmitError::Draining);
            }
            if st.pending.len() >= self.inner.cfg.capacity {
                self.inner.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                return Err(SubmitError::Full(self.inner.cfg.capacity));
            }
            let id = st.next_id;
            st.next_id += 1;
            st.jobs.insert(
                id,
                Entry {
                    spec,
                    state: JobState::Queued,
                    error: None,
                    result: None,
                    steps_done: 0,
                    tail: VecDeque::new(),
                    cancel: Arc::new(AtomicBool::new(false)),
                },
            );
            st.pending.push_back(id);
            id
        };
        self.inner.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        self.inner.persist(id);
        // notify_all, not notify_one: the condvar is shared with
        // wait_terminal waiters, and a single wakeup could land on one of
        // them while an idle worker keeps sleeping.
        self.inner.cv.notify_all();
        Ok(id)
    }

    /// Cancel a job. Queued jobs flip to `cancelled` immediately; running
    /// jobs get their flag set and finish at the next step boundary.
    /// Returns the state after the call, or `None` for unknown ids.
    pub fn cancel(&self, id: JobId) -> Option<JobState> {
        let (state, persist) = {
            let mut st = self.inner.state.lock().unwrap();
            let current = st.jobs.get(&id)?.state;
            match current {
                JobState::Queued => {
                    st.pending.retain(|&q| q != id);
                    if let Some(e) = st.jobs.get_mut(&id) {
                        e.state = JobState::Cancelled;
                        e.result = None;
                    }
                    self.inner.metrics.cancelled.fetch_add(1, Ordering::Relaxed);
                    (JobState::Cancelled, true)
                }
                JobState::Running => {
                    if let Some(e) = st.jobs.get(&id) {
                        e.cancel.store(true, Ordering::Relaxed);
                    }
                    // Persist too: the state file records cancel_requested
                    // so a crash before the next step boundary cannot
                    // resurrect an acknowledged cancellation on restart.
                    (JobState::Running, true)
                }
                s => (s, false),
            }
        };
        if persist {
            self.inner.persist(id);
            self.inner.prune();
            self.inner.cv.notify_all();
        }
        Some(state)
    }

    /// Status snapshot for the API (`None` for unknown ids).
    pub fn status_json(&self, id: JobId) -> Option<Json> {
        let st = self.inner.state.lock().unwrap();
        let e = st.jobs.get(&id)?;
        Some(entry_json(id, e, true))
    }

    /// (state, result, error) snapshot, for the result endpoint/tests.
    pub fn snapshot(&self, id: JobId) -> Option<(JobState, Option<JobResult>, Option<String>)> {
        let st = self.inner.state.lock().unwrap();
        let e = st.jobs.get(&id)?;
        Some((e.state, e.result.clone(), e.error.clone()))
    }

    /// All jobs, compact.
    pub fn list_json(&self) -> Json {
        let st = self.inner.state.lock().unwrap();
        Json::arr(st.jobs.iter().map(|(&id, e)| entry_json(id, e, false)))
    }

    /// (queued, running) — the gauges of `GET /metrics`.
    pub fn depth_running(&self) -> (usize, usize) {
        let st = self.inner.state.lock().unwrap();
        (st.pending.len(), st.running)
    }

    pub fn capacity(&self) -> usize {
        self.inner.cfg.capacity
    }

    pub fn workers(&self) -> usize {
        self.inner.cfg.workers
    }

    /// Block until the job reaches a terminal state (or the deadline).
    /// Returns the last observed state; `None` for unknown ids.
    pub fn wait_terminal(&self, id: JobId, timeout: Duration) -> Option<JobState> {
        let deadline = Instant::now() + timeout;
        let mut st = self.inner.state.lock().unwrap();
        loop {
            let state = st.jobs.get(&id)?.state;
            if state.is_terminal() {
                return Some(state);
            }
            let now = Instant::now();
            if now >= deadline {
                return Some(state);
            }
            let (guard, _) = self.inner.cv.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
    }

    /// Flip the queue into draining (workers stop claiming and exit once
    /// idle) without blocking on them — what `Server`'s `Drop` uses.
    pub fn begin_drain(&self) {
        {
            let mut st = self.inner.state.lock().unwrap();
            st.draining = true;
        }
        self.inner.cv.notify_all();
    }

    /// Graceful shutdown: stop claiming, drain in-flight jobs, join the
    /// workers. Queued jobs stay queued (persisted if a state dir is
    /// configured, for the next daemon to recover).
    pub fn shutdown(&self) {
        self.begin_drain();
        let handles = std::mem::take(&mut *self.workers.lock().unwrap());
        for h in handles {
            h.join().ok();
        }
    }
}

fn entry_json(id: JobId, e: &Entry, with_tail: bool) -> Json {
    let mut fields = vec![
        ("id", Json::num(id as f64)),
        ("name", Json::str(e.spec.name.clone())),
        ("state", Json::str(e.state.name())),
        ("problem", Json::str(e.spec.problem.name())),
        ("domain", Json::str(e.spec.domain.name())),
        ("engine", Json::str(e.spec.optimizer.engine.name())),
        ("batch", Json::num(e.spec.batch as f64)),
        ("p", Json::num(e.spec.p as f64)),
        ("n", Json::num(e.spec.n as f64)),
        ("steps", Json::num(e.spec.steps as f64)),
        ("steps_done", Json::num(e.steps_done as f64)),
    ];
    if let Some(err) = &e.error {
        fields.push(("error", Json::str(err.clone())));
    }
    if let Some(r) = &e.result {
        fields.push(("result", r.to_json()));
    }
    if with_tail {
        fields.push((
            "tail",
            Json::arr(e.tail.iter().map(|&(step, wall, loss)| {
                Json::obj(vec![
                    ("step", Json::num(step as f64)),
                    ("wall_s", Json::num(wall)),
                    ("loss", Json::num(loss)),
                ])
            })),
        ));
    }
    Json::obj(fields)
}

impl Inner {
    /// Bound the in-memory terminal-job history (after persisting, so
    /// an evicted job's state file is already final on disk).
    fn prune(&self) {
        self.state.lock().unwrap().prune_terminal();
    }

    /// Per-step progress from a worker: bump the entry and the counters.
    fn progress(&self, id: JobId, step: usize, wall_s: f64, loss: f64) {
        self.metrics.steps.fetch_add(1, Ordering::Relaxed);
        let mut st = self.state.lock().unwrap();
        if let Some(e) = st.jobs.get_mut(&id) {
            e.steps_done = step;
            if e.tail.len() == TAIL_LEN {
                e.tail.pop_front();
            }
            e.tail.push_back((step, wall_s, loss));
        }
    }

    /// Checkpoint path for a job, when persistence applies to it.
    fn checkpoint_path(&self, id: JobId, spec: &JobSpec) -> Option<PathBuf> {
        if spec.checkpoint_every == 0 || spec.domain != super::job::JobDomain::Real {
            return None;
        }
        self.cfg.state_dir.as_ref().map(|d| d.join(format!("job-{id}.ckpt")))
    }

    /// Persist one job's spec + state to the state dir (best effort: a
    /// full disk must not take the daemon down).
    fn persist(&self, id: JobId) {
        let Some(dir) = &self.cfg.state_dir else { return };
        let json = {
            let st = self.state.lock().unwrap();
            let Some(e) = st.jobs.get(&id) else { return };
            let mut fields = vec![
                ("id", Json::num(id as f64)),
                ("state", Json::str(e.state.name())),
                ("spec", e.spec.to_json()),
            ];
            if e.cancel.load(Ordering::Relaxed) {
                fields.push(("cancel_requested", Json::Bool(true)));
            }
            if let Some(err) = &e.error {
                fields.push(("error", Json::str(err.clone())));
            }
            if let Some(r) = &e.result {
                fields.push(("result", r.to_json()));
            }
            Json::obj(fields)
        };
        // Write-then-rename with a per-call unique temp name: a crash
        // mid-write never tears the state file, and two racing persists
        // (cancel ack vs worker finish) each land a complete document —
        // whichever rename lands last wins, and recover() maps either to
        // the same terminal outcome.
        static PERSIST_SEQ: std::sync::atomic::AtomicU64 =
            std::sync::atomic::AtomicU64::new(0);
        let seq = PERSIST_SEQ.fetch_add(1, Ordering::Relaxed);
        let path = dir.join(format!("job-{id}.json"));
        let tmp = dir.join(format!("job-{id}.json.{seq}.tmp"));
        let write = std::fs::write(&tmp, json.to_string_pretty() + "\n")
            .and_then(|()| std::fs::rename(&tmp, &path));
        if let Err(e) = write {
            std::fs::remove_file(&tmp).ok();
            log::warn!("failed to persist job {id} to {}: {e}", path.display());
        }
    }

    /// Re-list persisted jobs on startup. Unfinished jobs (queued or
    /// running at the previous daemon's death) are re-queued — their
    /// checkpoints, if any, make the re-run resume instead of restart.
    /// Terminal jobs stay queryable. Malformed files are skipped with a
    /// warning, never fatal.
    fn recover(&self) {
        let Some(dir) = &self.cfg.state_dir else { return };
        let Ok(entries) = std::fs::read_dir(dir) else { return };
        let mut found: Vec<(JobId, Json)> = Vec::new();
        for entry in entries.flatten() {
            let path = entry.path();
            let name = path.file_name().and_then(|s| s.to_str()).unwrap_or("");
            let Some(id) = name
                .strip_prefix("job-")
                .and_then(|s| s.strip_suffix(".json"))
                .and_then(|s| s.parse::<JobId>().ok())
            else {
                continue;
            };
            match Json::parse_file(&path) {
                Ok(j) => found.push((id, j)),
                Err(e) => log::warn!("skipping unreadable state file {}: {e:#}", path.display()),
            }
        }
        found.sort_by_key(|&(id, _)| id);
        let mut st = self.state.lock().unwrap();
        for (id, j) in found {
            let spec = match JobSpec::from_json(j.get("spec")) {
                Ok(s) => s,
                Err(e) => {
                    log::warn!("skipping persisted job {id} with bad spec: {e:#}");
                    continue;
                }
            };
            let state = j
                .get("state")
                .as_str()
                .and_then(JobState::parse)
                .unwrap_or(JobState::Queued);
            // An acknowledged-but-unfinished cancellation lands as
            // cancelled, never re-queued.
            let state = if !state.is_terminal()
                && j.get("cancel_requested").as_bool().unwrap_or(false)
            {
                JobState::Cancelled
            } else {
                state
            };
            let result = JobResult::from_json(j.get("result")).ok();
            let error = j.get("error").as_str().map(str::to_string);
            let requeue = !state.is_terminal();
            let steps_done =
                if requeue { 0 } else { result.as_ref().map(|r| r.steps_done).unwrap_or(0) };
            st.jobs.insert(
                id,
                Entry {
                    spec,
                    state: if requeue { JobState::Queued } else { state },
                    error,
                    result,
                    steps_done,
                    tail: VecDeque::new(),
                    cancel: Arc::new(AtomicBool::new(false)),
                },
            );
            if requeue {
                st.pending.push_back(id);
                log::info!("recovered unfinished job {id}; re-queued");
            }
            st.next_id = st.next_id.max(id + 1);
        }
        st.prune_terminal();
    }
}

fn worker_loop(inner: Arc<Inner>) {
    loop {
        // Claim one job (or exit once draining).
        let claimed = {
            let mut st = inner.state.lock().unwrap();
            loop {
                if st.draining {
                    break None;
                }
                if let Some(id) = st.pending.pop_front() {
                    let claim = match st.jobs.get_mut(&id) {
                        Some(e) => {
                            e.state = JobState::Running;
                            (id, e.spec.clone(), e.cancel.clone())
                        }
                        None => continue, // stale id; keep looking
                    };
                    st.running += 1;
                    break Some(claim);
                }
                st = inner.cv.wait(st).unwrap();
            }
        };
        let Some((id, spec, cancel)) = claimed else { return };
        inner.persist(id);

        // Run the job, recording its loss series through the
        // coordinator's MetricLog (its wall-stamped tail is what the
        // status endpoint serves).
        let log = std::cell::RefCell::new(MetricLog::new(format!("job-{id}")));
        let inner_cb = inner.clone();
        let on_step = |step: usize, loss: f64| {
            let wall = {
                let mut lg = log.borrow_mut();
                lg.record(step, &[("loss", loss)]);
                lg.elapsed()
            };
            inner_cb.progress(id, step, wall, loss);
        };
        let ctl = RunCtl {
            cancel: Some(&cancel),
            on_step: Some(&on_step),
            checkpoint_path: inner.checkpoint_path(id, &spec),
        };
        let outcome =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| job::run_job(&spec, &ctl)));

        {
            let mut st = inner.state.lock().unwrap();
            st.running -= 1;
            if let Some(e) = st.jobs.get_mut(&id) {
                match outcome {
                    Ok(Ok(JobOutcome::Done(r))) => {
                        e.state = JobState::Done;
                        e.steps_done = r.steps_done;
                        e.result = Some(r);
                        inner.metrics.completed.fetch_add(1, Ordering::Relaxed);
                    }
                    Ok(Ok(JobOutcome::Cancelled(r))) => {
                        e.state = JobState::Cancelled;
                        e.steps_done = r.steps_done;
                        e.result = Some(r);
                        inner.metrics.cancelled.fetch_add(1, Ordering::Relaxed);
                    }
                    Ok(Err(err)) => {
                        e.state = JobState::Failed;
                        e.error = Some(format!("{err:#}"));
                        inner.metrics.failed.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(panic) => {
                        let msg = panic
                            .downcast_ref::<String>()
                            .map(String::as_str)
                            .or_else(|| panic.downcast_ref::<&str>().copied())
                            .unwrap_or("worker panicked");
                        e.state = JobState::Failed;
                        e.error = Some(format!("panic: {msg}"));
                        inner.metrics.failed.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
        }
        inner.persist(id);
        inner.prune();
        inner.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::OptimizerSpec;
    use crate::optim::{Engine, Method};
    use crate::serve::job::ProblemKind;

    fn quick_spec(steps: usize) -> JobSpec {
        let mut s = JobSpec::new(ProblemKind::Quartic, 2, 2, 4);
        s.steps = steps;
        s.seed = 3;
        s.optimizer = OptimizerSpec::new(Method::Pogo, 0.05);
        s
    }

    fn start(workers: usize, capacity: usize) -> Arc<JobQueue> {
        JobQueue::start(
            QueueConfig { workers, capacity, state_dir: None },
            Arc::new(ServeMetrics::new()),
        )
        .unwrap()
    }

    #[test]
    fn runs_jobs_to_done() {
        let q = start(2, 16);
        let a = q.submit(quick_spec(20)).unwrap();
        let b = q.submit(quick_spec(20)).unwrap();
        assert_ne!(a, b);
        assert_eq!(q.wait_terminal(a, Duration::from_secs(30)), Some(JobState::Done));
        assert_eq!(q.wait_terminal(b, Duration::from_secs(30)), Some(JobState::Done));
        let (state, result, error) = q.snapshot(a).unwrap();
        assert_eq!(state, JobState::Done);
        assert!(error.is_none());
        let r = result.unwrap();
        assert_eq!(r.steps_done, 20);
        assert!(r.ortho_error <= 1e-3);
        // The metrics tail survives in the status JSON.
        let j = q.status_json(a).unwrap();
        assert_eq!(j.get("state").as_str(), Some("done"));
        assert!(!j.get("tail").as_arr().unwrap().is_empty());
        q.shutdown();
    }

    #[test]
    fn bad_spec_fails_cleanly() {
        let q = start(1, 4);
        let mut spec = quick_spec(5);
        spec.optimizer = spec.optimizer.with_engine(Engine::Xla); // no registry in serve
        let id = q.submit(spec).unwrap();
        assert_eq!(q.wait_terminal(id, Duration::from_secs(30)), Some(JobState::Failed));
        let (_, _, error) = q.snapshot(id).unwrap();
        assert!(error.unwrap().contains("registry"), "error should name the cause");
        // The queue is still alive after the failure.
        let ok = q.submit(quick_spec(5)).unwrap();
        assert_eq!(q.wait_terminal(ok, Duration::from_secs(30)), Some(JobState::Done));
        q.shutdown();
    }

    #[test]
    fn cancel_queued_job_and_capacity_limit() {
        // One worker, one long job occupying it; the backlog then fills.
        let q = start(1, 1);
        let long = q.submit(quick_spec(200_000)).unwrap();
        // Wait until the long job is claimed so the backlog is empty.
        let t0 = Instant::now();
        while q.depth_running() != (0, 1) {
            assert!(t0.elapsed() < Duration::from_secs(10), "worker never claimed the job");
            std::thread::sleep(Duration::from_millis(5));
        }
        let queued = q.submit(quick_spec(5)).unwrap();
        match q.submit(quick_spec(5)) {
            Err(SubmitError::Full(1)) => {}
            other => panic!("expected Full, got {other:?}"),
        }
        // Cancel the queued job: immediate, no worker involved.
        assert_eq!(q.cancel(queued), Some(JobState::Cancelled));
        // Cancel the running job: flag flips, worker drains at a step edge.
        q.cancel(long);
        assert_eq!(q.wait_terminal(long, Duration::from_secs(30)), Some(JobState::Cancelled));
        assert!(q.cancel(9999).is_none());
        q.shutdown();
    }

    #[test]
    fn shutdown_drains_in_flight_and_refuses_new() {
        let q = start(1, 8);
        let id = q.submit(quick_spec(50)).unwrap();
        q.shutdown();
        // The in-flight (or queued-then-drained-by-timing) job is not
        // left running; after shutdown new submissions are refused.
        match q.submit(quick_spec(5)) {
            Err(SubmitError::Draining) => {}
            other => panic!("expected Draining, got {other:?}"),
        }
        let (state, _, _) = q.snapshot(id).unwrap();
        assert!(
            state == JobState::Done || state == JobState::Queued,
            "drained job ended as {state:?}"
        );
    }

    #[test]
    fn acknowledged_cancellation_survives_a_crash() {
        // A state file left by a daemon that died after acknowledging a
        // DELETE of a running job: recovered as cancelled, never re-run.
        let dir = std::env::temp_dir()
            .join(format!("pogo_serve_queue_cancelreq_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let state = Json::obj(vec![
            ("id", Json::num(5.0)),
            ("state", Json::str("running")),
            ("cancel_requested", Json::Bool(true)),
            ("spec", quick_spec(10).to_json()),
        ]);
        std::fs::write(dir.join("job-5.json"), state.to_string_pretty()).unwrap();
        let q = JobQueue::start(
            QueueConfig { workers: 1, capacity: 4, state_dir: Some(dir.clone()) },
            Arc::new(ServeMetrics::new()),
        )
        .unwrap();
        let (state, _, _) = q.snapshot(5).unwrap();
        assert_eq!(state, JobState::Cancelled);
        q.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn persists_and_recovers_unfinished_jobs() {
        let dir = std::env::temp_dir()
            .join(format!("pogo_serve_queue_recover_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();

        // First daemon: enqueue two jobs into a zero-worker queue (they
        // stay queued), then shut down.
        let q = JobQueue::start(
            QueueConfig { workers: 0, capacity: 8, state_dir: Some(dir.clone()) },
            Arc::new(ServeMetrics::new()),
        )
        .unwrap();
        let a = q.submit(quick_spec(10)).unwrap();
        let b = q.submit(quick_spec(10)).unwrap();
        q.shutdown();
        drop(q);

        // Second daemon recovers both, runs them to done, and keeps ids.
        let q2 = JobQueue::start(
            QueueConfig { workers: 2, capacity: 8, state_dir: Some(dir.clone()) },
            Arc::new(ServeMetrics::new()),
        )
        .unwrap();
        assert_eq!(q2.wait_terminal(a, Duration::from_secs(30)), Some(JobState::Done));
        assert_eq!(q2.wait_terminal(b, Duration::from_secs(30)), Some(JobState::Done));
        // Fresh ids don't collide with recovered ones.
        let c = q2.submit(quick_spec(5)).unwrap();
        assert!(c > b);
        // Terminal states were persisted for the third daemon.
        q2.shutdown();
        let q3 = JobQueue::start(
            QueueConfig { workers: 0, capacity: 8, state_dir: Some(dir.clone()) },
            Arc::new(ServeMetrics::new()),
        )
        .unwrap();
        let (state, result, _) = q3.snapshot(a).unwrap();
        assert_eq!(state, JobState::Done);
        assert!(result.unwrap().ortho_error <= 1e-3);
        q3.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }
}
