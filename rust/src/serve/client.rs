//! In-process client for the serve API — what `benches/serve_load.rs`
//! and the e2e tests drive, and a reference for how to talk to the
//! daemon from anything that can open a TCP socket.
//!
//! Thin by design: one [`http::request`] round-trip per call (or one
//! [`http::stream_sse`] subscription for the v2 event feed), JSON in and
//! out, non-2xx mapped to `Err` carrying the server's error body. An
//! optional API key rides every call as `X-Api-Key` — the daemon's
//! tenant identity for quotas.

use super::http;
use super::job::JobSpec;
use super::queue::JobId;
use crate::util::json::Json;
use anyhow::{anyhow, Result};
use std::time::{Duration, Instant};

/// First-retry backoff for [`retry_transport`]; doubles per attempt.
const RETRY_BASE: Duration = Duration::from_millis(50);
/// Backoff ceiling — retries never sleep longer than this.
const RETRY_MAX_SLEEP: Duration = Duration::from_secs(2);

/// Run `op` up to `attempts` times, retrying **only** on
/// [`http::ReadError::Transport`] — the class where the peer vanished
/// and the request provably did not change server state. Protocol
/// errors (a real HTTP answer) return immediately: the server spoke,
/// retrying would just repeat the answer. Backoff doubles from
/// [`RETRY_BASE`] with a random jitter so a fleet of front doors
/// probing the same dead backend does not reconnect in lockstep.
///
/// Callers must only route idempotent work through this (GETs, health
/// probes). Submissions and cancels go through the single-shot path —
/// a POST whose response was lost may still have been applied.
pub fn retry_transport<T>(
    attempts: u32,
    mut op: impl FnMut() -> Result<T, http::ReadError>,
) -> Result<T, http::ReadError> {
    let mut delay = RETRY_BASE;
    for attempt in 1.. {
        match op() {
            Ok(v) => return Ok(v),
            Err(http::ReadError::Transport(_)) if attempt < attempts.max(1) => {
                std::thread::sleep(jittered(delay, attempt));
                delay = (delay * 2).min(RETRY_MAX_SLEEP);
            }
            Err(e) => return Err(e),
        }
    }
    unreachable!("loop returns on the final attempt");
}

/// `delay/2 .. delay`, seeded from the process-random hasher state so
/// no clock or RNG dependency is needed.
fn jittered(delay: Duration, attempt: u32) -> Duration {
    use std::hash::{BuildHasher, Hasher};
    let mut h = std::collections::hash_map::RandomState::new().build_hasher();
    h.write_u32(attempt);
    let half_ms = (delay.as_millis() as u64 / 2).max(1);
    delay / 2 + Duration::from_millis(h.finish() % (half_ms + 1))
}

/// One decoded `event: progress` record from the v2 stream.
#[derive(Clone, Copy, Debug)]
pub struct StreamedStep {
    pub step: usize,
    pub loss: f64,
    pub ortho_error: f64,
    pub wall_s: f64,
}

/// A handle on one daemon address. Cheap to clone per client thread.
#[derive(Clone, Debug)]
pub struct ServeClient {
    addr: String,
    api_key: Option<String>,
    retries: u32,
}

impl ServeClient {
    pub fn new(addr: impl Into<String>) -> ServeClient {
        ServeClient { addr: addr.into(), api_key: None, retries: 1 }
    }

    /// Attach an API key (the daemon's tenant identity) to every call.
    pub fn with_api_key(mut self, key: impl Into<String>) -> ServeClient {
        self.api_key = Some(key.into());
        self
    }

    /// Allow up to `attempts` tries for idempotent GETs (transport
    /// failures only — see [`retry_transport`]). Writes never retry.
    pub fn with_retries(mut self, attempts: u32) -> ServeClient {
        self.retries = attempts.max(1);
        self
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    fn headers(&self) -> Vec<(&str, &str)> {
        match &self.api_key {
            Some(k) => vec![("X-Api-Key", k.as_str())],
            None => Vec::new(),
        }
    }

    fn call(&self, method: &str, path: &str, body: Option<&str>) -> Result<Json> {
        let attempts = if method == "GET" { self.retries } else { 1 };
        let (code, _, text) = retry_transport(attempts, || {
            http::request_full(&self.addr, method, path, body, &self.headers())
        })?;
        let parsed = Json::parse(&text)
            .map_err(|e| anyhow!("{method} {path}: HTTP {code} with non-JSON body: {e}"))?;
        if !(200..300).contains(&code) {
            let msg = parsed.get("error").as_str().unwrap_or("unknown error").to_string();
            return Err(anyhow!("{method} {path}: HTTP {code}: {msg}"));
        }
        Ok(parsed)
    }

    fn submit_to(&self, path: &str, spec: &JobSpec) -> Result<JobId> {
        let j = self.call("POST", path, Some(&spec.to_json().to_string()))?;
        j.get("id")
            .as_usize()
            .map(|v| v as JobId)
            .ok_or_else(|| anyhow!("submit response has no id: {}", j.to_string()))
    }

    /// Submit a job (v1 surface); returns its id.
    pub fn submit(&self, spec: &JobSpec) -> Result<JobId> {
        self.submit_to("/v1/jobs", spec)
    }

    /// Submit a job on the v2 surface (inline sources, quota headers).
    pub fn submit_v2(&self, spec: &JobSpec) -> Result<JobId> {
        self.submit_to("/v2/jobs", spec)
    }

    /// Upload an encoded artifact (`POST /v2/artifacts`). Returns the
    /// upload receipt `{hash, bytes, existed}` — a `409` for an already
    /// stored hash is a success here (the receipt says `existed: true`),
    /// since content-addressed re-uploads are idempotent.
    pub fn upload_artifact(&self, encoded: &[u8]) -> Result<Json> {
        let (code, _, text) =
            http::request_bytes(&self.addr, "POST", "/v2/artifacts", encoded, &self.headers())?;
        let parsed = Json::parse(&text)
            .map_err(|e| anyhow!("POST /v2/artifacts: HTTP {code} with non-JSON body: {e}"))?;
        if !(200..300).contains(&code) && code != 409 {
            let msg = parsed.get("error").as_str().unwrap_or("unknown error").to_string();
            return Err(anyhow!("POST /v2/artifacts: HTTP {code}: {msg}"));
        }
        Ok(parsed)
    }

    /// Artifact-store summary (`GET /v2/artifacts`).
    pub fn artifact_summary(&self) -> Result<Json> {
        self.call("GET", "/v2/artifacts", None)
    }

    /// Status + metrics tail of one job (v1).
    pub fn status(&self, id: JobId) -> Result<Json> {
        self.call("GET", &format!("/v1/jobs/{id}"), None)
    }

    /// v2 status: v1 fields plus tenant, cost and series length.
    pub fn status_v2(&self, id: JobId) -> Result<Json> {
        self.call("GET", &format!("/v2/jobs/{id}"), None)
    }

    /// Final result (errors while the job is still queued/running).
    pub fn result(&self, id: JobId) -> Result<Json> {
        self.call("GET", &format!("/v1/jobs/{id}/result"), None)
    }

    /// v2 result: the full (untruncated) loss series and final iterate.
    pub fn result_v2(&self, id: JobId) -> Result<Json> {
        self.call("GET", &format!("/v2/jobs/{id}/result"), None)
    }

    /// Cancel; returns the state after the call.
    pub fn cancel(&self, id: JobId) -> Result<Json> {
        self.call("DELETE", &format!("/v1/jobs/{id}"), None)
    }

    /// v2 cancel (`DELETE /v2/jobs/:id`) — the route a federated front
    /// door proxies; same state semantics as [`ServeClient::cancel`].
    pub fn cancel_v2(&self, id: JobId) -> Result<Json> {
        self.call("DELETE", &format!("/v2/jobs/{id}"), None)
    }

    /// All jobs, compact.
    pub fn list(&self) -> Result<Json> {
        self.call("GET", "/v1/jobs", None)
    }

    pub fn healthz(&self) -> Result<Json> {
        self.call("GET", "/healthz", None)
    }

    /// Raw Prometheus text.
    pub fn metrics(&self) -> Result<String> {
        let (code, text) = http::request(&self.addr, "GET", "/metrics", None)?;
        if code != 200 {
            return Err(anyhow!("GET /metrics: HTTP {code}"));
        }
        Ok(text)
    }

    /// Subscribe to a job's live SSE stream and hand every progress
    /// record to `on_step`. Blocks until the stream's terminal `state`
    /// event (returned), `on_step` returns `false` (returns the last
    /// state seen, usually empty), or `timeout` passes (an error).
    pub fn stream_events(
        &self,
        id: JobId,
        timeout: Duration,
        mut on_step: impl FnMut(&StreamedStep) -> bool,
    ) -> Result<String> {
        let mut terminal = String::new();
        let path = format!("/v2/jobs/{id}/events");
        http::stream_sse(
            &self.addr,
            &path,
            &self.headers(),
            timeout,
            &mut |event, data| match event {
                "progress" => {
                    let Ok(j) = Json::parse(data) else { return true };
                    let step = StreamedStep {
                        step: j.get("step").as_usize().unwrap_or(0),
                        loss: j.get("loss").as_f64().unwrap_or(f64::NAN),
                        ortho_error: j.get("ortho_error").as_f64().unwrap_or(f64::NAN),
                        wall_s: j.get("wall_s").as_f64().unwrap_or(0.0),
                    };
                    on_step(&step)
                }
                "state" => {
                    if let Ok(j) = Json::parse(data) {
                        terminal = j.get("state").as_str().unwrap_or("").to_string();
                    }
                    true // the server closes the stream right after
                }
                _ => true,
            },
        )?;
        Ok(terminal)
    }

    /// Follow a job over SSE to its terminal state and fetch the v2
    /// result — the streaming analogue of [`wait_result`]
    /// (`failed`/`cancelled` end states are an error naming them).
    pub fn stream_result(&self, id: JobId, timeout: Duration) -> Result<Json> {
        let state = self.stream_events(id, timeout, |_| true)?;
        match state.as_str() {
            "done" => self.result_v2(id),
            "" => Err(anyhow!("job {id}: event stream ended without a terminal state")),
            other => Err(anyhow!("job {id} ended as '{other}'")),
        }
    }

    /// Poll until the job reaches a terminal state; returns the final
    /// status JSON (inspect `state` — it may be `failed`/`cancelled`).
    pub fn wait_terminal(&self, id: JobId, timeout: Duration) -> Result<Json> {
        let deadline = Instant::now() + timeout;
        loop {
            let status = self.status(id)?;
            match status.get("state").as_str() {
                Some("done" | "failed" | "cancelled") => return Ok(status),
                Some(_) if Instant::now() < deadline => {
                    std::thread::sleep(Duration::from_millis(20));
                }
                Some(s) => return Err(anyhow!("job {id} still '{s}' after {timeout:?}")),
                None => return Err(anyhow!("job {id} status has no state")),
            }
        }
    }

    /// Poll to `done` and fetch the result; a `failed`/`cancelled` end
    /// state is an error naming it.
    pub fn wait_result(&self, id: JobId, timeout: Duration) -> Result<Json> {
        let status = self.wait_terminal(id, timeout)?;
        match status.get("state").as_str() {
            Some("done") => self.result(id),
            Some(other) => Err(anyhow!(
                "job {id} ended as '{other}': {}",
                status.get("error").as_str().unwrap_or("(no error recorded)")
            )),
            None => Err(anyhow!("job {id} status has no state")),
        }
    }

    /// [`ServeClient::wait_terminal`] over the v2 surface — the only
    /// surface a federated front door (`pogo front`) proxies.
    pub fn wait_terminal_v2(&self, id: JobId, timeout: Duration) -> Result<Json> {
        let deadline = Instant::now() + timeout;
        loop {
            let status = self.status_v2(id)?;
            match status.get("state").as_str() {
                Some("done" | "failed" | "cancelled") => return Ok(status),
                Some(_) if Instant::now() < deadline => {
                    std::thread::sleep(Duration::from_millis(20));
                }
                Some(s) => return Err(anyhow!("job {id} still '{s}' after {timeout:?}")),
                None => return Err(anyhow!("job {id} status has no state")),
            }
        }
    }

    /// [`ServeClient::wait_result`] over the v2 surface.
    pub fn wait_result_v2(&self, id: JobId, timeout: Duration) -> Result<Json> {
        let status = self.wait_terminal_v2(id, timeout)?;
        match status.get("state").as_str() {
            Some("done") => self.result_v2(id),
            Some(other) => Err(anyhow!(
                "job {id} ended as '{other}': {}",
                status.get("error").as_str().unwrap_or("(no error recorded)")
            )),
            None => Err(anyhow!("job {id} status has no state")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;
    use std::net::TcpListener;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    /// A listener that drops its first `flaky_for` connections on the
    /// floor (accept, then hang up — the classic restarting-backend
    /// window) and answers every later request with 200 JSON. Returns
    /// (addr, connection counter).
    fn spawn_flaky(flaky_for: usize) -> (String, Arc<AtomicUsize>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let conns = Arc::new(AtomicUsize::new(0));
        let counter = conns.clone();
        std::thread::spawn(move || {
            for conn in listener.incoming() {
                let Ok(mut stream) = conn else { break };
                let n = counter.fetch_add(1, Ordering::SeqCst);
                if n < flaky_for {
                    drop(stream); // EOF before any status line: Transport
                    continue;
                }
                if http::read_request(&stream).is_err() {
                    continue;
                }
                let body = "{\"ok\": true}";
                let resp = format!(
                    "HTTP/1.1 200 OK\r\nContent-Type: application/json\r\n\
                     Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
                    body.len()
                );
                stream.write_all(resp.as_bytes()).ok();
            }
        });
        (addr, conns)
    }

    #[test]
    fn flaky_listener_succeeds_on_retry() {
        let (addr, conns) = spawn_flaky(2);
        let client = ServeClient::new(&addr).with_retries(4);
        let j = client.healthz().expect("retries should ride out two dropped connections");
        assert_eq!(j.get("ok").as_bool(), Some(true));
        assert_eq!(conns.load(Ordering::SeqCst), 3, "two drops + one success");
    }

    #[test]
    fn post_is_never_retried() {
        let (addr, conns) = spawn_flaky(usize::MAX);
        let client = ServeClient::new(&addr).with_retries(4);
        let spec = JobSpec::new(super::super::job::ProblemKind::Quartic, 2, 2, 4);
        let err = client.submit_v2(&spec).expect_err("dead listener must fail the POST");
        assert!(err.to_string().contains("transport"), "{err:#}");
        assert_eq!(conns.load(Ordering::SeqCst), 1, "a POST gets exactly one attempt");
    }

    #[test]
    fn exhausted_retries_surface_the_transport_error() {
        let (addr, conns) = spawn_flaky(usize::MAX);
        let client = ServeClient::new(&addr).with_retries(3);
        client.healthz().expect_err("every attempt drops");
        assert_eq!(conns.load(Ordering::SeqCst), 3);
    }
}
