//! In-process client for the serve API — what `benches/serve_load.rs`
//! and the e2e tests drive, and a reference for how to talk to the
//! daemon from anything that can open a TCP socket.
//!
//! Thin by design: one [`http::request`] round-trip per call, JSON in
//! and out, non-2xx mapped to `Err` carrying the server's error body.

use super::http;
use super::job::JobSpec;
use super::queue::JobId;
use crate::util::json::Json;
use anyhow::{anyhow, Result};
use std::time::{Duration, Instant};

/// A handle on one daemon address. Cheap to clone per client thread.
#[derive(Clone, Debug)]
pub struct ServeClient {
    addr: String,
}

impl ServeClient {
    pub fn new(addr: impl Into<String>) -> ServeClient {
        ServeClient { addr: addr.into() }
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    fn call(&self, method: &str, path: &str, body: Option<&str>) -> Result<Json> {
        let (code, text) = http::request(&self.addr, method, path, body)?;
        let parsed = Json::parse(&text)
            .map_err(|e| anyhow!("{method} {path}: HTTP {code} with non-JSON body: {e}"))?;
        if !(200..300).contains(&code) {
            let msg = parsed.get("error").as_str().unwrap_or("unknown error").to_string();
            return Err(anyhow!("{method} {path}: HTTP {code}: {msg}"));
        }
        Ok(parsed)
    }

    /// Submit a job; returns its id.
    pub fn submit(&self, spec: &JobSpec) -> Result<JobId> {
        let j = self.call("POST", "/v1/jobs", Some(&spec.to_json().to_string()))?;
        j.get("id")
            .as_usize()
            .map(|v| v as JobId)
            .ok_or_else(|| anyhow!("submit response has no id: {}", j.to_string()))
    }

    /// Status + metrics tail of one job.
    pub fn status(&self, id: JobId) -> Result<Json> {
        self.call("GET", &format!("/v1/jobs/{id}"), None)
    }

    /// Final result (errors while the job is still queued/running).
    pub fn result(&self, id: JobId) -> Result<Json> {
        self.call("GET", &format!("/v1/jobs/{id}/result"), None)
    }

    /// Cancel; returns the state after the call.
    pub fn cancel(&self, id: JobId) -> Result<Json> {
        self.call("DELETE", &format!("/v1/jobs/{id}"), None)
    }

    /// All jobs, compact.
    pub fn list(&self) -> Result<Json> {
        self.call("GET", "/v1/jobs", None)
    }

    pub fn healthz(&self) -> Result<Json> {
        self.call("GET", "/healthz", None)
    }

    /// Raw Prometheus text.
    pub fn metrics(&self) -> Result<String> {
        let (code, text) = http::request(&self.addr, "GET", "/metrics", None)?;
        if code != 200 {
            return Err(anyhow!("GET /metrics: HTTP {code}"));
        }
        Ok(text)
    }

    /// Poll until the job reaches a terminal state; returns the final
    /// status JSON (inspect `state` — it may be `failed`/`cancelled`).
    pub fn wait_terminal(&self, id: JobId, timeout: Duration) -> Result<Json> {
        let deadline = Instant::now() + timeout;
        loop {
            let status = self.status(id)?;
            match status.get("state").as_str() {
                Some("done" | "failed" | "cancelled") => return Ok(status),
                Some(_) if Instant::now() < deadline => {
                    std::thread::sleep(Duration::from_millis(20));
                }
                Some(s) => return Err(anyhow!("job {id} still '{s}' after {timeout:?}")),
                None => return Err(anyhow!("job {id} status has no state")),
            }
        }
    }

    /// Poll to `done` and fetch the result; a `failed`/`cancelled` end
    /// state is an error naming it.
    pub fn wait_result(&self, id: JobId, timeout: Duration) -> Result<Json> {
        let status = self.wait_terminal(id, timeout)?;
        match status.get("state").as_str() {
            Some("done") => self.result(id),
            Some(other) => Err(anyhow!(
                "job {id} ended as '{other}': {}",
                status.get("error").as_str().unwrap_or("(no error recorded)")
            )),
            None => Err(anyhow!("job {id} status has no state")),
        }
    }
}
