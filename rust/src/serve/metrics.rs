//! Daemon-level counters, rendered as Prometheus text for `GET /metrics`.
//!
//! Counters are lock-free atomics bumped by the queue and the HTTP layer;
//! gauges (queue depth, running jobs) are sampled from the queue at render
//! time. Per-job series (the loss tail of `GET /v1/jobs/:id`) live in the
//! queue entries, fed from each worker's
//! [`MetricLog`](crate::coordinator::MetricLog).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Monotonic counters for one daemon lifetime.
pub struct ServeMetrics {
    started: Instant,
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    pub failed: AtomicU64,
    pub cancelled: AtomicU64,
    /// Submissions refused (queue full / draining / invalid spec).
    pub rejected: AtomicU64,
    /// Optimizer steps applied across all jobs.
    pub steps: AtomicU64,
    /// HTTP requests handled (any endpoint, any status).
    pub requests: AtomicU64,
}

impl Default for ServeMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl ServeMetrics {
    pub fn new() -> ServeMetrics {
        ServeMetrics {
            started: Instant::now(),
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            cancelled: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            steps: AtomicU64::new(0),
            requests: AtomicU64::new(0),
        }
    }

    pub fn uptime_s(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Render the Prometheus exposition text. The gauges are passed in by
    /// the caller (sampled from the queue under its lock).
    pub fn render(
        &self,
        queue_depth: usize,
        running: usize,
        capacity: usize,
        workers: usize,
    ) -> String {
        let mut out = String::with_capacity(1024);
        let mut metric = |name: &str, kind: &str, help: &str, value: f64| {
            out.push_str(&format!(
                "# HELP {name} {help}\n# TYPE {name} {kind}\n{name} {value}\n"
            ));
        };
        metric(
            "pogo_serve_uptime_seconds",
            "gauge",
            "Seconds since the daemon started.",
            self.uptime_s(),
        );
        metric(
            "pogo_serve_jobs_submitted_total",
            "counter",
            "Jobs accepted into the queue.",
            self.submitted.load(Ordering::Relaxed) as f64,
        );
        metric(
            "pogo_serve_jobs_completed_total",
            "counter",
            "Jobs that reached done.",
            self.completed.load(Ordering::Relaxed) as f64,
        );
        metric(
            "pogo_serve_jobs_failed_total",
            "counter",
            "Jobs that failed.",
            self.failed.load(Ordering::Relaxed) as f64,
        );
        metric(
            "pogo_serve_jobs_cancelled_total",
            "counter",
            "Jobs cancelled by clients.",
            self.cancelled.load(Ordering::Relaxed) as f64,
        );
        metric(
            "pogo_serve_jobs_rejected_total",
            "counter",
            "Submissions refused (full queue, draining, invalid spec).",
            self.rejected.load(Ordering::Relaxed) as f64,
        );
        metric(
            "pogo_serve_steps_total",
            "counter",
            "Optimizer steps applied across all jobs.",
            self.steps.load(Ordering::Relaxed) as f64,
        );
        metric(
            "pogo_serve_http_requests_total",
            "counter",
            "HTTP requests handled.",
            self.requests.load(Ordering::Relaxed) as f64,
        );
        metric(
            "pogo_serve_queue_depth",
            "gauge",
            "Jobs queued and not yet running.",
            queue_depth as f64,
        );
        metric(
            "pogo_serve_jobs_running",
            "gauge",
            "Jobs currently executing.",
            running as f64,
        );
        metric(
            "pogo_serve_queue_capacity",
            "gauge",
            "Maximum queued-job backlog.",
            capacity as f64,
        );
        metric("pogo_serve_workers", "gauge", "Worker threads.", workers as f64);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_every_series_once() {
        let m = ServeMetrics::new();
        m.submitted.fetch_add(3, Ordering::Relaxed);
        m.steps.fetch_add(100, Ordering::Relaxed);
        let text = m.render(2, 1, 256, 4);
        for name in [
            "pogo_serve_uptime_seconds",
            "pogo_serve_jobs_submitted_total 3",
            "pogo_serve_steps_total 100",
            "pogo_serve_queue_depth 2",
            "pogo_serve_jobs_running 1",
            "pogo_serve_queue_capacity 256",
            "pogo_serve_workers 4",
        ] {
            assert!(text.contains(name), "missing {name} in:\n{text}");
        }
        // One TYPE line per series, no duplicates.
        assert_eq!(text.matches("# TYPE pogo_serve_queue_depth").count(), 1);
    }
}
