//! Daemon-level counters, rendered as Prometheus text for `GET /metrics`.
//!
//! Counters are lock-free atomics bumped by the queue and the HTTP layer;
//! gauges (queue depth, running jobs, per-state retention, outstanding
//! admission cost, live SSE subscribers) are sampled at render time from
//! a [`QueueGauges`] snapshot the caller fills under the queue lock.
//! Admission rejections are counted **by cause** (invalid spec, tenant
//! quota, cost budget, inline payload size) so the serve-smoke CI job can
//! assert on them — plus the aggregate `rejected_total` every cause also
//! bumps.

use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Queue-side gauge snapshot, sampled under the queue lock by the caller.
pub struct QueueGauges {
    /// Jobs queued and not yet running.
    pub depth: usize,
    /// Jobs currently executing.
    pub running: usize,
    /// Backlog capacity.
    pub capacity: usize,
    /// Worker threads.
    pub workers: usize,
    /// Retained jobs per state, `(state name, count)`.
    pub by_state: Vec<(&'static str, usize)>,
    /// Outstanding admitted `B·p·n·steps` cost units.
    pub outstanding_cost: u64,
    /// Compute-pool mode (`"resident"` / `"spawn"`).
    pub pool_mode: &'static str,
    /// Resident compute-pool worker threads (0 until first dispatch, or
    /// always 0 in spawn mode).
    pub pool_workers: usize,
    /// Parallel dispatches into the compute pool since startup.
    pub pool_dispatches: u64,
    /// Cumulative ns pool workers spent executing shards (accrues only
    /// while `POGO_OBS` is on; see `util::pool`).
    pub pool_busy_ns: u64,
    /// Cumulative ns pool workers spent parked between jobs (same gate).
    pub pool_idle_ns: u64,
}

/// Monotonic counters for one daemon lifetime.
pub struct ServeMetrics {
    started: Instant,
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    pub failed: AtomicU64,
    pub cancelled: AtomicU64,
    /// Submissions refused for any reason (the aggregate).
    pub rejected: AtomicU64,
    /// Rejections by cause (each also bumps `rejected`).
    pub rejected_invalid: AtomicU64,
    pub rejected_quota: AtomicU64,
    pub rejected_cost: AtomicU64,
    pub rejected_inline: AtomicU64,
    pub rejected_artifact: AtomicU64,
    /// Optimizer steps applied across all jobs.
    pub steps: AtomicU64,
    /// HTTP requests handled (any endpoint, any status — the aggregate
    /// every labelled route/status cell also bumps).
    pub requests: AtomicU64,
    /// HTTP requests by `(normalized route, status class)`. Both label
    /// values come from small fixed sets (`api::route_label`,
    /// `http::status_class`), so this table tops out at a few dozen rows;
    /// one short lock per request is noise next to the socket work.
    requests_by: Mutex<Vec<(&'static str, &'static str, u64)>>,
    /// Worker threads that panicked while running a job (the job turns
    /// `failed`; the daemon keeps serving).
    pub worker_panics: AtomicU64,
    /// Progress events written to SSE subscribers.
    pub events_streamed: AtomicU64,
    /// Artifact-store cache hits (job admissions and inline dedupe served
    /// from the store without revalidating the payload).
    pub artifact_hits: AtomicU64,
    /// Artifact-store cache misses (hash not stored, or inline payload
    /// seen for the first time).
    pub artifact_misses: AtomicU64,
    /// Artifact-store entries evicted to stay under the byte budget.
    pub artifact_evictions: AtomicU64,
    /// Live SSE subscriber connections (gauge; inc on attach, dec on
    /// detach — signed so a spurious double-decrement shows up as a
    /// negative reading instead of a 2^64 absurdity).
    pub sse_clients: AtomicI64,
}

impl Default for ServeMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl ServeMetrics {
    pub fn new() -> ServeMetrics {
        ServeMetrics {
            started: Instant::now(),
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            cancelled: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            rejected_invalid: AtomicU64::new(0),
            rejected_quota: AtomicU64::new(0),
            rejected_cost: AtomicU64::new(0),
            rejected_inline: AtomicU64::new(0),
            rejected_artifact: AtomicU64::new(0),
            steps: AtomicU64::new(0),
            requests: AtomicU64::new(0),
            requests_by: Mutex::new(Vec::new()),
            worker_panics: AtomicU64::new(0),
            events_streamed: AtomicU64::new(0),
            artifact_hits: AtomicU64::new(0),
            artifact_misses: AtomicU64::new(0),
            artifact_evictions: AtomicU64::new(0),
            sse_clients: AtomicI64::new(0),
        }
    }

    pub fn uptime_s(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Count one handled HTTP request under its normalized route and
    /// status class ("2xx".."5xx"). Bumps the aggregate too.
    pub fn count_request(&self, route: &'static str, class: &'static str) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        let mut t = self.requests_by.lock().unwrap();
        match t.iter_mut().find(|(r, c, _)| *r == route && *c == class) {
            Some(row) => row.2 += 1,
            None => t.push((route, class, 1)),
        }
    }

    /// Render the Prometheus exposition text.
    pub fn render(&self, q: &QueueGauges) -> String {
        fn metric(out: &mut String, name: &str, kind: &str, help: &str, value: f64) {
            out.push_str(&format!(
                "# HELP {name} {help}\n# TYPE {name} {kind}\n{name} {value}\n"
            ));
        }
        let mut out = String::with_capacity(2048);
        metric(
            &mut out,
            "pogo_serve_uptime_seconds",
            "gauge",
            "Seconds since the daemon started.",
            self.uptime_s(),
        );
        metric(
            &mut out,
            "pogo_serve_jobs_submitted_total",
            "counter",
            "Jobs accepted into the queue.",
            self.submitted.load(Ordering::Relaxed) as f64,
        );
        metric(
            &mut out,
            "pogo_serve_jobs_completed_total",
            "counter",
            "Jobs that reached done.",
            self.completed.load(Ordering::Relaxed) as f64,
        );
        metric(
            &mut out,
            "pogo_serve_jobs_failed_total",
            "counter",
            "Jobs that failed.",
            self.failed.load(Ordering::Relaxed) as f64,
        );
        metric(
            &mut out,
            "pogo_serve_jobs_cancelled_total",
            "counter",
            "Jobs cancelled by clients.",
            self.cancelled.load(Ordering::Relaxed) as f64,
        );
        metric(
            &mut out,
            "pogo_serve_jobs_rejected_total",
            "counter",
            "Submissions refused (all causes).",
            self.rejected.load(Ordering::Relaxed) as f64,
        );
        // Admission rejections by cause — one labelled series.
        out.push_str(
            "# HELP pogo_serve_admission_rejected_total Submissions refused by admission \
             control, by cause.\n# TYPE pogo_serve_admission_rejected_total counter\n",
        );
        for (cause, counter) in [
            ("invalid", &self.rejected_invalid),
            ("quota", &self.rejected_quota),
            ("cost", &self.rejected_cost),
            ("inline_bytes", &self.rejected_inline),
            ("artifact_missing", &self.rejected_artifact),
        ] {
            out.push_str(&format!(
                "pogo_serve_admission_rejected_total{{cause=\"{cause}\"}} {}\n",
                counter.load(Ordering::Relaxed)
            ));
        }
        metric(
            &mut out,
            "pogo_serve_steps_total",
            "counter",
            "Optimizer steps applied across all jobs.",
            self.steps.load(Ordering::Relaxed) as f64,
        );
        // HTTP requests — one labelled family, split by normalized route
        // and status class. Sorted so scrapes are deterministic.
        out.push_str(
            "# HELP pogo_serve_http_requests_total HTTP requests handled, by route and \
             status class.\n# TYPE pogo_serve_http_requests_total counter\n",
        );
        let mut rows = self.requests_by.lock().unwrap().clone();
        rows.sort_unstable();
        for (route, class, n) in rows {
            out.push_str(&format!(
                "pogo_serve_http_requests_total{{route=\"{route}\",status=\"{class}\"}} {n}\n"
            ));
        }
        metric(
            &mut out,
            "pogo_serve_worker_panics_total",
            "counter",
            "Worker threads that panicked while running a job.",
            self.worker_panics.load(Ordering::Relaxed) as f64,
        );
        metric(
            &mut out,
            "pogo_serve_artifact_cache_hits_total",
            "counter",
            "Artifact-store lookups served without revalidating the payload.",
            self.artifact_hits.load(Ordering::Relaxed) as f64,
        );
        metric(
            &mut out,
            "pogo_serve_artifact_cache_misses_total",
            "counter",
            "Artifact-store lookups that missed (or first-seen inline payloads).",
            self.artifact_misses.load(Ordering::Relaxed) as f64,
        );
        metric(
            &mut out,
            "pogo_serve_artifact_evictions_total",
            "counter",
            "Artifact-store entries evicted to stay under the byte budget.",
            self.artifact_evictions.load(Ordering::Relaxed) as f64,
        );
        metric(
            &mut out,
            "pogo_serve_sse_events_total",
            "counter",
            "Progress events written to SSE subscribers.",
            self.events_streamed.load(Ordering::Relaxed) as f64,
        );
        metric(
            &mut out,
            "pogo_serve_sse_clients",
            "gauge",
            "Live SSE subscriber connections.",
            self.sse_clients.load(Ordering::Relaxed) as f64,
        );
        metric(
            &mut out,
            "pogo_serve_queue_depth",
            "gauge",
            "Jobs queued and not yet running.",
            q.depth as f64,
        );
        metric(
            &mut out,
            "pogo_serve_jobs_running",
            "gauge",
            "Jobs currently executing.",
            q.running as f64,
        );
        // Retained jobs per state — one labelled series.
        out.push_str(
            "# HELP pogo_serve_jobs Retained jobs by state.\n\
             # TYPE pogo_serve_jobs gauge\n",
        );
        for (state, count) in &q.by_state {
            out.push_str(&format!("pogo_serve_jobs{{state=\"{state}\"}} {count}\n"));
        }
        metric(
            &mut out,
            "pogo_serve_queue_capacity",
            "gauge",
            "Maximum queued-job backlog.",
            q.capacity as f64,
        );
        metric(
            &mut out,
            "pogo_serve_admission_outstanding_cost",
            "gauge",
            "Admitted-but-unfinished B*p*n*steps cost units.",
            q.outstanding_cost as f64,
        );
        metric(&mut out, "pogo_serve_workers", "gauge", "Worker threads.", q.workers as f64);
        // The shared compute pool every serve worker dispatches into
        // (see `util::pool`): mode as a label, so dashboards can tell a
        // `POGO_POOL=spawn` A/B daemon from the resident default.
        out.push_str(&format!(
            "# HELP pogo_serve_pool_workers Resident compute-pool threads \
             (shared across serve workers).\n\
             # TYPE pogo_serve_pool_workers gauge\n\
             pogo_serve_pool_workers{{mode=\"{}\"}} {}\n",
            q.pool_mode, q.pool_workers
        ));
        metric(
            &mut out,
            "pogo_serve_pool_dispatches_total",
            "counter",
            "Parallel dispatches into the shared compute pool.",
            q.pool_dispatches as f64,
        );
        metric(
            &mut out,
            "pogo_serve_pool_busy_seconds_total",
            "counter",
            "Pool worker time spent executing shards (accrues while POGO_OBS is on).",
            q.pool_busy_ns as f64 / 1e9,
        );
        metric(
            &mut out,
            "pogo_serve_pool_idle_seconds_total",
            "counter",
            "Pool worker time spent parked between jobs (accrues while POGO_OBS is on).",
            q.pool_idle_ns as f64 / 1e9,
        );
        let pool_total = q.pool_busy_ns.saturating_add(q.pool_idle_ns);
        metric(
            &mut out,
            "pogo_serve_pool_utilization",
            "gauge",
            "Lifetime fraction of observed pool worker time spent busy.",
            if pool_total == 0 { 0.0 } else { q.pool_busy_ns as f64 / pool_total as f64 },
        );
        // The crate-wide latency histograms (HTTP, queue wait, run time,
        // checkpoint I/O, per-step, pool dispatch) ride the same scrape.
        crate::obs::render_prometheus(&mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gauges() -> QueueGauges {
        QueueGauges {
            depth: 2,
            running: 1,
            capacity: 256,
            workers: 4,
            by_state: vec![
                ("queued", 2),
                ("running", 1),
                ("done", 7),
                ("failed", 0),
                ("cancelled", 1),
            ],
            outstanding_cost: 4800,
            pool_mode: "resident",
            pool_workers: 3,
            pool_dispatches: 42,
            pool_busy_ns: 3_000_000_000,
            pool_idle_ns: 1_000_000_000,
        }
    }

    #[test]
    fn renders_every_series_once() {
        let m = ServeMetrics::new();
        m.submitted.fetch_add(3, Ordering::Relaxed);
        m.steps.fetch_add(100, Ordering::Relaxed);
        m.rejected_quota.fetch_add(2, Ordering::Relaxed);
        m.rejected_cost.fetch_add(1, Ordering::Relaxed);
        m.artifact_hits.fetch_add(5, Ordering::Relaxed);
        m.artifact_misses.fetch_add(2, Ordering::Relaxed);
        m.sse_clients.fetch_add(1, Ordering::Relaxed);
        m.count_request("/metrics", "2xx");
        m.count_request("/metrics", "2xx");
        m.count_request("/v1/jobs", "4xx");
        m.worker_panics.fetch_add(1, Ordering::Relaxed);
        let text = m.render(&gauges());
        for name in [
            "pogo_serve_uptime_seconds",
            "pogo_serve_jobs_submitted_total 3",
            "pogo_serve_steps_total 100",
            "pogo_serve_queue_depth 2",
            "pogo_serve_jobs_running 1",
            "pogo_serve_queue_capacity 256",
            "pogo_serve_workers 4",
            "pogo_serve_admission_rejected_total{cause=\"quota\"} 2",
            "pogo_serve_admission_rejected_total{cause=\"cost\"} 1",
            "pogo_serve_admission_rejected_total{cause=\"inline_bytes\"} 0",
            "pogo_serve_admission_rejected_total{cause=\"artifact_missing\"} 0",
            "pogo_serve_jobs{state=\"done\"} 7",
            "pogo_serve_jobs{state=\"queued\"} 2",
            "pogo_serve_admission_outstanding_cost 4800",
            "pogo_serve_pool_workers{mode=\"resident\"} 3",
            "pogo_serve_pool_dispatches_total 42",
            "pogo_serve_pool_busy_seconds_total 3",
            "pogo_serve_pool_idle_seconds_total 1",
            "pogo_serve_pool_utilization 0.75",
            "pogo_serve_http_requests_total{route=\"/metrics\",status=\"2xx\"} 2",
            "pogo_serve_http_requests_total{route=\"/v1/jobs\",status=\"4xx\"} 1",
            "pogo_serve_worker_panics_total 1",
            "pogo_serve_sse_clients 1",
            "pogo_serve_sse_events_total 0",
            "pogo_serve_artifact_cache_hits_total 5",
            "pogo_serve_artifact_cache_misses_total 2",
            "pogo_serve_artifact_evictions_total 0",
        ] {
            assert!(text.contains(name), "missing {name} in:\n{text}");
        }
        // One TYPE line per series, no duplicates.
        assert_eq!(text.matches("# TYPE pogo_serve_queue_depth").count(), 1);
        assert_eq!(
            text.matches("# TYPE pogo_serve_admission_rejected_total").count(),
            1,
            "labelled series share one TYPE line"
        );
    }
}
