//! # POGO — Proximal One-step Geometric Orthoptimizer, at scale
//!
//! A production-grade reproduction of *"An Embarrassingly Simple Way to
//! Optimize Orthogonal Matrices at Scale"* (Javaloy & Vergari, 2026) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! - **L3 (this crate)** — the training coordinator: parameter store,
//!   shape-grouped batched dispatch of orthogonality-constrained updates,
//!   learning-rate schedulers, early stopping, metrics, experiment drivers
//!   and a CLI. Python is never on this path.
//! - **L2** — JAX compute graphs (`python/compile/`): optimizer steps and
//!   model forward/backward programs, AOT-lowered to HLO text.
//! - **L1** — Pallas kernels (`python/compile/kernels/`): the batched POGO
//!   update as a tiled TPU-style kernel (run under `interpret=True` on the
//!   CPU PJRT client of this image).
//!
//! The crate also contains complete pure-Rust reference implementations of
//! POGO and every baseline orthoptimizer from the paper (RGD-QR, RSDM,
//! Landing, LandingPC, SLPG, unconstrained Adam), built on an in-crate
//! dense linear-algebra substrate — no external BLAS.
//!
//! ## Quickstart
//!
//! Every optimizer is described by one serializable
//! [`OptimizerSpec`](coordinator::OptimizerSpec) (method, lr, base
//! optimizer, λ policy, engine, …) and built through its generic
//! `build::<S>` — the crate's single construction path, at any scalar
//! precision, on either engine. Stepping is fallible: engine errors
//! propagate as `Result` instead of panicking.
//!
//! ```no_run
//! use pogo::coordinator::OptimizerSpec;
//! use pogo::linalg::Mat;
//! use pogo::manifold::stiefel;
//! use pogo::optim::Method;
//! use pogo::rng::Rng;
//!
//! # fn main() -> anyhow::Result<()> {
//! let mut rng = Rng::seed_from_u64(0);
//! // A random point on St(64, 128) and a Euclidean gradient.
//! let mut x = stiefel::random_point(64, 128, &mut rng);
//! let g = Mat::randn(64, 128, &mut rng);
//! let spec = OptimizerSpec::new(Method::Pogo, 0.1);
//! let mut opt = spec.build::<f32>(None, (1, 64, 128))?;
//! opt.step(0, &mut x, &g)?;
//! assert!(stiefel::distance(&x) < 1e-4); // stays on the manifold
//!
//! // Specs round-trip through JSON, so runs are replayable:
//! let text = spec.to_json_string();
//! assert_eq!(OptimizerSpec::from_json(&pogo::util::json::Json::parse(&text)?)?, spec);
//! # Ok(())
//! # }
//! ```
//!
//! For many matrices, a [`ParamStore`](coordinator::ParamStore) groups
//! same-shape parameters and an [`OptimSession`](coordinator::OptimSession)
//! (or the full [`Trainer`](coordinator::Trainer)) drives one batched
//! update per group — the paper's scalability mechanism.
//!
//! The [`serve`] module wraps the whole stack in a resident daemon
//! (`pogo serve`): clients submit serialized job specs over HTTP, a
//! bounded queue schedules them across worker-owned sessions, and
//! results/metrics stream back — optimization as a service.

pub mod artifact;
pub mod bench;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod federate;
pub mod linalg;
pub mod manifold;
pub mod obs;
pub mod optim;
pub mod rng;
pub mod runtime;
pub mod serve;
pub mod testing;
pub mod util;

/// Crate version, re-exported for the CLI `--version` flag.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");

/// Root of the repository, used to locate `artifacts/` in examples/tests.
/// Resolution order: `$POGO_REPO_ROOT`, then the crate manifest dir.
pub fn repo_root() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("POGO_REPO_ROOT") {
        return std::path::PathBuf::from(p);
    }
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

/// Default artifacts directory (`<repo>/artifacts`).
pub fn artifacts_dir() -> std::path::PathBuf {
    repo_root().join("artifacts")
}
