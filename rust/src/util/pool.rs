//! Minimal data-parallel helpers on std::thread::scope.
//!
//! No rayon in the offline registry, so the substrate's parallel-for lives
//! here. Two entry points cover everything the crate needs:
//!
//! - [`parallel_rows`]: shard a row-major output buffer by row ranges and
//!   hand each worker a disjoint `&mut [S]` chunk (used by matmul).
//! - [`parallel_rows_pair`]: the same sharding over two parallel row-major
//!   buffers with different row strides (used by the fused batched step:
//!   the `(B, p, n)` iterate tensor plus a per-matrix `f64` output).
//! - [`parallel_for`]: index-space parallel map collecting results (used by
//!   multi-matrix optimizer dispatch and dataset generation).

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use (min(available_parallelism, 16),
/// overridable via `POGO_THREADS`).
pub fn num_threads() -> usize {
    static CACHED: AtomicUsize = AtomicUsize::new(0);
    let c = CACHED.load(Ordering::Relaxed);
    if c != 0 {
        return c;
    }
    let n = std::env::var("POGO_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&v| v >= 1)
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
        });
    CACHED.store(n, Ordering::Relaxed);
    n
}

/// Split `buf` (a row-major `rows × cols` buffer) into contiguous row-range
/// chunks and run `f(rows_range, chunk)` on each, in parallel.
pub fn parallel_rows<S: Send, F>(buf: &mut [S], rows: usize, cols: usize, f: F)
where
    F: Fn(std::ops::Range<usize>, &mut [S]) + Sync,
{
    assert_eq!(buf.len(), rows * cols);
    let nt = num_threads().min(rows.max(1));
    if nt <= 1 {
        f(0..rows, buf);
        return;
    }
    let per = rows.div_ceil(nt);
    std::thread::scope(|scope| {
        let mut rest = buf;
        let mut r0 = 0;
        while r0 < rows {
            let r1 = (r0 + per).min(rows);
            let take = (r1 - r0) * cols;
            let (chunk, tail) = rest.split_at_mut(take);
            rest = tail;
            let fref = &f;
            let range = r0..r1;
            scope.spawn(move || fref(range, chunk));
            r0 = r1;
        }
    });
}

/// Split two parallel row-major buffers (`a`: `rows × cols_a`, `b`:
/// `rows × cols_b`) into the SAME contiguous row-range chunks and run
/// `f(rows_range, a_chunk, b_chunk)` on each, in parallel. Each worker
/// sees the matching slices of both buffers for its row range.
pub fn parallel_rows_pair<A: Send, B: Send, F>(
    a: &mut [A],
    b: &mut [B],
    rows: usize,
    cols_a: usize,
    cols_b: usize,
    f: F,
) where
    F: Fn(std::ops::Range<usize>, &mut [A], &mut [B]) + Sync,
{
    assert_eq!(a.len(), rows * cols_a);
    assert_eq!(b.len(), rows * cols_b);
    let nt = num_threads().min(rows.max(1));
    if nt <= 1 {
        f(0..rows, a, b);
        return;
    }
    let per = rows.div_ceil(nt);
    std::thread::scope(|scope| {
        let mut rest_a = a;
        let mut rest_b = b;
        let mut r0 = 0;
        while r0 < rows {
            let r1 = (r0 + per).min(rows);
            let (chunk_a, tail_a) = rest_a.split_at_mut((r1 - r0) * cols_a);
            let (chunk_b, tail_b) = rest_b.split_at_mut((r1 - r0) * cols_b);
            rest_a = tail_a;
            rest_b = tail_b;
            let fref = &f;
            let range = r0..r1;
            scope.spawn(move || fref(range, chunk_a, chunk_b));
            r0 = r1;
        }
    });
}

/// Parallel map over `0..n`, preserving order of results.
pub fn parallel_for<T: Send, F>(n: usize, f: F) -> Vec<T>
where
    F: Fn(usize) -> T + Sync,
{
    let nt = num_threads().min(n.max(1));
    if nt <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let per = n.div_ceil(nt);
    std::thread::scope(|scope| {
        let mut rest = out.as_mut_slice();
        let mut i0 = 0;
        while i0 < n {
            let i1 = (i0 + per).min(n);
            let (chunk, tail) = rest.split_at_mut(i1 - i0);
            rest = tail;
            let fref = &f;
            scope.spawn(move || {
                for (off, slot) in chunk.iter_mut().enumerate() {
                    *slot = Some(fref(i0 + off));
                }
            });
            i0 = i1;
        }
    });
    out.into_iter().map(|v| v.expect("worker filled all slots")).collect()
}

/// Parallel for-each over mutable items of a slice (disjoint access).
pub fn parallel_for_each_mut<T: Send, F>(items: &mut [T], f: F)
where
    F: Fn(usize, &mut T) + Sync,
{
    let n = items.len();
    let nt = num_threads().min(n.max(1));
    if nt <= 1 || n <= 1 {
        for (i, it) in items.iter_mut().enumerate() {
            f(i, it);
        }
        return;
    }
    let per = n.div_ceil(nt);
    std::thread::scope(|scope| {
        let mut rest = items;
        let mut i0 = 0;
        while i0 < n {
            let i1 = (i0 + per).min(n);
            let (chunk, tail) = rest.split_at_mut(i1 - i0);
            rest = tail;
            let fref = &f;
            scope.spawn(move || {
                for (off, item) in chunk.iter_mut().enumerate() {
                    fref(i0 + off, item);
                }
            });
            i0 = i1;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallel_rows_covers_all() {
        let rows = 37;
        let cols = 11;
        let mut buf = vec![0usize; rows * cols];
        parallel_rows(&mut buf, rows, cols, |range, chunk| {
            for (ci, r) in range.enumerate() {
                for c in 0..cols {
                    chunk[ci * cols + c] = r * cols + c;
                }
            }
        });
        for (i, &v) in buf.iter().enumerate() {
            assert_eq!(v, i);
        }
    }

    #[test]
    fn parallel_rows_pair_covers_both_buffers() {
        let rows = 29;
        let (ca, cb) = (7, 3);
        let mut a = vec![0usize; rows * ca];
        let mut b = vec![0usize; rows * cb];
        parallel_rows_pair(&mut a, &mut b, rows, ca, cb, |range, ac, bc| {
            for (ci, r) in range.enumerate() {
                for c in 0..ca {
                    ac[ci * ca + c] = r * ca + c;
                }
                for c in 0..cb {
                    bc[ci * cb + c] = r * cb + c;
                }
            }
        });
        assert!(a.iter().enumerate().all(|(i, &v)| v == i));
        assert!(b.iter().enumerate().all(|(i, &v)| v == i));
    }

    #[test]
    fn parallel_for_ordered() {
        let out = parallel_for(100, |i| i * i);
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i * i);
        }
    }

    #[test]
    fn parallel_for_each_mut_touches_all() {
        let mut xs = vec![0i64; 64];
        parallel_for_each_mut(&mut xs, |i, v| *v = i as i64 + 1);
        assert!(xs.iter().enumerate().all(|(i, &v)| v == i as i64 + 1));
    }

    #[test]
    fn empty_inputs_ok() {
        let out: Vec<usize> = parallel_for(0, |i| i);
        assert!(out.is_empty());
        let mut buf: Vec<f32> = vec![];
        parallel_rows(&mut buf, 0, 0, |_, _| {});
    }
}
