//! Data-parallel helpers on a lazily-initialized resident worker pool.
//!
//! No rayon in the offline registry, so the substrate's parallel-for lives
//! here. Four entry points cover everything the crate needs:
//!
//! - [`parallel_rows`]: shard a row-major output buffer by row ranges and
//!   hand each worker a disjoint `&mut [S]` chunk (used by matmul).
//! - [`parallel_rows_pair`]: the same sharding over two parallel row-major
//!   buffers with different row strides (used by the fused batched step:
//!   the `(B, p, n)` iterate tensor plus a per-matrix `f64` output).
//! - [`parallel_for`]: index-space parallel map collecting results (used by
//!   multi-matrix optimizer dispatch and dataset generation).
//! - [`parallel_for_each_mut`]: parallel for-each over a mutable slice.
//!
//! Execution backend: by default, jobs run on a process-global **resident
//! pool** — `num_threads() - 1` workers parked on a condvar, woken with a
//! sharded job descriptor, claiming shard indices off a shared atomic
//! counter while the submitting thread participates, then rendezvousing on
//! a completion barrier. The submitter blocks until the barrier clears, so
//! borrowed closures are sound without `'static` bounds. `POGO_POOL=spawn`
//! (or [`set_pool_mode`]) restores the previous spawn-per-call
//! `std::thread::scope` path for A/B measurement and debugging.
//!
//! Both backends compute the SAME shard geometry (`per = rows.div_ceil(nt)`
//! contiguous row ranges) and run the same closures over the same chunks,
//! so results are bit-identical across resident / spawn / serial — pinned
//! by `tests/pool_parity.rs`.

use std::any::Any;
use std::cell::Cell;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock};
use std::time::Instant;

/// Number of worker threads to use (min(available_parallelism, 16),
/// overridable via `POGO_THREADS`). The environment read is cached after
/// the first call; tests use [`set_num_threads`] / [`refresh_num_threads`]
/// instead of mutating the environment mid-process.
pub fn num_threads() -> usize {
    let o = THREADS_OVERRIDE.load(Ordering::Relaxed);
    if o != 0 {
        return o;
    }
    let c = CACHED.load(Ordering::Relaxed);
    if c != 0 {
        return c;
    }
    let n = threads_from_env();
    CACHED.store(n, Ordering::Relaxed);
    n
}

static CACHED: AtomicUsize = AtomicUsize::new(0);
/// In-process override (0 = none). Takes precedence over the cached env read.
static THREADS_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

fn threads_from_env() -> usize {
    std::env::var("POGO_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&v| v >= 1)
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
        })
}

/// Override the thread budget in-process (`None` clears the override and
/// falls back to the cached `POGO_THREADS` read). Used by parity tests and
/// benches to pin a serial (`Some(1)`) or fixed-width run without racing on
/// process-global environment variables.
pub fn set_num_threads(n: Option<usize>) {
    THREADS_OVERRIDE.store(n.unwrap_or(0), Ordering::Relaxed);
}

/// Drop the cached `POGO_THREADS` read and re-read the environment. Returns
/// the refreshed value. Without this, the first `num_threads()` call latches
/// the env value for the process lifetime.
pub fn refresh_num_threads() -> usize {
    CACHED.store(0, Ordering::Relaxed);
    num_threads()
}

/// Which execution backend the four `parallel_*` entry points dispatch to.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum PoolMode {
    /// Process-global resident worker pool (the default).
    Resident,
    /// Fresh `std::thread::scope` spawn per call (the pre-pool behavior;
    /// `POGO_POOL=spawn`).
    Spawn,
}

impl PoolMode {
    pub fn name(self) -> &'static str {
        match self {
            PoolMode::Resident => "resident",
            PoolMode::Spawn => "spawn",
        }
    }
}

/// 0 = no override, 1 = resident, 2 = spawn.
static MODE_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Current pool mode: the in-process override if set, else `POGO_POOL`
/// (read once; `spawn` selects the scoped-spawn path, anything else the
/// resident pool).
pub fn pool_mode() -> PoolMode {
    match MODE_OVERRIDE.load(Ordering::Relaxed) {
        1 => return PoolMode::Resident,
        2 => return PoolMode::Spawn,
        _ => {}
    }
    static ENV: OnceLock<PoolMode> = OnceLock::new();
    *ENV.get_or_init(|| match std::env::var("POGO_POOL").ok().as_deref() {
        Some("spawn") => PoolMode::Spawn,
        _ => PoolMode::Resident,
    })
}

/// Override the pool mode in-process (`None` clears the override). Used by
/// parity tests and the dispatch bench to A/B both backends in one process.
pub fn set_pool_mode(mode: Option<PoolMode>) {
    let v = match mode {
        None => 0,
        Some(PoolMode::Resident) => 1,
        Some(PoolMode::Spawn) => 2,
    };
    MODE_OVERRIDE.store(v, Ordering::Relaxed);
}

/// Snapshot of the resident pool for `/metrics` and benches.
#[derive(Clone, Copy, Debug)]
pub struct PoolStats {
    /// Active backend name (`"resident"` or `"spawn"`).
    pub mode: &'static str,
    /// Resident workers spawned so far (0 until the pool first runs a job).
    pub resident_workers: usize,
    /// Jobs dispatched through the resident pool since process start.
    pub dispatches: u64,
    /// Cumulative nanoseconds resident workers spent claiming shards.
    /// Accumulated only while observability is enabled (`POGO_OBS`), so it
    /// reads 0 when the clock reads on the hot path are switched off.
    pub busy_ns: u64,
    /// Cumulative nanoseconds resident workers spent parked between jobs.
    /// Same `POGO_OBS` gating as `busy_ns`.
    pub idle_ns: u64,
}

/// Stats for the process-global pool. Does not force pool initialization.
pub fn pool_stats() -> PoolStats {
    let (resident_workers, dispatches, busy_ns, idle_ns) = match POOL.get() {
        Some(p) => (
            p.spawned.load(Ordering::Relaxed),
            p.dispatches.load(Ordering::Relaxed),
            p.shared.busy_ns.load(Ordering::Relaxed),
            p.shared.idle_ns.load(Ordering::Relaxed),
        ),
        None => (0, 0, 0, 0),
    };
    PoolStats { mode: pool_mode().name(), resident_workers, dispatches, busy_ns, idle_ns }
}

/// Eagerly spawn the resident workers (normally they spawn on first job).
/// `pogo serve` calls this at queue start so all serve workers share one
/// fully-warmed pool instead of each paying first-dispatch spawn cost.
/// Returns the post-warmup stats.
pub fn warm_pool() -> PoolStats {
    if pool_mode() == PoolMode::Resident && num_threads() > 1 {
        let p = pool();
        let _guard = lock(&p.run_lock);
        p.grow_locked(num_threads().saturating_sub(1));
    }
    pool_stats()
}

// ---------------------------------------------------------------------------
// Resident pool internals.
// ---------------------------------------------------------------------------

/// A posted job: a lifetime-erased pointer to the submitter's sharded
/// closure plus the shard count. Sound because the submitter blocks in
/// `Pool::run` until every worker has passed the completion barrier, so the
/// closure outlives every dereference.
#[derive(Clone, Copy)]
struct Job {
    f: *const (dyn Fn(usize) + Sync),
    shards: usize,
}

// SAFETY: the pointee is `Sync` and outlives the job (see `Job` docs).
unsafe impl Send for Job {}

struct PoolState {
    /// Bumped on every post; workers compare against their last-seen value
    /// so a job is claimed at most once per worker.
    epoch: u64,
    job: Option<Job>,
    /// Workers that have not yet passed the completion barrier for the
    /// current job. The submitter waits for 0.
    active: usize,
    panicked: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    /// Workers park here between jobs.
    work_cv: Condvar,
    /// The submitter parks here until `active == 0`.
    done_cv: Condvar,
    /// Shard claim counter, reset before each post.
    next: AtomicUsize,
    /// Cumulative worker claim-loop nanoseconds (observability only; stays
    /// 0 while `POGO_OBS` is off so workers never read the clock).
    busy_ns: AtomicU64,
    /// Cumulative worker parked nanoseconds (same gating as `busy_ns`).
    idle_ns: AtomicU64,
}

struct Pool {
    shared: Arc<PoolShared>,
    /// Serializes jobs: concurrent submitters (e.g. serve workers) queue
    /// here, so pool threads are never oversubscribed across jobs.
    run_lock: Mutex<()>,
    /// Workers spawned so far; grows lazily toward `num_threads() - 1`.
    spawned: AtomicUsize,
    dispatches: AtomicU64,
}

static POOL: OnceLock<Pool> = OnceLock::new();

fn pool() -> &'static Pool {
    POOL.get_or_init(|| Pool {
        shared: Arc::new(PoolShared {
            state: Mutex::new(PoolState { epoch: 0, job: None, active: 0, panicked: false }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            next: AtomicUsize::new(0),
            busy_ns: AtomicU64::new(0),
            idle_ns: AtomicU64::new(0),
        }),
        run_lock: Mutex::new(()),
        spawned: AtomicUsize::new(0),
        dispatches: AtomicU64::new(0),
    })
}

/// Mutex locks in the pool never run user code while held, so poisoning can
/// only come from an unwinding assertion in pool bookkeeping itself; keep
/// going rather than cascading panics across unrelated jobs.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

thread_local! {
    static IS_POOL_WORKER: Cell<bool> = const { Cell::new(false) };
}

/// True on resident pool worker threads. Entry points use this to run
/// nested parallel calls inline (serially) instead of deadlocking on the
/// pool's run lock.
pub fn is_pool_worker() -> bool {
    IS_POOL_WORKER.with(|c| c.get())
}

fn worker_loop(shared: Arc<PoolShared>) {
    IS_POOL_WORKER.with(|c| c.set(true));
    let mut seen_epoch = 0u64;
    loop {
        // Observability: time the park (idle) and the claim loop (busy).
        // Gated so a disabled run never reads the clock on this path.
        let parked_at = crate::obs::enabled().then(Instant::now);
        let job = {
            let mut st = lock(&shared.state);
            loop {
                if st.epoch != seen_epoch {
                    if let Some(job) = st.job {
                        seen_epoch = st.epoch;
                        break job;
                    }
                }
                st = shared.work_cv.wait(st).unwrap_or_else(|e| e.into_inner());
            }
        };
        let claimed_at = parked_at.map(|t| {
            shared.idle_ns.fetch_add(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
            Instant::now()
        });
        // SAFETY: the submitter blocks until this worker passes the barrier
        // below, so the closure behind `job.f` is alive for the whole claim
        // loop.
        let f = unsafe { &*job.f };
        let result = catch_unwind(AssertUnwindSafe(|| loop {
            let i = shared.next.fetch_add(1, Ordering::Relaxed);
            if i >= job.shards {
                break;
            }
            f(i);
        }));
        if let Some(t) = claimed_at {
            shared.busy_ns.fetch_add(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
        }
        let mut st = lock(&shared.state);
        if result.is_err() {
            st.panicked = true;
        }
        st.active -= 1;
        if st.active == 0 {
            shared.done_cv.notify_all();
        }
    }
}

impl Pool {
    /// Spawn workers up to `target`. Caller must hold `run_lock` (workers
    /// spawned mid-job would desync the barrier count).
    fn grow_locked(&self, target: usize) {
        let cur = self.spawned.load(Ordering::Relaxed);
        for i in cur..target {
            let shared = Arc::clone(&self.shared);
            std::thread::Builder::new()
                .name(format!("pogo-pool-{i}"))
                .spawn(move || worker_loop(shared))
                .expect("failed to spawn pogo pool worker");
        }
        if target > cur {
            self.spawned.store(target, Ordering::Relaxed);
        }
    }

    /// Run `f(0), f(1), …, f(shards-1)` across the pool workers plus the
    /// calling thread, blocking until all shards complete. Panics (after
    /// the barrier) if any shard panicked.
    fn run(&self, shards: usize, f: &(dyn Fn(usize) + Sync)) {
        // Observability: how long this submitter queued behind other jobs
        // (`run_lock` acquisition) and how long the dispatched job took to
        // clear the barrier. Both clock reads are gated on `POGO_OBS`.
        let wait_from = crate::obs::enabled().then(Instant::now);
        let _guard = lock(&self.run_lock);
        let run_from = wait_from.map(|t| {
            crate::obs::hist::POOL_DISPATCH_WAIT_SECONDS.hist0().record_since(t);
            Instant::now()
        });
        self.dispatches.fetch_add(1, Ordering::Relaxed);
        self.grow_locked(num_threads().saturating_sub(1));
        let workers = self.spawned.load(Ordering::Relaxed);
        if workers == 0 {
            for i in 0..shards {
                f(i);
            }
            if let Some(t) = run_from {
                crate::obs::hist::POOL_RUN_SECONDS.hist0().record_since(t);
            }
            return;
        }
        {
            let mut st = lock(&self.shared.state);
            self.shared.next.store(0, Ordering::Relaxed);
            st.job = Some(Job { f: f as *const _, shards });
            st.epoch = st.epoch.wrapping_add(1);
            st.active = workers;
            st.panicked = false;
            self.shared.work_cv.notify_all();
        }
        // The submitter claims shards alongside the workers. A panicking
        // shard must not unwind past the barrier: workers may still hold
        // `f`, so catch, rendezvous, then resume.
        let caller = catch_unwind(AssertUnwindSafe(|| loop {
            let i = self.shared.next.fetch_add(1, Ordering::Relaxed);
            if i >= shards {
                break;
            }
            f(i);
        }));
        let worker_panicked = {
            let mut st = lock(&self.shared.state);
            while st.active != 0 {
                st = self.shared.done_cv.wait(st).unwrap_or_else(|e| e.into_inner());
            }
            st.job = None;
            st.panicked
        };
        if let Some(t) = run_from {
            crate::obs::hist::POOL_RUN_SECONDS.hist0().record_since(t);
        }
        if let Err(p) = caller {
            resume_unwind(p);
        }
        if worker_panicked {
            panic!("pogo pool worker panicked during a parallel job");
        }
    }
}

/// Send+Sync wrapper for a raw base pointer whose disjoint shard ranges are
/// written by different workers (same contract rayon's internal `SendPtr`
/// relies on).
struct SendPtr<T>(*mut T);

// SAFETY: shards index disjoint regions; `T: Send` moves element access
// across threads, never shares an element.
unsafe impl<T: Send> Send for SendPtr<T> {}
unsafe impl<T: Send> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    fn get(&self) -> *mut T {
        self.0
    }
}

/// Dispatch `shards` shard indices through the current pool backend:
/// resident workers + caller in resident mode, one scoped thread per shard
/// in spawn mode, inline when single-threaded or already on a pool worker.
/// This is the raw primitive the dispatch-latency bench measures.
pub fn parallel_shards<F>(shards: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    if shards == 0 {
        return;
    }
    if num_threads() <= 1 || is_pool_worker() {
        for i in 0..shards {
            f(i);
        }
        return;
    }
    match pool_mode() {
        PoolMode::Resident => pool().run(shards, &f),
        PoolMode::Spawn => {
            std::thread::scope(|scope| {
                for i in 0..shards {
                    let fref = &f;
                    scope.spawn(move || fref(i));
                }
            });
        }
    }
}

// ---------------------------------------------------------------------------
// Entry points. Shard geometry is computed ONCE here and shared verbatim by
// the resident and spawn backends: `per = rows.div_ceil(nt)` contiguous row
// ranges, `nt = num_threads().min(rows)`. Keeping the geometry identical is
// what makes backend choice invisible to results (bit-exactness).
// ---------------------------------------------------------------------------

/// Split `buf` (a row-major `rows × cols` buffer) into contiguous row-range
/// chunks and run `f(rows_range, chunk)` on each, in parallel.
pub fn parallel_rows<S: Send, F>(buf: &mut [S], rows: usize, cols: usize, f: F)
where
    F: Fn(std::ops::Range<usize>, &mut [S]) + Sync,
{
    assert_eq!(buf.len(), rows * cols);
    let nt = num_threads().min(rows.max(1));
    if nt <= 1 || is_pool_worker() {
        f(0..rows, buf);
        return;
    }
    let per = rows.div_ceil(nt);
    match pool_mode() {
        PoolMode::Spawn => parallel_rows_spawn(buf, rows, cols, per, &f),
        PoolMode::Resident => {
            let shards = rows.div_ceil(per);
            let base = SendPtr(buf.as_mut_ptr());
            pool().run(shards, &|s| {
                let r0 = s * per;
                let r1 = (r0 + per).min(rows);
                // SAFETY: shard row ranges are disjoint, so the chunks never
                // alias; the buffer outlives the blocking `run` call.
                let chunk = unsafe {
                    std::slice::from_raw_parts_mut(base.get().add(r0 * cols), (r1 - r0) * cols)
                };
                f(r0..r1, chunk);
            });
        }
    }
}

fn parallel_rows_spawn<S: Send, F>(buf: &mut [S], rows: usize, cols: usize, per: usize, f: &F)
where
    F: Fn(std::ops::Range<usize>, &mut [S]) + Sync,
{
    std::thread::scope(|scope| {
        let mut rest = buf;
        let mut r0 = 0;
        while r0 < rows {
            let r1 = (r0 + per).min(rows);
            let take = (r1 - r0) * cols;
            let (chunk, tail) = rest.split_at_mut(take);
            rest = tail;
            let range = r0..r1;
            scope.spawn(move || f(range, chunk));
            r0 = r1;
        }
    });
}

/// Split two parallel row-major buffers (`a`: `rows × cols_a`, `b`:
/// `rows × cols_b`) into the SAME contiguous row-range chunks and run
/// `f(rows_range, a_chunk, b_chunk)` on each, in parallel. Each worker
/// sees the matching slices of both buffers for its row range.
pub fn parallel_rows_pair<A: Send, B: Send, F>(
    a: &mut [A],
    b: &mut [B],
    rows: usize,
    cols_a: usize,
    cols_b: usize,
    f: F,
) where
    F: Fn(std::ops::Range<usize>, &mut [A], &mut [B]) + Sync,
{
    assert_eq!(a.len(), rows * cols_a);
    assert_eq!(b.len(), rows * cols_b);
    let nt = num_threads().min(rows.max(1));
    if nt <= 1 || is_pool_worker() {
        f(0..rows, a, b);
        return;
    }
    let per = rows.div_ceil(nt);
    match pool_mode() {
        PoolMode::Spawn => parallel_rows_pair_spawn(a, b, rows, cols_a, cols_b, per, &f),
        PoolMode::Resident => {
            let shards = rows.div_ceil(per);
            let base_a = SendPtr(a.as_mut_ptr());
            let base_b = SendPtr(b.as_mut_ptr());
            pool().run(shards, &|s| {
                let r0 = s * per;
                let r1 = (r0 + per).min(rows);
                // SAFETY: disjoint row ranges in both buffers; both outlive
                // the blocking `run` call.
                let (chunk_a, chunk_b) = unsafe {
                    (
                        std::slice::from_raw_parts_mut(
                            base_a.get().add(r0 * cols_a),
                            (r1 - r0) * cols_a,
                        ),
                        std::slice::from_raw_parts_mut(
                            base_b.get().add(r0 * cols_b),
                            (r1 - r0) * cols_b,
                        ),
                    )
                };
                f(r0..r1, chunk_a, chunk_b);
            });
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn parallel_rows_pair_spawn<A: Send, B: Send, F>(
    a: &mut [A],
    b: &mut [B],
    rows: usize,
    cols_a: usize,
    cols_b: usize,
    per: usize,
    f: &F,
) where
    F: Fn(std::ops::Range<usize>, &mut [A], &mut [B]) + Sync,
{
    std::thread::scope(|scope| {
        let mut rest_a = a;
        let mut rest_b = b;
        let mut r0 = 0;
        while r0 < rows {
            let r1 = (r0 + per).min(rows);
            let (chunk_a, tail_a) = rest_a.split_at_mut((r1 - r0) * cols_a);
            let (chunk_b, tail_b) = rest_b.split_at_mut((r1 - r0) * cols_b);
            rest_a = tail_a;
            rest_b = tail_b;
            let range = r0..r1;
            scope.spawn(move || f(range, chunk_a, chunk_b));
            r0 = r1;
        }
    });
}

/// Parallel map over `0..n`, preserving order of results.
pub fn parallel_for<T: Send, F>(n: usize, f: F) -> Vec<T>
where
    F: Fn(usize) -> T + Sync,
{
    let nt = num_threads().min(n.max(1));
    if nt <= 1 || n <= 1 || is_pool_worker() {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let per = n.div_ceil(nt);
    match pool_mode() {
        PoolMode::Spawn => parallel_for_spawn(&mut out, n, per, &f),
        PoolMode::Resident => {
            let shards = n.div_ceil(per);
            let base = SendPtr(out.as_mut_ptr());
            pool().run(shards, &|s| {
                let i0 = s * per;
                let i1 = (i0 + per).min(n);
                // SAFETY: disjoint index ranges; `out` outlives the blocking
                // `run` call.
                let chunk =
                    unsafe { std::slice::from_raw_parts_mut(base.get().add(i0), i1 - i0) };
                for (off, slot) in chunk.iter_mut().enumerate() {
                    *slot = Some(f(i0 + off));
                }
            });
        }
    }
    out.into_iter().map(|v| v.expect("worker filled all slots")).collect()
}

fn parallel_for_spawn<T: Send, F>(out: &mut [Option<T>], n: usize, per: usize, f: &F)
where
    F: Fn(usize) -> T + Sync,
{
    std::thread::scope(|scope| {
        let mut rest = out;
        let mut i0 = 0;
        while i0 < n {
            let i1 = (i0 + per).min(n);
            let (chunk, tail) = rest.split_at_mut(i1 - i0);
            rest = tail;
            scope.spawn(move || {
                for (off, slot) in chunk.iter_mut().enumerate() {
                    *slot = Some(f(i0 + off));
                }
            });
            i0 = i1;
        }
    });
}

/// Parallel for-each over mutable items of a slice (disjoint access).
pub fn parallel_for_each_mut<T: Send, F>(items: &mut [T], f: F)
where
    F: Fn(usize, &mut T) + Sync,
{
    let n = items.len();
    let nt = num_threads().min(n.max(1));
    if nt <= 1 || n <= 1 || is_pool_worker() {
        for (i, it) in items.iter_mut().enumerate() {
            f(i, it);
        }
        return;
    }
    let per = n.div_ceil(nt);
    match pool_mode() {
        PoolMode::Spawn => parallel_for_each_mut_spawn(items, n, per, &f),
        PoolMode::Resident => {
            let shards = n.div_ceil(per);
            let base = SendPtr(items.as_mut_ptr());
            pool().run(shards, &|s| {
                let i0 = s * per;
                let i1 = (i0 + per).min(n);
                // SAFETY: disjoint index ranges; `items` outlives the
                // blocking `run` call.
                let chunk =
                    unsafe { std::slice::from_raw_parts_mut(base.get().add(i0), i1 - i0) };
                for (off, item) in chunk.iter_mut().enumerate() {
                    f(i0 + off, item);
                }
            });
        }
    }
}

fn parallel_for_each_mut_spawn<T: Send, F>(items: &mut [T], n: usize, per: usize, f: &F)
where
    F: Fn(usize, &mut T) + Sync,
{
    std::thread::scope(|scope| {
        let mut rest = items;
        let mut i0 = 0;
        while i0 < n {
            let i1 = (i0 + per).min(n);
            let (chunk, tail) = rest.split_at_mut(i1 - i0);
            rest = tail;
            scope.spawn(move || {
                for (off, item) in chunk.iter_mut().enumerate() {
                    f(i0 + off, item);
                }
            });
            i0 = i1;
        }
    });
}

// ---------------------------------------------------------------------------
// Keyed thread-local scratch arena. Resident workers persist across steps,
// so scratch parked here is allocated once per (worker, key) and reused —
// the allocation-free steady state. Under `POGO_POOL=spawn`, threads die
// after every call and the arena re-allocates each step (that delta is
// exactly what `benches/pool_dispatch.rs` measures).
// ---------------------------------------------------------------------------

thread_local! {
    static SCRATCH_ARENA: std::cell::RefCell<
        std::collections::HashMap<(std::any::TypeId, usize, usize), Box<dyn Any>>,
    > = std::cell::RefCell::new(std::collections::HashMap::new());
}

/// Run `f` with this thread's scratch slot for `(V, k1, k2)`, creating it
/// with `make` on first use. The slot is taken OUT of the arena while `f`
/// runs (no `RefCell` borrow is held), so `f` may itself use the arena for
/// a different key — e.g. a fused step holding its `StepScratch` while the
/// quartic solve inside it borrows a coefficient scratch. Steady state does
/// not allocate: take + put-back reuse the map's existing capacity.
pub fn with_scratch<V: Any, R>(
    k1: usize,
    k2: usize,
    make: impl FnOnce() -> V,
    f: impl FnOnce(&mut V) -> R,
) -> R {
    let key = (std::any::TypeId::of::<V>(), k1, k2);
    let mut slot = SCRATCH_ARENA
        .with(|cell| cell.borrow_mut().remove(&key))
        .unwrap_or_else(|| Box::new(make()) as Box<dyn Any>);
    let v = slot.downcast_mut::<V>().expect("scratch slot holds the keyed type");
    let out = f(v);
    SCRATCH_ARENA.with(|cell| cell.borrow_mut().insert(key, slot));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Serializes tests that flip process-global overrides (shared with
    /// the obs tests, which toggle the same observability switch).
    use crate::obs::TEST_OVERRIDE_LOCK as OVERRIDE_LOCK;

    #[test]
    fn parallel_rows_covers_all() {
        let rows = 37;
        let cols = 11;
        let mut buf = vec![0usize; rows * cols];
        parallel_rows(&mut buf, rows, cols, |range, chunk| {
            for (ci, r) in range.enumerate() {
                for c in 0..cols {
                    chunk[ci * cols + c] = r * cols + c;
                }
            }
        });
        for (i, &v) in buf.iter().enumerate() {
            assert_eq!(v, i);
        }
    }

    #[test]
    fn parallel_rows_pair_covers_both_buffers() {
        let rows = 29;
        let (ca, cb) = (7, 3);
        let mut a = vec![0usize; rows * ca];
        let mut b = vec![0usize; rows * cb];
        parallel_rows_pair(&mut a, &mut b, rows, ca, cb, |range, ac, bc| {
            for (ci, r) in range.enumerate() {
                for c in 0..ca {
                    ac[ci * ca + c] = r * ca + c;
                }
                for c in 0..cb {
                    bc[ci * cb + c] = r * cb + c;
                }
            }
        });
        assert!(a.iter().enumerate().all(|(i, &v)| v == i));
        assert!(b.iter().enumerate().all(|(i, &v)| v == i));
    }

    #[test]
    fn parallel_for_ordered() {
        let out = parallel_for(100, |i| i * i);
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i * i);
        }
    }

    #[test]
    fn parallel_for_each_mut_touches_all() {
        let mut xs = vec![0i64; 64];
        parallel_for_each_mut(&mut xs, |i, v| *v = i as i64 + 1);
        assert!(xs.iter().enumerate().all(|(i, &v)| v == i as i64 + 1));
    }

    #[test]
    fn empty_inputs_ok() {
        let out: Vec<usize> = parallel_for(0, |i| i);
        assert!(out.is_empty());
        let mut buf: Vec<f32> = vec![];
        parallel_rows(&mut buf, 0, 0, |_, _| {});
        parallel_shards(0, |_| unreachable!("no shards to run"));
    }

    #[test]
    fn parallel_shards_covers_every_index_in_both_modes() {
        let _g = OVERRIDE_LOCK.lock().unwrap();
        for mode in [PoolMode::Resident, PoolMode::Spawn] {
            set_pool_mode(Some(mode));
            let hits: Vec<AtomicUsize> = (0..23).map(|_| AtomicUsize::new(0)).collect();
            parallel_shards(hits.len(), |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "every shard runs exactly once under {}",
                mode.name()
            );
        }
        set_pool_mode(None);
    }

    #[test]
    fn spawn_and_resident_fill_identically() {
        let _g = OVERRIDE_LOCK.lock().unwrap();
        let run = |mode: PoolMode| {
            set_pool_mode(Some(mode));
            let rows = 41;
            let cols = 5;
            let mut buf = vec![0usize; rows * cols];
            parallel_rows(&mut buf, rows, cols, |range, chunk| {
                for (ci, r) in range.enumerate() {
                    for c in 0..cols {
                        chunk[ci * cols + c] = r * 1000 + c;
                    }
                }
            });
            buf
        };
        let resident = run(PoolMode::Resident);
        let spawn = run(PoolMode::Spawn);
        set_pool_mode(None);
        assert_eq!(resident, spawn);
    }

    #[test]
    fn num_threads_override_and_refresh() {
        let _g = OVERRIDE_LOCK.lock().unwrap();
        let baseline = num_threads();
        set_num_threads(Some(3));
        assert_eq!(num_threads(), 3, "override takes precedence over the cache");
        set_num_threads(None);
        assert_eq!(num_threads(), baseline, "clearing the override restores the cached value");
        // Regression for the latched-forever cache: a changed POGO_THREADS
        // is invisible to num_threads() until refresh_num_threads().
        let saved = std::env::var("POGO_THREADS").ok();
        std::env::set_var("POGO_THREADS", "2");
        assert_eq!(num_threads(), baseline, "cache still serves the stale value");
        assert_eq!(refresh_num_threads(), 2, "refresh re-reads the environment");
        match saved {
            Some(v) => std::env::set_var("POGO_THREADS", v),
            None => std::env::remove_var("POGO_THREADS"),
        }
        refresh_num_threads();
    }

    #[test]
    fn nested_parallel_calls_run_inline_on_workers() {
        let _g = OVERRIDE_LOCK.lock().unwrap();
        set_pool_mode(Some(PoolMode::Resident));
        let done: Vec<AtomicUsize> = (0..8).map(|_| AtomicUsize::new(0)).collect();
        parallel_shards(done.len(), |i| {
            // A nested call from inside a pool job must not re-enter the
            // pool (that would deadlock on the run lock); it runs inline.
            let mut inner = vec![0usize; 12];
            parallel_rows(&mut inner, 4, 3, |range, chunk| {
                for (ci, r) in range.enumerate() {
                    for c in 0..3 {
                        chunk[ci * 3 + c] = r * 3 + c;
                    }
                }
            });
            assert!(inner.iter().enumerate().all(|(k, &v)| v == k));
            done[i].fetch_add(1, Ordering::Relaxed);
        });
        set_pool_mode(None);
        assert!(done.iter().all(|d| d.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn worker_panic_propagates_to_submitter() {
        let _g = OVERRIDE_LOCK.lock().unwrap();
        set_pool_mode(Some(PoolMode::Resident));
        let res = catch_unwind(AssertUnwindSafe(|| {
            parallel_shards(16, |i| {
                if i == 7 {
                    panic!("shard 7 exploded");
                }
            });
        }));
        assert!(res.is_err(), "a panicking shard must panic the submitter");
        // The pool stays usable after a panicked job.
        let hits = AtomicUsize::new(0);
        parallel_shards(4, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        set_pool_mode(None);
        assert_eq!(hits.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn scratch_arena_reuses_slots_per_key() {
        let first = with_scratch(4, 2, || vec![0u8; 8], |v| {
            v[0] = 9;
            v.as_ptr() as usize
        });
        let second = with_scratch(4, 2, || vec![0u8; 8], |v| {
            assert_eq!(v[0], 9, "slot state persists across borrows");
            v.as_ptr() as usize
        });
        assert_eq!(first, second, "same key reuses the same allocation");
        with_scratch(8, 2, || vec![1u8; 8], |v| {
            assert_eq!(v[0], 1, "a different key gets a fresh slot");
        });
    }

    #[test]
    fn pool_stats_reports_mode_and_dispatches() {
        let _g = OVERRIDE_LOCK.lock().unwrap();
        set_pool_mode(Some(PoolMode::Resident));
        let before = pool_stats().dispatches;
        parallel_shards(8, |_| {});
        let stats = warm_pool();
        assert_eq!(stats.mode, "resident");
        if num_threads() > 1 {
            assert!(stats.dispatches > before, "resident dispatch bumps the counter");
            assert!(stats.resident_workers >= 1, "warming spawns resident workers");
        }
        set_pool_mode(None);
    }

    #[test]
    fn pool_stats_accumulates_idle_and_busy_when_observed() {
        let _g = OVERRIDE_LOCK.lock().unwrap();
        if num_threads() <= 1 {
            return;
        }
        set_pool_mode(Some(PoolMode::Resident));
        // Job A parks the workers afterwards; the gap before job B is the
        // idle time each worker records when it wakes for B. Workers record
        // idle BEFORE entering the claim loop, and the submitter only
        // returns once every worker passed the barrier, so by the time
        // job B returns the idle from the inter-job park is visible.
        // Retried for scheduler-timing slack, not correctness.
        crate::obs::set_enabled(Some(true));
        let mut grew = false;
        for _ in 0..20 {
            parallel_shards(num_threads() * 2, |_| {
                std::thread::sleep(std::time::Duration::from_micros(100));
            });
            let before = pool_stats().idle_ns;
            std::thread::sleep(std::time::Duration::from_millis(2));
            parallel_shards(num_threads() * 2, |_| {});
            if pool_stats().idle_ns > before {
                grew = true;
                break;
            }
        }
        crate::obs::set_enabled(None);
        set_pool_mode(None);
        assert!(grew, "workers parked between jobs accrue idle time");
    }
}
