//! Standard base64 (RFC 4648, `+/` alphabet, `=` padding) — carries the
//! v2 serve API's binary payloads (inline problem matrices in, final
//! iterates out) through JSON without a dependency.

const ALPHABET: &[u8; 64] = b"ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz0123456789+/";

/// Encode bytes as standard base64 with padding.
pub fn encode(bytes: &[u8]) -> String {
    let mut out = String::with_capacity(bytes.len().div_ceil(3) * 4);
    for chunk in bytes.chunks(3) {
        let b0 = chunk[0] as u32;
        let b1 = chunk.get(1).copied().unwrap_or(0) as u32;
        let b2 = chunk.get(2).copied().unwrap_or(0) as u32;
        let word = (b0 << 16) | (b1 << 8) | b2;
        out.push(ALPHABET[(word >> 18) as usize & 63] as char);
        out.push(ALPHABET[(word >> 12) as usize & 63] as char);
        out.push(if chunk.len() > 1 { ALPHABET[(word >> 6) as usize & 63] as char } else { '=' });
        out.push(if chunk.len() > 2 { ALPHABET[word as usize & 63] as char } else { '=' });
    }
    out
}

fn value_of(c: u8) -> Option<u32> {
    Some(match c {
        b'A'..=b'Z' => (c - b'A') as u32,
        b'a'..=b'z' => (c - b'a') as u32 + 26,
        b'0'..=b'9' => (c - b'0') as u32 + 52,
        b'+' => 62,
        b'/' => 63,
        _ => return None,
    })
}

/// Decode standard base64. Padding is required to a 4-char multiple;
/// whitespace and other characters are rejected (payloads travel inside
/// JSON strings, so there is no line wrapping to tolerate).
pub fn decode(text: &str) -> Result<Vec<u8>, String> {
    let b = text.as_bytes();
    if b.len() % 4 != 0 {
        return Err(format!("base64 length {} is not a multiple of 4", b.len()));
    }
    let mut out = Vec::with_capacity(b.len() / 4 * 3);
    for (i, quad) in b.chunks(4).enumerate() {
        let is_last = (i + 1) * 4 == b.len();
        let pad = quad.iter().filter(|&&c| c == b'=').count();
        if pad > 2 || (pad > 0 && !is_last) {
            return Err("misplaced base64 padding".to_string());
        }
        // '=' may only appear as a suffix of the final quad.
        if pad > 0 && (quad[3] != b'=' || (pad == 2 && quad[2] != b'=')) {
            return Err("misplaced base64 padding".to_string());
        }
        let mut word = 0u32;
        for &c in &quad[..4 - pad] {
            let v = value_of(c)
                .ok_or_else(|| format!("invalid base64 character '{}'", c as char))?;
            word = (word << 6) | v;
        }
        word <<= 6 * pad as u32;
        out.push((word >> 16) as u8);
        if pad < 2 {
            out.push((word >> 8) as u8);
        }
        if pad < 1 {
            out.push(word as u8);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rfc4648_vectors() {
        for (plain, coded) in [
            ("", ""),
            ("f", "Zg=="),
            ("fo", "Zm8="),
            ("foo", "Zm9v"),
            ("foob", "Zm9vYg=="),
            ("fooba", "Zm9vYmE="),
            ("foobar", "Zm9vYmFy"),
        ] {
            assert_eq!(encode(plain.as_bytes()), coded, "{plain}");
            assert_eq!(decode(coded).unwrap(), plain.as_bytes(), "{coded}");
        }
    }

    #[test]
    fn binary_roundtrip() {
        let bytes: Vec<u8> = (0..=255u8).cycle().take(1021).collect();
        assert_eq!(decode(&encode(&bytes)).unwrap(), bytes);
        // f32 little-endian payloads — the serve wire case.
        let floats = [1.5f32, -0.0, f32::MIN_POSITIVE, 3.0e8];
        let raw: Vec<u8> = floats.iter().flat_map(|v| v.to_le_bytes()).collect();
        let back = decode(&encode(&raw)).unwrap();
        assert_eq!(back, raw);
    }

    #[test]
    fn rejects_malformed() {
        assert!(decode("Zm9").is_err(), "length not a multiple of 4");
        assert!(decode("Zm 9v").is_err(), "whitespace");
        assert!(decode("Zm=v").is_err(), "padding in the middle of a quad");
        assert!(decode("Zg==Zg==").is_err(), "padding before the final quad");
        assert!(decode("Z===").is_err(), "over-padded quad");
        assert!(decode("Zm9\u{e9}").is_err(), "non-ascii");
    }
}
