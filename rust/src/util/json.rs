//! Minimal JSON parser and writer.
//!
//! Carries the AOT artifact manifest (`artifacts/manifest.json` produced by
//! `python/compile/aot.py`), experiment configuration files, and the JSONL
//! metric logs. Supports the full JSON grammar except `\u` surrogate pairs
//! beyond the BMP (not needed by any producer in this repo).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys are kept sorted (BTreeMap) so serialization is
/// deterministic — bit-identical manifests/configs across runs.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- Constructors ---------------------------------------------------

    pub fn obj(entries: Vec<(&str, Json)>) -> Json {
        Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(v: f64) -> Json {
        Json::Num(v)
    }

    pub fn str(v: impl Into<String>) -> Json {
        Json::Str(v.into())
    }

    // ---- Accessors -------------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|v| *v >= 0.0 && v.fract() == 0.0).map(|v| v as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]` convenience; returns Null for misses.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|o| o.get(key)).unwrap_or(&NULL)
    }

    /// Path access: `j.at(&["a", "b"])`.
    pub fn at(&self, path: &[&str]) -> &Json {
        let mut cur = self;
        for k in path {
            cur = cur.get(k);
        }
        cur
    }

    // ---- Serialization ---------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => {
                if v.fract() == 0.0 && v.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *v as i64);
                } else if v.is_finite() {
                    let _ = write!(out, "{v}");
                } else {
                    // JSON has no Inf/NaN; emit null like most writers.
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }

    // ---- Parsing -----------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let bytes = text.as_bytes();
        let mut p = Parser { b: bytes, pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Parse a file.
    pub fn parse_file(path: &std::path::Path) -> anyhow::Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Ok(Json::parse(&text)?)
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let j = Json::obj(vec![
            ("name", Json::str("pogo")),
            ("lr", Json::num(0.5)),
            ("steps", Json::num(3000.0)),
            ("tags", Json::arr(vec![Json::str("a"), Json::str("b")])),
            ("nested", Json::obj(vec![("ok", Json::Bool(true)), ("none", Json::Null)])),
        ]);
        let text = j.to_string_pretty();
        let back = Json::parse(&text).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn parses_numbers() {
        for (s, v) in
            [("0", 0.0), ("-1.5", -1.5), ("2e3", 2000.0), ("1.25E-2", 0.0125), ("42", 42.0)]
        {
            assert_eq!(Json::parse(s).unwrap().as_f64().unwrap(), v, "{s}");
        }
    }

    #[test]
    fn parses_escapes() {
        let j = Json::parse(r#""a\nb\t\"c\" A""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\nb\t\"c\" A");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{,}").is_err());
        assert!(Json::parse("[1, 2,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn path_access() {
        let j = Json::parse(r#"{"a": {"b": [1, 2, 3]}}"#).unwrap();
        assert_eq!(j.at(&["a", "b"]).as_arr().unwrap().len(), 3);
        assert_eq!(j.at(&["a", "missing"]), &Json::Null);
    }

    #[test]
    fn integers_serialized_without_decimal() {
        assert_eq!(Json::num(3000.0).to_string(), "3000");
        assert_eq!(Json::num(0.5).to_string(), "0.5");
    }

    #[test]
    fn unicode_passthrough() {
        let j = Json::parse("\"héllo ✓\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo ✓");
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, back);
    }
}
