//! Tiny `log`-facade backend writing to stderr with wall-clock offsets.

use log::{Level, LevelFilter, Log, Metadata, Record};
use std::sync::OnceLock;
use std::time::Instant;

struct StderrLogger {
    start: Instant,
}

impl Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = self.start.elapsed().as_secs_f64();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{t:>9.3}s {lvl}] {}", record.args());
    }

    fn flush(&self) {}
}

static LOGGER: OnceLock<StderrLogger> = OnceLock::new();

/// Install the logger. Level comes from `POGO_LOG` (error|warn|info|debug|
/// trace), defaulting to `info`. Idempotent.
pub fn init() {
    let logger = LOGGER.get_or_init(|| StderrLogger { start: Instant::now() });
    let level = match std::env::var("POGO_LOG").as_deref() {
        Ok("error") => LevelFilter::Error,
        Ok("warn") => LevelFilter::Warn,
        Ok("debug") => LevelFilter::Debug,
        Ok("trace") => LevelFilter::Trace,
        _ => LevelFilter::Info,
    };
    // set_logger errors if called twice; that's fine.
    let _ = log::set_logger(logger);
    log::set_max_level(level);
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke");
    }
}
