//! Tiny `log`-facade backend writing to stderr with wall-clock offsets
//! and the emitting module (`target`) in every line.

use log::{Level, LevelFilter, Log, Metadata, Record};
use std::sync::OnceLock;
use std::time::Instant;

struct StderrLogger {
    start: Instant,
}

impl Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = self.start.elapsed().as_secs_f64();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{t:>9.3}s {lvl} {}] {}", record.target(), record.args());
    }

    fn flush(&self) {}
}

static LOGGER: OnceLock<StderrLogger> = OnceLock::new();

/// Parse one `POGO_LOG` value. Public so the doc/tests can pin the
/// accepted set: error | warn | info | debug | trace | off.
pub fn parse_level(s: &str) -> Option<LevelFilter> {
    match s {
        "error" => Some(LevelFilter::Error),
        "warn" => Some(LevelFilter::Warn),
        "info" => Some(LevelFilter::Info),
        "debug" => Some(LevelFilter::Debug),
        "trace" => Some(LevelFilter::Trace),
        "off" => Some(LevelFilter::Off),
        _ => None,
    }
}

/// Install the logger. Level comes from `POGO_LOG` (error|warn|info|
/// debug|trace|off), defaulting to `info`. An unrecognized value still
/// defaults to `info` but warns once naming the bad value, instead of
/// silently eating it. Idempotent.
pub fn init() {
    let logger = LOGGER.get_or_init(|| StderrLogger { start: Instant::now() });
    let var = std::env::var("POGO_LOG").ok();
    let (level, bad) = match var.as_deref() {
        None => (LevelFilter::Info, None),
        Some(v) => match parse_level(v) {
            Some(l) => (l, None),
            None => (LevelFilter::Info, Some(v.to_string())),
        },
    };
    // set_logger errors if called twice; that's fine.
    let first = log::set_logger(logger).is_ok();
    log::set_max_level(level);
    if first {
        if let Some(bad) = bad {
            log::warn!(
                "unrecognized POGO_LOG value '{bad}' (want error|warn|info|debug|trace|off); \
                 defaulting to info"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke");
    }

    #[test]
    fn parses_every_documented_level() {
        assert_eq!(parse_level("error"), Some(LevelFilter::Error));
        assert_eq!(parse_level("warn"), Some(LevelFilter::Warn));
        assert_eq!(parse_level("info"), Some(LevelFilter::Info));
        assert_eq!(parse_level("debug"), Some(LevelFilter::Debug));
        assert_eq!(parse_level("trace"), Some(LevelFilter::Trace));
        assert_eq!(parse_level("off"), Some(LevelFilter::Off));
        assert_eq!(parse_level("verbose"), None);
        assert_eq!(parse_level("INFO"), None); // case-sensitive, like before
    }
}
