//! Declarative command-line flag parsing (no clap in the offline registry).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, positional
//! arguments, and generates the usage text. Used by the `pogo` binary, the
//! examples and the bench drivers.

use std::collections::BTreeMap;

/// One registered flag.
#[derive(Clone, Debug)]
struct FlagSpec {
    name: String,
    help: String,
    default: Option<String>,
    is_bool: bool,
}

/// Parsed arguments.
#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    bools: BTreeMap<String, bool>,
    positional: Vec<String>,
}

impl Args {
    /// String flag (falls back to the registered default).
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_f64(&self, name: &str) -> Option<f64> {
        self.get(name).and_then(|v| v.parse().ok())
    }

    pub fn get_usize(&self, name: &str) -> Option<usize> {
        self.get(name).and_then(|v| v.parse().ok())
    }

    pub fn get_u64(&self, name: &str) -> Option<u64> {
        self.get(name).and_then(|v| v.parse().ok())
    }

    pub fn get_bool(&self, name: &str) -> bool {
        self.bools.get(name).copied().unwrap_or(false)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

/// Flag registry + parser for one (sub)command.
pub struct Cli {
    program: String,
    about: String,
    flags: Vec<FlagSpec>,
}

impl Cli {
    pub fn new(program: &str, about: &str) -> Self {
        Cli { program: program.to_string(), about: about.to_string(), flags: Vec::new() }
    }

    /// Register a value flag with a default.
    pub fn flag(mut self, name: &str, default: &str, help: &str) -> Self {
        self.flags.push(FlagSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: Some(default.to_string()),
            is_bool: false,
        });
        self
    }

    /// Register a value flag with no default (optional).
    pub fn flag_opt(mut self, name: &str, help: &str) -> Self {
        self.flags.push(FlagSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_bool: false,
        });
        self
    }

    /// Register a boolean switch.
    pub fn switch(mut self, name: &str, help: &str) -> Self {
        self.flags.push(FlagSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_bool: true,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nFlags:\n", self.program, self.about);
        for f in &self.flags {
            let head = if f.is_bool {
                format!("  --{}", f.name)
            } else {
                format!("  --{} <value>", f.name)
            };
            let def = match &f.default {
                Some(d) if !f.is_bool => format!(" [default: {d}]"),
                _ => String::new(),
            };
            s.push_str(&format!("{head:<28} {}{def}\n", f.help));
        }
        s.push_str("  --help                     show this message\n");
        s
    }

    /// Parse a token list (excluding argv[0]). Returns Err(usage) on
    /// `--help` or malformed input.
    pub fn parse(&self, tokens: &[String]) -> Result<Args, String> {
        let mut args = Args::default();
        // Seed defaults.
        for f in &self.flags {
            if let Some(d) = &f.default {
                args.values.insert(f.name.clone(), d.clone());
            }
        }
        let mut i = 0;
        while i < tokens.len() {
            let t = &tokens[i];
            if t == "--help" || t == "-h" {
                return Err(self.usage());
            }
            if let Some(stripped) = t.strip_prefix("--") {
                let (name, inline_val) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .flags
                    .iter()
                    .find(|f| f.name == name)
                    .ok_or_else(|| format!("unknown flag --{name}\n\n{}", self.usage()))?;
                if spec.is_bool {
                    if let Some(v) = inline_val {
                        let b = v.parse::<bool>().map_err(|_| {
                            format!("flag --{name} expects true/false, got '{v}'")
                        })?;
                        args.bools.insert(name, b);
                    } else {
                        args.bools.insert(name, true);
                    }
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            tokens
                                .get(i)
                                .cloned()
                                .ok_or_else(|| format!("flag --{name} expects a value"))?
                        }
                    };
                    args.values.insert(name, val);
                }
            } else {
                args.positional.push(t.clone());
            }
            i += 1;
        }
        Ok(args)
    }

    /// Parse std::env::args() (skipping argv[0] and an optional subcommand
    /// token), printing usage and exiting on error.
    pub fn parse_env_or_exit(&self, skip: usize) -> Args {
        let tokens: Vec<String> = std::env::args().skip(1 + skip).collect();
        match self.parse(&tokens) {
            Ok(a) => a,
            Err(msg) => {
                eprintln!("{msg}");
                std::process::exit(2);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("test", "test tool")
            .flag("lr", "0.5", "learning rate")
            .flag_opt("out", "output path")
            .switch("verbose", "log more")
    }

    fn toks(s: &[&str]) -> Vec<String> {
        s.iter().map(|t| t.to_string()).collect()
    }

    #[test]
    fn defaults_applied() {
        let a = cli().parse(&toks(&[])).unwrap();
        assert_eq!(a.get_f64("lr"), Some(0.5));
        assert_eq!(a.get("out"), None);
        assert!(!a.get_bool("verbose"));
    }

    #[test]
    fn space_and_equals_forms() {
        let a = cli().parse(&toks(&["--lr", "0.1", "--out=res.csv", "--verbose"])).unwrap();
        assert_eq!(a.get_f64("lr"), Some(0.1));
        assert_eq!(a.get("out"), Some("res.csv"));
        assert!(a.get_bool("verbose"));
    }

    #[test]
    fn positional_collected() {
        let a = cli().parse(&toks(&["fig4-pca", "--lr", "1.0"])).unwrap();
        assert_eq!(a.positional(), &["fig4-pca".to_string()]);
    }

    #[test]
    fn unknown_flag_rejected() {
        assert!(cli().parse(&toks(&["--nope", "1"])).is_err());
    }

    #[test]
    fn help_returns_usage() {
        let err = cli().parse(&toks(&["--help"])).unwrap_err();
        assert!(err.contains("learning rate"));
    }

    #[test]
    fn missing_value_rejected() {
        assert!(cli().parse(&toks(&["--lr"])).is_err());
    }

    #[test]
    fn bool_with_explicit_value() {
        let a = cli().parse(&toks(&["--verbose=false"])).unwrap();
        assert!(!a.get_bool("verbose"));
    }
}
