//! Dependency-free SHA-256 (FIPS 180-4), the content-address hash of the
//! artifact subsystem.
//!
//! The offline registry has no `sha2`/`ring`, so the compression function
//! is implemented in-crate: a streaming [`Sha256`] hasher plus the
//! [`digest`]/[`hex`] one-shot helpers. Checked against the NIST FIPS
//! 180-4 example vectors in the tests below. Performance is adequate for
//! the artifact path (manifests are kilobytes, payloads tens of MB); this
//! is not a hot-loop primitive.

/// Initial hash state (`H(0)`, FIPS 180-4 §5.3.3).
const H0: [u32; 8] = [
    0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a, 0x510e527f, 0x9b05688c, 0x1f83d9ab,
    0x5be0cd19,
];

/// Round constants (`K`, FIPS 180-4 §4.2.2).
const K: [u32; 64] = [
    0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5, 0x3956c25b, 0x59f111f1, 0x923f82a4,
    0xab1c5ed5, 0xd807aa98, 0x12835b01, 0x243185be, 0x550c7dc3, 0x72be5d74, 0x80deb1fe,
    0x9bdc06a7, 0xc19bf174, 0xe49b69c1, 0xefbe4786, 0x0fc19dc6, 0x240ca1cc, 0x2de92c6f,
    0x4a7484aa, 0x5cb0a9dc, 0x76f988da, 0x983e5152, 0xa831c66d, 0xb00327c8, 0xbf597fc7,
    0xc6e00bf3, 0xd5a79147, 0x06ca6351, 0x14292967, 0x27b70a85, 0x2e1b2138, 0x4d2c6dfc,
    0x53380d13, 0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85, 0xa2bfe8a1, 0xa81a664b,
    0xc24b8b70, 0xc76c51a3, 0xd192e819, 0xd6990624, 0xf40e3585, 0x106aa070, 0x19a4c116,
    0x1e376c08, 0x2748774c, 0x34b0bcb5, 0x391c0cb3, 0x4ed8aa4a, 0x5b9cca4f, 0x682e6ff3,
    0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208, 0x90befffa, 0xa4506ceb, 0xbef9a3f7,
    0xc67178f2,
];

/// Streaming SHA-256 hasher.
#[derive(Clone)]
pub struct Sha256 {
    state: [u32; 8],
    /// Partial input block not yet compressed.
    buf: [u8; 64],
    buf_len: usize,
    /// Total message length in bytes (the padding trailer needs it in bits).
    total: u64,
}

impl Default for Sha256 {
    fn default() -> Self {
        Self::new()
    }
}

impl Sha256 {
    pub fn new() -> Self {
        Sha256 { state: H0, buf: [0u8; 64], buf_len: 0, total: 0 }
    }

    /// Absorb more message bytes.
    pub fn update(&mut self, mut data: &[u8]) {
        self.total = self.total.wrapping_add(data.len() as u64);
        // Top up a partial block first.
        if self.buf_len > 0 {
            let take = (64 - self.buf_len).min(data.len());
            self.buf[self.buf_len..self.buf_len + take].copy_from_slice(&data[..take]);
            self.buf_len += take;
            data = &data[take..];
            if self.buf_len == 64 {
                let block = self.buf;
                self.compress(&block);
                self.buf_len = 0;
            }
        }
        // Whole blocks straight from the input.
        while data.len() >= 64 {
            let mut block = [0u8; 64];
            block.copy_from_slice(&data[..64]);
            self.compress(&block);
            data = &data[64..];
        }
        // Stash the tail.
        if !data.is_empty() {
            self.buf[..data.len()].copy_from_slice(data);
            self.buf_len = data.len();
        }
    }

    /// Pad, compress the final block(s), and return the 32-byte digest.
    pub fn finalize(mut self) -> [u8; 32] {
        let bit_len = self.total.wrapping_mul(8);
        // 0x80 terminator, zero fill to 56 mod 64, then the bit length.
        self.update(&[0x80]);
        // (`update` keeps bumping `total`, but the trailer was fixed above.)
        while self.buf_len != 56 {
            self.update(&[0x00]);
        }
        let mut block = self.buf;
        block[56..64].copy_from_slice(&bit_len.to_be_bytes());
        self.compress(&block);
        let mut out = [0u8; 32];
        for (i, word) in self.state.iter().enumerate() {
            out[i * 4..i * 4 + 4].copy_from_slice(&word.to_be_bytes());
        }
        out
    }

    /// One compression round over a 64-byte block (FIPS 180-4 §6.2.2).
    fn compress(&mut self, block: &[u8; 64]) {
        let mut w = [0u32; 64];
        for (i, chunk) in block.chunks_exact(4).enumerate() {
            w[i] = u32::from_be_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
        }
        for i in 16..64 {
            let s0 = w[i - 15].rotate_right(7) ^ w[i - 15].rotate_right(18) ^ (w[i - 15] >> 3);
            let s1 = w[i - 2].rotate_right(17) ^ w[i - 2].rotate_right(19) ^ (w[i - 2] >> 10);
            w[i] = w[i - 16]
                .wrapping_add(s0)
                .wrapping_add(w[i - 7])
                .wrapping_add(s1);
        }
        let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut h] = self.state;
        for i in 0..64 {
            let big_s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
            let ch = (e & f) ^ (!e & g);
            let t1 = h
                .wrapping_add(big_s1)
                .wrapping_add(ch)
                .wrapping_add(K[i])
                .wrapping_add(w[i]);
            let big_s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
            let maj = (a & b) ^ (a & c) ^ (b & c);
            let t2 = big_s0.wrapping_add(maj);
            h = g;
            g = f;
            f = e;
            e = d.wrapping_add(t1);
            d = c;
            c = b;
            b = a;
            a = t1.wrapping_add(t2);
        }
        let add = [a, b, c, d, e, f, g, h];
        for (s, v) in self.state.iter_mut().zip(add) {
            *s = s.wrapping_add(v);
        }
    }
}

/// One-shot digest.
pub fn digest(data: &[u8]) -> [u8; 32] {
    let mut h = Sha256::new();
    h.update(data);
    h.finalize()
}

/// Lowercase hex of a digest.
pub fn to_hex(digest: &[u8; 32]) -> String {
    let mut s = String::with_capacity(64);
    for b in digest {
        s.push_str(&format!("{b:02x}"));
    }
    s
}

/// One-shot lowercase-hex digest (the artifact content-address form).
pub fn hex(data: &[u8]) -> String {
    to_hex(&digest(data))
}

/// Is `s` a well-formed content address (64 lowercase hex chars)?
pub fn is_hex_digest(s: &str) -> bool {
    s.len() == 64 && s.bytes().all(|b| b.is_ascii_digit() || (b'a'..=b'f').contains(&b))
}

#[cfg(test)]
mod tests {
    use super::*;

    // NIST FIPS 180-4 / CAVP example vectors.

    #[test]
    fn nist_empty_message() {
        assert_eq!(
            hex(b""),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
    }

    #[test]
    fn nist_abc() {
        assert_eq!(
            hex(b"abc"),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
    }

    #[test]
    fn nist_two_block_message() {
        assert_eq!(
            hex(b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq"),
            "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1"
        );
    }

    #[test]
    fn nist_896_bit_message() {
        let msg = b"abcdefghbcdefghicdefghijdefghijkefghijklfghijklmghijklmnhijklmno\
                    ijklmnopjklmnopqklmnopqrlmnopqrsmnopqrstnopqrstu";
        assert_eq!(
            hex(msg),
            "cf5b16a778af8380036ce59e7b0492370b249b11e8f07a51afac45037afee9d1"
        );
    }

    #[test]
    fn nist_one_million_a() {
        let msg = vec![b'a'; 1_000_000];
        assert_eq!(
            hex(&msg),
            "cdc76e5c9914fb9281a1c7e284d73e67f1809a48a497200e046d39ccc7112cd0"
        );
    }

    #[test]
    fn streaming_matches_one_shot_at_every_split() {
        // Exercise every buffer-boundary case around the 64-byte block.
        let msg: Vec<u8> = (0..200u16).map(|i| (i * 7 % 251) as u8).collect();
        let want = digest(&msg);
        for split in [0, 1, 7, 63, 64, 65, 127, 128, 199, 200] {
            let mut h = Sha256::new();
            h.update(&msg[..split]);
            h.update(&msg[split..]);
            assert_eq!(h.finalize(), want, "split at {split}");
        }
        // Byte-at-a-time.
        let mut h = Sha256::new();
        for b in &msg {
            h.update(std::slice::from_ref(b));
        }
        assert_eq!(h.finalize(), want);
    }

    #[test]
    fn hex_digest_shape() {
        let h = hex(b"abc");
        assert!(is_hex_digest(&h));
        assert!(!is_hex_digest("abc"));
        assert!(!is_hex_digest(&h.to_uppercase()));
        assert!(!is_hex_digest(&format!("{}0", h)));
    }
}
