//! Cross-cutting utilities built in-crate (the offline registry lacks
//! serde/clap/rayon): JSON, CLI parsing, a thread pool, logging and timers.

pub mod b64;
pub mod cli;
pub mod json;
pub mod logging;
pub mod pool;
pub mod sha256;

/// Wall-clock stopwatch used by the metrics and bench harnesses.
#[derive(Clone, Copy, Debug)]
pub struct Stopwatch {
    start: std::time::Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: std::time::Instant::now() }
    }
    pub fn seconds(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
    pub fn millis(&self) -> f64 {
        self.seconds() * 1e3
    }
}

/// Format a duration in human units (used by experiment progress lines).
pub fn fmt_duration(secs: f64) -> String {
    if secs < 1e-3 {
        format!("{:.1}µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.1}ms", secs * 1e3)
    } else if secs < 120.0 {
        format!("{secs:.2}s")
    } else {
        format!("{}m{:02.0}s", (secs / 60.0) as u64, secs % 60.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(0.5e-4), "50.0µs");
        assert_eq!(fmt_duration(0.25), "250.0ms");
        assert_eq!(fmt_duration(3.0), "3.00s");
        assert_eq!(fmt_duration(150.0), "2m30s");
    }
}
