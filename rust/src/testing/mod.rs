//! Property-based testing mini-framework (proptest is not in the offline
//! registry).
//!
//! Provides seeded generators for the structures the paper's invariants are
//! stated over — Stiefel points, skew-symmetric matrices, bounded gradients
//! — plus a `forall` runner with failure reporting including the case seed,
//! so any failing property is reproducible from its printed seed.

use crate::linalg::{CMat, Mat, Scalar};
use crate::manifold::stiefel;
use crate::rng::Rng;

/// Number of cases per property (override with `POGO_PROPTEST_CASES`).
pub fn default_cases() -> usize {
    std::env::var("POGO_PROPTEST_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(32)
}

/// Run `prop` on `cases` generated inputs. The generator receives a seeded
/// RNG per case; on failure we panic with the reproducing seed and case id.
pub fn forall<T, G, P>(name: &str, cases: usize, gen: G, prop: P)
where
    G: Fn(&mut Rng) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    let base_seed: u64 = std::env::var("POGO_PROPTEST_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0x9E37_79B9_7F4A_7C15);
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64).wrapping_mul(0x2545F4914F6CDD1D);
        let mut rng = Rng::seed_from_u64(seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed on case {case} (seed {seed:#x}):\n  {msg}\n\
                 reproduce with POGO_PROPTEST_SEED={base_seed} and this case index"
            );
        }
    }
}

/// Generator: random shape (p, n) with p ≤ n within given bounds.
pub fn gen_wide_shape(rng: &mut Rng, p_max: usize, n_max: usize) -> (usize, usize) {
    let p = 1 + rng.index(p_max);
    let n = p + rng.index(n_max.saturating_sub(p) + 1);
    (p, n)
}

/// Generator: random point on St(p, n).
pub fn gen_stiefel<S: Scalar>(rng: &mut Rng, p: usize, n: usize) -> Mat<S> {
    stiefel::random_point_t(p, n, rng)
}

/// Generator: random matrix with Frobenius norm ≤ `bound`.
pub fn gen_bounded<S: Scalar>(rng: &mut Rng, p: usize, n: usize, bound: f64) -> Mat<S> {
    let g = Mat::<S>::randn(p, n, rng);
    let norm = g.norm().to_f64();
    if norm <= bound || norm == 0.0 {
        g
    } else {
        g.scale(S::from_f64(bound / norm * rng.uniform()))
    }
}

/// Generator: random skew-symmetric n×n matrix.
pub fn gen_skew<S: Scalar>(rng: &mut Rng, n: usize) -> Mat<S> {
    Mat::<S>::randn(n, n, rng).skew()
}

/// Generator: random complex Stiefel point (X X^H = I).
pub fn gen_unitary_stiefel<S: Scalar>(rng: &mut Rng, p: usize, n: usize) -> CMat<S> {
    stiefel::random_point_complex(p, n, rng)
}

/// Assertion helper: `|a − b| ≤ atol + rtol·|b|`.
pub fn close(a: f64, b: f64, atol: f64, rtol: f64) -> Result<(), String> {
    if (a - b).abs() <= atol + rtol * b.abs() {
        Ok(())
    } else {
        Err(format!("expected {a} ≈ {b} (atol={atol}, rtol={rtol}, diff={})", (a - b).abs()))
    }
}

/// Assertion helper for upper bounds with context.
pub fn leq(value: f64, bound: f64, what: &str) -> Result<(), String> {
    if value <= bound {
        Ok(())
    } else {
        Err(format!("{what}: {value} > {bound}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall("square non-negative", 16, |rng| rng.gaussian(), |x| {
            leq(0.0, x * x + 1e-18, "x² ≥ 0")
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn forall_reports_failure() {
        forall("always fails", 4, |rng| rng.gaussian(), |_| Err("nope".into()));
    }

    #[test]
    fn gen_stiefel_is_on_manifold() {
        forall(
            "gen_stiefel on manifold",
            8,
            |rng| {
                let (p, n) = gen_wide_shape(rng, 6, 12);
                gen_stiefel::<f64>(rng, p, n)
            },
            |x| leq(stiefel::distance_t(x), 1e-8, "distance"),
        );
    }

    #[test]
    fn gen_bounded_respects_bound() {
        forall(
            "gen_bounded norm",
            8,
            |rng| gen_bounded::<f64>(rng, 5, 9, 2.0),
            |g| leq(g.norm(), 2.0 + 1e-9, "norm"),
        );
    }

    #[test]
    fn gen_skew_antisymmetric() {
        forall(
            "skew antisymmetry",
            8,
            |rng| gen_skew::<f64>(rng, 7),
            |s| leq(s.add(&s.transpose()).max_abs(), 1e-12, "S + Sᵀ"),
        );
    }
}
