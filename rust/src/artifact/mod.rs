//! Sealed, content-addressed problem artifacts (`pogo-artifact-v1`).
//!
//! An artifact packages one inline-style problem payload — the matrices a
//! job's objective consumes — into a single self-describing file:
//!
//! ```text
//! ┌──────────────┬──────────────────────────┬─────────────────────────┐
//! │ u32 LE: L    │ manifest JSON (L bytes)  │ packed payload sections │
//! └──────────────┴──────────────────────────┴─────────────────────────┘
//! ```
//!
//! The manifest carries the schema magic, the problem family and domain,
//! the `(B, p, n)` shapes, a dtype tag from the `CkptDtype` vocabulary
//! (`f32`/`c64` on the serve wire), one entry per payload section with its
//! byte length and sha256, and provenance (optimizer spec JSON, seed,
//! creating tool). The **content address** of an artifact is the sha256 of
//! the manifest bytes exactly as framed — since the manifest commits to
//! every section checksum, the hash transitively pins the payload, and two
//! independently compiled artifacts with identical contents collide onto
//! the same address (what the serve store dedupes on).
//!
//! Payload sections are the matrices in manifest order, each stored as
//! row-major little-endian f32 words (complex entries interleave `re,im`)
//! — byte-for-byte the `InlineMat` wire layout, so an artifact-sourced job
//! decodes through the exact same path as an inline job and produces
//! bit-identical results.
//!
//! Decoding is total: truncation, framing lies, unknown magic/dtype and
//! shape mismatches are errors, never panics (mirroring the POGO-CKPT-v1
//! failure-path contract). [`Artifact::verify`] additionally re-hashes
//! every section against its manifest checksum, so a single flipped
//! payload byte is a clear checksum error.

pub mod store;

pub use store::{ArtifactStore, InsertOutcome, StoreSummary};

use crate::serve::job::JobDomain;
use crate::serve::problem::{InlineMat, InlineProblem};
use crate::util::json::Json;
use crate::util::sha256;
use anyhow::{anyhow, ensure, Context, Result};

/// Schema magic of the one (and so far only) artifact format version.
pub const MAGIC: &str = "pogo-artifact-v1";

/// Cap on the manifest header, so a corrupt length prefix cannot drive a
/// huge allocation. Real manifests are a few hundred bytes.
pub const MAX_MANIFEST_BYTES: usize = 1 << 20;

/// File extension used by the CLI and the on-disk store.
pub const FILE_EXT: &str = "pogoart";

/// One packed payload section: `count` matrices of one role ("a", "b",
/// "c"), all `rows x cols`, stored contiguously.
#[derive(Clone, Debug, PartialEq)]
pub struct Section {
    pub name: String,
    pub count: usize,
    pub rows: usize,
    pub cols: usize,
    /// Exact byte length of this section in the payload.
    pub bytes: usize,
    /// Lowercase-hex sha256 of those bytes.
    pub sha256: String,
}

impl Section {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("count", Json::num(self.count as f64)),
            ("rows", Json::num(self.rows as f64)),
            ("cols", Json::num(self.cols as f64)),
            ("bytes", Json::num(self.bytes as f64)),
            ("sha256", Json::str(self.sha256.clone())),
        ])
    }

    fn from_json(j: &Json) -> Result<Section> {
        let field = |k: &str| {
            j.get(k).as_usize().ok_or_else(|| anyhow!("section: missing or non-integer '{k}'"))
        };
        let name = j
            .get("name")
            .as_str()
            .ok_or_else(|| anyhow!("section: missing 'name'"))?
            .to_string();
        let digest = j
            .get("sha256")
            .as_str()
            .ok_or_else(|| anyhow!("section '{name}': missing 'sha256'"))?
            .to_string();
        ensure!(
            sha256::is_hex_digest(&digest),
            "section '{name}': 'sha256' is not a 64-char lowercase hex digest"
        );
        Ok(Section {
            count: field("count")?,
            rows: field("rows")?,
            cols: field("cols")?,
            bytes: field("bytes")?,
            name,
            sha256: digest,
        })
    }
}

/// Where an artifact came from: enough to replay the run that motivated
/// it. The optimizer spec is kept as raw JSON so the artifact layer stays
/// decoupled from the coordinator types.
#[derive(Clone, Debug, PartialEq)]
pub struct Provenance {
    /// Full `OptimizerSpec` JSON, when the compiler had one.
    pub optimizer: Option<Json>,
    /// The job seed the payload is associated with.
    pub seed: u64,
    /// Creating tool tag, e.g. `pogo 0.1.0`.
    pub created_by: String,
    /// Free-form operator note.
    pub note: Option<String>,
}

impl Provenance {
    pub fn new(seed: u64) -> Provenance {
        Provenance {
            optimizer: None,
            seed,
            created_by: format!("pogo {}", crate::VERSION),
            note: None,
        }
    }

    fn to_json(&self) -> Json {
        let mut fields = vec![
            ("seed", Json::str(self.seed.to_string())),
            ("created_by", Json::str(self.created_by.clone())),
        ];
        if let Some(opt) = &self.optimizer {
            fields.push(("optimizer", opt.clone()));
        }
        if let Some(note) = &self.note {
            fields.push(("note", Json::str(note.clone())));
        }
        Json::obj(fields)
    }

    fn from_json(j: &Json) -> Result<Provenance> {
        let seed = match j.get("seed") {
            Json::Null => 0,
            v => match (v.as_str(), v.as_f64()) {
                (Some(s), _) => s
                    .parse::<u64>()
                    .map_err(|_| anyhow!("provenance: 'seed' is not a u64: '{s}'"))?,
                (None, Some(x)) if x >= 0.0 && x.fract() == 0.0 => x as u64,
                _ => return Err(anyhow!("provenance: 'seed' must be an integer or string")),
            },
        };
        let optimizer = match j.get("optimizer") {
            Json::Null => None,
            v => Some(v.clone()),
        };
        let note = j.get("note").as_str().map(|s| s.to_string());
        let created_by = j.get("created_by").as_str().unwrap_or("unknown").to_string();
        Ok(Provenance { optimizer, seed, created_by, note })
    }
}

/// The manifest: everything about an artifact except the payload bytes.
#[derive(Clone, Debug, PartialEq)]
pub struct Manifest {
    /// Problem family the payload feeds ("procrustes" or "pca").
    pub objective: String,
    pub domain: JobDomain,
    pub batch: usize,
    pub p: usize,
    pub n: usize,
    /// Element dtype tag (`CkptDtype` vocabulary); the serve wire carries
    /// f32 words, so sealed artifacts use "f32" (real) or "c64" (complex).
    pub dtype: String,
    pub sections: Vec<Section>,
    pub provenance: Provenance,
}

/// Payload sections an objective requires, as `(name, rows, cols)` in
/// storage order. The single source of truth shared by seal (build),
/// parse (cross-check) and decode (slice).
fn expected_sections(
    objective: &str,
    p: usize,
    n: usize,
) -> Result<Vec<(&'static str, usize, usize)>> {
    match objective {
        "procrustes" => Ok(vec![("a", p, p), ("b", p, n)]),
        "pca" => Ok(vec![("c", n, n)]),
        other => Err(anyhow!(
            "unknown artifact objective '{other}' (supported: procrustes, pca)"
        )),
    }
}

/// f32 words per element for a wire dtype tag.
fn dtype_width(dtype: &str) -> Result<usize> {
    match dtype {
        "f32" => Ok(1),
        "c64" => Ok(2),
        "f64" | "c128" => Err(anyhow!(
            "artifact dtype '{dtype}' is not carried by the serve wire (f32/c64 only)"
        )),
        other => Err(anyhow!("unknown artifact dtype '{other}'")),
    }
}

impl Manifest {
    /// Total payload bytes the sections declare (overflow-checked).
    pub fn payload_bytes(&self) -> Result<usize> {
        let mut total = 0usize;
        for s in &self.sections {
            total = total
                .checked_add(s.bytes)
                .ok_or_else(|| anyhow!("manifest section sizes overflow"))?;
        }
        Ok(total)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("magic", Json::str(MAGIC)),
            ("objective", Json::str(self.objective.clone())),
            ("domain", Json::str(self.domain.name())),
            ("batch", Json::num(self.batch as f64)),
            ("p", Json::num(self.p as f64)),
            ("n", Json::num(self.n as f64)),
            ("dtype", Json::str(self.dtype.clone())),
            ("sections", Json::arr(self.sections.iter().map(Section::to_json))),
            ("provenance", self.provenance.to_json()),
        ])
    }

    /// Parse and structurally validate a manifest: magic, known objective
    /// and dtype, shapes >= 1, and sections that agree exactly with what
    /// the objective requires at these shapes.
    pub fn from_json(j: &Json) -> Result<Manifest> {
        let magic = j.get("magic").as_str().unwrap_or("");
        ensure!(magic == MAGIC, "not a {MAGIC} manifest (magic '{magic}')");
        let objective = j
            .get("objective")
            .as_str()
            .ok_or_else(|| anyhow!("manifest: missing 'objective'"))?
            .to_string();
        let domain_name = j
            .get("domain")
            .as_str()
            .ok_or_else(|| anyhow!("manifest: missing 'domain'"))?;
        let domain = JobDomain::parse(domain_name)
            .ok_or_else(|| anyhow!("manifest: unknown domain '{domain_name}'"))?;
        let dim = |k: &str| {
            j.get(k).as_usize().ok_or_else(|| anyhow!("manifest: missing or non-integer '{k}'"))
        };
        let (batch, p, n) = (dim("batch")?, dim("p")?, dim("n")?);
        ensure!(batch >= 1, "manifest: batch must be >= 1");
        ensure!(p >= 1 && p <= n, "manifest: need 1 <= p <= n, got p={p}, n={n}");
        let dtype = j
            .get("dtype")
            .as_str()
            .ok_or_else(|| anyhow!("manifest: missing 'dtype'"))?
            .to_string();
        let width = dtype_width(&dtype)?;
        let expect_width = match domain {
            JobDomain::Real => 1,
            JobDomain::Complex => 2,
        };
        ensure!(
            width == expect_width,
            "manifest: dtype '{dtype}' does not match domain '{}'",
            domain.name()
        );
        let sections = j
            .get("sections")
            .as_arr()
            .ok_or_else(|| anyhow!("manifest: missing 'sections' array"))?
            .iter()
            .enumerate()
            .map(|(i, s)| Section::from_json(s).with_context(|| format!("sections[{i}]")))
            .collect::<Result<Vec<Section>>>()?;
        // The sections must be exactly what the objective needs, in order.
        let want = expected_sections(&objective, p, n)?;
        ensure!(
            sections.len() == want.len(),
            "manifest: {} sections, but '{objective}' needs {}",
            sections.len(),
            want.len()
        );
        for (s, (name, rows, cols)) in sections.iter().zip(&want) {
            ensure!(
                s.name == *name && s.rows == *rows && s.cols == *cols,
                "manifest section '{}' ({}x{}) does not match the expected '{name}' \
                 ({rows}x{cols}) for objective '{objective}'",
                s.name,
                s.rows,
                s.cols
            );
            ensure!(
                s.count == batch,
                "manifest section '{}': {} matrices for batch {batch}",
                s.name,
                s.count
            );
            let need = s
                .count
                .checked_mul(s.rows * s.cols * width * 4)
                .ok_or_else(|| anyhow!("manifest section '{}': size overflow", s.name))?;
            ensure!(
                s.bytes == need,
                "manifest section '{}': declares {} bytes, shapes need {need}",
                s.name,
                s.bytes
            );
        }
        let provenance = Provenance::from_json(j.get("provenance")).context("provenance")?;
        Ok(Manifest { objective, domain, batch, p, n, dtype, sections, provenance })
    }
}

/// A sealed artifact: manifest + payload, with the serialized manifest
/// bytes pinned so the content address never drifts from what is (or was)
/// on the wire. Construct via [`Artifact::seal`] or [`Artifact::decode`].
#[derive(Clone, Debug)]
pub struct Artifact {
    pub manifest: Manifest,
    pub payload: Vec<u8>,
    /// The exact manifest JSON bytes as framed — the hash preimage.
    manifest_bytes: Vec<u8>,
}

impl Artifact {
    /// Seal an inline-style problem into an artifact. Validates the
    /// payload (shapes, widths, finiteness) before packing — a sealed
    /// artifact is admissible by construction.
    pub fn seal(
        problem: &InlineProblem,
        domain: JobDomain,
        batch: usize,
        p: usize,
        n: usize,
        provenance: Provenance,
    ) -> Result<Artifact> {
        problem.validate(domain, batch, p, n).context("sealing artifact")?;
        Self::seal_packed(problem, domain, batch, p, n, provenance)
    }

    /// Seal with structure checks only, skipping the O(payload) value
    /// scan. Byte-identical to [`Artifact::seal`] for the same inputs —
    /// same manifest, same hash — which is how the queue's inline-dedupe
    /// path computes a content address before deciding whether the full
    /// validation pass is needed. A caller inserting the result into a
    /// store must run the full payload validation first.
    pub fn seal_for_hash(
        problem: &InlineProblem,
        domain: JobDomain,
        batch: usize,
        p: usize,
        n: usize,
        provenance: Provenance,
    ) -> Result<Artifact> {
        problem.validate_structure(domain, batch, p, n).context("sealing artifact")?;
        Self::seal_packed(problem, domain, batch, p, n, provenance)
    }

    fn seal_packed(
        problem: &InlineProblem,
        domain: JobDomain,
        batch: usize,
        p: usize,
        n: usize,
        provenance: Provenance,
    ) -> Result<Artifact> {
        let dtype = match domain {
            JobDomain::Real => "f32",
            JobDomain::Complex => "c64",
        };
        let groups: Vec<(&'static str, &[InlineMat])> = match problem {
            InlineProblem::Procrustes { a, b } => vec![("a", a), ("b", b)],
            InlineProblem::Pca { c } => vec![("c", c)],
        };
        let mut payload = Vec::new();
        let mut sections = Vec::with_capacity(groups.len());
        for (name, mats) in groups {
            let start = payload.len();
            for m in mats {
                for w in &m.data {
                    payload.extend_from_slice(&w.to_le_bytes());
                }
            }
            let bytes = &payload[start..];
            sections.push(Section {
                name: name.to_string(),
                count: mats.len(),
                rows: mats[0].rows,
                cols: mats[0].cols,
                bytes: bytes.len(),
                sha256: sha256::hex(bytes),
            });
        }
        let manifest = Manifest {
            objective: problem.objective().to_string(),
            domain,
            batch,
            p,
            n,
            dtype: dtype.to_string(),
            sections,
            provenance,
        };
        let manifest_bytes = manifest.to_json().to_string().into_bytes();
        ensure!(
            manifest_bytes.len() <= MAX_MANIFEST_BYTES,
            "manifest of {} bytes exceeds the {MAX_MANIFEST_BYTES}-byte cap",
            manifest_bytes.len()
        );
        Ok(Artifact { manifest, payload, manifest_bytes })
    }

    /// Content address: lowercase-hex sha256 of the manifest bytes. The
    /// manifest commits to every section checksum, so this pins the
    /// payload transitively.
    pub fn hash(&self) -> String {
        sha256::hex(&self.manifest_bytes)
    }

    /// Serialize to the single-file wire/disk form.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(4 + self.manifest_bytes.len() + self.payload.len());
        out.extend_from_slice(&(self.manifest_bytes.len() as u32).to_le_bytes());
        out.extend_from_slice(&self.manifest_bytes);
        out.extend_from_slice(&self.payload);
        out
    }

    /// Total encoded size in bytes.
    pub fn encoded_len(&self) -> usize {
        4 + self.manifest_bytes.len() + self.payload.len()
    }

    /// Parse the wire/disk form. Checks framing and manifest structure
    /// (every failure is a clear error, never a panic); section checksums
    /// are verified separately by [`Artifact::verify`].
    pub fn decode(bytes: &[u8]) -> Result<Artifact> {
        ensure!(bytes.len() >= 4, "artifact truncated: {} bytes, no header length", bytes.len());
        let len = u32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]]) as usize;
        ensure!(
            len <= MAX_MANIFEST_BYTES,
            "artifact manifest length {len} exceeds the {MAX_MANIFEST_BYTES}-byte cap"
        );
        ensure!(
            bytes.len() >= 4 + len,
            "artifact truncated: manifest declares {len} bytes, only {} remain",
            bytes.len() - 4
        );
        let manifest_bytes = bytes[4..4 + len].to_vec();
        let text = std::str::from_utf8(&manifest_bytes).context("artifact manifest is not UTF-8")?;
        let manifest = Manifest::from_json(&Json::parse(text).context("artifact manifest")?)?;
        let payload = bytes[4 + len..].to_vec();
        let want = manifest.payload_bytes()?;
        ensure!(
            payload.len() == want,
            "artifact payload is {} bytes, but the manifest declares {want} \
             (truncated or trailing garbage)",
            payload.len()
        );
        Ok(Artifact { manifest, payload, manifest_bytes })
    }

    /// Integrity check: re-hash every payload section against its
    /// manifest checksum. A flipped byte anywhere is a named, clear error.
    pub fn verify(&self) -> Result<()> {
        let mut offset = 0usize;
        for s in &self.manifest.sections {
            let chunk = &self.payload[offset..offset + s.bytes];
            let got = sha256::hex(chunk);
            ensure!(
                got == s.sha256,
                "artifact section '{}': checksum mismatch — manifest says {}, payload hashes \
                 to {got} (payload corrupted)",
                s.name,
                s.sha256
            );
            offset += s.bytes;
        }
        Ok(())
    }

    /// Decode the payload back into the inline problem form — the exact
    /// `InlineMat` word layout an inline job carries, so downstream job
    /// construction is bit-identical between the two sources.
    pub fn to_problem(&self) -> Result<InlineProblem> {
        let width = dtype_width(&self.manifest.dtype)?;
        let mut offset = 0usize;
        let mut groups: Vec<Vec<InlineMat>> = Vec::with_capacity(self.manifest.sections.len());
        for s in &self.manifest.sections {
            let mat_words = s.rows * s.cols * width;
            let mut mats = Vec::with_capacity(s.count);
            for _ in 0..s.count {
                let data: Vec<f32> = self.payload[offset..offset + mat_words * 4]
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                offset += mat_words * 4;
                mats.push(InlineMat { rows: s.rows, cols: s.cols, data });
            }
            groups.push(mats);
        }
        match self.manifest.objective.as_str() {
            "procrustes" => {
                let b = groups.pop().unwrap_or_default();
                let a = groups.pop().unwrap_or_default();
                Ok(InlineProblem::Procrustes { a, b })
            }
            "pca" => Ok(InlineProblem::Pca { c: groups.pop().unwrap_or_default() }),
            other => Err(anyhow!("unknown artifact objective '{other}'")),
        }
    }

    /// Write the encoded artifact atomically (write-then-rename, like the
    /// checkpoint layer).
    pub fn write_file(&self, path: &std::path::Path) -> Result<()> {
        let tmp = path.with_extension("tmp");
        std::fs::write(&tmp, self.encode())
            .with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, path)
            .with_context(|| format!("renaming into {}", path.display()))?;
        Ok(())
    }

    /// Read and decode an artifact file (framing checks only; run
    /// [`Artifact::verify`] for the full integrity pass).
    pub fn read_file(path: &std::path::Path) -> Result<Artifact> {
        let bytes =
            std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
        Artifact::decode(&bytes).with_context(|| format!("decoding {}", path.display()))
    }

    /// Human-facing summary JSON (what `pogo artifact inspect` and
    /// `GET /v2/artifacts/<hash>` serve): manifest + derived sizes + hash.
    pub fn describe(&self) -> Json {
        Json::obj(vec![
            ("hash", Json::str(self.hash())),
            ("manifest", self.manifest.to_json()),
            ("payload_bytes", Json::num(self.payload.len() as f64)),
            ("encoded_bytes", Json::num(self.encoded_len() as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::rng::Rng;

    fn sample_problem(seed: u64, batch: usize, p: usize, n: usize) -> InlineProblem {
        let mut rng = Rng::seed_from_u64(seed);
        let a = (0..batch)
            .map(|_| InlineMat::from_mat(&Mat::<f32>::randn(p, p, &mut rng)))
            .collect();
        let b = (0..batch)
            .map(|_| InlineMat::from_mat(&Mat::<f32>::randn(p, n, &mut rng)))
            .collect();
        InlineProblem::Procrustes { a, b }
    }

    fn sample_artifact() -> Artifact {
        Artifact::seal(
            &sample_problem(7, 2, 3, 5),
            JobDomain::Real,
            2,
            3,
            5,
            Provenance::new(7),
        )
        .unwrap()
    }

    #[test]
    fn seal_encode_decode_roundtrip_bit_exact() {
        let art = sample_artifact();
        let encoded = art.encode();
        assert_eq!(encoded.len(), art.encoded_len());
        let back = Artifact::decode(&encoded).unwrap();
        assert_eq!(back.manifest, art.manifest);
        assert_eq!(back.payload, art.payload);
        assert_eq!(back.hash(), art.hash());
        back.verify().unwrap();
        // The payload decodes to the exact inline problem it was sealed from.
        assert_eq!(back.to_problem().unwrap(), sample_problem(7, 2, 3, 5));
    }

    #[test]
    fn seal_for_hash_is_byte_identical_to_seal() {
        let p = sample_problem(7, 2, 3, 5);
        let full = Artifact::seal(&p, JobDomain::Real, 2, 3, 5, Provenance::new(7)).unwrap();
        let fast =
            Artifact::seal_for_hash(&p, JobDomain::Real, 2, 3, 5, Provenance::new(7)).unwrap();
        assert_eq!(fast.hash(), full.hash());
        assert_eq!(fast.encode(), full.encode());
        // Structure lies still refuse to seal (only the value scan is
        // skipped): batch 3 against a 2-matrix payload.
        assert!(Artifact::seal_for_hash(&p, JobDomain::Real, 3, 3, 5, Provenance::new(7))
            .is_err());
    }

    #[test]
    fn content_address_is_deterministic_and_content_sensitive() {
        let a1 = sample_artifact();
        let a2 = sample_artifact();
        assert!(crate::util::sha256::is_hex_digest(&a1.hash()));
        // Same content twice -> same address.
        assert_eq!(a1.hash(), a2.hash());
        // Different data -> different address.
        let other = Artifact::seal(
            &sample_problem(8, 2, 3, 5),
            JobDomain::Real,
            2,
            3,
            5,
            Provenance::new(8),
        )
        .unwrap();
        assert_ne!(a1.hash(), other.hash());
    }

    #[test]
    fn manifest_json_roundtrip() {
        let mut art = sample_artifact();
        art.manifest.provenance.note = Some("fig4 regression payload".to_string());
        art.manifest.provenance.optimizer =
            Some(Json::parse(r#"{"method": "pogo", "lr": 0.05}"#).unwrap());
        let j = art.manifest.to_json();
        let back = Manifest::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back, art.manifest);
    }

    #[test]
    fn any_flipped_payload_byte_is_a_checksum_error() {
        let art = sample_artifact();
        let clean = art.encode();
        let payload_start = clean.len() - art.payload.len();
        // Flip a byte in each section's range plus the very last byte.
        for &at in &[payload_start, payload_start + art.manifest.sections[0].bytes, clean.len() - 1]
        {
            let mut bad = clean.clone();
            bad[at] ^= 0x01;
            let decoded = Artifact::decode(&bad).unwrap(); // framing still valid
            let err = decoded.verify().unwrap_err();
            assert!(format!("{err:#}").contains("checksum mismatch"), "{err:#}");
        }
    }

    #[test]
    fn truncation_and_framing_lies_are_errors_not_panics() {
        let art = sample_artifact();
        let clean = art.encode();
        // Truncations at every structural boundary and a few odd offsets.
        for cut in [0, 1, 3, 4, 10, clean.len() - art.payload.len() + 1, clean.len() - 1] {
            assert!(Artifact::decode(&clean[..cut]).is_err(), "cut at {cut}");
        }
        // Trailing garbage.
        let mut long = clean.clone();
        long.push(0);
        assert!(Artifact::decode(&long).is_err());
        // Header length pointing past the end.
        let mut lying = clean.clone();
        lying[0..4].copy_from_slice(&(u32::MAX).to_le_bytes());
        assert!(Artifact::decode(&lying).is_err());
        // Corrupted manifest JSON.
        let mut bad_json = clean.clone();
        bad_json[5] = b'!';
        assert!(Artifact::decode(&bad_json).is_err());
    }

    #[test]
    fn manifest_structure_is_cross_checked() {
        let art = sample_artifact();
        let base = art.manifest.to_json();
        let mutate = |key: &str, v: Json| {
            let Json::Obj(mut m) = base.clone() else { panic!() };
            m.insert(key.to_string(), v);
            Json::Obj(m)
        };
        // Wrong magic, unknown objective/dtype, batch/shape lies all fail.
        for bad in [
            mutate("magic", Json::str("pogo-artifact-v9")),
            mutate("objective", Json::str("quartic")),
            mutate("dtype", Json::str("f64")),
            mutate("dtype", Json::str("c64")), // real domain, complex dtype
            mutate("batch", Json::num(3.0)),   // sections say count=2
            mutate("p", Json::num(5.0)),       // breaks p <= n? p=5,n=5 ok; breaks section shape
            mutate("sections", Json::arr(Vec::<Json>::new())),
        ] {
            assert!(Manifest::from_json(&bad).is_err(), "{bad:?}");
        }
        // The unmutated manifest still parses.
        Manifest::from_json(&base).unwrap();
    }

    #[test]
    fn complex_payloads_seal_and_decode() {
        use crate::linalg::Complex;
        let mut rng = Rng::seed_from_u64(11);
        let c: Vec<InlineMat> = (0..2)
            .map(|_| InlineMat::from_mat(&Mat::<Complex<f32>>::randn(4, 4, &mut rng)))
            .collect();
        let problem = InlineProblem::Pca { c };
        let art =
            Artifact::seal(&problem, JobDomain::Complex, 2, 2, 4, Provenance::new(0)).unwrap();
        assert_eq!(art.manifest.dtype, "c64");
        let back = Artifact::decode(&art.encode()).unwrap();
        back.verify().unwrap();
        assert_eq!(back.to_problem().unwrap(), problem);
    }

    #[test]
    fn file_roundtrip() {
        let dir =
            std::env::temp_dir().join(format!("pogo_artifact_file_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let art = sample_artifact();
        let path = dir.join(format!("{}.{FILE_EXT}", art.hash()));
        art.write_file(&path).unwrap();
        let back = Artifact::read_file(&path).unwrap();
        assert_eq!(back.hash(), art.hash());
        back.verify().unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }
}
