//! Content-addressed on-disk artifact store with LRU-ish eviction.
//!
//! One file per artifact, named `<hash>.pogoart` under the store
//! directory, where `<hash>` is the manifest sha256 ([`super::Artifact`]'s
//! content address). Because the name IS the content address, the store
//! is self-deduplicating: inserting bytes that are already present is a
//! recency bump, not a rewrite.
//!
//! The byte budget is enforced on insert: least-recently-used entries are
//! evicted (their files deleted) until the newcomer fits. Recency is an
//! in-memory counter — a restart re-indexes the directory and restarts
//! recency from scratch, which is as "LRU-ish" as a crash-safe store gets
//! without a journal. All mutation happens under one lock; files are
//! written via write-then-rename so readers never observe a torn file.

use super::{Artifact, FILE_EXT};
use crate::util::sha256;
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// What an insert did.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct InsertOutcome {
    pub hash: String,
    /// The content was already stored (no bytes written).
    pub existed: bool,
    /// How many entries were evicted to make room.
    pub evicted: usize,
}

/// Point-in-time store contents (what `pogo report` and
/// `GET /v2/artifacts` summarize).
#[derive(Clone, Debug)]
pub struct StoreSummary {
    pub count: usize,
    pub total_bytes: u64,
    pub cap_bytes: u64,
    /// `(hash, encoded bytes)` sorted by size, largest first.
    pub entries: Vec<(String, u64)>,
}

#[derive(Debug)]
struct EntryInfo {
    bytes: u64,
    last_used: u64,
}

#[derive(Debug, Default)]
struct Inner {
    entries: BTreeMap<String, EntryInfo>,
    total_bytes: u64,
    /// Monotone recency clock; bumped on every touch.
    tick: u64,
}

/// The store handle (share via `Arc`; all methods take `&self`).
pub struct ArtifactStore {
    dir: PathBuf,
    cap_bytes: u64,
    inner: Mutex<Inner>,
}

impl std::fmt::Debug for ArtifactStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ArtifactStore")
            .field("dir", &self.dir)
            .field("cap_bytes", &self.cap_bytes)
            .finish()
    }
}

impl ArtifactStore {
    /// Open (and create) a store directory, indexing any `*.pogoart`
    /// files already there. Files whose stem is not a well-formed content
    /// address are ignored — they were not written by this store.
    pub fn open(dir: &Path, cap_bytes: u64) -> Result<ArtifactStore> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating artifact store dir {}", dir.display()))?;
        let mut inner = Inner::default();
        for entry in std::fs::read_dir(dir)
            .with_context(|| format!("indexing artifact store {}", dir.display()))?
        {
            let entry = entry?;
            let path = entry.path();
            let is_artifact = path.extension().and_then(|e| e.to_str()) == Some(FILE_EXT);
            let stem = path.file_stem().and_then(|s| s.to_str()).unwrap_or("");
            if !is_artifact || !sha256::is_hex_digest(stem) {
                continue;
            }
            let bytes = entry.metadata().map(|m| m.len()).unwrap_or(0);
            inner.total_bytes += bytes;
            inner.tick += 1;
            inner
                .entries
                .insert(stem.to_string(), EntryInfo { bytes, last_used: inner.tick });
        }
        Ok(ArtifactStore { dir: dir.to_path_buf(), cap_bytes, inner: Mutex::new(inner) })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn path_of(&self, hash: &str) -> PathBuf {
        self.dir.join(format!("{hash}.{FILE_EXT}"))
    }

    /// Insert a sealed artifact. Already-present content is a recency
    /// bump (`existed: true`); otherwise the encoded file is written and
    /// LRU entries are evicted until the byte budget holds. An artifact
    /// larger than the whole budget is refused.
    pub fn insert(&self, artifact: &Artifact) -> Result<InsertOutcome> {
        let hash = artifact.hash();
        let bytes = artifact.encoded_len() as u64;
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        inner.tick += 1;
        let tick = inner.tick;
        if let Some(info) = inner.entries.get_mut(&hash) {
            info.last_used = tick;
            return Ok(InsertOutcome { hash, existed: true, evicted: 0 });
        }
        if bytes > self.cap_bytes {
            return Err(anyhow!(
                "artifact {hash} is {bytes} bytes, larger than the whole {}-byte store budget",
                self.cap_bytes
            ));
        }
        let mut evicted = 0usize;
        while inner.total_bytes + bytes > self.cap_bytes {
            let victim = inner
                .entries
                .iter()
                .min_by_key(|(_, info)| info.last_used)
                .map(|(h, _)| h.clone());
            let Some(victim) = victim else { break };
            if let Some(info) = inner.entries.remove(&victim) {
                inner.total_bytes = inner.total_bytes.saturating_sub(info.bytes);
            }
            std::fs::remove_file(self.path_of(&victim)).ok();
            evicted += 1;
        }
        artifact.write_file(&self.path_of(&hash))?;
        inner.total_bytes += bytes;
        inner.entries.insert(hash.clone(), EntryInfo { bytes, last_used: tick });
        Ok(InsertOutcome { hash, existed: false, evicted })
    }

    /// Is this content address stored? Bumps recency on hit, so a
    /// dedupe/lookup hit also protects the entry from eviction.
    pub fn touch(&self, hash: &str) -> bool {
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        inner.tick += 1;
        let tick = inner.tick;
        match inner.entries.get_mut(hash) {
            Some(info) => {
                info.last_used = tick;
                true
            }
            None => false,
        }
    }

    /// Load an artifact by content address. `Ok(None)` when the hash is
    /// not stored; a stored-but-unreadable file is dropped from the index
    /// and surfaced as an error.
    pub fn get(&self, hash: &str) -> Result<Option<Artifact>> {
        if !self.touch(hash) {
            return Ok(None);
        }
        match Artifact::read_file(&self.path_of(hash)) {
            Ok(art) => Ok(Some(art)),
            Err(e) => {
                let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
                if let Some(info) = inner.entries.remove(hash) {
                    inner.total_bytes = inner.total_bytes.saturating_sub(info.bytes);
                }
                Err(e.context(format!("stored artifact {hash} is unreadable; dropped")))
            }
        }
    }

    pub fn summary(&self) -> StoreSummary {
        let inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        let mut entries: Vec<(String, u64)> =
            inner.entries.iter().map(|(h, info)| (h.clone(), info.bytes)).collect();
        entries.sort_by(|a, b| b.1.cmp(&a.1).then_with(|| a.0.cmp(&b.0)));
        StoreSummary {
            count: inner.entries.len(),
            total_bytes: inner.total_bytes,
            cap_bytes: self.cap_bytes,
            entries,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::{Artifact, Provenance};
    use super::*;
    use crate::linalg::Mat;
    use crate::rng::Rng;
    use crate::serve::job::JobDomain;
    use crate::serve::problem::{InlineMat, InlineProblem};

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("pogo_artifact_store_{tag}_{}", std::process::id()));
        std::fs::remove_dir_all(&d).ok();
        d
    }

    fn art(seed: u64) -> Artifact {
        let mut rng = Rng::seed_from_u64(seed);
        let c = vec![InlineMat::from_mat(&Mat::<f32>::randn(6, 6, &mut rng))];
        Artifact::seal(
            &InlineProblem::Pca { c },
            JobDomain::Real,
            1,
            2,
            6,
            Provenance::new(seed),
        )
        .unwrap()
    }

    #[test]
    fn insert_get_and_dedupe() {
        let dir = tmpdir("basic");
        let store = ArtifactStore::open(&dir, 1 << 20).unwrap();
        let a = art(1);
        let first = store.insert(&a).unwrap();
        assert!(!first.existed);
        assert_eq!(first.hash, a.hash());
        // Same content again: recency bump, no rewrite, no eviction.
        let again = store.insert(&a).unwrap();
        assert!(again.existed);
        assert_eq!(again.evicted, 0);
        assert!(store.touch(&a.hash()));
        assert!(!store.touch(&crate::util::sha256::hex(b"absent")));
        let loaded = store.get(&a.hash()).unwrap().unwrap();
        assert_eq!(loaded.hash(), a.hash());
        assert_eq!(loaded.payload, a.payload);
        assert!(store.get(&crate::util::sha256::hex(b"absent")).unwrap().is_none());
        let s = store.summary();
        assert_eq!(s.count, 1);
        assert_eq!(s.total_bytes, a.encoded_len() as u64);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn reopen_reindexes_directory() {
        let dir = tmpdir("reopen");
        let (h1, h2) = {
            let store = ArtifactStore::open(&dir, 1 << 20).unwrap();
            let (a1, a2) = (art(1), art(2));
            store.insert(&a1).unwrap();
            store.insert(&a2).unwrap();
            (a1.hash(), a2.hash())
        };
        // Junk files are not indexed.
        std::fs::write(dir.join("notes.txt"), b"junk").unwrap();
        std::fs::write(dir.join("bad-stem.pogoart"), b"junk").unwrap();
        let store = ArtifactStore::open(&dir, 1 << 20).unwrap();
        let s = store.summary();
        assert_eq!(s.count, 2);
        assert!(store.touch(&h1) && store.touch(&h2));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn lru_eviction_respects_recency() {
        let dir = tmpdir("lru");
        let (a1, a2, a3) = (art(1), art(2), art(3));
        // Budget: room for two artifacts, not three.
        let cap = (a1.encoded_len() + a2.encoded_len() + a3.encoded_len() / 2) as u64;
        let store = ArtifactStore::open(&dir, cap).unwrap();
        store.insert(&a1).unwrap();
        store.insert(&a2).unwrap();
        // Touch a1 so a2 is the least recently used.
        assert!(store.touch(&a1.hash()));
        let out = store.insert(&a3).unwrap();
        assert_eq!(out.evicted, 1);
        assert!(store.touch(&a1.hash()), "recently-used survivor");
        assert!(!store.touch(&a2.hash()), "LRU entry evicted");
        assert!(store.touch(&a3.hash()));
        assert!(!store.dir().join(format!("{}.{FILE_EXT}", a2.hash())).exists());
        let s = store.summary();
        assert_eq!(s.count, 2);
        assert!(s.total_bytes <= cap);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn oversized_artifact_refused_outright() {
        let dir = tmpdir("oversize");
        let store = ArtifactStore::open(&dir, 64).unwrap();
        let err = store.insert(&art(1)).unwrap_err();
        assert!(format!("{err:#}").contains("store budget"), "{err:#}");
        assert_eq!(store.summary().count, 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupted_stored_file_is_dropped_with_an_error() {
        let dir = tmpdir("corrupt");
        let store = ArtifactStore::open(&dir, 1 << 20).unwrap();
        let a = art(5);
        store.insert(&a).unwrap();
        // Truncate the stored file behind the store's back.
        let path = dir.join(format!("{}.{FILE_EXT}", a.hash()));
        std::fs::write(&path, &a.encode()[..10]).unwrap();
        assert!(store.get(&a.hash()).is_err());
        // The bad entry is out of the index now.
        assert!(!store.touch(&a.hash()));
        std::fs::remove_dir_all(&dir).ok();
    }
}
