//! Orthoptimizers: POGO (this paper) and every baseline it is evaluated
//! against (§5), as pure-Rust reference engines over the in-crate linalg
//! substrate. The XLA/Pallas engine for the matmul-only methods lives in
//! `crate::runtime` + `python/compile/`; integration tests assert the two
//! engines agree step-for-step.
//!
//! Construction is centralized: [`registry`] is the ONE place that maps a
//! [`Method`] (plus an `OptimizerSpec`) to a concrete optimizer, for any
//! scalar type and for both the real and complex Stiefel manifolds. Adding
//! an orthoptimizer means adding its module here and one arm in
//! `registry::construct` — nothing else in the crate changes.
//!
//! Terminology follows the paper: an *orthoptimizer* updates a wide matrix
//! `X ∈ St(p, n)` given the Euclidean gradient `∇f(X)`; a *base optimizer*
//! (§3.1) transforms raw gradients before the geometry is applied (only
//! *linear* base optimizers — Def. 1 — preserve tangent-space semantics).

pub mod adam;
pub mod base;
pub mod batched;
pub mod landing;
pub mod pogo;
pub mod quartic;
pub mod registry;
pub mod rgd;
pub mod rsdm;
pub mod slpg;
pub mod unitary;

use crate::linalg::{BatchMat, Field, Mat};
use anyhow::{ensure, Result};

/// A single-matrix orthoptimizer over `St(p, n)` of the element field
/// `E`: the real Stiefel manifold for `E = f32`/`f64`, the complex
/// (unitary) one for `E = Complex<S>` — one trait, both manifolds
/// (paper §2, fn. 1).
///
/// `idx` identifies the parameter so stateful methods (momentum, VAdam)
/// keep per-matrix state; implementations must accept any `idx <
/// n_params` passed at construction.
///
/// Stepping is fallible: the host engines never fail, but the XLA-backed
/// engines surface dispatch errors (missing artifact, shape mismatch,
/// runtime failure) as `Err` instead of panicking inside the trait impl,
/// so they propagate to the Trainer/CLI.
///
/// Deliberately NOT `Send`: the XLA-backed engines hold PJRT handles
/// (raw pointers) and the coordinator's step loop is single-threaded —
/// parallelism lives inside the linalg substrate and inside XLA.
pub trait Orthoptimizer<E: Field = f32> {
    /// In-place update of `x` given Euclidean gradient `g`.
    fn step(&mut self, idx: usize, x: &mut Mat<E>, g: &Mat<E>) -> Result<()>;

    /// Update all matrices of a group (default: sequential loop).
    /// The XLA-backed engines override this with one batched dispatch.
    fn step_group(&mut self, xs: &mut [Mat<E>], gs: &[Mat<E>]) -> Result<()> {
        ensure!(
            xs.len() == gs.len(),
            "step_group: {} points vs {} gradients",
            xs.len(),
            gs.len()
        );
        for (i, (x, g)) in xs.iter_mut().zip(gs.iter()).enumerate() {
            self.step(i, x, g)?;
        }
        Ok(())
    }

    /// Update a whole `(B, p, n)` batch in place. Default: unpack into
    /// per-matrix views and delegate to [`Orthoptimizer::step_group`].
    /// The batched host engine overrides this to run directly on the
    /// contiguous buffer (no per-matrix allocation at all); engines that
    /// do so should also return `true` from
    /// [`Orthoptimizer::prefers_batch`] so the coordinator extracts
    /// groups as one [`BatchMat`] instead of a `Vec<Mat>`.
    fn step_batch(&mut self, xs: &mut BatchMat<E>, gs: &BatchMat<E>) -> Result<()> {
        ensure!(
            xs.shape() == gs.shape(),
            "step_batch: points {:?} vs gradients {:?}",
            xs.shape(),
            gs.shape()
        );
        let mut xv = xs.to_mats();
        let gv = gs.to_mats();
        self.step_group(&mut xv, &gv)?;
        for (i, m) in xv.iter().enumerate() {
            xs.set_mat(i, m);
        }
        Ok(())
    }

    /// Whether this engine's native unit of work is a packed
    /// [`BatchMat`] (the coordinator then uses the zero-unpack path).
    fn prefers_batch(&self) -> bool {
        false
    }

    /// Human-readable name for logs/figures.
    fn name(&self) -> &str;

    /// Current learning rate (schedulers mutate it through `set_lr`).
    fn lr(&self) -> f64;
    fn set_lr(&mut self, lr: f64);

    /// λ chosen by the most recent step, for methods that have one (POGO);
    /// telemetry for the λ-policy ablation.
    fn last_lambda(&self) -> Option<f64> {
        None
    }
}

/// Which engine executes an optimizer's update rule.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Engine {
    /// Pure-Rust reference implementation: a sequential per-matrix loop
    /// over the group (this module's single-matrix optimizers).
    Rust,
    /// Pure-Rust batched engine: the whole `(B, p, n)` shape group packed
    /// into one [`BatchMat`] and stepped with batch-parallel kernels
    /// ([`batched`] module). Matmul-only methods plus Adam.
    BatchedHost,
    /// AOT-compiled HLO executable via PJRT (L1/L2 path).
    Xla,
}

impl Engine {
    pub fn name(&self) -> &'static str {
        match self {
            Engine::Rust => "rust",
            Engine::BatchedHost => "batched-host",
            Engine::Xla => "xla",
        }
    }

    pub fn parse(s: &str) -> Option<Engine> {
        Some(match s.to_ascii_lowercase().as_str() {
            "rust" => Engine::Rust,
            "batched-host" | "batched_host" | "batched" => Engine::BatchedHost,
            "xla" => Engine::Xla,
            _ => return None,
        })
    }
}

/// Identifier for every optimizer the paper evaluates (Fig. 4–8).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    Pogo,
    Landing,
    LandingPC,
    Slpg,
    Rgd,
    Rsdm,
    Adam,
}

impl Method {
    pub fn name(&self) -> &'static str {
        match self {
            Method::Pogo => "POGO",
            Method::Landing => "Landing",
            Method::LandingPC => "LandingPC",
            Method::Slpg => "SLPG",
            Method::Rgd => "RGD",
            Method::Rsdm => "RSDM",
            Method::Adam => "Adam",
        }
    }

    pub fn parse(s: &str) -> Option<Method> {
        Some(match s.to_ascii_lowercase().as_str() {
            "pogo" => Method::Pogo,
            "landing" => Method::Landing,
            "landingpc" | "landing-pc" | "landing_pc" => Method::LandingPC,
            "slpg" => Method::Slpg,
            "rgd" => Method::Rgd,
            "rsdm" => Method::Rsdm,
            "adam" => Method::Adam,
            _ => return None,
        })
    }

    /// All orthoptimizers compared in Fig. 4 (plus Adam for NN figures).
    pub fn all() -> &'static [Method] {
        &[
            Method::Pogo,
            Method::Landing,
            Method::LandingPC,
            Method::Slpg,
            Method::Rgd,
            Method::Rsdm,
            Method::Adam,
        ]
    }

    /// Static capabilities of this method (see [`registry`]).
    pub fn capabilities(&self) -> registry::Capabilities {
        registry::capabilities(*self)
    }

    /// Whether the update rule is matmul-only (accelerator-friendly — can
    /// be dispatched through the XLA engine).
    pub fn is_matmul_only(&self) -> bool {
        self.capabilities().matmul_only
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parse_roundtrip() {
        for &m in Method::all() {
            assert_eq!(Method::parse(m.name()), Some(m));
        }
        assert_eq!(Method::parse("landing-pc"), Some(Method::LandingPC));
        assert_eq!(Method::parse("bogus"), None);
    }

    #[test]
    fn engine_parse_roundtrip() {
        for e in [Engine::Rust, Engine::BatchedHost, Engine::Xla] {
            assert_eq!(Engine::parse(e.name()), Some(e));
        }
        assert_eq!(Engine::parse("batched"), Some(Engine::BatchedHost));
        assert_eq!(Engine::parse("tpu"), None);
    }

    #[test]
    fn matmul_only_classification() {
        assert!(Method::Pogo.is_matmul_only());
        assert!(!Method::Rgd.is_matmul_only());
        assert!(!Method::Rsdm.is_matmul_only());
        assert!(!Method::Adam.is_matmul_only()); // unconstrained, trivial anyway
    }

    #[test]
    fn default_step_group_checks_lengths() {
        struct Null;
        impl Orthoptimizer<f32> for Null {
            fn step(&mut self, _: usize, _: &mut Mat<f32>, _: &Mat<f32>) -> Result<()> {
                Ok(())
            }
            fn name(&self) -> &str {
                "null"
            }
            fn lr(&self) -> f64 {
                0.0
            }
            fn set_lr(&mut self, _: f64) {}
        }
        let mut opt = Null;
        let mut xs = vec![Mat::<f32>::zeros(2, 2)];
        let gs = vec![Mat::<f32>::zeros(2, 2); 2];
        assert!(opt.step_group(&mut xs, &gs).is_err());
        assert!(opt.step_group(&mut xs, &gs[..1]).is_ok());
    }
}
