//! Base optimizers (§3.1): the unconstrained transform `G = BO(∇f(X))`
//! applied *before* the geometry. POGO composes with any of these; the
//! paper's Def. 1 requires *linearity* (`G ∝ A ∇f`) for the tangent-space
//! semantics to be preserved, which holds for SGD, momentum-SGD and VAdam
//! (vector-wise normalization) but *not* elementwise Adam.

use crate::linalg::{Field, Mat, Scalar};
use crate::util::json::Json;
use anyhow::{anyhow, Result};

/// Kind + hyperparameters of a base optimizer, the serializable config.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum BaseOptKind {
    /// Identity: G = ∇f. Trivially linear.
    Sgd,
    /// Heavy-ball momentum: m ← β m + ∇f; G = m. Linear.
    Momentum { beta: f64 },
    /// Vector Adam (Ling et al., 2022): Adam with the elementwise second
    /// moment replaced by the *global* (matrix-wise) norm, making it linear
    /// per Def. 1 and bounding ‖G‖ ≈ 1 (the ξ < 1 control of Thm 3.5).
    VAdam { beta1: f64, beta2: f64, eps: f64 },
    /// Elementwise Adam — NOT linear; included to ablate Def. 1 and as the
    /// unconstrained baseline's core.
    Adam { beta1: f64, beta2: f64, eps: f64 },
}

impl BaseOptKind {
    pub fn momentum(beta: f64) -> Self {
        BaseOptKind::Momentum { beta }
    }
    pub fn vadam() -> Self {
        BaseOptKind::VAdam { beta1: 0.9, beta2: 0.999, eps: 1e-8 }
    }
    pub fn adam() -> Self {
        BaseOptKind::Adam { beta1: 0.9, beta2: 0.999, eps: 1e-8 }
    }

    pub fn name(&self) -> &'static str {
        match self {
            BaseOptKind::Sgd => "sgd",
            BaseOptKind::Momentum { .. } => "momentum",
            BaseOptKind::VAdam { .. } => "vadam",
            BaseOptKind::Adam { .. } => "adam",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s.to_ascii_lowercase().as_str() {
            "sgd" => BaseOptKind::Sgd,
            "momentum" => BaseOptKind::momentum(0.9),
            "vadam" => BaseOptKind::vadam(),
            "adam" => BaseOptKind::adam(),
            _ => return None,
        })
    }

    /// Linearity in the sense of Def. 1.
    pub fn is_linear(&self) -> bool {
        !matches!(self, BaseOptKind::Adam { .. })
    }

    /// Serialize with hyperparameters (kind alone is lossy for
    /// momentum/VAdam/Adam).
    pub fn to_json(&self) -> Json {
        match *self {
            BaseOptKind::Sgd => Json::obj(vec![("kind", Json::str("sgd"))]),
            BaseOptKind::Momentum { beta } => Json::obj(vec![
                ("beta", Json::num(beta)),
                ("kind", Json::str("momentum")),
            ]),
            BaseOptKind::VAdam { beta1, beta2, eps } => Json::obj(vec![
                ("beta1", Json::num(beta1)),
                ("beta2", Json::num(beta2)),
                ("eps", Json::num(eps)),
                ("kind", Json::str("vadam")),
            ]),
            BaseOptKind::Adam { beta1, beta2, eps } => Json::obj(vec![
                ("beta1", Json::num(beta1)),
                ("beta2", Json::num(beta2)),
                ("eps", Json::num(eps)),
                ("kind", Json::str("adam")),
            ]),
        }
    }

    /// Parse the `to_json` form; missing hyperparameters take the
    /// constructor defaults, but present-yet-malformed ones are errors
    /// (a replayed config must not silently change hyperparameters).
    pub fn from_json(j: &Json) -> Result<BaseOptKind> {
        fn num_or(j: &Json, key: &str, default: f64) -> Result<f64> {
            match j.get(key) {
                Json::Null => Ok(default),
                v => v
                    .as_f64()
                    .ok_or_else(|| anyhow!("base optimizer: '{key}' must be a number")),
            }
        }
        let kind = j
            .get("kind")
            .as_str()
            .ok_or_else(|| anyhow!("base optimizer: missing 'kind'"))?;
        let base = match Self::parse(kind) {
            Some(b) => b,
            None => return Err(anyhow!("base optimizer: unknown kind '{kind}'")),
        };
        Ok(match base {
            BaseOptKind::Sgd => BaseOptKind::Sgd,
            BaseOptKind::Momentum { beta } => {
                BaseOptKind::Momentum { beta: num_or(j, "beta", beta)? }
            }
            BaseOptKind::VAdam { beta1, beta2, eps } => BaseOptKind::VAdam {
                beta1: num_or(j, "beta1", beta1)?,
                beta2: num_or(j, "beta2", beta2)?,
                eps: num_or(j, "eps", eps)?,
            },
            BaseOptKind::Adam { beta1, beta2, eps } => BaseOptKind::Adam {
                beta1: num_or(j, "beta1", beta1)?,
                beta2: num_or(j, "beta2", beta2)?,
                eps: num_or(j, "eps", eps)?,
            },
        })
    }
}

/// Per-parameter state for a base optimizer.
#[derive(Clone, Debug)]
enum State<E: Field> {
    None,
    Momentum { m: Option<Mat<E>> },
    VAdam { m: Option<Mat<E>>, v: f64, t: u64 },
    Adam { m: Option<Mat<E>>, v: Option<Mat<E>>, t: u64 },
}

/// A base optimizer instance managing `n_params` parameter slots.
/// Field-generic: the same momentum/VAdam state machine serves the real
/// and the complex optimizers (for complex fields only the *linear* kinds
/// of Def. 1 are admissible — enforced at construction).
#[derive(Clone, Debug)]
pub struct BaseOpt<E: Field> {
    kind: BaseOptKind,
    states: Vec<State<E>>,
}

impl<E: Field> BaseOpt<E> {
    pub fn new(kind: BaseOptKind, n_params: usize) -> Self {
        assert!(
            kind.is_linear() || !E::COMPLEX,
            "complex base optimizers must be linear (Def. 1); got {}",
            kind.name()
        );
        let init = |_: usize| match kind {
            BaseOptKind::Sgd => State::None,
            BaseOptKind::Momentum { .. } => State::Momentum { m: None },
            BaseOptKind::VAdam { .. } => State::VAdam { m: None, v: 0.0, t: 0 },
            BaseOptKind::Adam { .. } => State::Adam { m: None, v: None, t: 0 },
        };
        BaseOpt { kind, states: (0..n_params).map(init).collect() }
    }

    pub fn kind(&self) -> BaseOptKind {
        self.kind
    }

    /// Grow the slot table (used when parameters are registered late).
    pub fn ensure_slots(&mut self, n_params: usize) {
        while self.states.len() < n_params {
            let idx = self.states.len();
            let s = match self.kind {
                BaseOptKind::Sgd => State::None,
                BaseOptKind::Momentum { .. } => State::Momentum { m: None },
                BaseOptKind::VAdam { .. } => State::VAdam { m: None, v: 0.0, t: 0 },
                BaseOptKind::Adam { .. } => State::Adam { m: None, v: None, t: 0 },
            };
            let _ = idx;
            self.states.push(s);
        }
    }

    /// Transform a raw gradient: `G = BO(∇f)`.
    pub fn transform(&mut self, idx: usize, grad: &Mat<E>) -> Mat<E> {
        assert!(idx < self.states.len(), "param index {idx} out of range");
        match (&self.kind, &mut self.states[idx]) {
            (BaseOptKind::Sgd, _) => grad.clone(),
            (BaseOptKind::Momentum { beta }, State::Momentum { m }) => {
                let beta = E::from_f64(*beta);
                match m {
                    Some(mm) => {
                        mm.scale_inplace(beta);
                        mm.axpy(E::ONE, grad);
                    }
                    None => *m = Some(grad.clone()),
                }
                m.as_ref().unwrap().clone()
            }
            (BaseOptKind::VAdam { beta1, beta2, eps }, State::VAdam { m, v, t }) => {
                *t += 1;
                let b1 = E::from_f64(*beta1);
                match m {
                    Some(mm) => {
                        mm.scale_inplace(b1);
                        mm.axpy(E::from_f64(1.0 - *beta1), grad);
                    }
                    None => *m = Some(grad.scale(E::from_f64(1.0 - *beta1))),
                }
                // Matrix-wise second moment (one scalar per parameter):
                // v ← β₂ v + (1−β₂) ‖∇f‖². Always real, on either field.
                let gn2 = grad.norm_sq().to_f64();
                *v = *beta2 * *v + (1.0 - *beta2) * gn2;
                // Bias corrections.
                let mhat_scale = 1.0 / (1.0 - beta1.powi(*t as i32));
                let vhat = *v / (1.0 - beta2.powi(*t as i32));
                // G = m̂ / (√v̂ + ε) — a *scalar* multiple of m̂: linear.
                let denom = vhat.sqrt() + *eps;
                m.as_ref().unwrap().scale(E::from_f64(mhat_scale / denom))
            }
            (BaseOptKind::Adam { beta1, beta2, eps }, State::Adam { m, v, t }) => {
                *t += 1;
                let b1 = E::from_f64(*beta1);
                let b2 = E::from_f64(*beta2);
                match m {
                    Some(mm) => {
                        mm.scale_inplace(b1);
                        mm.axpy(E::from_f64(1.0 - *beta1), grad);
                    }
                    None => *m = Some(grad.scale(E::from_f64(1.0 - *beta1))),
                }
                let g2 = grad.map(|x| x * x);
                match v {
                    Some(vv) => {
                        vv.scale_inplace(b2);
                        vv.axpy(E::from_f64(1.0 - *beta2), &g2);
                    }
                    None => *v = Some(g2.scale(E::from_f64(1.0 - *beta2))),
                }
                let mc = 1.0 / (1.0 - beta1.powi(*t as i32));
                let vc = 1.0 / (1.0 - beta2.powi(*t as i32));
                let eps_s = E::from_f64(*eps);
                let mhat = m.as_ref().unwrap().scale(E::from_f64(mc));
                let vhat = v.as_ref().unwrap().scale(E::from_f64(vc));
                mhat.zip(&vhat, |mi, vi| mi / (vi.sqrt() + eps_s))
            }
            _ => unreachable!("state/kind mismatch"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    type M = Mat<f64>;

    #[test]
    fn sgd_is_identity() {
        let mut rng = Rng::seed_from_u64(0);
        let g = M::randn(4, 6, &mut rng);
        let mut bo = BaseOpt::new(BaseOptKind::Sgd, 1);
        assert_eq!(bo.transform(0, &g), g);
    }

    #[test]
    fn momentum_accumulates() {
        let g = M::ones(2, 2);
        let mut bo = BaseOpt::new(BaseOptKind::momentum(0.5), 1);
        let g1 = bo.transform(0, &g); // m = g
        let g2 = bo.transform(0, &g); // m = 0.5 g + g = 1.5 g
        assert!((g1[(0, 0)] - 1.0).abs() < 1e-12);
        assert!((g2[(0, 0)] - 1.5).abs() < 1e-12);
    }

    #[test]
    fn vadam_is_linear_def1() {
        // Def. 1: scaling the input gradient stream by c scales the output
        // by exactly c (same direction, proportional magnitude... for VAdam
        // the normalization makes output *invariant* to c — still linear
        // "up to scaling" as the direction is a fixed linear map of input).
        let mut rng = Rng::seed_from_u64(1);
        let g = M::randn(3, 5, &mut rng);
        let mut bo1 = BaseOpt::new(BaseOptKind::vadam(), 1);
        let mut bo2 = BaseOpt::new(BaseOptKind::vadam(), 1);
        let out1 = bo1.transform(0, &g);
        let out2 = bo2.transform(0, &g.scale(3.0));
        // Directions must match exactly (cosine = 1).
        let cos = out1.dot(&out2).to_f64() / (out1.norm() * out2.norm()).to_f64();
        assert!((cos - 1.0).abs() < 1e-9, "cos={cos}");
    }

    #[test]
    fn adam_is_not_linear() {
        // Elementwise normalization destroys direction preservation for a
        // *sum* of gradients; show Adam(g1 + g2) direction differs from
        // Adam(g1) + Adam(g2)-style linearity proxy: use two steps instead.
        let mut rng = Rng::seed_from_u64(2);
        let g = M::randn(3, 5, &mut rng);
        let mut bo = BaseOpt::new(BaseOptKind::adam(), 1);
        let out = bo.transform(0, &g);
        // Adam's first-step output is sign(g)-ish, not proportional to g.
        let cos = out.dot(&g).to_f64() / (out.norm() * g.norm()).to_f64();
        assert!(cos < 0.999, "Adam unexpectedly proportional: cos={cos}");
    }

    #[test]
    fn vadam_norm_bounded() {
        // After bias correction the output norm ≈ ‖m̂‖/√v̂ ≤ ~1 when the
        // gradient stream is i.i.d.; check it stays modest over steps
        // (this is the ‖G‖ ≤ L control that Thm 3.5 relies on).
        let mut rng = Rng::seed_from_u64(3);
        let mut bo = BaseOpt::<f64>::new(BaseOptKind::vadam(), 1);
        for _ in 0..50 {
            let g = M::randn(6, 8, &mut rng).scale(10.0); // large raw grads
            let out = bo.transform(0, &g);
            assert!(out.norm() < 3.0, "‖G‖={}", out.norm());
        }
    }

    #[test]
    fn state_slots_are_independent() {
        let mut bo = BaseOpt::<f64>::new(BaseOptKind::momentum(0.9), 2);
        let g = M::ones(2, 2);
        bo.transform(0, &g);
        bo.transform(0, &g);
        let fresh = bo.transform(1, &g); // slot 1 unaffected by slot 0
        assert!((fresh[(0, 0)] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ensure_slots_grows() {
        let mut bo = BaseOpt::<f64>::new(BaseOptKind::vadam(), 1);
        bo.ensure_slots(5);
        let g = M::ones(1, 1);
        let _ = bo.transform(4, &g);
    }

    #[test]
    fn complex_base_rejects_nonlinear() {
        // Def. 1: elementwise Adam is not linear, so it has no complex
        // instantiation — construction must refuse.
        use crate::linalg::Complex;
        let result = std::panic::catch_unwind(|| {
            BaseOpt::<Complex<f64>>::new(BaseOptKind::adam(), 1);
        });
        assert!(result.is_err());
        // Linear kinds are fine on the complex field.
        let _ = BaseOpt::<Complex<f64>>::new(BaseOptKind::vadam(), 1);
    }

    #[test]
    fn parse_names() {
        for n in ["sgd", "momentum", "vadam", "adam"] {
            assert_eq!(BaseOptKind::parse(n).unwrap().name(), n);
        }
        assert!(BaseOptKind::parse("sgdm").is_none());
    }

    #[test]
    fn json_roundtrip_preserves_hyperparams() {
        for kind in [
            BaseOptKind::Sgd,
            BaseOptKind::momentum(0.35),
            BaseOptKind::VAdam { beta1: 0.8, beta2: 0.95, eps: 1e-6 },
            BaseOptKind::Adam { beta1: 0.5, beta2: 0.9, eps: 1e-7 },
        ] {
            let text = kind.to_json().to_string();
            let back =
                BaseOptKind::from_json(&crate::util::json::Json::parse(&text).unwrap())
                    .unwrap();
            assert_eq!(kind, back, "{text}");
            assert_eq!(back.to_json().to_string(), text);
        }
        assert!(BaseOptKind::from_json(&crate::util::json::Json::Null).is_err());
    }
}
