//! The batched host engine (`Engine::BatchedHost`): whole `(B, p, n)`
//! shape groups stepped as one [`BatchMat`], parallelized over the batch
//! dimension. Field-generic: `BatchedHost<f32>` steps real Stiefel
//! groups, `BatchedHost<Complex<S>>` steps unitary groups (the Fig. 8
//! Born-MPS regime), through the same code.
//!
//! This is the host-side mechanism behind the paper's Fig. 1 claim
//! (thousands of matrices in minutes): the per-matrix host loop spends its
//! time in allocator churn and 54-flop matmuls that can never cross the
//! thread threshold, while this engine steps the packed group in bulk.
//!
//! **Two execution paths**, selected by [`KernelChoice`] (spec key
//! `"kernel"`, default `auto`):
//!
//! - *fused* — POGO and Landing/LandingPC run the whole per-matrix update
//!   as ONE sweep per batch element
//!   ([`StepKernel::pogo_step`](crate::linalg::StepKernel) /
//!   `landing_step`): each worker walks its batch chunk matrix-by-matrix
//!   with an `O(p·n)` scratch resident in L1/L2, instead of 5+ full
//!   passes over the `(B, p, n)` buffer. This is the `auto` default.
//! - *naive* — the historical 5-pass `BatchMat` composition, one
//!   batch-parallel kernel per product. SLPG and Adam always run here
//!   (no fused rule).
//!
//! Both paths bottom out in the same runtime-selected `StepKernel` row
//! primitives and perform the same elementwise arithmetic in the same
//! order, so they are bit-identical — `tests/fused_parity.rs` pins this
//! elementwise, which is what lets `auto` default to fused without any
//! replay/checkpoint compatibility caveat.
//!
//! **Parity contract** (pinned by `tests/batched_parity.rs`): every rule
//! here performs the *same elementwise arithmetic in the same order* as
//! its per-matrix counterpart in [`pogo`](super::pogo) /
//! [`landing`](super::landing) / [`slpg`](super::slpg) /
//! [`adam`](super::adam) — the batched kernels invoke the identical
//! serial row-range kernels per batch element — so batched and looped
//! trajectories agree elementwise, not just statistically. Base-optimizer
//! state (momentum / VAdam / Adam) is held batched: one `(B, p, n)`
//! moment tensor (plus per-matrix scalars for VAdam) instead of B small
//! matrices.

use super::base::BaseOptKind;
use super::pogo::{landing_coeffs, landing_coeffs_slice, with_coeff_scratch, LambdaPolicy};
use super::quartic::solve_landing_quartic;
use super::Orthoptimizer;
use crate::linalg::{
    batch_a_bh, batch_matmul, for_each_mat_fused, fused_step_flops, shape_class,
    with_step_scratch, BatchMat, Field, KernelChoice, LandingParams, Mat, PogoLambda, Scalar,
};
use anyhow::{ensure, Result};
use std::time::Instant;

/// Which update rule a [`BatchedHost`] runs.
#[derive(Clone, Copy, Debug)]
enum Rule {
    Pogo { lambda: LambdaPolicy },
    Landing { attraction: f64, eps_ball: f64, safeguard: bool, normalize_grad: bool },
    Slpg,
    /// Unconstrained Adam (the base optimizer IS the update).
    Adam,
}

/// Batched base-optimizer state: the batched analogue of
/// [`super::base::BaseOpt`], with one packed moment tensor for the whole
/// group. Lazily sized on the first transform (groups have a fixed B).
struct BatchedBase<E: Field> {
    kind: BaseOptKind,
    /// First moment (momentum / VAdam / Adam).
    m: Option<BatchMat<E>>,
    /// Elementwise second moment (Adam only).
    v: Option<BatchMat<E>>,
    /// Per-matrix scalar second moment (VAdam only).
    v_scalar: Vec<f64>,
    /// Step count (shared: every matrix of a group steps together).
    t: u64,
    /// Reusable transformed-gradient output (VAdam / Adam): sized on first
    /// use, overwritten every step so the steady state never allocates.
    out: Option<BatchMat<E>>,
    /// Reusable per-matrix squared gradient norms (VAdam).
    gn2: Vec<E::Real>,
    /// Reusable per-matrix scale factors (VAdam).
    alphas: Vec<E>,
}

impl<E: Field> BatchedBase<E> {
    fn new(kind: BaseOptKind) -> Self {
        // Same Def. 1 gate as `BaseOpt::new`: elementwise Adam has no
        // complex instantiation (z² is not |z|²), so the batched engine
        // must refuse it too — parity with the loop engine includes the
        // construction contract.
        assert!(
            kind.is_linear() || !E::COMPLEX,
            "complex base optimizers must be linear (Def. 1); got {}",
            kind.name()
        );
        BatchedBase {
            kind,
            m: None,
            v: None,
            v_scalar: Vec::new(),
            t: 0,
            out: None,
            gn2: Vec::new(),
            alphas: Vec::new(),
        }
    }

    /// `G = BO(∇f)` over the whole batch, mirroring
    /// `BaseOpt::transform` per matrix (same order of operations, same
    /// f64 scalar paths). Returns a borrow: either the input itself (Sgd),
    /// the moment tensor (Momentum), or the reusable `out` buffer — no
    /// per-step clone.
    fn transform<'a>(&'a mut self, grad: &'a BatchMat<E>) -> Result<&'a BatchMat<E>> {
        if let Some(m) = &self.m {
            ensure!(
                m.shape() == grad.shape(),
                "batched base state {:?} vs gradient batch {:?} — one \
                 BatchedHost per shape group",
                m.shape(),
                grad.shape()
            );
        }
        Ok(match self.kind {
            BaseOptKind::Sgd => grad,
            BaseOptKind::Momentum { beta } => {
                match &mut self.m {
                    Some(m) => {
                        m.scale_inplace(E::from_f64(beta));
                        m.axpy(E::ONE, grad);
                    }
                    None => self.m = Some(grad.clone()),
                }
                self.m.as_ref().unwrap()
            }
            BaseOptKind::VAdam { beta1, beta2, eps } => {
                self.t += 1;
                match &mut self.m {
                    Some(m) => {
                        m.scale_inplace(E::from_f64(beta1));
                        m.axpy(E::from_f64(1.0 - beta1), grad);
                    }
                    None => {
                        let mut m = grad.clone();
                        m.scale_inplace(E::from_f64(1.0 - beta1));
                        self.m = Some(m);
                    }
                }
                // Matrix-wise second moment: one scalar per batch element.
                if self.v_scalar.is_empty() {
                    self.v_scalar = vec![0.0; grad.batch()];
                }
                grad.norm_sq_per_mat_into(&mut self.gn2);
                let mhat_scale = 1.0 / (1.0 - beta1.powi(self.t as i32));
                let v_corr = 1.0 - beta2.powi(self.t as i32);
                self.alphas.clear();
                for (v, &g2) in self.v_scalar.iter_mut().zip(&self.gn2) {
                    *v = beta2 * *v + (1.0 - beta2) * g2.to_f64();
                    let vhat = *v / v_corr;
                    self.alphas.push(E::from_f64(mhat_scale / (vhat.sqrt() + eps)));
                }
                let m = self.m.as_ref().unwrap();
                match &mut self.out {
                    Some(out) => out.as_mut_slice().copy_from_slice(m.as_slice()),
                    None => self.out = Some(m.clone()),
                }
                let out = self.out.as_mut().unwrap();
                out.scale_per_mat(&self.alphas);
                out
            }
            BaseOptKind::Adam { beta1, beta2, eps } => {
                self.t += 1;
                match &mut self.m {
                    Some(m) => {
                        m.scale_inplace(E::from_f64(beta1));
                        m.axpy(E::from_f64(1.0 - beta1), grad);
                    }
                    None => {
                        let mut m = grad.clone();
                        m.scale_inplace(E::from_f64(1.0 - beta1));
                        self.m = Some(m);
                    }
                }
                // v ← β₂v + (1−β₂)g², with g² formed on the fly: the same
                // scale-then-axpy elementwise ops as the old
                // `grad.map(|x| x*x)` temp, without the temp.
                match &mut self.v {
                    Some(v) => {
                        let b2 = E::from_f64(beta2);
                        let c2 = E::from_f64(1.0 - beta2);
                        v.zip_inplace(grad, |vi, gv| {
                            *vi *= b2;
                            *vi += c2 * (gv * gv);
                        });
                    }
                    None => {
                        let mut v = grad.map(|x| x * x);
                        v.scale_inplace(E::from_f64(1.0 - beta2));
                        self.v = Some(v);
                    }
                }
                let mc = E::from_f64(1.0 / (1.0 - beta1.powi(self.t as i32)));
                let vc = E::from_f64(1.0 / (1.0 - beta2.powi(self.t as i32)));
                let eps_s = E::from_f64(eps);
                let m = self.m.as_ref().unwrap();
                let v = self.v.as_ref().unwrap();
                // out_i = m̂_i / (√v̂_i + ε): same multiply-scale → sqrt →
                // divide order as the old mhat/vhat clones.
                let f = |mi: E, vi: E| (mi * mc) / ((vi * vc).sqrt() + eps_s);
                match &mut self.out {
                    Some(out) => m.zip_into(v, out, f),
                    None => self.out = Some(m.zip(v, f)),
                }
                self.out.as_ref().unwrap()
            }
        })
    }
}

/// Batched host engine over one shape group.
///
/// Implements [`Orthoptimizer`] so it drops into every construction site,
/// but its native unit of work is [`Orthoptimizer::step_batch`]
/// (`prefers_batch() == true`): the coordinator extracts the group as one
/// packed tensor and never allocates per-matrix intermediates.
///
/// State is batch-wide (like the XLA stepper): `step(idx, …)` treats its
/// input as a batch of one, so a `BatchedHost` must own exactly one shape
/// group — which is how `OptimSession` builds them.
pub struct BatchedHost<E: Field = f32> {
    rule: Rule,
    lr: f64,
    base: BatchedBase<E>,
    name: String,
    last_lambda: Option<f64>,
    kernel: KernelChoice,
    /// Reusable per-matrix f64 slots for the fused sweep (POGO's λ /
    /// Landing's safeguarded η) — sized on first step, reused after.
    lam_buf: Vec<f64>,
    /// Reusable per-matrix coefficient buffers for the naive paths
    /// (FindRoot's −λ scales, Landing's −η / −ηλ pairs).
    coef_a: Vec<E>,
    coef_b: Vec<E>,
    /// Cached per-step histogram handle (`pogo_step_duration_seconds`).
    /// A host owns one shape group and one kernel choice, so the labels —
    /// and therefore the series — never change after the first step.
    step_hist: Option<&'static crate::obs::Hist>,
}

impl<E: Field> BatchedHost<E> {
    /// Batched POGO (Alg. 1): the 5-matmul step + proximal normal step.
    pub fn pogo(lr: f64, lambda: LambdaPolicy, base: BaseOptKind) -> Self {
        let name = match lambda {
            LambdaPolicy::Half => format!("POGO({})[batched]", base.name()),
            LambdaPolicy::FindRoot => format!("POGO-root({})[batched]", base.name()),
        };
        BatchedHost {
            rule: Rule::Pogo { lambda },
            lr,
            base: BatchedBase::new(base),
            name,
            last_lambda: Some(0.5),
            kernel: KernelChoice::Auto,
            lam_buf: Vec::new(),
            coef_a: Vec::new(),
            coef_b: Vec::new(),
            step_hist: None,
        }
    }

    /// Select the execution path (`auto`/`fused`/`naive`) — see the module
    /// docs; bit-identical either way, so this is a pure perf knob.
    pub fn with_kernel(mut self, kernel: KernelChoice) -> Self {
        self.kernel = kernel;
        self
    }

    /// Batched Landing (safeguarded, paper defaults ε = 0.5).
    pub fn landing(lr: f64, attraction: f64, base: BaseOptKind) -> Self {
        BatchedHost {
            rule: Rule::Landing {
                attraction,
                eps_ball: 0.5,
                safeguard: true,
                normalize_grad: false,
            },
            lr,
            base: BatchedBase::new(base),
            name: format!("Landing({})[batched]", base.name()),
            last_lambda: None,
            kernel: KernelChoice::Auto,
            lam_buf: Vec::new(),
            coef_a: Vec::new(),
            coef_b: Vec::new(),
            step_hist: None,
        }
    }

    /// Batched LandingPC (per-matrix gradient normalization, no safeguard).
    pub fn landing_pc(lr: f64, attraction: f64) -> Self {
        BatchedHost {
            rule: Rule::Landing {
                attraction,
                eps_ball: 0.5,
                safeguard: false,
                normalize_grad: true,
            },
            lr,
            base: BatchedBase::new(BaseOptKind::Sgd),
            name: "LandingPC[batched]".to_string(),
            last_lambda: None,
            kernel: KernelChoice::Auto,
            lam_buf: Vec::new(),
            coef_a: Vec::new(),
            coef_b: Vec::new(),
            step_hist: None,
        }
    }

    /// Batched SLPG (smooth case).
    pub fn slpg(lr: f64, base: BaseOptKind) -> Self {
        BatchedHost {
            rule: Rule::Slpg,
            lr,
            base: BatchedBase::new(base),
            name: "SLPG[batched]".to_string(),
            last_lambda: None,
            kernel: KernelChoice::Auto,
            lam_buf: Vec::new(),
            coef_a: Vec::new(),
            coef_b: Vec::new(),
            step_hist: None,
        }
    }

    /// Batched unconstrained Adam (the NN figures' free-parameter rule).
    pub fn adam(lr: f64) -> Self {
        BatchedHost {
            rule: Rule::Adam,
            lr,
            base: BatchedBase::new(BaseOptKind::adam()),
            name: "Adam[batched]".to_string(),
            last_lambda: None,
            kernel: KernelChoice::Auto,
            lam_buf: Vec::new(),
            coef_a: Vec::new(),
            coef_b: Vec::new(),
            step_hist: None,
        }
    }

    /// Fused POGO over the batch: one `StepKernel::pogo_step` sweep per
    /// matrix, each worker reusing its thread-local `O(p·n)` scratch
    /// across its chunk AND across steps (resident workers persist).
    /// Returns the last matrix's λ (what `last_lambda` reports — matching
    /// the naive FindRoot loop, which overwrites `lam` per element).
    /// `lam_buf` is the host's reusable per-matrix λ storage.
    fn fused_pogo(
        x: &mut BatchMat<E>,
        g: &BatchMat<E>,
        eta: f64,
        lambda: LambdaPolicy,
        lam_buf: &mut Vec<f64>,
    ) -> f64 {
        let (b, p, n) = x.shape();
        let kern = E::step_kernel();
        let stride = p * n;
        let gdata = g.as_slice();
        // Per-matrix quartic roots from the p×p gram residuals (identical
        // arithmetic to the naive path: same coeffs through the same
        // slice-form computation, same solver — no per-solve allocation).
        let solve = |c: &[E], pp: usize| {
            with_coeff_scratch(pp, |s| solve_landing_quartic(landing_coeffs_slice(c, pp, s)))
        };
        let lam_policy = match lambda {
            LambdaPolicy::Half => PogoLambda::Const(0.5),
            LambdaPolicy::FindRoot => PogoLambda::Solve(&solve),
        };
        lam_buf.clear();
        lam_buf.resize(b, 0.5);
        for_each_mat_fused(x, lam_buf, fused_step_flops(b, p, n), |range, xc, lc| {
            with_step_scratch(p, n, |scratch| {
                for (ci, i) in range.clone().enumerate() {
                    lc[ci] = kern.pogo_step(
                        &mut xc[ci * stride..(ci + 1) * stride],
                        &gdata[i * stride..(i + 1) * stride],
                        p,
                        n,
                        eta,
                        &lam_policy,
                        scratch,
                    );
                }
            });
        });
        lam_buf.last().copied().unwrap_or(0.5)
    }

    /// Fused Landing/LandingPC over the batch (normalization, safeguard,
    /// and both axpys inside one per-matrix sweep). `eta_buf` is the
    /// host's reusable per-matrix safeguarded-η storage.
    fn fused_landing(
        x: &mut BatchMat<E>,
        g: &BatchMat<E>,
        params: LandingParams,
        eta_buf: &mut Vec<f64>,
    ) {
        let (b, p, n) = x.shape();
        let kern = E::step_kernel();
        let stride = p * n;
        let gdata = g.as_slice();
        eta_buf.clear();
        eta_buf.resize(b, params.eta);
        for_each_mat_fused(x, eta_buf, fused_step_flops(b, p, n), |range, xc, ec| {
            with_step_scratch(p, n, |scratch| {
                for (ci, i) in range.clone().enumerate() {
                    ec[ci] = kern.landing_step(
                        &mut xc[ci * stride..(ci + 1) * stride],
                        &gdata[i * stride..(i + 1) * stride],
                        p,
                        n,
                        &params,
                        scratch,
                    );
                }
            });
        });
    }

    /// One batched update of `x` given raw gradients `g0`.
    fn apply(&mut self, x: &mut BatchMat<E>, g0: &BatchMat<E>) -> Result<()> {
        ensure!(
            x.shape() == g0.shape(),
            "step_batch: points {:?} vs gradients {:?}",
            x.shape(),
            g0.shape()
        );
        if x.batch() == 0 {
            return Ok(());
        }
        // Observability: one clock pair per batched step (never per batch
        // element), gated so a disabled run does not read the clock.
        let t0 = crate::obs::enabled().then(Instant::now);
        let g = self.base.transform(g0)?;
        let eta = self.lr;
        let fused = !matches!(self.kernel, KernelChoice::Naive);
        match self.rule {
            Rule::Pogo { lambda } if fused => {
                self.last_lambda = Some(Self::fused_pogo(x, g, eta, lambda, &mut self.lam_buf));
            }
            Rule::Landing { attraction, eps_ball, safeguard, normalize_grad } if fused => {
                Self::fused_landing(
                    x,
                    g,
                    LandingParams { eta, attraction, eps_ball, safeguard, normalize_grad },
                    &mut self.lam_buf,
                );
            }
            Rule::Pogo { lambda } => {
                // M = X − η·½((X Xᴴ)G − (X Gᴴ)X)  (small-gram form).
                let xxh = batch_a_bh(x, x);
                let xgh = batch_a_bh(x, g);
                let a1 = batch_matmul(&xxh, g);
                let a2 = batch_matmul(&xgh, x);
                let mut m = x.clone();
                m.axpy(E::from_f64(-0.5 * eta), &a1);
                m.axpy(E::from_f64(0.5 * eta), &a2);
                // Normal step: X⁺ = M − λ(M Mᴴ − I)M.
                let mut c = batch_a_bh(&m, &m);
                c.sub_eye_inplace();
                let bmat = batch_matmul(&c, &m);
                match lambda {
                    LambdaPolicy::Half => {
                        m.axpy(E::from_f64(-0.5), &bmat);
                        self.last_lambda = Some(0.5);
                    }
                    LambdaPolicy::FindRoot => {
                        // Per-matrix quartic roots from the p×p gram
                        // residuals (identical arithmetic to the
                        // per-matrix path: same coeffs, same solver —
                        // the coefficients are real on either field).
                        let (_, p, _) = c.shape();
                        let alphas = &mut self.coef_a;
                        alphas.clear();
                        let mut lam = 0.5;
                        for i in 0..c.batch() {
                            let ci: Mat<E> = c.copy_mat(i);
                            debug_assert_eq!(ci.shape(), (p, p));
                            lam = solve_landing_quartic(landing_coeffs(&ci));
                            alphas.push(E::from_f64(-lam));
                        }
                        m.axpy_per_mat(alphas, &bmat);
                        self.last_lambda = Some(lam);
                    }
                }
                *x = m;
            }
            Rule::Landing { attraction, eps_ball, safeguard, normalize_grad } => {
                let g_normed;
                let g = if normalize_grad {
                    let mut gg = g.clone();
                    let alphas: Vec<E> = gg
                        .norm_sq_per_mat()
                        .iter()
                        .map(|&ns| {
                            let n = ns.sqrt().to_f64().max(1e-30);
                            E::from_f64(1.0 / n)
                        })
                        .collect();
                    gg.scale_per_mat(&alphas);
                    g_normed = gg;
                    &g_normed
                } else {
                    g
                };
                // R = ½((XXᴴ)G − (XGᴴ)X); ∇N = (XXᴴ − I)X.
                let xxh = batch_a_bh(x, x);
                let xgh = batch_a_bh(x, g);
                let a1 = batch_matmul(&xxh, g);
                let a2 = batch_matmul(&xgh, x);
                let mut r = a1.sub(&a2);
                r.scale_inplace(E::from_f64(0.5));
                let mut h = xxh;
                h.sub_eye_inplace();
                let ngrad = batch_matmul(&h, x);
                // Per-matrix safeguarded step size (same f64 formula as
                // the per-matrix engine).
                let h_ns = h.norm_sq_per_mat();
                let r_ns = r.norm_sq_per_mat();
                let n_ns = ngrad.norm_sq_per_mat();
                let lam = attraction;
                let a_r = &mut self.coef_a;
                let a_n = &mut self.coef_b;
                a_r.clear();
                a_n.clear();
                for i in 0..x.batch() {
                    let d = h_ns[i].sqrt().to_f64();
                    let lam_sq = r_ns[i].to_f64() + lam * lam * n_ns[i].to_f64();
                    let eta_i = if safeguard && lam_sq > 0.0 {
                        let slack = (eps_ball - d).max(0.0);
                        let b = lam * d * (1.0 - d).max(0.0);
                        let safe = (b + (b * b + lam_sq * slack).sqrt()) / lam_sq;
                        let cap = if lam > 0.0 { 0.5 / lam } else { f64::INFINITY };
                        eta.min(safe).min(cap)
                    } else {
                        eta
                    };
                    a_r.push(E::from_f64(-eta_i));
                    a_n.push(E::from_f64(-eta_i * lam));
                }
                x.axpy_per_mat(a_r, &r);
                x.axpy_per_mat(a_n, &ngrad);
            }
            Rule::Slpg => {
                // Y = X − η(G − SymH(G Xᴴ)X); X⁺ = Y − ½(Y Yᴴ − I)Y.
                let gxh = batch_a_bh(g, x);
                let sym = gxh.sym_per_mat();
                let sx = batch_matmul(&sym, x);
                let mut y = x.clone();
                y.axpy(E::from_f64(-eta), g);
                y.axpy(E::from_f64(eta), &sx);
                let mut c = batch_a_bh(&y, &y);
                c.sub_eye_inplace();
                let cy = batch_matmul(&c, &y);
                y.axpy(E::from_f64(-0.5), &cy);
                *x = y;
            }
            Rule::Adam => {
                x.axpy(E::from_f64(-eta), g);
            }
        }
        if let Some(t0) = t0 {
            let rule = self.rule;
            let hist = *self.step_hist.get_or_insert_with(|| {
                let kernel = match rule {
                    Rule::Pogo { .. } | Rule::Landing { .. } if fused => E::step_kernel().name(),
                    _ => "naive",
                };
                let (_, p, n) = x.shape();
                crate::obs::hist::STEP_SECONDS.hist(&["batched-host", kernel, shape_class(p, n)])
            });
            hist.record_since(t0);
        }
        Ok(())
    }
}

impl<E: Field> Orthoptimizer<E> for BatchedHost<E> {
    fn step(&mut self, _idx: usize, x: &mut Mat<E>, g: &Mat<E>) -> Result<()> {
        // A single matrix is a batch of one (state is batch-wide, like the
        // XLA stepper — `idx` is not a state slot here).
        let mut xb = BatchMat::from_mats(std::slice::from_ref(x));
        let gb = BatchMat::from_mats(std::slice::from_ref(g));
        self.apply(&mut xb, &gb)?;
        xb.unpack_into(std::slice::from_mut(x));
        Ok(())
    }

    fn step_group(&mut self, xs: &mut [Mat<E>], gs: &[Mat<E>]) -> Result<()> {
        ensure!(
            xs.len() == gs.len(),
            "step_group: {} points vs {} gradients",
            xs.len(),
            gs.len()
        );
        if xs.is_empty() {
            return Ok(());
        }
        let shape = xs[0].shape();
        ensure!(
            xs.iter().all(|x| x.shape() == shape) && gs.iter().all(|g| g.shape() == shape),
            "batched engine needs a shape-homogeneous group (expected {:?})",
            shape
        );
        let mut xb = BatchMat::from_mats(xs);
        let gb = BatchMat::from_mats(gs);
        self.apply(&mut xb, &gb)?;
        xb.unpack_into(xs);
        Ok(())
    }

    fn step_batch(&mut self, xs: &mut BatchMat<E>, gs: &BatchMat<E>) -> Result<()> {
        self.apply(xs, gs)
    }

    fn prefers_batch(&self) -> bool {
        true
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn lr(&self) -> f64 {
        self.lr
    }

    fn set_lr(&mut self, lr: f64) {
        self.lr = lr;
    }

    fn last_lambda(&self) -> Option<f64> {
        match self.rule {
            Rule::Pogo { .. } => self.last_lambda,
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifold::stiefel;
    use crate::rng::Rng;

    type B = BatchMat<f64>;

    fn group(b: usize, p: usize, n: usize, rng: &mut Rng) -> (B, B) {
        let xs: Vec<Mat<f64>> =
            (0..b).map(|_| stiefel::random_point_t::<f64>(p, n, rng)).collect();
        let gs: Vec<Mat<f64>> = (0..b)
            .map(|_| {
                let g = Mat::<f64>::randn(p, n, rng);
                let nn = g.norm();
                g.scale(0.5 / nn)
            })
            .collect();
        (BatchMat::from_mats(&xs), BatchMat::from_mats(&gs))
    }

    #[test]
    fn pogo_batch_stays_feasible() {
        let mut rng = Rng::seed_from_u64(0);
        let (mut x, g) = group(16, 4, 8, &mut rng);
        let mut opt = BatchedHost::<f64>::pogo(0.2, LambdaPolicy::Half, BaseOptKind::Sgd);
        for _ in 0..20 {
            opt.step_batch(&mut x, &g).unwrap();
        }
        for m in x.to_mats() {
            assert!(stiefel::distance_t(&m) < 1e-3);
        }
        assert_eq!(opt.last_lambda(), Some(0.5));
    }

    #[test]
    fn landing_batch_stays_in_ball() {
        let mut rng = Rng::seed_from_u64(1);
        let (mut x, _) = group(8, 3, 6, &mut rng);
        let mut opt = BatchedHost::<f64>::landing(1.0, 1.0, BaseOptKind::Sgd);
        for _ in 0..30 {
            let (_, g) = group(8, 3, 6, &mut rng);
            let mut big = g;
            big.scale_inplace(30.0);
            opt.step_batch(&mut x, &big).unwrap();
            for m in x.to_mats() {
                assert!(stiefel::distance_t(&m) <= 0.5 + 1e-6);
            }
        }
    }

    #[test]
    fn adam_batch_descends_quadratic() {
        // f(X) = ‖X − T‖² per batch element.
        let mut rng = Rng::seed_from_u64(2);
        let t = BatchMat::from_mats(&[
            Mat::<f64>::randn(3, 4, &mut rng),
            Mat::<f64>::randn(3, 4, &mut rng),
        ]);
        let mut x = BatchMat::<f64>::zeros(2, 3, 4);
        let mut opt = BatchedHost::<f64>::adam(0.05);
        for _ in 0..500 {
            let g = x.sub(&t).map(|v| v * 2.0);
            opt.step_batch(&mut x, &g).unwrap();
        }
        assert!(x.sub(&t).max_abs() < 1e-1);
    }

    #[test]
    fn batch_size_change_is_rejected_for_stateful_base() {
        let mut rng = Rng::seed_from_u64(3);
        let (mut x4, g4) = group(4, 3, 6, &mut rng);
        let (mut x2, g2) = group(2, 3, 6, &mut rng);
        let mut opt =
            BatchedHost::<f64>::pogo(0.1, LambdaPolicy::Half, BaseOptKind::vadam());
        opt.step_batch(&mut x4, &g4).unwrap();
        assert!(opt.step_batch(&mut x2, &g2).is_err());
    }

    #[test]
    fn complex_pogo_batch_stays_feasible() {
        // The SAME engine at E = Complex<f64>: batched unitary POGO keeps
        // every core near X Xᴴ = I.
        use crate::linalg::{CMat, Complex};
        let mut rng = Rng::seed_from_u64(5);
        let (p, n, b) = (4, 8, 12);
        let xs: Vec<CMat<f64>> =
            (0..b).map(|_| stiefel::random_point_complex::<f64>(p, n, &mut rng)).collect();
        let mut x = BatchMat::from_mats(&xs);
        let mut opt =
            BatchedHost::<Complex<f64>>::pogo(0.2, LambdaPolicy::Half, BaseOptKind::Sgd);
        for _ in 0..20 {
            let gs: Vec<CMat<f64>> = (0..b)
                .map(|_| {
                    let g = CMat::<f64>::randn(p, n, &mut rng);
                    let nn = g.norm();
                    g.scale(Complex::from_f64(0.5 / nn))
                })
                .collect();
            let gb = BatchMat::from_mats(&gs);
            opt.step_batch(&mut x, &gb).unwrap();
        }
        for m in x.to_mats() {
            assert!(m.stiefel_distance() < 1e-3);
        }
    }

    #[test]
    fn complex_batched_rejects_nonlinear_base() {
        // Def. 1 gate at construction, same as the loop engine's BaseOpt.
        use crate::linalg::Complex;
        let result = std::panic::catch_unwind(|| {
            BatchedHost::<Complex<f32>>::pogo(0.1, LambdaPolicy::Half, BaseOptKind::adam());
        });
        assert!(result.is_err());
        // Linear bases and the real Adam engine are unaffected.
        let _ = BatchedHost::<Complex<f32>>::pogo(0.1, LambdaPolicy::Half, BaseOptKind::vadam());
        let _ = BatchedHost::<f32>::adam(0.01);
    }

    #[test]
    fn fused_and_naive_paths_agree_exactly() {
        // The KernelChoice knob must be invisible in the bits (the full
        // method × shape × B matrix lives in tests/fused_parity.rs).
        let mut rng = Rng::seed_from_u64(6);
        let (x0, g) = group(9, 4, 8, &mut rng);
        for lambda in [LambdaPolicy::Half, LambdaPolicy::FindRoot] {
            let mut xf = x0.clone();
            let mut xn = x0.clone();
            let mut of = BatchedHost::<f64>::pogo(0.2, lambda, BaseOptKind::Sgd)
                .with_kernel(KernelChoice::Fused);
            let mut on = BatchedHost::<f64>::pogo(0.2, lambda, BaseOptKind::Sgd)
                .with_kernel(KernelChoice::Naive);
            for _ in 0..5 {
                of.step_batch(&mut xf, &g).unwrap();
                on.step_batch(&mut xn, &g).unwrap();
            }
            assert!(xf.sub(&xn).max_abs() == 0.0, "{lambda:?}");
            assert_eq!(of.last_lambda(), on.last_lambda(), "{lambda:?}");
        }
        let mut xf = x0.clone();
        let mut xn = x0.clone();
        let mut of = BatchedHost::<f64>::landing(0.3, 1.0, BaseOptKind::Sgd)
            .with_kernel(KernelChoice::Fused);
        let mut on = BatchedHost::<f64>::landing(0.3, 1.0, BaseOptKind::Sgd)
            .with_kernel(KernelChoice::Naive);
        for _ in 0..5 {
            of.step_batch(&mut xf, &g).unwrap();
            on.step_batch(&mut xn, &g).unwrap();
        }
        assert!(xf.sub(&xn).max_abs() == 0.0, "landing");
    }

    #[test]
    fn step_is_batch_of_one() {
        let mut rng = Rng::seed_from_u64(4);
        let mut x = stiefel::random_point_t::<f64>(4, 7, &mut rng);
        let g = Mat::<f64>::randn(4, 7, &mut rng).scale(0.1);
        let mut opt = BatchedHost::<f64>::pogo(0.1, LambdaPolicy::Half, BaseOptKind::Sgd);
        opt.step(0, &mut x, &g).unwrap();
        assert!(x.all_finite());
        assert!(stiefel::distance_t(&x) < 1e-3);
    }
}
