//! The method registry: the ONE place that constructs optimizers.
//!
//! Everything the crate knows about a [`Method`] beyond its name lives
//! here — its static [`Capabilities`], which XLA step programs can run it,
//! and how to build it for any scalar type on either the real or the
//! complex Stiefel manifold. `OptimizerSpec::{build, build_unitary}`
//! (coordinator layer), the Trainer, and every experiment driver route
//! through these functions; adding an orthoptimizer touches its module
//! plus this file only.
//!
//! With the `Field` abstraction, the manifold is encoded in the element
//! type: the matmul-only methods (POGO, Landing, LandingPC, SLPG) are
//! constructed by ONE generic match ([`construct_field`]) at `E = S` for
//! the real Stiefel manifold and `E = Complex<S>` for the unitary one —
//! the update-rule code is shared, not duplicated. Only the inherently
//! real methods (QR-retraction RGD/RSDM, elementwise Adam) and the
//! complex polar-RGD glue have domain-specific arms.
//!
//! Invariant (checked by `tests/spec_api.rs`): optimizer-constructing
//! `match`es over `Method` live in this file only — [`construct_field`] +
//! [`build_host`] / [`build_unitary`] for the per-matrix engines and
//! [`construct_batched`] for the batched host engine (both domains).

use super::adam::{Adam, AdamConfig};
use super::base::BaseOptKind;
use super::batched::BatchedHost;
use super::landing::{Landing, LandingConfig};
use super::pogo::{LambdaPolicy, Pogo, PogoConfig};
use super::rgd::{Rgd, RgdConfig};
use super::rsdm::{Rsdm, RsdmConfig};
use super::slpg::{Slpg, SlpgConfig};
use super::unitary::RgdC;
use super::{Method, Orthoptimizer};
use crate::coordinator::engine::OptimizerSpec;
use crate::linalg::{Complex, Field, Scalar};
use crate::runtime::stepper::{StepKind, XlaStepper};
use crate::runtime::Registry;
use anyhow::{anyhow, ensure, Result};

/// Static capabilities of a method.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Capabilities {
    /// Update rule is matmul-only (batched accelerator dispatch possible).
    pub matmul_only: bool,
    /// Has a complex-Stiefel (unitary) engine.
    pub complex: bool,
    /// Has a batched host engine (`Engine::BatchedHost`) on the real
    /// manifold: every matmul-only method, plus elementwise Adam.
    /// QR-retraction methods (RGD, RSDM) are inherently per-matrix and
    /// stay on the loop engine.
    pub batched_host: bool,
    /// Has a batched host engine on the COMPLEX manifold: exactly the
    /// matmul-only methods (the field-generic `BatchedHost<Complex<S>>`).
    /// Adam is real-only (not linear per Def. 1); polar-RGD is
    /// per-matrix.
    pub batched_host_complex: bool,
    /// XLA step programs this method can drive (empty = host-only).
    pub xla_step_kinds: &'static [StepKind],
}

/// Capability table (kept next to the construction matches so a new
/// method updates both in one edit).
pub fn capabilities(method: Method) -> Capabilities {
    match method {
        Method::Pogo => Capabilities {
            matmul_only: true,
            complex: true,
            batched_host: true,
            batched_host_complex: true,
            xla_step_kinds: &[StepKind::Pogo, StepKind::PogoVadam, StepKind::PogoFindRoot],
        },
        Method::Landing | Method::LandingPC => Capabilities {
            matmul_only: true,
            complex: true,
            batched_host: true,
            batched_host_complex: true,
            xla_step_kinds: &[StepKind::Landing],
        },
        Method::Slpg => Capabilities {
            matmul_only: true,
            complex: true,
            batched_host: true,
            batched_host_complex: true,
            xla_step_kinds: &[StepKind::Slpg],
        },
        Method::Rgd => Capabilities {
            matmul_only: false,
            complex: true,
            batched_host: false,
            batched_host_complex: false,
            xla_step_kinds: &[],
        },
        Method::Rsdm => Capabilities {
            matmul_only: false,
            complex: false,
            batched_host: false,
            batched_host_complex: false,
            xla_step_kinds: &[],
        },
        Method::Adam => Capabilities {
            matmul_only: false,
            complex: false,
            batched_host: true,
            batched_host_complex: false,
            xla_step_kinds: &[],
        },
    }
}

/// Which manifold the optimizer acts on. With the `Field` abstraction the
/// domain is carried by the element type; this enum remains the
/// *capability-gate* vocabulary (error messages, batched-engine support
/// queries).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Domain {
    /// Real Stiefel `X Xᵀ = I` (element `f32`/`f64`).
    Real,
    /// Complex Stiefel `X Xᴴ = I` (element `Complex<S>`).
    Complex,
}

/// Whether `method` has a batched host engine on `domain`.
pub fn batched_host_supported(method: Method, domain: Domain) -> bool {
    let caps = capabilities(method);
    match domain {
        Domain::Real => caps.batched_host,
        Domain::Complex => caps.batched_host_complex,
    }
}

/// THE field-generic construction match: every matmul-only method, for
/// any element type (real scalar or `Complex<S>`). Returns `None` for
/// methods that need a domain-specific engine (RGD/RSDM/Adam).
fn construct_field<E: Field>(
    spec: &OptimizerSpec,
    n_params: usize,
) -> Option<Box<dyn Orthoptimizer<E>>> {
    Some(match spec.method {
        Method::Pogo => Box::new(Pogo::<E>::new(
            PogoConfig { lr: spec.lr, lambda: spec.lambda, base: spec.base },
            n_params,
        )),
        Method::Landing => Box::new(Landing::<E>::new(
            LandingConfig {
                lr: spec.lr,
                attraction: spec.attraction,
                base: spec.base,
                ..Default::default()
            },
            n_params,
        )),
        Method::LandingPC => Box::new(Landing::<E>::new(
            LandingConfig::landing_pc(spec.lr, spec.attraction),
            n_params,
        )),
        Method::Slpg => Box::new(Slpg::<E>::new(
            SlpgConfig { lr: spec.lr, base: spec.base },
            n_params,
        )),
        Method::Rgd | Method::Rsdm | Method::Adam => return None,
    })
}

/// Build a host-engine (pure-Rust) orthoptimizer at scalar type `S` on
/// the real Stiefel manifold.
pub fn build_host<S: Scalar>(
    spec: &OptimizerSpec,
    n_params: usize,
) -> Result<Box<dyn Orthoptimizer<S>>> {
    if let Some(opt) = construct_field::<S>(spec, n_params) {
        return Ok(opt);
    }
    Ok(match spec.method {
        Method::Rgd => Box::new(Rgd::<S>::new(
            RgdConfig { lr: spec.lr, base: spec.base },
            n_params,
        )),
        Method::Rsdm => Box::new(Rsdm::<S>::new(
            RsdmConfig {
                lr: spec.lr,
                submanifold_dim: spec.submanifold_dim,
                base: spec.base,
                seed: spec.seed,
                ..Default::default()
            },
            n_params,
        )),
        Method::Adam => Box::new(Adam::<S>::new(
            AdamConfig { lr: spec.lr, ..Default::default() },
            n_params,
        )),
        // construct_field covers the matmul-only methods; reaching here
        // means the two matches drifted apart — surface it as an error (a
        // serving daemon must never panic on a spec), not a panic.
        m => {
            return Err(anyhow!(
                "{} fell through both construction matches (registry arms out of sync)",
                m.name()
            ))
        }
    })
}

/// Complex-domain capability gate shared by the unitary builders.
fn ensure_complex_capable(spec: &OptimizerSpec) -> Result<()> {
    ensure!(
        capabilities(spec.method).complex,
        "{} has no complex-Stiefel engine",
        spec.method.name()
    );
    ensure!(
        spec.base.is_linear(),
        "complex base optimizers must be linear (Def. 1); got {}",
        spec.base.name()
    );
    Ok(())
}

/// Build a complex-Stiefel (unitary) optimizer at scalar type `S`: the
/// field-generic methods instantiated at `Complex<S>`, plus the
/// polar-retraction RGD glue.
pub fn build_unitary<S: Scalar>(
    spec: &OptimizerSpec,
    n_params: usize,
) -> Result<Box<dyn Orthoptimizer<Complex<S>>>> {
    ensure_complex_capable(spec)?;
    if let Some(opt) = construct_field::<Complex<S>>(spec, n_params) {
        return Ok(opt);
    }
    match spec.method {
        Method::Rgd => Ok(Box::new(RgdC::<S>::new(spec.lr, spec.base, n_params))),
        m => Err(anyhow!(
            "{} passed the complex capability gate but has no unitary constructor \
             (capability table and construction match are out of sync)",
            m.name()
        )),
    }
}

/// The batched-host construction match, field-generic like
/// [`construct_field`]. `None` for methods with no batched rule at all.
fn construct_batched<E: Field>(spec: &OptimizerSpec) -> Option<Box<dyn Orthoptimizer<E>>> {
    // Every arm carries the spec's kernel choice (fused/naive/auto); the
    // methods without a fused kernel (SLPG, Adam) ignore it in `apply`.
    let host = match spec.method {
        Method::Pogo => BatchedHost::<E>::pogo(spec.lr, spec.lambda, spec.base),
        Method::Landing => BatchedHost::<E>::landing(spec.lr, spec.attraction, spec.base),
        Method::LandingPC => BatchedHost::<E>::landing_pc(spec.lr, spec.attraction),
        Method::Slpg => BatchedHost::<E>::slpg(spec.lr, spec.base),
        Method::Adam => BatchedHost::<E>::adam(spec.lr),
        Method::Rgd | Method::Rsdm => return None,
    };
    Some(Box::new(host.with_kernel(spec.kernel)))
}

/// Build the batched host engine (`Engine::BatchedHost`) for one shape
/// group at scalar type `S` on the REAL manifold: the whole group packed
/// into a `(B, p, n)` [`crate::linalg::BatchMat`] and stepped with
/// batch-parallel kernels. Gated on [`Capabilities::batched_host`].
pub fn build_batched_host<S: Scalar>(
    spec: &OptimizerSpec,
) -> Result<Box<dyn Orthoptimizer<S>>> {
    ensure!(
        batched_host_supported(spec.method, Domain::Real),
        "{} is retraction-based (per-matrix QR) — no batched host engine; \
         use engine 'rust'",
        spec.method.name()
    );
    construct_batched::<S>(spec).ok_or_else(|| {
        anyhow!(
            "{} advertises batched_host but construct_batched has no arm for it \
             (capability table and construction match are out of sync)",
            spec.method.name()
        )
    })
}

/// Build the batched host engine for a COMPLEX `(B, p, n)` shape group
/// (the Fig. 8 thousands-of-unitaries regime). Gated on
/// [`Capabilities::batched_host_complex`] plus Def. 1 linearity.
pub fn build_batched_host_unitary<S: Scalar>(
    spec: &OptimizerSpec,
) -> Result<Box<dyn Orthoptimizer<Complex<S>>>> {
    ensure_complex_capable(spec)?;
    ensure!(
        batched_host_supported(spec.method, Domain::Complex),
        "{} has no batched complex host engine; use engine 'rust'",
        spec.method.name()
    );
    construct_batched::<Complex<S>>(spec).ok_or_else(|| {
        anyhow!(
            "{} advertises batched_host_complex but construct_batched has no arm for it \
             (capability table and construction match are out of sync)",
            spec.method.name()
        )
    })
}

/// Which XLA step program a spec maps to (method × base × λ-policy).
pub fn xla_step_kind(spec: &OptimizerSpec) -> Result<StepKind> {
    let kind = match (spec.method, spec.base, spec.lambda) {
        (Method::Pogo, BaseOptKind::VAdam { .. }, LambdaPolicy::Half) => StepKind::PogoVadam,
        (Method::Pogo, _, LambdaPolicy::Half) => StepKind::Pogo,
        (Method::Pogo, _, LambdaPolicy::FindRoot) => StepKind::PogoFindRoot,
        (Method::Landing | Method::LandingPC, _, _) => StepKind::Landing,
        (Method::Slpg, _, _) => StepKind::Slpg,
        (m, _, _) => return Err(anyhow!("{} has no XLA engine (host retraction)", m.name())),
    };
    debug_assert!(capabilities(spec.method).xla_step_kinds.contains(&kind));
    Ok(kind)
}

/// Build the batched XLA stepper for a spec at one `(b, p, n)` group
/// shape (the artifact for that shape must exist in the registry).
pub fn build_xla(
    spec: &OptimizerSpec,
    registry: &Registry,
    b: usize,
    p: usize,
    n: usize,
) -> Result<XlaStepper> {
    let kind = xla_step_kind(spec)?;
    let mut stepper = XlaStepper::new(registry, kind, spec.lr, b, p, n)?;
    stepper.attraction = spec.attraction;
    stepper.normalize_grad = spec.method == Method::LandingPC;
    if spec.method == Method::LandingPC {
        // LandingPC has no safeguard (paper §5.1); neutralize it.
        stepper.eps_ball = 1e9;
    }
    stepper.set_base(spec.base);
    Ok(stepper)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capabilities_cover_every_method() {
        for &m in Method::all() {
            let caps = capabilities(m);
            // matmul-only ⇔ has at least one XLA step program.
            assert_eq!(caps.matmul_only, !caps.xla_step_kinds.is_empty(), "{}", m.name());
            // matmul-only ⇒ batched host engine exists, on BOTH domains
            // (the batched rule is field-generic).
            assert!(!caps.matmul_only || caps.batched_host, "{}", m.name());
            assert!(!caps.matmul_only || caps.batched_host_complex, "{}", m.name());
            // A complex batched engine requires a complex engine at all.
            assert!(!caps.batched_host_complex || caps.complex, "{}", m.name());
        }
    }

    #[test]
    fn batched_host_lineup_builds_and_retraction_methods_refuse() {
        for m in [
            Method::Pogo,
            Method::Landing,
            Method::LandingPC,
            Method::Slpg,
            Method::Adam,
        ] {
            let opt = build_batched_host::<f32>(&OptimizerSpec::new(m, 0.05)).unwrap();
            assert!(opt.prefers_batch(), "{}", m.name());
            assert!(opt.name().contains("[batched]"), "{}", opt.name());
        }
        for m in [Method::Rgd, Method::Rsdm] {
            let err = build_batched_host::<f32>(&OptimizerSpec::new(m, 0.05)).unwrap_err();
            assert!(format!("{err}").contains("no batched host engine"), "{err}");
        }
    }

    #[test]
    fn batched_host_accepts_every_kernel_choice() {
        use crate::linalg::KernelChoice;
        for kernel in [KernelChoice::Auto, KernelChoice::Fused, KernelChoice::Naive] {
            for m in [Method::Pogo, Method::Landing, Method::Slpg, Method::Adam] {
                let spec = OptimizerSpec::new(m, 0.05).with_kernel(kernel);
                let opt = build_batched_host::<f32>(&spec).unwrap();
                assert!(opt.prefers_batch(), "{} {:?}", m.name(), kernel);
            }
        }
    }

    #[test]
    fn batched_unitary_lineup_builds_and_gates() {
        // The matmul-only methods batch on the complex manifold too.
        for m in [Method::Pogo, Method::Landing, Method::LandingPC, Method::Slpg] {
            let opt =
                build_batched_host_unitary::<f32>(&OptimizerSpec::new(m, 0.05)).unwrap();
            assert!(opt.prefers_batch(), "{}", m.name());
            assert!(opt.name().contains("[batched]"), "{}", opt.name());
        }
        // Adam: no complex engine at all (not linear per Def. 1).
        for m in [Method::Adam, Method::Rsdm] {
            assert!(
                build_batched_host_unitary::<f32>(&OptimizerSpec::new(m, 0.05)).is_err(),
                "{}",
                m.name()
            );
        }
        // Polar-RGD exists complex but only per-matrix.
        let err = build_batched_host_unitary::<f32>(&OptimizerSpec::new(Method::Rgd, 0.05))
            .unwrap_err();
        assert!(format!("{err}").contains("no batched complex host engine"), "{err}");
        // Non-linear base is rejected on the complex domain.
        let spec = OptimizerSpec::new(Method::Pogo, 0.05).with_base(BaseOptKind::adam());
        assert!(build_batched_host_unitary::<f32>(&spec).is_err());
    }

    #[test]
    fn step_kind_selection_matches_capabilities() {
        let pogo = OptimizerSpec::new(Method::Pogo, 0.1);
        assert_eq!(xla_step_kind(&pogo).unwrap(), StepKind::Pogo);
        assert_eq!(
            xla_step_kind(&pogo.with_base(BaseOptKind::vadam())).unwrap(),
            StepKind::PogoVadam
        );
        assert_eq!(
            xla_step_kind(&pogo.with_lambda(LambdaPolicy::FindRoot)).unwrap(),
            StepKind::PogoFindRoot
        );
        assert!(xla_step_kind(&OptimizerSpec::new(Method::Rgd, 0.1)).is_err());
    }

    #[test]
    fn complex_gate_rejects_unsupported() {
        let spec = OptimizerSpec::new(Method::Rsdm, 0.1);
        assert!(build_unitary::<f32>(&spec, 1).is_err());
        let spec = OptimizerSpec::new(Method::Adam, 0.1);
        assert!(build_unitary::<f32>(&spec, 1).is_err());
        // Linear-base gate (Def. 1).
        let spec = OptimizerSpec::new(Method::Pogo, 0.1).with_base(BaseOptKind::adam());
        assert!(build_unitary::<f32>(&spec, 1).is_err());
    }

    #[test]
    fn unitary_lineup_builds() {
        for m in [Method::Pogo, Method::Landing, Method::LandingPC, Method::Slpg, Method::Rgd]
        {
            let opt = build_unitary::<f32>(&OptimizerSpec::new(m, 0.05), 4).unwrap();
            assert!(opt.lr() > 0.0, "{}", m.name());
        }
    }

    #[test]
    fn domain_support_table() {
        assert!(batched_host_supported(Method::Pogo, Domain::Real));
        assert!(batched_host_supported(Method::Pogo, Domain::Complex));
        assert!(batched_host_supported(Method::Adam, Domain::Real));
        assert!(!batched_host_supported(Method::Adam, Domain::Complex));
        assert!(!batched_host_supported(Method::Rgd, Domain::Complex));
    }
}
