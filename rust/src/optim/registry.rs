//! The method registry: the ONE place that constructs optimizers.
//!
//! Everything the crate knows about a [`Method`] beyond its name lives
//! here — its static [`Capabilities`], which XLA step programs can run it,
//! and how to build it for any scalar type on either the real or the
//! complex Stiefel manifold. `OptimizerSpec::{build, build_unitary}`
//! (coordinator layer), the Trainer, and every experiment driver route
//! through these functions; adding an orthoptimizer touches its module
//! plus this file only.
//!
//! Invariant (checked by `tests/spec_api.rs`): optimizer-constructing
//! `match`es over `Method` live in this file only — [`construct`] for the
//! per-matrix engines (real + complex) and [`build_batched_host`] for the
//! batched host engine.

use super::adam::{Adam, AdamConfig};
use super::base::BaseOptKind;
use super::batched::BatchedHost;
use super::landing::{Landing, LandingConfig};
use super::pogo::{LambdaPolicy, Pogo, PogoConfig};
use super::rgd::{Rgd, RgdConfig};
use super::rsdm::{Rsdm, RsdmConfig};
use super::slpg::{Slpg, SlpgConfig};
use super::unitary::{LandingC, PogoC, RgdC, SlpgC, UnitaryOptimizer};
use super::{Method, Orthoptimizer};
use crate::coordinator::engine::OptimizerSpec;
use crate::linalg::Scalar;
use crate::runtime::stepper::{StepKind, XlaStepper};
use crate::runtime::Registry;
use anyhow::{anyhow, ensure, Result};

/// Static capabilities of a method.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Capabilities {
    /// Update rule is matmul-only (batched accelerator dispatch possible).
    pub matmul_only: bool,
    /// Has a complex-Stiefel (unitary) engine.
    pub complex: bool,
    /// Has a batched host engine (`Engine::BatchedHost`): every
    /// matmul-only method, plus elementwise Adam. QR-retraction methods
    /// (RGD, RSDM) are inherently per-matrix and stay on the loop engine.
    pub batched_host: bool,
    /// XLA step programs this method can drive (empty = host-only).
    pub xla_step_kinds: &'static [StepKind],
}

/// Capability table (kept next to [`construct`] so a new method updates
/// both in one edit).
pub fn capabilities(method: Method) -> Capabilities {
    match method {
        Method::Pogo => Capabilities {
            matmul_only: true,
            complex: true,
            batched_host: true,
            xla_step_kinds: &[StepKind::Pogo, StepKind::PogoVadam, StepKind::PogoFindRoot],
        },
        Method::Landing | Method::LandingPC => Capabilities {
            matmul_only: true,
            complex: true,
            batched_host: true,
            xla_step_kinds: &[StepKind::Landing],
        },
        Method::Slpg => Capabilities {
            matmul_only: true,
            complex: true,
            batched_host: true,
            xla_step_kinds: &[StepKind::Slpg],
        },
        Method::Rgd => Capabilities {
            matmul_only: false,
            complex: true,
            batched_host: false,
            xla_step_kinds: &[],
        },
        Method::Rsdm => Capabilities {
            matmul_only: false,
            complex: false,
            batched_host: false,
            xla_step_kinds: &[],
        },
        Method::Adam => Capabilities {
            matmul_only: false,
            complex: false,
            batched_host: true,
            xla_step_kinds: &[],
        },
    }
}

/// Which manifold the optimizer acts on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Domain {
    /// Real Stiefel `X Xᵀ = I` (the [`Orthoptimizer`] trait).
    Real,
    /// Complex Stiefel `X X^H = I` (the [`UnitaryOptimizer`] trait).
    Complex,
}

/// A constructed optimizer, in whichever domain was requested.
enum Built<S: Scalar> {
    Real(Box<dyn Orthoptimizer<S>>),
    Unitary(Box<dyn UnitaryOptimizer<S>>),
}

/// THE optimizer construction match. Every host-engine optimizer in the
/// crate — any method, any scalar, real or complex — is built here.
fn construct<S: Scalar>(
    spec: &OptimizerSpec,
    domain: Domain,
    n_params: usize,
) -> Result<Built<S>> {
    use Domain::{Complex, Real};
    if domain == Complex {
        ensure!(
            capabilities(spec.method).complex,
            "{} has no complex-Stiefel engine",
            spec.method.name()
        );
        ensure!(
            spec.base.is_linear(),
            "complex base optimizers must be linear (Def. 1); got {}",
            spec.base.name()
        );
    }
    Ok(match spec.method {
        Method::Pogo => match domain {
            Real => Built::Real(Box::new(Pogo::<S>::new(
                PogoConfig { lr: spec.lr, lambda: spec.lambda, base: spec.base },
                n_params,
            ))),
            Complex => Built::Unitary(Box::new(PogoC::<S>::new(
                spec.lr,
                spec.lambda,
                spec.base,
                n_params,
            ))),
        },
        Method::Landing => match domain {
            Real => Built::Real(Box::new(Landing::<S>::new(
                LandingConfig {
                    lr: spec.lr,
                    attraction: spec.attraction,
                    base: spec.base,
                    ..Default::default()
                },
                n_params,
            ))),
            Complex => Built::Unitary(Box::new(LandingC::<S>::new(
                spec.lr,
                spec.attraction,
                spec.base,
                n_params,
            ))),
        },
        Method::LandingPC => match domain {
            Real => Built::Real(Box::new(Landing::<S>::new(
                LandingConfig::landing_pc(spec.lr, spec.attraction),
                n_params,
            ))),
            Complex => Built::Unitary(Box::new(LandingC::<S>::landing_pc(
                spec.lr,
                spec.attraction,
                n_params,
            ))),
        },
        Method::Slpg => match domain {
            Real => Built::Real(Box::new(Slpg::<S>::new(
                SlpgConfig { lr: spec.lr, base: spec.base },
                n_params,
            ))),
            Complex => Built::Unitary(Box::new(SlpgC::<S>::new(spec.lr, n_params))),
        },
        Method::Rgd => match domain {
            Real => Built::Real(Box::new(Rgd::<S>::new(
                RgdConfig { lr: spec.lr, base: spec.base },
                n_params,
            ))),
            Complex => Built::Unitary(Box::new(RgdC::<S>::new(spec.lr, n_params))),
        },
        Method::Rsdm => match domain {
            Real => Built::Real(Box::new(Rsdm::<S>::new(
                RsdmConfig {
                    lr: spec.lr,
                    submanifold_dim: spec.submanifold_dim,
                    base: spec.base,
                    seed: spec.seed,
                    ..Default::default()
                },
                n_params,
            ))),
            Complex => unreachable!("capability gate above"),
        },
        Method::Adam => match domain {
            Real => Built::Real(Box::new(Adam::<S>::new(
                AdamConfig { lr: spec.lr, ..Default::default() },
                n_params,
            ))),
            Complex => unreachable!("capability gate above"),
        },
    })
}

/// Build a host-engine (pure-Rust) orthoptimizer at scalar type `S`.
pub fn build_host<S: Scalar>(
    spec: &OptimizerSpec,
    n_params: usize,
) -> Result<Box<dyn Orthoptimizer<S>>> {
    match construct::<S>(spec, Domain::Real, n_params)? {
        Built::Real(opt) => Ok(opt),
        Built::Unitary(_) => unreachable!("Domain::Real yields Built::Real"),
    }
}

/// Build the batched host engine (`Engine::BatchedHost`) for one shape
/// group at scalar type `S`: the whole group packed into a `(B, p, n)`
/// [`crate::linalg::BatchMat`] and stepped with batch-parallel kernels.
/// Gated on [`Capabilities::batched_host`].
pub fn build_batched_host<S: Scalar>(
    spec: &OptimizerSpec,
) -> Result<Box<dyn Orthoptimizer<S>>> {
    ensure!(
        capabilities(spec.method).batched_host,
        "{} is retraction-based (per-matrix QR) — no batched host engine; \
         use engine 'rust'",
        spec.method.name()
    );
    Ok(match spec.method {
        Method::Pogo => {
            Box::new(BatchedHost::<S>::pogo(spec.lr, spec.lambda, spec.base))
        }
        Method::Landing => {
            Box::new(BatchedHost::<S>::landing(spec.lr, spec.attraction, spec.base))
        }
        Method::LandingPC => {
            Box::new(BatchedHost::<S>::landing_pc(spec.lr, spec.attraction))
        }
        Method::Slpg => Box::new(BatchedHost::<S>::slpg(spec.lr, spec.base)),
        Method::Adam => Box::new(BatchedHost::<S>::adam(spec.lr)),
        Method::Rgd | Method::Rsdm => unreachable!("capability gate above"),
    })
}

/// Build a complex-Stiefel (unitary) optimizer at scalar type `S`.
pub fn build_unitary<S: Scalar>(
    spec: &OptimizerSpec,
    n_params: usize,
) -> Result<Box<dyn UnitaryOptimizer<S>>> {
    match construct::<S>(spec, Domain::Complex, n_params)? {
        Built::Unitary(opt) => Ok(opt),
        Built::Real(_) => unreachable!("Domain::Complex yields Built::Unitary"),
    }
}

/// Which XLA step program a spec maps to (method × base × λ-policy).
pub fn xla_step_kind(spec: &OptimizerSpec) -> Result<StepKind> {
    let kind = match (spec.method, spec.base, spec.lambda) {
        (Method::Pogo, BaseOptKind::VAdam { .. }, LambdaPolicy::Half) => StepKind::PogoVadam,
        (Method::Pogo, _, LambdaPolicy::Half) => StepKind::Pogo,
        (Method::Pogo, _, LambdaPolicy::FindRoot) => StepKind::PogoFindRoot,
        (Method::Landing | Method::LandingPC, _, _) => StepKind::Landing,
        (Method::Slpg, _, _) => StepKind::Slpg,
        (m, _, _) => return Err(anyhow!("{} has no XLA engine (host retraction)", m.name())),
    };
    debug_assert!(capabilities(spec.method).xla_step_kinds.contains(&kind));
    Ok(kind)
}

/// Build the batched XLA stepper for a spec at one `(b, p, n)` group
/// shape (the artifact for that shape must exist in the registry).
pub fn build_xla(
    spec: &OptimizerSpec,
    registry: &Registry,
    b: usize,
    p: usize,
    n: usize,
) -> Result<XlaStepper> {
    let kind = xla_step_kind(spec)?;
    let mut stepper = XlaStepper::new(registry, kind, spec.lr, b, p, n)?;
    stepper.attraction = spec.attraction;
    stepper.normalize_grad = spec.method == Method::LandingPC;
    if spec.method == Method::LandingPC {
        // LandingPC has no safeguard (paper §5.1); neutralize it.
        stepper.eps_ball = 1e9;
    }
    stepper.set_base(spec.base);
    Ok(stepper)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capabilities_cover_every_method() {
        for &m in Method::all() {
            let caps = capabilities(m);
            // matmul-only ⇔ has at least one XLA step program.
            assert_eq!(caps.matmul_only, !caps.xla_step_kinds.is_empty(), "{}", m.name());
            // matmul-only ⇒ batched host engine exists.
            assert!(!caps.matmul_only || caps.batched_host, "{}", m.name());
        }
    }

    #[test]
    fn batched_host_lineup_builds_and_retraction_methods_refuse() {
        for m in [
            Method::Pogo,
            Method::Landing,
            Method::LandingPC,
            Method::Slpg,
            Method::Adam,
        ] {
            let opt = build_batched_host::<f32>(&OptimizerSpec::new(m, 0.05)).unwrap();
            assert!(opt.prefers_batch(), "{}", m.name());
            assert!(opt.name().contains("[batched]"), "{}", opt.name());
        }
        for m in [Method::Rgd, Method::Rsdm] {
            let err = build_batched_host::<f32>(&OptimizerSpec::new(m, 0.05)).unwrap_err();
            assert!(format!("{err}").contains("no batched host engine"), "{err}");
        }
    }

    #[test]
    fn step_kind_selection_matches_capabilities() {
        let pogo = OptimizerSpec::new(Method::Pogo, 0.1);
        assert_eq!(xla_step_kind(&pogo).unwrap(), StepKind::Pogo);
        assert_eq!(
            xla_step_kind(&pogo.with_base(BaseOptKind::vadam())).unwrap(),
            StepKind::PogoVadam
        );
        assert_eq!(
            xla_step_kind(&pogo.with_lambda(LambdaPolicy::FindRoot)).unwrap(),
            StepKind::PogoFindRoot
        );
        assert!(xla_step_kind(&OptimizerSpec::new(Method::Rgd, 0.1)).is_err());
    }

    #[test]
    fn complex_gate_rejects_unsupported() {
        let spec = OptimizerSpec::new(Method::Rsdm, 0.1);
        assert!(build_unitary::<f32>(&spec, 1).is_err());
        let spec = OptimizerSpec::new(Method::Adam, 0.1);
        assert!(build_unitary::<f32>(&spec, 1).is_err());
    }

    #[test]
    fn unitary_lineup_builds() {
        for m in [Method::Pogo, Method::Landing, Method::LandingPC, Method::Slpg, Method::Rgd]
        {
            let opt = build_unitary::<f32>(&OptimizerSpec::new(m, 0.05), 4).unwrap();
            assert!(opt.lr() > 0.0, "{}", m.name());
        }
    }
}
