//! SLPG — sequential linearized proximal gradient (Liu et al. 2024),
//! smooth case (r = 0), adapted to wide row-orthogonal matrices (paper §B).
//!
//! Per iteration:
//! 1. `Y = X − η (G − Sym(G Xᵀ) X)` — gradient step along the Riemannian
//!    gradient under the *Euclidean metric* (the proximal subproblem's
//!    closed-form solution with the explicit multiplier
//!    `Λ = Sym(Xᵀ∇f)`; note this direction is NOT orthogonal to the normal
//!    direction, unlike POGO's canonical-metric `X Skew(XᵀG)` — §B).
//! 2. `X⁺ = (3/2 I − ½ Y Yᵀ) Y` — first-order Taylor approximation of the
//!    polar retraction — identical in form to POGO's normal step with
//!    λ = 1/2.

use super::base::{BaseOpt, BaseOptKind};
use super::Orthoptimizer;
use crate::linalg::{matmul, matmul_a_bh, Field, Mat};

/// SLPG hyperparameters.
#[derive(Clone, Copy, Debug)]
pub struct SlpgConfig {
    pub lr: f64,
    pub base: BaseOptKind,
}

impl Default for SlpgConfig {
    fn default() -> Self {
        SlpgConfig { lr: 0.1, base: BaseOptKind::Sgd }
    }
}

/// SLPG over Stiefel matrices of any field (real or complex; `Sym`
/// becomes the Hermitian-symmetric part — same code, §2 fn. 1).
pub struct Slpg<E: Field = f32> {
    cfg: SlpgConfig,
    base: BaseOpt<E>,
    name: String,
}

impl<E: Field> Slpg<E> {
    pub fn new(cfg: SlpgConfig, n_params: usize) -> Self {
        Slpg { cfg, base: BaseOpt::new(cfg.base, n_params), name: "SLPG".to_string() }
    }

    /// One SLPG update.
    pub fn update(x: &Mat<E>, g: &Mat<E>, eta: f64) -> Mat<E> {
        // D = G − SymH(G Xᴴ) X   (Euclidean-metric Riemannian gradient)
        let gxh = matmul_a_bh(g, x); // p×p
        let sym = gxh.sym_h();
        let sx = matmul(&sym, x);
        let mut y = x.clone();
        y.axpy(E::from_f64(-eta), g);
        y.axpy(E::from_f64(eta), &sx);
        // Normal step: X⁺ = Y − ½ (Y Yᴴ − I) Y.
        let mut c = matmul_a_bh(&y, &y);
        c.sub_eye_inplace();
        let cy = matmul(&c, &y);
        let mut xp = y;
        xp.axpy(E::from_f64(-0.5), &cy);
        xp
    }
}

impl<E: Field> Orthoptimizer<E> for Slpg<E> {
    fn step(&mut self, idx: usize, x: &mut Mat<E>, grad: &Mat<E>) -> anyhow::Result<()> {
        self.base.ensure_slots(idx + 1);
        let g = self.base.transform(idx, grad);
        *x = Slpg::update(x, &g, self.cfg.lr);
        Ok(())
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn lr(&self) -> f64 {
        self.cfg.lr
    }

    fn set_lr(&mut self, lr: f64) {
        self.cfg.lr = lr;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifold::stiefel;
    use crate::rng::Rng;
    use crate::testing;

    type M = Mat<f64>;

    #[test]
    fn single_step_feasibility_small_lr() {
        // SLPG needs η‖G‖ genuinely small — the paper had to run it with
        // "very low learning rates to avoid numerical errors" (§5.2). With
        // a unit-norm gradient and η = 0.05 a single step stays ε-feasible.
        let mut rng = Rng::seed_from_u64(0);
        let x = stiefel::random_point_t::<f64>(6, 11, &mut rng);
        let g = M::randn(6, 11, &mut rng);
        let g = g.scale(1.0 / g.norm());
        let xp = Slpg::update(&x, &g, 0.05);
        assert!(stiefel::distance_t(&xp) < 1e-3, "d={}", stiefel::distance_t(&xp));
    }

    #[test]
    fn matches_pogo_on_full_square_case() {
        // §B: for p = n the Euclidean- and canonical-metric directions
        // coincide only when Sym(XᵀG)X = X Sym(... generally they differ;
        // but for G already tangent (G = X S₀, S₀ skew) both reduce to the
        // same tangent step. Check that special case.
        let mut rng = Rng::seed_from_u64(1);
        let n = 7;
        let x = stiefel::random_point_t::<f64>(n, n, &mut rng);
        let s0 = M::randn(n, n, &mut rng).skew();
        let g = matmul(&x, &s0); // tangent gradient
        let eta = 0.05;
        let slpg = Slpg::update(&x, &g, eta);
        let (pogo, _) = crate::optim::pogo::Pogo::update(
            &x,
            &g,
            eta,
            crate::optim::pogo::LambdaPolicy::Half,
        );
        assert!(slpg.sub(&pogo).max_abs() < 1e-9);
    }

    #[test]
    fn converges_to_procrustes_optimum() {
        // For square orthogonal X the optimum of ‖AX − B‖² is the polar
        // factor of AᵀB; SLPG must approach the analytic optimal loss.
        let mut rng = Rng::seed_from_u64(2);
        let p = 6;
        let a = M::randn(p, p, &mut rng);
        let b = M::randn(p, p, &mut rng);
        let mut x = stiefel::random_point_t::<f64>(p, p, &mut rng);
        let loss = |x: &M| matmul(&a, x).sub(&b).norm_sq();
        let xstar = crate::linalg::polar_project(
            &crate::linalg::matmul_at_b(&a, &b),
            crate::linalg::PolarOpts { tol: 1e-12, max_iters: 200 },
        );
        let lstar = loss(&xstar);
        let l0 = loss(&x);
        let mut opt = Slpg::<f64>::new(SlpgConfig { lr: 0.005, ..Default::default() }, 1);
        for _ in 0..1500 {
            let r = matmul(&a, &x).sub(&b);
            let g = crate::linalg::matmul_at_b(&a, &r).scale(2.0);
            opt.step(0, &mut x, &g).unwrap();
        }
        let l1 = loss(&x);
        assert!(
            l1 - lstar < 0.2 * (l0 - lstar),
            "optimality gap not closed: l0={l0} l1={l1} l*={lstar}"
        );
        assert!(stiefel::distance_t(&x) < 1e-4);
    }

    #[test]
    fn prop_feasibility_over_trajectory() {
        testing::forall(
            "SLPG trajectory feasibility",
            6,
            |rng| {
                let (p, n) = testing::gen_wide_shape(rng, 6, 12);
                let x = stiefel::random_point_t::<f64>(p, n, rng);
                let gs: Vec<M> =
                    (0..30).map(|_| testing::gen_bounded::<f64>(rng, p, n, 1.0)).collect();
                (x, gs)
            },
            |(x0, gs)| {
                let mut x = x0.clone();
                for g in gs {
                    x = Slpg::update(&x, g, 0.1);
                    testing::leq(stiefel::distance_t(&x), 1e-2, "distance")?;
                }
                Ok(())
            },
        );
    }
}
