//! Riemannian gradient descent with QR retraction (Absil et al. 2008) —
//! the classical feasible baseline of Fig. 4–8.
//!
//! `X⁺ = qf(X − η X Skew(XᵀG))` where `qf` is the (row-)QR retraction.
//! Exactly feasible each step, but the retraction runs on the host QR
//! substrate — the cost the paper's timing figures are about.

use super::base::{BaseOpt, BaseOptKind};
use super::Orthoptimizer;
use crate::linalg::{qr_retract_rows, Mat, Scalar};

/// RGD hyperparameters.
#[derive(Clone, Copy, Debug)]
pub struct RgdConfig {
    pub lr: f64,
    pub base: BaseOptKind,
}

impl Default for RgdConfig {
    fn default() -> Self {
        RgdConfig { lr: 0.1, base: BaseOptKind::Sgd }
    }
}

/// Riemannian gradient descent with QR retraction.
pub struct Rgd<S: Scalar = f32> {
    cfg: RgdConfig,
    base: BaseOpt<S>,
    name: String,
}

impl<S: Scalar> Rgd<S> {
    pub fn new(cfg: RgdConfig, n_params: usize) -> Self {
        Rgd { cfg, base: BaseOpt::new(cfg.base, n_params), name: "RGD".to_string() }
    }

    /// One RGD update: tangent step then QR retraction.
    pub fn update(x: &Mat<S>, g: &Mat<S>, eta: f64) -> Mat<S> {
        let m = super::pogo::intermediate(x, g, eta);
        qr_retract_rows(&m)
    }
}

impl<S: Scalar> Orthoptimizer<S> for Rgd<S> {
    fn step(&mut self, idx: usize, x: &mut Mat<S>, grad: &Mat<S>) -> anyhow::Result<()> {
        self.base.ensure_slots(idx + 1);
        let g = self.base.transform(idx, grad);
        *x = Rgd::update(x, &g, self.cfg.lr);
        Ok(())
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn lr(&self) -> f64 {
        self.cfg.lr
    }

    fn set_lr(&mut self, lr: f64) {
        self.cfg.lr = lr;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul, matmul_at_b};
    use crate::manifold::stiefel;
    use crate::rng::Rng;
    use crate::testing;

    type M = Mat<f64>;

    #[test]
    fn exactly_feasible_every_step() {
        let mut rng = Rng::seed_from_u64(0);
        let mut x = stiefel::random_point_t::<f64>(5, 13, &mut rng);
        let mut opt = Rgd::<f64>::new(RgdConfig { lr: 0.5, ..Default::default() }, 1);
        for _ in 0..20 {
            let g = M::randn(5, 13, &mut rng).scale(10.0);
            opt.step(0, &mut x, &g).unwrap();
            assert!(stiefel::distance_t(&x) < 1e-9);
        }
    }

    #[test]
    fn descends_procrustes() {
        let mut rng = Rng::seed_from_u64(1);
        let p = 6;
        let a = M::randn(p, p, &mut rng);
        let b = M::randn(p, p, &mut rng);
        let mut x = stiefel::random_point_t::<f64>(p, p, &mut rng);
        let loss = |x: &M| matmul(&a, x).sub(&b).norm_sq();
        let l0 = loss(&x);
        let mut opt = Rgd::<f64>::new(RgdConfig { lr: 0.02, ..Default::default() }, 1);
        for _ in 0..300 {
            let r = matmul(&a, &x).sub(&b);
            let g = matmul_at_b(&a, &r).scale(2.0);
            opt.step(0, &mut x, &g).unwrap();
        }
        assert!(loss(&x) < l0 * 0.5);
    }

    #[test]
    fn zero_gradient_is_fixed_point() {
        let mut rng = Rng::seed_from_u64(2);
        let x = stiefel::random_point_t::<f64>(4, 7, &mut rng);
        let xp = Rgd::update(&x, &M::zeros(4, 7), 0.3);
        assert!(xp.sub(&x).max_abs() < 1e-9);
    }

    #[test]
    fn prop_first_order_agreement_with_pogo() {
        // For small η, RGD(QR) and POGO(λ=1/2) agree to O(η²) — both are
        // retraction(-like) maps of the same tangent step.
        testing::forall(
            "RGD ≈ POGO to first order",
            6,
            |rng| {
                let (p, n) = testing::gen_wide_shape(rng, 5, 10);
                let x = stiefel::random_point_t::<f64>(p, n, rng);
                let g = testing::gen_bounded::<f64>(rng, p, n, 1.0);
                (x, g)
            },
            |(x, g)| {
                let eta = 1e-3;
                let rgd = Rgd::update(x, g, eta);
                let (pogo, _) = crate::optim::pogo::Pogo::update(
                    x,
                    g,
                    eta,
                    crate::optim::pogo::LambdaPolicy::Half,
                );
                testing::leq(rgd.sub(&pogo).norm(), 1e-5, "first-order gap")
            },
        );
    }
}
