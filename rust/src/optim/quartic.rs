//! Solving the landing polynomial `P(λ) = 0` (paper §3.2).
//!
//! `P` is the quartic `aλ⁴ + bλ³ + cλ² + dλ + e` of Lemma 3.1 whose value
//! is the squared manifold distance after the normal step with size λ. The
//! paper picks, among the four roots in the algebraic closure, *the real
//! part of the root with the least |imaginary part|* — the real λ whose
//! step lands closest to the manifold.
//!
//! The solver is Durand–Kerner (simultaneous complex Newton iteration on
//! all roots): a closed-form Ferrari solution exists — the property the
//! paper leans on — but Durand–Kerner has the same cost envelope
//! (microseconds; the coefficients, not the solve, dominate at `O(p²n)`)
//! and far better numerical behaviour near the repeated-root cases that
//! actually occur when `M` is already ε-close to the manifold. Residuals
//! are verified in tests against direct polynomial evaluation.

/// Minimal complex arithmetic (no `num-complex` in the offline registry).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct C64 {
    pub re: f64,
    pub im: f64,
}

impl C64 {
    pub const ZERO: C64 = C64 { re: 0.0, im: 0.0 };
    pub const ONE: C64 = C64 { re: 1.0, im: 0.0 };

    pub fn new(re: f64, im: f64) -> C64 {
        C64 { re, im }
    }
    pub fn real(re: f64) -> C64 {
        C64 { re, im: 0.0 }
    }
    pub fn add(self, o: C64) -> C64 {
        C64::new(self.re + o.re, self.im + o.im)
    }
    pub fn sub(self, o: C64) -> C64 {
        C64::new(self.re - o.re, self.im - o.im)
    }
    pub fn mul(self, o: C64) -> C64 {
        C64::new(self.re * o.re - self.im * o.im, self.re * o.im + self.im * o.re)
    }
    pub fn div(self, o: C64) -> C64 {
        let d = o.re * o.re + o.im * o.im;
        C64::new((self.re * o.re + self.im * o.im) / d, (self.im * o.re - self.re * o.im) / d)
    }
    pub fn scale(self, s: f64) -> C64 {
        C64::new(self.re * s, self.im * s)
    }
    pub fn abs(self) -> f64 {
        self.re.hypot(self.im)
    }
}

/// Evaluate a polynomial with real coefficients (highest degree first) at a
/// complex point, via Horner.
pub fn eval_poly(coeffs: &[f64], z: C64) -> C64 {
    let mut acc = C64::ZERO;
    for &c in coeffs {
        acc = acc.mul(z).add(C64::real(c));
    }
    acc
}

/// All complex roots of a real-coefficient polynomial (highest degree
/// first), via Durand–Kerner. Leading near-zero coefficients are deflated.
/// Degree after deflation must be ≥ 1.
pub fn poly_roots(coeffs: &[f64]) -> Vec<C64> {
    // Deflate leading ~zeros (relative to the largest coefficient).
    let maxc = coeffs.iter().fold(0.0f64, |m, &c| m.max(c.abs()));
    assert!(maxc > 0.0, "zero polynomial has no roots");
    let tol = maxc * 1e-14;
    let mut start = 0;
    while start < coeffs.len() - 1 && coeffs[start].abs() <= tol {
        start += 1;
    }
    let c = &coeffs[start..];
    let deg = c.len() - 1;
    assert!(deg >= 1, "constant polynomial has no roots");

    // Normalize to monic.
    let lead = c[0];
    let monic: Vec<f64> = c.iter().map(|&x| x / lead).collect();

    // Durand–Kerner from the standard staggered initial guesses on a
    // circle of radius r = 1 + max|coef| (Cauchy bound).
    let r = 1.0 + monic.iter().skip(1).fold(0.0f64, |m, &x| m.max(x.abs()));
    let mut roots: Vec<C64> = (0..deg)
        .map(|k| {
            let theta = 2.0 * std::f64::consts::PI * (k as f64) / (deg as f64) + 0.4;
            C64::new(r * theta.cos(), r * theta.sin())
        })
        .collect();

    for _ in 0..200 {
        let mut max_delta = 0.0f64;
        for i in 0..deg {
            let zi = roots[i];
            let mut denom = C64::ONE;
            for (j, &zj) in roots.iter().enumerate() {
                if j != i {
                    denom = denom.mul(zi.sub(zj));
                }
            }
            if denom.abs() < 1e-300 {
                // Perturb coincident estimates.
                roots[i] = zi.add(C64::new(1e-8, 1e-8));
                continue;
            }
            let delta = eval_poly(&monic, zi).div(denom);
            roots[i] = zi.sub(delta);
            max_delta = max_delta.max(delta.abs());
        }
        if max_delta < 1e-14 {
            break;
        }
    }
    roots
}

/// The paper's root-selection rule for the landing polynomial: return the
/// real part of the root with the smallest |Im| (ties → smaller |value|).
pub fn pick_landing_lambda(roots: &[C64]) -> f64 {
    let mut best = (f64::INFINITY, f64::INFINITY, 0.0f64);
    for r in roots {
        let key = (r.im.abs(), r.abs());
        if key < (best.0, best.1) {
            best = (key.0, key.1, r.re);
        }
    }
    best.2
}

/// Fixed-storage Durand–Kerner for the quartic case: same deflation, monic
/// normalization, initial guesses, and iteration as [`poly_roots`], but on
/// caller-provided arrays so the hot FindRoot path never touches the heap.
/// Writes the roots into `roots[..deg]` and returns `deg`.
fn quartic_roots(coeffs: &[f64; 5], roots: &mut [C64; 4]) -> usize {
    let maxc = coeffs.iter().fold(0.0f64, |m, &c| m.max(c.abs()));
    debug_assert!(maxc > 0.0, "zero polynomial has no roots");
    let tol = maxc * 1e-14;
    let mut start = 0;
    while start < coeffs.len() - 1 && coeffs[start].abs() <= tol {
        start += 1;
    }
    let c = &coeffs[start..];
    let deg = c.len() - 1;

    let lead = c[0];
    let mut monic_buf = [0.0f64; 5];
    for (m, &x) in monic_buf.iter_mut().zip(c.iter()) {
        *m = x / lead;
    }
    let monic = &monic_buf[..deg + 1];

    let r = 1.0 + monic.iter().skip(1).fold(0.0f64, |m, &x| m.max(x.abs()));
    for (k, slot) in roots.iter_mut().enumerate().take(deg) {
        let theta = 2.0 * std::f64::consts::PI * (k as f64) / (deg as f64) + 0.4;
        *slot = C64::new(r * theta.cos(), r * theta.sin());
    }

    for _ in 0..200 {
        let mut max_delta = 0.0f64;
        for i in 0..deg {
            let zi = roots[i];
            let mut denom = C64::ONE;
            for (j, &zj) in roots.iter().enumerate().take(deg) {
                if j != i {
                    denom = denom.mul(zi.sub(zj));
                }
            }
            if denom.abs() < 1e-300 {
                roots[i] = zi.add(C64::new(1e-8, 1e-8));
                continue;
            }
            let delta = eval_poly(monic, zi).div(denom);
            roots[i] = zi.sub(delta);
            max_delta = max_delta.max(delta.abs());
        }
        if max_delta < 1e-14 {
            break;
        }
    }
    deg
}

/// Solve the quartic landing polynomial given coefficients
/// `[a₄, a₃, a₂, a₁, a₀]` (highest first) and apply the selection rule.
/// Allocation-free: this runs once per matrix per FindRoot step inside the
/// fused batched path.
pub fn solve_landing_quartic(coeffs: [f64; 5]) -> f64 {
    // Degenerate cases: P ~0 for every λ (M on manifold) or a trajectory
    // that already blew up (non-finite coefficients) — return the default
    // λ and let the caller's divergence telemetry handle it.
    let scale = coeffs.iter().fold(0.0f64, |m, &c| m.max(c.abs()));
    if scale < 1e-30 || !scale.is_finite() {
        return 0.5;
    }
    let mut roots = [C64::ZERO; 4];
    let deg = quartic_roots(&coeffs, &mut roots);
    pick_landing_lambda(&roots[..deg])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_root_set(coeffs: &[f64], expect: &[C64], tol: f64) {
        let mut roots = poly_roots(coeffs);
        for e in expect {
            // Find and remove the closest root.
            let (idx, dist) = roots
                .iter()
                .enumerate()
                .map(|(i, r)| (i, r.sub(*e).abs()))
                .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                .unwrap();
            assert!(dist < tol, "missing root {e:?} (closest at distance {dist})");
            roots.remove(idx);
        }
    }

    #[test]
    fn factored_quartic_roots() {
        // (λ−1)(λ−2)(λ−3)(λ−4) = λ⁴ −10λ³ +35λ² −50λ +24
        assert_root_set(
            &[1.0, -10.0, 35.0, -50.0, 24.0],
            &[C64::real(1.0), C64::real(2.0), C64::real(3.0), C64::real(4.0)],
            1e-8,
        );
    }

    #[test]
    fn complex_pair_roots() {
        // (λ²+1)(λ−2)(λ+3) = λ⁴ + λ³ − 5λ² + λ − 6
        assert_root_set(
            &[1.0, 1.0, -5.0, 1.0, -6.0],
            &[C64::new(0.0, 1.0), C64::new(0.0, -1.0), C64::real(2.0), C64::real(-3.0)],
            1e-8,
        );
    }

    #[test]
    fn repeated_roots_converge() {
        // (λ−1)²(λ+2)² = λ⁴ + 2λ³ − 3λ² − 4λ + 4
        let roots = poly_roots(&[1.0, 2.0, -3.0, -4.0, 4.0]);
        for r in roots {
            let near1 = r.sub(C64::real(1.0)).abs() < 1e-4;
            let near2 = r.sub(C64::real(-2.0)).abs() < 1e-4;
            assert!(near1 || near2, "stray root {r:?}");
        }
    }

    #[test]
    fn residuals_small() {
        let coeffs = [2.5, -1.0, 3.0, 0.25, -7.0];
        for r in poly_roots(&coeffs) {
            assert!(eval_poly(&coeffs, r).abs() < 1e-6);
        }
    }

    #[test]
    fn deflates_zero_leading_coefficient() {
        // 0·λ⁴ + λ² − 4 = 0 → ±2
        assert_root_set(&[0.0, 0.0, 1.0, 0.0, -4.0], &[C64::real(2.0), C64::real(-2.0)], 1e-8);
    }

    #[test]
    fn selection_prefers_real_roots() {
        // Roots {±i, 2, −3}: rule picks a real root, the one with smaller
        // modulus... both 2 and −3 have Im=0; tie-break on |value| → 2.
        let roots =
            vec![C64::new(0.0, 1.0), C64::new(0.0, -1.0), C64::real(2.0), C64::real(-3.0)];
        assert!((pick_landing_lambda(&roots) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn selection_takes_real_part_when_all_complex() {
        let roots = vec![C64::new(0.4, 0.3), C64::new(0.4, -0.3), C64::new(5.0, 2.0),
                         C64::new(5.0, -2.0)];
        assert!((pick_landing_lambda(&roots) - 0.4).abs() < 1e-12);
    }

    #[test]
    fn degenerate_all_zero_returns_half() {
        assert_eq!(solve_landing_quartic([0.0; 5]), 0.5);
    }

    #[test]
    fn fixed_storage_quartic_matches_poly_roots_bitwise() {
        // The allocation-free path must mirror poly_roots exactly — the
        // fused and naive FindRoot engines both funnel through
        // solve_landing_quartic, and parity tests compare them bit-for-bit.
        let cases: [[f64; 5]; 5] = [
            [1.0, -10.0, 35.0, -50.0, 24.0],
            [1.0, 1.0, -5.0, 1.0, -6.0],
            [2.5, -1.0, 3.0, 0.25, -7.0],
            [0.0, 0.0, 1.0, 0.0, -4.0],
            [1e-3, 0.7, -0.2, 0.05, -1e-4],
        ];
        for coeffs in cases {
            let vec_roots = poly_roots(&coeffs);
            let mut arr_roots = [C64::ZERO; 4];
            let deg = quartic_roots(&coeffs, &mut arr_roots);
            assert_eq!(deg, vec_roots.len());
            for (a, v) in arr_roots[..deg].iter().zip(&vec_roots) {
                assert_eq!(a.re.to_bits(), v.re.to_bits());
                assert_eq!(a.im.to_bits(), v.im.to_bits());
            }
            assert_eq!(
                solve_landing_quartic(coeffs).to_bits(),
                pick_landing_lambda(&vec_roots).to_bits()
            );
        }
    }
}
