//! POGO — Proximal One-step Geometric Orthoptimizer (the paper's Alg. 1).
//!
//! Per update, for `X ∈ St(p, n)` and Euclidean gradient `∇f`:
//!
//! 1. `G = BaseOptimizer(∇f)`                       (§3.1, linear BO)
//! 2. `R = X·Skew(Xᵀ G)`  — Riemannian gradient. Computed in the
//!    *small-gram* form `R = ½((X Xᵀ)G − (X Gᵀ)X)` so every product is
//!    `O(p² n)` instead of `O(n² p)` (matters for wide matrices).
//! 3. `M = X − η R`       — intermediate step in the tangent direction.
//! 4. `X⁺ = M + λ(I − M Mᵀ)M` — proximal normal step, with λ either the
//!    root of the landing polynomial (exact landing, §3.2) or the constant
//!    `1/2` (the `o(ξ^{7/2})` approximation of §3.3 / Thm 3.5).
//!
//! Matmul-only ⇒ this same rule is the L1 Pallas kernel
//! (`python/compile/kernels/pogo_step.py`); integration tests check the
//! two engines agree.
//!
//! Written ONCE over a [`Field`] element (paper §2, fn. 1): on real
//! fields the adjoints degenerate to transposes and the code is the
//! original real POGO; on `Complex<S>` the same functions are the unitary
//! POGO (`Skew` becomes the skew-Hermitian projection, and the
//! landing-quartic coefficients stay real — they are Frobenius norms and
//! real inner products of Hermitian matrices).

use super::base::{BaseOpt, BaseOptKind};
use super::quartic::solve_landing_quartic;
use super::Orthoptimizer;
use crate::linalg::{matmul, matmul_a_bh, Field, Mat, Scalar};

/// How λ is chosen each step.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LambdaPolicy {
    /// Fix λ = 1/2 (default; Thm 3.5 guarantees o(ξ⁷) squared distance).
    Half,
    /// Solve the quartic landing polynomial for the exact landing λ.
    FindRoot,
}

impl LambdaPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            LambdaPolicy::Half => "half",
            LambdaPolicy::FindRoot => "find-root",
        }
    }

    pub fn parse(s: &str) -> Option<LambdaPolicy> {
        Some(match s.to_ascii_lowercase().as_str() {
            "half" => LambdaPolicy::Half,
            "find-root" | "findroot" | "find_root" | "root" => LambdaPolicy::FindRoot,
            _ => return None,
        })
    }
}

/// POGO hyperparameters.
#[derive(Clone, Copy, Debug)]
pub struct PogoConfig {
    pub lr: f64,
    pub lambda: LambdaPolicy,
    pub base: BaseOptKind,
}

impl Default for PogoConfig {
    fn default() -> Self {
        PogoConfig { lr: 0.1, lambda: LambdaPolicy::Half, base: BaseOptKind::Sgd }
    }
}

/// POGO over Stiefel matrices of any field (`f32`/`f64` real,
/// `Complex<S>` unitary).
pub struct Pogo<E: Field = f32> {
    cfg: PogoConfig,
    base: BaseOpt<E>,
    name: String,
    /// Landing-polynomial coefficients of the last step (telemetry).
    pub last_lambda: f64,
}

impl<E: Field> Pogo<E> {
    pub fn new(cfg: PogoConfig, n_params: usize) -> Self {
        let name = match cfg.lambda {
            LambdaPolicy::Half => format!("POGO({})", cfg.base.name()),
            LambdaPolicy::FindRoot => format!("POGO-root({})", cfg.base.name()),
        };
        Pogo { cfg, base: BaseOpt::new(cfg.base, n_params), name, last_lambda: 0.5 }
    }

    pub fn config(&self) -> &PogoConfig {
        &self.cfg
    }

    /// The POGO update on a single matrix, exposed as a free function so the
    /// property tests and the batched coordinator can drive it directly.
    pub fn update(x: &Mat<E>, g: &Mat<E>, eta: f64, policy: LambdaPolicy) -> (Mat<E>, f64) {
        let m = intermediate(x, g, eta);
        let (xp, lam) = normal_step(&m, policy);
        (xp, lam)
    }
}

/// `M = X − η·X SkewH(XᴴG)`, small-gram form (real fields: `Skew(XᵀG)`).
pub fn intermediate<E: Field>(x: &Mat<E>, g: &Mat<E>, eta: f64) -> Mat<E> {
    let xxh = matmul_a_bh(x, x); // p×p
    let xgh = matmul_a_bh(x, g); // p×p
    let a1 = matmul(&xxh, g); // (X Xᴴ) G : p×n
    let a2 = matmul(&xgh, x); // (X Gᴴ) X : p×n
    // R = ½ (A1 − A2); M = X − η R
    let mut m = x.clone();
    let he = E::from_f64(-0.5 * eta);
    m.axpy(he, &a1);
    m.axpy(E::from_f64(0.5 * eta), &a2);
    m
}

/// The normal step `X⁺ = M + λ(I − M Mᴴ)M`, with λ per policy.
/// Returns `(X⁺, λ)`.
pub fn normal_step<E: Field>(m: &Mat<E>, policy: LambdaPolicy) -> (Mat<E>, f64) {
    let mut c = matmul_a_bh(m, m); // p×p gram N = M Mᴴ
    c.sub_eye_inplace(); // C = N − I  (Hermitian)
    let lam = match policy {
        LambdaPolicy::Half => 0.5,
        LambdaPolicy::FindRoot => {
            let coeffs = landing_coeffs(&c);
            solve_landing_quartic(coeffs)
        }
    };
    // B = −C M; X⁺ = M + λ B.
    let b = matmul(&c, m);
    let mut xp = m.clone();
    xp.axpy(E::from_f64(-lam), &b);
    (xp, lam)
}

/// Landing-polynomial coefficients `[a₄, a₃, a₂, a₁, a₀]` from the p×p
/// gram residual `C = M Mᴴ − I` alone (Lemma 3.1 with the identities
/// `B = −C M`, `D = M Bᴴ + B Mᴴ = −(N C + C N)`, `E = B Bᴴ = C N C`, where
/// `N = C + I`). Everything is `O(p³)` on p×p Hermitian matrices — *no*
/// additional p×n products. The coefficients are **real on either field**
/// (norms and real inner products of Hermitian matrices), so the quartic
/// solve is field-independent.
///
/// Note: the published Lemma 3.1 has two typos in the λ² and λ¹ terms; we
/// implement the exact expansion of ‖C + Dλ + Eλ²‖², which tests verify
/// against the directly-computed squared distance.
pub fn landing_coeffs<E: Field>(c: &Mat<E>) -> [f64; 5] {
    let (p, q) = c.shape();
    assert_eq!(p, q, "landing_coeffs expects the square gram residual");
    let mut scratch = CoeffScratch::new(p);
    landing_coeffs_slice(c.as_slice(), p, &mut scratch)
}

/// Reusable p×p work buffers for [`landing_coeffs_slice`]: `N = C + I`,
/// `N·C`, `D`, and `E` of the Lemma 3.1 identities. One per
/// (thread, element-type, p) via [`with_coeff_scratch`] on the hot path.
pub struct CoeffScratch<E: Field> {
    n: Vec<E>,
    nc: Vec<E>,
    d: Vec<E>,
    e: Vec<E>,
}

impl<E: Field> CoeffScratch<E> {
    pub fn new(p: usize) -> Self {
        CoeffScratch {
            n: vec![E::ZERO; p * p],
            nc: vec![E::ZERO; p * p],
            d: vec![E::ZERO; p * p],
            e: vec![E::ZERO; p * p],
        }
    }
}

/// Run `f` with this thread's [`CoeffScratch`] for `(E, p)` — allocated on
/// first use, reused on every later FindRoot solve from the same thread.
/// Resident pool workers persist across steps, so the steady-state fused
/// FindRoot path stays off the heap entirely.
pub fn with_coeff_scratch<E: Field, R>(p: usize, f: impl FnOnce(&mut CoeffScratch<E>) -> R) -> R {
    crate::util::pool::with_scratch(p, 0, || CoeffScratch::<E>::new(p), f)
}

/// [`landing_coeffs`] on a raw row-major `p×p` slice with caller-provided
/// scratch — the allocation-free form used by the fused batched FindRoot
/// path, where `C` arrives as a chunk of [`StepScratch`] storage rather
/// than a [`Mat`]. Mirrors the `Mat` arithmetic operation-for-operation
/// (same products through the same row kernels, same elementwise order,
/// same sequential reductions), so both forms are bit-identical — pinned
/// by a test below.
pub fn landing_coeffs_slice<E: Field>(c: &[E], p: usize, s: &mut CoeffScratch<E>) -> [f64; 5] {
    assert_eq!(c.len(), p * p, "landing_coeffs_slice expects a p×p gram residual");
    assert_eq!(s.n.len(), p * p, "CoeffScratch sized for a different p");
    let kern = E::step_kernel();
    // N = C + I
    s.n.copy_from_slice(c);
    for i in 0..p {
        s.n[i * p + i] += E::ONE;
    }
    // N C
    s.nc.fill(E::ZERO);
    kern.mm_rows(&s.n, c, 0..p, &mut s.nc, p, p);
    // D = −(N C + (N C)ᴴ)   (since C, N Hermitian ⇒ C N = (N C)ᴴ)
    for i in 0..p {
        for j in 0..p {
            let mut v = s.nc[i * p + j] + s.nc[j * p + i].conj();
            v *= -E::ONE;
            s.d[i * p + j] = v;
        }
    }
    // E = C N C = (N C)ᴴ C ... use E = Cᴴ(NC) with C Hermitian: C·(N C).
    s.e.fill(E::ZERO);
    kern.mm_rows(c, &s.nc, 0..p, &mut s.e, p, p);
    // ‖C + Dλ + Eλ²‖² coefficients (real inner products).
    let a4 = dot_re_slice(&s.e, &s.e).to_f64();
    let a3 = 2.0 * dot_re_slice(&s.d, &s.e).to_f64();
    let a2 = dot_re_slice(&s.d, &s.d).to_f64() + 2.0 * dot_re_slice(c, &s.e).to_f64();
    let a1 = 2.0 * dot_re_slice(c, &s.d).to_f64();
    let a0 = dot_re_slice(c, c).to_f64();
    [a4, a3, a2, a1, a0]
}

/// `Mat::dot_re` on raw slices: the same sequential reduction, so the two
/// forms accumulate in the same order.
fn dot_re_slice<E: Field>(a: &[E], b: &[E]) -> E::Real {
    let mut acc = E::Real::ZERO;
    for (&x, &y) in a.iter().zip(b) {
        acc += x.mul_conj(y).re();
    }
    acc
}

/// Evaluate the landing polynomial at λ (used by tests and the ablation).
pub fn landing_poly_eval(coeffs: &[f64; 5], lam: f64) -> f64 {
    coeffs.iter().fold(0.0, |acc, &c| acc * lam + c)
}

impl<E: Field> Orthoptimizer<E> for Pogo<E> {
    fn step(&mut self, idx: usize, x: &mut Mat<E>, grad: &Mat<E>) -> anyhow::Result<()> {
        self.base.ensure_slots(idx + 1);
        let g = self.base.transform(idx, grad);
        let (xp, lam) = Pogo::update(x, &g, self.cfg.lr, self.cfg.lambda);
        self.last_lambda = lam;
        *x = xp;
        Ok(())
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn lr(&self) -> f64 {
        self.cfg.lr
    }

    fn set_lr(&mut self, lr: f64) {
        self.cfg.lr = lr;
    }

    fn last_lambda(&self) -> Option<f64> {
        Some(self.last_lambda)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifold::stiefel;
    use crate::rng::Rng;
    use crate::testing;

    type M = Mat<f64>;

    #[test]
    fn intermediate_matches_naive_formula() {
        let mut rng = Rng::seed_from_u64(0);
        let x = stiefel::random_point_t::<f64>(5, 9, &mut rng);
        let g = M::randn(5, 9, &mut rng);
        let m = intermediate(&x, &g, 0.3);
        // Naive: M = X − η X Skew(XᵀG) with the n×n skew.
        let s = crate::linalg::matmul_at_b(&x, &g).skew();
        let r = matmul(&x, &s);
        let mut want = x.clone();
        want.axpy(-0.3, &r);
        assert!(m.sub(&want).max_abs() < 1e-12);
    }

    #[test]
    fn single_step_stays_on_manifold_lambda_half() {
        // Thm 3.5 with t = 1: distance² = o(ξ⁷).
        let mut rng = Rng::seed_from_u64(1);
        let x = stiefel::random_point_t::<f64>(8, 16, &mut rng);
        let g = M::randn(8, 16, &mut rng);
        let eta = 0.5 / g.norm(); // ξ = 0.5
        let (xp, lam) = Pogo::update(&x, &g, eta, LambdaPolicy::Half);
        assert_eq!(lam, 0.5);
        let d = stiefel::distance_t(&xp);
        // o(ξ^{7/2}) with ξ=0.5 → well below 0.09; in practice ≪ 1e-3.
        assert!(d < 1e-3, "distance {d}");
    }

    #[test]
    fn find_root_lands_closer_or_equal() {
        // For p > 1 the quartic's minimum is generically > 0 (one normal
        // step cannot land exactly), so the roots are complex and the
        // paper's rule picks the real part of the least-imaginary root —
        // which should do no worse than λ = 1/2 and be near the grid
        // minimum of P over λ.
        let mut rng = Rng::seed_from_u64(2);
        let x = stiefel::random_point_t::<f64>(6, 10, &mut rng);
        let g = M::randn(6, 10, &mut rng).scale(4.0);
        let eta = 0.8 / g.norm(); // larger ξ so λ=1/2 is visibly inexact
        let (x_half, _) = Pogo::update(&x, &g, eta, LambdaPolicy::Half);
        let (x_root, lam) = Pogo::update(&x, &g, eta, LambdaPolicy::FindRoot);
        let (dh, dr) = (stiefel::distance_t(&x_half), stiefel::distance_t(&x_root));
        assert!(dr <= dh + 1e-12, "root {dr} vs half {dh} (λ={lam})");
        // Compare against a dense grid minimum of the landing polynomial.
        let m = intermediate(&x, &g, eta);
        let mut c = matmul_a_bh(&m, &m);
        c.sub_eye_inplace();
        let coeffs = landing_coeffs(&c);
        let grid_min = (0..=2000)
            .map(|i| landing_poly_eval(&coeffs, i as f64 * 1e-3))
            .fold(f64::INFINITY, f64::min);
        assert!(
            dr * dr <= grid_min * 1.05 + 1e-12,
            "root λ={lam} gives {} vs grid min {grid_min}",
            dr * dr
        );
    }

    #[test]
    fn slice_coeffs_match_mat_ops_bitwise() {
        // The scratch-based slice form must reproduce the original
        // Mat-expression arithmetic bit-for-bit (same products, same
        // elementwise order, same sequential reductions) — this is what
        // lets the allocation-free fused FindRoot path stay parity-exact
        // with the naive per-matrix engine.
        let mut rng = Rng::seed_from_u64(11);
        for p in [2usize, 4, 7] {
            let x = stiefel::random_point_t::<f64>(p, p + 5, &mut rng);
            let g = M::randn(p, p + 5, &mut rng);
            let m = intermediate(&x, &g, 0.37);
            let mut c = matmul_a_bh(&m, &m);
            c.sub_eye_inplace();
            // Original Mat-ops expression.
            let n = {
                let mut n = c.clone();
                n.add_diag_inplace(1.0);
                n
            };
            let nc = matmul(&n, &c);
            let d = {
                let mut d = nc.add(&nc.adjoint());
                d.scale_inplace(-1.0);
                d
            };
            let e = matmul(&c, &nc);
            let want = [
                e.dot_re(&e).to_f64(),
                2.0 * d.dot_re(&e).to_f64(),
                d.dot_re(&d).to_f64() + 2.0 * c.dot_re(&e).to_f64(),
                2.0 * c.dot_re(&d).to_f64(),
                c.dot_re(&c).to_f64(),
            ];
            let mut scratch = CoeffScratch::new(p);
            let got = landing_coeffs_slice(c.as_slice(), p, &mut scratch);
            for (g, w) in got.iter().zip(&want) {
                assert_eq!(g.to_bits(), w.to_bits(), "coefficient drifted at p={p}");
            }
            // And the Mat entry point delegates to the same path.
            let via_mat = landing_coeffs(&c);
            for (g, w) in via_mat.iter().zip(&got) {
                assert_eq!(g.to_bits(), w.to_bits());
            }
        }
    }

    #[test]
    fn landing_coeffs_match_direct_evaluation() {
        // P(λ) from the symbolic coefficients must equal ‖X₁X₁ᵀ−I‖²
        // computed directly, for several λ.
        let mut rng = Rng::seed_from_u64(3);
        let x = stiefel::random_point_t::<f64>(4, 7, &mut rng);
        let g = M::randn(4, 7, &mut rng);
        let m = intermediate(&x, &g, 0.4);
        let mut c = matmul_a_bh(&m, &m);
        c.sub_eye_inplace();
        let coeffs = landing_coeffs(&c);
        for &lam in &[0.0, 0.25, 0.5, 1.0, 2.0] {
            let b = matmul(&c, &m);
            let mut x1 = m.clone();
            x1.axpy(-lam, &b);
            let direct = {
                let d = stiefel::distance_t(&x1);
                d * d
            };
            let symbolic = landing_poly_eval(&coeffs, lam);
            assert!(
                (direct - symbolic).abs() < 1e-9 * (1.0 + direct),
                "λ={lam}: direct {direct} vs symbolic {symbolic}"
            );
        }
    }

    #[test]
    fn prop_stays_near_manifold_multi_step() {
        // Run 50 POGO steps with ξ < 1 from a manifold start; every iterate
        // must stay o(ξ^{7/2})-close (Thm 3.5). Random shapes and grads.
        testing::forall(
            "POGO multi-step feasibility",
            8,
            |rng| {
                let (p, n) = testing::gen_wide_shape(rng, 8, 16);
                let x = stiefel::random_point_t::<f64>(p, n, rng);
                let gs: Vec<M> =
                    (0..50).map(|_| testing::gen_bounded::<f64>(rng, p, n, 1.0)).collect();
                (x, gs)
            },
            |(x0, gs)| {
                let mut x = x0.clone();
                let eta = 0.3; // ‖G‖ ≤ 1 ⇒ ξ ≤ 0.3
                for g in gs {
                    let (xp, _) = Pogo::update(&x, g, eta, LambdaPolicy::Half);
                    x = xp;
                    testing::leq(stiefel::distance_t(&x), 1e-3, "manifold distance")?;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_find_root_residual_zero() {
        testing::forall(
            "landing quartic residual",
            8,
            |rng| {
                let (p, n) = testing::gen_wide_shape(rng, 6, 12);
                let x = stiefel::random_point_t::<f64>(p, n, rng);
                let g = M::randn(p, n, rng).scale(2.0);
                (x, g)
            },
            |(x, g)| {
                let eta = 0.5 / g.norm();
                let m = intermediate(x, g, eta);
                let mut c = matmul_a_bh(&m, &m);
                c.sub_eye_inplace();
                let coeffs = landing_coeffs(&c);
                let lam = solve_landing_quartic(coeffs);
                let p_at_root = landing_poly_eval(&coeffs, lam);
                // P ≥ 0 everywhere; the chosen λ must be a near-minimizer
                // (≤ grid minimum + slack) and beat both endpoints.
                let grid_min = (0..=2000)
                    .map(|i| landing_poly_eval(&coeffs, i as f64 * 1e-3))
                    .fold(f64::INFINITY, f64::min);
                let p0 = coeffs[4].max(1e-30);
                testing::leq(p_at_root, grid_min * 1.05 + p0 * 1e-9, "near grid minimum")?;
                testing::leq(p_at_root, landing_poly_eval(&coeffs, 0.5), "≤ P(1/2)")
            },
        );
    }

    #[test]
    fn optimizer_trait_descends_procrustes() {
        // End-to-end sanity: POGO(SGD) monotonically-ish decreases
        // ‖AX − B‖² while staying feasible.
        let mut rng = Rng::seed_from_u64(7);
        let (p, n) = (8, 8);
        let a = M::randn(p, p, &mut rng);
        let b = M::randn(p, n, &mut rng);
        let mut x = stiefel::random_point_t::<f64>(p, n, &mut rng);
        let mut opt =
            Pogo::<f64>::new(PogoConfig { lr: 0.05, ..Default::default() }, 1);
        let loss = |x: &M| {
            let r = matmul(&a, x).sub(&b);
            r.norm_sq()
        };
        let l0 = loss(&x);
        for _ in 0..200 {
            let r = matmul(&a, &x).sub(&b);
            let grad = crate::linalg::matmul_at_b(&a, &r).scale(2.0);
            opt.step(0, &mut x, &grad).unwrap();
        }
        let l1 = loss(&x);
        assert!(l1 < l0 * 0.9, "no descent: {l0} → {l1}");
        assert!(stiefel::distance_t(&x) < 1e-4);
    }

    #[test]
    fn vadam_base_controls_large_gradients() {
        // With raw SGD a huge gradient would fling X off the manifold;
        // VAdam's normalization keeps ξ < 1 (paper §3.3 "in practice").
        let mut rng = Rng::seed_from_u64(8);
        let mut x = stiefel::random_point_t::<f64>(6, 12, &mut rng);
        let mut opt = Pogo::<f64>::new(
            PogoConfig { lr: 0.2, lambda: LambdaPolicy::Half, base: BaseOptKind::vadam() },
            1,
        );
        for _ in 0..30 {
            let g = M::randn(6, 12, &mut rng).scale(100.0);
            opt.step(0, &mut x, &g).unwrap();
            assert!(stiefel::distance_t(&x) < 1e-2, "d={}", stiefel::distance_t(&x));
        }
    }
}
