//! RSDM — Randomized Riemannian Submanifold Descent (Han et al. 2025).
//!
//! Instead of retracting the full `p × n` matrix, each step samples an
//! `r`-row submanifold and performs a Riemannian update of the rotation
//! acting on those rows only:
//!
//! 1. sample `r` distinct row indices `I` (the "orthogonal sampling" of
//!    the paper corresponds to conjugating by a random rotation; we expose
//!    both subset sampling and Haar mixing),
//! 2. `B = Skew((G Xᵀ)[I, I])` — the gradient of `f(O X)` w.r.t. the `r×r`
//!    rotation block at `O = I`,
//! 3. `Q_r = qf(I_r − η B)` — QR retraction on the small group,
//! 4. `X[I, :] ← Q_r · X[I, :]`.
//!
//! Left-multiplication by an orthogonal block *preserves feasibility in
//! exact arithmetic* but repeated f32 products accumulate drift — the
//! paper's Fig. 4/5 observation that RSDM strays from the manifold (and
//! §C.5: in f64 the drift disappears). Our implementation reproduces that
//! faithfully by never re-projecting.

use super::base::{BaseOpt, BaseOptKind};
use super::Orthoptimizer;
use crate::linalg::{qr_thin, Mat, Scalar};
use crate::rng::Rng;

/// RSDM hyperparameters.
#[derive(Clone, Copy, Debug)]
pub struct RsdmConfig {
    pub lr: f64,
    /// Submanifold dimension r (rows updated per step).
    pub submanifold_dim: usize,
    pub base: BaseOptKind,
    /// RNG seed for the row sampling stream.
    pub seed: u64,
    /// "Orthogonal sampling" (Han et al. 2025, the variant the paper's §5
    /// baselines use): conjugate the r-dimensional update by a fresh Haar
    /// random orthonormal frame instead of a coordinate subset. Costlier
    /// per step (a p×r QR + two r×n products) but explores all directions.
    pub haar_mixing: bool,
}

impl Default for RsdmConfig {
    fn default() -> Self {
        RsdmConfig {
            lr: 0.5,
            submanifold_dim: 32,
            base: BaseOptKind::Sgd,
            seed: 0,
            haar_mixing: false,
        }
    }
}

/// Randomized Riemannian submanifold descent.
pub struct Rsdm<S: Scalar = f32> {
    cfg: RsdmConfig,
    base: BaseOpt<S>,
    rng: Rng,
    name: String,
}

impl<S: Scalar> Rsdm<S> {
    pub fn new(cfg: RsdmConfig, n_params: usize) -> Self {
        Rsdm {
            cfg,
            base: BaseOpt::new(cfg.base, n_params),
            rng: Rng::seed_from_u64(cfg.seed ^ 0x5D_D0_5A_11),
            name: format!("RSDM(r={})", cfg.submanifold_dim),
        }
    }

    /// One RSDM update with Haar orthogonal sampling (in place):
    /// sample a Haar frame `P ∈ St(r, p)`, rotate within its span by
    /// `Q_r = qf(I − η·Skew(P (G Xᵀ) Pᵀ))`:
    /// `X ← X + Pᵀ(Q_r − I) P X`.
    pub fn update_haar(x: &mut Mat<S>, g: &Mat<S>, eta: f64, r: usize, rng: &mut Rng) {
        let p = x.rows();
        let r = r.min(p);
        // Haar frame via QR of a Gaussian (rows orthonormal, r×p).
        let frame = crate::linalg::qr_thin(&Mat::<S>::randn(p, r, rng)).transpose();
        // Rotation gradient at identity restricted to the frame.
        let gxt = crate::linalg::matmul_a_bt(g, x); // p×p
        let pg = crate::linalg::matmul(&frame, &gxt); // r×p
        let b = crate::linalg::matmul_a_bt(&pg, &frame).skew(); // r×r
        let mut step = b.scale(S::from_f64(-eta));
        step.add_diag_inplace(S::ONE);
        let mut q = qr_thin(&step); // r×r rotation
        q.sub_eye_inplace(); // Q_r − I
        // X += Pᵀ (Q_r − I) (P X).
        let px = crate::linalg::matmul(&frame, x); // r×n
        let qpx = crate::linalg::matmul(&q, &px); // r×n
        let upd = crate::linalg::matmul_at_b(&frame, &qpx); // p×n
        x.axpy(S::ONE, &upd);
    }

    /// One RSDM update (in place).
    pub fn update(x: &mut Mat<S>, g: &Mat<S>, eta: f64, r: usize, rng: &mut Rng) {
        let p = x.rows();
        let n = x.cols();
        let r = r.min(p);
        let idx = rng.sample_indices(p, r);

        // Gradient of the rotation at identity, restricted to the block:
        // (G Xᵀ)[I, I], then skew-projected onto so(r).
        // Compute only the needed r×r block: rows of G at idx times rows
        // of X at idx (inner dim n).
        let mut blk = Mat::<S>::zeros(r, r);
        for (bi, &i) in idx.iter().enumerate() {
            let gi = g.row(i);
            for (bj, &j) in idx.iter().enumerate() {
                let xj = x.row(j);
                let mut acc = S::ZERO;
                for k in 0..n {
                    acc += gi[k] * xj[k];
                }
                blk[(bi, bj)] = acc;
            }
        }
        let b = blk.skew();

        // Retraction on SO(r): Q = qf(I − η B).
        let mut step = b.scale(S::from_f64(-eta));
        step.add_diag_inplace(S::ONE);
        let q = qr_thin(&step);

        // X[I, :] ← Q X[I, :].
        let mut sub = Mat::<S>::zeros(r, n);
        for (bi, &i) in idx.iter().enumerate() {
            sub.row_mut(bi).copy_from_slice(x.row(i));
        }
        let rotated = crate::linalg::matmul(&q, &sub);
        for (bi, &i) in idx.iter().enumerate() {
            x.row_mut(i).copy_from_slice(rotated.row(bi));
        }
    }
}

impl<S: Scalar> Orthoptimizer<S> for Rsdm<S> {
    fn step(&mut self, idx: usize, x: &mut Mat<S>, grad: &Mat<S>) -> anyhow::Result<()> {
        self.base.ensure_slots(idx + 1);
        let g = self.base.transform(idx, grad);
        let r = self.cfg.submanifold_dim;
        if self.cfg.haar_mixing {
            Rsdm::update_haar(x, &g, self.cfg.lr, r, &mut self.rng);
        } else {
            Rsdm::update(x, &g, self.cfg.lr, r, &mut self.rng);
        }
        Ok(())
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn lr(&self) -> f64 {
        self.cfg.lr
    }

    fn set_lr(&mut self, lr: f64) {
        self.cfg.lr = lr;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul, matmul_at_b};
    use crate::manifold::stiefel;
    use crate::testing;

    type M = Mat<f64>;

    #[test]
    fn preserves_feasibility_in_f64() {
        // §C.5: with high-precision arithmetic RSDM stays on the manifold.
        let mut rng = Rng::seed_from_u64(0);
        let mut x = stiefel::random_point_t::<f64>(8, 14, &mut rng);
        let mut opt = Rsdm::<f64>::new(
            RsdmConfig { lr: 0.3, submanifold_dim: 4, ..Default::default() },
            1,
        );
        for _ in 0..200 {
            let g = M::randn(8, 14, &mut rng);
            opt.step(0, &mut x, &g).unwrap();
        }
        let d = stiefel::distance_t(&x);
        assert!(d < 1e-8, "f64 drift {d}");
    }

    #[test]
    fn f32_accumulates_drift_relative_to_f64() {
        // The Fig. 4 observation: identical trajectories, f32 drifts more.
        let steps = 500;
        let mk = |seed| {
            let mut rng = Rng::seed_from_u64(seed);
            let xd = stiefel::random_point_t::<f64>(10, 16, &mut rng);
            let gs: Vec<M> = (0..steps).map(|_| M::randn(10, 16, &mut rng)).collect();
            (xd, gs)
        };
        let (x0, gs) = mk(1);
        let mut xf = x0.cast::<f32>();
        let mut xd = x0.clone();
        let mut rng_f = Rng::seed_from_u64(9);
        let mut rng_d = Rng::seed_from_u64(9);
        for g in &gs {
            Rsdm::update(&mut xf, &g.cast::<f32>(), 0.3, 5, &mut rng_f);
            Rsdm::update(&mut xd, g, 0.3, 5, &mut rng_d);
        }
        let df = stiefel::distance_t(&xf);
        let dd = stiefel::distance_t(&xd);
        assert!(df > dd * 10.0, "expected f32 drift ≫ f64: f32 {df} vs f64 {dd}");
    }

    #[test]
    fn descends_procrustes() {
        let mut rng = Rng::seed_from_u64(2);
        let p = 10;
        let a = M::randn(p, p, &mut rng);
        let b = M::randn(p, p, &mut rng);
        let mut x = stiefel::random_point_t::<f64>(p, p, &mut rng);
        let loss = |x: &M| matmul(&a, x).sub(&b).norm_sq();
        let l0 = loss(&x);
        let mut opt = Rsdm::<f64>::new(
            RsdmConfig { lr: 0.01, submanifold_dim: 5, ..Default::default() },
            1,
        );
        for _ in 0..600 {
            let r = matmul(&a, &x).sub(&b);
            let g = matmul_at_b(&a, &r).scale(2.0);
            opt.step(0, &mut x, &g).unwrap();
        }
        assert!(loss(&x) < l0 * 0.7, "{l0} → {}", loss(&x));
    }

    #[test]
    fn updates_only_sampled_rows() {
        let mut rng = Rng::seed_from_u64(3);
        let x0 = stiefel::random_point_t::<f64>(12, 20, &mut rng);
        let g = M::randn(12, 20, &mut rng);
        let mut x = x0.clone();
        let mut srng = Rng::seed_from_u64(4);
        // Reproduce the sampling to know which rows were touched.
        let mut srng_copy = srng.clone();
        let idx = srng_copy.sample_indices(12, 3);
        Rsdm::update(&mut x, &g, 0.2, 3, &mut srng);
        for i in 0..12 {
            let changed = x.row(i).iter().zip(x0.row(i)).any(|(a, b)| (a - b).abs() > 1e-12);
            assert_eq!(changed, idx.contains(&i), "row {i}");
        }
    }

    #[test]
    fn haar_variant_preserves_feasibility_and_descends() {
        let mut rng = Rng::seed_from_u64(5);
        let p = 10;
        let a = M::randn(p, p, &mut rng);
        let b = M::randn(p, p, &mut rng);
        let mut x = stiefel::random_point_t::<f64>(p, p, &mut rng);
        let loss = |x: &M| matmul(&a, x).sub(&b).norm_sq();
        let l0 = loss(&x);
        let mut opt = Rsdm::<f64>::new(
            RsdmConfig {
                lr: 0.01,
                submanifold_dim: 4,
                haar_mixing: true,
                ..Default::default()
            },
            1,
        );
        for _ in 0..400 {
            let r = matmul(&a, &x).sub(&b);
            let g = matmul_at_b(&a, &r).scale(2.0);
            opt.step(0, &mut x, &g).unwrap();
        }
        assert!(loss(&x) < l0 * 0.8, "{l0} → {}", loss(&x));
        assert!(stiefel::distance_t(&x) < 1e-7, "haar drift {}", stiefel::distance_t(&x));
    }

    #[test]
    fn prop_block_rotation_is_orthogonal() {
        testing::forall(
            "RSDM rotation block orthogonality",
            8,
            |rng| {
                let r = 2 + rng.index(6);
                let b = testing::gen_skew::<f64>(rng, r);
                (r, b, rng.uniform_in(0.01, 1.0))
            },
            |(r, b, eta)| {
                let mut step = b.scale(-*eta);
                step.add_diag_inplace(1.0);
                let q = qr_thin(&step);
                let mut qtq = matmul_at_b(&q, &q);
                qtq.sub_eye_inplace();
                testing::leq(qtq.max_abs(), 1e-9, &format!("QᵀQ−I for r={r}"))
            },
        );
    }
}
