//! Unconstrained Adam — the gray-dotted reference line of Figs. 1/5/7.
//!
//! Not an orthoptimizer: it ignores the manifold entirely. Included (a) as
//! the downstream-performance yardstick the paper compares against (D3)
//! and (b) to train the non-orthogonal parameters of the NN experiments.

use super::base::{BaseOpt, BaseOptKind};
use super::Orthoptimizer;
use crate::linalg::{Mat, Scalar};

/// Adam hyperparameters.
#[derive(Clone, Copy, Debug)]
pub struct AdamConfig {
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    pub weight_decay: f64,
}

impl Default for AdamConfig {
    fn default() -> Self {
        AdamConfig { lr: 1e-3, beta1: 0.9, beta2: 0.999, eps: 1e-8, weight_decay: 0.0 }
    }
}

/// Unconstrained Adam over matrices.
pub struct Adam<S: Scalar = f32> {
    cfg: AdamConfig,
    base: BaseOpt<S>,
}

impl<S: Scalar> Adam<S> {
    pub fn new(cfg: AdamConfig, n_params: usize) -> Self {
        let kind = BaseOptKind::Adam { beta1: cfg.beta1, beta2: cfg.beta2, eps: cfg.eps };
        Adam { cfg, base: BaseOpt::new(kind, n_params) }
    }
}

impl<S: Scalar> Orthoptimizer<S> for Adam<S> {
    fn step(&mut self, idx: usize, x: &mut Mat<S>, grad: &Mat<S>) -> anyhow::Result<()> {
        self.base.ensure_slots(idx + 1);
        let g = if self.cfg.weight_decay != 0.0 {
            let mut g = grad.clone();
            g.axpy(S::from_f64(self.cfg.weight_decay), x);
            self.base.transform(idx, &g)
        } else {
            self.base.transform(idx, grad)
        };
        x.axpy(S::from_f64(-self.cfg.lr), &g);
        Ok(())
    }

    fn name(&self) -> &str {
        "Adam"
    }

    fn lr(&self) -> f64 {
        self.cfg.lr
    }

    fn set_lr(&mut self, lr: f64) {
        self.cfg.lr = lr;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn minimizes_quadratic() {
        // f(X) = ‖X − T‖², grad = 2(X − T).
        let mut rng = Rng::seed_from_u64(0);
        let t = Mat::<f64>::randn(4, 6, &mut rng);
        let mut x = Mat::<f64>::zeros(4, 6);
        let mut opt = Adam::<f64>::new(AdamConfig { lr: 0.05, ..Default::default() }, 1);
        for _ in 0..500 {
            let g = x.sub(&t).scale(2.0);
            opt.step(0, &mut x, &g).unwrap();
        }
        assert!(x.sub(&t).norm() < 1e-2, "residual {}", x.sub(&t).norm());
    }

    #[test]
    fn weight_decay_shrinks_params() {
        let mut x = Mat::<f64>::ones(3, 3);
        let zero = Mat::<f64>::zeros(3, 3);
        let mut opt = Adam::<f64>::new(
            AdamConfig { lr: 0.01, weight_decay: 1.0, ..Default::default() },
            1,
        );
        let n0 = x.norm();
        for _ in 0..50 {
            opt.step(0, &mut x, &zero).unwrap();
        }
        assert!(x.norm() < n0);
    }
}
