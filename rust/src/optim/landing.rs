//! The Landing algorithm (Ablin & Peyré 2022; Ablin et al. 2024) and the
//! LandingPC variant (Loconte et al. 2025a).
//!
//! Landing follows the *landing field* `Λ(X) = X S + λ_a ∇N(X)` (Eq. 6):
//! a loss direction tangent to the sphere of constant distance plus an
//! attraction toward the manifold, never retracting. Feasibility is only
//! asymptotic; a *step-size safeguard* keeps iterates inside the ε-ball
//! `‖X Xᵀ − I‖ ≤ ε` (default ε = 0.5, as in the reference implementation).
//!
//! Safeguard derivation (documented because published variants differ in
//! constants): with `h = X Xᵀ − I`, `R = X S` (so `X Rᵀ + R Xᵀ = 0`
//! identically) and `∇N = h X`,
//!
//! `h⁺ = (1 − 2ηλ_a) h − 2ηλ_a h² + η² Λ Λᵀ`, hence for ηλ_a ≤ ½:
//! `‖h⁺‖ ≤ (1 − 2ηλ_a)d + 2ηλ_a d² + η²‖Λ‖²`.
//!
//! Requiring the bound ≤ ε gives the quadratic safe step
//! `η* = [λ_a d(1−d) + sqrt(λ_a² d²(1−d)² + ‖Λ‖²(ε−d))] / ‖Λ‖²`,
//! and the update uses `η = min(η₀, η*)`.

use super::base::{BaseOpt, BaseOptKind};
use super::Orthoptimizer;
use crate::linalg::{matmul, matmul_a_bh, Field, Mat, Scalar};

/// Landing hyperparameters.
#[derive(Clone, Copy, Debug)]
pub struct LandingConfig {
    /// Suggested learning rate η₀.
    pub lr: f64,
    /// Manifold attraction strength λ_a (paper default 1.0).
    pub attraction: f64,
    /// Safe-ball radius ε (paper default 0.5).
    pub eps_ball: f64,
    /// Base optimizer for the loss direction (momentum in the paper's
    /// experiments; must be linear for tangent semantics).
    pub base: BaseOptKind,
    /// Whether to apply the step-size safeguard (true for Landing; false
    /// for LandingPC which instead normalizes the loss direction).
    pub safeguard: bool,
    /// LandingPC: normalize the transformed gradient to unit Frobenius
    /// norm before the geometry (per-matrix preconditioning).
    pub normalize_grad: bool,
}

impl Default for LandingConfig {
    fn default() -> Self {
        LandingConfig {
            lr: 0.1,
            attraction: 1.0,
            eps_ball: 0.5,
            base: BaseOptKind::Sgd,
            safeguard: true,
            normalize_grad: false,
        }
    }
}

impl LandingConfig {
    /// LandingPC preset (Loconte et al. 2025a): per-matrix gradient
    /// normalization, fixed step (no safeguard), tunable attraction.
    pub fn landing_pc(lr: f64, attraction: f64) -> Self {
        LandingConfig {
            lr,
            attraction,
            eps_ball: 0.5,
            base: BaseOptKind::Sgd,
            safeguard: false,
            normalize_grad: true,
        }
    }
}

/// Landing / LandingPC over Stiefel matrices of any field (real or
/// complex — §2 fn. 1: transposes become adjoints, the safeguard algebra
/// is on real norms either way).
pub struct Landing<E: Field = f32> {
    cfg: LandingConfig,
    base: BaseOpt<E>,
    name: String,
    /// Last applied (possibly safeguarded) step size, for telemetry.
    pub last_eta: f64,
}

impl<E: Field> Landing<E> {
    pub fn new(cfg: LandingConfig, n_params: usize) -> Self {
        let name = if cfg.normalize_grad && !cfg.safeguard {
            format!("LandingPC({})", cfg.base.name())
        } else {
            format!("Landing({})", cfg.base.name())
        };
        Landing { cfg, base: BaseOpt::new(cfg.base, n_params), name, last_eta: cfg.lr }
    }

    pub fn config(&self) -> &LandingConfig {
        &self.cfg
    }

    /// One landing-field update. Returns the applied η.
    pub fn update(x: &Mat<E>, g: &Mat<E>, cfg: &LandingConfig) -> (Mat<E>, f64) {
        let g = if cfg.normalize_grad {
            let n = g.norm().to_f64().max(1e-30);
            g.scale(E::from_f64(1.0 / n))
        } else {
            g.clone()
        };
        // Small-gram Riemannian direction R = ½((XXᴴ)G − (XGᴴ)X).
        let xxh = matmul_a_bh(x, x);
        let xgh = matmul_a_bh(x, &g);
        let a1 = matmul(&xxh, &g);
        let a2 = matmul(&xgh, x);
        let mut r = a1.sub(&a2);
        r.scale_inplace(E::from_f64(0.5));
        // ∇N(X) = (XXᴴ − I)X = h X.
        let mut h = xxh.clone();
        h.sub_eye_inplace();
        let ngrad = matmul(&h, x);

        let d = h.norm().to_f64();
        let lam = cfg.attraction;
        // ‖Λ‖² = ‖R‖² + λ²‖∇N‖² (the two parts are orthogonal).
        let lam_sq =
            r.norm_sq().to_f64() + lam * lam * ngrad.norm_sq().to_f64();
        let eta = if cfg.safeguard && lam_sq > 0.0 {
            let eps = cfg.eps_ball;
            let slack = (eps - d).max(0.0);
            let b = lam * d * (1.0 - d).max(0.0);
            let safe = (b + (b * b + lam_sq * slack).sqrt()) / lam_sq;
            // Also honour the ηλ ≤ ½ regime the bound assumes.
            let cap = if lam > 0.0 { 0.5 / lam } else { f64::INFINITY };
            cfg.lr.min(safe).min(cap)
        } else {
            cfg.lr
        };

        let mut xp = x.clone();
        xp.axpy(E::from_f64(-eta), &r);
        xp.axpy(E::from_f64(-eta * lam), &ngrad);
        (xp, eta)
    }
}

impl<E: Field> Orthoptimizer<E> for Landing<E> {
    fn step(&mut self, idx: usize, x: &mut Mat<E>, grad: &Mat<E>) -> anyhow::Result<()> {
        self.base.ensure_slots(idx + 1);
        let g = self.base.transform(idx, grad);
        let (xp, eta) = Landing::update(x, &g, &self.cfg);
        self.last_eta = eta;
        *x = xp;
        Ok(())
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn lr(&self) -> f64 {
        self.cfg.lr
    }

    fn set_lr(&mut self, lr: f64) {
        self.cfg.lr = lr;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifold::stiefel;
    use crate::rng::Rng;
    use crate::testing;

    type M = Mat<f64>;

    #[test]
    fn stays_in_eps_ball() {
        // The safeguard must keep every iterate within ε of the manifold
        // even under adversarially large gradients.
        let mut rng = Rng::seed_from_u64(0);
        let mut x = stiefel::random_point_t::<f64>(6, 12, &mut rng);
        let cfg = LandingConfig { lr: 1.0, ..Default::default() };
        let mut opt = Landing::<f64>::new(cfg, 1);
        for _ in 0..60 {
            let g = M::randn(6, 12, &mut rng).scale(30.0);
            opt.step(0, &mut x, &g).unwrap();
            let d = stiefel::distance_t(&x);
            assert!(d <= cfg.eps_ball + 1e-6, "left the ball: {d}");
        }
    }

    #[test]
    fn attracts_back_to_manifold_without_loss_gradient() {
        // Pure attraction: from an off-manifold start with zero gradient,
        // distance decreases monotonically toward 0.
        let mut rng = Rng::seed_from_u64(1);
        let x0 = stiefel::random_point_t::<f64>(4, 9, &mut rng);
        let mut x = x0.add(&M::randn(4, 9, &mut rng).scale(0.05));
        let cfg = LandingConfig { lr: 0.3, ..Default::default() };
        let zero = M::zeros(4, 9);
        let mut prev = stiefel::distance_t(&x);
        assert!(prev > 1e-3);
        for _ in 0..100 {
            let (xp, _) = Landing::update(&x, &zero, &cfg);
            x = xp;
            let d = stiefel::distance_t(&x);
            assert!(d <= prev + 1e-12, "distance increased {prev} → {d}");
            prev = d;
        }
        assert!(prev < 1e-6, "did not land: {prev}");
    }

    #[test]
    fn descends_pca_objective() {
        let mut rng = Rng::seed_from_u64(2);
        let p = 4;
        let n = 10;
        let a = M::randn(n, n, &mut rng);
        let mut x = stiefel::random_point_t::<f64>(p, n, &mut rng);
        let mut opt = Landing::<f64>::new(LandingConfig { lr: 0.05, ..Default::default() }, 1);
        // maximize ‖XA‖² → minimize −‖XA‖², grad = −2 X A Aᵀ.
        let aat = crate::linalg::matmul_a_bt(&a, &a);
        let loss = |x: &M| -matmul(x, &a).norm_sq();
        let l0 = loss(&x);
        for _ in 0..200 {
            let grad = matmul(&x, &aat).scale(-2.0);
            opt.step(0, &mut x, &grad).unwrap();
        }
        let l1 = loss(&x);
        assert!(l1 < l0, "no descent: {l0} → {l1}");
        assert!(stiefel::distance_t(&x) < 0.5 + 1e-9);
    }

    #[test]
    fn landing_pc_preset_normalizes() {
        let cfg = LandingConfig::landing_pc(0.5, 0.1);
        assert!(cfg.normalize_grad && !cfg.safeguard);
        // Scale invariance of the update under gradient scaling.
        let mut rng = Rng::seed_from_u64(3);
        let x = stiefel::random_point_t::<f64>(5, 8, &mut rng);
        let g = M::randn(5, 8, &mut rng);
        let (x1, _) = Landing::update(&x, &g, &cfg);
        let (x2, _) = Landing::update(&x, &g.scale(37.0), &cfg);
        assert!(x1.sub(&x2).max_abs() < 1e-12);
    }

    #[test]
    fn prop_safeguard_never_exceeds_suggested_lr() {
        testing::forall(
            "safeguarded η ≤ η₀",
            8,
            |rng| {
                let (p, n) = testing::gen_wide_shape(rng, 6, 12);
                let x = stiefel::random_point_t::<f64>(p, n, rng);
                let g = M::randn(p, n, rng).scale(rng.uniform_in(0.1, 20.0));
                (x, g)
            },
            |(x, g)| {
                let cfg = LandingConfig { lr: 0.7, ..Default::default() };
                let (_, eta) = Landing::<f64>::update(x, g, &cfg);
                testing::leq(eta, 0.7 + 1e-12, "eta")
            },
        );
    }
}
