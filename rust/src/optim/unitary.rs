//! Orthoptimizers on the *complex* Stiefel manifold (`X Xᴴ = I_p`) —
//! the setting of the squared unitary PC experiment (Fig. 8, §5.3).
//!
//! "All derivations can be easily extended to other fields like the
//! complex numbers" (paper §2, fn. 1) — and since the core update rules
//! are written once over [`Field`](crate::linalg::Field), this module is
//! now just the *instantiation*: each matmul-only method at element type
//! `Complex<S>` is the corresponding unitary optimizer. Transposes become
//! adjoints and `Skew` the skew-Hermitian projection inside the shared
//! kernels; the landing polynomial's coefficients stay *real* (they are
//! Frobenius norms / real inner products of Hermitian matrices), so the
//! quartic solve is unchanged. The hand-duplicated complex fork that used
//! to live here (~400 LoC of `CMat` update rules) is gone.
//!
//! The one genuinely complex-specific piece that remains is [`RgdC`]:
//! RGD retracts with Newton–Schulz *polar* instead of complex Householder
//! QR — both are retractions; polar keeps the substrate matmul-only. This
//! substitution is recorded in DESIGN.md.

use super::base::{BaseOpt, BaseOptKind};
use super::landing::Landing;
use super::pogo::{intermediate, Pogo};
use super::slpg::Slpg;
use crate::linalg::{polar_project, CMat, Complex, PolarOpts, Scalar};

/// The unitary-optimizer interface IS the generic [`Orthoptimizer`]
/// trait at element type `Complex<S>` — one trait, both manifolds.
pub use super::Orthoptimizer as UnitaryOptimizer;

/// A boxed unitary optimizer (what the registry hands out).
pub type BoxedUnitary<S> = Box<dyn UnitaryOptimizer<Complex<S>>>;

/// POGO on the complex Stiefel manifold: [`Pogo`] over `Complex<S>`.
pub type PogoC<S = f32> = Pogo<Complex<S>>;

/// Landing / LandingPC on the complex Stiefel manifold.
pub type LandingC<S = f32> = Landing<Complex<S>>;

/// SLPG on the complex Stiefel manifold.
pub type SlpgC<S = f32> = Slpg<Complex<S>>;

// ---------------------------------------------------------------------------
// RGD with polar retraction (complex) — the polar-retraction glue.
// ---------------------------------------------------------------------------

/// Riemannian GD on the complex Stiefel manifold, polar retraction.
pub struct RgdC<S: Scalar = f32> {
    pub lr: f64,
    base: BaseOpt<Complex<S>>,
}

impl<S: Scalar> RgdC<S> {
    pub fn new(lr: f64, base: BaseOptKind, n_params: usize) -> Self {
        RgdC { lr, base: BaseOpt::new(base, n_params) }
    }
}

impl<S: Scalar> UnitaryOptimizer<Complex<S>> for RgdC<S> {
    fn step(&mut self, idx: usize, x: &mut CMat<S>, grad: &CMat<S>) -> anyhow::Result<()> {
        self.base.ensure_slots(idx + 1);
        let g = self.base.transform(idx, grad);
        let m = intermediate(x, &g, self.lr);
        *x = polar_project(&m, PolarOpts { tol: 1e-7, max_iters: 40 });
        Ok(())
    }
    fn name(&self) -> &str {
        "RGD-C"
    }
    fn lr(&self) -> f64 {
        self.lr
    }
    fn set_lr(&mut self, lr: f64) {
        self.lr = lr;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul, matmul_ah_b, Field};
    use crate::manifold::stiefel;
    use crate::optim::pogo::{LambdaPolicy, PogoConfig};
    use crate::optim::landing::LandingConfig;
    use crate::optim::slpg::SlpgConfig;
    use crate::rng::Rng;

    type C = CMat<f64>;

    fn scale_re(m: &C, r: f64) -> C {
        m.scale(Complex::from_f64(r))
    }

    fn rand_problem(rng: &mut Rng, p: usize, n: usize) -> (C, C) {
        let x = stiefel::random_point_complex::<f64>(p, n, rng);
        let g = C::randn(p, n, rng);
        (x, g)
    }

    #[test]
    fn pogo_c_stays_on_manifold() {
        let mut rng = Rng::seed_from_u64(0);
        let (mut x, _) = rand_problem(&mut rng, 5, 11);
        let mut opt = PogoC::<f64>::new(
            PogoConfig { lr: 0.1, lambda: LambdaPolicy::Half, base: BaseOptKind::Sgd },
            1,
        );
        for _ in 0..50 {
            let g = C::randn(5, 11, &mut rng);
            let gn = g.norm();
            let g = scale_re(&g, 1.0 / gn.max(1.0)); // keep ξ < 1
            opt.step(0, &mut x, &g).unwrap();
            assert!(stiefel::distance_complex(&x) < 1e-3);
        }
    }

    #[test]
    fn pogo_c_find_root_lands() {
        // Root-solved λ must do at least as well as λ = 1/2 (the quartic
        // minimum is generically > 0, so "exactly 0" is not expected).
        let mut rng = Rng::seed_from_u64(1);
        let (x, g) = rand_problem(&mut rng, 4, 9);
        let eta = 0.6 / g.norm();
        let (xr, lam) = PogoC::update(&x, &g, eta, LambdaPolicy::FindRoot);
        let (xh, _) = PogoC::update(&x, &g, eta, LambdaPolicy::Half);
        let (dr, dh) =
            (stiefel::distance_complex(&xr), stiefel::distance_complex(&xh));
        assert!(dr <= dh + 1e-12, "root {dr} vs half {dh} (λ={lam})");
        assert!(dr < 1e-2, "λ={lam}, d={dr}");
    }

    #[test]
    fn landing_c_eps_ball() {
        let mut rng = Rng::seed_from_u64(2);
        let (mut x, _) = rand_problem(&mut rng, 4, 8);
        let mut opt = LandingC::<f64>::new(
            LandingConfig { lr: 0.8, attraction: 1.0, ..Default::default() },
            1,
        );
        for _ in 0..50 {
            let g = scale_re(&C::randn(4, 8, &mut rng), 10.0);
            opt.step(0, &mut x, &g).unwrap();
            assert!(stiefel::distance_complex(&x) <= 0.5 + 1e-6);
        }
    }

    #[test]
    fn slpg_c_feasible() {
        let mut rng = Rng::seed_from_u64(3);
        let (mut x, _) = rand_problem(&mut rng, 4, 8);
        let mut opt = SlpgC::<f64>::new(SlpgConfig { lr: 0.05, base: BaseOptKind::Sgd }, 1);
        for _ in 0..30 {
            let g = C::randn(4, 8, &mut rng);
            opt.step(0, &mut x, &g).unwrap();
            assert!(stiefel::distance_complex(&x) < 1e-2);
        }
    }

    #[test]
    fn rgd_c_exactly_feasible() {
        let mut rng = Rng::seed_from_u64(4);
        let (mut x, _) = rand_problem(&mut rng, 4, 8);
        let mut opt = RgdC::<f64>::new(0.2, BaseOptKind::Sgd, 1);
        for _ in 0..20 {
            let g = scale_re(&C::randn(4, 8, &mut rng), 3.0);
            opt.step(0, &mut x, &g).unwrap();
            assert!(stiefel::distance_complex(&x) < 1e-5);
        }
    }

    #[test]
    fn unitary_procrustes_descends() {
        // min ‖A X − B‖² over unitary X (complex Procrustes).
        let mut rng = Rng::seed_from_u64(5);
        let p = 6;
        let a = C::randn(p, p, &mut rng);
        let b = C::randn(p, p, &mut rng);
        let mut x = stiefel::random_point_complex::<f64>(p, p, &mut rng);
        let loss = |x: &C| matmul(&a, x).sub(&b).norm_sq();
        let l0 = loss(&x);
        let mut opt = PogoC::<f64>::new(
            PogoConfig { lr: 0.05, lambda: LambdaPolicy::Half, base: BaseOptKind::vadam() },
            1,
        );
        for _ in 0..300 {
            let r = matmul(&a, &x).sub(&b);
            let g = scale_re(&matmul_ah_b(&a, &r), 2.0);
            opt.step(0, &mut x, &g).unwrap();
        }
        assert!(loss(&x) < l0 * 0.5, "{l0} → {}", loss(&x));
        assert!(stiefel::distance_complex(&x) < 1e-3);
    }
}
