//! Orthoptimizers on the *complex* Stiefel manifold (`X X^H = I_p`) —
//! the setting of the squared unitary PC experiment (Fig. 8, §5.3).
//!
//! "All derivations can be easily extended to other fields like the
//! complex numbers" (paper §2, fn. 1): transposes become adjoints and
//! `Skew` becomes the skew-Hermitian projection. The landing polynomial's
//! coefficients stay *real* (they are Frobenius norms / real inner
//! products of Hermitian matrices), so the quartic solve is unchanged.
//!
//! RGD here retracts with Newton–Schulz *polar* instead of complex
//! Householder QR — both are retractions; polar keeps the substrate
//! matmul-only. This substitution is recorded in DESIGN.md.

use super::base::BaseOptKind;
use super::pogo::LambdaPolicy;
use super::quartic::solve_landing_quartic;
use crate::linalg::{polar_project_complex, CMat, PolarOpts, Scalar};

/// A unitary (complex-Stiefel) optimizer. Fallible like
/// [`crate::optim::Orthoptimizer`] (host engines never fail, but the
/// signature keeps both traits uniform). Not `Send`; see the real trait.
pub trait UnitaryOptimizer<S: Scalar = f32> {
    fn step(&mut self, idx: usize, x: &mut CMat<S>, g: &CMat<S>) -> anyhow::Result<()>;
    fn name(&self) -> &str;
    fn lr(&self) -> f64;
    fn set_lr(&mut self, lr: f64);
}

// ---------------------------------------------------------------------------
// Complex base optimizers (the linear subset of §3.1).
// ---------------------------------------------------------------------------

/// Per-parameter state for complex base optimizers.
#[derive(Clone)]
enum CState<S: Scalar> {
    None,
    Momentum(Option<CMat<S>>),
    VAdam { m: Option<CMat<S>>, v: f64, t: u64 },
}

/// Complex base optimizer (SGD / momentum / VAdam).
pub struct CBase<S: Scalar> {
    kind: BaseOptKind,
    states: Vec<CState<S>>,
}

impl<S: Scalar> CBase<S> {
    pub fn new(kind: BaseOptKind, n_params: usize) -> Self {
        assert!(kind.is_linear(), "complex base optimizers must be linear (Def. 1)");
        let init = || match kind {
            BaseOptKind::Sgd => CState::None,
            BaseOptKind::Momentum { .. } => CState::Momentum(None),
            BaseOptKind::VAdam { .. } => CState::VAdam { m: None, v: 0.0, t: 0 },
            BaseOptKind::Adam { .. } => unreachable!(),
        };
        CBase { kind, states: (0..n_params).map(|_| init()).collect() }
    }

    pub fn ensure_slots(&mut self, n: usize) {
        while self.states.len() < n {
            let s = match self.kind {
                BaseOptKind::Sgd => CState::None,
                BaseOptKind::Momentum { .. } => CState::Momentum(None),
                BaseOptKind::VAdam { .. } => CState::VAdam { m: None, v: 0.0, t: 0 },
                BaseOptKind::Adam { .. } => unreachable!(),
            };
            self.states.push(s);
        }
    }

    pub fn transform(&mut self, idx: usize, grad: &CMat<S>) -> CMat<S> {
        match (&self.kind, &mut self.states[idx]) {
            (BaseOptKind::Sgd, _) => grad.clone(),
            (BaseOptKind::Momentum { beta }, CState::Momentum(m)) => {
                match m {
                    Some(mm) => {
                        let b = S::from_f64(*beta);
                        mm.re.scale_inplace(b);
                        mm.im.scale_inplace(b);
                        mm.axpy_re(S::ONE, grad);
                    }
                    None => *m = Some(grad.clone()),
                }
                m.as_ref().unwrap().clone()
            }
            (BaseOptKind::VAdam { beta1, beta2, eps }, CState::VAdam { m, v, t }) => {
                *t += 1;
                match m {
                    Some(mm) => {
                        let b1 = S::from_f64(*beta1);
                        mm.re.scale_inplace(b1);
                        mm.im.scale_inplace(b1);
                        mm.axpy_re(S::from_f64(1.0 - *beta1), grad);
                    }
                    None => *m = Some(grad.scale_re(S::from_f64(1.0 - *beta1))),
                }
                let gn2 = grad.norm_sq().to_f64();
                *v = *beta2 * *v + (1.0 - *beta2) * gn2;
                let mhat_scale = 1.0 / (1.0 - beta1.powi(*t as i32));
                let vhat = *v / (1.0 - beta2.powi(*t as i32));
                m.as_ref().unwrap().scale_re(S::from_f64(mhat_scale / (vhat.sqrt() + *eps)))
            }
            _ => unreachable!("state/kind mismatch"),
        }
    }
}

// ---------------------------------------------------------------------------
// Shared geometry.
// ---------------------------------------------------------------------------

/// `M = X − η X SkewH(X^H G)` via the small-gram form
/// `R = ½((X X^H)G − (X G^H)X)`.
pub fn intermediate_c<S: Scalar>(x: &CMat<S>, g: &CMat<S>, eta: f64) -> CMat<S> {
    let xxh = x.matmul_a_bh(x);
    let xgh = x.matmul_a_bh(g);
    let a1 = xxh.matmul(g);
    let a2 = xgh.matmul(x);
    let mut m = x.clone();
    m.axpy_re(S::from_f64(-0.5 * eta), &a1);
    m.axpy_re(S::from_f64(0.5 * eta), &a2);
    m
}

/// Landing-polynomial coefficients from the Hermitian residual
/// `C = M M^H − I` (all real; see `optim::pogo::landing_coeffs`).
pub fn landing_coeffs_c<S: Scalar>(c: &CMat<S>) -> [f64; 5] {
    let n = {
        let mut n = c.clone();
        n.re.add_diag_inplace(S::ONE);
        n
    };
    let nc = n.matmul(c);
    let d = {
        let sum = nc.add(&nc.adjoint());
        sum.scale_re(-S::ONE)
    };
    let e = c.matmul(&nc);
    let a4 = e.dot_re(&e).to_f64();
    let a3 = 2.0 * d.dot_re(&e).to_f64();
    let a2 = d.dot_re(&d).to_f64() + 2.0 * c.dot_re(&e).to_f64();
    let a1 = 2.0 * c.dot_re(&d).to_f64();
    let a0 = c.dot_re(&c).to_f64();
    [a4, a3, a2, a1, a0]
}

/// The POGO normal step on complex matrices. Returns `(X⁺, λ)`.
pub fn normal_step_c<S: Scalar>(m: &CMat<S>, policy: LambdaPolicy) -> (CMat<S>, f64) {
    let mut c = m.matmul_a_bh(m);
    c.sub_eye_inplace();
    let lam = match policy {
        LambdaPolicy::Half => 0.5,
        LambdaPolicy::FindRoot => solve_landing_quartic(landing_coeffs_c(&c)),
    };
    let b = c.matmul(m);
    let mut xp = m.clone();
    xp.axpy_re(S::from_f64(-lam), &b);
    (xp, lam)
}

// ---------------------------------------------------------------------------
// POGO (complex).
// ---------------------------------------------------------------------------

/// POGO on the complex Stiefel manifold.
pub struct PogoC<S: Scalar = f32> {
    pub lr: f64,
    pub lambda: LambdaPolicy,
    base: CBase<S>,
    name: String,
}

impl<S: Scalar> PogoC<S> {
    pub fn new(lr: f64, lambda: LambdaPolicy, base: BaseOptKind, n_params: usize) -> Self {
        PogoC {
            lr,
            lambda,
            base: CBase::new(base, n_params),
            name: format!("POGO-C({})", base.name()),
        }
    }

    pub fn update(x: &CMat<S>, g: &CMat<S>, eta: f64, policy: LambdaPolicy) -> (CMat<S>, f64) {
        let m = intermediate_c(x, g, eta);
        normal_step_c(&m, policy)
    }
}

impl<S: Scalar> UnitaryOptimizer<S> for PogoC<S> {
    fn step(&mut self, idx: usize, x: &mut CMat<S>, grad: &CMat<S>) -> anyhow::Result<()> {
        self.base.ensure_slots(idx + 1);
        let g = self.base.transform(idx, grad);
        let (xp, _) = PogoC::update(x, &g, self.lr, self.lambda);
        *x = xp;
        Ok(())
    }
    fn name(&self) -> &str {
        &self.name
    }
    fn lr(&self) -> f64 {
        self.lr
    }
    fn set_lr(&mut self, lr: f64) {
        self.lr = lr;
    }
}

// ---------------------------------------------------------------------------
// Landing (complex), with the same safeguard as the real case.
// ---------------------------------------------------------------------------

/// Landing / LandingPC on the complex Stiefel manifold.
pub struct LandingC<S: Scalar = f32> {
    pub lr: f64,
    pub attraction: f64,
    pub eps_ball: f64,
    pub safeguard: bool,
    pub normalize_grad: bool,
    base: CBase<S>,
    name: String,
}

impl<S: Scalar> LandingC<S> {
    pub fn new(lr: f64, attraction: f64, base: BaseOptKind, n_params: usize) -> Self {
        LandingC {
            lr,
            attraction,
            eps_ball: 0.5,
            safeguard: true,
            normalize_grad: false,
            base: CBase::new(base, n_params),
            name: "Landing-C".into(),
        }
    }

    /// LandingPC preset (normalized gradient, fixed step).
    pub fn landing_pc(lr: f64, attraction: f64, n_params: usize) -> Self {
        LandingC {
            lr,
            attraction,
            eps_ball: 0.5,
            safeguard: false,
            normalize_grad: true,
            base: CBase::new(BaseOptKind::Sgd, n_params),
            name: "LandingPC-C".into(),
        }
    }
}

impl<S: Scalar> UnitaryOptimizer<S> for LandingC<S> {
    fn step(&mut self, idx: usize, x: &mut CMat<S>, grad: &CMat<S>) -> anyhow::Result<()> {
        self.base.ensure_slots(idx + 1);
        let mut g = self.base.transform(idx, grad);
        if self.normalize_grad {
            let n = g.norm().to_f64().max(1e-30);
            g = g.scale_re(S::from_f64(1.0 / n));
        }
        // R = ½((XX^H)G − (XG^H)X); ∇N = (XX^H − I)X.
        let xxh = x.matmul_a_bh(x);
        let xgh = x.matmul_a_bh(&g);
        let a1 = xxh.matmul(&g);
        let a2 = xgh.matmul(x);
        let mut r = a1.sub(&a2);
        r.re.scale_inplace(S::from_f64(0.5));
        r.im.scale_inplace(S::from_f64(0.5));
        let mut h = xxh;
        h.sub_eye_inplace();
        let ngrad = h.matmul(x);

        let d = h.norm().to_f64();
        let lam = self.attraction;
        let lam_sq = r.norm_sq().to_f64() + lam * lam * ngrad.norm_sq().to_f64();
        let eta = if self.safeguard && lam_sq > 0.0 {
            let slack = (self.eps_ball - d).max(0.0);
            let b = lam * d * (1.0 - d).max(0.0);
            let safe = (b + (b * b + lam_sq * slack).sqrt()) / lam_sq;
            let cap = if lam > 0.0 { 0.5 / lam } else { f64::INFINITY };
            self.lr.min(safe).min(cap)
        } else {
            self.lr
        };

        x.axpy_re(S::from_f64(-eta), &r);
        x.axpy_re(S::from_f64(-eta * lam), &ngrad);
        Ok(())
    }
    fn name(&self) -> &str {
        &self.name
    }
    fn lr(&self) -> f64 {
        self.lr
    }
    fn set_lr(&mut self, lr: f64) {
        self.lr = lr;
    }
}

// ---------------------------------------------------------------------------
// SLPG (complex).
// ---------------------------------------------------------------------------

/// SLPG on the complex Stiefel manifold.
pub struct SlpgC<S: Scalar = f32> {
    pub lr: f64,
    base: CBase<S>,
}

impl<S: Scalar> SlpgC<S> {
    pub fn new(lr: f64, n_params: usize) -> Self {
        SlpgC { lr, base: CBase::new(BaseOptKind::Sgd, n_params) }
    }
}

impl<S: Scalar> UnitaryOptimizer<S> for SlpgC<S> {
    fn step(&mut self, idx: usize, x: &mut CMat<S>, grad: &CMat<S>) -> anyhow::Result<()> {
        self.base.ensure_slots(idx + 1);
        let g = self.base.transform(idx, grad);
        // Y = X − η(G − Sym_H(G X^H) X), Sym_H(A) = (A + A^H)/2.
        let gxh = g.matmul_a_bh(x);
        let sym = {
            let s = gxh.add(&gxh.adjoint());
            s.scale_re(S::from_f64(0.5))
        };
        let sx = sym.matmul(x);
        let mut y = x.clone();
        y.axpy_re(S::from_f64(-self.lr), &g);
        y.axpy_re(S::from_f64(self.lr), &sx);
        // Normal step with λ = 1/2.
        let (xp, _) = normal_step_c(&y, LambdaPolicy::Half);
        *x = xp;
        Ok(())
    }
    fn name(&self) -> &str {
        "SLPG-C"
    }
    fn lr(&self) -> f64 {
        self.lr
    }
    fn set_lr(&mut self, lr: f64) {
        self.lr = lr;
    }
}

// ---------------------------------------------------------------------------
// RGD with polar retraction (complex).
// ---------------------------------------------------------------------------

/// Riemannian GD on the complex Stiefel manifold, polar retraction.
pub struct RgdC<S: Scalar = f32> {
    pub lr: f64,
    base: CBase<S>,
}

impl<S: Scalar> RgdC<S> {
    pub fn new(lr: f64, n_params: usize) -> Self {
        RgdC { lr, base: CBase::new(BaseOptKind::Sgd, n_params) }
    }
}

impl<S: Scalar> UnitaryOptimizer<S> for RgdC<S> {
    fn step(&mut self, idx: usize, x: &mut CMat<S>, grad: &CMat<S>) -> anyhow::Result<()> {
        self.base.ensure_slots(idx + 1);
        let g = self.base.transform(idx, grad);
        let m = intermediate_c(x, &g, self.lr);
        *x = polar_project_complex(&m, PolarOpts { tol: 1e-7, max_iters: 40 });
        Ok(())
    }
    fn name(&self) -> &str {
        "RGD-C"
    }
    fn lr(&self) -> f64 {
        self.lr
    }
    fn set_lr(&mut self, lr: f64) {
        self.lr = lr;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifold::stiefel;
    use crate::rng::Rng;

    type C = CMat<f64>;

    fn rand_problem(rng: &mut Rng, p: usize, n: usize) -> (C, C) {
        let x = stiefel::random_point_complex::<f64>(p, n, rng);
        let g = C::randn(p, n, rng);
        (x, g)
    }

    #[test]
    fn pogo_c_stays_on_manifold() {
        let mut rng = Rng::seed_from_u64(0);
        let (mut x, _) = rand_problem(&mut rng, 5, 11);
        let mut opt = PogoC::<f64>::new(0.1, LambdaPolicy::Half, BaseOptKind::Sgd, 1);
        for _ in 0..50 {
            let g = C::randn(5, 11, &mut rng);
            let gn = g.norm().to_f64();
            let g = g.scale_re(1.0 / gn.max(1.0)); // keep ξ < 1
            opt.step(0, &mut x, &g).unwrap();
            assert!(stiefel::distance_complex(&x) < 1e-3);
        }
    }

    #[test]
    fn pogo_c_find_root_lands() {
        // Root-solved λ must do at least as well as λ = 1/2 (the quartic
        // minimum is generically > 0, so "exactly 0" is not expected).
        let mut rng = Rng::seed_from_u64(1);
        let (x, g) = rand_problem(&mut rng, 4, 9);
        let eta = 0.6 / g.norm().to_f64();
        let (xr, lam) = PogoC::update(&x, &g, eta, LambdaPolicy::FindRoot);
        let (xh, _) = PogoC::update(&x, &g, eta, LambdaPolicy::Half);
        let (dr, dh) =
            (stiefel::distance_complex(&xr), stiefel::distance_complex(&xh));
        assert!(dr <= dh + 1e-12, "root {dr} vs half {dh} (λ={lam})");
        assert!(dr < 1e-2, "λ={lam}, d={dr}");
    }

    #[test]
    fn landing_c_eps_ball() {
        let mut rng = Rng::seed_from_u64(2);
        let (mut x, _) = rand_problem(&mut rng, 4, 8);
        let mut opt = LandingC::<f64>::new(0.8, 1.0, BaseOptKind::Sgd, 1);
        for _ in 0..50 {
            let g = C::randn(4, 8, &mut rng).scale_re(10.0);
            opt.step(0, &mut x, &g).unwrap();
            assert!(stiefel::distance_complex(&x) <= 0.5 + 1e-6);
        }
    }

    #[test]
    fn slpg_c_feasible() {
        let mut rng = Rng::seed_from_u64(3);
        let (mut x, _) = rand_problem(&mut rng, 4, 8);
        let mut opt = SlpgC::<f64>::new(0.05, 1);
        for _ in 0..30 {
            let g = C::randn(4, 8, &mut rng);
            opt.step(0, &mut x, &g).unwrap();
            assert!(stiefel::distance_complex(&x) < 1e-2);
        }
    }

    #[test]
    fn rgd_c_exactly_feasible() {
        let mut rng = Rng::seed_from_u64(4);
        let (mut x, _) = rand_problem(&mut rng, 4, 8);
        let mut opt = RgdC::<f64>::new(0.2, 1);
        for _ in 0..20 {
            let g = C::randn(4, 8, &mut rng).scale_re(3.0);
            opt.step(0, &mut x, &g).unwrap();
            assert!(stiefel::distance_complex(&x) < 1e-5);
        }
    }

    #[test]
    fn unitary_procrustes_descends() {
        // min ‖A X − B‖² over unitary X (complex Procrustes).
        let mut rng = Rng::seed_from_u64(5);
        let p = 6;
        let a = C::randn(p, p, &mut rng);
        let b = C::randn(p, p, &mut rng);
        let mut x = stiefel::random_point_complex::<f64>(p, p, &mut rng);
        let loss = |x: &C| a.matmul(x).sub(&b).norm_sq().to_f64();
        let l0 = loss(&x);
        let mut opt = PogoC::<f64>::new(0.05, LambdaPolicy::Half, BaseOptKind::vadam(), 1);
        for _ in 0..300 {
            let r = a.matmul(&x).sub(&b);
            let g = a.matmul_ah_b(&r).scale_re(2.0);
            opt.step(0, &mut x, &g).unwrap();
        }
        assert!(loss(&x) < l0 * 0.5, "{l0} → {}", loss(&x));
        assert!(stiefel::distance_complex(&x) < 1e-3);
    }

    #[test]
    fn cbase_rejects_nonlinear() {
        let result = std::panic::catch_unwind(|| {
            CBase::<f64>::new(BaseOptKind::adam(), 1);
        });
        assert!(result.is_err());
    }
}
