//! Synthetic datasets (DESIGN.md §Substitutions: no network access on this
//! image, so CIFAR-10/MNIST are replaced by deterministic generators that
//! exercise identical code paths and preserve relative optimizer ordering).

pub mod cifar_like;
pub mod corpus;
pub mod mnist_like;
