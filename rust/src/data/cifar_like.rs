//! Synthetic CIFAR-10-like image classification dataset.
//!
//! 10 classes, 32×32×3 float images. Each class owns a deterministic
//! low-frequency template (2-D sinusoid mixtures per channel) that is
//! randomly shifted, amplitude-jittered and noised per sample — enough
//! structure for a small CNN/ViT to climb well above chance within a few
//! hundred steps, with difficulty controlled by `noise`.

use crate::rng::Rng;

pub const HW: usize = 32;
pub const CHANNELS: usize = 3;
pub const CLASSES: usize = 10;
pub const PIXELS: usize = HW * HW * CHANNELS;

/// The dataset generator (templates fixed by the dataset seed).
pub struct CifarLike {
    /// Per class, per channel: (fx, fy, phase, amplitude) of 3 sinusoids.
    templates: Vec<[[f32; 4]; 9]>,
    noise: f32,
    rng: Rng,
}

impl CifarLike {
    pub fn new(seed: u64, noise: f32) -> Self {
        let mut trng = Rng::seed_from_u64(seed ^ 0xC1FA_u64);
        let templates = (0..CLASSES * CHANNELS)
            .map(|_| {
                let mut t = [[0.0f32; 4]; 9];
                for s in t.iter_mut() {
                    s[0] = (1 + trng.index(4)) as f32; // fx ∈ 1..4
                    s[1] = (1 + trng.index(4)) as f32; // fy
                    s[2] = trng.uniform_in(0.0, std::f64::consts::TAU) as f32;
                    s[3] = trng.uniform_in(0.3, 1.0) as f32;
                }
                t
            })
            .collect();
        CifarLike { templates, noise, rng: Rng::seed_from_u64(seed) }
    }

    /// Render one image of `class` into `out` (length PIXELS, HWC order).
    fn render(&mut self, class: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), PIXELS);
        let shift_x = self.rng.uniform_in(0.0, std::f64::consts::TAU) as f32;
        let shift_y = self.rng.uniform_in(0.0, std::f64::consts::TAU) as f32;
        let amp = self.rng.uniform_in(0.7, 1.3) as f32;
        for c in 0..CHANNELS {
            let tpl = &self.templates[class * CHANNELS + c];
            for y in 0..HW {
                for x in 0..HW {
                    let (xf, yf) = (
                        x as f32 / HW as f32 * std::f32::consts::TAU,
                        y as f32 / HW as f32 * std::f32::consts::TAU,
                    );
                    let mut v = 0.0f32;
                    for s in tpl {
                        v += s[3] * (s[0] * xf + shift_x).sin() * (s[1] * yf + shift_y + s[2]).cos();
                    }
                    let noise = self.noise * self.rng.gaussian() as f32;
                    out[(y * HW + x) * CHANNELS + c] = amp * v / 3.0 + noise;
                }
            }
        }
    }

    /// Sample a batch: returns (images flat `B*32*32*3` HWC, labels `B`).
    pub fn batch(&mut self, b: usize) -> (Vec<f32>, Vec<i32>) {
        let mut images = vec![0.0f32; b * PIXELS];
        let mut labels = Vec::with_capacity(b);
        for i in 0..b {
            let class = self.rng.index(CLASSES);
            labels.push(class as i32);
            self.render(class, &mut images[i * PIXELS..(i + 1) * PIXELS]);
        }
        (images, labels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_shapes_and_ranges() {
        let mut ds = CifarLike::new(0, 0.1);
        let (imgs, labels) = ds.batch(8);
        assert_eq!(imgs.len(), 8 * PIXELS);
        assert_eq!(labels.len(), 8);
        assert!(labels.iter().all(|&l| (0..10).contains(&l)));
        assert!(imgs.iter().all(|v| v.is_finite()));
        // Values roughly standardized.
        let mean: f32 = imgs.iter().sum::<f32>() / imgs.len() as f32;
        assert!(mean.abs() < 0.5, "mean {mean}");
    }

    #[test]
    fn classes_are_distinguishable() {
        // Same class renders correlate more than different classes
        // (averaged over samples — the signal a classifier learns).
        let mut ds = CifarLike::new(1, 0.02);
        let mut img = vec![0.0f32; PIXELS];
        let mut render = |c: usize| {
            ds.render(c, &mut img);
            img.clone()
        };
        let corr = |a: &[f32], b: &[f32]| {
            let dot: f32 = a.iter().zip(b).map(|(x, y)| x * y).sum();
            let na: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt();
            let nb: f32 = b.iter().map(|x| x * x).sum::<f32>().sqrt();
            (dot / (na * nb)).abs()
        };
        let mut same = 0.0;
        let mut diff = 0.0;
        let n = 8;
        for _ in 0..n {
            let a0 = render(0);
            let a1 = render(0);
            let b0 = render(5);
            same += corr(&a0, &a1);
            diff += corr(&a0, &b0);
        }
        assert!(same / n as f32 > diff / n as f32,
                "same {same} not more correlated than diff {diff}");
    }

    #[test]
    fn deterministic_given_seed() {
        let (a, la) = CifarLike::new(7, 0.1).batch(4);
        let (b, lb) = CifarLike::new(7, 0.1).batch(4);
        assert_eq!(la, lb);
        assert_eq!(a, b);
    }
}
