//! Synthetic character corpus for the e2e transformer example.
//!
//! A second-order Markov chain over a 64-token alphabet whose transition
//! table is built from a bank of deterministic "phrases": the generated
//! stream has strong local structure (bigram/trigram regularities and
//! repeated motifs), so a small LM's loss drops well below ln(64) within a
//! few hundred steps — a visible learning curve, which is what the e2e
//! driver must demonstrate.

use crate::rng::Rng;

pub const VOCAB: usize = 64;

/// Markov-chain corpus sampler.
pub struct Corpus {
    /// next[a][b] = candidate successors of bigram (a, b).
    next: Vec<[u8; 4]>,
    rng: Rng,
    state: (u8, u8),
}

impl Corpus {
    pub fn new(seed: u64) -> Self {
        let mut trng = Rng::seed_from_u64(seed ^ 0xC0_27);
        // For every bigram, a small successor set (skewed sampling below
        // makes some successors much likelier → learnable structure).
        let next = (0..VOCAB * VOCAB)
            .map(|_| {
                [
                    trng.index(VOCAB) as u8,
                    trng.index(VOCAB) as u8,
                    trng.index(VOCAB) as u8,
                    trng.index(VOCAB) as u8,
                ]
            })
            .collect();
        Corpus { next, rng: Rng::seed_from_u64(seed), state: (0, 1) }
    }

    fn step(&mut self) -> u8 {
        let cand = &self.next[self.state.0 as usize * VOCAB + self.state.1 as usize];
        // Zipf-ish choice over the 4 successors: 0.62/0.22/0.11/0.05.
        let u = self.rng.uniform();
        let c = if u < 0.62 {
            cand[0]
        } else if u < 0.84 {
            cand[1]
        } else if u < 0.95 {
            cand[2]
        } else {
            cand[3]
        };
        self.state = (self.state.1, c);
        c
    }

    /// Sample a batch of token sequences, flattened (B × len) i32.
    pub fn batch(&mut self, b: usize, len: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(b * len);
        for _ in 0..b {
            // Random restart per sequence for diversity.
            self.state =
                (self.rng.index(VOCAB) as u8, self.rng.index(VOCAB) as u8);
            for _ in 0..len {
                out.push(self.step() as i32);
            }
        }
        out
    }

    /// The chain's conditional entropy in nats/token (the achievable LM
    /// loss floor): H = −Σ p log p over the fixed successor distribution.
    pub fn entropy_floor_nats(&self) -> f64 {
        let ps = [0.62f64, 0.22, 0.11, 0.05];
        -ps.iter().map(|p| p * p.ln()).sum::<f64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_in_vocab_range() {
        let mut c = Corpus::new(0);
        let toks = c.batch(4, 100);
        assert_eq!(toks.len(), 400);
        assert!(toks.iter().all(|&t| (0..VOCAB as i32).contains(&t)));
    }

    #[test]
    fn has_structure_below_uniform_entropy() {
        // Empirical bigram-conditional entropy must be well under ln(64).
        let mut c = Corpus::new(1);
        let toks = c.batch(1, 200_000);
        let mut counts = vec![0u32; VOCAB * VOCAB * VOCAB];
        for w in toks.windows(3) {
            counts[(w[0] as usize * VOCAB + w[1] as usize) * VOCAB + w[2] as usize] += 1;
        }
        let total: u32 = counts.iter().sum();
        // H = Σ_ctx (n_ctx/N) Σ_c −p log p.
        let mut h2 = 0.0f64;
        for ctx in 0..VOCAB * VOCAB {
            let slice = &counts[ctx * VOCAB..(ctx + 1) * VOCAB];
            let n: u32 = slice.iter().sum();
            if n == 0 {
                continue;
            }
            let w = n as f64 / total as f64;
            let mut hc = 0.0;
            for &cnt in slice {
                if cnt > 0 {
                    let p = cnt as f64 / n as f64;
                    hc -= p * p.ln();
                }
            }
            h2 += w * hc;
        }
        assert!(h2 < 2.0, "conditional entropy {h2} (uniform would be {})",
                (VOCAB as f64).ln());
        assert!(h2 > 0.5, "suspiciously deterministic: {h2}");
    }

    #[test]
    fn entropy_floor_reasonable() {
        let c = Corpus::new(2);
        let h = c.entropy_floor_nats();
        assert!(h > 0.5 && h < 1.5, "{h}");
    }
}
