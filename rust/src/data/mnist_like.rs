//! Synthetic binary dataset for the Born-machine (Fig. 8) experiment.
//!
//! T-bit strings drawn from a mixture of K prototype patterns with
//! independent bit-flip noise: a distribution with real structure
//! (entropy well below T bits) so a density model can reach bpd < 1,
//! while remaining trivially sampleable and reproducible.

use crate::rng::Rng;

/// Mixture-of-prototypes binary source.
pub struct MnistLike {
    prototypes: Vec<Vec<u8>>,
    flip_p: f64,
    t_bits: usize,
    rng: Rng,
}

impl MnistLike {
    pub fn new(seed: u64, t_bits: usize, k_prototypes: usize, flip_p: f64) -> Self {
        let mut prng = Rng::seed_from_u64(seed ^ 0x3157);
        let prototypes = (0..k_prototypes)
            .map(|_| (0..t_bits).map(|_| prng.bernoulli(0.5) as u8).collect())
            .collect();
        MnistLike { prototypes, flip_p, t_bits, rng: Rng::seed_from_u64(seed) }
    }

    /// Ground-truth entropy rate upper bound in bits/dim: H(mixture) ≤
    /// log2(K)/T + H(flip). Useful as the bpd target line in Fig. 8.
    pub fn entropy_bound_bpd(&self) -> f64 {
        let h_flip = if self.flip_p > 0.0 && self.flip_p < 1.0 {
            let p = self.flip_p;
            -(p * p.log2() + (1.0 - p) * (1.0 - p).log2())
        } else {
            0.0
        };
        (self.prototypes.len() as f64).log2() / self.t_bits as f64 + h_flip
    }

    /// Sample a batch of bit strings, flattened (B × T) i32 in {0, 1}.
    pub fn batch(&mut self, b: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(b * self.t_bits);
        for _ in 0..b {
            let proto = &self.prototypes[self.rng.index(self.prototypes.len())];
            for &bit in proto {
                let flipped = if self.rng.bernoulli(self.flip_p) { 1 - bit } else { bit };
                out.push(flipped as i32);
            }
        }
        out
    }

    pub fn t_bits(&self) -> usize {
        self.t_bits
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_is_binary_with_right_shape() {
        let mut ds = MnistLike::new(0, 16, 4, 0.05);
        let b = ds.batch(32);
        assert_eq!(b.len(), 32 * 16);
        assert!(b.iter().all(|&v| v == 0 || v == 1));
    }

    #[test]
    fn low_flip_concentrates_near_prototypes() {
        let mut ds = MnistLike::new(1, 16, 2, 0.01);
        let batch = ds.batch(64);
        // With K=2, samples cluster into ≤2 hamming balls: count distinct
        // patterns; should be far fewer than 64.
        let mut set = std::collections::BTreeSet::new();
        for i in 0..64 {
            set.insert(batch[i * 16..(i + 1) * 16].to_vec());
        }
        assert!(set.len() < 40, "too diffuse: {} distinct", set.len());
    }

    #[test]
    fn entropy_bound_sane() {
        let ds = MnistLike::new(2, 16, 4, 0.05);
        let h = ds.entropy_bound_bpd();
        assert!(h > 0.0 && h < 1.0, "bpd bound {h}");
    }
}
